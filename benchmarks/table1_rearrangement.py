"""Paper Table 1: rearrangement threshold vs cost and latency effect.

We pour `threshold` vectors into ONE cluster (the paper's hot-list
scenario), measure search latency before, the rearrangement cost, and
search latency after.  Thresholds are scaled (CPU) but span the same 10x
range as the paper's {10k, 50k, 100k}.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from benchmarks.common import timed
from repro.core import build_ivf
from repro.data.synthetic import sift_like

THRESHOLDS = (2_000, 10_000, 20_000)  # paper: 10k/50k/100k, CPU-scaled /5


def run():
    rows = []
    dim = 128
    for thr in THRESHOLDS:
        base = sift_like(4000, dim, seed=1)
        idx = build_ivf(
            base, n_clusters=8, block_size=64, max_chain=1024,
            capacity_vectors=8 * (4000 + thr), nprobe=8, k=10,
            rearrange_threshold=thr - 1, add_batch=2048,
        )
        # hot list: every new vector lands in one cluster (constant target)
        target = np.asarray(idx.state.centroids)[3]
        hot = np.tile(target, (thr, 1)).astype(np.float32)
        hot += 0.05 * np.random.default_rng(2).normal(size=hot.shape).astype(np.float32)
        for off in range(0, thr, 2048):
            idx.add(hot[off : off + 2048])
        q = base[:10]
        before_s = timed(lambda: idx.search(q), iters=9)
        t0 = time.perf_counter()
        passes = idx.maybe_rearrange(max_passes=4)
        jax.block_until_ready(idx.state.pool_payload)
        cost_s = time.perf_counter() - t0
        after_s = timed(lambda: idx.search(q), iters=9)
        rows.append({
            "threshold": thr,
            "latency_before_ms": round(before_s * 1e3, 3),
            "rearrange_cost_ms": round(cost_s * 1e3, 3),
            "latency_after_ms": round(after_s * 1e3, 3),
            "passes": passes,
        })
    return rows


def main():
    rows = run()
    print("threshold,latency_before_ms,rearrange_cost_ms,latency_after_ms,passes")
    for r in rows:
        print(",".join(str(r[k]) for k in r))
    return rows


if __name__ == "__main__":
    main()
