"""Durability artifact: snapshot cost, WAL replay rate, measured RPO/RTO.

Drives acked mutation traffic against a persistent ``ServingRuntime``,
takes mid-stream snapshots, then crashes the hard way — the runtime object
is abandoned without ``stop()``, so the durable state is exactly what hit
the filesystem — and recovers:

* **snapshot cost** — wall time of ``snapshot(wait=True)`` (barrier +
  checkpoint publish + WAL prune) at several live sizes, plus the
  on-disk snapshot bytes;
* **WAL replay rate** — a pure ``recover_index`` pass (recovery never
  writes the persist dir, so it is repeatable) timed end-to-end:
  records/s and rows/s over the replayed tail;
* **RPO** — every row acked before the crash is present, bit-exact, in
  the recovered index (the fsync-per-batch default's claim: **0 acked
  rows lost**, measured, not asserted from theory);
* **RTO** — wall time of ``ServingRuntime.recover`` (verified recovery +
  post-recovery snapshot) to a serving-ready runtime, and search parity
  between the pre-crash and recovered runtimes on the same queries.

The ISSUE's acceptance bar is asserted in-script: recovery verifies, the
acked-row loss count is exactly 0, every logged record past the fence
replays, and recovered top-10 search results overlap the pre-crash
results within 0.5%.

Writes ``BENCH_recovery.json`` at the repo root when run as a script.
"""

from __future__ import annotations

import json
import os
import pathlib
import tempfile
import time

import numpy as np

try:
    from benchmarks.common import provenance
except ImportError:  # run as `python benchmarks/recovery.py`
    import sys as _sys
    _sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import provenance

from repro.core import build_ivf
from repro.core.block_pool import NULL
from repro.core.runtime import RuntimeConfig, ServingRuntime
from repro.persist import SNAP_SUBDIR, WAL_SUBDIR, recover_index

DIM = 32
N0 = 4000
N_CLUSTERS = 8
BATCH_ROWS = 64  # rows per acked mutation batch
SNAP_EVERY = 16  # batches between mid-stream snapshots
N_BATCHES = 64  # acked traffic after the last warmup
Q = 32  # parity probe queries
K = 10


def _make_runtime(persist_dir: str):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(N0, DIM)).astype(np.float32)
    idx = build_ivf(
        x, n_clusters=N_CLUSTERS, block_size=64, max_chain=64,
        nprobe=4, k=K, capacity_vectors=4 * N0, add_batch=512,
    )
    rt = ServingRuntime(
        idx,
        RuntimeConfig(
            mode="parallel", nprobe=4, k=K, flush_min=BATCH_ROWS,
            flush_interval=0.05, persist_dir=persist_dir,
            wal_sync_interval=1,  # the RPO = 0 configuration under test
        ),
    )
    return rt, idx.cfg


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


def _drive(rt, oracle: dict, n_batches: int, seed: int,
           snap_every: int = 0):
    """Acked insert/delete/update traffic; every ack lands in ``oracle``
    (id -> vector) before the next submit — the host-side truth recovery
    is measured against."""
    rng = np.random.default_rng(seed)
    snap_times = []
    for b in range(n_batches):
        if snap_every and b and b % snap_every == 0:
            t0 = time.perf_counter()
            rt.snapshot(wait=True)
            snap_times.append(time.perf_counter() - t0)
        r = rng.random()
        if r < 0.6 or len(oracle) < 2 * BATCH_ROWS:
            vecs = rng.normal(size=(BATCH_ROWS, DIM)).astype(np.float32)
            ids = rt.submit_insert(vecs).result(timeout=120)
            for i, vid in enumerate(ids):
                oracle[int(vid)] = vecs[i]
        elif r < 0.8:
            pick = rng.choice(sorted(oracle), size=BATCH_ROWS // 2,
                              replace=False).astype(np.int32)
            rt.submit_delete(pick).result(timeout=120)
            for vid in pick:
                del oracle[int(vid)]
        else:
            pick = rng.choice(sorted(oracle), size=BATCH_ROWS // 2,
                              replace=False).astype(np.int32)
            vecs = rng.normal(size=(len(pick), DIM)).astype(np.float32)
            rt.submit_update(vecs, pick).result(timeout=120)
            for i, vid in enumerate(pick):
                oracle[int(vid)] = vecs[i]
    return snap_times


def _live_vectors(index) -> dict:
    st, cfg = index.state, index.pool_cfg
    id_map = np.asarray(st.id_map)
    live = np.asarray(st.pool_live)
    pay = np.asarray(st.pool_payload)
    out = {}
    for vid in np.flatnonzero(id_map != NULL):
        blk, off = divmod(int(id_map[vid]), cfg.block_size)
        if live[blk, off]:
            out[int(vid)] = pay[blk, off]
    return out


def _overlap(a: np.ndarray, b: np.ndarray) -> float:
    """Mean per-query top-K id overlap between two [Q, K] result sets."""
    return float(np.mean([
        len(set(map(int, ra)) & set(map(int, rb))) / K
        for ra, rb in zip(a, b)
    ]))


def main():
    tmp = tempfile.mkdtemp(prefix="bench_recovery_")
    rt, icfg = _make_runtime(tmp)
    oracle: dict = {}
    queries = np.random.default_rng(9).normal(
        size=(Q, DIM)).astype(np.float32)

    # warmup: pay the mutation/search compiles outside every measurement
    _drive(rt, oracle, n_batches=3, seed=1)
    rt.submit_search(queries).result(timeout=120)

    snap_times = _drive(
        rt, oracle, n_batches=N_BATCHES, seed=2, snap_every=SNAP_EVERY
    )
    pre_crash_ids = rt.submit_search(queries).result(timeout=120)[1]
    acked = dict(oracle)  # frozen at the crash point
    stats = rt.stats()
    # ---- crash: abandon the runtime; disk is all that survives ----------
    del rt

    wal_bytes = _dir_bytes(os.path.join(tmp, WAL_SUBDIR))
    snap_bytes = _dir_bytes(os.path.join(tmp, SNAP_SUBDIR))

    # pure recovery pass: snapshot load + WAL replay + verification
    t0 = time.perf_counter()
    index, report = recover_index(icfg, tmp)
    t_replay = time.perf_counter() - t0

    # ---- RPO: acked rows missing from the recovered state ---------------
    recovered = _live_vectors(index)
    missing = [vid for vid in acked if vid not in recovered]
    mismatched = [
        vid for vid in acked
        if vid in recovered
        and not np.array_equal(recovered[vid], acked[vid])
    ]

    # serving RTO: verified recovery -> a runtime accepting traffic
    t0 = time.perf_counter()
    rt2 = ServingRuntime.recover(icfg, tmp, cfg=RuntimeConfig(
        mode="parallel", nprobe=4, k=K, flush_min=BATCH_ROWS,
        flush_interval=0.05,
    ))
    t_rto = time.perf_counter() - t0
    post_ids = rt2.submit_search(queries).result(timeout=120)[1]
    parity = _overlap(pre_crash_ids, post_ids)
    rt2.stop()

    # ---- the ISSUE's acceptance bar, asserted in-script ------------------
    assert report.verified, "recovery did not verify"
    assert not missing and not mismatched, (
        f"RPO violated: {len(missing)} acked rows lost, "
        f"{len(mismatched)} corrupted"
    )
    assert report.last_lsn == stats["applied_lsn"], (
        f"replay stopped at lsn {report.last_lsn}, "
        f"pre-crash applied lsn was {stats['applied_lsn']}"
    )
    assert parity >= 0.995, f"top-{K} parity {parity:.4f} < 0.995"
    assert snap_times, "no mid-stream snapshot was measured"

    result = {
        "meta": {
            "schema": {
                "snapshot_s": "wall time of snapshot(wait=True): barrier "
                              "+ checkpoint publish + WAL prune, at "
                              f"every {SNAP_EVERY}th acked batch",
                "replay_records_per_s": "WAL records replayed / pure "
                                        "recover_index wall time (includes "
                                        "snapshot load + verification)",
                "rpo_acked_rows_lost": "acked-before-crash rows absent or "
                                       "bit-different after recovery "
                                       "(asserted == 0)",
                "rto_s": "ServingRuntime.recover wall time to a verified, "
                         "serving-ready runtime (includes the "
                         "post-recovery snapshot)",
                "search_parity": f"mean per-query top-{K} id overlap, "
                                 "pre-crash vs recovered (asserted "
                                 ">= 0.995)",
            },
            "workload": {
                "batch_rows": BATCH_ROWS,
                "acked_batches": N_BATCHES,
                "mix": "60% insert / 20% delete / 20% update",
                "wal_sync_interval": 1,
            },
        },
        "snapshot": {
            "count": len(snap_times),
            "snapshot_s_mean": float(np.mean(snap_times)),
            "snapshot_s_max": float(np.max(snap_times)),
            "snapshot_dir_bytes": snap_bytes,
        },
        "replay": {
            "wal_dir_bytes": wal_bytes,
            "wal_segments": report.wal_segments,
            "replayed_records": report.replayed_records,
            "replayed_rows": report.replayed_rows,
            "recover_s": t_replay,
            "replay_records_per_s": report.replayed_records / t_replay,
            "replay_rows_per_s": report.replayed_rows / t_replay,
            "torn_tail": report.torn_tail,
        },
        "rpo_rto": {
            "acked_rows_at_crash": len(acked),
            "rpo_acked_rows_lost": len(missing) + len(mismatched),
            "rto_s": t_rto,
            "search_parity": parity,
            "snapshot_lsn": report.snapshot_lsn,
            "last_lsn": report.last_lsn,
        },
    }
    print("section,metric,value")
    print(f"snapshot,mean_s,{result['snapshot']['snapshot_s_mean']:.4f}")
    print(f"replay,records_per_s,"
          f"{result['replay']['replay_records_per_s']:.1f}")
    print(f"replay,rows_per_s,{result['replay']['replay_rows_per_s']:.0f}")
    print(f"rpo,acked_rows_lost,{result['rpo_rto']['rpo_acked_rows_lost']}")
    print(f"rto,seconds,{t_rto:.3f}")
    print(f"parity,top{K}_overlap,{parity:.4f}")
    result["provenance"] = provenance(
        "recovery",
        geometry={"dim": DIM, "corpus": N0, "n_clusters": N_CLUSTERS,
                  "batch_rows": BATCH_ROWS},
        samples={"acked_batches": N_BATCHES, "snap_every": SNAP_EVERY,
                 "parity_queries": Q},
    )
    out = pathlib.Path(__file__).resolve().parent.parent / \
        "BENCH_recovery.json"
    out.write_text(json.dumps(result, indent=1))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
