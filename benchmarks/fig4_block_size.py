"""Paper Fig. 4: memory-block size T_m vs latency and pad memory.

Sweeps T_m; reports search+insert latency and the padded (reserved but
unused) pool memory — reproducing the paper's conclusion that latency
improves with block size with diminishing returns past ~1024, while pad
memory grows.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core import build_ivf
from repro.data.synthetic import sift_like

BLOCK_SIZES = (16, 32, 64, 128, 256, 512, 1024)


def run(n=20_000):
    corpus = sift_like(n, 128, seed=3)
    rng = np.random.default_rng(4)
    q = corpus[rng.integers(0, n, 10)]
    newv = corpus[rng.integers(0, n, 128)] + 0.01
    rows = []
    for tm in BLOCK_SIZES:
        idx = build_ivf(
            corpus, n_clusters=64, block_size=tm,
            max_chain=max(16, 2 * n // (64 * tm) + 8),
            capacity_vectors=2 * n, nprobe=8, k=10, add_batch=4096,
        )
        search_s = timed(lambda: idx.search(q), iters=7)
        insert_s = timed(lambda: idx.add(newv.copy()), iters=3)
        s = idx.state
        used_blocks = int(s.cur_p) - int(s.free_top)
        resident = int(s.num_vectors)
        pad_bytes = (used_blocks * tm - resident) * 128 * 4
        rows.append({
            "block_size": tm,
            "search_ms": round(search_s * 1e3, 3),
            "insert_ms": round(insert_s * 1e3, 3),
            "pad_mem_mb": round(pad_bytes / 2**20, 2),
        })
    return rows


def main():
    rows = run()
    print("block_size,search_ms,insert_ms,pad_mem_mb")
    for r in rows:
        print(",".join(str(r[k]) for k in r))
    return rows


if __name__ == "__main__":
    main()
