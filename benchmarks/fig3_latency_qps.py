"""Paper Fig. 3: latency vs (QPS_search x QPS_insert) for the four systems.

Grid matches the paper: QPS_search in {1000, 5000, 10000}, QPS_insert in
{500, 1000, 2000}, on a SIFT-like 128-d corpus and a DSSM-like 64-d corpus.
Service times are measured on CPU (absolute scale differs from the paper's
A10), the queueing structure is exact — see benchmarks/common.py.

Expected morphology (paper §4.1): RTAMS lowest latency and flattest growth
with QPS_insert; realloc baselines degrade (insert service grows with list
length and, serially, blocks search); Faiss-like worst (host round trip).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import build_systems, measure_services, simulate
from benchmarks.loadgen import measure_runtime_services
from repro.data.synthetic import dssm_like, sift_like

# The paper's absolute grid (1k/5k/10k x 500/1k/2k QPS) targets an A10;
# a CPU lane saturates orders of magnitude earlier, so the grid is scaled
# to the *measured capacity of the fastest system* per dataset: load
# fractions matching the paper's relative sweep (its 10k cell is the
# saturation cell).  Paper-equivalent labels are kept alongside absolute
# CPU QPS so the morphology comparison is direct.
SEARCH_LOADS = ((1000, 0.2), (5000, 0.5), (10000, 0.9))
INSERT_LOADS = ((500, 0.05), (1000, 0.1), (2000, 0.2))


def run(fast: bool = True):
    datasets = {
        "sift1m_like": (sift_like(20_000 if fast else 100_000, 128), 64),
        "dssmrt40m_like": (dssm_like(40_000 if fast else 400_000, 64), 128),
    }
    rows = []
    for dname, (corpus, n_clusters) in datasets.items():
        systems = build_systems(corpus, n_clusters)
        services = measure_services(systems, corpus)
        # rtams service times are measured THROUGH the real serving
        # runtime (the adaptive controller's own EWMA service signal, see
        # benchmarks/loadgen.py) rather than the bare-kernel harness: the
        # analytic queue model and the deployed system share one source
        # of truth, so they cannot drift apart on service times.
        services["rtams"] = measure_runtime_services(corpus, n_clusters)
        # capacity anchors: search load relative to the SLOWEST searcher
        # (every system starts unsaturated, so latency growth is visible);
        # insert load relative to the FASTEST insert lane (the paper's
        # stressor — realloc-based inserts then saturate first, exactly
        # the Fig. 3 timeout effect).
        cap_search = 10.0 / max(s["search_s"] for s in services.values())
        cap_insert = 128.0 / min(s["insert_s"] for s in services.values())
        # the paper's 20 ms timeout is ~4-20x its GPU service times; keep
        # the same ratio against the slowest CPU search service
        timeout_ms = 4e3 * max(s["search_s"] for s in services.values())
        for sys_name, svc in services.items():
            parallel = sys_name == "rtams"
            for label_s, frac_s in SEARCH_LOADS:
                for label_i, frac_i in INSERT_LOADS:
                    qs = frac_s * cap_search
                    qi = frac_i * cap_insert
                    r = simulate(
                        qs, qi, svc["search_s"], svc["insert_s"],
                        parallel=parallel,
                        duration_s=2.0 if fast else 10.0,
                        timeout_ms=timeout_ms,
                    )
                    rows.append({
                        "dataset": dname, "system": sys_name,
                        "qps_search": label_s, "qps_insert": label_i,
                        "cpu_qps_search": round(qs, 1),
                        "cpu_qps_insert": round(qi, 1),
                        "timeout_ms": round(timeout_ms, 1),
                        "search_ms": round(r.search_mean_ms, 3),
                        "insert_ms": round(r.insert_mean_ms, 3),
                        "latency_avg_ms": round(r.latency_avg_ms, 3),
                        "timeout_frac": round(r.timeout_frac, 4),
                        "svc_search_ms": round(svc["search_s"] * 1e3, 3),
                        "svc_insert_ms": round(svc["insert_s"] * 1e3, 3),
                    })
    return rows


def main(fast: bool = True):
    rows = run(fast)
    hdr = ("dataset", "system", "qps_search", "qps_insert", "latency_avg_ms",
           "timeout_frac")
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r[k]) for k in hdr))
    # paper headline: RTAMS reduction vs the best *serial-architecture*
    # baseline (faiss_like / raft_like — the systems the paper's Fig. 3
    # beats).  rt_cpu is reported separately: on a CPU-only container it is
    # naturally competitive (the paper itself notes Rt-cpu overtaking Faiss
    # at high insert QPS, Fig. 3d; its RTAMS margins come from the GPU).
    print("\n# latency reduction of rtams vs best serial realloc baseline")
    for ds in sorted({r["dataset"] for r in rows}):
        for qs, _ in SEARCH_LOADS:
            for qi, _ in INSERT_LOADS:
                cell = {
                    r["system"]: r["latency_avg_ms"] for r in rows
                    if r["dataset"] == ds and r["qps_search"] == qs
                    and r["qps_insert"] == qi
                }
                base = min(cell["faiss_like"], cell["raft_like"])
                red = 100 * (1 - cell["rtams"] / base) if base else 0.0
                print(
                    f"{ds},qs={qs},qi={qi},reduction={red:.1f}%"
                    f",rt_cpu_ms={cell['rt_cpu']}"
                )
    return rows


if __name__ == "__main__":
    main(fast=False)
