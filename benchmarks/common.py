"""Shared benchmark machinery: corpus builders, service-time measurement,
and the two-lane queueing simulator used to replay the paper's QPS grids.

Methodology (EXPERIMENTS.md §Paper-repro): the container is CPU-only, so
absolute GPU milliseconds are not reproducible — but the paper's effects are
*structural* (realloc cost grows with list length; serial execution blocks
search behind insert; block insertion is O(1)).  We measure real service
times per system on CPU, then replay Poisson arrival traces through a
deterministic queue model:

* serial systems (Faiss/RAFT/Rt-cpu, Fig. 2a): ONE lane; every request
  (search batch or insert batch) occupies the lane for its measured service
  time; latency = completion - arrival.
* RTAMS (Fig. 2b): search lane(s) and a dedicated insert lane run
  concurrently (the multi-stream architecture); search batches <= 10, insert
  batches per the paper's dynamic batching.

The threaded ServingRuntime (core/scheduler.py) is validated separately in
tests; the queue model makes the full 1000-10000 QPS grid tractable and
deterministic.
"""

from __future__ import annotations

import dataclasses
import platform
import time
from typing import Callable, Optional

import numpy as np
import jax

from repro.core import build_ivf
from repro.core.baselines import FaissLikeIndex, RaftLikeIndex, RtCpuIndex
from repro.data.synthetic import dssm_like, sift_like

#: Version of the shared BENCH_*.json provenance block.  Bump when the
#: block's key set changes shape; readers (docs/observability.md tooling,
#: cross-run diffing) key their expectations off it.
BENCH_SCHEMA_VERSION = 1


def provenance(benchmark: str, *, fast: Optional[bool] = None,
               geometry: Optional[dict] = None,
               samples: Optional[dict] = None,
               extra: Optional[dict] = None) -> dict:
    """Uniform ``provenance`` block stamped into every ``BENCH_*.json``.

    Before this helper each benchmark invented its own partial ``meta``;
    two BENCH files from different runs could not be compared because
    neither said what geometry or sample counts produced it.  Keys:

    * ``schema_version`` — :data:`BENCH_SCHEMA_VERSION`;
    * ``benchmark`` — the writing script's name;
    * ``written_unix_s`` / ``python`` / ``jax`` / ``backend`` — when and
      on what stack the numbers were measured;
    * ``fast`` — CI-shrunk grid or the full one (when the script has one);
    * ``geometry`` — corpus/config shape (dim, n, clusters, ...);
    * ``samples`` — how many measurements back each reported number.
    """
    out = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "benchmark": benchmark,
        "written_unix_s": round(time.time(), 3),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
    }
    if fast is not None:
        out["fast"] = bool(fast)
    if geometry:
        out["geometry"] = dict(geometry)
    if samples:
        out["samples"] = dict(samples)
    if extra:
        out.update(extra)
    return out


def timed(fn, *args, warmup=1, iters=5) -> float:
    """Median wall-clock seconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args)) if hasattr(
            fn(*args), "block_until_ready"
        ) else fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        try:
            jax.block_until_ready(out)
        except Exception:
            pass
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def build_systems(corpus: np.ndarray, n_clusters: int, *, block_size=64,
                  nprobe=8, k=10, max_chain=512):
    """All four §4 systems over the same corpus + coarse quantizer seed."""
    n, dim = corpus.shape
    rtams = build_ivf(
        corpus, n_clusters=n_clusters, block_size=block_size,
        max_chain=max_chain, capacity_vectors=4 * n, nprobe=nprobe, k=k,
        add_batch=8192,
    )
    faiss = FaissLikeIndex(n_clusters, dim, nprobe=nprobe, k=k)
    faiss.train(corpus)
    faiss.add(corpus)
    raft = RaftLikeIndex(n_clusters, dim, nprobe=nprobe, k=k)
    raft.train(corpus)
    raft.add(corpus)
    rtcpu = RtCpuIndex(n_clusters, dim, block_size=block_size,
                       pool_blocks=4 * n // block_size + n_clusters + 16,
                       nprobe=nprobe, k=k)
    rtcpu.train(corpus)
    rtcpu.add(corpus)
    return {"rtams": rtams, "faiss_like": faiss, "raft_like": raft,
            "rt_cpu": rtcpu}


def measure_services(systems: dict, corpus: np.ndarray, *,
                     search_batch=10, insert_batch=128) -> dict:
    """Median service seconds for (search batch, insert batch) per system."""
    rng = np.random.default_rng(0)
    q = corpus[rng.integers(0, len(corpus), search_batch)]
    newv = corpus[rng.integers(0, len(corpus), insert_batch)] + 0.01
    out = {}
    for name, idx in systems.items():
        s = timed(lambda: idx.search(q), iters=7)
        i = timed(lambda: idx.add(newv.copy()), iters=3)
        out[name] = {"search_s": s, "insert_s": i}
    return out


@dataclasses.dataclass
class SimResult:
    search_mean_ms: float
    insert_mean_ms: float
    timeout_frac: float

    @property
    def latency_avg_ms(self) -> float:  # paper Eq. 4
        return self.search_mean_ms + self.insert_mean_ms


def simulate(
    qps_search: float,
    qps_insert: float,
    search_service_s: float,
    insert_service_s: float,
    *,
    parallel: bool,
    duration_s: float = 10.0,  # paper: first 10 seconds
    search_batch: int = 10,
    insert_batch: int = 128,
    timeout_ms: float = 20.0,  # paper: latency_avg > 20ms counted timeout
    seed: int = 0,
) -> SimResult:
    """Replay Poisson traffic through the one-lane / two-lane queue model."""
    rng = np.random.default_rng(seed)

    def poisson_times(rate, unit):
        if rate <= 0:
            return np.zeros((0,))
        n = rng.poisson(rate * duration_s / unit)
        return np.sort(rng.uniform(0, duration_s, n))

    s_arr = poisson_times(qps_search, 1)  # one query per request
    i_arr = poisson_times(qps_insert, insert_batch)  # batched vectors

    if parallel:
        lanes = {"s": 0.0, "i": 0.0}
    else:
        lanes = {"s": 0.0}

    # merge event streams in arrival order; searches batch up to
    # search_batch when the lane is busy (they queue and coalesce)
    s_lat, i_lat, timeouts, total = [], [], 0, 0
    si, ii = 0, 0
    pend_s: list[float] = []
    while si < len(s_arr) or ii < len(i_arr) or pend_s:
        next_s = s_arr[si] if si < len(s_arr) else np.inf
        next_i = i_arr[ii] if ii < len(i_arr) else np.inf
        lane_s = "s"
        lane_i = "i" if parallel else "s"
        # dispatch pending search batch as soon as the search lane frees
        if pend_s and lanes[lane_s] <= min(next_s, next_i):
            start = max(lanes[lane_s], pend_s[0])
            end = start + search_service_s
            lanes[lane_s] = end
            for a in pend_s:
                s_lat.append(end - a)
            pend_s = []
            continue
        if next_s <= next_i:
            pend_s.append(next_s)
            si += 1
            # coalesce immediately-available queued searches
            while (
                si < len(s_arr)
                and len(pend_s) < search_batch
                and s_arr[si] <= max(lanes[lane_s], pend_s[0])
            ):
                pend_s.append(s_arr[si])
                si += 1
        else:
            start = max(lanes[lane_i], next_i)
            end = start + insert_service_s
            lanes[lane_i] = end
            i_lat.append(end - next_i)
            ii += 1

    s_ms = 1e3 * float(np.mean(s_lat)) if s_lat else 0.0
    i_ms = 1e3 * float(np.mean(i_lat)) if i_lat else 0.0
    lats = np.concatenate([np.asarray(s_lat), np.asarray(i_lat)]) * 1e3
    to = float((lats > timeout_ms).mean()) if lats.size else 0.0
    return SimResult(
        search_mean_ms=min(s_ms, timeout_ms * 2),  # paper caps at timeout
        insert_mean_ms=min(i_ms, timeout_ms * 2),
        timeout_frac=to,
    )
