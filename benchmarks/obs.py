"""Observability benchmark: the traced latency decomposition and the
tracing overhead budget, asserted in-script.

Two claims back the obs subsystem (``src/repro/obs``; ISSUE 10):

* **Decomposition is ground truth** — drive one moderate mixed-load cell
  with ``trace_sample_rate=1.0`` and reconstruct each lane's end-to-end
  latency from its per-stage spans (admission -> queue -> batch_form ->
  compile|execute -> device_wait -> ack).  The span sums must match the
  independently-measured e2e latency (future-resolution stopwatch, the
  loadgen ``_Recorder``) within 5% at p50 and p99 — the repo's first
  per-stage latency *budget* rather than a single opaque number.
* **Tracing is cheap** — the same cell at the default sample rate
  (``RuntimeConfig().trace_sample_rate``) versus tracing disabled
  (``0.0``) must cost < 5% extra search p50.

Dispatch costs are pinned with ``FaultPlan`` delays exactly as in
benchmarks/loadgen.py, so both checks are structural, not host-lottery.
Writes ``BENCH_obs.json`` at the repo root when run as a script.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

try:
    from benchmarks.common import provenance
except ImportError:  # run as `python benchmarks/obs.py`
    import sys as _sys
    _sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import provenance

from benchmarks.loadgen import (
    CAP_MUT_ROWS,
    CAP_SEARCH_QPS,
    DIM,
    FLUSH_MAX,
    MAX_SEARCH_BATCH,
    N0,
    N_CLUSTERS,
    _drive_cell,
    _make_runtime,
    _warmup,
)
from repro.core.runtime import RuntimeConfig
from repro.obs.trace import OUTCOME_OK, decompose

# the moderate cell: far from saturation so queueing noise stays small,
# busy enough that batches form (spans exercise every stage)
FRAC_SEARCH = 0.4
FRAC_MUT = 0.15
TOLERANCE = 0.05  # span-sum vs measured e2e, p50 and p99
OVERHEAD_BUDGET = 0.05  # default-rate tracing vs disabled, search p50


def _cfg(sample_rate: float) -> RuntimeConfig:
    return RuntimeConfig(
        mode="parallel", nprobe=4, k=10, n_slots=32,
        max_search_batch=MAX_SEARCH_BATCH, auto_compact=True,
        compact_passes=2, adaptive=True, window_min=0.005, window_max=1.0,
        flush_interval=1.0, flush_min=128, flush_max=FLUSH_MAX,
        rate_tau=0.3, adaptive_interval=0.02, adaptive_patience=2,
        pool_rebalance=False, trace_sample_rate=sample_rate,
    )


def _traced_cell(sample_rate: float, seconds: float, seed: int) -> dict:
    """One driven cell; returns measured percentiles + the trace ring."""
    rng = np.random.default_rng(seed)
    rt = _make_runtime(_cfg(sample_rate))
    try:
        _warmup(rt, rt.cfg, rng)
        rt.reset_stats()  # drop warmup samples AND warmup/compile traces
        cell = _drive_cell(
            rt, FRAC_SEARCH * CAP_SEARCH_QPS, FRAC_MUT * CAP_MUT_ROWS,
            seconds, rng,
        )
        traces = rt.traces()
    finally:
        rt.stop()
    return {"cell": cell, "traces": traces}


def _lane_decomposition(traces, kinds, measured: dict,
                        min_n: int = 100) -> dict:
    """Decompose one lane's ok traces + compare against the recorder."""
    lane = [
        t for t in traces if t.kind in kinds and t.outcome == OUTCOME_OK
    ]
    d = decompose(lane)
    out = {
        "n_traces": d["n_ok"],
        "stages_ms": {k: v["p50_ms"] for k, v in d["stages"].items()},
        "span_sum": d["span_sum"],
        "trace_e2e": d["e2e"],
        "measured_e2e": measured,
    }
    assert d["n_ok"] >= min_n, f"thin sample ({d['n_ok']} traces): {out}"
    for q in ("p50_ms", "p99_ms"):
        span, e2e = d["span_sum"][q], measured[q]
        rel = abs(span - e2e) / max(e2e, 1e-9)
        out[f"rel_err_{q}"] = round(rel, 4)
        assert rel <= TOLERANCE, (
            f"{kinds}: span-sum {q} {span:.2f}ms vs measured e2e "
            f"{e2e:.2f}ms ({rel:.1%} > {TOLERANCE:.0%}): {out}"
        )
    return out


def run(fast: bool = True) -> dict:
    seconds = 2.0 if fast else 5.0
    # ---- claim 1: the per-stage decomposition sums to measured e2e ------
    full = _traced_cell(1.0, seconds, seed=3)
    decomp = {
        "search": _lane_decomposition(
            full["traces"], ("search",), full["cell"]["search"]
        ),
        "mutation": _lane_decomposition(
            full["traces"], ("insert", "delete", "update"),
            full["cell"]["mutation"], min_n=20,
        ),
    }
    # ---- claim 2: default-rate tracing costs < 5% search p50 ------------
    default_rate = RuntimeConfig().trace_sample_rate
    off = _traced_cell(0.0, seconds, seed=5)["cell"]
    on = _traced_cell(default_rate, seconds, seed=5)["cell"]
    p50_off = off["search"]["p50_ms"]
    p50_on = on["search"]["p50_ms"]
    overhead = (p50_on - p50_off) / max(p50_off, 1e-9)
    assert overhead < OVERHEAD_BUDGET, (
        f"default-rate tracing added {overhead:.1%} search p50 "
        f"({p50_off:.2f}ms -> {p50_on:.2f}ms; budget {OVERHEAD_BUDGET:.0%})"
    )
    n_search = decomp["search"]["n_traces"]
    n_mut = decomp["mutation"]["n_traces"]
    return {
        "provenance": provenance(
            "obs", fast=fast,
            geometry={"dim": DIM, "corpus": N0, "n_clusters": N_CLUSTERS,
                      "max_search_batch": MAX_SEARCH_BATCH,
                      "flush_max": FLUSH_MAX},
            samples={"traces_search": n_search, "traces_mutation": n_mut,
                     "overhead_search_n": on["search"]["n"]},
        ),
        "meta": {
            "cell_seconds": seconds, "fast": fast,
            "frac_search": FRAC_SEARCH, "frac_mutation": FRAC_MUT,
            "tolerance": TOLERANCE, "overhead_budget": OVERHEAD_BUDGET,
            "default_sample_rate": default_rate,
        },
        "decomposition": decomp,
        "overhead": {
            "sample_rate": default_rate,
            "search_p50_ms_disabled": p50_off,
            "search_p50_ms_default": p50_on,
            "relative": round(overhead, 4),
        },
    }


def main(fast: bool = True) -> dict:
    out = run(fast)
    for lane in ("search", "mutation"):
        d = out["decomposition"][lane]
        stages = " ".join(
            f"{k}={v:.2f}" for k, v in d["stages_ms"].items()
        )
        print(f"{lane}: n={d['n_traces']} p50 stage-ms {stages}")
        print(
            f"{lane}: span-sum p50 {d['span_sum']['p50_ms']:.2f}ms vs "
            f"measured {d['measured_e2e']['p50_ms']:.2f}ms "
            f"(err {d['rel_err_p50_ms']:.1%}); p99 "
            f"{d['span_sum']['p99_ms']:.2f} vs "
            f"{d['measured_e2e']['p99_ms']:.2f} "
            f"(err {d['rel_err_p99_ms']:.1%})"
        )
    ov = out["overhead"]
    print(
        f"overhead @ rate {ov['sample_rate']}: search p50 "
        f"{ov['search_p50_ms_disabled']:.2f}ms -> "
        f"{ov['search_p50_ms_default']:.2f}ms ({ov['relative']:+.1%})"
    )
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_obs.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    main(fast=args.fast)
