"""Open-loop load generator: the Fig. 3 morphology measured on the REAL
serving runtime (not the seed's analytic queue model).

Drives a live ``ServingRuntime`` with mixed search / insert / delete /
update traffic at controlled QPS over the paper's Fig. 3 grid, for three
system configurations:

* ``adaptive``    — the arrival-rate-driven control loop (this repo's
  namesake claim): batch window and flush threshold follow live QPS.
* ``fixed_small`` — latency-tuned static schedule (tiny window, small
  cap): great at low QPS, saturates early on the insert axis.
* ``fixed_large`` — throughput-tuned static schedule (the paper's §3.3
  defaults, 1 s window / big cap): survives saturation, wastes a full
  window on every lone mutation at low QPS.

Per-dispatch service cost is pinned deterministically with ``FaultPlan``
delays on the ``search_step``/``mutation_step`` sites (same methodology
as benchmarks/overload.py): a dispatch costs the same wherever the
benchmark runs, so the *structural* effects — batch amortization, window
waste, saturation — are host-independent.  Submission is open-loop and
absolute-scheduled (a slow submit never silently lowers offered load).

Each (system, search-QPS, insert-QPS) cell records p50/p95/p99 per lane
via the shared ``metrics.percentile_summary`` helper into
``BENCH_fig3.json``.  The paper's morphology is asserted in-script:

* **(a) sub-linear growth** — adaptive mutation p99 across the insert-QPS
  axis grows by less than 0.75x the offered-load growth factor;
* **(b) saturation cell** — adaptive p99 <= 1.3x the best fixed-window
  config at the highest-load cell;
* **(c) low-QPS headline** — adaptive p99 <= 0.6x ``fixed_large`` at the
  lowest insert cell (the paper's 40-80% reduction claim);
* **(d) bounded compiles** — each runtime's jit-cache entry count across
  its FULL sweep (every cell, one runtime, adaptive knobs moving freely)
  stays under a fixed bound — adaptive control never recompiles per
  request.

``--fast`` shrinks the grid to one search row and shorter cells for CI.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import threading
import time
from concurrent.futures import wait as futures_wait

import numpy as np

try:
    from benchmarks.common import provenance
except ImportError:  # run as `python benchmarks/loadgen.py`
    import sys as _sys
    _sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import provenance

from repro.core import build_ivf
from repro.core.admission import RequestRejected
from repro.core.faults import FaultPlan
from repro.core.metrics import percentile_summary
from repro.core.runtime import RuntimeConfig, ServingRuntime
from repro.obs.events import EV_POOL_REBALANCE

DIM = 32
N0 = 4000
N_CLUSTERS = 8

# pinned per-dispatch service cost (seconds) — the structural constants
D_SEARCH = 0.02  # one search dispatch (batch <= MAX_SEARCH_BATCH)
D_MUT = 0.04  # one mutation dispatch (batch <= flush_max rows)
MAX_SEARCH_BATCH = 8
MUT_ROWS = 32  # rows per submitted mutation
FLUSH_MAX = 256  # adaptive / fixed_large cap

# derived pinned capacities the grid is scaled against.  The mutation
# capacity is derated 2x below the raw FLUSH_MAX / D_MUT bound: batches
# split at kind switches (insert|delete|update), so every non-insert
# request in the mix pays a full un-amortized dispatch AND splits the
# surrounding insert run in two — the achievable rows/s under a mixed
# stream is well below the pure-insert bound.
CAP_SEARCH_QPS = MAX_SEARCH_BATCH / D_SEARCH  # 400 req/s
CAP_MUT_ROWS = FLUSH_MAX / D_MUT / 2  # 3200 rows/s (mixed-stream)

# paper Fig. 3 axis labels -> load fraction of pinned capacity
SEARCH_LOADS = ((1000, 0.2), (5000, 0.5), (10000, 0.9))
INSERT_LOADS = ((500, 0.05), (1000, 0.2), (2000, 0.8))
FAST_SEARCH_LOADS = ((5000, 0.5),)

# mutation mix (fractions of mutation submits): each non-insert request
# costs a whole dispatch (kind-split), so the mix is thin — 4% non-insert
# already contributes ~0.25 dispatch-utilization at the saturation cell
P_DELETE = 0.02
P_UPDATE = 0.02

MAX_COMPILED_STEPS = 16  # assertion (d) bound per runtime, full sweep


def _systems() -> dict:
    """The three serving configurations under test (same lanes, same
    pinned service costs — only the schedule differs)."""
    base = dict(
        mode="parallel", nprobe=4, k=10, n_slots=32,
        max_search_batch=MAX_SEARCH_BATCH, auto_compact=True,
        compact_passes=2,
    )
    return {
        "adaptive": RuntimeConfig(
            adaptive=True, window_min=0.005, window_max=1.0,
            flush_interval=1.0, flush_min=128, flush_max=FLUSH_MAX,
            rate_tau=0.3, adaptive_interval=0.02, adaptive_patience=2,
            # pool rebalance is exercised in tests/test_adaptive.py; off
            # here so all three systems share identical admission bounds
            pool_rebalance=False,
            **base,
        ),
        "fixed_small": RuntimeConfig(
            flush_interval=0.01, flush_min=32, flush_max=64, **base
        ),
        "fixed_large": RuntimeConfig(
            flush_interval=1.0, flush_min=128, flush_max=FLUSH_MAX, **base
        ),
    }


def _make_runtime(cfg: RuntimeConfig) -> ServingRuntime:
    rng = np.random.default_rng(0)
    x = rng.normal(size=(N0, DIM)).astype(np.float32)
    idx = build_ivf(
        x, n_clusters=N_CLUSTERS, block_size=64, max_chain=512,
        nprobe=4, k=10, capacity_vectors=400_000, add_batch=1024,
    )
    plan = (
        FaultPlan()
        .delay("search_step", D_SEARCH, nth=None)
        .delay("mutation_step", D_MUT, nth=None)
    )
    return ServingRuntime(idx, cfg, faults=plan)


class _Recorder:
    """Latency capture at future-resolution time (done-callbacks run in
    the resolving worker thread, so completion is stamped at completion,
    not when the driver gets around to polling)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.search: list = []
        self.mutation: list = []
        self.failed = 0

    def callback(self, lane: str, t_submit: float):
        def _done(fut):
            t = time.perf_counter() - t_submit
            with self._lock:
                if fut.exception() is not None:
                    self.failed += 1
                elif lane == "search":
                    self.search.append(t)
                else:
                    self.mutation.append(t)

        return _done


def _warmup(rt: ServingRuntime, cfg: RuntimeConfig, rng) -> None:
    """Pay the jit compiles outside the measurement: one dispatch per
    pow2 bucket each mutation kind can batch into, plus a search."""
    sizes, b = [], 8
    while b <= cfg.flush_max:
        sizes.append(b)
        b *= 2
    futs = []
    for n in sizes:
        futs.append(rt.submit_insert(
            rng.normal(size=(n, DIM)).astype(np.float32)
        ))
    futures_wait(futs, timeout=300)
    futs = []
    for n in sizes:
        futs.append(rt.submit_delete(rng.integers(0, N0, n)))
        futs.append(rt.submit_update(
            rng.normal(size=(n, DIM)).astype(np.float32),
            rng.integers(0, N0, n),
        ))
    n = 1
    while n <= MAX_SEARCH_BATCH:  # every pow2 batch bucket a cell can hit
        futs.append(rt.submit_search(
            rng.normal(size=(n, DIM)).astype(np.float32)
        ))
        n *= 2
    futures_wait(futs, timeout=300)
    # pay the compaction/rearrange compile here too: under the adaptive
    # config the pacing gate may have deferred it past the warmup deletes
    # (warmup bursts leave a high queue-age watermark), and a multi-second
    # first-compaction jit trace inside a measured cell stalls both lanes
    for _ in range(20):
        rt._controller.mutation.observe_queue_age(0.0)
    rt._maybe_compact()


def _drive_cell(rt: ServingRuntime, qps_search: float, mut_rows_qps: float,
                seconds: float, rng) -> dict:
    """One open-loop cell: absolute-scheduled mixed traffic, then a full
    drain (every accepted future must resolve — no hangs)."""
    rec = _Recorder()
    rejected_search = rejected_mutation = 0
    queries = rng.normal(size=(64, MAX_SEARCH_BATCH, DIM)).astype(np.float32)
    dt_s = 1.0 / qps_search
    dt_m = MUT_ROWS / mut_rows_qps
    futs = []
    t0 = time.perf_counter()
    next_s, next_m = t0, t0
    end = t0 + seconds
    qi = 0
    while True:
        now = time.perf_counter()
        if now >= end:
            break
        if now >= next_s:
            next_s += dt_s
            t_sub = time.perf_counter()
            try:
                f = rt.submit_search(queries[qi % 64, :1])
                f.add_done_callback(rec.callback("search", t_sub))
                futs.append(f)
            except RequestRejected:
                rejected_search += 1
            qi += 1
            continue
        if now >= next_m:
            next_m += dt_m
            r = rng.random()
            t_sub = time.perf_counter()
            try:
                if r < P_DELETE:
                    f = rt.submit_delete(rng.integers(0, N0, MUT_ROWS))
                elif r < P_DELETE + P_UPDATE:
                    f = rt.submit_update(
                        rng.normal(size=(MUT_ROWS, DIM)).astype(np.float32),
                        rng.integers(0, N0, MUT_ROWS),
                    )
                else:
                    f = rt.submit_insert(
                        rng.normal(size=(MUT_ROWS, DIM)).astype(np.float32)
                    )
                f.add_done_callback(rec.callback("mutation", t_sub))
                futs.append(f)
            except RequestRejected:
                rejected_mutation += 1
            continue
        time.sleep(min(0.002, max(0.0, min(next_s, next_m) - now)))
    # drain: a saturated cell leaves a backlog; every accepted request
    # must still resolve (the no-hang discipline the runtime guarantees)
    done, not_done = futures_wait(futs, timeout=300)
    assert not not_done, f"{len(not_done)} futures never resolved"
    return {
        "search": percentile_summary(rec.search),
        "mutation": percentile_summary(rec.mutation),
        "rejected_search": rejected_search,
        "rejected_mutation": rejected_mutation,
        "failed": rec.failed,
        "offered_search_qps": round(qps_search, 1),
        "offered_mutation_rows_qps": round(mut_rows_qps, 1),
    }


def _compiled_steps(rt: ServingRuntime) -> int:
    return len(rt._search_steps) + len(rt._fused_steps)


def run(fast: bool = True) -> dict:
    search_loads = FAST_SEARCH_LOADS if fast else SEARCH_LOADS
    cell_s = 1.5 if fast else 4.0
    settle_s = 0.8 if fast else 1.2
    cells = []
    compiled = {}
    for sys_name, cfg in _systems().items():
        rng = np.random.default_rng(7)
        rt = _make_runtime(cfg)
        try:
            _warmup(rt, cfg, rng)
            for label_s, frac_s in search_loads:
                for label_i, frac_i in INSERT_LOADS:
                    # settle: drain the estimator / window state from the
                    # previous cell so cells are independent measurements
                    time.sleep(settle_s)
                    rt.reset_stats()
                    cell = _drive_cell(
                        rt, frac_s * CAP_SEARCH_QPS,
                        frac_i * CAP_MUT_ROWS, cell_s, rng,
                    )
                    stats = rt.stats()
                    cell.update({
                        "system": sys_name,
                        "qps_search": label_s, "qps_insert": label_i,
                        "frac_search": frac_s, "frac_insert": frac_i,
                        "compactions": stats["compactions"],
                        "compactions_deferred": stats.get(
                            "compactions_deferred", 0
                        ),
                        "compiled_steps": _compiled_steps(rt),
                    })
                    if "adaptive" in stats:
                        a = stats["adaptive"]
                        cell["adaptive"] = {
                            "window_s": a["window_s"],
                            "window_changes": a["window_changes"],
                            "mutation_rate": round(a["mutation_rate"], 1),
                            "load_factor": round(a["load_factor"], 3),
                        }
                    cells.append(cell)
            compiled[sys_name] = _compiled_steps(rt)
        finally:
            rt.stop()
    report = _assert_morphology(cells, compiled, search_loads)
    rebalancer = run_rebalancer(fast)
    return {
        "provenance": provenance(
            "loadgen", fast=fast,
            geometry={"dim": DIM, "corpus": N0, "n_clusters": N_CLUSTERS,
                      "max_search_batch": MAX_SEARCH_BATCH,
                      "flush_max": FLUSH_MAX},
            samples={"cells": len(cells),
                     "search_lat": sum(c["search"]["n"] for c in cells),
                     "mutation_lat": sum(c["mutation"]["n"] for c in cells)},
        ),
        "meta": {
            "d_search_s": D_SEARCH, "d_mut_s": D_MUT,
            "cap_search_qps": CAP_SEARCH_QPS,
            "cap_mutation_rows_qps": CAP_MUT_ROWS,
            "cell_seconds": cell_s, "fast": fast,
            "mut_rows_per_submit": MUT_ROWS,
            "mix": {"insert": 1 - P_DELETE - P_UPDATE,
                    "delete": P_DELETE, "update": P_UPDATE},
        },
        "compiled_steps": compiled,
        "cells": cells,
        "assertions": report,
        "rebalancer": rebalancer,
    }


def _cell(cells, system, label_s, label_i) -> dict:
    for c in cells:
        if (c["system"] == system and c["qps_search"] == label_s
                and c["qps_insert"] == label_i):
            return c
    raise KeyError((system, label_s, label_i))


def _assert_morphology(cells, compiled, search_loads) -> dict:
    """The in-script acceptance gate (see module docstring, (a)-(d))."""
    # assert on the middle search row — present in fast and full grids
    row = 5000 if any(s == 5000 for s, _ in search_loads) \
        else search_loads[0][0]
    labels = [li for li, _ in INSERT_LOADS]
    fracs = dict(INSERT_LOADS)
    p99 = {
        s: [_cell(cells, s, row, li)["mutation"]["p99_ms"] for li in labels]
        for s in ("adaptive", "fixed_small", "fixed_large")
    }
    load_growth = fracs[labels[-1]] / fracs[labels[0]]
    p99_growth = p99["adaptive"][-1] / max(p99["adaptive"][0], 1e-9)
    sat_best_fixed = min(p99["fixed_small"][-1], p99["fixed_large"][-1])
    report = {
        "search_row": row,
        "insert_labels": labels,
        "p99_ms": p99,
        "load_growth": load_growth,
        "adaptive_p99_growth": round(p99_growth, 3),
        "saturation_best_fixed_p99_ms": sat_best_fixed,
        "compiled_steps": compiled,
    }
    # (a) flat morphology: p99 across the insert axis grows sub-linearly
    assert p99_growth <= 0.75 * load_growth, (
        f"adaptive p99 grew {p99_growth:.1f}x over a {load_growth:.0f}x "
        f"load sweep (expected sub-linear): {p99['adaptive']}"
    )
    # (b) saturation cell: adaptive at least matches the best fixed config
    assert p99["adaptive"][-1] <= 1.3 * sat_best_fixed, (
        f"adaptive p99 {p99['adaptive'][-1]:.1f}ms at saturation vs best "
        f"fixed {sat_best_fixed:.1f}ms"
    )
    # (c) the 40-80% low-QPS headline vs the paper's static defaults
    assert p99["adaptive"][0] <= 0.6 * p99["fixed_large"][0], (
        f"adaptive p99 {p99['adaptive'][0]:.1f}ms at low insert QPS vs "
        f"fixed_large {p99['fixed_large'][0]:.1f}ms (expected >= 40% cut)"
    )
    # (d) bounded compiles across each full sweep (adaptive knobs quantize
    # into the pow2/rung jit-cache keys; never one compile per request)
    for sys_name, n in compiled.items():
        assert n <= MAX_COMPILED_STEPS, (
            f"{sys_name}: {n} compiled steps (> {MAX_COMPILED_STEPS})"
        )
    return report


def _warmup_bounded_gate(rt: ServingRuntime, cfg: RuntimeConfig,
                         rng) -> None:
    """``_warmup`` for a runtime with a bounded admission gate: one
    mutation in flight at a time, so the compile-priming burst can never
    overflow ``max_pending_mutations``."""
    sizes, b = [], 8
    while b <= cfg.flush_max:
        sizes.append(b)
        b *= 2
    for n in sizes:
        rt.submit_insert(
            rng.normal(size=(n, DIM)).astype(np.float32)
        ).result(timeout=300)
        rt.submit_delete(rng.integers(0, N0, n)).result(timeout=300)
        rt.submit_update(
            rng.normal(size=(n, DIM)).astype(np.float32),
            rng.integers(0, N0, n),
        ).result(timeout=300)
    n = 1
    while n <= MAX_SEARCH_BATCH:
        rt.submit_search(
            rng.normal(size=(n, DIM)).astype(np.float32)
        ).result(timeout=300)
        n *= 2


def run_rebalancer(fast: bool = True) -> dict:
    """Exercise the ``DynamicResourcePool`` rebalancer inside the loadgen
    methodology (a ROADMAP leftover: it was only unit-tested before).

    One adaptive runtime with rebalancing ON sees two phases of lopsided
    load — search-heavy, then mutation-heavy — under the same pinned
    dispatch costs as the grid.  The pool must move search slots toward
    the hot lane in each phase, and every move must land in the flight
    recorder as a ``pool.rebalance`` event (this scenario doubles as the
    recorder's integration check).  Asserted in-script:

    * slots grew above the initial apportionment during the search phase;
    * slots moved back down during the mutation phase;
    * ``moves`` matches the flight recorder's event count exactly.
    """
    phase_s = 1.2 if fast else 2.5
    cfg = RuntimeConfig(
        mode="parallel", nprobe=4, k=10, adaptive=True,
        pool_rebalance=True, n_slots=8, max_pending_mutations=256,
        pool_rows_per_slot=64, pool_min_search=2, pool_min_mutation=1,
        pool_interval=0.05, adaptive_patience=2,
        window_min=0.005, window_max=0.5, flush_min=64,
        flush_max=FLUSH_MAX, rate_tau=0.3, adaptive_interval=0.02,
        max_search_batch=MAX_SEARCH_BATCH, auto_compact=False,
    )
    rt = _make_runtime(cfg)
    try:
        rng = np.random.default_rng(11)
        _warmup_bounded_gate(rt, cfg, rng)
        initial = rt.stats()["pool"]["search_slots"]
        # phase 1: saturate the search slots, starve the mutation gate
        _drive_cell(rt, 0.9 * CAP_SEARCH_QPS, 32.0, phase_s, rng)
        p1 = rt.stats()["pool"]
        # phase 2: searches go quiet, mutations flood the (shrunken) gate
        _drive_cell(rt, 5.0, 2000.0, phase_s, rng)
        p2 = rt.stats()["pool"]
        moves = p2["moves"]
        rebalances = [
            e for e in rt.events() if e.name == EV_POOL_REBALANCE
        ]
    finally:
        rt.stop()
    report = {
        "initial_search_slots": initial,
        "after_search_phase": p1,
        "after_mutation_phase": p2,
        "rebalance_events": len(rebalances),
        "phase_seconds": phase_s,
    }
    assert p1["search_slots"] > initial, (
        f"search phase never grew the search share: {report}"
    )
    assert p2["search_slots"] < p1["search_slots"], (
        f"mutation phase never took slots back: {report}"
    )
    assert moves > 0 and len(rebalances) == moves, (
        f"flight recorder disagrees with the pool: {moves} moves vs "
        f"{len(rebalances)} pool.rebalance events: {report}"
    )
    return report


def measure_runtime_services(corpus: np.ndarray, n_clusters: int,
                             *, search_batch: int = 10,
                             insert_batch: int = 128) -> dict:
    """Median-free service estimate measured THROUGH the serving runtime
    (no injected delays): the controller's own EWMA service signal after
    a short burst.  benchmarks/fig3_latency_qps.py feeds this to the
    analytic model's rtams lane, so the model and the real runtime can't
    drift apart on service times."""
    n, dim = corpus.shape
    idx = build_ivf(
        corpus, n_clusters=n_clusters, block_size=64, max_chain=512,
        nprobe=8, k=10, capacity_vectors=4 * n, add_batch=8192,
    )
    rt = ServingRuntime(idx, RuntimeConfig(
        mode="parallel", nprobe=8, k=10, adaptive=True,
        flush_min=insert_batch, flush_max=insert_batch,
        flush_interval=0.05, window_min=0.01, window_max=0.05,
    ))
    try:
        rng = np.random.default_rng(0)
        q = corpus[rng.integers(0, n, search_batch)]
        newv = corpus[rng.integers(0, n, insert_batch)] + 0.01
        # warmup (compiles), then measured dispatches
        rt.submit_search(q).result(timeout=300)
        rt.submit_insert(newv.copy()).result(timeout=300)
        for _ in range(5):
            rt.submit_search(q).result(timeout=300)
            rt.submit_insert(newv.copy()).result(timeout=300)
        a = rt.stats()["adaptive"]
        return {
            "search_s": a["search_service_s"],
            "insert_s": a["mutation_service_s"],
        }
    finally:
        rt.stop()


def main(fast: bool = True) -> dict:
    out = run(fast)
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fig3.json"
    path.write_text(json.dumps(out, indent=2))
    hdr = ("system", "qps_search", "qps_insert", "mut_p99_ms",
           "search_p99_ms", "rejected", "compactions_deferred")
    print(",".join(hdr))
    for c in out["cells"]:
        print(",".join(str(v) for v in (
            c["system"], c["qps_search"], c["qps_insert"],
            round(c["mutation"]["p99_ms"], 1),
            round(c["search"]["p99_ms"], 1),
            c["rejected_search"] + c["rejected_mutation"],
            c["compactions_deferred"],
        )))
    rep = out["assertions"]
    print(
        f"\n# adaptive p99 growth {rep['adaptive_p99_growth']}x over "
        f"{rep['load_growth']:.0f}x load; compiled steps "
        f"{rep['compiled_steps']}; all morphology assertions passed"
    )
    rb = out["rebalancer"]
    print(
        f"# rebalancer: search slots "
        f"{rb['initial_search_slots']} -> "
        f"{rb['after_search_phase']['search_slots']} (search phase) -> "
        f"{rb['after_mutation_phase']['search_slots']} (mutation phase), "
        f"{rb['rebalance_events']} moves, all recorded"
    )
    print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    main(fast=args.fast)
