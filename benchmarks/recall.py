"""Recall parity: RTAMS must lose nothing vs the realloc baselines.

The paper's claim is architectural (latency), not algorithmic — the block
pool must return *identical* results to contiguous IVF storage.  We check
(a) recall@10 vs brute force across nprobe for IVFFlat and IVFPQ, and
(b) exact id parity between RTAMS and the RAFT-like baseline at equal
nprobe (same centroids, same data).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import build_ivf, exact_search
from repro.core.baselines import RaftLikeIndex
from repro.core.metrics import recall_at_k
from repro.data.synthetic import sift_like


def run(n=20_000, n_queries=64):
    corpus = sift_like(n, 128, seed=5)
    rng = np.random.default_rng(6)
    q = corpus[rng.integers(0, n, n_queries)] + 0.01
    _, exact_ids = exact_search(jnp.asarray(corpus), jnp.asarray(q), 10)
    exact_ids = np.asarray(exact_ids)

    rows = []
    flat = build_ivf(corpus, n_clusters=64, block_size=64, max_chain=64,
                     nprobe=8, k=10, add_batch=8192)
    pq = build_ivf(corpus, n_clusters=64, payload="pq", pq_m=16,
                   block_size=64, max_chain=64, nprobe=8, k=10,
                   add_batch=8192)
    # same kmeans seed/iters as build_ivf -> identical coarse quantizer
    raft = RaftLikeIndex(64, 128, nprobe=8, k=10)
    raft.train(corpus)
    raft.add(corpus)

    for nprobe in (1, 4, 8, 16, 32, 64):
        df, idf = flat.search(q, nprobe=nprobe, k=10)
        dp, idp = pq.search(q, nprobe=nprobe, k=10)
        rows.append({
            "nprobe": nprobe,
            "ivfflat_recall@10": round(recall_at_k(idf, exact_ids, 10), 4),
            "ivfpq_recall@10": round(recall_at_k(idp, exact_ids, 10), 4),
        })
    # id parity vs raft-like at nprobe=8
    dr, idr = raft.search(q, nprobe=8, k=10)
    df, idf = flat.search(q, nprobe=8, k=10)
    parity = float((np.sort(idf, 1) == np.sort(idr, 1)).mean())
    return rows, parity


def main():
    rows, parity = run()
    print("nprobe,ivfflat_recall@10,ivfpq_recall@10")
    for r in rows:
        print(f"{r['nprobe']},{r['ivfflat_recall@10']},{r['ivfpq_recall@10']}")
    print(f"# id parity rtams vs raft_like (nprobe=8): {parity:.4f}")
    return rows, parity


if __name__ == "__main__":
    main()
