"""ANNS search-path ladder (the §Perf ANNS hillclimb artifact):

chain_walk (paper-faithful linked list) -> block_table (vectorised gather)
-> union (dedup across batch) -> union_pallas (scalar-prefetch kernel)
-> union_fused (streaming top-k selection, no [C, Q, T] HBM writeback).

CPU wall-clock; the structural deltas (dependent-gather hops vs one gather;
per-query vs per-batch block reads; [C, Q, T] score writeback vs [Q, K']
accumulator) carry to TPU where they are DMA-count and HBM-traffic
differences.  ``intermediate_bytes`` is the peak scoring intermediate each
path materializes between scoring and selection:

* union / union_pallas: the full score tensor, ``CB * Q * T * 4`` bytes
  (plus the same again for the masked copy fed to top_k);
* union_fused / union_fused_scan: the on-chip accumulator, ``Q * K' * 8``
  bytes (f32 score + i32 id) — the quantity this PR drives to O(Q*K').

The PQ sweep covers the quantized half of the ladder (IVFPQ payload):
``block_table`` + the ADC score_fn materializes ``[Q, C, T]`` float scores
from uint8 codes, while ``union_fused`` routes through the PQ-ADC streaming
kernel (``ivf_pq_block_topk``) and keeps the ``[Q, K']`` accumulator shape.

Writes ``BENCH_scan_paths.json`` at the repo root when run as a script.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core import build_ivf
from repro.core import pq as pqmod
from repro.core.search import default_kprime, make_search_fn
from repro.data.synthetic import sift_like

PATHS = (
    "chain_walk",
    "block_table",
    "union",
    "union_pallas",
    "union_fused",
    "union_fused_scan",
)

PQ_PATHS = ("block_table", "union_fused", "union_fused_scan")


def intermediate_bytes(path: str, *, q: int, nprobe: int, budget: int,
                       t: int, k: int, pq_m: int = 0) -> int:
    """Peak scoring-intermediate bytes between scoring and selection."""
    cb = q * nprobe * budget  # candidate blocks (union is NULL-padded)
    if path == "union_fused":
        return q * default_kprime(k) * 8  # f32 dist + i32 id accumulator
    if path == "union_fused_scan":
        if pq_m:
            # PQ scan fallback: one [Q, chunk, T, M] f32 gathered-LUT-terms
            # chunk per step (chunk = 16 blocks), merged into the [Q, K']
            # (f32 dist + i32 id) carry
            return q * 16 * t * pq_m * 4 + q * default_kprime(k) * 8
        # lax.scan fallback: one [Q, chunk*T] score+id chunk per step,
        # merged into the [Q, K'] carry (chunk = 64 blocks)
        return q * (64 * t + default_kprime(k)) * 8
    if path.startswith("union"):
        return cb * q * t * 4  # full [CB, Q, T] f32 writeback
    if path == "block_table":
        return q * nprobe * budget * t * 4  # [Q, C, T] scores
    # chain_walk: one [Q, nprobe, T] frontier per hop
    return q * nprobe * t * 4


# (corpus size, block size T, query batch Q) — spans batch sizes and chain
# depths (smaller T => deeper per-cluster chains for the same corpus)
CONFIGS = ((20_000, 64, 10), (20_000, 64, 64), (10_000, 32, 10))


def run_pq(nprobe=8, k=10, iters=3, n=10_000, block_size=64, batch=64,
           pq_m=16):
    """Quantized-payload sweep at the acceptance batch size Q=64: the fused
    path's peak scoring intermediate stays [Q, K']-scale while block_table
    materializes [Q, C, T] ADC scores."""
    corpus = sift_like(n, 128, seed=7)
    idx = build_ivf(
        corpus, n_clusters=64, payload="pq", pq_m=pq_m,
        block_size=block_size, max_chain=64, nprobe=nprobe, k=k,
        add_batch=8192, capacity_vectors=int(1.2 * n),
    )
    budget = idx._chain_budget()
    rng = np.random.default_rng(8)
    q = jnp.asarray(corpus[rng.integers(0, n, batch)] + 0.01)
    rows = []
    ref_d = None
    for path in PQ_PATHS:
        fn = make_search_fn(
            idx.pool_cfg, nprobe=nprobe, k=k, path=path,
            score_fn=pqmod.pq_score_fn(idx.pq), pq=idx.pq,
            chain_budget=budget,
        )
        d, ids = fn(idx.state, q)
        jax.block_until_ready(ids)
        if ref_d is None:
            ref_d = np.asarray(d)
        else:
            # PQ distances tie whenever codes collide, so ids may permute at
            # equal distance — the distance ladder itself must agree
            np.testing.assert_allclose(
                np.asarray(d), ref_d, rtol=1e-4, atol=1e-3,
                err_msg=f"pq path {path} diverged",
            )
        t = timed(lambda: fn(idx.state, q), iters=iters)
        rows.append({
            "path": path,
            "payload": "pq",
            "pq_m": pq_m,
            "n": n,
            "batch": batch,
            "block_size": block_size,
            "chain_budget": budget,
            "us_per_call": round(t * 1e6, 1),
            "intermediate_bytes": intermediate_bytes(
                path, q=batch, nprobe=nprobe, budget=budget,
                t=block_size, k=k, pq_m=pq_m,
            ),
        })
    return rows


def run(nprobe=8, k=10, configs=CONFIGS, iters=3):
    rows = []
    indexes: dict = {}
    for n, block_size, batch in configs:
        if (n, block_size) not in indexes:
            corpus = sift_like(n, 128, seed=7)
            indexes[(n, block_size)] = (corpus, build_ivf(
                corpus, n_clusters=64, block_size=block_size,
                max_chain=64, nprobe=nprobe, k=k, add_batch=8192))
        corpus, idx = indexes[(n, block_size)]
        budget = idx._chain_budget()  # live chain depth, pow2-bucketed
        rng = np.random.default_rng(8)
        q = jnp.asarray(corpus[rng.integers(0, n, batch)] + 0.01)
        ref_ids = None
        for path in PATHS:
            fn = make_search_fn(idx.pool_cfg, nprobe=nprobe, k=k,
                                path=path, chain_budget=budget)
            d, ids = fn(idx.state, q)
            jax.block_until_ready(ids)
            if ref_ids is None:
                ref_ids = np.asarray(ids)
            else:
                assert (np.asarray(ids) == ref_ids).all(), (
                    f"{path} diverged (batch={batch}, T={block_size})"
                )
            t = timed(lambda: fn(idx.state, q), iters=iters)
            rows.append({
                "path": path,
                "n": n,
                "batch": batch,
                "block_size": block_size,
                "chain_budget": budget,
                "us_per_call": round(t * 1e6, 1),
                "intermediate_bytes": intermediate_bytes(
                    path, q=batch, nprobe=nprobe, budget=budget,
                    t=block_size, k=k,
                ),
            })
    return rows


def main():
    rows = run() + run_pq()
    print("path,payload,n,batch,block_size,us_per_call,intermediate_bytes")
    for r in rows:
        print(f"{r['path']},{r.get('payload', 'flat')},{r['n']},{r['batch']},"
              f"{r['block_size']},{r['us_per_call']},{r['intermediate_bytes']}")
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_scan_paths.json"
    out.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"wrote {out}")
    return rows


if __name__ == "__main__":
    main()
