"""ANNS search-path ladder (the §Perf ANNS hillclimb artifact):

chain_walk (paper-faithful linked list) -> block_table (vectorised gather)
-> union (dedup across batch) -> union_pallas (scalar-prefetch kernel)
-> union_fused (streaming top-k selection, no [C, Q, T] HBM writeback)
-> union_fused over quantized payloads (bf16 / int8 / PQ) [+ exact re-rank].

CPU wall-clock; the structural deltas (dependent-gather hops vs one gather;
per-query vs per-batch block reads; [C, Q, T] score writeback vs [Q, K']
accumulator; 4 vs 2 vs 1 payload bytes per dimension) carry to TPU where
they are DMA-count and HBM-traffic differences.

INTERPRET-MODE CAVEAT (the reason every row records ``grid_steps``): off-TPU
the Pallas kernels run ``interpret=True`` and each grid step costs ~1-10 ms
on this CPU regardless of how little it computes, so ``us_per_call`` for the
pallas paths measures *step count*, not kernel quality — a fused kernel that
moves 4x fewer HBM bytes can still wall-clock slower than its pure-XLA
``lax.scan`` fallback here.  Sweeps are therefore sized by step count
(configs keep every launched grid under ``MAX_GRID_STEPS``; larger ones are
recorded as skipped), and the byte metrics — not us_per_call — are the
quantities that transfer to hardware.

Metrics per row:

* ``intermediate_bytes`` — peak scoring intermediate between scoring and
  selection ([CB, Q, T] f32 writeback for the union paths vs the [Q, K']
  on-chip accumulator for the fused ones);
* ``payload_bytes_moved`` — pool-payload bytes DMA'd by the scan loop
  (C * T * D * itemsize): the quantity the dtype axis divides (f32 -> bf16
  halves it, f32 -> int8 quarters it);
* ``side_bytes_moved`` — non-payload per-slot bytes riding along (i32 ids,
  plus f32 scales for int8);
* ``prologue_bytes_moved`` — routing-operand bytes of the search prologue
  (coarse-probe output + candidate list + membership/probe-slot data the
  scan consumes).  The fused prologue pays O(Q*NP + CB): [Q, NP] probe
  ids/dists + [CB] block ids/owners — membership is derived in-kernel.
* ``prologue_bytes_moved_old`` — same accounting for the PR-3 prologue
  (dense [Q, N_clusters] coarse matrix in HBM + [Q, CB] cand_ok/pslot
  operand + [CB] block ids); the acceptance criterion is a >= 10x
  reduction at Q=64, nprobe=32 on the default synthetic config.
* ``grid_steps`` — Pallas grid steps launched (0 for pure-XLA paths; the
  pallas paths now include the ``coarse_topk`` prologue steps);
* ``recall_at_10`` — dtype sweep only, vs the exact fp32 brute-force oracle.

Writes ``BENCH_scan_paths.json`` ({"meta": ..., "rows": [...]}) at the repo
root when run as a script.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

try:
    from benchmarks.common import provenance, timed
except ImportError:  # run as `python benchmarks/scan_paths.py`
    import sys as _sys
    _sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import provenance, timed
from repro.core import build_ivf
from repro.core import pq as pqmod
from repro.core.metrics import recall_at_k
from repro.core.search import default_kprime, exact_search, make_search_fn
from repro.data.synthetic import sift_like

PATHS = (
    "chain_walk",
    "block_table",
    "union",
    "union_pallas",
    "union_fused",
    "union_fused_scan",
)

PQ_PATHS = ("block_table", "union_fused", "union_fused_scan")

DTYPES = ("float32", "bfloat16", "int8")

# interpret-mode grid-step budget per launched kernel (see module docstring):
# ~1-10 ms/step on CPU puts 512 steps at single-digit seconds per call.
MAX_GRID_STEPS = 512

ITEMSIZE = {"float32": 4, "bfloat16": 2, "int8": 1}


def candidate_cap(*, q: int, nprobe: int, budget: int, pool_blocks: int) -> int:
    """Static candidate-block count the fused kernels launch over: the
    NULL-padded union [Q*nprobe*budget] compacted to at most the pool size
    (every live block appears at most once)."""
    return min(q * nprobe * budget, pool_blocks)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def coarse_grid_steps(q: int, n_clusters: int, q_tile: int = 128,
                      c_tile: int = 128) -> int:
    """Grid steps of the streaming coarse probe (coarse_topk defaults)."""
    qt = min(q_tile, _ceil_div(q, 8) * 8)
    tc = min(c_tile, _ceil_div(n_clusters, 8) * 8)
    return _ceil_div(q, qt) * _ceil_div(n_clusters, tc)


def grid_steps(path: str, *, q: int, nprobe: int, budget: int,
               pool_blocks: int, n_clusters: int, pq: bool = False,
               rerank: bool = False) -> int:
    """Pallas grid steps a config launches (0 = no kernel: pure XLA)."""
    cap = candidate_cap(q=q, nprobe=nprobe, budget=budget,
                        pool_blocks=pool_blocks)
    if path == "union_pallas":
        # ivf_block_scan now runs over the *compacted* candidate list
        # (the prologue dedups + truncates), plus the coarse prologue
        return cap + coarse_grid_steps(q, n_clusters)
    if path == "union_fused":
        q_tile = 8 if pq else 128  # kernel defaults (LUT tile vs query tile)
        steps = _ceil_div(q, q_tile) * cap + coarse_grid_steps(q, n_clusters)
        if rerank:
            steps += _ceil_div(q, 8)  # one re-rank step per 8-query tile
        return steps
    return 0


def intermediate_bytes(path: str, *, q: int, nprobe: int, budget: int,
                       t: int, k: int, pool_blocks: int,
                       pq_m: int = 0) -> int:
    """Peak scoring-intermediate bytes between scoring and selection."""
    cb = candidate_cap(q=q, nprobe=nprobe, budget=budget,
                       pool_blocks=pool_blocks)  # compacted union list
    if path == "union_fused":
        return q * default_kprime(k) * 8  # f32 dist + i32 id accumulator
    if path == "union_fused_scan":
        if pq_m:
            # PQ scan fallback: one [Q, chunk, T, M] f32 gathered-LUT-terms
            # chunk per step (chunk = 16 blocks), merged into the [Q, K']
            # (f32 dist + i32 id) carry
            return q * 16 * t * pq_m * 4 + q * default_kprime(k) * 8
        # lax.scan fallback: one [Q, chunk*T] score+id chunk per step,
        # merged into the [Q, K'] carry (chunk = 64 blocks)
        return q * (64 * t + default_kprime(k)) * 8
    if path.startswith("union"):
        return cb * q * t * 4  # full [CB, Q, T] f32 writeback
    if path == "block_table":
        return q * nprobe * budget * t * 4  # [Q, C, T] scores
    # chain_walk: one [Q, nprobe, T] frontier per hop
    return q * nprobe * t * 4


def payload_bytes_moved(path: str, *, q: int, nprobe: int, budget: int,
                        t: int, d: int, pool_blocks: int,
                        dtype: str = "float32", pq_m: int = 0) -> int:
    """Pool-payload bytes the scan loop reads from HBM.  This is the
    latency floor the dtype axis attacks: bf16 halves it, int8 quarters it,
    PQ reads 1 byte per subquantizer."""
    per_vec = pq_m if pq_m else d * ITEMSIZE[dtype]
    if path.startswith("union"):
        # the whole union family now scans the deduped *compacted*
        # candidate list (plain union/union_pallas included — they used to
        # score every NULL-padded slot against clamped block 0)
        cap = candidate_cap(q=q, nprobe=nprobe, budget=budget,
                            pool_blocks=pool_blocks)
        return cap * t * per_vec
    # the per-query gather paths read q*nprobe*budget slots
    return q * nprobe * budget * t * per_vec


def side_bytes_moved(path: str, *, q: int, nprobe: int, budget: int,
                     t: int, pool_blocks: int, dtype: str = "float32") -> int:
    """Non-payload per-slot bytes riding along with the scan (i32 vector
    ids; int8 additionally streams one f32 scale per vector)."""
    per_slot = 4 + (4 if dtype == "int8" else 0)
    if path.startswith("union"):
        cap = candidate_cap(q=q, nprobe=nprobe, budget=budget,
                            pool_blocks=pool_blocks)
        return cap * t * per_slot
    return q * nprobe * budget * t * per_slot


UNION_PATHS = ("union", "union_pallas", "union_fused", "union_fused_scan")


def prologue_bytes_moved(path: str, *, q: int, nprobe: int, budget: int,
                         pool_blocks: int, n_clusters: int) -> int:
    """Routing-operand bytes of the *current* search prologue: everything
    the dispatch moves to decide which rows each query scores, excluding
    the payload/id/scale traffic counted above.

    Union family (fused prologue): the streaming coarse probe emits
    [Q, NP] probe ids + dists (8 B/entry, the [Q, N] matrix never exists),
    and the kernels consume the [CB] candidate block ids + [CB] owners
    (4 B each) — membership/probe slots are derived on-chip, so per-query
    routing is O(NP).  block_table/chain_walk still materialize the dense
    [Q, N] coarse matrix and gather per query."""
    cap = candidate_cap(q=q, nprobe=nprobe, budget=budget,
                        pool_blocks=pool_blocks)
    if path in UNION_PATHS:
        return q * nprobe * 8 + cap * 8
    return q * n_clusters * 4 + q * nprobe * 4


def prologue_bytes_moved_old(path: str, *, q: int, nprobe: int, budget: int,
                             pool_blocks: int, n_clusters: int) -> int:
    """Same accounting for the PR-3 prologue: dense [Q, N_clusters] f32
    coarse matrix in HBM, a [Q, CB] i32 cand_ok/pslot operand shipped into
    the fused kernels, and the [CB] i32 block ids.  Non-union paths are
    unchanged."""
    cap = candidate_cap(q=q, nprobe=nprobe, budget=budget,
                        pool_blocks=pool_blocks)
    if path in UNION_PATHS:
        return q * n_clusters * 4 + q * cap * 4 + cap * 4
    return prologue_bytes_moved(path, q=q, nprobe=nprobe, budget=budget,
                                pool_blocks=pool_blocks,
                                n_clusters=n_clusters)


# (corpus size, block size T, query batch Q) — spans batch sizes and chain
# depths (smaller T => deeper per-cluster chains for the same corpus),
# sized so every launched Pallas grid stays under MAX_GRID_STEPS.
CONFIGS = ((6_000, 64, 10), (6_000, 64, 64), (4_000, 32, 10))


def _row_common(path, idx, *, n, batch, nprobe, budget, block_size, k,
                dtype="float32", pq_m=0, rerank=False):
    pool_blocks = idx.pool_cfg.n_blocks
    n_clusters = idx.pool_cfg.n_clusters
    return {
        "path": path,
        "payload": "pq" if pq_m else "flat",
        "dtype": "uint8-codes" if pq_m else dtype,
        "rerank": rerank,
        "n": n,
        "batch": batch,
        "nprobe": nprobe,
        "n_clusters": n_clusters,
        "block_size": block_size,
        "chain_budget": budget,
        "grid_steps": grid_steps(
            path, q=batch, nprobe=nprobe, budget=budget,
            pool_blocks=pool_blocks, n_clusters=n_clusters, pq=bool(pq_m),
            rerank=rerank,
        ),
        "intermediate_bytes": intermediate_bytes(
            path, q=batch, nprobe=nprobe, budget=budget, t=block_size,
            k=k, pool_blocks=pool_blocks, pq_m=pq_m,
        ),
        "payload_bytes_moved": payload_bytes_moved(
            path, q=batch, nprobe=nprobe, budget=budget, t=block_size,
            d=idx.pool_cfg.dim, pool_blocks=pool_blocks, dtype=dtype,
            pq_m=pq_m,
        ),
        "side_bytes_moved": side_bytes_moved(
            path, q=batch, nprobe=nprobe, budget=budget, t=block_size,
            pool_blocks=pool_blocks, dtype=dtype,
        ),
        "prologue_bytes_moved": prologue_bytes_moved(
            path, q=batch, nprobe=nprobe, budget=budget,
            pool_blocks=pool_blocks, n_clusters=n_clusters,
        ),
        "prologue_bytes_moved_old": prologue_bytes_moved_old(
            path, q=batch, nprobe=nprobe, budget=budget,
            pool_blocks=pool_blocks, n_clusters=n_clusters,
        ),
    }


def run(nprobe=8, k=10, configs=CONFIGS, iters=3):
    """Flat-f32 ladder: every path cross-checked against the first, timed
    unless its grid would blow the interpret-mode step budget."""
    rows = []
    indexes: dict = {}
    for n, block_size, batch in configs:
        if (n, block_size) not in indexes:
            corpus = sift_like(n, 128, seed=7)
            indexes[(n, block_size)] = (corpus, build_ivf(
                corpus, n_clusters=64, block_size=block_size,
                max_chain=64, nprobe=nprobe, k=k, add_batch=8192))
        corpus, idx = indexes[(n, block_size)]
        budget = idx._chain_budget()  # live chain depth, pow2-bucketed
        rng = np.random.default_rng(8)
        q = jnp.asarray(corpus[rng.integers(0, n, batch)] + 0.01)
        ref_ids = None
        for path in PATHS:
            row = _row_common(path, idx, n=n, batch=batch, nprobe=nprobe,
                              budget=budget, block_size=block_size, k=k)
            if row["grid_steps"] > MAX_GRID_STEPS:
                row.update(us_per_call=None, skipped="grid_steps over "
                           f"MAX_GRID_STEPS={MAX_GRID_STEPS}")
                rows.append(row)
                continue
            fn = make_search_fn(idx.pool_cfg, nprobe=nprobe, k=k,
                                path=path, chain_budget=budget)
            d, ids = fn(idx.state, q)
            jax.block_until_ready(ids)
            if ref_ids is None:
                ref_ids = np.asarray(ids)
            else:
                assert (np.asarray(ids) == ref_ids).all(), (
                    f"{path} diverged (batch={batch}, T={block_size})"
                )
            t = timed(lambda: fn(idx.state, q), iters=iters)
            row["us_per_call"] = round(t * 1e6, 1)
            rows.append(row)
    return rows


def run_dtypes(nprobe=8, k=10, iters=3, n=8_000, block_size=64, batch=64,
               n_clusters=384):
    """The dtype axis on ``union_fused`` at the acceptance batch Q=64:
    payload bytes drop 2x (bf16) / 4x (int8) while the exact re-rank
    epilogue holds recall@10 at the fp32 level.  Asserts the acceptance
    criteria so regeneration enforces them.

    The coarse quantizer is finer here (384 lists) than in the f32 ladder:
    int8 rows are *residual* codes, so more centroids directly shrink the
    8-bit quantization step (the same nprobe/cluster geometry is used for
    every dtype, so the comparison is apples-to-apples)."""
    corpus = sift_like(n, 128, seed=7)
    rng = np.random.default_rng(8)
    qsel = rng.integers(0, n, batch)
    q = jnp.asarray(corpus[qsel] + 0.01)
    _, true_ids = exact_search(jnp.asarray(corpus), q, k)
    true_ids = np.asarray(true_ids)

    rows = []
    recalls = {}
    for dtype in DTYPES:
        idx = build_ivf(
            corpus, n_clusters=n_clusters, block_size=block_size,
            max_chain=64, nprobe=nprobe, k=k, add_batch=8192, dtype=dtype,
        )
        budget = idx._chain_budget()
        variants = [False] if dtype == "float32" else [False, True]
        for rerank in variants:
            row = _row_common(
                "union_fused", idx, n=n, batch=batch, nprobe=nprobe,
                budget=budget, block_size=block_size, k=k, dtype=dtype,
                rerank=rerank,
            )
            assert row["grid_steps"] <= MAX_GRID_STEPS, row
            fn = make_search_fn(
                idx.pool_cfg, nprobe=nprobe, k=k, path="union_fused",
                chain_budget=budget, rerank=rerank,
            )
            d, ids = fn(idx.state, q)
            jax.block_until_ready(ids)
            rec = recall_at_k(np.asarray(ids), true_ids, k)
            recalls[(dtype, rerank)] = rec
            t = timed(lambda: fn(idx.state, q), iters=iters)
            row.update(us_per_call=round(t * 1e6, 1),
                       recall_at_10=round(rec, 4))
            rows.append(row)

    # acceptance: payload bytes 2x / 4x down, int8+rerank recall within
    # 0.5% of the fp32 fused path
    f32 = next(r for r in rows if r["dtype"] == "float32")
    bf16 = next(r for r in rows if r["dtype"] == "bfloat16")
    i8 = next(r for r in rows if r["dtype"] == "int8")
    assert f32["payload_bytes_moved"] >= 2 * bf16["payload_bytes_moved"]
    assert f32["payload_bytes_moved"] >= 4 * i8["payload_bytes_moved"]
    gap = recalls[("float32", False)] - recalls[("int8", True)]
    assert gap <= 0.005, (
        f"int8+rerank recall {recalls[('int8', True)]:.4f} more than 0.5% "
        f"below fp32 {recalls[('float32', False)]:.4f}"
    )
    return rows


def run_prologue(nprobe=32, k=10, iters=3, n=8_000, block_size=64, batch=64,
                 n_clusters=384):
    """Acceptance sweep for the fused routing prologue at Q=64, nprobe=32
    on the default synthetic config: the routing-operand bytes of the
    fused dispatch must be >= 10x below the PR-3 prologue (dense [Q, N]
    coarse matrix + [Q, CB] membership/probe-slot operands).  Asserted
    in-script so regeneration enforces it."""
    corpus = sift_like(n, 128, seed=7)
    idx = build_ivf(
        corpus, n_clusters=n_clusters, block_size=block_size, max_chain=64,
        nprobe=nprobe, k=k, add_batch=8192,
    )
    budget = idx._chain_budget()
    rng = np.random.default_rng(8)
    q = jnp.asarray(corpus[rng.integers(0, n, batch)] + 0.01)
    rows = []
    ref_ids = None
    for path in ("block_table", "union_fused", "union_fused_scan"):
        row = _row_common(path, idx, n=n, batch=batch, nprobe=nprobe,
                          budget=budget, block_size=block_size, k=k)
        row["sweep"] = "prologue"
        if row["grid_steps"] > MAX_GRID_STEPS:
            row.update(us_per_call=None, skipped="grid_steps over "
                       f"MAX_GRID_STEPS={MAX_GRID_STEPS}")
            rows.append(row)
            continue
        fn = make_search_fn(idx.pool_cfg, nprobe=nprobe, k=k, path=path,
                            chain_budget=budget)
        d, ids = fn(idx.state, q)
        jax.block_until_ready(ids)
        if ref_ids is None:
            ref_ids = np.asarray(ids)
        else:
            assert (np.asarray(ids) == ref_ids).all(), f"{path} diverged"
        t = timed(lambda: fn(idx.state, q), iters=iters)
        row["us_per_call"] = round(t * 1e6, 1)
        rows.append(row)
    fused = next(r for r in rows if r["path"] == "union_fused")
    ratio = fused["prologue_bytes_moved_old"] / fused["prologue_bytes_moved"]
    assert ratio >= 10.0, (
        f"prologue routing bytes only dropped {ratio:.1f}x "
        f"(old {fused['prologue_bytes_moved_old']}, "
        f"new {fused['prologue_bytes_moved']}) at Q={batch}, nprobe={nprobe}"
    )
    return rows


def run_coarse(nprobe=16, iters=3, batch=64, dim=128,
               sweep=(64, 128, 256, 512)):
    """Coarse-probe sweep over N_clusters: the streaming ``coarse_topk``
    kernel (interpret mode off-TPU — grid steps dominate wall clock, the
    byte column is what transfers) vs the dense ``coarse_probe`` matmul.
    Results are cross-checked bit-exact per N."""
    import types

    from repro.kernels.ivf_scan import coarse_topk
    from repro.core.search import coarse_probe

    rng = np.random.default_rng(9)
    queries = jnp.asarray(rng.normal(size=(batch, dim)), jnp.float32)
    rows = []
    for n_clusters in sweep:
        cents = jnp.asarray(
            rng.normal(size=(n_clusters, dim)), jnp.float32
        )
        probe_fn = jax.jit(
            lambda c, qs: coarse_probe(types.SimpleNamespace(centroids=c),
                                       qs, nprobe)
        )
        kern_fn = jax.jit(
            lambda c, qs: coarse_topk(qs, c, nprobe=nprobe, interpret=True)
        )
        want_i, want_d = probe_fn(cents, queries)
        got_i, got_d = kern_fn(cents, queries)
        assert (np.asarray(got_i) == np.asarray(want_i)).all(), n_clusters
        assert (np.asarray(got_d) == np.asarray(want_d)).all(), n_clusters
        steps = coarse_grid_steps(batch, n_clusters)
        for name, fn, gsteps, pbytes in (
            ("coarse_probe", probe_fn, 0,
             batch * n_clusters * 4 + batch * nprobe * 8),
            ("coarse_topk", kern_fn, steps, batch * nprobe * 8),
        ):
            if gsteps > MAX_GRID_STEPS:
                rows.append({"path": name, "sweep": "coarse",
                             "n_clusters": n_clusters, "batch": batch,
                             "nprobe": nprobe, "grid_steps": gsteps,
                             "prologue_bytes_moved": pbytes,
                             "us_per_call": None,
                             "skipped": "grid_steps over "
                                        f"MAX_GRID_STEPS={MAX_GRID_STEPS}"})
                continue
            t = timed(lambda: fn(cents, queries), iters=iters)
            rows.append({"path": name, "sweep": "coarse",
                         "n_clusters": n_clusters, "batch": batch,
                         "nprobe": nprobe, "grid_steps": gsteps,
                         "prologue_bytes_moved": pbytes,
                         "us_per_call": round(t * 1e6, 1)})
    return rows


def run_pq(nprobe=8, k=10, iters=3, n=4_000, block_size=32, batch=16,
           pq_m=16):
    """Quantized-PQ sweep (batch sized by grid steps: the PQ kernel's
    q_tile is 8, so Q=16 keeps the grid at 2 * cap steps)."""
    corpus = sift_like(n, 128, seed=7)
    idx = build_ivf(
        corpus, n_clusters=64, payload="pq", pq_m=pq_m,
        block_size=block_size, max_chain=64, nprobe=nprobe, k=k,
        add_batch=8192, capacity_vectors=int(1.2 * n),
    )
    budget = idx._chain_budget()
    rng = np.random.default_rng(8)
    q = jnp.asarray(corpus[rng.integers(0, n, batch)] + 0.01)
    rows = []
    ref_d = None
    for path in PQ_PATHS:
        row = _row_common(path, idx, n=n, batch=batch, nprobe=nprobe,
                          budget=budget, block_size=block_size, k=k,
                          pq_m=pq_m)
        if row["grid_steps"] > MAX_GRID_STEPS:
            row.update(us_per_call=None, skipped="grid_steps over "
                       f"MAX_GRID_STEPS={MAX_GRID_STEPS}")
            rows.append(row)
            continue
        fn = make_search_fn(
            idx.pool_cfg, nprobe=nprobe, k=k, path=path,
            score_fn=pqmod.pq_score_fn(idx.pq), pq=idx.pq,
            chain_budget=budget,
        )
        d, ids = fn(idx.state, q)
        jax.block_until_ready(ids)
        if ref_d is None:
            ref_d = np.asarray(d)
        else:
            # PQ distances tie whenever codes collide, so ids may permute at
            # equal distance — the distance ladder itself must agree
            np.testing.assert_allclose(
                np.asarray(d), ref_d, rtol=1e-4, atol=1e-3,
                err_msg=f"pq path {path} diverged",
            )
        t = timed(lambda: fn(idx.state, q), iters=iters)
        row["us_per_call"] = round(t * 1e6, 1)
        rows.append(row)
    return rows


META = {
    "schema": {
        "us_per_call": "median wall-clock; null when skipped (see "
                       "interpret_mode_caveat)",
        "grid_steps": "Pallas grid steps launched; 0 = pure-XLA path",
        "intermediate_bytes": "peak scoring intermediate between scoring "
                              "and selection",
        "payload_bytes_moved": "pool-payload bytes the scan loop reads "
                               "(C*T*D*itemsize) — the dtype axis divides "
                               "this 2x (bf16) / 4x (int8)",
        "side_bytes_moved": "per-slot i32 ids (+ f32 scales for int8) "
                            "riding along with the scan",
        "prologue_bytes_moved": "routing-operand bytes of the search "
                                "prologue (union family: [Q,NP] probe "
                                "ids/dists + [CB] block ids/owners — "
                                "membership derived in-kernel)",
        "prologue_bytes_moved_old": "the PR-3 prologue's routing bytes "
                                    "([Q,N] coarse matrix + [Q,CB] "
                                    "cand_ok/pslot + [CB] ids); acceptance "
                                    "is >= 10x reduction at Q=64, "
                                    "nprobe=32 (asserted in run_prologue)",
        "recall_at_10": "dtype sweep only: vs exact fp32 brute force",
        "skipped": "present when the config was not timed",
    },
    "interpret_mode_caveat": (
        "Off-TPU, Pallas kernels run interpret=True at ~1-10 ms per grid "
        "step regardless of compute, so us_per_call for pallas paths "
        "measures grid-step count, not kernel quality; sweeps are sized by "
        "step count (grid_steps <= MAX_GRID_STEPS) and the byte columns "
        "are the quantities that carry to TPU."
    ),
    "max_grid_steps": MAX_GRID_STEPS,
}


def main():
    rows = run() + run_dtypes() + run_prologue() + run_coarse() + run_pq()
    print("path,payload,dtype,rerank,n,batch,block_size,us_per_call,"
          "grid_steps,intermediate_bytes,payload_bytes_moved,"
          "prologue_bytes_moved")
    for r in rows:
        print(f"{r['path']},{r.get('payload')},{r.get('dtype')},"
              f"{r.get('rerank')},{r.get('n')},{r['batch']},"
              f"{r.get('block_size')},{r['us_per_call']},"
              f"{r['grid_steps']},{r.get('intermediate_bytes')},"
              f"{r.get('payload_bytes_moved')},"
              f"{r.get('prologue_bytes_moved')}")
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_scan_paths.json"
    out.write_text(json.dumps({
        "provenance": provenance(
            "scan_paths",
            geometry={"dim": 128, "n_clusters": 64,
                      "max_grid_steps": MAX_GRID_STEPS},
            samples={"rows": len(rows), "iters_per_row": 3},
        ),
        "meta": META, "rows": rows,
    }, indent=2) + "\n")
    print(f"wrote {out}")
    return rows


if __name__ == "__main__":
    main()
