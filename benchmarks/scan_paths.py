"""ANNS search-path ladder (the §Perf ANNS hillclimb artifact):

chain_walk (paper-faithful linked list) -> block_table (vectorised gather)
-> union (dedup across batch) -> union_pallas (scalar-prefetch kernel).

CPU wall-clock; the structural deltas (dependent-gather hops vs one gather;
per-query vs per-batch block reads) carry to TPU where they are DMA-count
and HBM-traffic differences.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core import build_ivf
from repro.core.search import make_search_fn
from repro.data.synthetic import sift_like

PATHS = ("chain_walk", "block_table", "union", "union_pallas")


def run(n=20_000, nprobe=8, k=10, batch=10):
    corpus = sift_like(n, 128, seed=7)
    idx = build_ivf(corpus, n_clusters=64, block_size=64, max_chain=64,
                    nprobe=nprobe, k=k, add_batch=8192)
    rng = np.random.default_rng(8)
    q = jnp.asarray(corpus[rng.integers(0, n, batch)] + 0.01)
    rows = []
    ref_ids = None
    for path in PATHS:
        fn = make_search_fn(idx.pool_cfg, nprobe=nprobe, k=k, path=path)
        d, ids = fn(idx.state, q)
        jax.block_until_ready(ids)
        if ref_ids is None:
            ref_ids = np.asarray(ids)
        else:
            assert (np.asarray(ids) == ref_ids).all(), f"{path} diverged"
        t = timed(lambda: fn(idx.state, q), iters=9)
        rows.append({"path": path, "us_per_call": round(t * 1e6, 1)})
    return rows


def main():
    rows = run()
    print("path,us_per_call")
    for r in rows:
        print(f"{r['path']},{r['us_per_call']}")
    return rows


if __name__ == "__main__":
    main()
