"""Benchmark aggregator: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines at the end, plus each
benchmark's own detailed table.  Default is a scaled fast mode; ``--full``
uses larger corpora (slower, same structure).
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args, _ = ap.parse_known_args()
    fast = not args.full

    summary = []

    print("=" * 72)
    print("## Fig. 3 — latency vs QPS grid (4 systems x 2 datasets)")
    print("=" * 72)
    from benchmarks import fig3_latency_qps

    t0 = time.time()
    rows = fig3_latency_qps.main(fast=fast)
    rt = [r for r in rows if r["system"] == "rtams"]
    base = [r for r in rows if r["system"] != "rtams"]
    summary.append((
        "fig3_latency_qps",
        round(1e6 * (time.time() - t0) / max(len(rows), 1), 1),
        f"rtams_mean_ms={sum(r['latency_avg_ms'] for r in rt)/len(rt):.2f};"
        f"baseline_mean_ms={sum(r['latency_avg_ms'] for r in base)/len(base):.2f}",
    ))

    print()
    print("=" * 72)
    print("## Fig. 3 (measured) — open-loop load sweep on the real runtime")
    print("=" * 72)
    from benchmarks import loadgen

    t0 = time.time()
    out = loadgen.main(fast=fast)
    cells = out["cells"]
    rep = out["assertions"]
    summary.append((
        "loadgen_fig3",
        round(1e6 * (time.time() - t0) / max(len(cells), 1), 1),
        f"adaptive_p99_growth={rep['adaptive_p99_growth']}x;"
        f"compiled={max(rep['compiled_steps'].values())}",
    ))

    print()
    print("=" * 72)
    print("## Table 1 — rearrangement threshold vs cost")
    print("=" * 72)
    from benchmarks import table1_rearrangement

    t0 = time.time()
    rows = table1_rearrangement.main()
    summary.append((
        "table1_rearrangement",
        round(1e6 * (time.time() - t0) / max(len(rows), 1), 1),
        f"max_cost_ms={max(r['rearrange_cost_ms'] for r in rows)}",
    ))

    print()
    print("=" * 72)
    print("## Fig. 4 — memory block size sweep")
    print("=" * 72)
    from benchmarks import fig4_block_size

    t0 = time.time()
    rows = fig4_block_size.main()
    summary.append((
        "fig4_block_size",
        round(1e6 * (time.time() - t0) / max(len(rows), 1), 1),
        f"best_block={min(rows, key=lambda r: r['search_ms'])['block_size']}",
    ))

    print()
    print("=" * 72)
    print("## Recall parity (IVFFlat / IVFPQ vs brute force; RTAMS vs RAFT)")
    print("=" * 72)
    from benchmarks import recall

    t0 = time.time()
    rows, parity = recall.main()
    summary.append((
        "recall",
        round(1e6 * (time.time() - t0) / max(len(rows), 1), 1),
        f"parity_vs_raft={parity:.4f}",
    ))

    print()
    print("=" * 72)
    print("## Search path ladder (chain_walk -> block_table -> union -> pallas)")
    print("=" * 72)
    from benchmarks import scan_paths

    t0 = time.time()
    rows = scan_paths.main()
    summary.append((
        "scan_paths",
        round(1e6 * (time.time() - t0) / max(len(rows), 1), 1),
        ";".join(f"{r['path']}={r['us_per_call']}us" for r in rows),
    ))

    print()
    print("name,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
