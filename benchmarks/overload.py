"""Overload protection artifact: admission control + degradation ladder.

Drives the serving runtime at **2x its sustainable mutation throughput**
(service rate pinned deterministically with a ``FaultPlan`` delay on the
mutation lane — no host-speed tuning) and contrasts:

* **unprotected** (the seed behaviour, ``max_pending_mutations=None``) —
  the pending-row backlog grows without bound for as long as the overload
  lasts (the classic queue death spiral: every request is eventually
  served, arbitrarily late);
* **protected** (bounded admission, ``reject`` policy) — backlog stays
  under the configured cap at all times and the excess is rejected in the
  caller's thread, so accepted requests keep bounded latency.

A third section overloads the *search* lane (slots pinned busy by a
``search_step`` delay) and shows the degradation ladder stepping down
under the queue-age watermark and back up when pressure clears.

The ISSUE's acceptance bar is asserted in-script:

* unprotected backlog grows monotonically across sample windows and ends
  above a floor proportional to the injected excess;
* protected backlog never exceeds the cap, with a nonzero reject count;
* every accepted future resolves (no hangs under overload);
* the ladder reports at least one downward transition under pressure.

Writes ``BENCH_overload.json`` at the repo root when run as a script.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

try:
    from benchmarks.common import provenance
except ImportError:  # run as `python benchmarks/overload.py`
    import sys as _sys
    _sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import provenance

from repro.core import build_ivf
from repro.core.admission import RequestRejected
from repro.core.faults import FaultPlan
from repro.core.runtime import RuntimeConfig, ServingRuntime

DIM = 32
N0 = 2000
N_CLUSTERS = 8
SERVICE_DELAY = 0.05  # injected per-iteration stall on the mutation lane
BATCH_ROWS = 32  # rows per submitted insert == flush_min (one batch/cycle)
DRIVE_S = 2.0  # overload duration
SAMPLE_DT = 0.1
CAP = 128  # protected run: max pending rows (4 batches)


def _make_index(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(N0, DIM)).astype(np.float32)
    return x, build_ivf(
        x, n_clusters=N_CLUSTERS, block_size=32, max_chain=64,
        nprobe=4, k=10, capacity_vectors=12 * N0, add_batch=512,
    )


def _drive_mutations(rt: ServingRuntime, rate_hz: float, seconds: float):
    """Submit BATCH_ROWS-row inserts at ``rate_hz``, absolute-scheduled
    (a slow submit never silently lowers the offered load).  Samples the
    pending-row gauge every SAMPLE_DT.  Returns (samples, futures,
    rejects, offered)."""
    rng = np.random.default_rng(1)
    samples, futures, rejects, offered = [], [], 0, 0
    dt = 1.0 / rate_hz
    t0 = time.perf_counter()
    next_submit, next_sample = t0, t0
    while True:
        now = time.perf_counter()
        if now - t0 >= seconds:
            break
        if now >= next_sample:
            samples.append(rt.stats()["pending_mutations"])
            next_sample += SAMPLE_DT
        if now >= next_submit:
            offered += 1
            try:
                futures.append(rt.submit_insert(
                    rng.normal(size=(BATCH_ROWS, DIM)).astype(np.float32)
                ))
            except RequestRejected:
                rejects += 1
            next_submit += dt
        time.sleep(0.002)
    return samples, futures, rejects, offered


def _window_means(samples, n=4):
    w = max(1, len(samples) // n)
    return [float(np.mean(samples[i * w : (i + 1) * w])) for i in range(n)]


def mutation_overload(bounded: bool):
    """One overload run; service rate is BATCH_ROWS rows per SERVICE_DELAY
    cycle, offered load is 2x that."""
    x, idx = _make_index()
    plan = FaultPlan().delay("insert_loop", SERVICE_DELAY, nth=None)
    rt = ServingRuntime(
        idx,
        RuntimeConfig(
            mode="parallel", nprobe=4, k=10,
            flush_min=BATCH_ROWS, flush_max=BATCH_ROWS,
            flush_interval=SERVICE_DELAY,
            max_pending_mutations=CAP if bounded else None,
            admission="reject",
        ),
        faults=plan,
    )
    try:
        # warmup outside the measurement: pays the insert-step compile
        rt.submit_insert(x[:BATCH_ROWS]).result(timeout=120)
        sustainable_hz = 1.0 / SERVICE_DELAY  # one batch per delayed cycle
        samples, futures, rejects, offered = _drive_mutations(
            rt, rate_hz=2.0 * sustainable_hz, seconds=DRIVE_S
        )
        peak = max(samples)
        # no accepted future may hang under overload
        unresolved = 0
        for f in futures:
            try:
                f.result(timeout=120)
            except Exception:
                unresolved += 1  # typed failure still counts as resolved
        return {
            "bounded": bounded,
            "cap_rows": CAP if bounded else None,
            "offered_batches": offered,
            "accepted_batches": len(futures),
            "rejected_batches": rejects,
            "pending_rows_samples": samples,
            "pending_rows_window_means": _window_means(samples),
            "pending_rows_peak": peak,
            "pending_rows_final": samples[-1],
            "failed_futures": unresolved,
            "stats": {
                k: rt.stats()[k]
                for k in ("rejected_mutation", "inserts", "poisoned")
            },
        }
    finally:
        rt.stop()


def search_overload():
    """Pin search dispatch slow; the ladder must step down under the
    queue-age watermark and back up when pressure clears."""
    x, idx = _make_index(seed=5)
    plan = FaultPlan().delay("search_step", 0.08, nth=range(12))
    rt = ServingRuntime(
        idx,
        RuntimeConfig(
            mode="parallel", nprobe=4, k=10, n_slots=64, max_search_batch=1,
            degradation_ladder=("no_rerank", "half_nprobe"),
            overload_high=0.05, overload_low=0.01, overload_patience=2,
        ),
        faults=plan,
    )
    try:
        rt.submit_search(x[:1]).result(timeout=120)  # compile warmup
        futures = [rt.submit_search(x[i : i + 1]) for i in range(14)]
        for f in futures:
            f.result(timeout=120)
        s_peak = rt.stats()
        # pressure cleared: trickle until full service returns
        t_end = time.perf_counter() + 60
        while rt.stats()["degradation_level"] > 0:
            assert time.perf_counter() < t_end, "ladder never recovered"
            rt.submit_search(x[:1]).result(timeout=120)
        return {
            "rung_at_peak": s_peak["degradation_rung"],
            "level_at_peak": s_peak["degradation_level"],
            "transitions": rt.stats()["degradation_transitions"],
            "recovered_rung": rt.stats()["degradation_rung"],
            "search_steps_compiled": len(rt._search_steps),
        }
    finally:
        rt.stop()


META = {
    "schema": {
        "pending_rows_samples": "admission-gate pending-row gauge, "
                                f"sampled every {SAMPLE_DT}s during the "
                                "overload drive",
        "pending_rows_window_means": "samples split into 4 windows; the "
                                     "unprotected run must be strictly "
                                     "increasing across them (asserted)",
        "rejected_batches": "QueueFull raised in the caller's thread "
                            "(protected run only)",
        "rung_at_peak": "degradation ladder rung active while the search "
                        "lane was pinned slow",
    },
    "workload": {
        "service": f"one {BATCH_ROWS}-row batch per {SERVICE_DELAY}s "
                   "cycle (FaultPlan delay on the mutation lane)",
        "offered": "2x the sustainable batch rate for "
                   f"{DRIVE_S}s; excess ~{int(DRIVE_S / SERVICE_DELAY)}"
                   " batches",
        "cap_rows": CAP,
    },
}


def main():
    unprot = mutation_overload(bounded=False)
    prot = mutation_overload(bounded=True)
    ladder = search_overload()

    # ---- the ISSUE's acceptance bar, asserted in-script ------------------
    wm = unprot["pending_rows_window_means"]
    assert all(b > a for a, b in zip(wm, wm[1:])), (
        f"unprotected backlog not monotone across windows: {wm}"
    )
    excess_rows = DRIVE_S / SERVICE_DELAY * BATCH_ROWS  # offered - served
    assert unprot["pending_rows_final"] >= 0.25 * excess_rows, unprot
    assert unprot["rejected_batches"] == 0

    assert prot["pending_rows_peak"] <= CAP, prot["pending_rows_peak"]
    assert prot["rejected_batches"] > 0
    assert prot["failed_futures"] == 0 and unprot["failed_futures"] == 0

    assert ladder["level_at_peak"] >= 1, ladder
    assert ladder["transitions"] >= 2  # down under load, up after
    assert ladder["recovered_rung"] == "full"

    print("run,offered,accepted,rejected,peak_pending,final_pending")
    for r in (unprot, prot):
        tag = "protected" if r["bounded"] else "unprotected"
        print(f"{tag},{r['offered_batches']},{r['accepted_batches']},"
              f"{r['rejected_batches']},{r['pending_rows_peak']},"
              f"{r['pending_rows_final']}")
    print(f"ladder: peak={ladder['rung_at_peak']} "
          f"transitions={ladder['transitions']} "
          f"recovered={ladder['recovered_rung']}")
    out = pathlib.Path(__file__).resolve().parent.parent / \
        "BENCH_overload.json"
    out.write_text(json.dumps(
        {"provenance": provenance(
            "overload",
            geometry={"dim": DIM, "corpus": N0, "n_clusters": N_CLUSTERS,
                      "batch_rows": BATCH_ROWS},
            samples={"runs": 2,
                     "pending_samples": len(unprot["pending_rows_samples"]),
                     "drive_seconds": DRIVE_S},
         ),
         "meta": META,
         "rows": [unprot, prot],
         "ladder": ladder},
        indent=1,
    ))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
