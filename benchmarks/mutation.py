"""Online-mutation churn sweep (the mutation-subsystem §Perf artifact).

Per target dead fraction ∈ {0, 0.1, 0.3} the sweep drives an index through
interleaved insert/delete/update rounds, then measures:

* per-row mutation latency (delete / update / insert dispatches, wall-clock
  with the state donated — the thing the tombstone design keeps O(batch));
* search latency + recall@10 against the exact oracle over the *live*
  corpus, before and after compaction;
* reclamation: compaction passes run, blocks returned to the free stack,
  and the dead-fraction gauge collapsing back to ~0;
* the acceptance bar: recall@10 of the churned-then-compacted index within
  0.5% of an index **rebuilt from only the live vectors** (asserted
  in-script at the 0.3 sweep point, same discipline as scan_paths'
  int8-rerank bar).

Interpret-mode sizing: the search-timing rows run ``union_fused_scan``
(pure XLA — wall-clock is meaningful on CPU) and one ``union_fused``
(Pallas) row sized by grid-step count under ``MAX_GRID_STEPS`` —
interpret mode costs ~ms per grid step, so the pallas row's
``us_per_call`` measures step count, not kernel quality (see
benchmarks/scan_paths.py).  Writes ``BENCH_mutation.json`` at the repo
root when run as a script.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

try:
    from benchmarks.common import provenance, timed
except ImportError:  # run as `python benchmarks/mutation.py`
    import sys as _sys
    _sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import provenance, timed
from benchmarks.scan_paths import MAX_GRID_STEPS, grid_steps
from repro.core import build_ivf
from repro.core.block_pool import pool_stats
from repro.core.metrics import recall_at_k
from repro.core.search import exact_search, make_search_fn
from repro.data.synthetic import sift_like

N0 = 4000  # offline corpus
DIM = 32
N_CLUSTERS = 16
BLOCK = 32
NPROBE = 8
K = 10
Q = 32
ROUNDS = 4
DEAD_FRACS = (0.0, 0.1, 0.3)


def _timed_apply(fn, *args):
    """Wall-clock one state-mutating dispatch (donated state: not
    re-runnable, so no median-of-iters)."""
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out.cluster_len)
    return out, time.perf_counter() - t0


def churn(df: float, seed: int = 0):
    """Interleave ROUNDS of insert/update/delete toward dead fraction
    ``df``, measuring per-row mutation latency along the way."""
    corpus = sift_like(N0, dim=DIM, seed=seed)
    idx = build_ivf(
        corpus, n_clusters=N_CLUSTERS, block_size=BLOCK, max_chain=32,
        nprobe=NPROBE, k=K, capacity_vectors=3 * N0,
        rearrange_threshold=10**9, dead_frac_threshold=max(df / 2, 0.05),
        search_path="union_fused_scan",
    )
    oracle = {i: corpus[i] for i in range(N0)}
    rng = np.random.default_rng(seed + 1)
    lat = {"insert": [], "delete": [], "update": []}
    deleted: set[int] = set()
    per_round_del = int(df * N0 / ROUNDS)
    for r in range(ROUNDS):
        # insert fresh rows
        x = sift_like(N0 // 20, dim=DIM, seed=seed + 10 + r)
        t0 = time.perf_counter()
        ids = idx.add(x)
        jax.block_until_ready(idx.state.cluster_len)
        lat["insert"].append((time.perf_counter() - t0) / len(x))
        oracle.update({int(i): v for i, v in zip(ids, x)})
        # update resident rows in place
        live = np.asarray(sorted(oracle), np.int32)
        upd = rng.choice(live, N0 // 40, replace=False)
        newv = sift_like(len(upd), dim=DIM, seed=seed + 20 + r)
        t0 = time.perf_counter()
        idx.update(newv, upd)
        jax.block_until_ready(idx.state.cluster_len)
        lat["update"].append((time.perf_counter() - t0) / len(upd))
        for i, v in zip(upd, newv):
            oracle[int(i)] = v
        # tombstone toward the target dead fraction
        if per_round_del:
            live = np.asarray(sorted(oracle), np.int32)
            dele = rng.choice(live, per_round_del, replace=False)
            t0 = time.perf_counter()
            n = idx.delete(dele)
            jax.block_until_ready(idx.state.cluster_len)
            lat["delete"].append((time.perf_counter() - t0) / len(dele))
            assert n == len(dele)
            for i in dele:
                del oracle[int(i)]
                deleted.add(int(i))
    return idx, oracle, deleted, lat


def recall(idx, oracle, q, true_ids):
    d, i = idx.search(q, nprobe=NPROBE, k=K)
    return recall_at_k(i, true_ids, K), i


def run():
    rows = []
    for df in DEAD_FRACS:
        idx, oracle, deleted, lat = churn(df, seed=3)
        live_ids = np.asarray(sorted(oracle), np.int32)
        corpus = np.stack([oracle[int(i)] for i in live_ids])
        rng = np.random.default_rng(7)
        q = corpus[rng.integers(0, len(corpus), Q)] + 0.01
        _, ie = exact_search(jnp.asarray(corpus), jnp.asarray(q), K)
        true_ids = live_ids[np.asarray(ie)]

        stats_pre = pool_stats(idx.state, idx.pool_cfg)
        r_pre, i_pre = recall(idx, oracle, q, true_ids)
        assert not np.isin(i_pre, np.asarray(sorted(deleted) or [-2])).any()

        # reclamation: loop the maintenance step until quiescent
        t0 = time.perf_counter()
        passes = idx.maybe_rearrange(max_passes=32)
        compact_s = time.perf_counter() - t0
        stats_post = pool_stats(idx.state, idx.pool_cfg)
        r_post, i_post = recall(idx, oracle, q, true_ids)
        assert not np.isin(i_post, np.asarray(sorted(deleted) or [-2])).any()

        # the honest baseline: an index rebuilt from only the live vectors
        rebuilt = build_ivf(
            corpus, n_clusters=N_CLUSTERS, block_size=BLOCK, max_chain=32,
            nprobe=NPROBE, k=K, capacity_vectors=3 * N0,
            search_path="union_fused_scan",
        )
        d2, i2 = rebuilt.search(q, nprobe=NPROBE, k=K)
        remapped = np.where(i2 >= 0, live_ids[np.maximum(i2, 0)], -1)
        r_rebuilt = recall_at_k(remapped, true_ids, K)
        if df >= 0.29:  # the ISSUE's acceptance bar, at the 30% point
            assert abs(r_post - r_rebuilt) <= 0.005, (r_post, r_rebuilt)

        # search timing: pure-XLA scan path (meaningful on CPU) + the
        # pallas fused path sized by grid-step count
        budget = idx._chain_budget()
        scan_fn = make_search_fn(idx.pool_cfg, nprobe=NPROBE, k=K,
                                 path="union_fused_scan",
                                 chain_budget=budget)
        qj = jnp.asarray(q)
        search_us = timed(lambda: scan_fn(idx.state, qj), iters=5) * 1e6
        gsteps = grid_steps(
            "union_fused", q=Q, nprobe=NPROBE, budget=budget,
            pool_blocks=idx.pool_cfg.n_blocks,
            n_clusters=N_CLUSTERS,
        )
        fused_us = None
        if gsteps <= MAX_GRID_STEPS:
            fused_fn = make_search_fn(idx.pool_cfg, nprobe=NPROBE, k=K,
                                      path="union_fused",
                                      chain_budget=budget)
            fused_us = round(
                timed(lambda: fused_fn(idx.state, qj), iters=2) * 1e6, 1
            )

        rows.append({
            "dead_frac_target": df,
            "dead_frac_measured": stats_pre["dead_fraction"],
            "live_vectors": stats_pre["live_vectors"],
            "delete_us_per_row": round(
                float(np.median(lat["delete"]) * 1e6), 1
            ) if lat["delete"] else None,
            "update_us_per_row": round(
                float(np.median(lat["update"]) * 1e6), 1
            ),
            "insert_us_per_row": round(
                float(np.median(lat["insert"]) * 1e6), 1
            ),
            "compaction_passes": passes,
            "compaction_s": round(compact_s, 3),
            "blocks_in_use_pre": stats_pre["blocks_in_use"],
            "blocks_in_use_post": stats_post["blocks_in_use"],
            "blocks_reclaimed": stats_pre["blocks_in_use"]
                                - stats_post["blocks_in_use"],
            "dead_frac_post": stats_post["dead_fraction"],
            "recall_at_10_pre_compaction": round(r_pre, 4),
            "recall_at_10_post_compaction": round(r_post, 4),
            "recall_at_10_rebuilt": round(r_rebuilt, 4),
            "search_us_scan_path": round(search_us, 1),
            "search_us_fused_pallas": fused_us,
            "grid_steps_fused": gsteps,
            "batch": Q,
        })
    return rows


META = {
    "schema": {
        "dead_frac_measured": "tombstoned fraction of chain slots after "
                              "the churn rounds, before compaction",
        "delete_us_per_row": "median wall-clock per tombstoned row (one "
                             "jitted dispatch per batch, state donated)",
        "blocks_reclaimed": "pool blocks returned to the free stack by "
                            "the compaction passes",
        "recall_at_10_*": "vs exact fp32 search over the LIVE corpus; "
                          "'rebuilt' is an index built from only the live "
                          "vectors (acceptance: |post - rebuilt| <= 0.005 "
                          "at the 0.3 sweep point, asserted in-script)",
        "search_us_scan_path": "union_fused_scan (pure XLA — meaningful "
                               "wall-clock on CPU)",
        "search_us_fused_pallas": "union_fused in interpret mode; null "
                                  "when grid_steps_fused exceeds "
                                  "max_grid_steps (us measures step "
                                  "count off-TPU, see scan_paths)",
    },
    "interpret_mode_caveat": (
        "Off-TPU, Pallas kernels run interpret=True at ~1-10 ms per grid "
        "step; rows are sized by step count and the scan-path timings are "
        "the ones comparable across sweep points."
    ),
    "max_grid_steps": MAX_GRID_STEPS,
    "workload": {
        "corpus": N0, "dim": DIM, "n_clusters": N_CLUSTERS,
        "block_size": BLOCK, "rounds": ROUNDS,
        "per_round": {"insert": N0 // 20, "update": N0 // 40,
                      "delete": "df * corpus / rounds"},
    },
}


def main():
    rows = run()
    print("dead_frac,del_us/row,upd_us/row,blocks_reclaimed,"
          "recall_pre,recall_post,recall_rebuilt,search_us_scan")
    for r in rows:
        print(f"{r['dead_frac_target']},{r['delete_us_per_row']},"
              f"{r['update_us_per_row']},{r['blocks_reclaimed']},"
              f"{r['recall_at_10_pre_compaction']},"
              f"{r['recall_at_10_post_compaction']},"
              f"{r['recall_at_10_rebuilt']},{r['search_us_scan_path']}")
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_mutation.json"
    out.write_text(json.dumps({
        "provenance": provenance(
            "mutation",
            geometry={"dim": DIM, "corpus": N0, "n_clusters": N_CLUSTERS,
                      "block_size": BLOCK, "nprobe": NPROBE, "k": K},
            samples={"rows": len(rows), "rounds": ROUNDS,
                     "queries": Q},
        ),
        "meta": META, "rows": rows,
    }, indent=1))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
