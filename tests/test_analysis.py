"""Self-tests for the ``repro.analysis`` static layers (marker: analysis).

The acceptance contract:

* the clean repo passes — zero findings from the linter, the trace audit,
  and the VMEM docs check, and the CLI exits 0;
* every seeded-bad fixture under ``tests/fixtures/analysis/`` is flagged
  with its declared rule(s), and the CLI exits nonzero on it;
* the trace enumeration counts are pinned, so a registry change that adds
  a search path (or payload) without an audit budget fails here;
* the generated VMEM section of ``docs/search_paths.md`` is byte-identical
  to a fresh render from the estimator.
"""

import importlib.util
import os
import textwrap

import pytest

from repro.analysis import jaxpr_audit, run_all, vmem
from repro.analysis.__main__ import _run_fixture, main
from repro.analysis.lint import lint_file

pytestmark = pytest.mark.analysis

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")


def _fixture_paths():
    return sorted(
        os.path.join(FIXTURES, f)
        for f in os.listdir(FIXTURES)
        if f.endswith(".py")
    )


@pytest.fixture(scope="module")
def clean_run():
    # traces all 42 programs + the 5 kernel wrappers; do it once per module
    return run_all(REPO)


# ------------------------------------------------------------- clean repo --
def test_clean_repo_has_no_findings(clean_run):
    findings, _ = clean_run
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_exits_zero_on_clean_repo(capsys):
    assert main(["--root", REPO, "--fail-on-findings"]) == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out


def test_enumeration_counts_are_pinned(clean_run):
    _, stats = clean_run
    assert stats["search"] == jaxpr_audit.EXPECTED_SEARCH_TRACES == 26
    assert stats["mutation"] == jaxpr_audit.EXPECTED_MUTATION_TRACES == 12
    assert stats["rearrange"] == jaxpr_audit.EXPECTED_REARRANGE_TRACES == 4
    assert stats["invalid_combos"] == jaxpr_audit.EXPECTED_INVALID_COMBOS == 22
    assert stats["total"] == jaxpr_audit.EXPECTED_TOTAL_TRACES == 42


# --------------------------------------------------------------- fixtures --
def test_fixture_inventory_complete():
    names = {os.path.basename(p) for p in _fixture_paths()}
    assert names == {
        "oversized_intermediate.py",
        "int8_upcast.py",
        "baked_constant.py",
        "unlocked_field.py",
        "incomplete_cache_key.py",
        "nondet_in_jit.py",
        "inline_format.py",
        "inline_event_name.py",
    }


@pytest.mark.parametrize("path", _fixture_paths(), ids=os.path.basename)
def test_fixture_is_flagged(path, capsys):
    spec = importlib.util.spec_from_file_location("_fixture_probe", path)
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    expected = set(module.EXPECT_RULES)

    findings = _run_fixture(path)
    assert findings, f"{path}: seeded-bad fixture produced no findings"
    rules = {f.rule for f in findings}
    assert expected <= rules, f"{path}: flagged {rules}, expected {expected}"
    assert main(["--fixture", path]) == 1


# --------------------------------------------------------------- vmem docs --
def test_docs_vmem_section_byte_identical():
    doc = os.path.join(REPO, "docs", "search_paths.md")
    with open(doc, encoding="utf-8") as f:
        text = f.read()
    _, body, _ = vmem._split_docs(text, doc)
    assert body == "\n" + vmem.render_markdown() + "\n"
    assert vmem.check_docs(doc) == []


def test_kernel_budgets_fit_vmem():
    for budget in vmem.all_budgets():
        assert budget.peak_bytes <= vmem.VMEM_LIMIT_BYTES, budget.kernel
        assert budget.residents, budget.kernel


# ------------------------------------------------------------ linter units --
def _lint_source(tmp_path, source):
    p = tmp_path / "snippet.py"
    p.write_text(textwrap.dedent(source))
    return lint_file("snippet.py", repo_root=str(tmp_path))


def test_empty_suppression_is_itself_a_finding(tmp_path):
    findings = _lint_source(
        tmp_path,
        """
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0  # guarded-by: _lock

            def bump(self):
                # unlocked-ok:
                self._n = 1
        """,
    )
    assert {f.rule for f in findings} == {"invalid-suppression"}


def test_trailing_annotation_does_not_leak_to_next_line(tmp_path):
    findings = _lint_source(
        tmp_path,
        """
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._a = 0  # guarded-by: _lock
                self._b = 0

            def poke(self):
                self._b = 1
        """,
    )
    assert findings == []


def test_holds_helper_checked_at_call_site(tmp_path):
    findings = _lint_source(
        tmp_path,
        """
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0  # guarded-by: _lock

            def _bump(self):  # holds: _lock
                self._n += 1

            def good(self):
                with self._lock:
                    self._bump()

            def bad(self):
                self._bump()
        """,
    )
    assert [f.rule for f in findings] == ["guarded-by"]
    assert "_bump" in findings[0].message


def test_event_name_flags_inline_literal(tmp_path):
    findings = _lint_source(
        tmp_path,
        """
        def emit(rec, trace):
            rec.record_event("pool.rebalance", moves=1)
            trace.stamp("queue")
        """,
    )
    assert [f.rule for f in findings] == ["event-name", "event-name"]
    assert "pool.rebalance" in findings[0].message


def test_event_name_constant_and_suppression_pass(tmp_path):
    findings = _lint_source(
        tmp_path,
        """
        EV_POOL_REBALANCE = "pool.rebalance"

        def emit(rec):
            rec.record_event(EV_POOL_REBALANCE, moves=1)
            # deliberate: asserting the unknown-name ValueError
            rec.record_event("no.such.event")  # event-ok: negative test
        """,
    )
    assert findings == []


def test_event_name_empty_suppression_is_a_finding(tmp_path):
    findings = _lint_source(
        tmp_path,
        """
        def emit(rec):
            # event-ok:
            rec.record_event("pool.rebalance")
        """,
    )
    assert {f.rule for f in findings} == {"invalid-suppression"}
