"""Regression tests for the lock-discipline fixes the linter demanded.

Each test targets one concrete race the ``guarded-by`` / ``counter-race``
rules flagged in the serving layer (marker: analysis, same CI slice as the
analyzer):

* id allocation in ``ServingRuntime._mutation_args`` read-modify-writes
  ``index._next_id`` — without ``_state_lock`` two mutation lanes could
  hand out overlapping id ranges;
* ``stats()`` read ``_accepting`` without ``_submit_lock`` and the three
  ladder properties without its lock, so a snapshot could pair a level
  with a rung that never co-existed;
* serial-mode's pending-mutation buffer was touched by both the flush
  loop and the drain path; the drain must still resolve every queued
  future now that the buffer is ``_submit_lock``-guarded.
"""

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core import build_ivf
from repro.core.admission import DegradationLadder
from repro.core.runtime import RuntimeConfig, ServingRuntime, _Timed

pytestmark = pytest.mark.analysis

D = 16


def _data(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, D)).astype(np.float32)


def _make_runtime(**kw):
    index = build_ivf(_data(256, seed=1), n_clusters=4, block_size=16,
                      max_chain=64, add_batch=256, capacity_vectors=8000)
    return ServingRuntime(index, RuntimeConfig(nprobe=4, k=5, **kw))


def test_concurrent_id_allocation_never_overlaps():
    rt = _make_runtime()
    n_threads, rounds, rows = 8, 25, 4
    barrier = threading.Barrier(n_threads)
    chunks = [[] for _ in range(n_threads)]

    def worker(slot):
        barrier.wait()
        for _ in range(rounds):
            item = _Timed(Future(), 0.0, _data(rows, seed=slot), kind="insert")
            _, ids, _ = rt._mutation_args("insert", [item])
            chunks[slot].append(ids)

    try:
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        allocated = np.concatenate([i for c in chunks for i in c])
        assert allocated.size == n_threads * rounds * rows
        assert np.unique(allocated).size == allocated.size, \
            "overlapping id ranges handed to concurrent mutation batches"
    finally:
        rt.stop()


def test_stats_accepting_tracks_shutdown():
    rt = _make_runtime()
    try:
        s = rt.stats()
        assert s["accepting"] is True
        assert "degradation_rung" in s and "degradation_level" in s
    finally:
        rt.stop()
    assert rt.stats()["accepting"] is False


def test_ladder_snapshot_is_internally_consistent():
    ladder = DegradationLadder(("no_rerank", "half_nprobe"),
                               high_s=0.01, low_s=0.001, patience=1)
    stop = threading.Event()

    def churn():
        flip = True
        while not stop.is_set():
            ladder.observe(1.0 if flip else 0.0)
            flip = not flip

    t = threading.Thread(target=churn)
    t.start()
    try:
        for _ in range(2000):
            snap = ladder.snapshot()
            assert snap["rung"] == ladder.rungs[snap["level"]]
    finally:
        stop.set()
        t.join(timeout=10)


def test_serial_mode_drain_resolves_pending_mutations():
    # flush thresholds high enough that the insert stays buffered in
    # _serial_pending until stop(drain=True) sweeps it out
    rt = _make_runtime(mode="serial", flush_interval=30.0, flush_min=10_000)
    fut = rt.submit_insert(_data(4, seed=7))
    time.sleep(0.3)
    assert not fut.done()
    rt.stop(drain=True)
    ids = fut.result(timeout=10)
    assert len(ids) == 4
