"""Quantized flat payloads (bf16/int8) + exact re-rank epilogue.

Kernel parity, insert round-trip, end-to-end parity vs the fp32 oracle, and
the tie-restoration regression.  Everything here is marked ``quant`` so CI
can run it as its own job slice (interpret-mode grid steps cost ~ms each on
CPU — grids are kept tiny, but the slice still deserves its own wall-clock
budget).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import build_ivf
from repro.core.block_pool import quantize_int8
from repro.core.search import exact_search, make_search_fn, search_union_fused
from repro.kernels import ref
from repro.kernels.ivf_scan import (
    ivf_block_topk,
    ivf_block_topk_int8,
    ivf_block_topk_int8_scan,
    ivf_block_topk_scan,
    quantize_queries,
    rerank_topk,
)


def _block_cluster(state):
    """[P] owning cluster per block (host-side), NULL-safe."""
    cb = np.asarray(state.cluster_blocks)
    bc = np.zeros(state.pool_ids.shape[0], np.int32)
    for cl in range(cb.shape[0]):
        for b in cb[cl]:
            if b >= 0:
                bc[b] = cl
    return bc


def _reconstruct(state):
    """Host-side int8 reconstruction: centroid[owner] + code * scale."""
    bc = _block_cluster(state)
    cents = np.asarray(state.centroids)
    codes = np.asarray(state.pool_payload).astype(np.float32)
    scales = np.asarray(state.pool_scales)
    return cents[bc][:, None, :] + codes * scales[..., None]

pytestmark = pytest.mark.quant


def _topk_inputs(q, d, p, t, c, seed, dtype=np.float32, ncl=8, nprobe=6):
    """Union-scan shaped inputs: hole blocks, empty id slots, and the
    owner/probe-list routing the kernels derive membership from."""
    rng = np.random.default_rng(seed)
    queries = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    pool_f = rng.normal(size=(p, t, d)).astype(np.float32)
    ids = rng.integers(0, p, size=(c,)).astype(np.int32)
    ids[rng.random(c) < 0.25] = -1  # hole blocks
    pool_ids = rng.permutation(p * t).astype(np.int32).reshape(p, t)
    pool_ids[rng.random((p, t)) < 0.3] = -1  # empty slots
    live = (pool_ids != -1).astype(np.uint8)
    owners = rng.integers(0, ncl, size=(c,)).astype(np.int32)
    owners[ids == -1] = -1  # NULL slots own nothing
    probe = np.stack(
        [rng.permutation(ncl)[:nprobe] for _ in range(q)]
    ).astype(np.int32)
    return (queries, pool_f, jnp.asarray(ids), jnp.asarray(owners),
            jnp.asarray(pool_ids), jnp.asarray(live), jnp.asarray(probe))


def _int8_topk_inputs(q, npb, d, p, t, c, seed, ncl=None):
    """Residual-int8 kernel inputs: per-probe quantized query residuals,
    candidate owners, and distinct per-query probe lists (the probe slot —
    including the non-member case — is derived from owner membership,
    exactly as in-kernel)."""
    rng = np.random.default_rng(seed)
    ncl = ncl or 2 * npb  # ~half the (query, candidate) pairs are members
    qres = jnp.asarray(rng.normal(size=(q, npb, d)), jnp.float32)
    q_codes, q_meta = quantize_queries(qres)
    codes, scales = quantize_int8(
        jnp.asarray(rng.normal(size=(p, t, d)), jnp.float32)
    )
    ids = rng.integers(0, p, size=(c,)).astype(np.int32)
    ids[rng.random(c) < 0.25] = -1  # hole blocks
    pool_ids = rng.permutation(p * t).astype(np.int32).reshape(p, t)
    pool_ids[rng.random((p, t)) < 0.3] = -1  # empty slots
    live = (pool_ids != -1).astype(np.uint8)
    owners = rng.integers(0, ncl, size=(c,)).astype(np.int32)
    owners[ids == -1] = -1  # hole blocks are invalid for every query
    probe = np.stack(
        [rng.permutation(ncl)[:npb] for _ in range(q)]
    ).astype(np.int32)
    return (q_codes, q_meta, codes, scales, jnp.asarray(ids),
            jnp.asarray(owners), jnp.asarray(pool_ids), jnp.asarray(live),
            jnp.asarray(probe))


@pytest.mark.parametrize(
    "q,npb,d,p,t,c,kp",
    [
        (8, 4, 64, 16, 128, 4, 16),
        (13, 3, 32, 9, 16, 11, 8),  # Q not a multiple of 8 (pad path)
        (5, 2, 128, 4, 64, 3, 256),  # kprime > live candidates
        (1, 4, 64, 6, 8, 7, 4),
    ],
)
def test_ivf_block_topk_int8_matches_ref(q, npb, d, p, t, c, kp):
    """Kernel / lax.scan fallback / oracle agree: identical ids (the
    (distance, id) sort makes quantization ties deterministic), distances
    to float ulps."""
    (qc, qm, codes, scales, ids, owners, pool_ids, live,
     probe) = _int8_topk_inputs(q, npb, d, p, t, c, q + c)
    want_d, want_i = ref.ivf_block_topk_int8_ref(
        qc, qm, codes, scales, ids, owners, pool_ids, live, probe, kprime=kp
    )
    got_d, got_i = ivf_block_topk_int8(
        qc, qm, codes, scales, ids, owners, pool_ids, live, probe,
        kprime=kp, interpret=True,
    )
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(got_i, want_i)
    sc_d, sc_i = ivf_block_topk_int8_scan(
        qc, qm, codes, scales, ids, owners, pool_ids, live, probe,
        kprime=kp, chunk=4,
    )
    np.testing.assert_allclose(sc_d, want_d, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(sc_i, want_i)


def test_ivf_block_topk_int8_approximates_fp32():
    """With a zero centroid (residual == vector, NP=1) the int8 scores are
    the exact distances between the reconstructions, so they track the fp32
    scores to quantization error."""
    q, d, p, t, c, kp = 8, 64, 10, 16, 9, 16
    # every query probes cluster 0; candidates owned by 0 or by nobody,
    # so both payload families see the identical membership pattern
    queries, pool_f, ids, _, pool_ids, live, _ = _topk_inputs(
        q, d, p, t, c, 5
    )
    rng = np.random.default_rng(5)
    owners = np.where(rng.random(c) < 0.7, 0, -1).astype(np.int32)
    owners[np.asarray(ids) == -1] = -1
    owners = jnp.asarray(owners)
    probe = jnp.zeros((q, 1), jnp.int32)
    codes, scales = quantize_int8(jnp.asarray(pool_f))
    q_codes, q_meta = quantize_queries(queries[:, None, :])  # NP=1
    qd, _ = ivf_block_topk_int8(
        q_codes, q_meta, codes, scales, ids, owners, pool_ids, live, probe,
        kprime=kp, interpret=True,
    )
    fd, _ = ref.ivf_block_topk_ref(
        queries, jnp.asarray(pool_f), ids, owners, pool_ids, live, probe,
        kprime=kp,
    )
    qd, fd = np.asarray(qd), np.asarray(fd)
    live = np.isfinite(fd) & np.isfinite(qd)
    rel = np.abs(qd[live] - fd[live]) / np.maximum(fd[live], 1e-3)
    assert rel.max() < 0.05, rel.max()


def test_ivf_block_topk_int8_all_invalid_returns_inf():
    q, npb, d, p, t, c = 4, 2, 16, 3, 8, 5
    rng = np.random.default_rng(0)
    q_codes, q_meta = quantize_queries(
        jnp.asarray(rng.normal(size=(q, npb, d)), jnp.float32)
    )
    codes, scales = quantize_int8(
        jnp.asarray(rng.normal(size=(p, t, d)), jnp.float32)
    )
    ids = jnp.full((c,), -1, jnp.int32)
    owners = jnp.full((c,), -1, jnp.int32)
    pool_ids = jnp.zeros((p, t), jnp.int32)
    live = jnp.ones((p, t), jnp.uint8)
    probe = jnp.asarray(rng.integers(0, 4, size=(q, npb)), jnp.int32)
    d_out, i_out = ivf_block_topk_int8(
        q_codes, q_meta, codes, scales, ids, owners, pool_ids, live, probe,
        kprime=8, interpret=True,
    )
    assert np.isinf(np.asarray(d_out)).all()
    assert (np.asarray(i_out) == -1).all()


@pytest.mark.parametrize("q,d,p,t,c,kp", [(8, 64, 16, 128, 4, 16),
                                          (13, 32, 9, 16, 11, 8)])
def test_ivf_block_topk_bf16_matches_ref(q, d, p, t, c, kp):
    """bf16 payloads flow through the same fused kernel (bf16 operands,
    f32 accumulation on the MXU)."""
    queries, pool_f, ids, owners, pool_ids, live, probe = _topk_inputs(
        q, d, p, t, c, q * c
    )
    pool = jnp.asarray(pool_f, jnp.bfloat16)
    want_d, want_i = ref.ivf_block_topk_ref(
        queries, pool, ids, owners, pool_ids, live, probe, kprime=kp
    )
    got_d, got_i = ivf_block_topk(
        queries, pool, ids, owners, pool_ids, live, probe, kprime=kp,
        interpret=True,
    )
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(got_i, want_i)
    sc_d, sc_i = ivf_block_topk_scan(
        queries, pool, ids, owners, pool_ids, live, probe, kprime=kp,
        chunk=4,
    )
    np.testing.assert_allclose(sc_d, want_d, rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(sc_i, want_i)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_rerank_topk_matches_ref(dtype):
    """The fused re-rank kernel (dequant + exact distance + sort) against
    its jnp oracle, across payload dtypes and with invalid (-1) locations."""
    q, kp, d = 11, 16, 32
    rng = np.random.default_rng(3)
    queries = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    rows_f = jnp.asarray(rng.normal(size=(q, kp, d)), jnp.float32)
    loc = jnp.asarray(rng.integers(-1, 99, size=(q, kp)), jnp.int32)
    if dtype == "int8":
        rows, scales = quantize_int8(rows_f)
    else:
        rows = rows_f.astype(dtype)
        scales = jnp.ones((q, kp), jnp.float32)
    want_d, want_i = ref.rerank_topk_ref(queries, rows, scales, loc)
    got_d, got_i = rerank_topk(queries, rows, scales, loc, interpret=True)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(got_i, want_i)
    # ascending, invalid slots at the tail as (inf, -1)
    gd = np.asarray(got_d)
    assert (np.diff(gd, axis=1) >= 0).all()
    assert (np.asarray(got_i)[np.isinf(gd)] == -1).all()


# ---------------------------------------------------------------------------
# Insert round-trip + end-to-end across dtypes (pool with holes, NULL
# padding, multi-block chains, rearranged + recycled blocks).
# ---------------------------------------------------------------------------


def _clustered(n, d, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 3
    return (
        centers[rng.integers(0, 8, n)]
        + rng.normal(size=(n, d)).astype(np.float32)
    ).astype(np.float32)


def _grown_index(dtype):
    x = _clustered(900, 32, seed=3)
    idx = build_ivf(
        x, n_clusters=8, block_size=16, max_chain=32, add_batch=256,
        nprobe=4, k=10, rearrange_threshold=60, dtype=dtype,
        capacity_vectors=4000,
    )
    # online growth + rearrangement: multi-block chains, freed blocks
    # recycled -> scales must travel with their rows through compaction
    extra = _clustered(200, 32, seed=4)
    idx.add(extra)
    idx.maybe_rearrange(max_passes=6)
    tail = _clustered(100, 32, seed=5)
    idx.add(tail)
    corpus = np.concatenate([x, extra, tail])
    return corpus, idx


@pytest.fixture(scope="module")
def int8_index():
    return _grown_index("int8")


@pytest.fixture(scope="module")
def bf16_index():
    return _grown_index("bfloat16")


@pytest.fixture(scope="module")
def f32_index():
    return _grown_index("float32")


def test_int8_insert_roundtrip(int8_index):
    """insert -> reconstruct (centroid + dequantized residual) reproduces
    every resident row to within the per-vector quantization step (s/2 per
    coordinate) — including rows that moved through rearrangement."""
    corpus, idx = int8_index
    from repro.core.block_pool import check_invariants

    check_invariants(idx.state, idx.pool_cfg)
    pool_ids = np.asarray(idx.state.pool_ids)
    mask = pool_ids != -1
    assert mask.sum() == len(corpus)
    recon = _reconstruct(idx.state)[mask]
    scales = np.asarray(idx.state.pool_scales)[mask]
    orig = corpus[pool_ids[mask]]
    err = np.abs(recon - orig)
    assert (err <= scales[:, None] * 0.5 + 1e-5).all(), err.max()


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("rerank", [False, True])
def test_union_fused_impls_agree(dtype, rerank, request):
    """Pallas kernel / lax.scan fallback / jnp oracle return identical ids
    across dtypes, with and without the re-rank epilogue."""
    fixture = {"float32": "f32_index", "bfloat16": "bf16_index",
               "int8": "int8_index"}[dtype]
    corpus, idx = request.getfixturevalue(fixture)
    rng = np.random.default_rng(6)
    q = jnp.asarray(corpus[rng.integers(0, len(corpus), 6)] + 0.001)
    budget = idx._chain_budget()
    d0 = i0 = None
    for path in ("union_fused", "union_fused_scan"):
        fn = make_search_fn(
            idx.pool_cfg, nprobe=4, k=10, path=path, chain_budget=budget,
            rerank=rerank,
        )
        d, i = fn(idx.state, q)
        if d0 is None:
            d0, i0 = np.asarray(d), np.asarray(i)
        else:
            np.testing.assert_allclose(
                np.asarray(d), d0, rtol=1e-4, atol=1e-3
            )
            np.testing.assert_array_equal(np.asarray(i), i0)
    # the jnp oracle branch of the dispatcher agrees too
    d, i = search_union_fused(
        idx.pool_cfg, idx.state, q, nprobe=4, k=10, scan_impl="jnp",
        chain_budget=budget, rerank=rerank,
    )
    np.testing.assert_allclose(np.asarray(d), d0, rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(i), i0)


def test_fp32_rerank_is_identity(f32_index):
    """On a float32 payload the re-rank epilogue recomputes the same exact
    distances, so results are unchanged (locations map back to the same
    ids)."""
    corpus, idx = f32_index
    rng = np.random.default_rng(7)
    q = jnp.asarray(corpus[rng.integers(0, len(corpus), 6)] + 0.001)
    budget = idx._chain_budget()
    f0 = make_search_fn(idx.pool_cfg, nprobe=4, k=10,
                        path="union_fused_scan", chain_budget=budget)
    f1 = make_search_fn(idx.pool_cfg, nprobe=4, k=10,
                        path="union_fused_scan", chain_budget=budget,
                        rerank=True)
    d0, i0 = f0(idx.state, q)
    d1, i1 = f1(idx.state, q)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d0), rtol=1e-5,
                               atol=1e-4)


def test_int8_rerank_distances_match_dequant_oracle(int8_index):
    """Re-ranked distances are exact fp32 distances to the reconstructed
    (centroid + dequantized residual) rows, and recall tracks the fp32
    index."""
    corpus, idx = int8_index
    rng = np.random.default_rng(8)
    sel = rng.integers(0, len(corpus), 8)
    q = jnp.asarray(corpus[sel] + 0.001)
    fn = make_search_fn(
        idx.pool_cfg, nprobe=8, k=10, path="union_fused_scan",
        chain_budget=idx._chain_budget(), rerank=True,
    )
    d, i = fn(idx.state, q)
    d, i = np.asarray(d), np.asarray(i)
    # oracle: id -> exact distance to the reconstructed resident row
    pool_ids = np.asarray(idx.state.pool_ids)
    recon = _reconstruct(idx.state)
    id2row = {int(v): recon[p, t]
              for (p, t) in zip(*np.nonzero(pool_ids != -1))
              for v in [pool_ids[p, t]]}
    for qi in range(len(sel)):
        for dist, cid in zip(d[qi], i[qi]):
            if cid < 0:
                continue
            want = float(np.sum((np.asarray(q)[qi] - id2row[int(cid)]) ** 2))
            np.testing.assert_allclose(dist, want, rtol=1e-4, atol=1e-3)
    # self-recall: the quantized-scan + exact-rerank path finds the query
    hit = (i == sel[:, None]).any(axis=1).mean()
    assert hit > 0.8, hit


def test_int8_k_exceeds_live_masks_tail(int8_index):
    """k > vectors in the probed list: (inf, -1) tail with and without
    re-rank (hole/NULL masking survives the epilogue)."""
    corpus, idx = int8_index
    rng = np.random.default_rng(9)
    q = jnp.asarray(corpus[rng.integers(0, len(corpus), 4)])
    for rerank in (False, True):
        fn = make_search_fn(
            idx.pool_cfg, nprobe=1, k=300, path="union_fused_scan",
            chain_budget=idx._chain_budget(), rerank=rerank,
        )
        d, i = fn(idx.state, q)
        d, i = np.asarray(d), np.asarray(i)
        assert np.isinf(d).any(), "expected padded tail past the probed list"
        assert (i[np.isinf(d)] == -1).all()
        assert (i[~np.isinf(d)] >= 0).all()


def test_rerank_restores_int8_tie_ordering():
    """Two vectors whose int8-quantized first-pass distances tie exactly
    come back in id order from the quantized pass but in true fp32 order
    after the re-rank.

    Construction (centroid at the origin, so residual == vector): v1/v2
    share the quantization scale and differ only in the sign of one
    coordinate; the query's component along that coordinate is below half
    its own quantization step, so the *quantized* query is exactly
    equidistant from both codes — while the exact fp32 query prefers v1."""
    from repro.core.block_pool import PoolConfig, init_state
    from repro.core.insert import make_insert_fn

    dim = 16
    s = np.float32(1.27) / 127
    v1 = np.zeros(dim, np.float32)
    v2 = np.zeros(dim, np.float32)
    v1[0], v1[1] = 1.27, 1.0  # codes [127, 100, 0, ...], scale s
    v2[0], v2[1] = 1.27, -1.0  # codes [127, -100, 0, ...], same scale
    query = np.zeros((1, dim), np.float32)
    query[0, 0], query[0, 1] = 1.0, 0.003  # 0.003 < (1.0/127)/2: rounds to 0
    filler = np.abs(_clustered(60, dim, seed=11)) + 100.0
    cents = np.zeros((2, dim), np.float32)
    cents[1] = 110.0
    cfg = PoolConfig(n_clusters=2, dim=dim, block_size=16, n_blocks=16,
                     max_chain=4, dtype="int8")
    state = init_state(cfg, jnp.asarray(cents))
    insert = make_insert_fn(cfg)
    corpus = np.concatenate([v2[None], v1[None], filler])  # v2 gets id 0
    state = insert(state, jnp.asarray(corpus),
                   jnp.arange(len(corpus), dtype=jnp.int32))
    plain = make_search_fn(cfg, nprobe=2, k=2, path="union_fused_scan")
    rer = make_search_fn(cfg, nprobe=2, k=2, path="union_fused_scan",
                         rerank=True)
    qd, qi = plain(state, jnp.asarray(query))
    assert np.asarray(qd)[0, 0] == np.asarray(qd)[0, 1], "expected exact tie"
    # tie breaks by pool location == insertion order here, not by distance
    assert list(np.asarray(qi)[0]) == [0, 1]
    rd, ri = rer(state, jnp.asarray(query))
    assert list(np.asarray(ri)[0]) == [1, 0], "rerank must restore fp32 order"
    assert np.asarray(rd)[0, 0] < np.asarray(rd)[0, 1]
    # and the restored order is the true fp32 order
    _, ei = exact_search(jnp.asarray(corpus), jnp.asarray(query), 2)
    np.testing.assert_array_equal(np.asarray(ri)[0], np.asarray(ei)[0])


def test_int8_requires_fused_path():
    """Non-fused paths would score raw int8 codes as numbers — rejected
    loudly; ditto rerank on a path without the epilogue."""
    import dataclasses

    corpus = _clustered(200, 16, seed=12)
    idx = build_ivf(corpus, n_clusters=2, block_size=16, max_chain=16,
                    add_batch=64, dtype="int8")
    with pytest.raises(NotImplementedError, match="int8"):
        make_search_fn(idx.pool_cfg, nprobe=2, k=5, path="block_table")
    f32_cfg = dataclasses.replace(idx.pool_cfg, dtype="float32")
    with pytest.raises(NotImplementedError, match="rerank"):
        make_search_fn(f32_cfg, nprobe=2, k=5, path="union", rerank=True)


def test_int8_serving_runtime_rerank():
    """The serving runtime routes an int8 index through the fused path with
    the re-rank epilogue."""
    import time

    from repro.core.scheduler import RuntimeConfig, ServingRuntime

    x = _clustered(600, 16, seed=13)
    idx = build_ivf(x, n_clusters=4, block_size=16, max_chain=32,
                    add_batch=256, dtype="int8", capacity_vectors=3000)
    rt = ServingRuntime(
        idx,
        RuntimeConfig(mode="parallel", nprobe=4, k=5,
                      search_path="union_fused_scan", rerank=True,
                      flush_min=4, flush_interval=0.05),
    )
    try:
        d, ids = rt.submit_search(x[:4]).result(timeout=120)
        assert (ids[:, 0] == np.arange(4)).all()
        new = _clustered(12, 16, seed=14) + 60.0
        new_ids = rt.submit_insert(new).result(timeout=30)
        time.sleep(0.1)
        d, ids = rt.submit_search(new[:2]).result(timeout=60)
        assert (ids[:, 0] == new_ids[:2]).all()
    finally:
        rt.stop()
