"""Dry-run machinery tests (subprocess: needs 512 host devices, which must
not leak into the other tests' jax runtime)."""

import json
import subprocess
import sys
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_one_cell_compiles_both_meshes(tmp_path):
    out = tmp_path / "cells.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-1.7b", "--shape", "decode_32k", "--out", str(out)],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    records = [json.loads(l) for l in open(out)]
    assert {rec["mesh"] for rec in records} == {"16x16", "2x16x16"}
    for rec in records:
        assert rec["flops"] > 0
        assert rec["argument_size_in_bytes"] > 0
        # roofline terms derivable
        from repro.launch.roofline import roofline_terms

        t = roofline_terms(rec)
        assert t["bound_s"] > 0
        assert t["dominant"] in ("compute", "memory", "collective")


def test_roofline_math():
    from repro.launch.roofline import roofline_terms

    rec = {
        "n_devices": 256,
        "flops": 197e12,  # exactly one second of compute per chip
        "bytes_accessed": 819e9 / 2,  # half a second of HBM
        "collectives": {"bytes": {"all-reduce": 50e9 / 4}},  # quarter second
        "meta": {"n_params": 1e9, "tokens": 1000, "backward": True},
    }
    t = roofline_terms(rec)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 0.5) < 1e-9
    assert abs(t["collective_s"] - 0.25) < 1e-9
    assert t["dominant"] == "compute"
    assert 0 < t["useful_fraction"] < 1


def test_collective_parser():
    from repro.launch.roofline import collective_bytes_from_hlo

    hlo = """
      %ag = f32[16,1024]{1,0} all-gather(%x), replica_groups={}
      %ar = bf16[8,8]{1,0} all-reduce-start(%y), to_apply=%add
      %rs = f32[4,256]{1,0} reduce-scatter(%z), dimensions={0}
      %a2a = f32[2,2]{1,0} all-to-all(%w)
      %cp = f32[128]{0} collective-permute(%v)
      %dot = f32[16,16]{1,0} dot(%a, %b)
    """
    out = collective_bytes_from_hlo(hlo)
    assert out["counts"] == {
        "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
        "all-to-all": 1, "collective-permute": 1,
    }
    assert out["bytes"]["all-gather"] == 16 * 1024 * 4
    assert out["bytes"]["all-reduce"] == 8 * 8 * 2
