"""Equiformer-v2 / Wigner properties: orthogonality, alignment, equivariance."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from scipy.spatial.transform import Rotation

from repro.models.gnn.equiformer_v2 import (
    EquiformerConfig,
    equiformer_forward,
    equiformer_loss,
    init_equiformer,
)
from repro.models.gnn.wigner import edge_wigner, real_sph_harm_l1


def test_wigner_blocks_orthogonal():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(32, 3)), jnp.float32)
    for l, d in enumerate(edge_wigner(4, v)):
        eye = jnp.einsum("eab,ecb->eac", d, d)
        np.testing.assert_allclose(
            np.asarray(eye), np.broadcast_to(np.eye(2 * l + 1), eye.shape),
            atol=5e-6,
        )


def test_wigner_aligns_edge_to_z():
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
    d = edge_wigner(2, v)
    rot = jnp.einsum("eab,eb->ea", d[1], real_sph_harm_l1(v))
    target = real_sph_harm_l1(jnp.asarray([[0.0, 0.0, 1.0]]))
    np.testing.assert_allclose(
        np.asarray(rot), np.broadcast_to(np.asarray(target), rot.shape),
        atol=5e-6,
    )


@pytest.fixture(scope="module")
def tiny_graph():
    rng = np.random.default_rng(2)
    n, e = 24, 80
    return dict(
        feat=jnp.asarray(rng.normal(size=(n, 5)), jnp.float32),
        pos=jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
        src=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, e), jnp.int32),
    )


@pytest.fixture(scope="module")
def tiny_model():
    cfg = EquiformerConfig(
        name="tiny", n_layers=2, channels=16, l_max=2, m_max=1, n_heads=4,
        d_feat_in=5, edge_chunk=32, readout="node", n_out=3,
    )
    params = init_equiformer(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_rotation_invariance_of_outputs(tiny_graph, tiny_model):
    """Invariant readout must be unchanged under a global rotation."""
    cfg, params = tiny_model
    g = tiny_graph
    out0 = equiformer_forward(params, cfg, g["feat"], g["pos"], g["src"], g["dst"])
    r = jnp.asarray(
        Rotation.from_euler("zyx", [0.7, -1.1, 0.4]).as_matrix(), jnp.float32
    )
    out1 = equiformer_forward(
        params, cfg, g["feat"], g["pos"] @ r.T, g["src"], g["dst"]
    )
    np.testing.assert_allclose(
        np.asarray(out0), np.asarray(out1), rtol=2e-3, atol=2e-3
    )


def test_translation_invariance(tiny_graph, tiny_model):
    cfg, params = tiny_model
    g = tiny_graph
    out0 = equiformer_forward(params, cfg, g["feat"], g["pos"], g["src"], g["dst"])
    out1 = equiformer_forward(
        params, cfg, g["feat"], g["pos"] + 13.7, g["src"], g["dst"]
    )
    np.testing.assert_allclose(
        np.asarray(out0), np.asarray(out1), rtol=2e-3, atol=2e-3
    )


def test_edge_chunking_exactness(tiny_graph, tiny_model):
    """Chunked edge scan must give bit-comparable results to one chunk."""
    cfg, params = tiny_model
    g = tiny_graph
    import dataclasses

    cfg_small = dataclasses.replace(cfg, edge_chunk=7)  # ragged chunks + pad
    out0 = equiformer_forward(params, cfg, g["feat"], g["pos"], g["src"], g["dst"])
    out1 = equiformer_forward(
        params, cfg_small, g["feat"], g["pos"], g["src"], g["dst"]
    )
    np.testing.assert_allclose(
        np.asarray(out0), np.asarray(out1), rtol=1e-4, atol=1e-4
    )


def test_loss_and_grad(tiny_graph, tiny_model):
    cfg, params = tiny_model
    g = tiny_graph
    batch = dict(
        node_feat=g["feat"], pos=g["pos"], edge_src=g["src"],
        edge_dst=g["dst"], label=jnp.zeros((24,), jnp.int32),
    )
    loss, _ = equiformer_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: equiformer_loss(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(x)).all() for x in flat)
