"""Fused candidate-routing prologue: streaming coarse probe parity,
``block_owner`` maintenance, and old-vs-new prologue equivalence.

Three contracts guard the prologue refactor:

* ``coarse_topk`` (kernel / ``lax.scan`` fallback / jnp oracle) is
  bit-exact with ``coarse_probe`` — ties included (``top_k`` prefers the
  lower index; the streaming kernels reproduce it with a (distance, id)
  sort key) and for N_clusters that is not a multiple of the centroid
  tile.
* ``IVFState.block_owner`` stays consistent with the block table through
  insert -> rearrange -> insert round trips (allocation, recycling via the
  free stack, and compaction all move ownership).
* The fused search paths return results identical to the old prologue
  (``jnp.unique`` union + dense ``[Q, CB]`` membership/probe-slot
  operands) across every payload dtype x rerank, on randomized grown
  workloads — verified by re-running the same dispatch with the old
  prologue swapped back in.

Runs in tier-1 (no marker): grids are kept tiny per the interpret-mode
grid-step budget.
"""

import dataclasses
import types

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import build_ivf
from repro.core.block_pool import check_invariants
from repro.core.search import coarse_probe, search_union_fused
from repro.kernels.ivf_scan import coarse_topk, coarse_topk_scan
from repro.kernels.ref import coarse_topk_ref


# ---------------------------------------------------------------------------
# coarse_topk parity (kernel <-> scan <-> oracle <-> coarse_probe)
# ---------------------------------------------------------------------------


def _probe(centroids, queries, nprobe):
    """``coarse_probe`` itself, jitted the way every search path runs it
    (eager XLA can round the fused epilogue differently than jit)."""
    fn = jax.jit(lambda c, q: coarse_probe(
        types.SimpleNamespace(centroids=c), q, nprobe
    ))
    return fn(centroids, queries)


@pytest.mark.parametrize(
    "q,d,n,nprobe",
    [
        (13, 32, 100, 7),  # N not a multiple of the 128 tile (pad + mask)
        (64, 128, 384, 32),  # acceptance geometry: 3 centroid tiles
        (1, 16, 8, 8),  # nprobe == N (full probe)
        (130, 64, 300, 16),  # Q > q_tile -> two query tiles
        (5, 16, 30, 9),  # everything tiny and misaligned
    ],
)
def test_coarse_topk_bitexact_with_coarse_probe(q, d, n, nprobe):
    rng = np.random.default_rng(q * 100 + n)
    queries = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    cents = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    want_i, want_d = _probe(cents, queries, nprobe)
    for name, (got_i, got_d) in {
        "kernel": coarse_topk(queries, cents, nprobe=nprobe, interpret=True),
        "scan": coarse_topk_scan(queries, cents, nprobe=nprobe),
        "ref": jax.jit(
            lambda c, qs: coarse_topk_ref(qs, c, nprobe=nprobe)
        )(cents, queries),
    }.items():
        np.testing.assert_array_equal(
            np.asarray(got_i), np.asarray(want_i), err_msg=name
        )
        np.testing.assert_array_equal(
            np.asarray(got_d), np.asarray(want_d), err_msg=name
        )


def test_coarse_topk_breaks_ties_by_centroid_id():
    """Duplicated centroids produce exact distance ties; every impl must
    return them in ``top_k`` order (lower centroid id first)."""
    rng = np.random.default_rng(3)
    base = rng.normal(size=(10, 16)).astype(np.float32)
    cents = jnp.asarray(np.repeat(base, 3, axis=0))  # ids 3k,3k+1,3k+2 tie
    queries = jnp.asarray(rng.normal(size=(6, 16)), jnp.float32)
    want_i, want_d = _probe(cents, queries, 9)
    want_i = np.asarray(want_i)
    # the construction really does produce in-row ties
    assert (np.diff(np.asarray(want_d), axis=1) == 0).any()
    for name, (got_i, got_d) in {
        "kernel": coarse_topk(queries, cents, nprobe=9, interpret=True),
        "scan": coarse_topk_scan(queries, cents, nprobe=9),
        "ref": jax.jit(
            lambda c, qs: coarse_topk_ref(qs, c, nprobe=9)
        )(cents, queries),
    }.items():
        np.testing.assert_array_equal(np.asarray(got_i), want_i, err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(got_d), np.asarray(want_d), err_msg=name
        )


def test_coarse_topk_small_c_tile_covers_multi_tile_merge():
    """A tiny centroid tile forces many accumulator merges (the streaming
    path proper); still bit-exact."""
    rng = np.random.default_rng(5)
    queries = jnp.asarray(rng.normal(size=(9, 24)), jnp.float32)
    cents = jnp.asarray(rng.normal(size=(70, 24)), jnp.float32)
    want_i, want_d = _probe(cents, queries, 11)
    got_i, got_d = coarse_topk(
        queries, cents, nprobe=11, c_tile=16, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))


# ---------------------------------------------------------------------------
# block_owner maintenance
# ---------------------------------------------------------------------------


def _clustered(n, d, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 3
    return (
        centers[rng.integers(0, 8, n)]
        + rng.normal(size=(n, d)).astype(np.float32)
    ).astype(np.float32)


def _owner_oracle(state):
    """[P] owner map derived from the block table (host side)."""
    cb = np.asarray(state.cluster_blocks)
    owner = np.full(state.pool_ids.shape[0], -1, np.int32)
    for cl in range(cb.shape[0]):
        for b in cb[cl]:
            if b >= 0:
                owner[b] = cl
    return owner


def test_block_owner_tracks_insert_rearrange_insert():
    """Ownership follows every allocation path: fresh bump blocks, chains
    compacted by rearrangement (old blocks freed -> owner NULL), and
    recycled free-stack blocks claimed by later inserts."""
    x = _clustered(700, 16, seed=1)
    idx = build_ivf(
        x, n_clusters=8, block_size=16, max_chain=32, add_batch=128,
        nprobe=4, k=5, rearrange_threshold=50, capacity_vectors=3000,
    )
    np.testing.assert_array_equal(
        np.asarray(idx.state.block_owner), _owner_oracle(idx.state)
    )
    idx.add(_clustered(200, 16, seed=2))
    passes = idx.maybe_rearrange(max_passes=8)
    assert passes > 0, "workload must actually trigger compaction"
    np.testing.assert_array_equal(
        np.asarray(idx.state.block_owner), _owner_oracle(idx.state)
    )
    # freed chain blocks sit on the free stack owning nothing
    s = jax.device_get(idx.state)
    freed = s.free_stack[: int(s.free_top)]
    assert len(freed) > 0
    assert (np.asarray(s.block_owner)[freed] == -1).all()
    # the next insert recycles them and re-claims ownership
    idx.add(_clustered(300, 16, seed=3))
    np.testing.assert_array_equal(
        np.asarray(idx.state.block_owner), _owner_oracle(idx.state)
    )
    check_invariants(idx.state, idx.pool_cfg)  # includes the owner checks


# ---------------------------------------------------------------------------
# e2e: new prologue == old prologue, all fused dtypes x rerank
# ---------------------------------------------------------------------------


def _old_union_candidates(cfg, state, queries, nprobe, chain_budget,
                          scan_impl="jnp"):
    """The PR-3 prologue re-expressed in the new UnionCandidates format:
    ``jnp.unique`` union, cluster-major candidate order, stable-argsort
    compaction, owners taken from the union clusters (not block_owner).
    Feeding this through the unchanged fused dispatch reproduces the old
    pipeline end to end."""
    from repro.core.search import UnionCandidates

    q = queries.shape[0]
    mc = min(chain_budget or cfg.max_chain, cfg.max_chain)
    probe_idx, _ = coarse_probe(state, queries, nprobe)
    union = jnp.unique(
        probe_idx.reshape(-1), size=q * nprobe, fill_value=-1
    )
    blocks = state.cluster_blocks[jnp.maximum(union, 0), :mc]
    blocks = jnp.where((union != -1)[:, None], blocks, -1)
    flat = blocks.reshape(-1)
    owners = jnp.where(flat != -1, jnp.repeat(union, mc), -1)
    cap = min(flat.shape[0], state.pool_payload.shape[0])
    if cap < flat.shape[0]:
        perm = jnp.argsort(flat == -1, stable=True)[:cap]
        flat, owners = flat[perm], owners[perm]
    return UnionCandidates(flat, owners, probe_idx)


def _grown_index(dtype, payload="flat", pq_m=0):
    x = _clustered(700, 32, seed=4)
    kw = dict(payload=payload, pq_m=pq_m) if payload == "pq" else dict(
        dtype=dtype
    )
    idx = build_ivf(
        x, n_clusters=8, block_size=16, max_chain=32, add_batch=256,
        nprobe=4, k=10, rearrange_threshold=60, capacity_vectors=3000, **kw,
    )
    extra = _clustered(150, 32, seed=5)
    idx.add(extra)
    idx.maybe_rearrange(max_passes=6)
    tail = _clustered(80, 32, seed=6)
    idx.add(tail)
    return np.concatenate([x, extra, tail]), idx


@pytest.mark.parametrize(
    "dtype,rerank",
    [
        ("float32", False),
        ("float32", True),
        ("bfloat16", False),
        ("bfloat16", True),
        ("int8", False),
        ("int8", True),
        ("pq", False),
        ("pq", True),
    ],
)
def test_fused_matches_old_prologue(dtype, rerank, monkeypatch):
    """The complete fused dispatch (scan impl; the kernel impl shares the
    routing derivation, tested per-kernel) returns identical (distance,
    id) results with the old and new prologues on a randomized grown
    workload — the refactor changes HBM traffic, not results."""
    if dtype == "pq":
        corpus, idx = _grown_index(None, payload="pq", pq_m=8)
    else:
        corpus, idx = _grown_index(dtype)
    rng = np.random.default_rng(7)
    q = jnp.asarray(corpus[rng.integers(0, len(corpus), 6)] + 0.001)
    budget = idx._chain_budget()

    def run():
        return search_union_fused(
            idx.pool_cfg, idx.state, q, nprobe=4, k=10, scan_impl="scan",
            chain_budget=budget, pq=idx.pq, rerank=rerank,
        )

    d_new, i_new = run()
    import repro.core.search as search_mod

    monkeypatch.setattr(
        search_mod, "_union_candidates", _old_union_candidates
    )
    d_old, i_old = run()
    np.testing.assert_array_equal(np.asarray(i_new), np.asarray(i_old))
    np.testing.assert_allclose(
        np.asarray(d_new), np.asarray(d_old), rtol=0, atol=0
    )


def test_union_path_skips_dead_slots_same_results():
    """search_union (and its pallas twin's candidate list) now scores only
    the deduped live blocks; results match the per-query block_table path
    on ties-free data."""
    from repro.core.search import make_search_fn

    corpus, idx = _grown_index("float32")
    rng = np.random.default_rng(8)
    q = jnp.asarray(corpus[rng.integers(0, len(corpus), 5)] + 0.001)
    budget = idx._chain_budget()
    d0, i0 = make_search_fn(
        idx.pool_cfg, nprobe=4, k=10, path="block_table", chain_budget=budget
    )(idx.state, q)
    d1, i1 = make_search_fn(
        idx.pool_cfg, nprobe=4, k=10, path="union", chain_budget=budget
    )(idx.state, q)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_allclose(
        np.asarray(d1), np.asarray(d0), rtol=1e-5, atol=1e-4
    )
    # and the compacted candidate list really is smaller than the padded
    # union the old prologue shipped
    from repro.core.search import _union_candidates

    uc = _union_candidates(idx.pool_cfg, idx.state, q, 4, budget)
    old = _old_union_candidates(idx.pool_cfg, idx.state, q, 4, budget)
    n_live_new = int((np.asarray(uc.flat_blocks) >= 0).sum())
    n_live_old = int((np.asarray(old.flat_blocks) >= 0).sum())
    assert uc.flat_blocks.shape[0] <= old.flat_blocks.shape[0]
    assert n_live_new <= n_live_old  # dedup can only shrink
    # identical live block sets
    assert set(np.asarray(uc.flat_blocks)[np.asarray(uc.flat_blocks) >= 0]
               .tolist()) == \
        set(np.asarray(old.flat_blocks)[np.asarray(old.flat_blocks) >= 0]
            .tolist())


def test_prologue_owner_matches_union_cluster():
    """block_owner-derived owners agree with the union-cluster-derived
    owners of the old prologue for every live candidate."""
    from repro.core.search import _union_candidates

    corpus, idx = _grown_index("float32")
    rng = np.random.default_rng(9)
    q = jnp.asarray(corpus[rng.integers(0, len(corpus), 4)])
    uc = _union_candidates(idx.pool_cfg, idx.state, q, 4, idx._chain_budget())
    flat = np.asarray(uc.flat_blocks)
    owners = np.asarray(uc.owners)
    oracle = _owner_oracle(idx.state)
    live = flat >= 0
    np.testing.assert_array_equal(owners[live], oracle[flat[live]])
    assert (owners[~live] == -1).all()
