"""Hypothesis property tests for the system's core invariants.

Properties of the paper's Alg. 2/3 that must hold for ANY insert sequence:
* conservation — every accepted vector is retrievable in exactly one chain;
* determinism — same batch sequence => bit-identical state;
* search-over-insert consistency — full-probe search always finds a just-
  inserted vector as its own nearest neighbour;
* rearrangement is a no-op on results.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core.block_pool import PoolConfig, check_invariants, init_state, snapshot_ids
from repro.core.insert import assign_clusters, make_insert_fn
from repro.core.rearrange import make_rearrange_fn
from repro.core.search import make_search_fn

DIM = 6
N_CLUSTERS = 3
CFG = PoolConfig(
    n_clusters=N_CLUSTERS, dim=DIM, block_size=4, n_blocks=256, max_chain=32
)
CENTS = np.random.default_rng(0).normal(size=(N_CLUSTERS, DIM)).astype(np.float32)

batches = st.lists(
    st.integers(min_value=1, max_value=17), min_size=1, max_size=6
)


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(sizes=batches, seed=st.integers(0, 2**16))
def test_insert_conservation_and_determinism(sizes, seed):
    rng = np.random.default_rng(seed)
    ins = make_insert_fn(CFG)

    def run():
        state = init_state(CFG, jnp.asarray(CENTS))
        r = np.random.default_rng(seed)
        nid = 0
        for b in sizes:
            x = r.normal(size=(b, DIM)).astype(np.float32)
            ids = np.arange(nid, nid + b, dtype=np.int32)
            nid += b
            state = ins(state, jnp.asarray(x), jnp.asarray(ids))
        return state, nid

    state, nid = run()
    check_invariants(state, CFG)
    # conservation: every id present exactly once
    all_ids = sorted(
        i for ids in snapshot_ids(state, CFG).values() for i in ids
    )
    assert all_ids == list(range(nid))
    # determinism: replay gives identical pool bytes
    state2, _ = run()
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**16), n0=st.integers(8, 40), n1=st.integers(1, 12))
def test_inserted_vector_is_own_nearest_neighbor(seed, n0, n1):
    rng = np.random.default_rng(seed)
    ins = make_insert_fn(CFG)
    state = init_state(CFG, jnp.asarray(CENTS))
    x0 = rng.normal(size=(n0, DIM)).astype(np.float32)
    state = ins(state, jnp.asarray(x0), jnp.arange(n0, dtype=jnp.int32))
    x1 = rng.normal(size=(n1, DIM)).astype(np.float32) * 2.0
    state = ins(
        state, jnp.asarray(x1), jnp.arange(n0, n0 + n1, dtype=jnp.int32)
    )
    search = make_search_fn(CFG, nprobe=N_CLUSTERS, k=1)
    d, i = search(state, jnp.asarray(x1))
    assert (np.asarray(i)[:, 0] == np.arange(n0, n0 + n1)).all()


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**16))
def test_rearrange_never_changes_results(seed):
    rng = np.random.default_rng(seed)
    ins = make_insert_fn(CFG)
    rearr = make_rearrange_fn(CFG, threshold=0)  # always triggers
    state = init_state(CFG, jnp.asarray(CENTS))
    for step in range(3):
        b = int(rng.integers(4, 20))
        x = rng.normal(size=(b, DIM)).astype(np.float32)
        base = int(state.num_vectors)
        state = ins(
            state, jnp.asarray(x),
            jnp.arange(base, base + b, dtype=jnp.int32),
        )
    search = make_search_fn(CFG, nprobe=N_CLUSTERS, k=5)
    q = jnp.asarray(rng.normal(size=(4, DIM)).astype(np.float32))
    d0, i0 = search(state, q)
    for _ in range(N_CLUSTERS):
        state, _ = rearr(state)
    check_invariants(state, CFG)
    d1, i1 = search(state, q)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-5, atol=1e-5)
    assert (np.asarray(i0) == np.asarray(i1)).all()
