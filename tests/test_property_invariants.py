"""Hypothesis property tests for the system's core invariants.

Properties of the paper's Alg. 2/3 that must hold for ANY insert sequence:
* conservation — every accepted vector is retrievable in exactly one chain;
* determinism — same batch sequence => bit-identical state;
* search-over-insert consistency — full-probe search always finds a just-
  inserted vector as its own nearest neighbour;
* rearrangement is a no-op on results.

Plus the mutation subsystem's property (marked ``mutation``, own CI slice):
random insert/delete/update/rearrange/search interleavings vs a host-side
dict oracle — surviving ids match exactly, deleted ids never surface,
across the fused dtypes x rerank.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core.block_pool import PoolConfig, check_invariants, init_state, snapshot_ids
from repro.core.insert import assign_clusters, make_insert_fn
from repro.core.mutate import make_delete_fn, make_update_fn
from repro.core.rearrange import make_rearrange_fn
from repro.core.search import make_search_fn

DIM = 6
N_CLUSTERS = 3
CFG = PoolConfig(
    n_clusters=N_CLUSTERS, dim=DIM, block_size=4, n_blocks=256, max_chain=32
)
CENTS = np.random.default_rng(0).normal(size=(N_CLUSTERS, DIM)).astype(np.float32)

batches = st.lists(
    st.integers(min_value=1, max_value=17), min_size=1, max_size=6
)


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(sizes=batches, seed=st.integers(0, 2**16))
def test_insert_conservation_and_determinism(sizes, seed):
    rng = np.random.default_rng(seed)
    ins = make_insert_fn(CFG)

    def run():
        state = init_state(CFG, jnp.asarray(CENTS))
        r = np.random.default_rng(seed)
        nid = 0
        for b in sizes:
            x = r.normal(size=(b, DIM)).astype(np.float32)
            ids = np.arange(nid, nid + b, dtype=np.int32)
            nid += b
            state = ins(state, jnp.asarray(x), jnp.asarray(ids))
        return state, nid

    state, nid = run()
    check_invariants(state, CFG)
    # conservation: every id present exactly once
    all_ids = sorted(
        i for ids in snapshot_ids(state, CFG).values() for i in ids
    )
    assert all_ids == list(range(nid))
    # determinism: replay gives identical pool bytes
    state2, _ = run()
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**16), n0=st.integers(8, 40), n1=st.integers(1, 12))
def test_inserted_vector_is_own_nearest_neighbor(seed, n0, n1):
    rng = np.random.default_rng(seed)
    ins = make_insert_fn(CFG)
    state = init_state(CFG, jnp.asarray(CENTS))
    x0 = rng.normal(size=(n0, DIM)).astype(np.float32)
    state = ins(state, jnp.asarray(x0), jnp.arange(n0, dtype=jnp.int32))
    x1 = rng.normal(size=(n1, DIM)).astype(np.float32) * 2.0
    state = ins(
        state, jnp.asarray(x1), jnp.arange(n0, n0 + n1, dtype=jnp.int32)
    )
    search = make_search_fn(CFG, nprobe=N_CLUSTERS, k=1)
    d, i = search(state, jnp.asarray(x1))
    assert (np.asarray(i)[:, 0] == np.arange(n0, n0 + n1)).all()


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**16))
def test_rearrange_never_changes_results(seed):
    rng = np.random.default_rng(seed)
    ins = make_insert_fn(CFG)
    rearr = make_rearrange_fn(CFG, threshold=0)  # always triggers
    state = init_state(CFG, jnp.asarray(CENTS))
    for step in range(3):
        b = int(rng.integers(4, 20))
        x = rng.normal(size=(b, DIM)).astype(np.float32)
        base = int(state.num_vectors)
        state = ins(
            state, jnp.asarray(x),
            jnp.arange(base, base + b, dtype=jnp.int32),
        )
    search = make_search_fn(CFG, nprobe=N_CLUSTERS, k=5)
    q = jnp.asarray(rng.normal(size=(4, DIM)).astype(np.float32))
    d0, i0 = search(state, q)
    for _ in range(N_CLUSTERS):
        state, _ = rearr(state)
    check_invariants(state, CFG)
    d1, i1 = search(state, q)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-5, atol=1e-5)
    assert (np.asarray(i0) == np.asarray(i1)).all()


# ---------------------------------------------------------------------------
# Mutation subsystem property: random interleavings vs a dict oracle
# (own CI slice — fused scans over every op interleaving are not tier-1
# cheap).
# ---------------------------------------------------------------------------

# op stream: each entry is (kind, size); parameters are derived from the
# per-example rng so hypothesis shrinks over structure, not raw data
ops = st.lists(
    st.tuples(
        st.sampled_from(["insert", "delete", "update", "rearrange", "search"]),
        st.integers(min_value=1, max_value=12),
    ),
    min_size=3,
    max_size=10,
)


@pytest.mark.mutation
@pytest.mark.parametrize(
    "dtype,rerank",
    [("float32", False), ("bfloat16", False), ("int8", False),
     ("int8", True)],
)
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(script=ops, seed=st.integers(0, 2**16))
def test_mutation_interleavings_match_dict_oracle(dtype, rerank, script,
                                                  seed):
    """Any interleaving of insert/delete/update/rearrange/search leaves the
    pool holding exactly the oracle's id -> vector dict: invariants hold
    after every op, surviving ids match exactly, and a full-probe fused
    search never returns a deleted id."""
    cfg = PoolConfig(
        n_clusters=N_CLUSTERS, dim=DIM, block_size=4, n_blocks=256,
        max_chain=32, dtype=dtype,
    )
    rng = np.random.default_rng(seed)
    ins = make_insert_fn(cfg)
    dele = make_delete_fn(cfg)
    upd = make_update_fn(cfg)
    rearr = make_rearrange_fn(cfg, threshold=10**9, dead_frac=0.25)
    search = make_search_fn(
        cfg, nprobe=N_CLUSTERS, k=8, path="union_fused_scan", rerank=rerank,
    )
    state = init_state(cfg, jnp.asarray(CENTS))
    oracle: dict[int, np.ndarray] = {}
    ever_deleted: set[int] = set()
    nid = 0
    for kind, size in script:
        if kind == "insert":
            x = rng.normal(size=(size, DIM)).astype(np.float32)
            ids = np.arange(nid, nid + size, dtype=np.int32)
            nid += size
            state = ins(state, jnp.asarray(x), jnp.asarray(ids))
            oracle.update({int(i): v for i, v in zip(ids, x)})
        elif kind == "delete":
            # mix of live ids and guaranteed misses
            pool = sorted(oracle) + [nid + 10_000 + j for j in range(2)]
            take = rng.choice(len(pool), min(size, len(pool)),
                              replace=False)
            ids = np.asarray([pool[j] for j in take], np.int32)
            state = dele(state, jnp.asarray(ids))
            for i in ids:
                if int(i) in oracle:
                    del oracle[int(i)]
                    ever_deleted.add(int(i))
        elif kind == "update":
            if not oracle:
                continue
            live = sorted(oracle)
            take = rng.choice(len(live), min(size, len(live)),
                              replace=False)
            ids = np.asarray([live[j] for j in take], np.int32)
            x = rng.normal(size=(len(ids), DIM)).astype(np.float32) * 2.0
            state = upd(state, jnp.asarray(x), jnp.asarray(ids))
            oracle.update({int(i): v for i, v in zip(ids, x)})
        elif kind == "rearrange":
            for _ in range(size):
                state, triggered = rearr(state)
                if not bool(triggered):
                    break
        else:  # search
            q = rng.normal(size=(2, DIM)).astype(np.float32)
            _, got = search(state, jnp.asarray(q))
            got = np.asarray(got)
            found = set(int(i) for i in got.ravel() if i >= 0)
            assert found <= set(oracle), found - set(oracle)
        check_invariants(state, cfg)
    # conservation: the pool holds exactly the oracle's ids
    live_ids = sorted(
        i for ids_ in snapshot_ids(state, cfg).values() for i in ids_
    )
    assert live_ids == sorted(oracle)
    # deleted ids never surface from a final full-probe search, and every
    # surviving id is retrievable as its own nearest neighbour
    if oracle:
        keys = sorted(oracle)
        qs = np.stack([oracle[i] for i in keys]).astype(np.float32)
        d, got = search(state, jnp.asarray(qs))
        got = np.asarray(got)
        if ever_deleted:
            assert not np.isin(got, np.asarray(sorted(ever_deleted))).any()
        assert (got[:, 0] == np.asarray(keys)).all()
