"""Core block-pool IVF behaviour: insertion, search, rearrangement.

These are the system-level invariants of the paper's Alg. 2/3:
state consistency after arbitrary insert sequences, search parity between
the faithful chain-walk and the block-table path, and rearrangement
preserving results while compacting chains.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    IVFIndex,
    IVFIndexConfig,
    build_ivf,
    check_invariants,
    exact_search,
    snapshot_ids,
)
from repro.core.block_pool import PoolConfig, init_state
from repro.core.insert import assign_clusters, make_insert_fn
from repro.core.metrics import recall_at_k
from repro.core.rearrange import make_rearrange_fn
from repro.core.search import make_search_fn


def _data(n, d, seed=0):
    rng = np.random.default_rng(seed)
    # clustered data so IVF lists are meaningful
    centers = rng.normal(size=(16, d)).astype(np.float32) * 3
    x = centers[rng.integers(0, 16, n)] + rng.normal(size=(n, d)).astype(
        np.float32
    )
    return x.astype(np.float32)


@pytest.fixture(scope="module")
def small_index():
    x = _data(2000, 32)
    idx = build_ivf(
        x, n_clusters=8, block_size=16, max_chain=160, add_batch=256,
        nprobe=8, k=10,
    )
    return idx, x


def test_capacity_rejection_counted():
    d, tm = 8, 4
    cfg_kw = dict(n_clusters=2, dim=d, block_size=tm, n_blocks=64, max_chain=2)
    cfg = PoolConfig(**cfg_kw)  # capacity = 2 clusters x 8 vectors
    rng = np.random.default_rng(42)
    cents = rng.normal(size=(2, d)).astype(np.float32)
    state = init_state(cfg, jnp.asarray(cents))
    ins = make_insert_fn(cfg)
    x = rng.normal(size=(40, d)).astype(np.float32)
    state = ins(state, jnp.asarray(x), jnp.arange(40, dtype=jnp.int32))
    check_invariants(state, cfg)
    assert int(state.num_vectors) + int(state.num_dropped) == 40
    assert int(state.num_dropped) >= 40 - 16
    assert int(state.cluster_len.max()) <= 8


def test_pool_exhaustion_masked_and_counted():
    """Regression (silent wrong results): when the pool ran out of blocks the
    bump pointer kept allocating past n_blocks, out-of-range ids landed in
    cluster_blocks, and later clamped gathers returned the wrong vectors.
    Overflowed allocations must come back NULL, the affected rows must be
    rejected through num_dropped, and every accepted vector must stay
    retrievable."""
    from repro.core.block_pool import capacity_ok
    from repro.core.search import make_search_fn

    d, tm = 8, 4
    cfg = PoolConfig(n_clusters=3, dim=d, block_size=tm, n_blocks=6,
                     max_chain=16)
    rng = np.random.default_rng(23)
    cents = rng.normal(size=(3, d)).astype(np.float32) * 4
    state = init_state(cfg, jnp.asarray(cents))
    ins = make_insert_fn(cfg)
    total, vecs = 0, []
    for bsz in (5, 9, 7, 11):  # runs past the 6-block / 24-vector pool
        x = (cents[rng.integers(0, 3, bsz)]
             + rng.normal(size=(bsz, d)).astype(np.float32))
        vecs.append(x)
        state = ins(state, jnp.asarray(x),
                    jnp.arange(total, total + bsz, dtype=jnp.int32))
        total += bsz
        check_invariants(state, cfg)
    assert int(np.asarray(state.cluster_blocks).max()) < cfg.n_blocks
    assert int(state.cur_p) <= cfg.n_blocks
    assert int(state.num_vectors) + int(state.num_dropped) == total
    assert int(state.num_dropped) > 0
    assert not bool(capacity_ok(state, cfg))
    # recall holds for everything that was accepted: full-probe search finds
    # each resident id from its own vector
    all_x = np.concatenate(vecs)
    resident = sorted(i for ids in snapshot_ids(state, cfg).values()
                      for i in ids)
    fn = make_search_fn(cfg, nprobe=cfg.n_clusters, k=1, path="block_table")
    _, got = fn(state, jnp.asarray(all_x[resident]))
    assert (np.asarray(got)[:, 0] == np.asarray(resident)).all()


def test_insert_invariants_random_batches():
    d, n_clusters, tm = 8, 4, 4
    cfg = PoolConfig(
        n_clusters=n_clusters, dim=d, block_size=tm, n_blocks=128, max_chain=24
    )
    rng = np.random.default_rng(1)
    cents = rng.normal(size=(n_clusters, d)).astype(np.float32)
    state = init_state(cfg, jnp.asarray(cents))
    ins = make_insert_fn(cfg)
    nid = 0
    oracle: dict[int, list[int]] = {k: [] for k in range(n_clusters)}
    for bsz in [1, 3, 7, 16, 2, 31, 5]:
        x = rng.normal(size=(bsz, d)).astype(np.float32)
        ids = np.arange(nid, nid + bsz, dtype=np.int32)
        nid += bsz
        assign = np.asarray(assign_clusters(jnp.asarray(cents), jnp.asarray(x)))
        for i in range(bsz):
            oracle[int(assign[i])].append(int(ids[i]))
        state = ins(state, jnp.asarray(x), jnp.asarray(ids))
        check_invariants(state, cfg)
    assert snapshot_ids(state, cfg) == oracle
    assert int(state.num_vectors) == nid


def test_insert_with_padding_mask():
    d, n_clusters, tm = 8, 4, 4
    cfg = PoolConfig(
        n_clusters=n_clusters, dim=d, block_size=tm, n_blocks=64, max_chain=16
    )
    rng = np.random.default_rng(2)
    cents = rng.normal(size=(n_clusters, d)).astype(np.float32)
    state = init_state(cfg, jnp.asarray(cents))
    ins = make_insert_fn(cfg)
    x = rng.normal(size=(8, d)).astype(np.float32)
    valid = jnp.asarray([True, True, False, True, False, False, True, True])
    state = ins(state, jnp.asarray(x), jnp.arange(8, dtype=jnp.int32), valid)
    check_invariants(state, cfg)
    assert int(state.num_vectors) == 5
    got = sorted(i for ids in snapshot_ids(state, cfg).values() for i in ids)
    assert got == [0, 1, 3, 6, 7]


def test_search_paths_agree(small_index):
    idx, x = small_index
    rng = np.random.default_rng(3)
    q = x[rng.integers(0, len(x), 10)] + 0.01
    d_bt, i_bt = idx.search(q, nprobe=8, k=10)
    walk = make_search_fn(idx.pool_cfg, nprobe=8, k=10, path="chain_walk")
    d_cw, i_cw = walk(idx.state, jnp.asarray(q))
    np.testing.assert_allclose(d_bt, np.asarray(d_cw), rtol=1e-5, atol=1e-5)
    assert (i_bt == np.asarray(i_cw)).all()


def test_full_probe_equals_exact(small_index):
    idx, x = small_index
    rng = np.random.default_rng(4)
    q = x[rng.integers(0, len(x), 16)] + 0.01 * rng.normal(size=(16, 32)).astype(np.float32)
    d, i = idx.search(q, nprobe=8, k=10)  # nprobe = n_clusters: exhaustive
    de, ie = exact_search(jnp.asarray(x), jnp.asarray(q), 10)
    assert recall_at_k(i, np.asarray(ie), 10) == 1.0
    # (atol covers ||q||²+||v||²-2q·v cancellation on near-zero self-distances)
    np.testing.assert_allclose(d, np.asarray(de), rtol=1e-4, atol=1e-3)


def test_online_insert_visible_immediately(small_index):
    idx, x = small_index
    # insert brand-new far-away vectors; they must be retrievable at once
    rng = np.random.default_rng(5)
    new = rng.normal(size=(7, 32)).astype(np.float32) + 50.0
    ids = idx.add(new)
    d, i = idx.search(new, nprobe=8, k=1)
    assert set(i[:, 0].tolist()) == set(ids.tolist())


def test_rearrange_preserves_results():
    x = _data(1500, 16, seed=7)
    idx = build_ivf(
        x, n_clusters=4, block_size=8, max_chain=64, add_batch=100,
        rearrange_threshold=50,
    )
    q = x[:20]
    d0, i0 = idx.search(q, nprobe=4, k=5)
    passes = idx.maybe_rearrange(max_passes=8)
    assert passes >= 1
    check_invariants(idx.state, idx.pool_cfg)
    d1, i1 = idx.search(q, nprobe=4, k=5)
    np.testing.assert_allclose(d0, d1, rtol=1e-5, atol=1e-5)
    assert (i0 == i1).all()
    # compacted chains are physically contiguous runs
    s = jax.device_get(idx.state)
    for k in range(4):
        nblk = int(s.cluster_nblocks[k])
        tbl = s.cluster_blocks[k][:nblk]
        if nblk > 1 and int(s.new_since_rearrange[k]) == 0:
            assert (np.diff(tbl) == 1).all(), tbl


def test_free_list_reuse():
    x = _data(800, 16, seed=8)
    idx = build_ivf(
        x, n_clusters=4, block_size=8, max_chain=48, add_batch=80,
        rearrange_threshold=10,
    )
    before = int(idx.state.cur_p)
    idx.maybe_rearrange(max_passes=8)
    assert int(idx.state.free_top) > 0  # old blocks recycled
    free_top = int(idx.state.free_top)
    idx.add(_data(200, 16, seed=9))
    # new inserts consumed freed blocks before bumping cur_p
    assert int(idx.state.free_top) < free_top
    check_invariants(idx.state, idx.pool_cfg)


def test_ivfpq_recall_reasonable():
    x = _data(3000, 32, seed=10)
    idx = build_ivf(
        x, n_clusters=8, payload="pq", pq_m=8, block_size=32,
        max_chain=16, add_batch=512,
    )
    q = x[:32]
    d, i = idx.search(q, nprobe=8, k=10)
    de, ie = exact_search(jnp.asarray(x), jnp.asarray(q), 10)
    r = recall_at_k(i, np.asarray(ie), 10)
    assert r > 0.5, f"pq recall {r}"  # quantized, lossy — but self-query
    # and the query's own id should almost always be found
    self_hit = (i == np.arange(32)[:, None]).any(axis=1).mean()
    assert self_hit > 0.8


def test_insert_latency_independent_of_list_length():
    """The paper's core claim: block insert cost does not grow with list size.

    We verify the *algorithmic* property on CPU: inserting into an index
    whose lists are 50x longer must not cost materially more than into a
    short one (realloc baselines copy the whole list; we only scatter)."""
    import time

    d = 16
    short = build_ivf(_data(500, d, seed=11), n_clusters=4, block_size=32,
                      max_chain=512, capacity_vectors=80_000)
    long = build_ivf(_data(40_000, d, seed=12), n_clusters=4, block_size=32,
                     max_chain=512, capacity_vectors=80_000)
    batch = _data(128, d, seed=13)

    def cost(idx):
        idx.add(batch[:1])  # warm compile
        t0 = time.perf_counter()
        for _ in range(10):
            idx.add(batch)
            jax.block_until_ready(idx.state.pool_payload)
        return time.perf_counter() - t0

    c_short, c_long = cost(short), cost(long)
    assert c_long < 5 * c_short + 0.05, (c_short, c_long)


@pytest.mark.parametrize("path", ["union", "union_pallas", "union_fused_scan"])
def test_union_search_agrees_with_block_table(small_index, path):
    idx, x = small_index
    rng = np.random.default_rng(21)
    q = x[rng.integers(0, len(x), 10)] + 0.01
    d_bt, i_bt = idx.search(q, nprobe=5, k=10)
    fn = make_search_fn(idx.pool_cfg, nprobe=5, k=10, path=path)
    d_u, i_u = fn(idx.state, jnp.asarray(q))
    np.testing.assert_allclose(d_bt, np.asarray(d_u), rtol=1e-4, atol=1e-3)
    assert (i_bt == np.asarray(i_u)).all()


def test_pq_kernel_path_matches_jnp_path():
    x = _data(2000, 32, seed=30)
    kw = dict(n_clusters=8, payload="pq", pq_m=8, block_size=32,
              max_chain=16, add_batch=512)
    a = build_ivf(x, **kw)
    b = build_ivf(x, use_kernel=True, **kw)
    q = x[:16]
    da, ia = a.search(q, nprobe=4, k=10)
    db, ib = b.search(q, nprobe=4, k=10)
    np.testing.assert_allclose(da, db, rtol=1e-4, atol=1e-3)
    assert (ia == ib).all()
