"""Seeded-bad lint: inline struct format string in a persistence path.

The record layout below exists only at this call site — nothing names
it, so a format change is invisible to the version-bump discipline that
keeps old WAL/snapshot files readable.  The linter must flag
``persist-format``; the fix is a module-level ``REC_FMT = "<IIQ"``.
"""

import struct

FIXTURE_KIND = "lint"
EXPECT_RULES = ("persist-format",)


def write_record(f, length: int, crc: int, lsn: int) -> None:
    f.write(struct.pack("<IIQ", length, crc, lsn))  # anonymous layout
