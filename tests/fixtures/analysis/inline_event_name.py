"""Seeded-bad lint: inline flight-recorder event name at an emission site.

The event name below exists only at this call site — a typo here would
emit into the void (or raise at runtime) instead of failing at import
time against ``repro.obs.events.EVENT_CATALOG``, and grep for the
``EV_*`` constant would never find it.  The linter must flag
``event-name``; the fix is importing ``EV_CONTROLLER_RUNG`` and passing
the constant.
"""

FIXTURE_KIND = "lint"
EXPECT_RULES = ("event-name",)


class _Recorder:
    def record_event(self, name: str, **fields) -> None:
        pass


def emit_rung(recorder: _Recorder, rung: int) -> None:
    recorder.record_event("controller.window_rung", rung=rung)  # anonymous
