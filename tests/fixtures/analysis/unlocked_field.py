"""Seeded-bad lint: a ``# guarded-by:`` field written outside its lock.

``stop()`` flips the shared flag without the declared lock — exactly the
submit/stop race the serving runtime's ``_submit_lock`` exists to close.
The linter must flag ``guarded-by`` on the unlocked write (and accept the
locked one).
"""

import threading

FIXTURE_KIND = "lint"
EXPECT_RULES = ("guarded-by",)


class MiniRuntime:
    def __init__(self):
        self._lock = threading.Lock()
        self._accepting = True  # guarded-by: _lock

    def stop(self):
        self._accepting = False  # unlocked write: must be flagged

    def stop_locked(self):
        with self._lock:
            self._accepting = False  # fine
