"""Seeded-bad trace: a ``[C, Q, T]``-class score materialization.

The one-HLO gather-everything idiom the fused paths were built to kill:
scoring every probed block against every query materializes an 8 MB
tensor where the streaming kernel's writeback budget is ~128 KB.  The
audit must flag ``intermediate-bytes``.
"""

import jax
import jax.numpy as jnp

FIXTURE_KIND = "trace"
EXPECT_RULES = ("intermediate-bytes",)


def build():
    S = jax.ShapeDtypeStruct

    def scores(queries, blocks):
        # [C, Q, T] in one HLO: C=256 blocks x Q=64 queries x T=128 slots
        s = jnp.einsum("qd,ctd->cqt", queries, blocks)
        return s.max(axis=(0, 2))

    return {
        "name": "fixture/oversized_intermediate",
        "fn": scores,
        "args": (
            S((64, 64), jnp.float32),
            S((256, 128, 64), jnp.float32),
        ),
        # the K'-row budget a streaming path would get (2x Q*K' floats)
        "budget_bytes": 2 * 64 * 128 * 8,
    }
