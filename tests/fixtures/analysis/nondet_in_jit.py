"""Seeded-bad lint: wall-clock read inside a jitted function.

``time.time()`` runs once at trace time and bakes that instant into the
compiled program as a constant — it measures nothing and silently
poisons any logic built on it.  The linter must flag ``nondeterminism``.
"""

import time

import jax

FIXTURE_KIND = "lint"
EXPECT_RULES = ("nondeterminism",)


@jax.jit
def stamped_step(x):
    t = time.time()  # trace-time constant, not a timestamp
    return x * t
