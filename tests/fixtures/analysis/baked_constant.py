"""Seeded-bad trace: a concrete array closed over as a jit constant.

The PR 2 stale-centroids class: the closure captures a host array, so the
compiled program scores against the snapshot taken at trace time forever,
no matter how the live state moves.  The audit must flag ``baked-const``.
"""

import jax
import jax.numpy as jnp
import numpy as np

FIXTURE_KIND = "trace"
EXPECT_RULES = ("baked-const",)

# 16 KiB of f32 — over the 4 KiB constant allowance
CENTROIDS = np.zeros((64, 64), np.float32)


def build():
    S = jax.ShapeDtypeStruct

    def assign(queries):
        cents = jnp.asarray(CENTROIDS)  # baked in, not a traced argument
        d = ((queries[:, None, :] - cents[None]) ** 2).sum(-1)
        return jnp.argmin(d, axis=1)

    return {
        "name": "fixture/baked_constant",
        "fn": assign,
        "args": (S((8, 64), jnp.float32),),
        "budget_bytes": 1 << 20,
    }
