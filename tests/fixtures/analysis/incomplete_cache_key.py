"""Seeded-bad lint: a jit-cache key missing a parameter.

``nprobe`` varies the traced closure but is absent from the key tuple, so
the first-compiled step is silently reused for every later ``nprobe`` —
the PR 2 frozen-chain-budget bug class.  The linter must flag
``jit-cache-key``.
"""

FIXTURE_KIND = "lint"
EXPECT_RULES = ("jit-cache-key",)


class Steps:
    def __init__(self):
        self._steps = {}

    def step_for(self, budget, nprobe, rerank):
        key = (budget, rerank)  # nprobe missing
        if key not in self._steps:
            self._steps[key] = ("compiled", budget, nprobe, rerank)
        return self._steps[key]
