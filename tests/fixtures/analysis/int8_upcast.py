"""Seeded-bad trace: pool-scale int8 dequantization before the dot.

Converting the whole int8 pool to float32 and contracting in f32 throws
away the integer-MXU path (and doubles HBM traffic).  The audit must flag
``int8-upcast`` twice: once for the oversized convert, once because no
integer ``dot_general`` remains in the trace.
"""

import jax
import jax.numpy as jnp

FIXTURE_KIND = "trace"
EXPECT_RULES = ("int8-upcast",)


def build():
    S = jax.ShapeDtypeStruct

    def score(queries, pool_codes):
        # dequantize 1M int8 codes up front (the legit ceiling is the
        # [Q, K', D] rerank gather, ~0.5M elements at the audit geometry)
        deq = pool_codes.astype(jnp.float32)
        return jax.lax.top_k(queries @ deq.T, 10)

    return {
        "name": "fixture/int8_upcast",
        "fn": score,
        "args": (
            S((64, 64), jnp.float32),
            S((16384, 64), jnp.int8),
        ),
        # generous: only the int8 rules should fire
        "budget_bytes": 64 << 20,
        "int8_contract": True,
    }
