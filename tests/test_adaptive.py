"""Adaptive control loop: estimator correctness, controller hysteresis,
resource-pool stability, window regression, compaction pacing, bounded
compiles.  Runs as the ``adaptive`` CI slice."""

import threading
import time

import numpy as np
import pytest

from repro.core import build_ivf
from repro.core.admission import AdmissionGate, DynamicResourcePool
from repro.core.metrics import ArrivalEstimator, percentile_summary
from repro.core.runtime import (
    AdaptiveController,
    AdaptiveSlots,
    RuntimeConfig,
    ServingRuntime,
)

pytestmark = pytest.mark.adaptive


def _data(n, d, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 3
    return (
        centers[rng.integers(0, 8, n)]
        + rng.normal(size=(n, d)).astype(np.float32)
    ).astype(np.float32)


def _runtime(cfg_kwargs, n=1500, d=16, seed=0):
    x = _data(n, d, seed)
    idx = build_ivf(
        x, n_clusters=4, block_size=16, max_chain=64, add_batch=256,
        capacity_vectors=8000,
    )
    kw = dict(nprobe=4, k=5)
    kw.update(cfg_kwargs)
    return x, ServingRuntime(idx, RuntimeConfig(**kw))


# ------------------------------------------------------- estimator ------
class TestArrivalEstimator:
    def test_rejects_bad_tau(self):
        with pytest.raises(ValueError):
            ArrivalEstimator(tau_s=0.0)

    def test_steady_rate_converges(self):
        # 100 arrivals/s for 4 tau with explicit timestamps: the EWMA
        # event-count estimate must converge on the true rate
        est = ArrivalEstimator(tau_s=0.5)
        for i in range(200):
            est.observe_arrival(1, now=i * 0.01)
        rate = est.rate(now=2.0)
        assert 90.0 <= rate <= 110.0, rate

    def test_batched_arrivals_count_rows(self):
        est = ArrivalEstimator(tau_s=0.5)
        for i in range(100):
            est.observe_arrival(32, now=i * 0.02)  # 1600 rows/s
        rate = est.rate(now=2.0)
        assert 1400.0 <= rate <= 1800.0, rate

    def test_rate_decays_in_silence(self):
        est = ArrivalEstimator(tau_s=0.5)
        for i in range(100):
            est.observe_arrival(1, now=i * 0.01)
        busy = est.rate(now=1.0)
        idle = est.rate(now=1.0 + 5 * 0.5)  # 5 tau of silence
        assert idle < 0.01 * busy, (busy, idle)

    def test_empty_estimator_reads_zero(self):
        est = ArrivalEstimator()
        assert est.rate(now=10.0) == 0.0
        assert est.queue_age() == 0.0
        assert est.service(default=1.5) == 1.5

    def test_snapshot_consistent(self):
        est = ArrivalEstimator(tau_s=0.5)
        est.observe_arrival(4, now=0.0)
        est.observe_queue_age(0.1)
        est.observe_service(0.02)
        s = est.snapshot(now=0.0)
        assert s["events"] == 4
        assert s["rate"] == pytest.approx(4 / 0.5)
        assert s["queue_age_s"] > 0.0
        assert s["service_s"] == pytest.approx(0.02)


def test_percentile_summary_matches_numpy():
    samples = [i / 1000.0 for i in range(1, 101)]  # 1..100 ms
    p = percentile_summary(samples)
    assert p["n"] == 100
    assert p["p50_ms"] == pytest.approx(np.percentile(
        np.asarray(samples) * 1e3, 50
    ))
    assert p["p99_ms"] <= p["max_ms"] == pytest.approx(100.0)
    empty = percentile_summary([])
    assert empty == {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
                     "mean_ms": 0.0, "max_ms": 0.0, "n": 0}


# ------------------------------------------------------------ pool ------
class TestDynamicResourcePool:
    def test_square_wave_never_oscillates(self):
        # utilization flips sides every call: the direction streak resets
        # on every sign flip, so patience is never reached -> zero moves
        pool = DynamicResourcePool(total=16, patience=3)
        for i in range(60):
            hot_search = i % 2 == 0
            pool.rebalance(
                0.9 if hot_search else 0.1, 0.1 if hot_search else 0.9
            )
        assert pool.moves == 0

    def test_sustained_imbalance_moves_with_patience(self):
        pool = DynamicResourcePool(total=16, patience=3, initial_search=8)
        before = pool.search_slots
        for _ in range(2):
            pool.rebalance(0.95, 0.05)
        assert pool.search_slots == before  # patience not yet reached
        pool.rebalance(0.95, 0.05)
        assert pool.search_slots == before + 1  # one move, then re-arm
        for _ in range(2):
            pool.rebalance(0.95, 0.05)
        assert pool.search_slots == before + 1

    def test_deadband_is_a_dead_zone(self):
        pool = DynamicResourcePool(total=16, deadband=0.3, patience=1)
        for _ in range(50):
            pool.rebalance(0.55, 0.45)  # gap 0.1 < deadband
        assert pool.moves == 0

    def test_floors_never_starve_a_lane(self):
        pool = DynamicResourcePool(
            total=8, min_search=2, min_mutation=2, patience=1,
            rows_per_slot=10,
        )
        for _ in range(100):
            pool.rebalance(1.0, 0.0)  # all pressure toward search
        assert pool.search_slots == 6
        assert pool.mutation_rows == 2 * 10
        for _ in range(100):
            pool.rebalance(0.0, 1.0)
        assert pool.search_slots == 2

    def test_validates_construction(self):
        with pytest.raises(ValueError):
            DynamicResourcePool(total=1, min_search=1, min_mutation=1)
        with pytest.raises(ValueError):
            DynamicResourcePool(total=4, rows_per_slot=0)


def test_admission_gate_resize_never_revokes():
    gate = AdmissionGate(max_pending=100, policy="reject")
    gate.acquire(80)
    assert gate.utilization() == pytest.approx(0.8)
    gate.set_max_pending(40)  # shrink below what's admitted
    assert gate.pending() == 80  # nothing revoked
    assert gate.utilization() == 1.0  # clamped, not > 1
    gate.release(50)
    assert gate.pending() == 30
    gate.set_max_pending(None)
    assert gate.utilization() == 0.0


def test_adaptive_slots_resize():
    slots = AdaptiveSlots(2)
    assert slots.acquire() and slots.acquire()
    assert not slots.acquire()  # at capacity
    slots.set_capacity(3)
    assert slots.acquire()  # grown capacity admits immediately
    slots.set_capacity(1)  # shrink below in-flight: nothing revoked
    assert slots.in_flight == 3
    assert not slots.acquire()
    for _ in range(3):
        slots.release()
    assert slots.utilization() == 0.0
    with pytest.raises(ValueError):
        slots.acquire(blocking=True)


# ------------------------------------------------------ controller ------
def _controller(**over):
    kw = dict(
        adaptive=True, window_min=0.005, window_max=0.64, flush_max=256,
        adaptive_interval=0.05, adaptive_patience=2, rate_tau=0.5,
    )
    kw.update(over)
    return AdaptiveController(RuntimeConfig(**kw))


class TestAdaptiveController:
    def test_disabled_returns_static_schedule(self):
        c = AdaptiveController(RuntimeConfig(
            adaptive=False, flush_interval=1.0, flush_min=128
        ))
        assert c.window() == 1.0
        assert c.flush_rows() == 128
        assert c.search_effort(16, True, 64) == (16, True, 64)
        assert c.should_compact(0.0) is True
        assert c.compaction_owed() is False

    def test_window_rungs_are_pow2_spaced(self):
        c = _controller()
        rungs = c.window_rungs
        assert rungs[0] == 0.005 and rungs[-1] == 0.64
        for a, b in zip(rungs, rungs[1:]):
            assert b <= 2 * a + 1e-12

    def test_window_widens_under_load_and_narrows_in_lull(self):
        # deterministic clock: all observations carry explicit timestamps
        c = _controller()
        t = 0.0
        # saturating mutation load: ~90% of the 256-rows-per-40ms capacity
        c.mutation.observe_service(0.04)
        for i in range(400):
            c.mutation.observe_arrival(32, now=i * 0.0055)  # ~5800 rows/s
        t = 400 * 0.0055
        assert c.load_factor(now=t) > 0.7
        w0 = c.window(now=t)
        for k in range(1, 40):
            c.mutation.observe_arrival(32, now=t + k * 0.0055)
            c.window(now=t + k * 0.0055)
        w_hot = c.window(now=t + 39 * 0.0055)
        assert w_hot > w0, (w0, w_hot)
        # lull: rate decays, patience steps walk the window back down —
        # but only to the stability floor (2x the 0.04 measured service),
        # never into the un-amortized-dispatch death-spiral zone
        floor = min(r for r in c.window_rungs if r >= 2 * 0.04)
        t2 = t + 40 * 0.0055 + 5 * 0.5
        for k in range(60):
            c.window(now=t2 + k * 0.06)
        assert c.window(now=t2 + 60 * 0.06) == floor

    def test_window_floor_amortizes_dispatch_cost(self):
        # moderate rate, expensive dispatch: rho is small (0.1) but a
        # rho-only law would pick a window whose flush threshold is one
        # request -> every dispatch pays the fixed cost un-amortized.
        # The floor must keep service/window <= 0.5.
        c = _controller()
        c.mutation.observe_service(0.04)
        for i in range(400):
            c.mutation.observe_arrival(16, now=i * 0.0125)  # 1280 rows/s
        t = 400 * 0.0125
        assert c.load_factor(now=t) < 0.3  # rho alone says "narrow"
        for k in range(60):
            c.window(now=t + k * 0.06)
        w = c.window(now=t + 60 * 0.06)
        assert 0.04 / w <= 0.5, f"window {w} leaves dispatch util > 0.5"

    def test_flush_rows_tracks_rate_and_quantizes_pow2(self):
        c = _controller()
        assert c.flush_rows(now=0.0) == 1  # no traffic: dispatch singles
        for i in range(400):
            c.mutation.observe_arrival(32, now=i * 0.01)  # 3200 rows/s
        rows = c.flush_rows(now=4.0)
        assert rows & (rows - 1) == 0  # pow2
        assert 1 <= rows <= 256

    def test_single_rung_per_patience_window_no_oscillation(self):
        # a square-wave rate signal cannot move the window: target flips
        # sides each controller step, the streak resets every flip
        c = _controller(adaptive_patience=3)
        c.mutation.observe_service(0.04)
        # settle onto the stability floor first (deterministic climb),
        # so only square-wave-driven moves are counted below
        t = 0.0
        for _ in range(40):
            c.window(now=t)
            t += 0.06
        changes0 = c.snapshot(now=t)["window_changes"]
        for cycle in range(30):
            # burst half-period: one controller step of rho ~0.8 load,
            # targeting a rung well above the settled floor
            c.mutation.observe_arrival(2600, now=t)
            c.window(now=t + 0.051)
            t += 0.06
            # silent half-period: rate collapses before the next step
            t += 2.5  # 5 tau
            c.window(now=t)
            t += 0.06
        assert c.snapshot(now=t)["window_changes"] == changes0

    def test_effort_degrades_into_envelope_and_recovers(self):
        c = _controller(latency_slo=0.1, adaptive_patience=2)
        assert c.search_effort(16, True, 64) == (16, True, 64)
        t = 0.0
        c.search.observe_service(0.09)  # 90% of the envelope
        for k in range(6):
            t += 0.06
            c.window(now=t)
        nprobe, rerank, budget = c.search_effort(16, True, 64)
        assert nprobe < 16  # stepped down
        assert nprobe & (nprobe - 1) == 0 and budget & (budget - 1) == 0
        for _ in range(30):  # fast again: converge the service EWMA down
            c.search.observe_service(0.001)
        for k in range(20):
            t += 0.06
            c.window(now=t)
        assert c.search_effort(16, True, 64) == (16, True, 64)

    def test_compaction_defers_under_load_but_honours_dead_bound(self):
        c = _controller(compact_force_dead_frac=0.45)
        # burst: queue-age watermark above overload_high (0.05)
        for _ in range(20):
            c.mutation.observe_queue_age(0.2)
        assert c.should_compact(0.1) is False  # deferred
        assert c.snapshot(now=0.0)["compactions_owed"] > 0
        # ... but NEVER past the dead-fraction bound (recall guard)
        assert c.should_compact(0.5) is True
        # still loaded: no catch-up yet
        assert c.compaction_owed() is False
        # lull: watermark decays below overload_low -> owed pass released
        for _ in range(50):
            c.mutation.observe_queue_age(0.0)
        assert c.compaction_owed() is True
        c.compacted()
        assert c.compaction_owed() is False


# --------------------------------------------------- runtime-level ------
def test_window_shrink_takes_effect_on_queued_items():
    """Regression for the stale-batch deadline bug: the flush deadline
    must be derived from the oldest queued item's arrival + the CURRENT
    window, re-read every wait iteration — so a window shrink applies to
    items already sitting in the queue, not one old-window later."""
    x, rt = _runtime(dict(
        adaptive=True, mode="parallel", flush_interval=5.0, window_min=0.005,
    ))
    try:
        # warm the insert path (compiles) so dispatch time is queue wait
        rt.submit_insert(x[:4]).result(timeout=60)
        box = {"w": 5.0}
        rt._controller.window = lambda now=None: box["w"]
        rt._controller.flush_rows = lambda now=None: 10 ** 6
        fut = rt.submit_insert(x[:4])
        time.sleep(0.3)
        assert not fut.done()  # parked behind the 5 s window
        t0 = time.perf_counter()
        box["w"] = 0.01  # shrink: oldest item's deadline is already past
        fut.result(timeout=2.0)
        took = time.perf_counter() - t0
        assert took < 1.0, f"shrink took {took:.2f}s to take effect"
    finally:
        rt.stop()


def test_low_rate_adaptive_dispatches_lone_mutation_fast():
    """The paper's low-QPS claim: with the controller on, a lone insert
    must not wait out a 1 s static window."""
    x, rt = _runtime(dict(
        adaptive=True, mode="parallel", flush_interval=1.0,
        flush_min=128, window_min=0.005, rate_tau=0.3,
    ))
    try:
        rt.submit_insert(x[:4]).result(timeout=60)  # pay compiles
        t0 = time.perf_counter()
        rt.submit_insert(x[4:8]).result(timeout=10)
        took = time.perf_counter() - t0
        assert took < 0.5, f"lone insert took {took:.2f}s (static window?)"
    finally:
        rt.stop()


def test_adaptive_off_is_legacy_schedule():
    """adaptive=False must preserve the static §3.3 behaviour: a lone
    insert waits for the flush window (no premature dispatch)."""
    x, rt = _runtime(dict(
        adaptive=False, mode="parallel", flush_interval=0.4, flush_min=128,
    ))
    try:
        rt.submit_insert(x[:4]).result(timeout=60)
        t0 = time.perf_counter()
        rt.submit_insert(x[4:8]).result(timeout=10)
        took = time.perf_counter() - t0
        assert took > 0.1, f"static window dispatched early ({took:.3f}s)"
    finally:
        rt.stop()


def test_bounded_compiles_across_adaptive_sweep():
    """Adaptive knob changes must quantize into the pow2/rung jit-cache
    keys: a full sweep over effort levels and ladder rungs compiles a
    bounded set of steps, never one per request."""
    x, rt = _runtime(dict(
        adaptive=True, mode="parallel", nprobe=4,
        degradation_ladder=("no_rerank", "half_nprobe"),
        latency_slo=10.0, max_effort=2,
    ))
    try:
        rt.submit_insert(x[:64]).result(timeout=60)
        for effort in (0, 1, 2, 1, 0, 2, 0):
            with rt._controller._lock:
                rt._controller._effort = effort
            for _ in range(3):
                rt.submit_search(x[:2]).result(timeout=30)
        keys = set(rt._search_steps) | set(rt._fused_steps)
        # every key coordinate the controller/ladder vary stays pow2
        for key in keys:
            base, budget, nprobe = key[0], key[1], key[2]
            for v in (base, budget, nprobe):
                assert v >= 1 and v & (v - 1) == 0, key
        # 3 effort levels x 1 budget base(+growth) is the whole key space
        assert len(keys) <= 8, sorted(keys)
    finally:
        rt.stop()


def test_stats_percentiles_and_adaptive_gauges():
    x, rt = _runtime(dict(
        adaptive=True, mode="parallel", max_pending_mutations=512,
    ))
    try:
        rt.submit_search(x[:1]).result(timeout=30)
        rt.submit_insert(x[:8]).result(timeout=60)
        s = rt.stats()
        for lane in ("search", "insert", "mutation"):
            p = s["percentiles"][lane]
            assert set(p) == {"p50_ms", "p95_ms", "p99_ms", "mean_ms",
                              "max_ms", "n"}
        assert s["percentiles"]["search"]["n"] == 1
        # the percentile path and the LatencyStats path must agree
        assert s["percentiles"]["search"]["p99_ms"] == pytest.approx(
            s["search"].p99_ms
        )
        a = s["adaptive"]
        assert a["window_s"] in rt._controller.window_rungs
        assert a["search_rate"] >= 0.0 and a["mutation_rate"] >= 0.0
        assert s["pool"]["search_slots"] >= 1
        assert s["search_slots"] == rt._slots.capacity
    finally:
        rt.stop()


def test_pool_rebalance_wired_into_runtime():
    """The search loop applies pool decisions: saturating the search lane
    while the mutation lane idles moves capacity toward search."""
    x, rt = _runtime(dict(
        adaptive=True, mode="parallel", n_slots=4,
        max_pending_mutations=256, pool_rows_per_slot=32,
        pool_interval=0.02, adaptive_patience=2, pool_min_search=2,
    ))
    try:
        rt.submit_search(x[:1]).result(timeout=30)  # pay the compile
        deadline = time.perf_counter() + 10.0
        moved = False
        while time.perf_counter() < deadline and not moved:
            try:
                rt.submit_search(x[:1])
            except Exception:
                pass  # slot-full rejections are part of the pressure
            moved = rt._pool.moves > 0
        assert moved, "pool never rebalanced under one-sided load"
        assert rt._pool.search_slots >= 4  # moved toward search, not away
    finally:
        rt.stop()
