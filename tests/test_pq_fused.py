"""PQ-ADC fused streaming top-k: kernel parity + IVFPQ end-to-end parity.

Everything here is marked ``pq`` so CI can run it as its own job slice
(interpret-mode grid steps cost ~ms each on CPU — grids are kept tiny, but
the slice still deserves its own wall-clock budget).
"""

import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import build_ivf
from repro.core import pq as pqmod
from repro.core.search import make_search_fn
from repro.kernels import ref
from repro.kernels.ivf_scan import ivf_pq_block_topk, ivf_pq_block_topk_scan

pytestmark = pytest.mark.pq

KSUB = 256


def _pq_topk_inputs(q, npb, m, p, t, c, seed, hole_frac=0.25, empty_frac=0.3,
                    ncl=None):
    """Union-scan shaped PQ inputs: hole blocks (-1 in the NULL-padded
    union), empty (-1) id slots, and owner/probe-list routing (the
    LUT-selecting probe slot — including the non-member case — is derived
    from owner membership, exactly as in-kernel)."""
    rng = np.random.default_rng(seed)
    ncl = ncl or 2 * npb  # ~half the (query, candidate) pairs are members
    lut = jnp.asarray(rng.normal(size=(q, npb, m, KSUB)) ** 2, jnp.float32)
    codes = jnp.asarray(rng.integers(0, KSUB, size=(p, t, m)), jnp.uint8)
    ids = rng.integers(0, p, size=(c,)).astype(np.int32)
    ids[rng.random(c) < hole_frac] = -1  # hole blocks
    pool_ids = rng.permutation(p * t).astype(np.int32).reshape(p, t)
    pool_ids[rng.random((p, t)) < empty_frac] = -1  # empty slots
    live = (pool_ids != -1).astype(np.uint8)
    owners = rng.integers(0, ncl, size=(c,)).astype(np.int32)
    owners[ids == -1] = -1  # hole blocks are invalid for every query
    probe = np.stack(
        [rng.permutation(ncl)[:npb] for _ in range(q)]
    ).astype(np.int32)
    return (lut, codes, jnp.asarray(ids), jnp.asarray(owners),
            jnp.asarray(pool_ids), jnp.asarray(live), jnp.asarray(probe))


@pytest.mark.parametrize(
    "q,npb,m,p,t,c,kp",
    [
        (8, 4, 8, 6, 16, 5, 8),
        (10, 3, 4, 5, 8, 7, 16),  # Q pads to 16 -> two q tiles
        (4, 2, 8, 4, 32, 3, 128),  # kprime > live candidates
        (1, 4, 2, 6, 8, 6, 4),
    ],
)
def test_ivf_pq_block_topk_matches_ref(q, npb, m, p, t, c, kp):
    lut, codes, ids, owners, pool_ids, live, probe = _pq_topk_inputs(
        q, npb, m, p, t, c, seed=q * 10 + c
    )
    want_d, want_i = ref.ivf_pq_block_topk_ref(
        lut, codes, ids, owners, pool_ids, live, probe, kprime=kp
    )
    got_d, got_i = ivf_pq_block_topk(
        lut, codes, ids, owners, pool_ids, live, probe, kprime=kp,
        interpret=True,
    )
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-3)
    np.testing.assert_array_equal(got_i, want_i)
    sc_d, sc_i = ivf_pq_block_topk_scan(
        lut, codes, ids, owners, pool_ids, live, probe, kprime=kp, chunk=4
    )
    np.testing.assert_allclose(sc_d, want_d, rtol=1e-5, atol=1e-3)
    np.testing.assert_array_equal(sc_i, want_i)


def test_ivf_pq_block_topk_ref_matches_adc_accumulate():
    """The ref oracle is itself checked against core.pq.adc_accumulate (the
    acceptance oracle): per-candidate LUT rows fed through the jnp ADC."""
    q, npb, m, p, t, c, kp = 6, 4, 8, 5, 8, 6, 8
    lut, codes, ids, owners, pool_ids, live, probe = _pq_topk_inputs(
        q, npb, m, p, t, c, seed=77
    )
    # expand the owner/probe routing to the dense probe-slot index the
    # kernels derive on-chip
    pslot = ref._pslot_from_owners(probe, owners)  # [Q, C]
    lq = jnp.take_along_axis(lut, jnp.clip(pslot, 0)[:, :, None, None], axis=1)
    cb = jnp.broadcast_to(
        codes[jnp.maximum(ids, 0)][None], (q, c, t, m)
    )
    d_acc = pqmod.adc_accumulate(lq, cb)  # [Q, C, T]
    vids = pool_ids[jnp.maximum(ids, 0)]
    ok = (pslot != -1)[:, :, None] & (vids != -1)[None]
    flat = np.where(np.asarray(ok), np.asarray(d_acc), np.inf).reshape(q, -1)
    want = np.sort(flat, axis=1)[:, :kp]
    got_d, _ = ref.ivf_pq_block_topk_ref(
        lut, codes, ids, owners, pool_ids, live, probe, kprime=kp
    )
    np.testing.assert_allclose(got_d, want, rtol=1e-5, atol=1e-3)


def test_ivf_pq_block_topk_all_invalid_returns_inf():
    q, npb, m, p, t, c = 4, 2, 4, 3, 8, 5
    rng = np.random.default_rng(0)
    lut = jnp.asarray(rng.normal(size=(q, npb, m, KSUB)) ** 2, jnp.float32)
    codes = jnp.asarray(rng.integers(0, KSUB, size=(p, t, m)), jnp.uint8)
    ids = jnp.full((c,), -1, jnp.int32)
    owners = jnp.full((c,), -1, jnp.int32)
    pool_ids = jnp.zeros((p, t), jnp.int32)
    live = jnp.ones((p, t), jnp.uint8)
    probe = jnp.asarray(rng.integers(0, 4, size=(q, npb)), jnp.int32)
    d, i = ivf_pq_block_topk(
        lut, codes, ids, owners, pool_ids, live, probe, kprime=8,
        interpret=True,
    )
    assert np.isinf(np.asarray(d)).all()
    assert (np.asarray(i) == -1).all()


# ---------------------------------------------------------------------------
# IVFPQ end-to-end: union_fused (pq) vs block_table + pq_score_fn vs the
# adc_accumulate oracle, on a pool with holes (rearranged + recycled
# blocks), NULL padding, and multi-block chains.
# ---------------------------------------------------------------------------


def _clustered(n, d, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 3
    return (
        centers[rng.integers(0, 8, n)]
        + rng.normal(size=(n, d)).astype(np.float32)
    ).astype(np.float32)


@pytest.fixture(scope="module")
def pq_index():
    x = _clustered(1600, 32, seed=3)
    idx = build_ivf(
        x, n_clusters=8, payload="pq", pq_m=8, block_size=16, max_chain=32,
        add_batch=256, nprobe=4, k=10, rearrange_threshold=60,
    )
    # online growth + rearrangement: chains go multi-block, old blocks land
    # on the free stack, later inserts recycle them -> physically scattered
    # pool with NULL padding in partially filled tail blocks
    extra = _clustered(300, 32, seed=4)
    idx.add(extra)
    idx.maybe_rearrange(max_passes=6)
    idx.add(_clustered(150, 32, seed=5))
    corpus = np.concatenate([x, extra, _clustered(150, 32, seed=5)])
    rng = np.random.default_rng(6)
    q = jnp.asarray(corpus[rng.integers(0, len(corpus), 6)] + 0.001)
    return corpus, idx, q


def _oracle_adc(idx, queries, nprobe):
    """id -> ADC distance maps per query over the probed candidate set,
    computed straight from pq_score_fn's building blocks."""
    from repro.core.search import coarse_probe, gather_candidate_blocks

    probe_idx, _ = coarse_probe(idx.state, queries, nprobe)
    payload, ids, valid = gather_candidate_blocks(idx.state, probe_idx)
    lut = pqmod.probe_residual_luts(
        idx.pq, idx.state.centroids, queries, probe_idx
    )  # [Q, NP, M, K]
    q, c, t, m = payload.shape
    chain = c // probe_idx.shape[1]
    codes = payload.reshape(q, probe_idx.shape[1], chain * t, m)
    d = pqmod.adc_accumulate(lut, codes).reshape(q, c, t)
    d = np.where(np.asarray(valid), np.asarray(d), np.inf)
    maps = []
    for qi in range(q):
        m_ = {}
        for cid, dist in zip(
            np.asarray(ids)[qi].ravel(), d[qi].ravel()
        ):
            if cid >= 0 and np.isfinite(dist):
                m_[int(cid)] = min(dist, m_.get(int(cid), np.inf))
        maps.append(m_)
    return maps


@pytest.mark.parametrize("path", ["union_fused", "union_fused_scan"])
def test_ivfpq_union_fused_matches_block_table(pq_index, path):
    corpus, idx, q = pq_index
    budget = idx._chain_budget()
    d_bt, i_bt = idx.search(np.asarray(q), nprobe=4, k=10)  # block_table
    fn = make_search_fn(
        idx.pool_cfg, nprobe=4, k=10, path=path,
        score_fn=pqmod.pq_score_fn(idx.pq), pq=idx.pq, chain_budget=budget,
    )
    d, i = fn(idx.state, q)
    d, i = np.asarray(d), np.asarray(i)
    # PQ distances tie whenever two vectors share a code, so ids may differ
    # at equal distance — distances must agree exactly rank-for-rank, and
    # every returned id must carry its true oracle ADC distance.
    np.testing.assert_allclose(d, d_bt, rtol=1e-4, atol=1e-3)
    oracle = _oracle_adc(idx, q, nprobe=4)
    for qi in range(len(oracle)):
        for dist, cid in zip(d[qi], i[qi]):
            assert cid in oracle[qi], (qi, cid)
            np.testing.assert_allclose(dist, oracle[qi][cid], atol=1e-3)


def test_ivfpq_union_fused_k_exceeds_live(pq_index):
    corpus, idx, q = pq_index
    fn = make_search_fn(
        idx.pool_cfg, nprobe=1, k=300, path="union_fused",
        pq=idx.pq, chain_budget=idx._chain_budget(),
    )
    d, i = fn(idx.state, q)
    d, i = np.asarray(d), np.asarray(i)
    assert np.isinf(d).any(), "expected padded tail past the probed list"
    assert (i[np.isinf(d)] == -1).all()
    assert (i[~np.isinf(d)] >= 0).all()


def test_ivfpq_union_fused_serves():
    """The serving runtime can now route a PQ index through the fused path
    (the 'PQ must use block_table' restriction is gone)."""
    from repro.core.scheduler import RuntimeConfig, ServingRuntime

    x = _clustered(900, 16, seed=11)
    idx = build_ivf(x, n_clusters=4, payload="pq", pq_m=4, block_size=16,
                    max_chain=32, add_batch=256)
    rt = ServingRuntime(
        idx,
        RuntimeConfig(mode="parallel", nprobe=4, k=5,
                      search_path="union_fused", flush_min=4,
                      flush_interval=0.05),
    )
    try:
        d, ids = rt.submit_search(x[:4]).result(timeout=120)
        hit = (ids[:, :1] == np.arange(4)[:, None]).mean()
        assert hit > 0.5, ids[:, 0]  # PQ is lossy; self-match mostly holds
        new = _clustered(12, 16, seed=12) + 60.0
        new_ids = rt.submit_insert(new).result(timeout=30)
        time.sleep(0.1)
        d, ids = rt.submit_search(new[:2]).result(timeout=60)
        assert (ids[:, 0] == new_ids[:2]).all()
    finally:
        rt.stop()


def test_ivfpq_union_fused_self_recall(pq_index):
    corpus, idx, q = pq_index
    fn = make_search_fn(
        idx.pool_cfg, nprobe=8, k=10, path="union_fused",
        pq=idx.pq, chain_budget=idx._chain_budget(),
    )
    rng = np.random.default_rng(9)
    sel = rng.integers(0, len(corpus), 8)
    d, i = fn(idx.state, jnp.asarray(corpus[sel]))
    hit = (np.asarray(i) == sel[:, None]).any(axis=1).mean()
    assert hit > 0.8, hit
