"""Observability layer: tracing, flight recorder, exporters, bundles.

Three tiers:

* pure-unit — ring wraparound/concurrency, stage/outcome/event-name
  registry validation, sampler strides, decompose arithmetic, and the
  ``percentile_summary`` / ``ArrivalEstimator`` edge cases the exporters
  lean on;
* format — Perfetto ``trace_event`` and Prometheus text exposition
  checked against the format grammar, not just "is a string";
* end-to-end — a real ``ServingRuntime`` serving real traffic, asserting
  the span stages (including the compile-vs-execute split), terminal
  outcomes, flight-recorder transitions (WAL fsync/rotate, snapshot
  cut/publish, injected faults, worker restarts), ``reset_stats``
  semantics, and the debug bundle written on ``stop()``.
"""

import json
import os
import re
import threading
import time

import numpy as np
import pytest

from repro.core import build_ivf
from repro.core.admission import QueueFull
from repro.core.faults import FaultPlan
from repro.core.metrics import ArrivalEstimator, percentile_summary
from repro.core.runtime import RuntimeConfig, ServingRuntime
from repro.obs import events as obs_events
from repro.obs.bundle import write_debug_bundle
from repro.obs.events import (
    EV_FAULT_INJECTED,
    EV_SNAPSHOT_CUT,
    EV_SNAPSHOT_PUBLISH,
    EV_WAL_FSYNC,
    EV_WAL_ROTATE,
    EV_WORKER_RESTART,
    EVENT_CATALOG,
    FlightRecorder,
)
from repro.obs.export import (
    PROM_COUNTER_KEYS,
    _prom_value,
    flatten_metrics,
    perfetto_trace,
    prometheus_text,
)
from repro.obs.trace import (
    OUTCOME_OK,
    OUTCOME_REJECTED,
    SPAN_STAGES,
    STAGE_ACK,
    STAGE_ADMISSION,
    STAGE_COMPILE,
    STAGE_EXECUTE,
    STAGE_QUEUE,
    RequestTrace,
    RequestTracer,
    TraceRing,
    decompose,
)

pytestmark = pytest.mark.obs

D = 16


def _data(n, d=D, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 3
    return (
        centers[rng.integers(0, 8, n)]
        + rng.normal(size=(n, d)).astype(np.float32)
    ).astype(np.float32)


@pytest.fixture(scope="module")
def base_index():
    x = _data(1200)
    return x, lambda: build_ivf(
        x, n_clusters=4, block_size=16, max_chain=64, add_batch=256,
        capacity_vectors=8000,
    )


def _mk_trace(tid=1, kind="search", marks=()):
    tr = RequestTrace(tid, kind, t_start=0.0)
    for stage, t in marks:
        tr.stamp(stage, t)
    return tr


# ------------------------------------------------------------- trace unit --
def test_stamp_rejects_unregistered_stage():
    tr = RequestTrace(1, "search", 0.0)
    with pytest.raises(ValueError, match="unregistered span stage"):
        tr.stamp("warp_drive")


def test_spans_tile_timeline_and_sum_to_e2e_exactly():
    tr = _mk_trace(marks=[(STAGE_ADMISSION, 1.0), (STAGE_QUEUE, 2.25),
                          (STAGE_ACK, 3.5)])
    spans = tr.spans()
    assert spans == [(STAGE_ADMISSION, 0.0, 1.0), (STAGE_QUEUE, 1.0, 2.25),
                     (STAGE_ACK, 2.25, 3.5)]
    # contiguity: each span starts where the previous ended
    for (_, _, t1), (_, t0, _) in zip(spans, spans[1:]):
        assert t1 == t0
    assert sum(t1 - t0 for _, t0, t1 in spans) == tr.e2e_s() == 3.5
    d = tr.as_dict()
    assert d["e2e_s"] == 3.5 and len(d["spans"]) == 3


def test_repeated_stage_keeps_spans_contiguous():
    # per-item poison retries legitimately re-stamp a stage
    tr = _mk_trace(marks=[(STAGE_QUEUE, 1.0), (STAGE_QUEUE, 2.0),
                          (STAGE_ACK, 3.0)])
    assert sum(t1 - t0 for _, t0, t1 in tr.spans()) == tr.e2e_s() == 3.0


def test_trace_ring_wraparound_keeps_newest_oldest_first():
    ring = TraceRing(4)
    for i in range(1, 11):
        ring.record(_mk_trace(tid=i))
    assert [t.trace_id for t in ring.snapshot()] == [7, 8, 9, 10]
    assert ring.total == 10 and ring.capacity == 4
    ring.clear()
    assert ring.snapshot() == [] and ring.total == 10  # lifetime survives


def test_trace_ring_rejects_nonpositive_capacity():
    with pytest.raises(ValueError):
        TraceRing(0)


def test_trace_ring_concurrent_writers_lose_nothing():
    ring = TraceRing(64)
    n_threads, per = 8, 500

    def work(base):
        for i in range(per):
            ring.record(_mk_trace(tid=base + i))

    ts = [threading.Thread(target=work, args=(k * per,))
          for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert ring.total == n_threads * per
    assert len(ring.snapshot()) == 64  # exactly one full window survives


def test_sampler_strides():
    assert RequestTracer(0.0).enabled is False
    assert RequestTracer(0.0).start("search") is None
    every = RequestTracer(1.0)
    assert every.stride == 1
    assert all(every.start("search") is not None for _ in range(5))
    half = RequestTracer(0.5)
    assert half.stride == 2
    hits = [half.start("search") is not None for _ in range(10)]
    assert hits == [False, True] * 5  # deterministic: every 2nd submit
    assert RequestTracer(0.01).stride == 100
    assert RequestTracer(7.0).stride == 1  # rate clamped into [0, 1]


def test_finish_is_idempotent_and_validates_outcome():
    tracer = RequestTracer(1.0)
    tr = tracer.start("search")
    with pytest.raises(ValueError, match="unknown trace outcome"):
        tracer.finish(tr, "vanished")
    tracer.finish(tr, OUTCOME_OK)
    tracer.finish(tr, "error")  # resolution/failure race: first wins
    assert tr.outcome == OUTCOME_OK
    assert tracer.ring.total == 1  # recorded once, not twice


def test_decompose_uses_only_ok_traces():
    ok = _mk_trace(tid=1, marks=[(STAGE_ADMISSION, 1.0), (STAGE_ACK, 3.0)])
    ok.outcome = OUTCOME_OK
    rej = _mk_trace(tid=2, marks=[(STAGE_ADMISSION, 9.0)])
    rej.outcome = OUTCOME_REJECTED
    out = decompose([ok, rej])
    assert out["n_ok"] == 1
    assert out["stages"][STAGE_ADMISSION]["p50_ms"] == 1000.0
    assert out["stages"][STAGE_ACK]["p50_ms"] == 2000.0
    assert out["e2e"]["p50_ms"] == out["span_sum"]["p50_ms"] == 3000.0


# ---------------------------------------------------- flight-recorder unit --
def test_record_event_rejects_unregistered_name():
    rec = FlightRecorder(8)
    with pytest.raises(ValueError, match="unregistered event name"):
        rec.record_event("controller.window_rungg")  # event-ok: negative test


def test_every_ev_constant_is_in_the_catalog():
    consts = {v for k, v in vars(obs_events).items() if k.startswith("EV_")}
    assert consts == EVENT_CATALOG
    assert all(re.fullmatch(r"[a-z_]+\.[a-z_]+", n) for n in EVENT_CATALOG)


def test_flight_recorder_wraparound_count_and_clear():
    rec = FlightRecorder(4)
    for i in range(6):
        rec.record_event(EV_WAL_FSYNC, t=float(i), lsn=i)
    win = rec.snapshot()
    assert [e.fields["lsn"] for e in win] == [2, 3, 4, 5]  # oldest first
    assert rec.count(EV_WAL_FSYNC) == 4 and rec.total == 6
    assert win[0].as_dict() == {"seq": 3, "t": 2.0, "name": EV_WAL_FSYNC,
                                "lsn": 2}
    rec.clear()
    assert rec.snapshot() == [] and rec.count(EV_WAL_FSYNC) == 0


def test_flight_recorder_concurrent_emitters_get_unique_seqs():
    rec = FlightRecorder(4096)
    n_threads, per = 8, 200

    def work():
        for _ in range(per):
            rec.record_event(EV_WAL_FSYNC)

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    seqs = [e.seq for e in rec.snapshot()]
    assert len(seqs) == len(set(seqs)) == n_threads * per == rec.total


# ----------------------------------------------------- metrics edge cases --
def test_percentile_summary_empty_is_zeros_not_nan():
    out = percentile_summary([])
    assert out == {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
                   "mean_ms": 0.0, "max_ms": 0.0, "n": 0}


def test_percentile_summary_single_sample_collapses():
    out = percentile_summary([0.25])
    assert out["n"] == 1
    assert (out["p50_ms"] == out["p95_ms"] == out["p99_ms"]
            == out["mean_ms"] == out["max_ms"] == 250.0)


def test_arrival_estimator_empty_and_single_arrival():
    est = ArrivalEstimator(tau_s=0.5)
    assert est.rate(now=100.0) == 0.0
    assert est.snapshot(now=100.0) == {"rate": 0.0, "queue_age_s": 0.0,
                                       "service_s": 0.0, "events": 0}
    est.observe_arrival(1, now=100.0)
    assert est.rate(now=100.0) == pytest.approx(1 / 0.5)
    # decay is monotone in elapsed silence
    assert est.rate(now=100.0) > est.rate(now=100.4) > est.rate(now=101.0)


def test_arrival_estimator_service_seeds_then_smooths():
    est = ArrivalEstimator(tau_s=0.5)
    assert est.service(default=0.123) == 0.123
    est.observe_service(1.0)
    assert est.service() == 1.0  # EWMA seeds on the first sample
    est.observe_service(0.0)
    assert est.service() == pytest.approx(0.7)


def test_arrival_estimator_reset_forgets_everything():
    est = ArrivalEstimator(tau_s=0.5)
    est.observe_arrival(5, now=10.0)
    est.observe_queue_age(0.4)
    est.observe_service(0.2)
    est.reset()
    assert est.snapshot(now=10.0) == {"rate": 0.0, "queue_age_s": 0.0,
                                      "service_s": 0.0, "events": 0}


# -------------------------------------------------------- exporter format --
def test_flatten_metrics_recurses_and_drops_strings():
    flat = flatten_metrics({
        "a": 1, "b": {"c": 2.5, "d": {"e": 3}}, "accepting": True,
        "label": "ignored",
    })
    assert flat == {"a": 1.0, "b_c": 2.5, "b_d_e": 3.0, "accepting": 1.0}


def test_prom_value_special_floats():
    assert _prom_value(float("nan")) == "NaN"
    assert _prom_value(float("inf")) == "+Inf"
    assert _prom_value(float("-inf")) == "-Inf"
    assert _prom_value(2.0) == "2.0"


_PROM_LINE = re.compile(
    r"^(# HELP [a-zA-Z_:][a-zA-Z0-9_:]* .+"
    r"|# TYPE [a-zA-Z_:][a-zA-Z0-9_:]* (counter|gauge)"
    r"|[a-zA-Z_:][a-zA-Z0-9_:]* (NaN|[+-]Inf|[-+0-9.e]+))$"
)


def test_prometheus_text_grammar_and_typing():
    text = prometheus_text({"inserts": 3.0, "pending_mutations": 7.0,
                            "percentiles_search_p50_ms": 1.25})
    for line in text.strip().split("\n"):
        assert _PROM_LINE.match(line), f"bad exposition line: {line!r}"
    assert "# TYPE repro_inserts counter" in text
    assert "# TYPE repro_pending_mutations gauge" in text
    assert "# TYPE repro_percentiles_search_p50_ms gauge" in text
    assert "repro_inserts 3.0" in text


def test_perfetto_envelope_spans_and_instants():
    tr = _mk_trace(marks=[(STAGE_ADMISSION, 0.001), (STAGE_ACK, 0.003)])
    tr.outcome = OUTCOME_OK
    rec = FlightRecorder(8)
    rec.record_event(EV_WAL_ROTATE, t=0.002, segment=1)
    env = perfetto_trace([tr], rec.snapshot())
    json.loads(json.dumps(env))  # round-trips as JSON
    assert env["displayTimeUnit"] == "ms"
    xs = [e for e in env["traceEvents"] if e["ph"] == "X"]
    ins = [e for e in env["traceEvents"] if e["ph"] == "i"]
    assert len(xs) == 2 and len(ins) == 1
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] > 0 and e["tid"] == 1
        assert e["name"] in SPAN_STAGES
    assert ins[0]["name"] == EV_WAL_ROTATE and ins[0]["s"] == "g"
    # time_origin defaults to the earliest timestamp -> timeline starts at 0
    assert min(e["ts"] for e in env["traceEvents"]) == 0


def test_debug_bundle_roundtrip_and_jsonable_fallback(tmp_path):
    rec = FlightRecorder(8)
    rec.record_event(EV_SNAPSHOT_CUT, t=1.0, lsn=7)
    path = write_debug_bundle(
        str(tmp_path), reason="unit test!", events=rec.snapshot(),
        extra={"np_scalar": np.int32(5), "opaque": object()},
    )
    assert os.path.dirname(path) == str(tmp_path / "debug")
    payload = json.loads(open(path).read())
    assert payload["reason"] == "unit test!"
    assert payload["events"][0]["name"] == EV_SNAPSHOT_CUT
    assert payload["extra"]["np_scalar"] == 5
    assert payload["extra"]["opaque"].startswith("<object object")
    assert not [f for f in os.listdir(tmp_path / "debug")
                if f.endswith(".tmp")]  # atomic: no tmp residue


# ------------------------------------------------------------- end-to-end --
def test_runtime_traces_full_path_with_compile_execute_split(base_index):
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, trace_sample_rate=1.0),
    )
    try:
        for _ in range(4):
            rt.submit_search(x[:2]).result(timeout=60)
        rt.submit_insert(_data(3, seed=7)).result(timeout=60)
        traces = rt.traces()
        searches = [t for t in traces if t.kind == "search"]
        inserts = [t for t in traces if t.kind == "insert"]
        assert len(searches) == 4 and len(inserts) == 1
        for tr in traces:
            assert tr.outcome == OUTCOME_OK
            stages = [s for s, _, _ in tr.spans()]
            assert stages[0] == STAGE_ADMISSION and stages[-1] == STAGE_ACK
            assert set(stages) <= SPAN_STAGES
            # contiguous spans sum to e2e exactly (float-add associativity
            # aside): the invariant BENCH_obs.json certifies at scale
            assert sum(t1 - t0 for _, t0, t1 in tr.spans()) == \
                pytest.approx(tr.e2e_s(), rel=1e-9)
        # first dispatch of the shape traces+compiles; warm repeats execute
        assert STAGE_COMPILE in [s for s, _, _ in searches[0].spans()]
        assert STAGE_EXECUTE in [s for s, _, _ in searches[-1].spans()]
        assert decompose(traces)["n_ok"] == 5
    finally:
        rt.stop()


def test_runtime_rejected_submit_leaves_rejected_trace(base_index):
    x, make = base_index
    # hold the insert worker so the first submit's rows stay pending and
    # the second deterministically overflows the admission gate
    plan = FaultPlan().delay("insert_loop", 0.5, nth=0)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=64,
                      flush_interval=0.05, trace_sample_rate=1.0,
                      max_pending_mutations=8),
        faults=plan,
    )
    try:
        first = rt.submit_insert(_data(8, seed=8))
        with pytest.raises(QueueFull):
            rt.submit_insert(_data(8, seed=8))
        first.result(timeout=60)
        rejected = [t for t in rt.traces() if t.outcome == OUTCOME_REJECTED]
        assert len(rejected) == 1 and rejected[0].kind == "insert"
        assert [s for s, _, _ in rejected[0].spans()] == [STAGE_ADMISSION]
    finally:
        rt.stop()


def test_reset_stats_clears_traces_but_keeps_flight_history(base_index):
    x, make = base_index
    plan = FaultPlan().delay("search_loop", 0.01, nth=0)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, trace_sample_rate=1.0),
        faults=plan,
    )
    try:
        rt.submit_search(x[:1]).result(timeout=60)
        injected = [e for e in rt.events() if e.name == EV_FAULT_INJECTED]
        assert injected and injected[0].fields["site"] == "search_loop"
        assert rt.traces() and rt.stats()["percentiles"]["search"]["n"] > 0
        rt.reset_stats()
        assert rt.traces() == []
        assert rt.stats()["percentiles"]["search"]["n"] == 0
        # the flight recorder is history, not a sampling window
        assert [e for e in rt.events() if e.name == EV_FAULT_INJECTED]
    finally:
        rt.stop()


def test_runtime_durability_events_and_shutdown_bundle(base_index, tmp_path):
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, trace_sample_rate=1.0,
                      persist_dir=str(tmp_path), wal_sync_interval=1),
    )
    try:
        rt.submit_insert(_data(4, seed=9)).result(timeout=60)
        rt.snapshot(wait=True)
        names = {e.name for e in rt.events()}
        assert {EV_WAL_FSYNC, EV_WAL_ROTATE, EV_SNAPSHOT_CUT,
                EV_SNAPSHOT_PUBLISH} <= names
    finally:
        rt.stop()
    bundles = list((tmp_path / "debug").glob("bundle-shutdown-*.json"))
    assert len(bundles) == 1
    payload = json.loads(bundles[0].read_text())
    assert payload["reason"] == "shutdown"
    assert {e["name"] for e in payload["events"]} >= {EV_WAL_FSYNC}
    assert payload["stats"]["inserts"] == 4
    assert payload["config"]["persist_dir"] == str(tmp_path)
    assert any(t["kind"] == "insert" for t in payload["traces"])


def test_worker_restart_emits_flight_event(base_index):
    x, make = base_index
    plan = FaultPlan().fail("search_loop", nth=2)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, restart_backoff=0.01),
        faults=plan,
    )
    try:
        deadline = time.perf_counter() + 30
        while plan.calls("search_loop") < 4:
            assert time.perf_counter() < deadline, "lane never restarted"
            time.sleep(0.01)
        rt.submit_search(x[:1]).result(timeout=60)
        restarts = [e for e in rt.events() if e.name == EV_WORKER_RESTART]
        assert restarts and restarts[0].fields["lane"] == "search_loop"
        assert restarts[0].fields["restarts"] == 1
    finally:
        rt.stop()


def test_runtime_exporters_are_format_valid(base_index):
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, trace_sample_rate=1.0),
    )
    try:
        for _ in range(3):
            rt.submit_search(x[:2]).result(timeout=60)
        text = rt.prometheus_text()
        for line in text.strip().split("\n"):
            assert _PROM_LINE.match(line), f"bad exposition line: {line!r}"
        # the counters the runbook's example queries rely on are present
        assert "repro_inserts" in text
        assert "repro_percentiles_search_p50_ms" in text
        env = rt.export_perfetto()
        json.loads(json.dumps(env))
        assert [e for e in env["traceEvents"] if e["ph"] == "X"]
        flat = rt.metrics()
        assert all(isinstance(v, float) for v in flat.values())
        assert flat["percentiles_search_n"] == 3.0
    finally:
        rt.stop()
