"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ivf_scan import (
    ivf_block_scan,
    ivf_block_topk,
    ivf_block_topk_scan,
)
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.pq_adc import pq_adc


@pytest.mark.parametrize(
    "q,d,p,t,c",
    [
        (8, 64, 16, 128, 4),
        (16, 128, 32, 256, 9),
        (8, 32, 7, 8, 7),  # odd sizes
        (1, 128, 4, 64, 2),
    ],
)
def test_ivf_block_scan_matches_ref(q, d, p, t, c):
    rng = np.random.default_rng(q * 1000 + t)
    queries = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    pool = jnp.asarray(rng.normal(size=(p, t, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(-1, p, size=(c,)), jnp.int32)
    got = ivf_block_scan(queries, pool, ids, interpret=True)
    want = ref.ivf_block_scan_ref(queries, pool, ids)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def _topk_inputs(q, d, p, t, c, seed, hole_frac=0.25, empty_frac=0.3,
                 ncl=8, nprobe=6, dead_frac=0.2):
    """Union-scan shaped inputs: hole blocks (-1 in the NULL-padded union),
    empty (-1) id slots, tombstoned (live == 0) rows, and owner/probe-list
    routing (membership is derived in-kernel: a query owns a candidate iff
    its distinct probe list contains the candidate's owner; NULL slots own
    -1)."""
    rng = np.random.default_rng(seed)
    queries = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    pool = jnp.asarray(rng.normal(size=(p, t, d)), jnp.float32)
    ids = rng.integers(0, p, size=(c,)).astype(np.int32)
    ids[rng.random(c) < hole_frac] = -1  # hole blocks
    pool_ids = rng.permutation(p * t).astype(np.int32).reshape(p, t)
    pool_ids[rng.random((p, t)) < empty_frac] = -1  # empty slots
    # occupied rows are live unless tombstoned (deleted rows keep their id)
    live = (pool_ids != -1) & (rng.random((p, t)) >= dead_frac)
    owners = rng.integers(0, ncl, size=(c,)).astype(np.int32)
    owners[ids == -1] = -1  # NULL slots own nothing
    probe = np.stack(
        [rng.permutation(ncl)[:nprobe] for _ in range(q)]
    ).astype(np.int32)
    return (queries, pool, jnp.asarray(ids), jnp.asarray(owners),
            jnp.asarray(pool_ids), jnp.asarray(live.astype(np.uint8)),
            jnp.asarray(probe))


@pytest.mark.parametrize(
    "q,d,p,t,c,kp",
    [
        (8, 64, 16, 128, 4, 16),
        (13, 32, 9, 16, 11, 8),  # Q not a multiple of 8 (pad path)
        (5, 128, 4, 64, 3, 256),  # kprime > live candidates
        (1, 64, 6, 8, 7, 4),
        (130, 32, 8, 16, 5, 8),  # Q > q_tile default tile split
    ],
)
def test_ivf_block_topk_matches_ref(q, d, p, t, c, kp):
    queries, pool, ids, owners, pool_ids, live, probe = _topk_inputs(
        q, d, p, t, c, seed=q + c
    )
    want_d, want_i = ref.ivf_block_topk_ref(
        queries, pool, ids, owners, pool_ids, live, probe, kprime=kp
    )
    got_d, got_i = ivf_block_topk(
        queries, pool, ids, owners, pool_ids, live, probe, kprime=kp,
        interpret=True,
    )
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(got_i, want_i)
    sc_d, sc_i = ivf_block_topk_scan(
        queries, pool, ids, owners, pool_ids, live, probe, kprime=kp,
        chunk=4,
    )
    np.testing.assert_allclose(sc_d, want_d, rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(sc_i, want_i)
    # tombstoned locations never appear in any impl's survivor set
    dead_locs = np.flatnonzero(
        (np.asarray(pool_ids).ravel() != -1)
        & (np.asarray(live).ravel() == 0)
    )
    for out in (want_i, got_i, sc_i):
        assert not np.isin(np.asarray(out), dead_locs).any()


def test_ivf_block_topk_all_holes_returns_inf():
    """A NULL-padded union with every candidate masked yields (inf, -1)."""
    q, d, p, t, c = 4, 16, 3, 8, 5
    rng = np.random.default_rng(0)
    queries = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    pool = jnp.asarray(rng.normal(size=(p, t, d)), jnp.float32)
    ids = jnp.full((c,), -1, jnp.int32)
    owners = jnp.full((c,), -1, jnp.int32)  # NULL slots own nothing
    pool_ids = jnp.zeros((p, t), jnp.int32)
    live = jnp.ones((p, t), jnp.uint8)
    probe = jnp.asarray(rng.integers(0, 4, size=(q, 3)), jnp.int32)
    d_out, i_out = ivf_block_topk(
        queries, pool, ids, owners, pool_ids, live, probe, kprime=8,
        interpret=True,
    )
    assert np.isinf(np.asarray(d_out)).all()
    assert (np.asarray(i_out) == -1).all()


@pytest.fixture(scope="module")
def fused_index():
    from repro.core import build_ivf

    rng = np.random.default_rng(3)
    corpus = rng.normal(size=(1200, 32)).astype(np.float32)
    idx = build_ivf(corpus, n_clusters=8, block_size=16, max_chain=32,
                    nprobe=4, k=10, add_batch=512)
    q = jnp.asarray(corpus[rng.integers(0, len(corpus), 6)] + 0.001)
    return corpus, idx, q


@pytest.mark.parametrize("k", [1, 10, 100])
def test_union_fused_bit_identical_to_union(fused_index, k):
    """Acceptance: (dist, id) bit-identical to search_union, k in {1,10,100}."""
    from repro.core.search import make_search_fn

    corpus, idx, q = fused_index
    d0, i0 = make_search_fn(idx.pool_cfg, nprobe=4, k=k, path="union")(
        idx.state, q
    )
    for path in ("union_fused", "union_fused_scan"):
        d, i = make_search_fn(idx.pool_cfg, nprobe=4, k=k, path=path)(
            idx.state, q
        )
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i0))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(d0))


def test_union_fused_full_probe_matches_exact_oracle(fused_index):
    """Probing every cluster, the fused path must equal brute force."""
    from repro.core.search import exact_search, make_search_fn

    corpus, idx, q = fused_index
    d, i = make_search_fn(
        idx.pool_cfg, nprobe=idx.pool_cfg.n_clusters, k=10, path="union_fused"
    )(idx.state, q)
    de, ie = exact_search(jnp.asarray(corpus), q, 10)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ie))
    np.testing.assert_allclose(np.asarray(d), np.asarray(de), rtol=1e-5,
                               atol=1e-4)


def test_union_fused_k_exceeds_live_candidates(fused_index):
    """k > vectors in the probed lists: tail must be (inf, NULL)."""
    from repro.core.search import make_search_fn

    corpus, idx, q = fused_index
    d, i = make_search_fn(idx.pool_cfg, nprobe=1, k=300, path="union_fused")(
        idx.state, q
    )
    d, i = np.asarray(d), np.asarray(i)
    assert np.isinf(d).any(), "expected padded tail past the probed list"
    assert (i[np.isinf(d)] == -1).all()
    live = ~np.isinf(d)
    assert (i[live] >= 0).all()


@pytest.mark.parametrize(
    "r,m,n,tile",
    [(4, 8, 256, 128), (2, 16, 100, 64), (1, 4, 1024, 1024), (3, 32, 77, 32)],
)
def test_pq_adc_matches_ref(r, m, n, tile):
    rng = np.random.default_rng(r * 100 + n)
    lut = jnp.asarray(rng.normal(size=(r, m, 256)) ** 2, jnp.float32)
    codes = jnp.asarray(rng.integers(0, 256, size=(r, n, m)), jnp.int32)
    got = pq_adc(lut, codes, tile_n=tile, interpret=True)
    want = ref.pq_adc_ref(lut, codes)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize(
    "b,h,kvh,dh,t,nb,dtype",
    [
        (2, 8, 2, 64, 16, 4, jnp.float32),
        (1, 4, 4, 128, 32, 2, jnp.float32),  # MHA (G=1)
        (3, 8, 1, 64, 8, 5, jnp.float32),  # MQA
        (2, 8, 2, 64, 16, 4, jnp.bfloat16),
    ],
)
def test_paged_attention_matches_ref(b, h, kvh, dh, t, nb, dtype):
    rng = np.random.default_rng(b * 10 + h)
    p = nb * b + 2
    q = jnp.asarray(rng.normal(size=(b, h, dh)), dtype)
    k_pool = jnp.asarray(rng.normal(size=(p, t, kvh, dh)), dtype)
    v_pool = jnp.asarray(rng.normal(size=(p, t, kvh, dh)), dtype)
    # each sequence owns nb blocks; random lengths, some partial, one zero
    perm = rng.permutation(p)[: b * nb].reshape(b, nb).astype(np.int32)
    lengths = rng.integers(0, nb * t + 1, size=(b,)).astype(np.int32)
    lengths[0] = 0  # empty-cache edge case
    if b > 1:
        lengths[1] = nb * t  # full
    tables = np.where(
        np.arange(nb)[None, :] * t < np.maximum(lengths, 1)[:, None], perm, -1
    ).astype(np.int32)
    got = paged_decode_attention(
        q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(lengths),
        interpret=True,
    )
    want = ref.paged_decode_attention_ref(
        q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(lengths)
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )
