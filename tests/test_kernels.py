"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ivf_scan import ivf_block_scan
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.pq_adc import pq_adc


@pytest.mark.parametrize(
    "q,d,p,t,c",
    [
        (8, 64, 16, 128, 4),
        (16, 128, 32, 256, 9),
        (8, 32, 7, 8, 7),  # odd sizes
        (1, 128, 4, 64, 2),
    ],
)
def test_ivf_block_scan_matches_ref(q, d, p, t, c):
    rng = np.random.default_rng(q * 1000 + t)
    queries = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    pool = jnp.asarray(rng.normal(size=(p, t, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(-1, p, size=(c,)), jnp.int32)
    got = ivf_block_scan(queries, pool, ids, interpret=True)
    want = ref.ivf_block_scan_ref(queries, pool, ids)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize(
    "r,m,n,tile",
    [(4, 8, 256, 128), (2, 16, 100, 64), (1, 4, 1024, 1024), (3, 32, 77, 32)],
)
def test_pq_adc_matches_ref(r, m, n, tile):
    rng = np.random.default_rng(r * 100 + n)
    lut = jnp.asarray(rng.normal(size=(r, m, 256)) ** 2, jnp.float32)
    codes = jnp.asarray(rng.integers(0, 256, size=(r, n, m)), jnp.int32)
    got = pq_adc(lut, codes, tile_n=tile, interpret=True)
    want = ref.pq_adc_ref(lut, codes)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize(
    "b,h,kvh,dh,t,nb,dtype",
    [
        (2, 8, 2, 64, 16, 4, jnp.float32),
        (1, 4, 4, 128, 32, 2, jnp.float32),  # MHA (G=1)
        (3, 8, 1, 64, 8, 5, jnp.float32),  # MQA
        (2, 8, 2, 64, 16, 4, jnp.bfloat16),
    ],
)
def test_paged_attention_matches_ref(b, h, kvh, dh, t, nb, dtype):
    rng = np.random.default_rng(b * 10 + h)
    p = nb * b + 2
    q = jnp.asarray(rng.normal(size=(b, h, dh)), dtype)
    k_pool = jnp.asarray(rng.normal(size=(p, t, kvh, dh)), dtype)
    v_pool = jnp.asarray(rng.normal(size=(p, t, kvh, dh)), dtype)
    # each sequence owns nb blocks; random lengths, some partial, one zero
    perm = rng.permutation(p)[: b * nb].reshape(b, nb).astype(np.int32)
    lengths = rng.integers(0, nb * t + 1, size=(b,)).astype(np.int32)
    lengths[0] = 0  # empty-cache edge case
    if b > 1:
        lengths[1] = nb * t  # full
    tables = np.where(
        np.arange(nb)[None, :] * t < np.maximum(lengths, 1)[:, None], perm, -1
    ).astype(np.int32)
    got = paged_decode_attention(
        q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(lengths),
        interpret=True,
    )
    want = ref.paged_decode_attention_ref(
        q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(lengths)
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )
