"""LM / recsys model behaviour: parity, training, paged serving."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.transformer import (
    LMConfig,
    decode_step,
    forward,
    init_kv_cache,
    init_lm,
    lm_loss,
    prefill,
)
from repro.serving.paged_lm import init_paged_kv, paged_decode_step
from repro.models.recsys.models import (
    RecConfig,
    apply_rec,
    init_rec,
    rec_loss,
    score_candidates,
)
from repro.optim.optimizers import OptConfig, make_optimizer

TINY = LMConfig(
    name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
    d_ff=64, vocab=128, qk_norm=True, qkv_bias=True, attn_chunk=8,
    dtype=jnp.float32,
)
# capacity_factor = n_experts => capacity can never truncate, so MoE decode
# is exactly parity-testable against forward (drops are a lossy serving
# approximation by design; drop accounting is covered in test_moe_dispatch).
TINY_MOE = dataclasses.replace(
    TINY, name="tiny_moe", moe=True, n_experts=8, top_k=2, d_ff_expert=32,
    d_ff=0, capacity_factor=8.0,
)


def test_moe_dispatch_capacity_accounting():
    from repro.models.moe import MoEConfig, init_moe, moe_apply

    cfg = MoEConfig(d_model=16, n_experts=4, top_k=2, d_ff_expert=8,
                    capacity_factor=0.5)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    out, aux = moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert float(aux["drop_frac"]) > 0.0  # tight capacity must drop
    assert float(aux["aux_loss"]) >= 1.0  # >= 1 by Cauchy-Schwarz


@pytest.fixture(scope="module", params=["dense", "moe"])
def lm(request):
    cfg = TINY if request.param == "dense" else TINY_MOE
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def test_lm_forward_shapes_finite(lm):
    cfg, params = lm
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    logits, aux = forward(params, cfg, toks)
    assert logits.shape == (2, 12, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_lm_decode_matches_forward(lm):
    cfg, params = lm
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0, cfg.vocab)
    cache = init_kv_cache(cfg, 2, 16)
    lg, cache = prefill(params, cfg, toks, cache)
    fl, _ = forward(params, cfg, toks)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(fl[:, -1]), rtol=3e-4, atol=3e-4)
    nxt = jnp.argmax(lg, -1)
    lg2, _ = decode_step(params, cfg, nxt, cache, jnp.int32(9))
    seq = jnp.concatenate([toks, nxt[:, None]], 1)
    fl2, _ = forward(params, cfg, seq)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(fl2[:, -1]), rtol=5e-4, atol=5e-4)


def test_lm_paged_decode_matches_forward(lm):
    cfg, params = lm
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 1), 0, cfg.vocab)[:, 0]
    pst = init_paged_kv(cfg, 2, n_blocks=16, block_size=4, max_blocks_per_seq=6)
    seq = toks[:, None]
    lg, pst = paged_decode_step(params, cfg, toks, pst)
    for _ in range(7):  # crosses block boundaries
        nxt = jnp.argmax(lg, -1)
        seq = jnp.concatenate([seq, nxt[:, None]], 1)
        lg, pst = paged_decode_step(params, cfg, nxt, pst)
        fl, _ = forward(params, cfg, seq)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(fl[:, -1]), rtol=2e-3, atol=2e-3
        )


@pytest.mark.parametrize("opt_kind", ["adamw", "adafactor", "adam8bit"])
def test_lm_training_reduces_loss(opt_kind):
    cfg = TINY
    params = init_lm(jax.random.PRNGKey(0), cfg)
    init, update = make_optimizer(OptConfig(kind=opt_kind, lr=3e-3))
    opt = init(params)
    toks = jax.random.randint(jax.random.PRNGKey(4), (4, 16), 0, cfg.vocab)

    @jax.jit
    def step(params, opt):
        (loss, _), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, toks, toks), has_aux=True
        )(params)
        params, opt = update(grads, opt, params)
        return params, opt, loss

    losses = []
    for _ in range(12):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.15, losses


REC_CFGS = [
    RecConfig(
        name="dlrm_t", kind="dlrm", n_dense=4, vocab_sizes=(50,) * 6,
        embed_dim=8, bot_mlp=(16, 8), top_mlp=(32, 16, 1),
    ),
    RecConfig(
        name="dcn_t", kind="dcn_v2", n_dense=4, vocab_sizes=(50,) * 6,
        embed_dim=8, mlp_sizes=(32, 16), n_cross_layers=2,
    ),
    RecConfig(
        name="wd_t", kind="wide_deep", n_dense=0, vocab_sizes=(50,) * 8,
        embed_dim=8, mlp_sizes=(32, 16),
    ),
    RecConfig(
        name="dien_t", kind="dien", n_dense=0, vocab_sizes=(100, 20, 20),
        embed_dim=8, mlp_sizes=(32, 16), seq_len=12, gru_dim=16,
    ),
]


@pytest.mark.parametrize("cfg", REC_CFGS, ids=lambda c: c.kind)
def test_recsys_forward_and_train(cfg):
    rng = np.random.default_rng(0)
    params = init_rec(jax.random.PRNGKey(0), cfg)
    b = 32
    batch = {
        "dense": jnp.asarray(rng.normal(size=(b, max(cfg.n_dense, 1))), jnp.float32)[
            :, : cfg.n_dense
        ],
        "sparse": jnp.asarray(
            rng.integers(0, 50, size=(b, cfg.n_sparse)) % np.asarray(cfg.vocab_sizes),
            jnp.int32,
        ),
        "label": jnp.asarray(rng.random(b) < 0.3, jnp.float32),
    }
    if cfg.kind == "dien":
        batch["history"] = jnp.asarray(
            rng.integers(0, cfg.vocab_sizes[0], size=(b, cfg.seq_len)), jnp.int32
        )
    logits = apply_rec(params, cfg, batch)
    assert logits.shape == (b,)
    assert np.isfinite(np.asarray(logits)).all()

    init, update = make_optimizer(OptConfig(kind="adamw", lr=1e-2))
    opt = init(params)

    @jax.jit
    def step(params, opt):
        (loss, _), grads = jax.value_and_grad(
            lambda p: rec_loss(p, cfg, batch), has_aux=True
        )(params)
        params, opt = update(grads, opt, params)
        return params, opt, loss

    losses = [float(step(params, opt)[2])]
    for _ in range(10):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_retrieval_scoring_topk():
    cfg = REC_CFGS[0]
    params = init_rec(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    batch = {
        "dense": jnp.zeros((1, cfg.n_dense), jnp.float32),
        "sparse": jnp.asarray(rng.integers(0, 50, size=(1, cfg.n_sparse)), jnp.int32),
    }
    cand = jnp.asarray(rng.normal(size=(1000, cfg.embed_dim)), jnp.float32)
    scores, idx = score_candidates(params, cfg, batch, cand, k=10)
    assert idx.shape == (1, 10)
    # scores sorted descending
    s = np.asarray(scores)[0]
    assert (np.diff(s) <= 1e-6).all()


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    cfg = TINY
    params = init_lm(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(10, params, extra={"data_cursor": 123})
    mgr.async_save(20, params)
    mgr.wait()
    assert mgr.latest_step() == 20
    restored, manifest = mgr.restore(step=10, like=params)
    assert manifest["data_cursor"] == 123
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # retention: saving a third checkpoint evicts step 10
    mgr.save(30, params)
    assert mgr.latest_step() == 30
    with pytest.raises(FileNotFoundError):
        mgr.restore(step=999, like=params)
