"""Fault-tolerant serving: deterministic fault-injection coverage.

The invariant under test everywhere: **no submitted future ever hangs** —
under injected step failures, worker-loop crashes, deadline expiry, queue
overflow, and shutdown mid-traffic, every future resolves (result or typed
exception) within a bounded wait, in every mode, and a poisoned batch
fails only the poisoned item's future.  All failure paths are driven
through ``repro.core.faults.FaultPlan`` (no timing-dependent luck).

Determinism notes: ``delay("search_loop"/"insert_loop", t, nth=0)`` puts
the worker to sleep on its *first* iteration (the fault site sits before
any dequeue), so requests submitted right after construction are
guaranteed to be queued together when the worker wakes — which makes the
batch composition, and therefore the ``search_step``/``mutation_step``
call indices, deterministic.
"""

import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeout

import numpy as np
import pytest

from repro.core import build_ivf
from repro.core.admission import (
    AdmissionGate,
    DeadlineExceeded,
    DegradationLadder,
    QueueFull,
    RequestRejected,
    RuntimeShutdown,
)
from repro.core.block_pool import snapshot_ids
from repro.core.faults import FaultError, FaultPlan
from repro.core.metrics import CounterSet
from repro.core.runtime import RuntimeConfig, ServingRuntime, _Timed

pytestmark = pytest.mark.robust

D = 16


def _data(n, d=D, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 3
    return (
        centers[rng.integers(0, 8, n)]
        + rng.normal(size=(n, d)).astype(np.float32)
    ).astype(np.float32)


@pytest.fixture(scope="module")
def base_index():
    x = _data(1200)
    return x, lambda: build_ivf(
        x, n_clusters=4, block_size=16, max_chain=64, add_batch=256,
        capacity_vectors=8000,
    )


def _resolved(fut: Future, timeout=30.0):
    """The no-hung-future assertion: resolves (result or exception) within
    a bounded wait."""
    return fut.exception(timeout=timeout)  # raises TimeoutError on a hang


# ------------------------------------------------------ poison isolation --
def test_mutation_batch_poison_fails_only_poisoned_item(base_index):
    """Call 0 = the 3-item batch, calls 1..3 = the per-item retries; fail
    the batch and the middle retry -> only item 1's future fails."""
    x, make = base_index
    plan = (FaultPlan()
            .delay("insert_loop", 0.3, nth=0)  # batch the 3 submits
            .fail("mutation_step", nth=[0, 2]))
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=64,
                      flush_interval=0.05),
        faults=plan,
    )
    try:
        futs = [rt.submit_insert(_data(4, seed=10 + i)) for i in range(3)]
        assert _resolved(futs[0]) is None and len(futs[0].result()) == 4
        assert isinstance(_resolved(futs[1]), FaultError)
        assert _resolved(futs[2]) is None and len(futs[2].result()) == 4
        s = rt.stats()
        assert s["poisoned"] == 1
        assert s["isolations"] == 1
        assert s["pending_mutations"] == 0  # admission rows all returned
    finally:
        rt.stop()


def test_search_batch_poison_fails_only_poisoned_item(base_index):
    x, make = base_index
    plan = (FaultPlan()
            .delay("search_loop", 0.3, nth=0)
            .fail("search_step", nth=[0, 2]))
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, n_slots=8),
        faults=plan,
    )
    try:
        futs = [rt.submit_search(x[i : i + 1]) for i in range(3)]
        assert _resolved(futs[0]) is None
        assert futs[0].result()[1][0, 0] == 0
        assert isinstance(_resolved(futs[1]), FaultError)
        assert _resolved(futs[2]) is None
        assert futs[2].result()[1][0, 0] == 2
        s = rt.stats()
        assert s["poisoned"] == 1 and s["isolations"] == 1
        # all slots back: a full valid burst succeeds
        good = [rt.submit_search(x[i : i + 1]) for i in range(8)]
        for i, f in enumerate(good):
            assert f.result(timeout=30)[1][0, 0] == i
    finally:
        rt.stop()


def test_fused_step_failure_decomposes_and_isolates(base_index):
    """A failed fused search+mutation program falls back to the two
    separate lanes; both sides resolve, nothing hangs."""
    x, make = base_index
    plan = (FaultPlan()
            .delay("insert_loop", 0.25, nth=0)
            .delay("search_loop", 0.35, nth=0)  # wake after insert handoff
            .fail("fused_step", nth=0))
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="fused", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02),
        faults=plan,
    )
    try:
        sf = rt.submit_search(x[:1])
        mf = rt.submit_insert(_data(4, seed=20))
        assert _resolved(sf) is None and sf.result()[1][0, 0] == 0
        assert _resolved(mf) is None and len(mf.result()) == 4
        assert rt.stats()["fused_fallbacks"] >= 1
    finally:
        rt.stop()


# ------------------------------------------------------ crash-safe workers --
@pytest.mark.parametrize("lane", ["search_loop", "insert_loop"])
def test_worker_crash_restarts_and_keeps_serving(base_index, lane):
    x, make = base_index
    plan = FaultPlan().fail(lane, nth=2)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, restart_backoff=0.01),
        faults=plan,
    )
    try:
        deadline = time.perf_counter() + 30
        while plan.calls(lane) < 4:  # crash happened and loop came back
            assert time.perf_counter() < deadline, "lane never restarted"
            time.sleep(0.01)
        assert rt.submit_search(x[:1]).result(timeout=30)[1][0, 0] == 0
        assert len(rt.submit_insert(_data(3, seed=30)).result(timeout=30)) \
            == 3
        assert rt.stats()["worker_restarts"] >= 1
    finally:
        rt.stop()


def test_restart_budget_exhausted_fails_queue_and_admission(base_index):
    """A permanently-crashing lane must terminate loudly: queued futures
    resolve with RuntimeShutdown, later submits raise — never a silent
    wedge."""
    x, make = base_index
    plan = FaultPlan().fail("insert_loop", nth=None)  # every iteration
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, max_worker_restarts=2,
                      restart_backoff=0.005),
        faults=plan,
    )
    try:
        fut = rt.submit_insert(_data(2, seed=40))
        exc = _resolved(fut, timeout=30)
        assert isinstance(exc, (RuntimeShutdown, FaultError)), exc
        deadline = time.perf_counter() + 30
        while rt.stats()["accepting"]:
            assert time.perf_counter() < deadline
            time.sleep(0.01)
        with pytest.raises(RuntimeShutdown, match="insert_loop"):
            rt.submit_insert(_data(2, seed=41))
        assert rt.stats()["worker_restarts"] == 3  # 2 restarts + final crash
    finally:
        rt.stop()


# --------------------------------------------------- deadlines & shedding --
def test_expired_search_shed_with_deadline_exceeded(base_index):
    x, make = base_index
    n_slots = 4
    plan = FaultPlan().delay("search_loop", 0.3, nth=0)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, n_slots=n_slots),
        faults=plan,
    )
    try:
        doomed = rt.submit_search(x[:1], deadline=0.05)
        fine = rt.submit_search(x[1:2])  # no deadline: dispatched late, fine
        assert isinstance(_resolved(doomed), DeadlineExceeded)
        assert _resolved(fine) is None and fine.result()[1][0, 0] == 1
        assert rt.stats()["shed_search"] == 1
        # the shed request's slot came back
        burst = [rt.submit_search(x[i : i + 1]) for i in range(n_slots)]
        for i, f in enumerate(burst):
            assert f.result(timeout=30)[1][0, 0] == i
    finally:
        rt.stop()


@pytest.mark.parametrize("mode", ["serial", "parallel", "fused"])
def test_expired_mutation_shed_and_gate_released(base_index, mode):
    x, make = base_index
    lane = "search_loop" if mode == "serial" else "insert_loop"
    plan = FaultPlan().delay(lane, 0.3, nth=0)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode=mode, nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, max_pending_mutations=64),
        faults=plan,
    )
    try:
        doomed = rt.submit_insert(_data(4, seed=50), deadline=0.05)
        assert isinstance(_resolved(doomed), DeadlineExceeded)
        deadline = time.perf_counter() + 30
        while rt.stats()["pending_mutations"] != 0:  # admission rows back
            assert time.perf_counter() < deadline
            time.sleep(0.01)
        assert rt.stats()["shed_mutation"] == 1
        ok = rt.submit_insert(_data(4, seed=51))
        assert len(ok.result(timeout=30)) == 4
    finally:
        rt.stop()


def test_default_deadline_config_applies(base_index):
    x, make = base_index
    plan = FaultPlan().delay("search_loop", 0.3, nth=0)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5,
                      default_deadline=0.05),
        faults=plan,
    )
    try:
        doomed = rt.submit_search(x[:1])  # inherits the config deadline
        assert isinstance(_resolved(doomed), DeadlineExceeded)
    finally:
        rt.stop()


# ------------------------------------------------------- admission control --
def test_mutation_queue_overflow_rejects(base_index):
    x, make = base_index
    plan = FaultPlan().delay("insert_loop", 0.5, nth=None)  # slow lane
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, max_pending_mutations=8,
                      admission="reject"),
        faults=plan,
    )
    try:
        f1 = rt.submit_insert(_data(4, seed=60))
        f2 = rt.submit_insert(_data(4, seed=61))
        with pytest.raises(QueueFull):
            rt.submit_insert(_data(1, seed=62))
        s = rt.stats()
        assert s["rejected_mutation"] == 1
        assert s["pending_mutations"] == 8
        for f in (f1, f2):  # admitted work still completes
            assert len(f.result(timeout=30)) == 4
    finally:
        rt.stop()


def test_mutation_queue_overflow_block_policy(base_index):
    """``block`` admission waits (bounded) for capacity: the blocked submit
    succeeds once the lane drains, or raises QueueFull at the timeout."""
    x, make = base_index
    plan = FaultPlan().delay("insert_loop", 0.2, nth=0)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02, max_pending_mutations=8,
                      admission="block", admission_timeout=10.0),
        faults=plan,
    )
    try:
        rt.submit_insert(_data(8, seed=63))  # fills the budget
        t0 = time.perf_counter()
        fut = rt.submit_insert(_data(4, seed=64))  # blocks until drain
        assert time.perf_counter() - t0 > 0.05  # actually waited
        assert len(fut.result(timeout=30)) == 4
    finally:
        rt.stop()

    # timeout flavour: capacity never frees -> QueueFull after the wait
    plan = FaultPlan().delay("insert_loop", 5.0, nth=None)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      max_pending_mutations=8, admission="block",
                      admission_timeout=0.1),
        faults=plan,
    )
    try:
        rt.submit_insert(_data(8, seed=65))
        t0 = time.perf_counter()
        with pytest.raises(QueueFull):
            rt.submit_insert(_data(4, seed=66))
        assert time.perf_counter() - t0 >= 0.09
        assert rt.stats()["rejected_mutation"] == 1
    finally:
        rt.stop(drain=False)


def test_oversized_item_admitted_alone():
    """A single request larger than the whole budget is admitted when the
    gate is empty (never-split-an-item) instead of deadlocking."""
    gate = AdmissionGate(8, "reject")
    gate.acquire(20)  # oversized, gate empty: admitted
    with pytest.raises(QueueFull):
        gate.acquire(1)
    gate.release(20)
    gate.acquire(8)
    with pytest.raises(QueueFull):
        gate.acquire(20)  # oversized but gate non-empty
    gate.release(8)
    assert gate.pending() == 0


# ------------------------------------------------------ graceful shutdown --
@pytest.mark.parametrize("mode", ["serial", "parallel", "fused"])
def test_stop_drains_queued_mutations_and_fails_searches(base_index, mode):
    """Regression: stop() used to abandon queued items (serial-mode
    pending, fused hand-offs, anything in the queues) — their futures hung
    forever.  Now queued mutations are flushed and queued searches fail
    with RuntimeShutdown, in every mode."""
    x, make = base_index
    lane = "search_loop" if mode == "serial" else "insert_loop"
    plan = (FaultPlan()
            .delay(lane, 0.4, nth=0)
            .delay("search_loop", 0.4, nth=0))
    idx = make()
    before = idx.ntotal
    rt = ServingRuntime(
        idx,
        RuntimeConfig(mode=mode, nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02),
        faults=plan,
    )
    try:
        m1 = rt.submit_insert(_data(4, seed=70))
        m2 = rt.submit_delete(np.arange(3, dtype=np.int32))
        s1 = rt.submit_search(x[:1])
    finally:
        rt.stop()
    assert _resolved(m1) is None and len(m1.result()) == 4  # flushed
    assert _resolved(m2) is None and len(m2.result()) == 3
    # the search either dispatched before stop (result) or was failed with
    # RuntimeShutdown — but it must have resolved either way
    s_exc = _resolved(s1)
    assert s_exc is None or isinstance(s_exc, RuntimeShutdown)
    assert rt.index.ntotal == before + 4 - 3
    with pytest.raises(RuntimeShutdown):
        rt.submit_search(x[:1])
    with pytest.raises(RuntimeShutdown):
        rt.submit_insert(_data(2, seed=71))


def test_stop_serial_mode_flushes_instance_pending(base_index):
    """Serial-mode items pulled into the pending list (but below
    flush_min) used to be loop-locals lost at stop; they now flush."""
    x, make = base_index
    idx = make()
    before = idx.ntotal
    rt = ServingRuntime(
        idx,
        RuntimeConfig(mode="serial", nprobe=4, k=5, flush_min=10_000,
                      flush_interval=60.0),
    )
    try:
        fut = rt.submit_insert(_data(4, seed=72))
        deadline = time.perf_counter() + 30
        while not rt._serial_pending:  # pulled off the queue, not flushed
            assert time.perf_counter() < deadline
            time.sleep(0.005)
    finally:
        rt.stop()
    assert _resolved(fut) is None and len(fut.result()) == 4
    assert rt.index.ntotal == before + 4


def test_stop_without_drain_fails_mutations(base_index):
    x, make = base_index
    plan = FaultPlan().delay("insert_loop", 0.4, nth=0)
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02),
        faults=plan,
    )
    fut = rt.submit_insert(_data(4, seed=73))
    rt.stop(drain=False)
    assert isinstance(_resolved(fut), RuntimeShutdown)
    assert rt.stats()["pending_mutations"] == 0  # gate rows returned


# ------------------------------------------------- fused / ordering corners --
def test_fused_standalone_mutation_path(base_index):
    """Fused mode with NO paired search: the hand-off batch drains through
    the standalone-mutation path and resolves (previously untested)."""
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="fused", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.02),
    )
    try:
        ins = rt.submit_insert(_data(4, seed=80))
        ids = ins.result(timeout=30)
        assert len(ids) == 4
        dele = rt.submit_delete(ids[:2])
        assert len(dele.result(timeout=30)) == 2
        assert rt.stats()["deletes"] == 2
    finally:
        rt.stop()


def test_split_flush_kind_switch_ordering():
    """Unit: a kind switch ends the batch (same-kind runs dispatch as one
    step, arrival order across kinds preserved), flush_max bounds rows,
    and the remainder is never dropped."""
    rt = ServingRuntime.__new__(ServingRuntime)  # no threads needed
    rt.cfg = RuntimeConfig(flush_max=8)

    def item(kind, rows, tag):
        payload = {
            "insert": np.zeros((rows, 4), np.float32),
            "delete": np.zeros((rows,), np.int32),
            "update": (np.zeros((rows, 4), np.float32),
                       np.zeros((rows,), np.int32)),
        }[kind]
        t = _Timed(Future(), 0.0, payload, kind=kind)
        t.tag = tag
        return t

    items = [item("insert", 3, 0), item("insert", 3, 1), item("delete", 2, 2),
             item("delete", 1, 3), item("insert", 2, 4), item("update", 1, 5)]
    runs = []
    while items:
        take, items = rt._split_flush(items)
        runs.append((take[0].kind, [t.tag for t in take]))
    assert runs == [
        ("insert", [0, 1]),   # same-kind run batched together
        ("delete", [2, 3]),   # kind switch ended the previous batch
        ("insert", [4]),      # arrival order across kinds preserved
        ("update", [5]),
    ]
    # flush_max: whole-item prefix within the cap, remainder kept
    items = [item("insert", 6, 0), item("insert", 6, 1), item("insert", 6, 2)]
    take, rest = rt._split_flush(items)
    assert [t.tag for t in take] == [0] and [t.tag for t in rest] == [1, 2]


def test_mixed_kind_arrival_order_never_reorders(base_index):
    """update-then-delete of one id, batched into a single drain, must
    leave the id dead (reversing the runs would resurrect it)."""
    x, make = base_index
    plan = FaultPlan().delay("insert_loop", 0.3, nth=0)  # batch both
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=64,
                      flush_interval=0.05),
        faults=plan,
    )
    try:
        victim = np.asarray([7], np.int32)
        u = rt.submit_update(_data(1, seed=90) * 0.5, victim)
        d = rt.submit_delete(victim)
        assert _resolved(u) is None and _resolved(d) is None
        live = {i for ids in
                snapshot_ids(rt.index.state, rt.pool_cfg).values()
                for i in ids}
        assert 7 not in live
    finally:
        rt.stop()


# ------------------------------------------------------ degradation ladder --
def test_ladder_unit_hysteresis_and_params():
    lad = DegradationLadder(("no_rerank", "half_nprobe", "half_budget"),
                            high_s=0.1, low_s=0.02, patience=2)
    assert lad.level == 0 and lad.rung == "full"
    lad.observe(0.5)
    assert lad.level == 0  # patience not yet reached
    lad.observe(0.5)
    assert lad.level == 1 and lad.rung == "no_rerank"
    for _ in range(4):
        lad.observe(0.5)
    assert lad.level == 3  # bottom rung, clamped
    lad.observe(0.5)
    assert lad.level == 3
    # cumulative params at the bottom: no rerank, nprobe/2, budget/2
    assert lad.apply(16, True, 32) == (8, False, 16)
    assert lad.apply(16, True, 32, level=1) == (16, False, 32)
    # recovery needs `patience` consecutive cool observations
    lad.observe(0.01)
    lad.observe(0.5)  # pressure back: resets the cool streak
    assert lad.level == 3
    for _ in range(2 * 2):  # patience * two step-ups
        lad.observe(0.01)
    assert lad.level == 1
    lad.observe(0.05)  # inside the hysteresis band: no movement
    assert lad.level == 1
    assert lad.transitions == 5
    with pytest.raises(ValueError, match="unknown degradation rungs"):
        DegradationLadder(("half_recall",))


def test_ladder_e2e_steps_down_and_recovers(base_index):
    """Queue-age pressure steps the runtime down the ladder; clearing it
    steps back up.  Degraded dispatches reuse cached jit steps — at most
    one compile per (bucket, rung), never one per request."""
    x, make = base_index
    plan = FaultPlan().delay("search_step", 0.08, nth=range(8))  # slow svc
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, n_slots=32,
                      max_search_batch=1,
                      degradation_ladder=("no_rerank", "half_nprobe"),
                      overload_high=0.05, overload_low=0.01,
                      overload_patience=2),
        faults=plan,
    )
    try:
        futs = [rt.submit_search(x[i : i + 1]) for i in range(10)]
        for f in futs:
            assert _resolved(f) is None  # degraded, never failed
        s = rt.stats()
        assert s["degradation_level"] >= 1, s["degradation_rung"]
        assert s["degradation_transitions"] >= 1
        # pressure cleared: a slow trickle steps back up to full service
        deadline = time.perf_counter() + 30
        while rt.stats()["degradation_level"] > 0:
            assert time.perf_counter() < deadline, "never recovered"
            rt.submit_search(x[:1]).result(timeout=30)
        assert rt.stats()["degradation_rung"] == "full"
        # bounded compile count: base rung + at most one per ladder rung
        assert len(rt._search_steps) <= 3
    finally:
        rt.stop()


# ---------------------------------------------------------- counters etc. --
def test_counter_set_is_thread_safe():
    c = CounterSet()

    def bump():
        for _ in range(10_000):
            c.inc("x")

    ts = [threading.Thread(target=bump) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c["x"] == 80_000
    assert c.snapshot() == {"x": 80_000}


def test_fault_plan_counts_and_resets():
    # sites are registered now; test-private ones use the escape hatch
    plan = FaultPlan(extra_sites=("s",)).fail("s", nth=1).delay("s", 0.0, nth=0)
    plan.check("s")  # call 0: delay only
    with pytest.raises(FaultError):
        plan.check("s")  # call 1: fail
    plan.check("s")  # call 2: nothing
    assert plan.calls("s") == 3
    plan.reset()
    assert plan.calls("s") == 0
    plan.check("s")  # no rules left


# ------------------------------------------------ the headline invariant --
@pytest.mark.parametrize("mode", ["serial", "parallel", "fused"])
def test_no_hung_future_under_combined_faults(base_index, mode):
    """The acceptance bar: step failures + a worker crash + deadline expiry
    + queue overflow + shutdown mid-traffic, all at once, in every mode —
    every accepted future resolves (result or typed exception) within a
    bounded wait."""
    x, make = base_index
    plan = (FaultPlan()
            .fail("search_step", nth=[1, 4])
            .fail("mutation_step", nth=[1, 3])
            .fail("fused_step", nth=0)
            .fail("insert_loop" if mode != "serial" else "search_loop",
                  nth=3))
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode=mode, nprobe=4, k=5, flush_min=4,
                      flush_interval=0.02, n_slots=8,
                      max_pending_mutations=64, restart_backoff=0.01,
                      degradation_ladder=("no_rerank",)),
        faults=plan,
    )
    futures: list[Future] = []
    rejected = 0
    try:
        rng = np.random.default_rng(3)
        for i in range(40):
            kind = i % 4
            try:
                if kind == 0:
                    futures.append(rt.submit_search(
                        x[i % len(x) : i % len(x) + 1],
                        deadline=0.001 if i % 8 == 0 else None,
                    ))
                elif kind == 1:
                    futures.append(rt.submit_insert(_data(3, seed=100 + i)))
                elif kind == 2:
                    futures.append(rt.submit_delete(
                        rng.integers(0, 1000, 2).astype(np.int32)
                    ))
                else:
                    ids = rng.integers(0, 1000, 2).astype(np.int32)
                    futures.append(rt.submit_update(_data(2, seed=i), ids))
            except (RequestRejected, RuntimeShutdown):
                rejected += 1
            if i == 25:
                time.sleep(0.05)
    finally:
        rt.stop()  # mid-traffic shutdown: drains mutations, fails searches
    hung = []
    for i, f in enumerate(futures):
        try:
            exc = f.exception(timeout=30)
        except (TimeoutError, FutureTimeout):  # 3.10: distinct classes
            hung.append(i)
            continue
        if exc is not None:
            assert isinstance(
                exc,
                (FaultError, DeadlineExceeded, RuntimeShutdown, QueueFull),
            ), (i, exc)
    assert not hung, f"futures {hung} never resolved"
    assert len(futures) + rejected == 40
