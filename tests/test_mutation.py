"""Online mutation subsystem: tombstone deletes, in-place updates, and
dead-space-reclaiming compaction.

Everything here is marked ``mutation`` so CI runs it as its own job slice
(mirroring ``pq``/``quant``); tier-1 excludes it.  The acceptance contract:

* after interleaved insert/delete/update + at least one compaction, search
  results across every fused dtype x rerank contain no deleted id, agree
  with the pure-JAX ref oracle, and recall@10 at 30% deletions is within
  0.5% of an index rebuilt from only the live vectors;
* ``check_invariants`` validates live-mask <-> id-map <-> chain consistency
  in both directions after every mutation kind;
* the serving runtime's mutation stream (submit_delete / submit_update)
  applies batched, ordered, and counted.
"""

import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import build_ivf
from repro.core.block_pool import (
    PoolConfig,
    check_invariants,
    dead_fraction,
    init_state,
    pool_stats,
    snapshot_ids,
    utilisation,
)
from repro.core.insert import make_insert_fn
from repro.core.metrics import recall_at_k
from repro.core.mutate import make_delete_fn, make_update_fn
from repro.core.rearrange import make_rearrange_fn
from repro.core.search import exact_search, make_search_fn, search_union_fused

pytestmark = pytest.mark.mutation


def _clustered(n, d, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 3
    return (
        centers[rng.integers(0, 8, n)]
        + rng.normal(size=(n, d)).astype(np.float32)
    ).astype(np.float32)


# ---------------------------------------------------------------------------
# delete / update primitives
# ---------------------------------------------------------------------------


def _small_state(dtype="float32", seed=1, n=60):
    d, tm = 8, 4
    cfg = PoolConfig(n_clusters=3, dim=d, block_size=tm, n_blocks=64,
                     max_chain=16, dtype=dtype)
    rng = np.random.default_rng(seed)
    cents = rng.normal(size=(3, d)).astype(np.float32) * 3
    state = init_state(cfg, jnp.asarray(cents))
    ins = make_insert_fn(cfg)
    x = (cents[rng.integers(0, 3, n)]
         + rng.normal(size=(n, d)).astype(np.float32))
    state = ins(state, jnp.asarray(x), jnp.arange(n, dtype=jnp.int32))
    return cfg, state, x


def test_delete_tombstones_and_counts():
    cfg, state, x = _small_state()
    delete = make_delete_fn(cfg)
    targets = np.asarray([3, 17, 44, 9], np.int32)
    state = delete(state, jnp.asarray(targets))
    check_invariants(state, cfg)
    assert int(state.num_deleted) == 4
    assert int(state.num_vectors) == 60 - 4
    assert int(state.dead_count.sum()) == 4
    live = sorted(i for ids in snapshot_ids(state, cfg).values() for i in ids)
    assert live == sorted(set(range(60)) - set(targets.tolist()))
    # chain slots are untouched — only the live bit flipped
    assert int(state.cluster_len.sum()) == 60


def test_delete_misses_and_duplicates_counted():
    cfg, state, x = _small_state()
    delete = make_delete_fn(cfg)
    # 7 twice in one batch (one hit + one miss), 999 never inserted (miss),
    # and a second batch re-deleting 7 (miss)
    state = delete(state, jnp.asarray([7, 999, 7], jnp.int32))
    check_invariants(state, cfg)
    assert int(state.num_deleted) == 1
    assert int(state.num_missed) == 2
    state = delete(state, jnp.asarray([7], jnp.int32))
    check_invariants(state, cfg)
    assert int(state.num_deleted) == 1
    assert int(state.num_missed) == 3
    assert int(state.num_vectors) == 59


def test_delete_respects_validity_mask():
    cfg, state, x = _small_state()
    delete = make_delete_fn(cfg)
    ids = jnp.asarray([5, 6, 7, 8], jnp.int32)
    valid = jnp.asarray([True, False, True, False])
    state = delete(state, ids, valid)
    check_invariants(state, cfg)
    live = {i for ids_ in snapshot_ids(state, cfg).values() for i in ids_}
    assert 5 not in live and 7 not in live
    assert 6 in live and 8 in live
    assert int(state.num_missed) == 0  # masked rows are not misses


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_update_moves_vector_between_clusters(dtype):
    cfg, state, x = _small_state(dtype=dtype)
    update = make_update_fn(cfg)
    search = make_search_fn(cfg, nprobe=3, k=1, path="union_fused_scan")
    # replace id 11 with a vector near a *different* centroid
    cents = np.asarray(state.centroids)
    old_cluster = int(np.argmin(np.sum((cents - x[11]) ** 2, axis=1)))
    new_cluster = (old_cluster + 1) % 3
    new_v = (cents[new_cluster] + 0.01).astype(np.float32)[None]
    state = update(state, jnp.asarray(new_v), jnp.asarray([11], jnp.int32))
    check_invariants(state, cfg)
    assert int(state.num_vectors) == 60  # net zero: tombstone + insert
    assert int(state.dead_count.sum()) == 1  # the stale copy
    d, i = search(state, jnp.asarray(new_v))
    assert int(np.asarray(i)[0, 0]) == 11  # same id, fresh vector
    # searching near the old vector no longer returns 11
    d, i = search(state, jnp.asarray(x[11][None]))
    assert int(np.asarray(i)[0, 0]) != 11 or np.allclose(x[11], new_v[0])


def test_update_unknown_id_is_upsert():
    cfg, state, x = _small_state()
    update = make_update_fn(cfg)
    v = np.full((1, 8), 9.0, np.float32)
    state = update(state, jnp.asarray(v), jnp.asarray([500], jnp.int32))
    check_invariants(state, cfg)
    assert int(state.num_vectors) == 61
    assert int(state.num_missed) == 1  # the tombstone pass found nothing
    live = {i for ids_ in snapshot_ids(state, cfg).values() for i in ids_}
    assert 500 in live


def test_unmappable_id_insert_then_delete_misses():
    """Ids past max_ids stay resident and searchable but cannot be mutated
    (documented map-capacity contract)."""
    d, tm = 8, 4
    cfg = PoolConfig(n_clusters=2, dim=d, block_size=tm, n_blocks=8,
                     max_chain=4, max_ids=16)
    rng = np.random.default_rng(3)
    cents = rng.normal(size=(2, d)).astype(np.float32)
    state = init_state(cfg, jnp.asarray(cents))
    ins = make_insert_fn(cfg)
    state = ins(state, jnp.asarray(rng.normal(size=(2, d)), jnp.float32),
                jnp.asarray([3, 99], jnp.int32))  # 99 >= max_ids
    check_invariants(state, cfg)
    delete = make_delete_fn(cfg)
    state = delete(state, jnp.asarray([99], jnp.int32))
    check_invariants(state, cfg)
    assert int(state.num_deleted) == 0
    assert int(state.num_missed) == 1
    live = {i for ids_ in snapshot_ids(state, cfg).values() for i in ids_}
    assert 99 in live  # still resident


def test_update_duplicate_ids_last_write_wins():
    """Regression: update([7, 7]) used to re-insert two live rows under one
    id — the unmapped copy was undeletable forever.  Duplicates within a
    batch now collapse to the last write."""
    cfg, state, x = _small_state()
    update = make_update_fn(cfg)
    v_first = np.full((1, 8), 2.0, np.float32)
    v_last = np.full((1, 8), -2.0, np.float32)
    batch = np.concatenate([v_first, v_last])
    state = update(state, jnp.asarray(batch),
                   jnp.asarray([7, 7], jnp.int32))
    check_invariants(state, cfg)
    assert int(state.num_vectors) == 60  # exactly one live copy of id 7
    s = jax.device_get(state)
    loc = int(s.id_map[7])
    b, t = loc // cfg.block_size, loc % cfg.block_size
    np.testing.assert_allclose(s.pool_payload[b, t], v_last[0], atol=1e-5)
    # and the single copy is still deletable
    delete = make_delete_fn(cfg)
    state = delete(state, jnp.asarray([7], jnp.int32))
    check_invariants(state, cfg)
    live = {i for ids_ in snapshot_ids(state, cfg).values() for i in ids_}
    assert 7 not in live


def test_unmapped_inserts_counted():
    """Ids past max_ids can never be mutated; the gauge makes the overflow
    loud instead of letting deletes silently start missing."""
    d, tm = 8, 4
    cfg = PoolConfig(n_clusters=2, dim=d, block_size=tm, n_blocks=16,
                     max_chain=8, max_ids=8)
    rng = np.random.default_rng(9)
    state = init_state(cfg, jnp.asarray(
        rng.normal(size=(2, d)).astype(np.float32)))
    ins = make_insert_fn(cfg)
    state = ins(state, jnp.asarray(rng.normal(size=(4, d)), jnp.float32),
                jnp.asarray([1, 2, 20, 21], jnp.int32))
    check_invariants(state, cfg)
    assert int(state.num_unmapped) == 2
    assert pool_stats(state, cfg)["num_unmapped"] == 2


# ---------------------------------------------------------------------------
# compaction = reclamation
# ---------------------------------------------------------------------------


def test_compaction_drops_tombstones_and_reclaims_blocks():
    cfg, state, x = _small_state(n=60)
    delete = make_delete_fn(cfg)
    rearr = make_rearrange_fn(cfg, threshold=10**9, dead_frac=0.2)
    rng = np.random.default_rng(4)
    targets = rng.choice(60, 30, replace=False).astype(np.int32)
    state = delete(state, jnp.asarray(targets))
    check_invariants(state, cfg)
    used_before = int(state.cur_p) - int(state.free_top)
    # loop the maintenance step until quiescent (dead-fraction trigger only:
    # the insert-statistic threshold is set unreachable)
    passes = 0
    for _ in range(8):
        state, triggered = rearr(state)
        if not bool(triggered):
            break
        passes += 1
        check_invariants(state, cfg)
    assert passes >= 1
    assert int(state.dead_count.sum()) == 0
    assert int(state.cluster_len.sum()) == 30  # live rows only
    used_after = int(state.cur_p) - int(state.free_top)
    assert used_after < used_before  # dead space returned to the free stack
    live = sorted(i for ids_ in snapshot_ids(state, cfg).values()
                  for i in ids_)
    assert live == sorted(set(range(60)) - set(targets.tolist()))


def test_fully_dead_cluster_frees_every_block():
    cfg, state, x = _small_state(n=60)
    delete = make_delete_fn(cfg)
    rearr = make_rearrange_fn(cfg, threshold=10**9, dead_frac=0.1)
    sn = snapshot_ids(state, cfg)
    k = max(sn, key=lambda c: len(sn[c]))
    state = delete(state, jnp.asarray(sn[k], jnp.int32))
    for _ in range(8):
        state, triggered = rearr(state)
        if not bool(triggered):
            break
        check_invariants(state, cfg)
    s = jax.device_get(state)
    assert int(s.cluster_len[k]) == 0
    assert int(s.cluster_nblocks[k]) == 0
    assert int(s.cluster_head[k]) == -1 and int(s.cluster_tail[k]) == -1
    # its blocks all landed on the free stack and are reusable
    ins = make_insert_fn(cfg)
    cents = np.asarray(state.centroids)
    refill = (cents[k] + 0.01 * np.arange(8)[:, None]).astype(np.float32)
    state = ins(state, jnp.asarray(refill),
                jnp.arange(200, 208, dtype=jnp.int32))
    check_invariants(state, cfg)


def test_compaction_survives_bump_exhaustion():
    """Regression: the bump pointer is monotone, so bump-only compaction
    shut reclamation off permanently once cur_p neared the pool end.  The
    free-stack fallback keeps reclaiming (non-contiguous run) forever."""
    d, tm = 8, 4
    cfg = PoolConfig(n_clusters=2, dim=d, block_size=tm, n_blocks=24,
                     max_chain=8)
    rng = np.random.default_rng(11)
    cents = np.stack([np.zeros(d), np.full(d, 10.0)]).astype(np.float32)
    state = init_state(cfg, jnp.asarray(cents))
    ins = make_insert_fn(cfg)
    delete = make_delete_fn(cfg)
    rearr = make_rearrange_fn(cfg, threshold=10**9, dead_frac=0.2)
    # churn until the bump region is exhausted, then keep churning: every
    # round deletes half a cluster and must still get its space back
    nid = 0
    for round_ in range(12):
        x = (cents[rng.integers(0, 2, 8)]
             + 0.1 * rng.normal(size=(8, d))).astype(np.float32)
        ids = np.arange(nid, nid + 8, dtype=np.int32)
        nid += 8
        state = ins(state, jnp.asarray(x), jnp.asarray(ids))
        assert int(state.num_dropped) == 0, round_  # space WAS reclaimed
        live = [i for ids_ in snapshot_ids(state, cfg).values()
                for i in ids_]
        victims = rng.choice(live, len(live) // 2, replace=False)
        state = delete(state, jnp.asarray(victims.astype(np.int32)))
        for _ in range(6):
            state, triggered = rearr(state)
            if not bool(triggered):
                break
        check_invariants(state, cfg)
        assert int(state.dead_count.sum()) == 0, round_  # reclaimed
    # the bump region really was exhausted along the way (the fallback
    # engages once cur_p + chain length would overflow, so cur_p parks
    # within one chain of the pool end)
    assert int(state.cur_p) >= cfg.n_blocks - 2, int(state.cur_p)


def test_utilisation_and_dead_fraction_track_live_population():
    cfg, state, x = _small_state(n=60)
    cap = cfg.n_blocks * cfg.block_size
    assert float(utilisation(state, cfg)) == pytest.approx(60 / cap)
    assert float(dead_fraction(state)) == 0.0
    delete = make_delete_fn(cfg)
    state = delete(state, jnp.arange(15, dtype=jnp.int32))
    # live occupancy drops immediately; before the fix every allocated slot
    # still counted as occupied
    assert float(utilisation(state, cfg)) == pytest.approx(45 / cap)
    assert float(dead_fraction(state)) == pytest.approx(15 / 60)
    stats = pool_stats(state, cfg)
    assert stats["live_vectors"] == 45
    assert stats["dead_slots"] == 15
    assert stats["utilisation"] == pytest.approx(45 / cap)
    assert stats["dead_fraction"] == pytest.approx(0.25)


def test_scales_travel_with_compacted_int8_rows():
    """int8 reconstruction survives tombstone-dropping compaction (scales
    and codes move together; the id map re-points at the new slots)."""
    cfg, state, x = _small_state(dtype="int8", n=60)
    delete = make_delete_fn(cfg)
    rearr = make_rearrange_fn(cfg, threshold=10**9, dead_frac=0.1)
    rng = np.random.default_rng(5)
    targets = rng.choice(60, 20, replace=False).astype(np.int32)
    state = delete(state, jnp.asarray(targets))
    for _ in range(8):
        state, triggered = rearr(state)
        if not bool(triggered):
            break
        check_invariants(state, cfg)
    s = jax.device_get(state)
    live_ids = np.setdiff1d(np.arange(60), targets)
    for vid in live_ids:
        loc = int(s.id_map[vid])
        b, t = loc // cfg.block_size, loc % cfg.block_size
        owner = int(s.block_owner[b])
        recon = (np.asarray(s.centroids)[owner]
                 + s.pool_payload[b, t].astype(np.float32)
                 * s.pool_scales[b, t])
        err = np.abs(recon - x[vid])
        assert (err <= s.pool_scales[b, t] * 0.5 + 1e-5).all(), (vid, err.max())


# ---------------------------------------------------------------------------
# e2e acceptance: churn workload across all fused dtypes x rerank
# ---------------------------------------------------------------------------


def _churned(dtype, payload="flat", pq_m=0, seed=7):
    """Interleaved insert/delete/update + >= 1 compaction; returns
    (live corpus dict id->vector, deleted id set, index)."""
    d = 32
    x = _clustered(900, d, seed=seed)
    kw = dict(payload=payload, pq_m=pq_m) if payload == "pq" else dict(
        dtype=dtype
    )
    idx = build_ivf(
        x, n_clusters=8, block_size=16, max_chain=32, add_batch=256,
        nprobe=4, k=10, rearrange_threshold=10**9, dead_frac_threshold=0.15,
        capacity_vectors=4000, **kw,
    )
    rng = np.random.default_rng(seed + 1)
    oracle = {i: x[i] for i in range(900)}
    # grow online
    extra = _clustered(150, d, seed=seed + 2)
    ids = idx.add(extra)
    oracle.update({int(i): v for i, v in zip(ids, extra)})
    # delete 30% of everything resident
    all_ids = np.asarray(sorted(oracle), np.int32)
    dead = rng.choice(all_ids, int(0.3 * len(all_ids)), replace=False)
    n = idx.delete(dead)
    assert n == len(dead)
    for i in dead:
        del oracle[int(i)]
    # update 60 survivors in place
    upd = rng.choice(np.asarray(sorted(oracle), np.int32), 60, replace=False)
    newv = _clustered(60, d, seed=seed + 3)
    idx.update(newv, upd)
    for i, v in zip(upd, newv):
        oracle[int(i)] = v
    # reclaim (dead-fraction trigger)
    passes = idx.maybe_rearrange(max_passes=16)
    assert passes >= 1, "churn must trigger at least one compaction"
    check_invariants(idx.state, idx.pool_cfg)
    # a little more growth after compaction (recycled blocks)
    tail = _clustered(80, d, seed=seed + 4)
    ids = idx.add(tail)
    oracle.update({int(i): v for i, v in zip(ids, tail)})
    return oracle, set(int(i) for i in dead), idx


@pytest.mark.parametrize(
    "dtype,rerank",
    [
        ("float32", False),
        ("float32", True),
        ("bfloat16", False),
        ("bfloat16", True),
        ("int8", False),
        ("int8", True),
        ("pq", False),
        ("pq", True),
    ],
)
def test_churned_search_all_dtypes(dtype, rerank):
    """Acceptance: post-churn search (scan impl vs the pure-JAX jnp oracle)
    returns identical ids, never a deleted id, and every returned id is
    live."""
    if dtype == "pq":
        oracle, dead, idx = _churned(None, payload="pq", pq_m=8)
    else:
        oracle, dead, idx = _churned(dtype)
    rng = np.random.default_rng(11)
    live_ids = np.asarray(sorted(oracle), np.int32)
    q = jnp.asarray(
        np.stack([oracle[int(i)] for i in live_ids[
            rng.integers(0, len(live_ids), 8)]]) + 0.001
    )
    budget = idx._chain_budget()

    def run(scan_impl):
        return search_union_fused(
            idx.pool_cfg, idx.state, q, nprobe=4, k=10,
            scan_impl=scan_impl, chain_budget=budget, pq=idx.pq,
            rerank=rerank,
        )

    d_s, i_s = run("scan")
    d_j, i_j = run("jnp")
    np.testing.assert_array_equal(np.asarray(i_s), np.asarray(i_j))
    np.testing.assert_allclose(
        np.asarray(d_s), np.asarray(d_j), rtol=1e-5, atol=1e-5
    )
    out = np.asarray(i_s)
    found = out[out >= 0]
    assert not np.isin(found, np.asarray(sorted(dead))).any()
    assert np.isin(found, live_ids).all()


def test_churn_recall_within_half_percent_of_rebuild():
    """Acceptance: recall@10 at 30% deletions (after compaction) within
    0.5% of an index rebuilt from only the live vectors."""
    oracle, dead, idx = _churned("float32")
    live_ids = np.asarray(sorted(oracle), np.int32)
    corpus = np.stack([oracle[int(i)] for i in live_ids])
    rng = np.random.default_rng(13)
    q = corpus[rng.integers(0, len(corpus), 32)] + 0.01
    # exact oracle over the live corpus, in live-id space
    _, ie = exact_search(jnp.asarray(corpus), jnp.asarray(q), 10)
    true_ids = live_ids[np.asarray(ie)]
    d, i = idx.search(q, nprobe=8, k=10)
    r_churn = recall_at_k(i, true_ids, 10)
    rebuilt = build_ivf(
        corpus, n_clusters=8, block_size=16, max_chain=32, add_batch=256,
        nprobe=4, k=10, capacity_vectors=4000,
    )
    d2, i2 = rebuilt.search(q, nprobe=8, k=10)
    # rebuilt row j carries original id live_ids[j]
    remapped = np.where(i2 >= 0, live_ids[np.maximum(i2, 0)], -1)
    r_rebuilt = recall_at_k(remapped, true_ids, 10)
    assert abs(r_churn - r_rebuilt) <= 0.005, (r_churn, r_rebuilt)


def test_rerank_epilogue_never_resurrects_dead_rows():
    """Defense-in-depth contract of _live_locs: even if survivor locations
    pointed at tombstones, the epilogue masks them (here exercised through
    the normal pipeline: post-delete pre-compaction state, rerank on)."""
    oracle, dead, idx = _churned("int8")
    rng = np.random.default_rng(17)
    # query directly at deleted vectors — the strongest bait
    dead_l = sorted(dead)
    probe_targets = [dead_l[i] for i in
                     rng.integers(0, len(dead_l), 8)]
    # reconstruct bait queries from the original corpus positions
    x = _clustered(900, 32, seed=7)
    q = jnp.asarray(np.stack([
        x[t] if t < 900 else np.zeros(32, np.float32)
        for t in probe_targets
    ]))
    fn = make_search_fn(
        idx.pool_cfg, nprobe=8, k=10, path="union_fused_scan",
        chain_budget=idx._chain_budget(), rerank=True,
    )
    d, i = fn(idx.state, q)
    out = np.asarray(i)
    assert not np.isin(out[out >= 0], np.asarray(dead_l)).any()


# ---------------------------------------------------------------------------
# serving runtime mutation stream
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["parallel", "fused"])
def test_runtime_delete_update_stream(mode):
    from repro.core.scheduler import RuntimeConfig, ServingRuntime

    x = _clustered(600, 16, seed=21)
    idx = build_ivf(x, n_clusters=4, block_size=16, max_chain=32,
                    add_batch=256, capacity_vectors=3000,
                    rearrange_threshold=10**9, dead_frac_threshold=0.1)
    rt = ServingRuntime(
        idx,
        RuntimeConfig(mode=mode, nprobe=4, k=5, flush_min=4,
                      flush_interval=0.05, auto_compact=True),
    )
    try:
        # warm the search path
        d, ids = rt.submit_search(x[:2]).result(timeout=120)
        assert (ids[:, 0] == np.arange(2)).all()
        # delete a batch; the victim must vanish from results
        victims = np.arange(10, 20, dtype=np.int32)
        got = rt.submit_delete(victims).result(timeout=60)
        np.testing.assert_array_equal(got, victims)
        deadline = time.perf_counter() + 30
        while True:  # the lane applies asynchronously in fused mode
            d, ids = rt.submit_search(x[10:12]).result(timeout=60)
            if not np.isin(ids, victims).any():
                break
            assert time.perf_counter() < deadline
            time.sleep(0.05)
        # update: same id, new vector, retrievable at the new location
        newv = _clustered(3, 16, seed=22) + 70.0
        upd_ids = np.asarray([100, 101, 102], np.int32)
        got = rt.submit_update(newv, upd_ids).result(timeout=60)
        np.testing.assert_array_equal(got, upd_ids)
        deadline = time.perf_counter() + 30
        while True:
            d, ids = rt.submit_search(newv).result(timeout=60)
            if (ids[:, 0] == upd_ids).all():
                break
            assert time.perf_counter() < deadline
            time.sleep(0.05)
        s = rt.stats()
        assert s["deletes"] == 10
        assert s["updates"] == 3
        assert s["mutation"].n >= 2  # delete + update latency samples
        assert 0.0 <= s["dead_fraction"] <= 1.0
        assert s["live_vectors"] == 600 - 10
        check_invariants(idx.state, idx.pool_cfg)
    finally:
        rt.stop()


def test_runtime_mixed_kind_order_preserved():
    """delete(id) then insert-of-new-rows then update(id2) submitted
    back-to-back must apply in order (runs split on kind change)."""
    from repro.core.scheduler import RuntimeConfig, ServingRuntime

    x = _clustered(300, 16, seed=31)
    idx = build_ivf(x, n_clusters=4, block_size=16, max_chain=32,
                    add_batch=128, capacity_vectors=2000)
    rt = ServingRuntime(
        idx,
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=64,
                      flush_interval=0.2),
    )
    try:
        f1 = rt.submit_delete(np.asarray([5], np.int32))
        f2 = rt.submit_insert(_clustered(4, 16, seed=32) + 50.0)
        newv = _clustered(1, 16, seed=33) + 90.0
        f3 = rt.submit_update(newv, np.asarray([7], np.int32))
        for f in (f1, f2, f3):
            f.result(timeout=60)
        s = rt.stats()
        assert s["deletes"] == 1 and s["updates"] == 1 and s["inserts"] >= 4
        check_invariants(idx.state, idx.pool_cfg)
        live = {i for ids_ in snapshot_ids(idx.state, idx.pool_cfg).values()
                for i in ids_}
        assert 5 not in live and 7 in live
    finally:
        rt.stop()


def test_runtime_auto_compact_reclaims():
    from repro.core.scheduler import RuntimeConfig, ServingRuntime

    x = _clustered(600, 16, seed=41)
    idx = build_ivf(x, n_clusters=4, block_size=16, max_chain=32,
                    add_batch=256, capacity_vectors=3000,
                    rearrange_threshold=10**9, dead_frac_threshold=0.1)
    rt = ServingRuntime(
        idx,
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=4,
                      flush_interval=0.05, auto_compact=True),
    )
    try:
        rng = np.random.default_rng(42)
        victims = rng.choice(600, 200, replace=False).astype(np.int32)
        rt.submit_delete(victims).result(timeout=60)
        deadline = time.perf_counter() + 30
        while rt.stats()["compactions"] == 0:
            assert time.perf_counter() < deadline, "auto-compact never ran"
            time.sleep(0.05)
        s = rt.stats()
        assert s["dead_fraction"] < 0.1
        check_invariants(idx.state, idx.pool_cfg)
    finally:
        rt.stop()
