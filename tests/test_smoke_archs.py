"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions.  One test per assigned architecture."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.data.synthetic import click_stream, molecule_batch, random_graph
from repro.models.gnn.equiformer_v2 import equiformer_loss, init_equiformer
from repro.models.recsys.models import init_rec, rec_loss
from repro.models.transformer import init_lm, lm_loss
from repro.optim.optimizers import OptConfig, make_optimizer

LM_ARCHS = [
    "llama3-8b", "qwen3-1.7b", "qwen1.5-110b", "kimi-k2-1t-a32b",
    "llama4-maverick-400b-a17b",
]
REC_ARCHS = ["dlrm-mlperf", "dcn-v2", "wide-deep", "dien"]


def test_all_ten_archs_registered():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_train_step(arch_id):
    cfg = get_arch(arch_id).smoke_config
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    init, update = make_optimizer(OptConfig(kind="adamw", lr=1e-3))
    opt = init(params)

    @jax.jit
    def step(params, opt):
        (loss, m), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, toks, toks), has_aux=True
        )(params)
        params, opt = update(grads, opt, params)
        return params, opt, loss

    params, opt, loss = step(params, opt)
    assert np.isfinite(float(loss)), arch_id
    logits_shape = (2, 16, cfg.vocab)
    from repro.models.transformer import forward

    logits, _ = forward(params, cfg, toks)
    assert logits.shape == logits_shape
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch_id", REC_ARCHS)
def test_recsys_smoke_train_step(arch_id):
    cfg = get_arch(arch_id).smoke_config
    params = init_rec(jax.random.PRNGKey(0), cfg)
    stream = click_stream(
        16, max(cfg.n_dense, 1), cfg.vocab_sizes, seq_len=cfg.seq_len
    )
    raw = next(stream)
    batch = {
        "dense": jnp.asarray(raw["dense"][:, : cfg.n_dense]),
        "sparse": jnp.asarray(raw["sparse"]),
        "label": jnp.asarray(raw["label"]),
    }
    if cfg.kind == "dien":
        batch["history"] = jnp.asarray(raw["history"])
    loss, _ = jax.jit(lambda p: rec_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), arch_id
    grads = jax.grad(lambda p: rec_loss(p, cfg, batch)[0])(params)
    assert all(
        np.isfinite(np.asarray(g, np.float32)).all()
        for g in jax.tree.leaves(grads)
    )


def test_equiformer_smoke_full_graph():
    cfg = get_arch("equiformer-v2").smoke_config
    g = random_graph(64, 4, cfg.d_feat_in, n_classes=cfg.n_out)
    batch = dict(
        node_feat=jnp.asarray(g["node_feat"]), pos=jnp.asarray(g["pos"]),
        edge_src=jnp.asarray(g["edge_src"]), edge_dst=jnp.asarray(g["edge_dst"]),
        label=jnp.asarray(g["label"]),
    )
    loss, _ = equiformer_loss(
        init_equiformer(jax.random.PRNGKey(0), cfg), cfg, batch
    )
    assert np.isfinite(float(loss))


def test_equiformer_smoke_molecule_batch():
    import dataclasses

    cfg = dataclasses.replace(
        get_arch("equiformer-v2").smoke_config, readout="graph", n_out=1,
        d_feat_in=16,
    )
    m = molecule_batch(8, 6, 10)
    batch = {k: (jnp.asarray(v) if not np.isscalar(v) else v) for k, v in m.items()}
    loss, _ = equiformer_loss(
        init_equiformer(jax.random.PRNGKey(0), cfg), cfg, batch
    )
    assert np.isfinite(float(loss))


def test_equiformer_smoke_sampled_block():
    from repro.models.gnn.sampler import CSRGraph, sample_block
    from repro.models.gnn.equiformer_v2 import equiformer_forward

    cfg = get_arch("equiformer-v2").smoke_config
    g = random_graph(500, 8, cfg.d_feat_in)
    graph = CSRGraph.from_edges(
        g["edge_src"].astype(np.int64), g["edge_dst"].astype(np.int64), 500
    )
    rng = np.random.default_rng(0)
    block = sample_block(
        graph, np.arange(16), (4, 3), rng, max_nodes=256, max_edges=512
    )
    params = init_equiformer(jax.random.PRNGKey(0), cfg)
    out = equiformer_forward(
        params, cfg,
        jnp.asarray(g["node_feat"][block["node_ids"]]),
        jnp.asarray(g["pos"][block["node_ids"]]),
        jnp.asarray(block["edge_src"]),
        jnp.asarray(block["edge_dst"]),
    )
    assert out.shape == (256, cfg.n_out)
    assert np.isfinite(np.asarray(out)).all()
    assert block["n_edges"] > 0
