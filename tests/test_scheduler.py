"""Serving-runtime behaviour: batching, rejection, modes, consistency."""

import time

import numpy as np
import pytest

from repro.core import IVFIndex, IVFIndexConfig, build_ivf
from repro.core.faults import FaultPlan
from repro.core.scheduler import RequestRejected, RuntimeConfig, ServingRuntime


def _data(n, d, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)).astype(np.float32) * 3
    return (
        centers[rng.integers(0, 8, n)]
        + rng.normal(size=(n, d)).astype(np.float32)
    ).astype(np.float32)


@pytest.fixture(scope="module")
def base_index():
    x = _data(1500, 16)
    return x, lambda: build_ivf(
        x, n_clusters=4, block_size=16, max_chain=64, add_batch=256,
        capacity_vectors=8000,
    )


@pytest.mark.parametrize("mode", ["serial", "parallel", "fused"])
def test_modes_serve_and_insert(base_index, mode):
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode=mode, nprobe=4, k=5, flush_interval=0.05,
                      flush_min=4),
    )
    try:
        # searches return correct neighbours
        futs = [rt.submit_search(x[i : i + 1]) for i in range(6)]
        for i, f in enumerate(futs):
            d, ids = f.result(timeout=10)
            assert ids.shape == (1, 5)
            assert ids[0, 0] == i  # self-match
        # online inserts become visible
        new = _data(12, 16, seed=9) + 40.0
        ins = rt.submit_insert(new)
        new_ids = ins.result(timeout=10)
        assert len(new_ids) == 12
        time.sleep(0.1)
        f = rt.submit_search(new[:1])
        d, ids = f.result(timeout=10)
        assert ids[0, 0] == new_ids[0]
    finally:
        rt.stop()


def test_rejection_when_slots_exhausted(base_index):
    x, make = base_index
    rt = ServingRuntime(
        make(), RuntimeConfig(mode="parallel", n_slots=2, nprobe=4, k=5)
    )
    try:
        # grab both slots without letting the worker drain (burst)
        got_reject = False
        futs = []
        for _ in range(50):
            try:
                futs.append(rt.submit_search(x[:1]))
            except RequestRejected:
                got_reject = True
                break
        assert got_reject
        for f in futs:
            f.result(timeout=10)
    finally:
        rt.stop()


def test_insert_batching_respects_cap(base_index):
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", flush_min=8, flush_max=16,
                      flush_interval=0.05, nprobe=4, k=5),
    )
    try:
        futs = [rt.submit_insert(_data(4, 16, seed=100 + i)) for i in range(4)]
        for f in futs:
            f.result(timeout=10)
        assert rt.index.ntotal >= 1500  # all applied eventually
    finally:
        rt.stop()


def test_flush_max_overflow_requeued_not_dropped(base_index):
    """Batches past flush_max are requeued; every future gets exactly the
    ids of its own vectors (no silent drop, no shared full-batch ids)."""
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", flush_min=4, flush_max=8,
                      flush_interval=0.05, nprobe=4, k=5),
    )
    try:
        before = rt.index.ntotal
        sizes = [6, 6, 6, 5]  # 23 rows: forces several flush_max splits
        futs = [
            rt.submit_insert(_data(s, 16, seed=200 + i))
            for i, s in enumerate(sizes)
        ]
        got = [f.result(timeout=20) for f in futs]
        for s, ids in zip(sizes, got):
            assert len(ids) == s  # per-item ids, not the whole batch's
        all_ids = np.concatenate(got)
        assert len(np.unique(all_ids)) == sum(sizes)  # disjoint, none lost
        deadline = time.perf_counter() + 10
        while rt.index.ntotal < before + sum(sizes):
            assert time.perf_counter() < deadline, "vectors vanished"
            time.sleep(0.02)
    finally:
        rt.stop()


def test_search_path_union_fused_serves(base_index):
    """The fused streaming path plugs into the runtime end to end."""
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5,
                      search_path="union_fused"),
    )
    try:
        futs = [rt.submit_search(x[i : i + 1]) for i in range(4)]
        for i, f in enumerate(futs):
            d, ids = f.result(timeout=60)
            assert ids.shape == (1, 5)
            assert ids[0, 0] == i  # self-match
    finally:
        rt.stop()


def test_unknown_search_path_raises(base_index):
    """A typo'd path must fail at construction, not silently benchmark
    block_table (regression: the impl map used .get with a default)."""
    x, make = base_index
    with pytest.raises(ValueError, match="union_fusde"):
        ServingRuntime(make(), RuntimeConfig(search_path="union_fusde"))


@pytest.mark.parametrize("path", ["union_pallas", "union_fused_scan"])
def test_runtime_accepts_full_path_set(base_index, path):
    """Every path make_search_fn supports must be dispatchable."""
    x, make = base_index
    rt = ServingRuntime(
        make(), RuntimeConfig(mode="parallel", nprobe=4, k=5, search_path=path)
    )
    try:
        d, ids = rt.submit_search(x[:1]).result(timeout=60)
        assert ids[0, 0] == 0
    finally:
        rt.stop()


def test_chain_budget_recomputed_after_growth():
    """Regression (silent recall loss): the chain budget was frozen at
    construction, so chains grown past 2x the initial depth were truncated
    and their candidates dropped.  A runtime that inserted far past the
    initial depth must return the same ids as a freshly-built index over the
    same corpus."""
    rng = np.random.default_rng(17)
    d = 16
    x0 = _data(120, d, seed=31)  # ~4 blocks/cluster at block_size 8
    x1 = _data(2000, d, seed=32)  # grows chains ~16x
    cfg = IVFIndexConfig(
        n_clusters=4, dim=d, block_size=8, max_chain=128, nprobe=4, k=5,
        capacity_vectors=6000,
    )
    idx = IVFIndex(cfg)
    idx.train(x0)
    idx.add(x0)
    init_depth = idx._chain_budget()
    rt = ServingRuntime(
        idx,
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=4,
                      flush_interval=0.02),
    )
    try:
        chunks = [x1[i : i + 250] for i in range(0, len(x1), 250)]
        for ch in chunks:  # sequential: deterministic insertion order
            rt.submit_insert(ch).result(timeout=30)
        assert idx._chain_budget() > 2 * init_depth, "test must outgrow 2x"
        q = x1[-20:]
        d_rt, i_rt = rt.submit_search(q).result(timeout=60)
    finally:
        rt.stop()
    # oracle: same centroids (trained on x0), same insertion order
    fresh = IVFIndex(cfg)
    fresh.train(x0)
    fresh.add(x0)
    for ch in chunks:
        fresh.add(ch)
    d_f, i_f = fresh.search(q, nprobe=4, k=5)
    np.testing.assert_allclose(d_rt, d_f, rtol=1e-5, atol=1e-4)
    assert (i_rt == i_f).all()


def test_budget_buckets_pow2_and_evicts_stale_steps():
    """Dispatch-time chain budgets are power-of-two buckets (O(log
    max_chain) recompiles under steady growth, never one per increment)
    and advancing the bucket evicts the jit-cache entries keyed by the
    superseded smaller budgets (chains never shrink, so those executables
    can never be dispatched again)."""
    d = 16
    x0 = _data(60, d, seed=41)
    cfg = IVFIndexConfig(
        n_clusters=4, dim=d, block_size=8, max_chain=128, nprobe=4, k=5,
        capacity_vectors=8000,
    )
    idx = IVFIndex(cfg)
    idx.train(x0)
    idx.add(x0)
    rt = ServingRuntime(
        idx, RuntimeConfig(mode="parallel", nprobe=4, k=5)
    )
    try:
        rt.stop()  # drive budgets/caches directly, no worker races
        seen = set()
        for n in (200, 400, 800, 1600, 3200):
            idx.add(_data(n, d, seed=n))
            rt._budget = None  # what _apply_insert does after an insert
            b = rt._current_budget()
            assert b & (b - 1) == 0 or b == cfg.max_chain, b
            seen.add(b)
            rt._search_step_for(b)
            rt._fused_step_for(b)
            # only the current bucket's entries survive growth; keys carry
            # (base, effective_budget, nprobe, rerank[, kind]) so ladder
            # rungs can share the caches without thrashing eviction
            assert set(rt._search_steps) == {(b, b, 4, False)}
            assert set(rt._fused_steps) == {(b, b, 4, False, "insert")}
        assert len(seen) > 2, "test must cross several buckets"
        assert len(seen) < 8, "pow2 bucketing keeps the bucket count small"
    finally:
        rt.stop()


def test_search_failure_resolves_futures_and_releases_slots(base_index):
    """Regression (slot/future leak): an exception mid-dispatch used to
    leave every batched future unresolved and the semaphore slots acquired
    forever — after a few failures the runtime rejected all traffic.
    Malformed payloads now fail fast at submit, so the mid-step failure is
    injected deterministically instead."""
    x, make = base_index
    n_slots = 4
    plan = FaultPlan().fail("search_step", nth=range(n_slots))
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", n_slots=n_slots, nprobe=4, k=5),
        faults=plan,
    )
    try:
        # every dispatch in the first wave fails (single-item batches fail
        # outright; multi-item batches burn several call indices retrying)
        bad = [rt.submit_search(x[:1]) for _ in range(2)]
        for f in bad:
            with pytest.raises(Exception):
                f.result(timeout=30)
        # every slot must be back: a full burst of valid searches succeeds
        deadline = time.perf_counter() + 30
        while plan.calls("search_step") < n_slots:  # drain the fault window
            assert time.perf_counter() < deadline
            try:
                rt.submit_search(x[:1]).result(timeout=30)
            except Exception:
                pass
        good = [rt.submit_search(x[i : i + 1]) for i in range(n_slots)]
        for i, f in enumerate(good):
            d, ids = f.result(timeout=30)
            assert ids[0, 0] == i
    finally:
        rt.stop()


def test_insert_failure_resolves_futures(base_index):
    """A failing insert batch must fail its futures, not hang them, and the
    insert lane must keep serving afterwards (failure injected: malformed
    payloads no longer reach the worker)."""
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, flush_min=1,
                      flush_interval=0.05),
        faults=FaultPlan().fail("mutation_step", nth=0),
    )
    try:
        bad = rt.submit_insert(_data(2, 16, seed=299))
        with pytest.raises(Exception):
            bad.result(timeout=30)
        ok = rt.submit_insert(_data(4, 16, seed=300))
        assert len(ok.result(timeout=30)) == 4
    finally:
        rt.stop()


def test_malformed_payload_fails_fast_at_submit(base_index):
    """Wrong-dim / non-finite / empty payloads raise in the caller's thread
    at submit time and consume no slot — they can never fail a co-batched
    request deep in a worker."""
    x, make = base_index
    n_slots = 3
    rt = ServingRuntime(
        make(), RuntimeConfig(mode="parallel", n_slots=n_slots, nprobe=4, k=5)
    )
    try:
        for _ in range(2 * n_slots):  # more tries than slots: none consumed
            with pytest.raises(ValueError, match="dim"):
                rt.submit_search(np.zeros((1, 3), np.float32))
        with pytest.raises(ValueError, match="non-finite"):
            rt.submit_insert(np.full((2, 16), np.nan, np.float32))
        with pytest.raises(ValueError, match="empty"):
            rt.submit_insert(np.zeros((0, 16), np.float32))
        with pytest.raises(ValueError, match="not integral"):
            rt.submit_delete(np.array([1.5, 2.5]))
        with pytest.raises(ValueError, match="ids for"):
            rt.submit_update(_data(3, 16), np.array([1, 2], np.int32))
        # all slots still free; the lanes were never involved
        good = [rt.submit_search(x[i : i + 1]) for i in range(n_slots)]
        for i, f in enumerate(good):
            assert f.result(timeout=30)[1][0, 0] == i
    finally:
        rt.stop()


def test_latency_samples_bounded(base_index):
    """Regression: _search_lat/_insert_lat grew forever under sustained
    traffic; stats() now reports over a bounded sliding window."""
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5, latency_window=8),
    )
    try:
        for _ in range(3):
            futs = [rt.submit_search(x[:1]) for _ in range(7)]
            for f in futs:
                f.result(timeout=30)
        assert len(rt._search_lat) == 8  # maxlen, not 21
        assert rt.stats()["search"].n == 8
    finally:
        rt.stop()


def test_rerank_requires_fused_path(base_index):
    """rerank on a non-fused path must fail at construction."""
    x, make = base_index
    with pytest.raises(NotImplementedError, match="rerank"):
        ServingRuntime(
            make(), RuntimeConfig(search_path="block_table", rerank=True)
        )


def test_search_path_union_fused_rerank_serves(base_index):
    """The exact re-rank epilogue plugs into the runtime end to end (fp32
    payload: identical results to the plain fused path)."""
    x, make = base_index
    rt = ServingRuntime(
        make(),
        RuntimeConfig(mode="parallel", nprobe=4, k=5,
                      search_path="union_fused_scan", rerank=True),
    )
    try:
        futs = [rt.submit_search(x[i : i + 1]) for i in range(4)]
        for i, f in enumerate(futs):
            d, ids = f.result(timeout=60)
            assert ids.shape == (1, 5)
            assert ids[0, 0] == i  # self-match
    finally:
        rt.stop()


def test_stats_collected(base_index):
    x, make = base_index
    rt = ServingRuntime(make(), RuntimeConfig(mode="parallel", nprobe=4, k=5))
    try:
        futs = [rt.submit_search(x[:1]) for _ in range(5)]
        for f in futs:
            f.result(timeout=10)
        s = rt.stats()
        assert s["search"].n == 5
        assert s["search"].mean_ms > 0
    finally:
        rt.stop()
