"""Durability: WAL, crash-consistent snapshots, verified recovery
(marker: persist).

The invariant under test everywhere: after a ``kill -9`` at *any* point —
mid-append, mid-fsync, mid-snapshot-publish, mid-replay, or between any
two of those — recovery either restores a state that contains exactly the
acknowledged mutations (verified against a host-side oracle) or refuses
to serve with a named error.  Crashes are simulated the honest way: the
runtime object is abandoned without ``stop()`` (its durable artifacts are
whatever already hit the filesystem), plus byte-level truncation/flips
for torn-write and bit-rot cases, plus ``FaultPlan`` rules at the four
persist sites for process-death-at-instruction cases.

The property test runs under hypothesis when the environment has it and
falls back to the same generator driven by seeded ``np.random`` when it
does not (the container image pins its package set) — either way the
sequences and crash points are random but reproducible.
"""

import glob
import json
import os
import shutil
import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointCorruption, CheckpointManager
from repro.core.block_pool import NULL, snapshot_ids
from repro.core.faults import KNOWN_SITES, FaultError, FaultPlan
from repro.core.ivf import IVFIndex, IVFIndexConfig
from repro.core.runtime import RuntimeConfig, ServingRuntime, _Timed
from repro.persist import (
    SNAP_SUBDIR,
    WAL_SUBDIR,
    MutationWAL,
    PersistDirConflict,
    RecoveryError,
    WALCorruption,
    WALUnavailable,
    read_wal,
    recover_index,
)

pytestmark = pytest.mark.persist

D = 8


def _data(n, d=D, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def _index_cfg(**kw):
    base = dict(
        n_clusters=4, dim=D, block_size=16, max_chain=64,
        capacity_vectors=4000, seed=0,
    )
    base.update(kw)
    return IVFIndexConfig(**base)


def _fresh_index(cfg):
    idx = IVFIndex(cfg)
    idx.train(_data(256, cfg.dim, seed=99))
    return idx


def _runtime(persist_dir, icfg=None, faults=None, **rkw):
    icfg = icfg or _index_cfg()
    base = dict(
        mode="parallel", nprobe=4, k=5, flush_min=64, flush_interval=0.05,
        persist_dir=str(persist_dir),
    )
    base.update(rkw)
    return ServingRuntime(
        _fresh_index(icfg), RuntimeConfig(**base), faults=faults
    ), icfg


def _live_vectors(index) -> dict:
    """Host oracle view of an index: id -> stored vector (flat payload)."""
    st, cfg = index.state, index.pool_cfg
    id_map = np.asarray(st.id_map)
    live = np.asarray(st.pool_live)
    pay = np.asarray(st.pool_payload)
    out = {}
    for vid in np.flatnonzero(id_map != NULL):
        loc = int(id_map[vid])
        blk, off = divmod(loc, cfg.block_size)
        if live[blk, off]:
            out[int(vid)] = pay[blk, off].copy()
    return out


def _assert_state_equals_oracle(index, oracle: dict):
    got = _live_vectors(index)
    assert sorted(got) == sorted(oracle), (
        f"live ids diverge: extra={sorted(set(got) - set(oracle))[:5]} "
        f"missing={sorted(set(oracle) - set(got))[:5]}"
    )
    for vid, vec in oracle.items():
        np.testing.assert_array_equal(got[vid], vec, err_msg=f"id {vid}")


# ------------------------------------------------------------- WAL unit ---
def test_wal_roundtrip(tmp_path):
    wal = MutationWAL(str(tmp_path))
    v = _data(5)
    l1 = wal.append("insert", np.arange(5, dtype=np.int32), v)
    l2 = wal.append("delete", np.array([1, 3], np.int32))
    l3 = wal.append("update", np.array([0], np.int32), v[:1] * 2)
    assert (l1, l2, l3) == (1, 2, 3)
    assert wal.durable_lsn == 3  # sync_interval=1: every append fsyncs
    wal.close()
    records, report = read_wal(str(tmp_path))
    assert [r.lsn for r in records] == [1, 2, 3]
    assert [r.kind for r in records] == ["insert", "delete", "update"]
    np.testing.assert_array_equal(records[0].vectors, v)
    np.testing.assert_array_equal(records[1].ids, [1, 3])
    assert records[1].vectors is None
    np.testing.assert_array_equal(records[2].vectors, v[:1] * 2)
    assert report["torn_tail"] == 0
    # min_lsn filters strictly-greater
    tail, _ = read_wal(str(tmp_path), min_lsn=2)
    assert [r.lsn for r in tail] == [3]


def test_wal_torn_tail_truncates_loudly_and_reopen_repairs(tmp_path):
    wal = MutationWAL(str(tmp_path))
    for i in range(3):
        wal.append("insert", np.array([i], np.int32), _data(1, seed=i))
    wal.close()
    (seg,) = glob.glob(str(tmp_path / "wal_*.log"))
    size = os.path.getsize(seg)
    with open(seg, "r+b") as f:
        f.truncate(size - 7)  # tear the last record mid-body
    records, report = read_wal(str(tmp_path))
    assert [r.lsn for r in records] == [1, 2]
    assert report["torn_tail"] == 1 and "torn" in report["torn_detail"]
    # reopening repairs the tail and continues numbering after the last
    # *intact* record — the torn lsn 3 is reissued
    wal2 = MutationWAL(str(tmp_path))
    assert wal2.append("delete", np.array([0], np.int32)) == 3
    wal2.close()
    records, report = read_wal(str(tmp_path))
    assert [(r.lsn, r.kind) for r in records] == [
        (1, "insert"), (2, "insert"), (3, "delete")
    ]
    assert report["torn_tail"] == 0  # the damage was healed on reopen


def test_wal_crc_flip_truncates_from_damage_point(tmp_path):
    wal = MutationWAL(str(tmp_path))
    for i in range(3):
        wal.append("insert", np.array([i], np.int32), _data(1, seed=i))
    wal.close()
    (seg,) = glob.glob(str(tmp_path / "wal_*.log"))
    with open(seg, "r+b") as f:
        f.seek(os.path.getsize(seg) // 2)  # lands inside record 2
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    records, report = read_wal(str(tmp_path))
    assert [r.lsn for r in records] == [1]  # 2 fails CRC; 3 is unreachable
    assert report["torn_tail"] == 1 and "CRC" in report["torn_detail"]


def test_wal_damage_in_non_final_segment_is_corruption(tmp_path):
    wal = MutationWAL(str(tmp_path))
    wal.append("insert", np.array([0], np.int32), _data(1))
    wal.rotate()
    wal.append("insert", np.array([1], np.int32), _data(1))
    wal.close()
    first = sorted(glob.glob(str(tmp_path / "wal_*.log")))[0]
    with open(first, "r+b") as f:
        f.truncate(os.path.getsize(first) - 3)
    with pytest.raises(WALCorruption, match="non-final"):
        read_wal(str(tmp_path))


def test_wal_missing_middle_segment_is_an_lsn_gap(tmp_path):
    wal = MutationWAL(str(tmp_path))
    for i in range(3):
        wal.append("insert", np.array([i], np.int32), _data(1))
        wal.rotate()  # one record per sealed segment
    wal.close()
    os.remove(sorted(glob.glob(str(tmp_path / "wal_*.log")))[1])
    with pytest.raises(WALCorruption, match="gap"):
        read_wal(str(tmp_path))


def test_wal_fsync_batching_and_prune(tmp_path):
    wal = MutationWAL(str(tmp_path), sync_interval=3)
    for i in range(2):
        wal.append("delete", np.array([i], np.int32))
    assert wal.last_lsn == 2 and wal.durable_lsn == 0  # batched, not due
    assert wal.sync() == 2
    wal.append("delete", np.array([9], np.int32))
    wal.rotate()  # rotate fsyncs + seals
    assert wal.durable_lsn == 3
    wal.append("delete", np.array([10], np.int32))
    assert wal.prune(upto_lsn=3) == 1  # the sealed segment is covered
    wal.close()
    records, _ = read_wal(str(tmp_path), min_lsn=3)
    assert [r.lsn for r in records] == [4]


def test_wal_lsn_floor_survives_full_prune(tmp_path):
    wal = MutationWAL(str(tmp_path))
    for i in range(4):
        wal.append("delete", np.array([i], np.int32))
    wal.rotate()
    wal.prune(4)  # everything covered by a (hypothetical) snapshot @ 4
    wal.close()
    # reopening with the fence as the floor must not reuse LSNs 1..4
    wal2 = MutationWAL(str(tmp_path), start_lsn=4)
    assert wal2.append("delete", np.array([9], np.int32)) == 5
    wal2.close()


def test_wal_failed_fsync_rolls_back_the_record(tmp_path):
    """A record whose due fsync fails must not leave its bytes in the
    segment: the retry's re-append would otherwise coexist with the dead
    record (duplicate rows / mid-log garbage on recovery)."""
    plan = FaultPlan().fail("wal_fsync", nth=1)
    wal = MutationWAL(str(tmp_path), faults=plan)
    assert wal.append("insert", np.array([0], np.int32), _data(1)) == 1
    size_before = os.path.getsize(wal._path)
    with pytest.raises(FaultError):
        wal.append("insert", np.array([1], np.int32), _data(1, seed=1))
    assert wal.last_lsn == 1  # lsn counter rolled back with the bytes
    assert os.path.getsize(wal._path) == size_before
    # a retry re-appends cleanly at the next lsn
    assert wal.append("insert", np.array([1], np.int32),
                      _data(1, seed=1)) == 2
    wal.close()
    records, report = read_wal(str(tmp_path))
    assert [r.lsn for r in records] == [1, 2]
    assert report["torn_tail"] == 0  # nothing of the failure lingers


def test_wal_fails_closed_when_rollback_fails(tmp_path):
    """If the post-failure truncate itself fails, the active tail is
    untrusted: further appends/rotates must raise WALUnavailable instead
    of burying garbage mid-log."""

    class _NoTruncate:
        def __init__(self, f):
            self._f = f

        def __getattr__(self, name):
            return getattr(self._f, name)

        def truncate(self, *a):
            raise OSError("injected truncate failure")

    plan = FaultPlan().fail("wal_fsync", nth=0)
    wal = MutationWAL(str(tmp_path), faults=plan)
    wal._file = _NoTruncate(wal._file)
    with pytest.raises(FaultError):
        wal.append("insert", np.array([0], np.int32), _data(1))
    with pytest.raises(WALUnavailable):
        wal.append("insert", np.array([1], np.int32), _data(1))
    with pytest.raises(WALUnavailable):
        wal.rotate()
    wal.close()


# ------------------------------------------------------ fault-site registry --
def test_fault_sites_are_registered():
    for site in ("wal_append", "wal_fsync", "snapshot_publish",
                 "recovery_replay"):
        assert site in KNOWN_SITES
    FaultPlan().fail("wal_append").delay("snapshot_publish", 0.01)  # ok


def test_unknown_fault_site_rejected_at_rule_creation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan().fail("wal_appendz")
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan().delay("snapshot_pubish", 0.1)
    # escape hatch for test-private sites
    plan = FaultPlan(extra_sites=("my_harness_site",))
    plan.fail("my_harness_site", nth=0)
    with pytest.raises(FaultError):
        plan.check("my_harness_site")


# --------------------------------------------------- checkpoint manager ----
def _save(mgr, step, leaves, extra=None):
    import jax.numpy as jnp
    mgr.save(step, [jnp.asarray(x) for x in leaves], extra=extra)


def test_checkpoint_resave_has_no_unpublished_window(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    _save(mgr, 5, [np.arange(4)], extra={"v": 1})
    _save(mgr, 5, [np.arange(4) * 2], extra={"v": 2})  # re-save same step
    tree, man = mgr.restore(step=5, like=[np.zeros(4)])
    assert man["v"] == 2
    np.testing.assert_array_equal(np.asarray(tree[0]), np.arange(4) * 2)
    assert not glob.glob(str(tmp_path / "*.old"))
    assert not glob.glob(str(tmp_path / "*.tmp"))


def test_checkpoint_old_dir_with_missing_base_is_restored(tmp_path):
    """A crash between the two publish renames leaves ``step_X.old`` as the
    only good copy; the old code's GC would have deleted it."""
    mgr = CheckpointManager(str(tmp_path))
    _save(mgr, 7, [np.arange(3)], extra={"v": 1})
    d = mgr._step_dir(7)
    os.rename(d, d + ".old")  # simulate death between rename-aside/publish
    mgr2 = CheckpointManager(str(tmp_path))  # sweep runs at init
    assert mgr2.latest_step() == 7
    _, man = mgr2.restore(step=7, like=[np.zeros(3)])
    assert man["v"] == 1


def test_checkpoint_orphans_are_swept(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    _save(mgr, 3, [np.arange(3)])
    os.makedirs(str(tmp_path / "step_0000000009.tmp"))  # crashed save
    os.makedirs(str(tmp_path / "step_0000000003.old"))  # superseded leftover
    CheckpointManager(str(tmp_path))
    assert sorted(os.listdir(str(tmp_path))) == ["step_0000000003"]
    assert mgr.latest_step() == 3


def test_checkpoint_restore_raises_named_errors(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    _save(mgr, 1, [np.arange(3), np.arange(5)])
    # leaf-count mismatch vs the `like` template: named, not a bare assert
    with pytest.raises(CheckpointCorruption, match="schema mismatch"):
        mgr.restore(step=1, like=[np.zeros(3)])
    # manifest/archive divergence
    man_path = os.path.join(mgr._step_dir(1), "manifest.json")
    with open(man_path) as f:
        manifest = json.load(f)
    manifest["n_leaves"] = 3
    with open(man_path, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(CheckpointCorruption, match="manifest says 3"):
        mgr.restore(step=1, like=[np.zeros(3), np.zeros(5)])


# ----------------------------------------------------- end-to-end recovery --
def _drive(rt, rng, oracle, n_ops=6, base_seed=0):
    """Random acked traffic; returns op futures only after resolution, and
    folds every *acked* result into the oracle dict."""
    for op in range(n_ops):
        kind = rng.choice(["insert", "insert", "delete", "update"])
        if kind == "insert" or not oracle:
            vecs = _data(int(rng.integers(1, 9)), seed=base_seed + op)
            ids = rt.submit_insert(vecs).result(30)
            for i, vid in enumerate(ids):
                oracle[int(vid)] = vecs[i]
        elif kind == "delete":
            pick = rng.choice(sorted(oracle), size=min(3, len(oracle)),
                              replace=False).astype(np.int32)
            rt.submit_delete(pick).result(30)
            for vid in pick:
                oracle.pop(int(vid), None)
        else:
            pick = rng.choice(sorted(oracle), size=min(2, len(oracle)),
                              replace=False).astype(np.int32)
            vecs = _data(len(pick), seed=1000 + base_seed + op)
            rt.submit_update(vecs, pick).result(30)
            for i, vid in enumerate(pick):
                oracle[int(vid)] = vecs[i]


@pytest.mark.parametrize("mode", ["parallel", "fused"])
def test_recover_matches_acked_oracle(tmp_path, mode):
    rt, icfg = _runtime(tmp_path, mode=mode)
    rng = np.random.default_rng(7)
    oracle: dict = {}
    _drive(rt, rng, oracle, n_ops=5)
    rt.snapshot(wait=True)  # barrier mid-history
    _drive(rt, rng, oracle, n_ops=5, base_seed=50)
    stats = rt.stats()
    assert stats["applied_lsn"] == stats["wal_lsn"] >= stats["snapshot_lsn"]
    # crash: abandon rt without stop(); recover from disk alone
    rt2 = ServingRuntime.recover(icfg, str(tmp_path), cfg=rt.cfg)
    assert rt2.recovery_report.verified
    assert rt2.recovery_report.snapshot_lsn >= 0
    _assert_state_equals_oracle(rt2.index, oracle)
    # recovered node serves and keeps mutating durably
    more = rt2.submit_insert(_data(4, seed=123)).result(30)
    assert rt2.submit_search(_data(2, seed=5)).result(30)[1].shape == (2, 5)
    assert len(more) == 4
    rt2.stop()


def test_recovered_ids_do_not_collide(tmp_path):
    rt, icfg = _runtime(tmp_path)
    ids = rt.submit_insert(_data(6, seed=1)).result(30)
    rt2 = ServingRuntime.recover(icfg, str(tmp_path), cfg=rt.cfg)
    new = rt2.submit_insert(_data(3, seed=2)).result(30)
    assert set(new).isdisjoint(set(ids))  # allocator cursor recovered
    rt2.stop()


def test_torn_wal_tail_truncated_loudly_on_recovery(tmp_path):
    """With fsync batching (> 1), the newest acked batch can be torn by a
    crash; recovery truncates it loudly and restores the durable prefix."""
    rt, icfg = _runtime(tmp_path, wal_sync_interval=100)
    oracle: dict = {}
    v1 = _data(4, seed=1)
    ids1 = rt.submit_insert(v1).result(30)
    for i, vid in enumerate(ids1):
        oracle[int(vid)] = v1[i]
    last = rt.submit_insert(_data(3, seed=2)).result(30)
    assert len(last) == 3
    # crash tears the final record: drop its last bytes from the active
    # segment (they were acked but never fsynced — the page cache's loss)
    seg = sorted(glob.glob(os.path.join(str(tmp_path), WAL_SUBDIR,
                                        "wal_*.log")))[-1]
    with open(seg, "r+b") as f:
        f.truncate(os.path.getsize(seg) - 11)
    index, report = recover_index(icfg, str(tmp_path))
    assert report.torn_tail == 1 and report.verified
    _assert_state_equals_oracle(index, oracle)  # prefix, exactly


def test_recovery_refuses_without_snapshot(tmp_path):
    rt, icfg = _runtime(tmp_path)
    rt.submit_insert(_data(4)).result(30)
    shutil.rmtree(os.path.join(str(tmp_path), SNAP_SUBDIR))
    with pytest.raises(RecoveryError, match="cannot load a snapshot"):
        recover_index(icfg, str(tmp_path))


def test_recovery_refuses_on_pruned_gap(tmp_path):
    rt, icfg = _runtime(tmp_path)
    rt.submit_insert(_data(4, seed=1)).result(30)
    rt._wal.rotate()
    rt.submit_insert(_data(4, seed=2)).result(30)
    wal_dir = os.path.join(str(tmp_path), WAL_SUBDIR)
    os.remove(sorted(glob.glob(os.path.join(wal_dir, "wal_*.log")))[0])
    with pytest.raises(RecoveryError):
        recover_index(icfg, str(tmp_path))


def test_recovery_refuses_on_corrupt_snapshot_bytes(tmp_path):
    rt, icfg = _runtime(tmp_path)
    rt.submit_insert(_data(4)).result(30)
    rt.snapshot(wait=True)
    snap_dir = os.path.join(str(tmp_path), SNAP_SUBDIR)
    shard = sorted(  # newest snapshot (construction published one too)
        glob.glob(os.path.join(snap_dir, "step_*", "shard_0.npz"))
    )[-1]
    with open(shard, "r+b") as f:
        f.seek(os.path.getsize(shard) // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(RecoveryError):
        recover_index(icfg, str(tmp_path))


def test_config_mismatch_refuses(tmp_path):
    rt, icfg = _runtime(tmp_path)
    rt.submit_insert(_data(4)).result(30)
    wrong = _index_cfg(block_size=32)  # different pool geometry
    with pytest.raises(RecoveryError):
        recover_index(wrong, str(tmp_path))


# ------------------------------------------------------------ crash matrix --
def test_crash_at_wal_append_fails_future_keeps_rest(tmp_path):
    plan = FaultPlan().fail("wal_append", nth=1)  # batch 1 of this run
    rt, icfg = _runtime(tmp_path, faults=plan)
    oracle: dict = {}
    v1 = _data(4, seed=1)
    ids1 = rt.submit_insert(v1).result(30)  # append call 0: fine
    for i, vid in enumerate(ids1):
        oracle[int(vid)] = v1[i]
    with pytest.raises(FaultError):
        rt.submit_insert(_data(3, seed=2)).result(30)  # call 1: dies
    v3 = _data(2, seed=3)
    ids3 = rt.submit_insert(v3).result(30)  # lane recovers
    for i, vid in enumerate(ids3):
        oracle[int(vid)] = v3[i]
    index, report = recover_index(icfg, str(tmp_path))
    assert report.verified
    _assert_state_equals_oracle(index, oracle)


def test_crash_at_wal_fsync_fails_future_keeps_rest(tmp_path):
    plan = FaultPlan().fail("wal_fsync", nth=1)
    rt, icfg = _runtime(tmp_path, faults=plan)
    oracle: dict = {}
    v1 = _data(4, seed=1)
    for i, vid in enumerate(rt.submit_insert(v1).result(30)):
        oracle[int(vid)] = v1[i]
    with pytest.raises(FaultError):
        rt.submit_insert(_data(3, seed=2)).result(30)
    index, report = recover_index(icfg, str(tmp_path))
    assert report.verified
    # the fsync-failed batch was never acked; its record may or may not
    # replay (at-least-once for unacked work) — acked rows must all exist
    got = _live_vectors(index)
    for vid, vec in oracle.items():
        np.testing.assert_array_equal(got[vid], vec)


def test_crash_at_snapshot_publish_keeps_previous_snapshot_and_wal(tmp_path):
    plan = FaultPlan()
    rt, icfg = _runtime(tmp_path, faults=plan)  # publish call 0: initial
    oracle: dict = {}
    v1 = _data(5, seed=1)
    for i, vid in enumerate(rt.submit_insert(v1).result(30)):
        oracle[int(vid)] = v1[i]
    plan.fail("snapshot_publish", nth=1)
    with pytest.raises(FaultError):
        rt.snapshot(wait=True)
    assert rt.stats()["snapshot_failures"] == 1
    # serving continued; WAL intact -> recovery is exact from snapshot 0
    index, report = recover_index(icfg, str(tmp_path))
    assert report.snapshot_lsn == 0 and report.replayed_records >= 1
    _assert_state_equals_oracle(index, oracle)


def test_crash_mid_replay_is_rerecoverable(tmp_path):
    rt, icfg = _runtime(tmp_path)
    oracle: dict = {}
    v1 = _data(6, seed=1)
    for i, vid in enumerate(rt.submit_insert(v1).result(30)):
        oracle[int(vid)] = v1[i]
    rt.submit_delete(np.array(sorted(oracle)[:2], np.int32)).result(30)
    for vid in sorted(oracle)[:2]:
        oracle.pop(vid)
    with pytest.raises(RecoveryError, match="replay failed"):
        recover_index(
            icfg, str(tmp_path),
            faults=FaultPlan().fail("recovery_replay", nth=1),
        )
    # recovery never writes to the persist dir: same bytes, second attempt
    index, report = recover_index(icfg, str(tmp_path))
    assert report.verified and report.replayed_records == 2
    _assert_state_equals_oracle(index, oracle)


def test_crash_at_mutation_step_replays_logged_batch(tmp_path):
    """Append succeeded, device apply died, future failed: the record is
    at-least-once — recovery may hold the unacked rows, must hold every
    acked one, and must still verify."""
    plan = FaultPlan().fail("mutation_step", nth=[1])
    rt, icfg = _runtime(tmp_path, faults=plan)
    oracle: dict = {}
    v1 = _data(4, seed=1)
    for i, vid in enumerate(rt.submit_insert(v1).result(30)):
        oracle[int(vid)] = v1[i]
    with pytest.raises(FaultError):
        rt.submit_insert(_data(2, seed=2)).result(30)
    index, report = recover_index(icfg, str(tmp_path))
    assert report.verified
    got = _live_vectors(index)
    for vid, vec in oracle.items():
        np.testing.assert_array_equal(got[vid], vec)


# --------------------------------------------- record/cut atomicity matrix --
def _insert_items(seeds, rows=2):
    """Hand-built multi-item insert run (the lock-discipline tests' idiom)
    so one _apply_run dispatch carries several futures."""
    items, vecs = [], []
    for s in seeds:
        v = _data(rows, seed=100 + s)
        vecs.append(v)
        items.append(_Timed(Future(), time.perf_counter(), v, kind="insert"))
    return items, vecs


def test_isolation_retry_after_failed_append_stays_recoverable(tmp_path):
    """Reviewer scenario (WAL): a multi-item run whose append dies at the
    fsync re-appends per item on the isolation retry; the failed record's
    bytes must have been rolled back, or recovery hits duplicate ids."""
    plan = FaultPlan().fail("wal_fsync", nth=1)
    rt, icfg = _runtime(tmp_path, faults=plan)
    oracle: dict = {}
    v0 = _data(2, seed=0)
    for i, vid in enumerate(rt.submit_insert(v0).result(30)):  # fsync 0
        oracle[int(vid)] = v0[i]
    # one run of three items: the run's own append dies (fsync 1), the
    # per-item retries append their own records (fsyncs 2..4) and all ack
    items, vecs = _insert_items([1, 2, 3])
    rt._apply_run(items)
    for it, v in zip(items, vecs):
        for i, vid in enumerate(it.future.result(30)):
            oracle[int(vid)] = v[i]
    assert rt.stats()["isolations"] == 1
    # crash: abandon rt; the log must replay without duplicate ids
    index, report = recover_index(icfg, str(tmp_path))
    assert report.verified
    _assert_state_equals_oracle(index, oracle)


def test_snapshot_cut_waits_for_inflight_record(tmp_path):
    """The cut must wait out an in-flight record's append->apply->fence
    sequence (that is what makes the fence trustworthy)."""
    rt, _ = _runtime(tmp_path)
    rt.submit_insert(_data(4, seed=1)).result(30)
    assert rt._record_lock.acquire(timeout=5)  # simulate a mid-record apply
    try:
        t = threading.Thread(target=rt.snapshot, kwargs={"wait": True})
        t.start()
        t.join(0.5)
        assert t.is_alive(), "snapshot cut while a record was in flight"
    finally:
        rt._record_lock.release()
    t.join(30)
    assert not t.is_alive()
    s = rt.stats()
    assert s["snapshot_lsn"] == s["applied_lsn"] == s["wal_lsn"]
    rt.stop()


def test_cut_never_lands_inside_a_retried_record(tmp_path):
    """Reviewer scenario (fence): a logged run fails after its append and
    retries per item; a snapshot racing the retry loop must not cut
    between items — it would fence a half-applied record and recovery
    would silently drop rows acked after the cut."""
    rt, icfg = _runtime(tmp_path)
    oracle: dict = {}

    calls = {"step": 0}
    real_step = rt._insert_step

    def flaky_step(state, *a):
        calls["step"] += 1
        if calls["step"] == 1:  # the whole-run dispatch, post-append
            raise RuntimeError("injected device failure after the append")
        return real_step(state, *a)

    rt._insert_step = flaky_step

    snap: dict = {}
    real_args = rt._mutation_args

    def racing_args(kind, items, ids=None):
        # second retry item of the logged run: race a snapshot against
        # the remainder of the loop and give it a wide-open window
        if ids is not None and calls["step"] == 2 and "t" not in snap:
            t = threading.Thread(target=rt.snapshot, kwargs={"wait": True})
            t.start()
            snap["t"] = t
            time.sleep(0.3)  # unfixed code: the cut lands here, mid-record
        return real_args(kind, items, ids=ids)

    rt._mutation_args = racing_args

    items, vecs = _insert_items([1, 2, 3])
    rt._apply_run(items)
    for it, v in zip(items, vecs):
        for i, vid in enumerate(it.future.result(30)):
            oracle[int(vid)] = v[i]
    snap["t"].join(30)
    assert not snap["t"].is_alive()
    # crash: the snapshot (plus whatever WAL survived its prune) must
    # rebuild every acked row — a mid-record cut loses the loop's tail
    index, report = recover_index(icfg, str(tmp_path))
    assert report.verified
    _assert_state_equals_oracle(index, oracle)


def test_plain_constructor_refuses_used_persist_dir(tmp_path):
    """Constructing a fresh runtime over a directory that already holds
    snapshots/WAL would fork the log from the in-memory index — enforced
    with a named error, not a config comment."""
    rt, icfg = _runtime(tmp_path)
    rt.submit_insert(_data(3, seed=1)).result(30)
    rt.stop()
    with pytest.raises(PersistDirConflict, match="recover"):
        _runtime(tmp_path)
    rt2 = ServingRuntime.recover(icfg, str(tmp_path))  # the blessed path
    assert rt2.recovery_report.verified
    rt2.stop()


# ---------------------------------------------------------- property test --
def _durability_property(seed: int, tmp_path):
    """Random mutation sequence, crash at a random point (plain kill /
    mid-snapshot / mid-replay), recovered state == acked oracle exactly."""
    rng = np.random.default_rng(seed)
    root = os.path.join(str(tmp_path), f"run_{seed}")
    plan = FaultPlan()
    rt, icfg = _runtime(root, faults=plan)
    oracle: dict = {}
    n_ops = int(rng.integers(6, 14))
    snap_at = int(rng.integers(0, n_ops)) if rng.random() < 0.7 else -1
    for op in range(n_ops):
        if op == snap_at:
            rt.snapshot(wait=True)
        _drive(rt, rng, oracle, n_ops=1, base_seed=seed * 100 + op)
    crash_kind = rng.choice(["kill", "mid_snapshot", "mid_replay"])
    if crash_kind == "mid_snapshot":
        plan.fail("snapshot_publish", nth=plan.calls("snapshot_publish"))
        with pytest.raises(FaultError):
            rt.snapshot(wait=True)
    # crash: abandon the runtime, recover from disk
    if crash_kind == "mid_replay":
        try:
            recover_index(
                icfg, root,
                faults=FaultPlan().fail("recovery_replay", nth=0),
            )
        except RecoveryError:
            pass  # died mid-replay; fall through to the real recovery
    index, report = recover_index(icfg, root)
    assert report.verified
    _assert_state_equals_oracle(index, oracle)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**20))
    def test_durability_property(seed, tmp_path_factory):
        _durability_property(
            seed, tmp_path_factory.mktemp(f"prop_{seed}")
        )

except ImportError:  # no hypothesis in this environment: seeded fallback
    @pytest.mark.parametrize("seed", [3, 11, 42, 1337])
    def test_durability_property(seed, tmp_path):
        _durability_property(seed, tmp_path)
