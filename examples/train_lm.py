"""Train a small LM end-to-end with the production substrate:
data pipeline -> train step -> checkpointing -> preemption restore.

Uses a reduced llama3-family config (CPU container); the identical step
function scales to the dry-run meshes via launch/steps.py.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.data.synthetic import token_stream
from repro.models.transformer import init_lm, lm_loss
from repro.optim.optimizers import OptConfig, make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    # reduced config (~11M params), same code path as the full model
    cfg = dataclasses.replace(
        get_arch(args.arch).smoke_config,
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_head=32,
        d_ff=512, vocab=2048, attn_chunk=64,
    )
    params = init_lm(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"training {cfg.name}-reduced: {n_params/1e6:.1f}M params")

    opt_init, opt_update = make_optimizer(OptConfig(kind="adamw", lr=1e-3))
    opt = opt_init(params)

    @jax.jit
    def step(params, opt, tokens, labels):
        (loss, m), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, tokens, labels), has_aux=True
        )(params)
        params, opt = opt_update(grads, opt, params)
        return params, opt, loss

    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_lm_ckpt")
    mgr = CheckpointManager(ckpt_dir, keep=2)
    stream = token_stream(batch=8, seq=128, vocab=cfg.vocab, seed=0)

    start = 0
    try:  # elastic restart: resume from the latest checkpoint if present
        (params, opt), manifest = mgr.restore(like=(params, opt))
        start = manifest["step"]
        stream = token_stream(batch=8, seq=128, vocab=cfg.vocab, seed=0,
                              start_step=start)
        print(f"restored from step {start}")
    except FileNotFoundError:
        pass

    first_loss = last_loss = None
    for i in range(start, args.steps):
        batch = next(stream)
        params, opt, loss = step(
            params, opt, jnp.asarray(batch["tokens"]), jnp.asarray(batch["labels"])
        )
        if first_loss is None:
            first_loss = float(loss)
        last_loss = float(loss)
        if (i + 1) % args.ckpt_every == 0:
            mgr.async_save(i + 1, (params, opt), extra={"data_cursor": i + 1})
            print(f"step {i+1}: loss {last_loss:.4f} (checkpoint async)")
    mgr.wait()
    print(f"done: loss {first_loss:.4f} -> {last_loss:.4f} "
          f"over {args.steps - start} steps")
    assert last_loss < first_loss, "loss did not improve"


if __name__ == "__main__":
    main()
