"""Quickstart: build an RTAMS-GANNS index, insert online, search.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import build_ivf, exact_search
from repro.core.metrics import recall_at_k
from repro.data.synthetic import sift_like


def main():
    # ---- offline segment: train + load 20k SIFT-like vectors -----------
    corpus = sift_like(20_000, dim=128, seed=0)
    index = build_ivf(
        corpus, n_clusters=64, block_size=64, max_chain=64,
        nprobe=8, k=10,
    )
    print(f"built index: {index.ntotal} vectors, "
          f"{int(index.state.cur_p)} blocks in use")

    # ---- search ---------------------------------------------------------
    rng = np.random.default_rng(1)
    queries = corpus[rng.integers(0, len(corpus), 10)] + 0.01
    dists, ids = index.search(queries)
    import jax.numpy as jnp

    _, exact_ids = exact_search(jnp.asarray(corpus), jnp.asarray(queries), 10)
    print(f"recall@10 vs brute force: "
          f"{recall_at_k(ids, np.asarray(exact_ids), 10):.3f}")

    # ---- online insertion (the paper's contribution) --------------------
    new_vectors = sift_like(500, dim=128, seed=2) + 100.0  # far-away cluster
    new_ids = index.add(new_vectors)
    print(f"inserted {len(new_ids)} new vectors "
          f"(no realloc: still {int(index.state.cur_p)} bump-allocated blocks)")

    # immediately searchable
    d, i = index.search(new_vectors[:5], k=1)
    print(f"new vectors retrievable at once: "
          f"{(i[:, 0] == new_ids[:5]).all()}")

    # ---- rearrangement (Alg. 3) -----------------------------------------
    passes = index.maybe_rearrange()
    print(f"rearrangement passes run: {passes}")

    # ---- online mutations: delete + in-place update ---------------------
    # Deletes tombstone rows (one jitted dispatch through the device id
    # map — nothing moves); updates tombstone + re-insert under the same
    # id in one dispatch.  Dead space is reclaimed by the next compaction
    # pass once a cluster's dead fraction crosses the trigger.
    victims = new_ids[:350]  # most of the far cluster: crosses the
    # dead-fraction trigger so the compaction below actually reclaims
    n = index.delete(victims)
    d, i = index.search(new_vectors[:5], k=1)
    print(f"deleted {n} ids; deleted ids surface in results: "
          f"{bool(np.isin(i, victims).any())}")
    refreshed = new_vectors[350:353] * 0.5  # same ids, new vectors
    index.update(refreshed, new_ids[350:353])
    d, i = index.search(refreshed, k=1)
    print(f"updated rows retrievable under their old ids: "
          f"{(i[:, 0] == new_ids[350:353]).all()}")
    s = index.stats()
    print(f"live utilisation {s['utilisation']:.3f}, "
          f"dead fraction {s['dead_fraction']:.3f} "
          f"(blocks in use: {s['blocks_in_use']})")
    passes = index.maybe_rearrange(max_passes=16)  # reclaim the dead space
    s = index.stats()
    print(f"after {passes} compaction passes: dead fraction "
          f"{s['dead_fraction']:.3f}, blocks in use {s['blocks_in_use']}")

    # ---- int8 payload + exact re-rank (the dtype axis) ------------------
    # Quantized flat payload: rows are stored as int8 *residual* codes
    # (vs their coarse centroid) + one f32 scale per vector
    # (quantize-on-insert), quartering the HBM bytes the fused scan
    # streams; rerank=True re-sorts the K' fused survivors by exact fp32
    # distance so recall stays near the fp32 level.  union_fused_scan is
    # the pure-XLA fallback (fast off-TPU); on TPU use
    # search_path="union_fused" for the integer-MXU kernel.
    int8_index = build_ivf(
        corpus, n_clusters=64, block_size=64, max_chain=64,
        nprobe=8, k=10, dtype="int8", rerank=True,
        search_path="union_fused_scan",
    )
    d_i8, i_i8 = int8_index.search(queries)
    print(f"int8 + exact re-rank recall@10 vs brute force: "
          f"{recall_at_k(i_i8, np.asarray(exact_ids), 10):.3f} "
          f"(payload bytes/dim: 1 vs 4)")

    # The routing prologue of the fused paths is itself fused: the coarse
    # probe streams through the coarse_topk kernel (no [Q, N_clusters]
    # distance matrix in HBM, bit-exact with the dense probe), and
    # per-query candidate membership is derived *inside* the scan kernels
    # from each block's owner (IVFState.block_owner) — per-query routing
    # traffic is O(nprobe), not O(candidates).  Nothing to configure: every
    # union path uses it automatically.

    # ---- IVFPQ on the fused streaming path (§3.3 deployment) ------------
    # Quantized payload: 1 byte/dim in the pool, searched via the PQ-ADC
    # fused top-k kernel (LUT in VMEM, [Q, K'] writeback — no [C, Q, T]
    # score tensor).  See docs/search_paths.md for the ladder.  Off-TPU the
    # kernel runs in interpret mode and this section takes a minute or so;
    # swap search_path="union_fused_scan" for the fast pure-XLA fallback.
    pq_index = build_ivf(
        corpus, n_clusters=64, payload="pq", pq_m=16, block_size=64,
        max_chain=64, nprobe=8, k=10, search_path="union_fused",
    )
    d_pq, i_pq = pq_index.search(queries)
    print(f"ivfpq (union_fused) recall@10 vs brute force: "
          f"{recall_at_k(i_pq, np.asarray(exact_ids), 10):.3f}")

    # ---- serving --------------------------------------------------------
    # To serve an index under live traffic, wrap it in
    # repro.core.runtime.ServingRuntime (examples/online_serving.py):
    # request batching, serial/parallel/fused execution modes, and a
    # fault-tolerance layer — bounded mutation admission, per-request
    # deadlines with load shedding, a degradation ladder under overload,
    # crash-safe workers, drain-on-shutdown.  Operational contract and
    # the fault-injection API: docs/serving_ops.md.
    #
    # Set RuntimeConfig(persist_dir=...) and the index also survives
    # kill -9: every acked mutation is WAL-logged before it applies
    # (RPO = 0 acked rows at the default fsync cadence), snapshots are
    # crash-consistent online cuts, and ServingRuntime.recover() replays
    # + verifies before serving — or refuses with RecoveryError.
    # Runbook and RPO/RTO table: docs/serving_ops.md "Durability".
    #
    # The runtime is observable end to end: sampled per-request span
    # traces (RuntimeConfig.trace_sample_rate), a structured event
    # flight recorder for control-plane transitions, Prometheus text /
    # Perfetto trace exporters (rt.prometheus_text(),
    # rt.export_perfetto()), and post-mortem debug bundles on recovery
    # failure, lane death, and shutdown.  Runbook: docs/observability.md.

    # ---- static analysis ------------------------------------------------
    # Before shipping changes to kernels or the serving layer, run
    # `python -m repro.analysis --fail-on-findings`: it traces every
    # dispatchable program above (all search paths x payloads, mutations,
    # compaction) and enforces intermediate-byte budgets, int8-contraction
    # dtype discipline, VMEM residency, and host-side lock/counter/
    # jit-cache-key hygiene.  Rule catalog: docs/static_analysis.md.


if __name__ == "__main__":
    main()
