"""End-to-end serving driver (the paper's deployment, §3.3): the
multi-stream runtime under mixed search+insert traffic with batched
requests, comparing serial vs parallel vs fused execution modes.

    PYTHONPATH=src python examples/online_serving.py
"""

import threading
import time

import numpy as np

from repro.core import build_ivf
from repro.core.scheduler import RequestRejected, RuntimeConfig, ServingRuntime
from repro.data.synthetic import sift_like


def drive(runtime: ServingRuntime, corpus, *, qps_search=3, qps_insert=20,
          duration=4.0, seed=0, warmup=True):
    if warmup:  # jit-compile the search/insert/fused steps outside the
        # measurement window, then reset the latency stats
        runtime.submit_search(corpus[:1]).result(timeout=60)
        runtime.submit_insert(corpus[:4] + 0.01).result(timeout=60)
        time.sleep(0.3)
        runtime.reset_stats()
    return _drive(runtime, corpus, qps_search=qps_search,
                  qps_insert=qps_insert, duration=duration, seed=seed)


def _drive(runtime: ServingRuntime, corpus, *, qps_search, qps_insert,
           duration, seed=0):
    """Open-loop Poisson traffic generator."""
    rng = np.random.default_rng(seed)
    t_end = time.perf_counter() + duration
    futures, rejected = [], 0
    next_s = time.perf_counter()
    next_i = time.perf_counter()
    while time.perf_counter() < t_end:
        now = time.perf_counter()
        if now >= next_s:
            q = corpus[rng.integers(0, len(corpus), 1)]
            try:
                futures.append(runtime.submit_search(q))
            except RequestRejected:
                rejected += 1
            next_s += rng.exponential(1.0 / qps_search)
        if now >= next_i:
            v = corpus[rng.integers(0, len(corpus), 16)] + 0.01
            futures.append(runtime.submit_insert(v))
            next_i += rng.exponential(16.0 / qps_insert)
        time.sleep(0.0005)
    for f in futures:
        try:
            f.result(timeout=30)
        except Exception:
            pass
    return rejected


def main():
    corpus = sift_like(10_000, dim=128, seed=0)
    for mode in ("serial", "parallel", "fused"):
        index = build_ivf(
            corpus, n_clusters=32, block_size=64, max_chain=64,
            capacity_vectors=40_000, nprobe=8, k=10,
        )
        rt = ServingRuntime(
            index,
            # fault-tolerant serving posture (docs/serving_ops.md): bound
            # the mutation backlog, expire requests instead of serving
            # them arbitrarily late, and degrade before falling over
            RuntimeConfig(mode=mode, nprobe=8, k=10, flush_min=16,
                          flush_interval=0.1,
                          max_pending_mutations=4096,
                          default_deadline=5.0,
                          degradation_ladder=("no_rerank", "half_nprobe")),
        )
        try:
            rejected = drive(rt, corpus)
            # the mutation stream rides the same lane: deletes tombstone
            # through the device id map, updates replace in place under
            # the same id (one fused dispatch each); auto_compact reclaims
            # the dead space once a cluster crosses the trigger
            rng = np.random.default_rng(7)
            victims = rng.choice(5000, 400, replace=False).astype(np.int32)
            rt.submit_delete(victims).result(timeout=30)
            keep = np.asarray([6000, 6001, 6002], np.int32)
            rt.submit_update(corpus[keep] * 0.5, keep).result(timeout=30)
            time.sleep(0.2)
            s = rt.stats()
            print(f"mode={mode:<9} search {s['search'].row()}")
            print(f"{'':15}insert {s['insert'].row()}  rejected={rejected}")
            print(f"{'':15}mutation {s['mutation'].row()}")
            print(f"{'':15}deletes={s['deletes']} updates={s['updates']} "
                  f"live={s['live_vectors']} "
                  f"dead_frac={s['dead_fraction']:.3f} "
                  f"util={s['utilisation']:.3f}")
            print(f"{'':15}shed={s['shed_search']}/{s['shed_mutation']} "
                  f"rejected={s['rejected_search']}/"
                  f"{s['rejected_mutation']} "
                  f"rung={s['degradation_rung']}")
            print(f"{'':15}corpus now {rt.index.ntotal} live vectors")
        finally:
            rt.stop()


if __name__ == "__main__":
    main()
