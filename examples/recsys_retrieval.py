"""The paper's technique as a first-class recsys feature: candidate
retrieval (the ``retrieval_cand`` shape) served from an RTAMS IVF index
with *online item insertion* — new items become retrievable immediately,
which is exactly the production problem the paper solves (§1).

Compares: brute-force scoring vs IVF search (recall + latency), then
streams new items in and verifies immediate retrievability.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_ivf, exact_search
from repro.core.metrics import recall_at_k
from repro.data.synthetic import dssm_like


def main():
    # candidate corpus: item embeddings from a two-tower-style model
    n_items, dim = 400_000, 64
    items = dssm_like(n_items, dim, seed=0)

    index = build_ivf(
        items, n_clusters=512, block_size=64, max_chain=32,
        capacity_vectors=4 * n_items, nprobe=8, k=100,
    )

    # user queries (normalised like the item tower output)
    users = dssm_like(8, dim, seed=1)

    # warm both paths (exclude jit compile from the timings)
    index.search(users, nprobe=8, k=100)
    exact_search(jnp.asarray(items), jnp.asarray(users), 100)

    t0 = time.perf_counter()
    _, ivf_ids = index.search(users, nprobe=8, k=100)
    jax.block_until_ready(ivf_ids)
    t_ivf = time.perf_counter() - t0

    # union-dedup scan (beyond-paper optimisation: each candidate block is
    # read once per *batch* instead of once per query — see DESIGN.md §8)
    from repro.core.search import make_search_fn

    union_fn = make_search_fn(index.pool_cfg, nprobe=8, k=100, path="union")
    union_fn(index.state, jnp.asarray(users))  # warm
    t0 = time.perf_counter()
    _, union_ids = union_fn(index.state, jnp.asarray(users))
    jax.block_until_ready(union_ids)
    t_union = time.perf_counter() - t0

    t0 = time.perf_counter()
    _, exact_ids = exact_search(jnp.asarray(items), jnp.asarray(users), 100)
    jax.block_until_ready(exact_ids)
    t_exact = time.perf_counter() - t0

    r = recall_at_k(ivf_ids, np.asarray(exact_ids), 100)
    print(f"retrieval over {n_items} candidates, batch 8 users:")
    print(f"  brute force:       {t_exact*1e3:7.1f} ms")
    print(f"  IVF (per-query):   {t_ivf*1e3:7.1f} ms   recall@100 = {r:.3f}")
    print(f"  IVF (union scan):  {t_union*1e3:7.1f} ms")

    # ---- online catalogue updates (new items published) -----------------
    new_items = dssm_like(256, dim, seed=2)
    index.add(dssm_like(256, dim, seed=3))  # warm the insert step
    t0 = time.perf_counter()
    new_ids = index.add(new_items)
    jax.block_until_ready(index.state.pool_payload)
    t_ins = time.perf_counter() - t0
    print(f"inserted 256 new items in {t_ins*1e3:.1f} ms (no realloc)")

    # the new items are their own nearest neighbours immediately
    _, got = index.search(new_items[:8], nprobe=16, k=1)
    print(f"new items immediately retrievable: "
          f"{(got[:, 0] == new_ids[:8]).all()}")


if __name__ == "__main__":
    main()
