"""Structured event flight recorder for the serving runtime.

Control-plane transitions (the *why* behind a latency cliff: a window
rung move, a ladder step, a compaction pass, a pool rebalance, a WAL
rotate, a worker restart) are recorded into a bounded ring with names
drawn from :data:`EVENT_CATALOG`.  The catalog is a registry in exactly
the ``FaultPlan.KNOWN_SITES`` mold: emitting an unregistered name
raises ``ValueError`` at the emit site instead of producing an event
nobody's dashboard filter will ever match, and the ``event-name`` lint
rule keeps call sites on the named constants below.

The recorder ring survives ``ServingRuntime.reset_stats()`` (it is a
flight recorder — history is the point); the debug bundle written on
``RecoveryError`` / lane death / shutdown snapshots it wholesale.
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional

# ---------------------------------------------------------------- catalog --
# Register new event names here + the table in docs/observability.md.
EV_WINDOW_RUNG = "controller.window_rung"
EV_EFFORT = "controller.effort"
EV_LADDER_STEP = "ladder.step"
EV_COMPACTION = "compaction.pass"
EV_COMPACTION_DEFERRED = "compaction.deferred"
EV_POOL_REBALANCE = "pool.rebalance"
EV_WAL_FSYNC = "wal.fsync"
EV_WAL_ROTATE = "wal.rotate"
EV_SNAPSHOT_CUT = "snapshot.cut"
EV_SNAPSHOT_PUBLISH = "snapshot.publish"
EV_SNAPSHOT_FAILED = "snapshot.publish_failed"
EV_WORKER_RESTART = "worker.restart"
EV_LANE_DEAD = "worker.lane_dead"
EV_FAULT_INJECTED = "fault.injected"

EVENT_CATALOG = frozenset({
    EV_WINDOW_RUNG,
    EV_EFFORT,
    EV_LADDER_STEP,
    EV_COMPACTION,
    EV_COMPACTION_DEFERRED,
    EV_POOL_REBALANCE,
    EV_WAL_FSYNC,
    EV_WAL_ROTATE,
    EV_SNAPSHOT_CUT,
    EV_SNAPSHOT_PUBLISH,
    EV_SNAPSHOT_FAILED,
    EV_WORKER_RESTART,
    EV_LANE_DEAD,
    EV_FAULT_INJECTED,
})


class Event:
    """One recorded control-plane transition."""

    __slots__ = ("seq", "t", "name", "fields")

    def __init__(self, seq: int, t: float, name: str, fields: dict):
        self.seq = seq
        self.t = t
        self.name = name
        self.fields = fields

    def as_dict(self) -> dict:
        d = {"seq": self.seq, "t": self.t, "name": self.name}
        d.update(self.fields)
        return d


class FlightRecorder:
    """Bounded, lock-disciplined ring of catalog-validated events.

    ``record_event`` is called from inside other subsystems' critical
    sections (e.g. the WAL emits ``wal.fsync`` under its log lock), so
    the recorder lock is a *leaf*: nothing is acquired while holding it
    and no callback runs under it."""

    def __init__(self, capacity: int = 2048):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._lock = threading.Lock()
        self._buf: List[Optional[Event]] = [None] * int(capacity)
        self._head = 0  # guarded-by: _lock (next write index)
        self._total = 0  # guarded-by: _lock (lifetime events)
        self._seq = 0  # guarded-by: _lock (event sequence numbers)

    @property
    def capacity(self) -> int:
        return len(self._buf)

    @property
    def total(self) -> int:
        """Lifetime event count (evictions included)."""
        with self._lock:
            return self._total

    def record_event(self, name: str, t: Optional[float] = None,
                     **fields) -> None:
        """Record one event; ``name`` must come from the catalog."""
        if name not in EVENT_CATALOG:
            raise ValueError(
                f"unregistered event name {name!r}; known events: "
                f"{sorted(EVENT_CATALOG)} (register in repro.obs.events)"
            )
        if t is None:
            t = time.perf_counter()
        with self._lock:
            self._seq += 1
            self._buf[self._head] = Event(self._seq, t, name, fields)
            self._head = (self._head + 1) % len(self._buf)
            self._total += 1

    def snapshot(self) -> List[Event]:
        """Live window, oldest first."""
        with self._lock:
            n = len(self._buf)
            ordered = [self._buf[(self._head + i) % n] for i in range(n)]
        return [e for e in ordered if e is not None]

    def count(self, name: str) -> int:
        """Occurrences of ``name`` currently in the ring (post-eviction)."""
        return sum(1 for e in self.snapshot() if e.name == name)

    def clear(self) -> None:
        with self._lock:
            for i in range(len(self._buf)):
                self._buf[i] = None
            self._head = 0
