"""Exporters: Perfetto trace_event JSON and Prometheus text exposition.

No new dependencies — both formats are plain text/JSON:

* :func:`perfetto_trace` emits the Chrome/Perfetto ``trace_event``
  envelope (``{"traceEvents": [...]}``).  Each request trace becomes a
  row (``tid`` = trace id) of complete-duration ``"X"`` events, one per
  span; flight-recorder events become global ``"i"`` instants.  Open in
  https://ui.perfetto.dev or ``chrome://tracing``.
* :func:`prometheus_text` flattens the runtime's unified metrics
  registry (``ServingRuntime.metrics()``: counters + estimator
  snapshots + ``stats()`` gauges) into the text exposition format, with
  ``# HELP`` / ``# TYPE`` preamble per metric.  Counter-vs-gauge typing
  is by registered name suffix (:data:`PROM_COUNTER_KEYS`).

Format validity for both is asserted in ``tests/test_obs.py``.
"""

from __future__ import annotations

import json
import math
import re
from typing import Iterable, Optional

# stats() keys (flattened leaf names) that are monotone counters; the
# rest export as gauges.  Names here track CounterSet users in
# core/runtime.py and the lifetime counters inside stats() sub-dicts.
PROM_COUNTER_KEYS = frozenset({
    "accepted_search", "accepted_mutation",
    "rejected_search", "rejected_mutation",
    "shed_search", "shed_mutation",
    "inserts", "deletes", "updates",
    "compactions", "compactions_deferred",
    "worker_restarts", "poisoned", "isolations", "fused_fallbacks",
    "snapshots", "snapshot_failures",
    "transitions", "window_changes", "effort_changes",
    "events", "moves", "n", "timeouts",
})

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v)


def flatten_metrics(stats: dict, prefix: str = "") -> dict:
    """Flatten a nested stats dict to ``name -> float`` leaves.

    Dicts recurse with ``_``-joined keys; numbers pass through; bools
    become 0/1; strings and other leaves are dropped (Prometheus has no
    string samples — the full structured form stays available as JSON
    via :func:`metrics_json`)."""
    flat: dict = {}
    for key, val in stats.items():
        name = f"{prefix}_{key}" if prefix else str(key)
        if isinstance(val, dict):
            flat.update(flatten_metrics(val, name))
        elif isinstance(val, bool):
            flat[name] = 1.0 if val else 0.0
        elif isinstance(val, (int, float)):
            flat[name] = float(val)
    return flat


def prometheus_text(metrics: dict, namespace: str = "repro") -> str:
    """Prometheus text exposition (version 0.0.4) over flat metrics."""
    lines = []
    for name in sorted(metrics):
        value = metrics[name]
        metric = _NAME_OK.sub("_", f"{namespace}_{name}")
        leaf = name.rsplit("_", 1)[-1] if "_" in name else name
        kind = "counter" if (name in PROM_COUNTER_KEYS
                             or leaf in PROM_COUNTER_KEYS) else "gauge"
        lines.append(f"# HELP {metric} repro serving runtime metric {name}")
        lines.append(f"# TYPE {metric} {kind}")
        lines.append(f"{metric} {_prom_value(value)}")
    return "\n".join(lines) + "\n"


def metrics_json(metrics: dict) -> str:
    """The same registry as JSON (structured consumers / debug bundle)."""
    return json.dumps(metrics, indent=1, sort_keys=True)


def perfetto_trace(traces: Iterable, events: Iterable = (),
                   time_origin: Optional[float] = None) -> dict:
    """Chrome/Perfetto ``trace_event`` JSON envelope.

    ``time_origin`` (monotonic seconds) anchors ``ts`` 0; defaults to
    the earliest trace start / event time so timelines start near 0."""
    traces = list(traces)
    events = list(events)
    if time_origin is None:
        starts = [tr.t_start for tr in traces] + [ev.t for ev in events]
        time_origin = min(starts) if starts else 0.0
    te = []
    for tr in traces:
        for stage, t0, t1 in tr.spans():
            te.append({
                "name": stage,
                "cat": tr.kind,
                "ph": "X",
                "ts": round((t0 - time_origin) * 1e6, 3),
                "dur": round((t1 - t0) * 1e6, 3),
                "pid": 1,
                "tid": int(tr.trace_id),
                "args": {"trace_id": int(tr.trace_id), "kind": tr.kind,
                         "outcome": tr.outcome},
            })
    for ev in events:
        te.append({
            "name": ev.name,
            "cat": "event",
            "ph": "i",
            "s": "g",
            "ts": round((ev.t - time_origin) * 1e6, 3),
            "pid": 1,
            "tid": 0,
            "args": {str(k): v for k, v in ev.fields.items()},
        })
    return {"traceEvents": te, "displayTimeUnit": "ms"}
