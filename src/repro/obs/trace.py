"""Per-request span tracing for the serving runtime.

A :class:`RequestTrace` is created (subject to sampling) when a request
enters ``submit_*`` and is carried on the queued item through the whole
serving path.  Each pipeline boundary calls :meth:`RequestTrace.stamp`
with a *stage name from the registered catalog* (:data:`SPAN_STAGES` —
inline string literals are rejected here at runtime and by the
``event-name`` lint rule at review time) and a monotonic timestamp.  A
stamp closes the span that *ends* at that boundary, so the recorded
spans are contiguous: they tile ``[t_start, t_last]`` with no gaps and
no overlaps, and therefore sum to the request's end-to-end latency by
construction.  ``benchmarks/obs.py`` asserts that this trace-internal
budget matches the load generator's externally measured latency within
5% at p50/p99.

Stage model (docs/observability.md):

``admission``   submit entry -> enqueued (validation, gate/slot acquire)
``queue``       enqueued -> popped by a worker loop
``batch_form``  popped -> batch closed / dispatch starts
``compile``     dispatch -> step returned, when this dispatch traced+
                compiled a new program (trace-cache detection, PR 9)
``execute``     same span when the jit cache was already warm
``device_wait`` step returned -> device results materialized on host
``ack``         results on host -> future resolved (callbacks ran)

Terminal outcomes: ``ok``, ``rejected`` (admission refused), ``shed``
(deadline passed in queue), ``error`` (lane failure / poison).

Threading: a trace object is only ever touched by the thread that
currently owns the request (submitter, then exactly one worker loop —
the queue hand-off provides the happens-before edge), so traces need no
lock.  The ring and the sampler are shared and take a leaf lock.
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional, Tuple

from repro.core.metrics import percentile_summary

# ---------------------------------------------------------------- catalog --
# Span stage names.  Register new stages here (and in the table in
# docs/observability.md); `stamp()` rejects anything else, and the
# `event-name` lint rule rejects inline literals at call sites.
STAGE_ADMISSION = "admission"
STAGE_QUEUE = "queue"
STAGE_BATCH = "batch_form"
STAGE_COMPILE = "compile"
STAGE_EXECUTE = "execute"
STAGE_DEVICE = "device_wait"
STAGE_ACK = "ack"

SPAN_STAGES = frozenset({
    STAGE_ADMISSION,
    STAGE_QUEUE,
    STAGE_BATCH,
    STAGE_COMPILE,
    STAGE_EXECUTE,
    STAGE_DEVICE,
    STAGE_ACK,
})

OUTCOME_OK = "ok"
OUTCOME_REJECTED = "rejected"
OUTCOME_SHED = "shed"
OUTCOME_ERROR = "error"

OUTCOMES = frozenset({
    OUTCOME_OK, OUTCOME_REJECTED, OUTCOME_SHED, OUTCOME_ERROR,
})


class RequestTrace:
    """Span timeline of one request; single-owner, no lock (see module
    docstring for the hand-off argument)."""

    __slots__ = ("trace_id", "kind", "t_start", "marks", "outcome")

    def __init__(self, trace_id: int, kind: str, t_start: float):
        self.trace_id = trace_id
        self.kind = kind
        self.t_start = t_start
        # (stage, t) pairs; span i runs from marks[i-1].t (or t_start)
        # to marks[i].t.  A stage may legitimately repeat (per-item
        # poison retry re-dispatches), so this is a list, not a dict.
        self.marks: List[Tuple[str, float]] = []
        self.outcome: Optional[str] = None

    def stamp(self, stage: str, t: Optional[float] = None) -> None:
        """Close the span ending now (or at explicit monotonic ``t``)."""
        if stage not in SPAN_STAGES:
            raise ValueError(
                f"unregistered span stage {stage!r}; known stages: "
                f"{sorted(SPAN_STAGES)} (register in repro.obs.trace)"
            )
        self.marks.append((stage, time.perf_counter() if t is None else t))

    def spans(self) -> List[Tuple[str, float, float]]:
        """Contiguous ``(stage, t0, t1)`` triples tiling the timeline."""
        out = []
        prev = self.t_start
        for stage, t in self.marks:
            out.append((stage, prev, t))
            prev = t
        return out

    def e2e_s(self) -> float:
        """End-to-end seconds, submit entry to last recorded boundary."""
        return (self.marks[-1][1] - self.t_start) if self.marks else 0.0

    def as_dict(self) -> dict:
        return {
            "trace_id": self.trace_id,
            "kind": self.kind,
            "outcome": self.outcome,
            "t_start": self.t_start,
            "e2e_s": self.e2e_s(),
            "spans": [
                {"stage": s, "t0": t0, "t1": t1, "dur_s": t1 - t0}
                for s, t0, t1 in self.spans()
            ],
        }


class TraceRing:
    """Bounded ring of finished traces (oldest evicted first).

    One leaf lock; ``record`` is O(1) and ``snapshot`` copies the live
    window oldest-to-newest.  Writers never block readers for longer
    than the copy."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._lock = threading.Lock()
        self._buf: List[Optional[RequestTrace]] = [None] * int(capacity)
        self._head = 0  # guarded-by: _lock (next write index)
        self._total = 0  # guarded-by: _lock (lifetime records)

    @property
    def capacity(self) -> int:
        return len(self._buf)

    @property
    def total(self) -> int:
        """Lifetime record count (evictions included)."""
        with self._lock:
            return self._total

    def record(self, trace: RequestTrace) -> None:
        with self._lock:
            self._buf[self._head] = trace
            self._head = (self._head + 1) % len(self._buf)
            self._total += 1

    def clear(self) -> None:
        with self._lock:
            for i in range(len(self._buf)):
                self._buf[i] = None
            self._head = 0

    def snapshot(self) -> List[RequestTrace]:
        """Live window, oldest first."""
        with self._lock:
            n = len(self._buf)
            ordered = [self._buf[(self._head + i) % n] for i in range(n)]
        return [t for t in ordered if t is not None]


class RequestTracer:
    """Sampling front-end over a :class:`TraceRing`.

    ``sample_rate`` in [0, 1] maps to a deterministic stride (every
    Nth submit is traced) so overhead and coverage are load-independent
    and tests are reproducible.  The disabled path (rate 0) is one
    ``None`` check per submit; the enabled path adds one leaf-lock
    counter increment — the "always-on cheap path" in the runbook, with
    the measured cost written to BENCH_obs.json."""

    def __init__(self, sample_rate: float, capacity: int = 2048):
        rate = min(1.0, max(0.0, float(sample_rate)))
        # rate 0 -> stride 0 (disabled); rate 1 -> stride 1 (trace all)
        self._stride = 0 if rate <= 0.0 else max(1, round(1.0 / rate))
        self.ring = TraceRing(capacity)
        self._lock = threading.Lock()
        self._count = 0  # guarded-by: _lock (submit counter for stride)
        self._seq = 0  # guarded-by: _lock (trace id allocator)

    @property
    def enabled(self) -> bool:
        return self._stride > 0

    @property
    def stride(self) -> int:
        return self._stride

    def start(self, kind: str,
              t: Optional[float] = None) -> Optional[RequestTrace]:
        """Return a live trace for this submit, or ``None`` (unsampled /
        disabled).  Callers must treat ``None`` as the no-op path."""
        if self._stride == 0:
            return None
        with self._lock:
            self._count += 1
            if self._count % self._stride:
                return None
            self._seq += 1
            tid = self._seq
        return RequestTrace(tid, kind,
                            time.perf_counter() if t is None else t)

    def finish(self, trace: RequestTrace, outcome: str) -> None:
        """Seal the trace with a terminal outcome and ring-record it.

        Idempotent: failure paths can race resolution paths on the same
        item (e.g. ``_fail_futures`` sweeping a lane whose batch already
        acked); first outcome wins, later calls are no-ops."""
        if outcome not in OUTCOMES:
            raise ValueError(
                f"unknown trace outcome {outcome!r}; known: {sorted(OUTCOMES)}"
            )
        if trace.outcome is not None:
            return
        trace.outcome = outcome
        self.ring.record(trace)


def decompose(traces) -> dict:
    """Per-stage latency budget over ``ok`` traces.

    Returns percentile summaries per stage plus the trace-internal
    end-to-end distribution and the per-trace span-sum distribution.
    Because spans are contiguous, ``span_sum`` equals ``e2e`` up to
    float rounding — exporting both keeps the invariant auditable."""
    per_stage: dict = {}
    e2e: List[float] = []
    sums: List[float] = []
    for tr in traces:
        if tr.outcome != OUTCOME_OK:
            continue
        total = 0.0
        for stage, t0, t1 in tr.spans():
            per_stage.setdefault(stage, []).append(t1 - t0)
            total += t1 - t0
        e2e.append(tr.e2e_s())
        sums.append(total)
    return {
        "stages": {s: percentile_summary(v) for s, v in sorted(per_stage.items())},
        "e2e": percentile_summary(e2e),
        "span_sum": percentile_summary(sums),
        "n_ok": len(e2e),
    }
