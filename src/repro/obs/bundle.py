"""Debug bundles: one JSON file with everything a post-mortem needs.

Written on the three paths where the process state is about to become
unavailable or untrustworthy — ``RecoveryError`` (the node refused to
serve), lane death (a worker exhausted its restart budget), and clean
shutdown — into ``<dir>/debug/``.  The subdirectory is a sibling of the
persist layer's ``snapshots/`` and ``wal/`` trees, which
``persist_dir_in_use`` / recovery never scan, so bundles can safely
land inside a persist directory.

Contents: reason, wall-clock time, runtime config, the full stats
snapshot, the flight-recorder window, the trace-ring window, and any
path-specific extras (e.g. the chained recovery error).  Writes are
atomic (tmp + rename) and best-effort: a failing bundle dump must never
mask the shutdown or the original error, so callers wrap this in
try/except and log.
"""

from __future__ import annotations

import json
import os
import time
from typing import Optional

BUNDLE_SUBDIR = "debug"

_counter = [0]  # disambiguates bundles written within the same ms


def _jsonable(obj):
    """JSON fallback: numpy scalars -> python numbers, else repr."""
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:
            pass
    return repr(obj)


def write_debug_bundle(
    directory: str,
    *,
    reason: str,
    config: Optional[dict] = None,
    stats: Optional[dict] = None,
    events=(),
    traces=(),
    extra: Optional[dict] = None,
) -> str:
    """Write one bundle under ``directory/debug/``; returns the path."""
    out_dir = os.path.join(directory, BUNDLE_SUBDIR)
    os.makedirs(out_dir, exist_ok=True)
    _counter[0] += 1
    slug = "".join(c if c.isalnum() else "-" for c in reason)[:64]
    name = f"bundle-{slug}-{int(time.time() * 1e3)}-{_counter[0]}.json"
    payload = {
        "reason": reason,
        "written_unix_s": time.time(),
        "pid": os.getpid(),
        "config": config or {},
        "stats": stats or {},
        "events": [e.as_dict() for e in events],
        "traces": [t.as_dict() for t in traces],
        "extra": extra or {},
    }
    path = os.path.join(out_dir, name)
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1, default=_jsonable)
        f.write("\n")
    os.replace(tmp, path)
    return path
