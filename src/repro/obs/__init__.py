"""Observability for the serving runtime (docs/observability.md).

Three layers, all dependency-free and lock-disciplined:

* :mod:`repro.obs.trace` — per-request span tracing: a sampled trace
  context rides each submitted request through the full serving path
  (admission -> queue -> batch formation -> compile|execute ->
  device_wait -> ack) and lands in a bounded ring.  The per-stage spans
  are *contiguous by construction*, so they sum to the request's
  end-to-end latency — the repo's ground-truth latency budget
  (asserted against the load generator in ``benchmarks/obs.py``).
* :mod:`repro.obs.events` — the structured event flight recorder: a
  second bounded ring of control-plane events (controller rung moves,
  ladder steps, compaction, pool rebalances, WAL fsync/rotate, snapshot
  cut/publish, worker restarts, injected faults) with names drawn from
  a registered catalog, mirroring ``FaultPlan.KNOWN_SITES``.
* :mod:`repro.obs.export` / :mod:`repro.obs.bundle` — exporters:
  Chrome/Perfetto ``trace_event`` JSON, Prometheus text exposition over
  the unified (flattened) metrics registry, and the post-mortem debug
  bundle written on ``RecoveryError`` / lane death / shutdown.
"""

from repro.obs.events import EVENT_CATALOG, FlightRecorder
from repro.obs.trace import (
    SPAN_STAGES,
    RequestTrace,
    RequestTracer,
    TraceRing,
    decompose,
)

__all__ = [
    "EVENT_CATALOG",
    "FlightRecorder",
    "SPAN_STAGES",
    "RequestTrace",
    "RequestTracer",
    "TraceRing",
    "decompose",
]
