"""Paged-KV LM serving: the paper's memory-block pool applied to decode.

A KV cache grows token-by-token exactly like an IVF list grows vector-by-
vector.  Contiguous caches (the Faiss/RAFT analogue) must be pre-sized to
max_seq per sequence or re-allocated+copied on growth; the block pool gives
O(1) allocation-free appends and per-token memory granularity — identical
discipline to ``repro.core.block_pool``, down to the bump allocator and the
per-sequence block *table*.

Decode attention reads through the table via the Pallas kernel
(``repro.kernels.paged_attention``); appends are a two-scatter update.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.ops import paged_decode_attention
from repro.models.layers import Shard, _qkv, no_shard, rmsnorm
from repro.models.moe import moe_apply
from repro.models.transformer import LMConfig
from repro.models.layers import mlp_swiglu

NULL = jnp.int32(-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PagedKVState:
    k_pool: jax.Array  # [L, P, T, KV, dh]
    v_pool: jax.Array  # [L, P, T, KV, dh]
    block_tables: jax.Array  # [B, NB] i32 (shared across layers)
    seq_lens: jax.Array  # [B] i32
    cur_p: jax.Array  # [] i32 bump pointer (same discipline as IVF pool)


def init_paged_kv(
    cfg: LMConfig,
    batch: int,
    *,
    n_blocks: int,
    block_size: int,
    max_blocks_per_seq: int,
    dtype: Any = None,
) -> PagedKVState:
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads, cfg.d_head)
    return PagedKVState(
        k_pool=jnp.zeros(shape, dtype),
        v_pool=jnp.zeros(shape, dtype),
        block_tables=jnp.full((batch, max_blocks_per_seq), NULL, jnp.int32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
        cur_p=jnp.zeros((), jnp.int32),
    )


def _alloc_blocks(state: PagedKVState, t: int) -> PagedKVState:
    """Bump-allocate one block for every sequence crossing a block boundary
    (the IVF insert allocator, Alg. 2 line 13, verbatim)."""
    b = state.seq_lens.shape[0]
    needs = state.seq_lens % t == 0  # next token starts a fresh block
    order = jnp.cumsum(needs.astype(jnp.int32)) - needs.astype(jnp.int32)
    new_blk = state.cur_p + order
    rows = jnp.where(needs, jnp.arange(b), b)
    cols = jnp.where(needs, state.seq_lens // t, state.block_tables.shape[1])
    tables = state.block_tables.at[rows, cols].set(
        jnp.where(needs, new_blk, NULL), mode="drop"
    )
    return dataclasses.replace(
        state,
        block_tables=tables,
        cur_p=state.cur_p + needs.sum().astype(jnp.int32),
    )


def paged_decode_step(
    params: dict,
    cfg: LMConfig,
    token: jax.Array,  # [B] i32
    state: PagedKVState,
    shard: Shard = no_shard,
):
    """One decode step over the block-pool cache.

    Returns (logits [B, V], state').  State flows through donated jit steps
    just like the IVF pool — no copy of resident KV ever happens.
    """
    b = token.shape[0]
    acfg = cfg.attn_config()
    t = state.k_pool.shape[2]
    state = _alloc_blocks(state, t)
    lens = state.seq_lens
    rows = state.block_tables[jnp.arange(b), lens // t]  # block per seq
    offs = lens % t

    x = params["embed"][token][:, None].astype(cfg.dtype)
    x = shard(x, "act_embed")

    def body(x, inp):
        lp, kp, vp = inp  # kp [P, T, KV, dh]
        xn = rmsnorm(x, lp["attn_norm"])
        q, k_new, v_new = _qkv(lp["attn"], acfg, xn, lens[:, None], shard)
        kp = kp.at[rows, offs].set(k_new[:, 0].astype(kp.dtype))
        vp = vp.at[rows, offs].set(v_new[:, 0].astype(vp.dtype))
        o = paged_decode_attention(
            q[:, 0], kp, vp, state.block_tables, lens + 1
        )  # [B, H, dh]
        o = o.reshape(b, 1, cfg.n_heads * cfg.d_head) @ lp["attn"]["wo"]
        h = x + shard(o, "act_embed")
        hn = rmsnorm(h, lp["mlp_norm"])
        if cfg.moe:
            y, _ = moe_apply(
                lp["moe"], cfg.moe_config(), hn.reshape(-1, cfg.d_model), shard
            )
            y = y.reshape(b, 1, cfg.d_model)
        else:
            y = mlp_swiglu(lp["mlp"], hn, shard)
        return h + y, (kp, vp)

    x, (kps, vps) = jax.lax.scan(
        body, x, (params["layers"], state.k_pool, state.v_pool)
    )
    state = dataclasses.replace(
        state, k_pool=kps, v_pool=vps, seq_lens=lens + 1
    )
    x = rmsnorm(x, params["final_norm"])
    logits = shard(x @ params["lm_head"], "act_vocab")[:, 0]
    return logits, state


def make_paged_decode_fn(cfg: LMConfig, shard: Shard = no_shard):
    """Jitted, state-donated decode step (the serving hot loop)."""

    @jax.jit
    def step(params, token, state):
        return paged_decode_step(params, cfg, token, state, shard)

    return jax.jit(step, donate_argnums=(2,))
