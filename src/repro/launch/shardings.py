"""Sharding rules: logical names -> PartitionSpecs per mesh, per family.

Two surfaces:

* ``make_shard_fn(mesh)`` — the activation-constraint callback threaded
  through the models (``shard(x, logical_name)``); applies
  ``with_sharding_constraint`` under the mesh.
* ``lm_param_specs`` / ``rec_param_specs`` / ``gnn_param_specs`` — pytrees
  of PartitionSpec matching the init functions' outputs, used as
  ``in_shardings`` for the dry-run and the real launchers.

Layout summary (DESIGN.md §7):
  LM      — batch over (pod, data); TP over "model" (qkv/o, ffn, vocab);
            FSDP over "data" for weight matrices (giant configs); experts
            over "model" (EP); decode KV cache shards d_head over "model".
  RecSys  — embedding tables row-sharded over every mesh axis; dense
            towers replicated; batch over (pod, data).
  GNN     — node/edge arrays over (pod, data); channels over "model";
            weights replicated (they are tiny).
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes


# ------------------------------------------------------------ shard_fn ----


def make_shard_fn(mesh, serving: bool = False):
    bd = batch_axes(mesh)

    rules = {
        "act_embed": P(bd, None, None),  # [B, S, D]
        "act_heads": P(bd, None, "model", None),  # [B, S, H, dh]
        "act_kv_heads": P(bd, None, None, None),  # kv heads < model size
        "act_ff": P(bd, None, "model"),  # [B, S, F]
        "act_vocab": P(bd, None, "model"),  # [B, S, V]
        # [E, C, D]: experts over "model" (EP) AND capacity over the batch
        # axes — without the C sharding, GSPMD replicates every expert's
        # compute across the data axis (measured 16x FLOP waste on kimi-k2;
        # EXPERIMENTS.md §Perf iteration 1).
        "moe_experts": P("model", bd, None),
        "act_nodes": P(bd, None, "model"),  # [N, S, C]
        "act_embed_bag": P(bd, None, None),  # [B, F, D]
    }
    if serving:
        # align dispatch buffers with the stationary expert-bank layout
        # (E over "data", features over "model") — a mismatched E axis
        # makes GSPMD regather the 2 TB expert weights per step
        # (EXPERIMENTS.md §Perf cell 2, MoE iteration).
        rules["moe_experts"] = P("data", None, "model")

    def shard(x, name: str):
        spec = rules.get(name)
        if spec is None:
            return x
        # drop axes the array doesn't have (e.g. 3D rule on 4D tensor)
        if len(spec) > x.ndim:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


# ------------------------------------------------------------ LM params ---


def _div(n: int, mesh, axis) -> bool:
    if axis is None:
        return True
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0


def lm_param_specs(cfg, mesh, fsdp: bool | None = None, serving: bool = False) -> dict:
    """PartitionSpec pytree matching init_lm(cfg)'s output.

    ``serving=True`` keeps weights *stationary*: pure TP for dense tensors
    and experts sharded over ("data", "model") for MoE — FSDP's per-step
    weight all-gather is catastrophic at decode batch sizes (§Perf cell 2:
    2 TB of gathers per decode step on kimi-k2 before this split).
    """
    if fsdp is None:
        fsdp = (not serving) and cfg.n_params > 20_000_000_000
    d_axis = "data" if fsdp else None

    attn = {
        "wq": P(None, d_axis, "model"),
        "wk": P(None, d_axis, "model"),
        "wv": P(None, d_axis, "model"),
        "wo": P(None, "model", d_axis),
    }
    if cfg.qkv_bias:
        attn["bq"] = P(None, "model")
        attn["bk"] = P(None, "model")
        attn["bv"] = P(None, "model")
    if cfg.qk_norm:
        attn["q_scale"] = P(None, None)
        attn["k_scale"] = P(None, None)
    layers: dict[str, Any] = {
        "attn": attn,
        "attn_norm": P(None, None),
        "mlp_norm": P(None, None),
    }
    if cfg.moe:
        if serving:
            # stationary expert bank: E over "data", inner feature over
            # "model" -> 1/256 of the 1T params resident per device, zero
            # per-step weight gathers (contractions reduce-scatter tiny
            # activation partials instead).
            layers["moe"] = {
                "router": P(None, None, "model"),
                "w_gate": P(None, "data", "model", None),
                "w_up": P(None, "data", "model", None),
                "w_down": P(None, "data", "model", None),
            }
        else:
            layers["moe"] = {
                "router": P(None, None, "model"),
                "w_gate": P(None, "model", d_axis, None),
                "w_up": P(None, "model", d_axis, None),
                "w_down": P(None, "model", None, d_axis),
            }
    else:
        layers["mlp"] = {
            "w_gate": P(None, d_axis, "model"),
            "w_up": P(None, d_axis, "model"),
            "w_down": P(None, "model", d_axis),
        }
    return {
        "embed": P("model", None),
        "layers": layers,
        "final_norm": P(None),
        "lm_head": P(None, "model"),
    }


def lm_batch_specs(mesh) -> dict:
    bd = batch_axes(mesh)
    return {"tokens": P(bd, None), "labels": P(bd, None)}


def kv_cache_spec(mesh) -> dict:
    bd = batch_axes(mesh)
    # [L, B, S, KV, dh] — SEQUENCE over "model" (flash-decoding split-S).
    # History (§Perf iteration 2): d_head-sharding made every decode layer
    # all-reduce the full [B, KV, G, S] logits (~34 GB/step on llama3
    # decode_32k); with S-sharding only the softmax partials and the
    # [B, KV, G, dh] partial outputs cross the ICI (~600x fewer bytes).
    # kv heads (8) cannot shard a 16-way axis, so heads stay local.
    return {
        "k": P(None, bd, "model", None, None),
        "v": P(None, bd, "model", None, None),
    }


# --------------------------------------------------------- RecSys params --


def rec_param_specs(cfg, mesh) -> dict:
    every = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    table = {"table": P(every, None)}

    def repl(tree):
        return jax.tree.map(lambda _: P(), tree)

    import jax.numpy as jnp

    from repro.models.recsys.models import init_rec

    shapes = jax.eval_shape(
        lambda k: init_rec(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    specs = jax.tree.map(lambda _: P(), shapes)
    specs["embed"] = table
    if "wide" in specs:
        specs["wide"] = {"table": P(every, None)}
    return specs


def rec_batch_specs(cfg, mesh, with_history: bool) -> dict:
    bd = batch_axes(mesh)
    out = {"dense": P(bd, None), "sparse": P(bd, None), "label": P(bd)}
    if with_history:
        out["history"] = P(bd, None)
    return out


# ------------------------------------------------------------ GNN params --


def gnn_param_specs(cfg, mesh) -> dict:
    import jax.numpy as jnp

    from repro.models.gnn.equiformer_v2 import init_equiformer

    shapes = jax.eval_shape(
        lambda k: init_equiformer(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    return jax.tree.map(lambda _: P(), shapes)  # weights are small: replicate


def gnn_batch_specs(mesh) -> dict:
    bd = batch_axes(mesh)
    return {
        "node_feat": P(bd, None),
        "pos": P(bd, None),
        "edge_src": P(bd),
        "edge_dst": P(bd),
        "label": P(bd),
    }


# ------------------------------------------------------ optimizer states --


def opt_state_specs(opt_kind: str, param_specs, param_shapes):
    """Specs for the optimizer state pytree, derived from param specs."""
    if opt_kind == "adamw":
        return {
            "mu": param_specs,
            "nu": param_specs,
            "step": P(),
        }
    if opt_kind == "adafactor":
        leaves_spec = jax.tree.leaves(
            param_specs, is_leaf=lambda x: isinstance(x, P)
        )
        leaves_shape = jax.tree.leaves(param_shapes)
        v = []
        for spec, shp in zip(leaves_spec, leaves_shape):
            t = tuple(spec) + (None,) * (len(shp.shape) - len(tuple(spec)))
            if len(shp.shape) >= 2 and shp.shape[-1] > 1 and shp.shape[-2] > 1:
                v.append({"vr": P(*t[:-1]), "vc": P(*(t[:-2] + t[-1:]))})
            else:
                v.append({"v": P(*t)})
        return {"v": v, "step": P()}
    if opt_kind == "adam8bit":
        leaves_spec = jax.tree.leaves(
            param_specs, is_leaf=lambda x: isinstance(x, P)
        )
        # quantised blocks are flat [n_blocks, block]; leave unspecified
        q = [
            {"mu_q": P(), "mu_s": P(), "nu_q": P(), "nu_lo": P(), "nu_hi": P()}
            for _ in leaves_spec
        ]
        return {"q": q, "step": P()}
    raise ValueError(opt_kind)
