"""Training launcher: real arrays, any arch, checkpoint/restart, preemption.

On this CPU container it drives reduced configs (see examples/train_lm.py);
on a real cluster the same step functions run under the production mesh via
``--mesh single|multi`` (devices permitting).  Fault tolerance:

* periodic async checkpoints (atomic rename, retention)
* SIGTERM -> synchronous final checkpoint (preemption window)
* restart resumes params/opt AND the data cursor (deterministic stream)
* gradient compression (bf16 on the wire) for cross-pod all-reduce

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import signal
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.data.synthetic import token_stream
from repro.models.transformer import init_lm, lm_loss
from repro.optim.optimizers import OptConfig, compress_grads_bf16, make_optimizer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "adam8bit"])
    ap.add_argument("--compress-grads", action="store_true",
                    help="bf16 gradient compression (cross-pod traffic /2)")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    assert spec.family == "lm", "train.py drives LM archs; see examples/"
    cfg = spec.smoke_config if args.smoke else spec.config

    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt_init, opt_update = make_optimizer(OptConfig(kind=args.optimizer, lr=1e-3))
    opt = opt_init(params)

    compress = args.compress_grads

    @jax.jit
    def step(params, opt, tokens, labels):
        (loss, m), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, tokens, labels), has_aux=True
        )(params)
        if compress:
            # bf16 on the wire: the cross-pod all-reduce moves half the
            # bytes; optimizer still accumulates in fp32
            grads = compress_grads_bf16(grads)
        params, opt = opt_update(grads, opt, params)
        return params, opt, loss

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start = 0
    try:
        (params, opt), manifest = mgr.restore(like=(params, opt))
        start = int(manifest["step"])
        print(f"[train] restored step {start} from {args.ckpt_dir}")
    except FileNotFoundError:
        pass

    stream = token_stream(args.batch, args.seq, cfg.vocab, seed=0,
                          start_step=start)

    preempted = {"flag": False}

    def _sigterm(signum, frame):  # preemption: save and exit cleanly
        preempted["flag"] = True

    signal.signal(signal.SIGTERM, _sigterm)

    i = start
    for i in range(start, args.steps):
        batch = next(stream)
        params, opt, loss = step(
            params, opt, jnp.asarray(batch["tokens"]),
            jnp.asarray(batch["labels"]),
        )
        if (i + 1) % args.ckpt_every == 0:
            mgr.async_save(i + 1, (params, opt), extra={"data_cursor": i + 1})
            print(f"[train] step {i+1} loss {float(loss):.4f} (ckpt)")
        if preempted["flag"]:
            print("[train] SIGTERM: synchronous checkpoint + exit")
            mgr.save(i + 1, (params, opt), extra={"data_cursor": i + 1})
            sys.exit(0)
    mgr.wait()
    mgr.save(args.steps, (params, opt), extra={"data_cursor": args.steps})
    print(f"[train] done at step {args.steps}, final loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
