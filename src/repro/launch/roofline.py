"""Roofline analysis from compiled dry-run artifacts (assignment §Roofline).

Three terms per (arch, shape, mesh), in seconds:
  compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = collective_bytes / (chips * 50e9 B/s per ICI link)

``cost_analysis`` counts whole-program FLOPs/bytes (all devices), so both
numerators are divided by the device count; collective bytes are parsed
from the compiled HLO (operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute) and are per-device
already (SPMD module is per-device).
"""

from __future__ import annotations

import json
import re
from collections import defaultdict

# TPU v5e hardware constants (assignment-provided)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every array literal in an HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-collective-kind byte totals (output operand sizes), from HLO."""
    out: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        s = line.strip()
        # match instruction lines: "%x = TYPE op-name(...)" / fusion-less
        m = re.match(r"^[%\w.\-]+\s*=\s*([^=]+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        base = op.rstrip("0123456789.").rstrip("-")
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op.startswith(c + "."):
                out[c] += _shape_bytes(type_str)
                counts[c] += 1
                break
    return {"bytes": dict(out), "counts": dict(counts)}


def roofline_terms(record: dict) -> dict:
    """record = one dryrun.py JSON line -> the three roofline terms."""
    chips = record["n_devices"]
    # cost_analysis runs on the SPMD-partitioned (per-device) module, so
    # flops/bytes are already per-chip — equal to HLO_FLOPs/(chips) of the
    # assignment formula.  (Verified: qwen3 train_4k reports 6.66e13/dev =
    # 1.7e16 global / 256, matching 6*N*D + remat recompute.)
    compute_s = record["flops"] / PEAK_FLOPS
    memory_s = record["bytes_accessed"] / HBM_BW
    coll_bytes = record.get(
        "collective_bytes_corrected",
        sum(record.get("collectives", {}).get("bytes", {}).values()),
    )
    collective_s = coll_bytes / ICI_BW  # HLO is per-device already
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    out = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "collective_bytes": coll_bytes,
        "dominant": dominant,
        "bound_s": max(compute_s, memory_s, collective_s),
    }
    meta = record.get("meta", {})
    if meta.get("n_params"):
        n = meta["n_active"] if "n_active" in meta else meta["n_params"]
        factor = 6 if meta.get("backward") else 2
        model_flops = factor * n * meta["tokens"]  # global
        out["model_flops"] = model_flops
        hlo_global = record["flops"] * chips
        out["useful_fraction"] = model_flops / hlo_global if hlo_global else 0.0
        # roofline fraction: useful model FLOP/s achieved at the bound
        out["roofline_fraction"] = (
            model_flops / chips / PEAK_FLOPS / out["bound_s"]
            if out["bound_s"] else 0.0
        )
    return out


def summarize(path: str) -> list[dict]:
    # keep the LAST record per (arch, shape, mesh): reruns supersede
    by_key: dict[tuple, dict] = {}
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            by_key[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    rows = [
        {**rec, **roofline_terms(rec)}
        for rec in sorted(
            by_key.values(), key=lambda r: (r["arch"], r["shape"], r["mesh"])
        )
    ]
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (
        f"{'arch':<26}{'shape':<15}{'mesh':<9}{'compute_s':>11}"
        f"{'memory_s':>11}{'collect_s':>11}{'dominant':>11}{'useful%':>9}{'roof%':>7}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        uf = r.get("useful_fraction")
        rf = r.get("roofline_fraction")
        lines.append(
            f"{r['arch']:<26}{r['shape']:<15}{r['mesh']:<9}"
            f"{r['compute_s']:>11.2e}{r['memory_s']:>11.2e}"
            f"{r['collective_s']:>11.2e}{r['dominant']:>11}"
            f"{(f'{uf*100:.1f}' if uf is not None else '-'):>9}"
            f"{(f'{rf*100:.1f}' if rf is not None else '-'):>7}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    rows = summarize(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json")
    print(format_table(rows))
