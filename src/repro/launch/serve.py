"""ANNS serving launcher: the paper's system end-to-end.

Builds an index per the paper's config (scaled for this container), starts
the multi-stream runtime, and serves a mixed Poisson workload, printing the
latency statistics that correspond to the paper's Fig. 3 cells.

    PYTHONPATH=src python -m repro.launch.serve --index ivfflat_sift1m \
        --scale 0.02 --qps-search 200 --qps-insert 50 --duration 5
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs.anns import ivfflat_sift1m, ivfpq_dssm40m
from repro.core.ivf import IVFIndex
from repro.core.scheduler import RuntimeConfig, ServingRuntime
from repro.data.synthetic import dssm_like, sift_like


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", default="ivfflat_sift1m",
                    choices=["ivfflat_sift1m", "ivfpq_dssm40m"])
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--mode", default="parallel",
                    choices=["serial", "parallel", "fused"])
    ap.add_argument("--qps-search", type=float, default=200)
    ap.add_argument("--qps-insert", type=float, default=50)
    ap.add_argument("--duration", type=float, default=5.0)
    args = ap.parse_args()

    if args.index == "ivfflat_sift1m":
        cfg = ivfflat_sift1m(args.scale)
        corpus = sift_like(int(1_000_000 * args.scale), cfg.dim, seed=0)
    else:
        cfg = ivfpq_dssm40m(args.scale)
        corpus = dssm_like(int(40_000_000 * args.scale), cfg.dim, seed=0)

    print(f"[serve] building {args.index} at scale {args.scale}: "
          f"{len(corpus)} vectors, {cfg.n_clusters} lists, T_m={cfg.block_size}")
    index = IVFIndex(cfg)
    index.train(corpus)
    for off in range(0, len(corpus), 65536):
        index.add(corpus[off : off + 65536])

    rt = ServingRuntime(
        index, RuntimeConfig(mode=args.mode, nprobe=cfg.nprobe, k=cfg.k,
                             flush_min=32, flush_interval=0.2),
    )
    try:
        from examples.online_serving import drive

        rejected = drive(rt, corpus, qps_search=args.qps_search,
                         qps_insert=args.qps_insert, duration=args.duration)
        s = rt.stats()
        print(f"[serve] mode={args.mode}")
        print(f"  search {s['search'].row()}")
        print(f"  insert {s['insert'].row()}")
        print(f"  rejected={rejected}  corpus={rt.index.ntotal}")
    finally:
        rt.stop()


if __name__ == "__main__":
    main()
