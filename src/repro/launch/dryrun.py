import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialisation (assignment MULTI-POD DRY-RUN §0).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell, ``jax.jit(step).lower(*ShapeDtypeStructs).compile()`` must
succeed on the single-pod (16, 16) mesh AND the multi-pod (2, 16, 16) mesh.
No arrays are ever allocated; ``memory_analysis()`` proves the per-device
fit and ``cost_analysis()`` + the HLO collective scan feed §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b   # one arch
  PYTHONPATH=src python -m repro.launch.dryrun --shape train_4k --multi-pod-only
  ... --out results/dryrun.json
"""

import argparse
import json
import time
import traceback


def _compile_once(spec, shape_name, mesh, cfg_override=None):
    import jax

    from repro.launch.roofline import collective_bytes_from_hlo
    from repro.launch.steps import build_cell

    t0 = time.time()
    with mesh:
        cell = build_cell(spec, shape_name, mesh, cfg_override)
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    t1 = time.time()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    return cell, compiled, {
        "compile_s": round(t1 - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": collective_bytes_from_hlo(compiled.as_text()),
    }


def _coll_sum(c):
    return sum(c["bytes"].values())


def run_cell(spec, shape_name: str, multi_pod: bool):
    """Compile the full cell (+ calibration variants for exact FLOPs).

    The main compile proves the production config lowers/fits (scan over
    layers: realistic buffers, fast compile).  XLA cost analysis counts
    while-loop bodies once, so flops/bytes/collectives are corrected from
    the calibration variants (see steps.calibration_overrides).
    """
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import calibration_overrides

    mesh = make_production_mesh(multi_pod=multi_pod)
    cell, compiled, main = _compile_once(spec, shape_name, mesh)

    mem = compiled.memory_analysis()
    record = {
        "arch": spec.arch_id,
        "shape": shape_name,
        "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "meta": cell.meta,
        **main,
    }
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        record[attr] = getattr(mem, attr, None)

    # ---- exact-FLOP calibration --------------------------------------
    cals = calibration_overrides(spec, shape_name)
    if cals and cals[0][2] == "lm_extrapolate":
        (_, c1, _), (_, c2, _) = cals
        _, _, v1 = _compile_once(spec, shape_name, mesh, c1)
        _, _, v2 = _compile_once(spec, shape_name, mesh, c2)
        layers = spec.config.n_layers
        record["calib"] = {
            "v1_flops": v1["flops"], "v2_flops": v2["flops"],
            "v1_bytes": v1["bytes_accessed"], "v2_bytes": v2["bytes_accessed"],
        }
        for k in ("flops", "bytes_accessed"):
            if v2[k] > v1[k] > 0:
                record[k] = v1[k] + (v2[k] - v1[k]) * (layers - 1)
            else:
                # GSPMD occasionally picks different layouts for the 1- vs
                # 2-layer variant; fall back to linear scaling of the
                # 2-layer module (slight over-count of the non-layer part)
                record[k] = v2[k] * layers / 2
        cb1, cb2 = _coll_sum(v1["collectives"]), _coll_sum(v2["collectives"])
        if cb2 > cb1 > 0:
            record["collective_bytes_corrected"] = cb1 + (cb2 - cb1) * (layers - 1)
        else:
            record["collective_bytes_corrected"] = cb2 * layers / 2
        record["calibration"] = "lm_extrapolate(L1,L2)"
    elif cals and cals[0][2] == "gnn_exact":
        _, c1, _ = cals[0]
        _, _, v1 = _compile_once(spec, shape_name, mesh, c1)
        for k in ("flops", "bytes_accessed"):
            record[k] = v1[k]
        record["collective_bytes_corrected"] = _coll_sum(v1["collectives"])
        record["calibration"] = "gnn_exact(single_chunk)"
    return record


def main() -> None:
    from repro.configs import get_arch, list_archs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    records, failures = [], []
    for arch_id in archs:
        spec = get_arch(arch_id)
        shapes = [args.shape] if args.shape else sorted(spec.shapes)
        for shape_name in shapes:
            for multi_pod in meshes:
                tag = f"{arch_id} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}"
                try:
                    rec = run_cell(spec, shape_name, multi_pod)
                    records.append(rec)
                    print(
                        f"[OK]   {tag}: compile {rec['compile_s']}s, "
                        f"args/dev {rec['argument_size_in_bytes']/2**30:.2f} GiB, "
                        f"temp/dev {rec['temp_size_in_bytes']/2**30:.2f} GiB, "
                        f"flops {rec['flops']:.3e}",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    traceback.print_exc()

    print(f"\n{len(records)} cells compiled, {len(failures)} failed")
    for tag, err in failures:
        print(f"  FAILED: {tag}: {err[:200]}")
    if args.out:
        with open(args.out, "a") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
