"""Production mesh construction (assignment-mandated geometry).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is an
outer data axis whose collectives ride DCI, while "data"/"model" stay on
in-pod ICI.

``make_production_mesh`` is a *function* so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax call).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (data parallel), pod-outer."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_devices(mesh) -> int:
    return mesh.devices.size
