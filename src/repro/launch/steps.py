"""Cell builders: (arch x input-shape x mesh) -> jit-able step + specs.

``build_cell`` returns everything the dry-run needs:
  fn              — the step function
  args            — ShapeDtypeStruct pytree (NO device allocation)
  in_shardings    — NamedSharding pytree (prefix) for jit
  donate_argnums  — donated state positions
  meta            — bookkeeping for the roofline (kind, token counts, ...)

The same builders back the real launchers (train.py / serve.py): swap
ShapeDtypeStructs for real arrays and the jitted step is identical.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec
from repro.launch.mesh import batch_axes
from repro.launch import shardings as sh
from repro.optim.optimizers import OptConfig, make_optimizer

KEY = jax.ShapeDtypeStruct((2,), jnp.uint32)


def _named(mesh, tree):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Any
    args: tuple
    in_shardings: tuple
    donate_argnums: tuple
    meta: dict


# ---------------------------------------------------------------- LM -----


def _lm_opt_kind(cfg) -> str:
    # giant / MoE configs default to Adafactor (DESIGN.md §7)
    return "adafactor" if (cfg.moe or cfg.n_params > 150e9) else "adamw"


def _build_lm(
    spec: ArchSpec, shape_name: str, mesh, cfg_override=None
) -> Cell:
    from repro.models.transformer import (
        decode_step,
        init_kv_cache,
        init_lm,
        lm_loss,
        prefill,
    )

    cfg = cfg_override or spec.config
    shape = spec.shapes[shape_name]
    serving = shape["kind"] in ("prefill", "decode")
    shard = sh.make_shard_fn(mesh, serving=serving)
    bd = batch_axes(mesh)
    b, s = shape["global_batch"], shape["seq_len"]

    param_shapes = jax.eval_shape(lambda k: init_lm(k, cfg), KEY)
    pspecs = sh.lm_param_specs(cfg, mesh, serving=serving)

    if shape["kind"] == "train":
        opt_kind = _lm_opt_kind(cfg)
        opt_init, opt_update = make_optimizer(OptConfig(kind=opt_kind))
        opt_shapes = jax.eval_shape(opt_init, param_shapes)
        ospecs = sh.opt_state_specs(opt_kind, pspecs, param_shapes)

        def train_step(params, opt, batch):
            (loss, m), grads = jax.value_and_grad(
                lambda p: lm_loss(p, cfg, batch["tokens"], batch["labels"], shard),
                has_aux=True,
            )(params)
            params, opt = opt_update(grads, opt, params)
            return params, opt, {"loss": loss, **m}

        batch_shapes = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        return Cell(
            spec.arch_id, shape_name, "train", train_step,
            (param_shapes, opt_shapes, batch_shapes),
            (_named(mesh, pspecs), _named(mesh, ospecs),
             _named(mesh, sh.lm_batch_specs(mesh))),
            (0, 1),
            {"tokens": b * s, "n_params": cfg.n_params,
             "n_active": cfg.n_active_params, "backward": True},
        )

    cache_shapes = jax.eval_shape(
        lambda: init_kv_cache(cfg, b, s)
    )
    cspec = sh.kv_cache_spec(mesh)

    if shape["kind"] == "prefill":
        def prefill_step(params, cache, tokens):
            return prefill(params, cfg, tokens, cache, shard)

        return Cell(
            spec.arch_id, shape_name, "prefill", prefill_step,
            (param_shapes, cache_shapes,
             jax.ShapeDtypeStruct((b, s), jnp.int32)),
            (_named(mesh, pspecs), _named(mesh, cspec),
             NamedSharding(mesh, P(bd, None))),
            (1,),
            {"tokens": b * s, "n_params": cfg.n_params,
             "n_active": cfg.n_active_params, "backward": False},
        )

    if shape["kind"] == "decode":
        def dec_step(params, cache, token, cache_len):
            return decode_step(params, cfg, token, cache, cache_len, shard)

        return Cell(
            spec.arch_id, shape_name, "decode", dec_step,
            (param_shapes, cache_shapes,
             jax.ShapeDtypeStruct((b,), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32)),
            (_named(mesh, pspecs), _named(mesh, cspec),
             NamedSharding(mesh, P(bd)), NamedSharding(mesh, P())),
            (1,),
            {"tokens": b, "n_params": cfg.n_params,
             "n_active": cfg.n_active_params, "backward": False,
             "kv_len": s},
        )
    raise ValueError(shape["kind"])


# --------------------------------------------------------------- GNN -----


def _build_gnn(
    spec: ArchSpec, shape_name: str, mesh, cfg_override=None
) -> Cell:
    from repro.models.gnn.equiformer_v2 import equiformer_loss, init_equiformer

    shape = spec.shapes[shape_name]
    bd = batch_axes(mesh)
    shard = sh.make_shard_fn(mesh)

    def pad32(v: int) -> int:
        # node/edge arrays are jit *inputs* sharded over (pod, data) = up to
        # 32 ways; input shardings require exact divisibility (internal
        # constraints pad, inputs don't), so the cell shapes round up and
        # the loss masks sentinel rows.
        return -(-v // 32) * 32

    base_cfg = cfg_override or spec.config
    if shape["kind"] == "gnn_batched":
        n = pad32(shape["batch"] * shape["n_nodes"])
        e = pad32(shape["batch"] * shape["n_edges"])
        cfg = dataclasses.replace(
            base_cfg, d_feat_in=shape["d_feat"], readout="graph", n_out=1
        )
        batch_shapes = {
            "node_feat": jax.ShapeDtypeStruct((n, shape["d_feat"]), jnp.float32),
            "pos": jax.ShapeDtypeStruct((n, 3), jnp.float32),
            "edge_src": jax.ShapeDtypeStruct((e,), jnp.int32),
            "edge_dst": jax.ShapeDtypeStruct((e,), jnp.int32),
            "graph_ids": jax.ShapeDtypeStruct((n,), jnp.int32),
            "target": jax.ShapeDtypeStruct((shape["batch"],), jnp.float32),
        }
        bspecs = {
            "node_feat": P(bd, None), "pos": P(bd, None),
            "edge_src": P(bd), "edge_dst": P(bd),
            "graph_ids": P(bd), "target": P(bd),
        }
        extra = {"n_graphs": shape["batch"]}
        n_tokens = n
    else:
        if shape["kind"] == "gnn_sampled":
            n, e = pad32(shape["max_nodes"]), pad32(shape["max_edges"])
        else:
            n, e = pad32(shape["n_nodes"]), pad32(shape["n_edges"])
        cfg = dataclasses.replace(base_cfg, d_feat_in=shape["d_feat"])
        batch_shapes = {
            "node_feat": jax.ShapeDtypeStruct((n, shape["d_feat"]), jnp.float32),
            "pos": jax.ShapeDtypeStruct((n, 3), jnp.float32),
            "edge_src": jax.ShapeDtypeStruct((e,), jnp.int32),
            "edge_dst": jax.ShapeDtypeStruct((e,), jnp.int32),
            "label": jax.ShapeDtypeStruct((n,), jnp.int32),
        }
        bspecs = sh.gnn_batch_specs(mesh)
        extra = {}
        n_tokens = n

    param_shapes = jax.eval_shape(lambda k: init_equiformer(k, cfg), KEY)
    pspecs = sh.gnn_param_specs(cfg, mesh)
    opt_init, opt_update = make_optimizer(OptConfig(kind="adamw"))
    opt_shapes = jax.eval_shape(opt_init, param_shapes)
    ospecs = sh.opt_state_specs("adamw", pspecs, param_shapes)

    def train_step(params, opt, batch):
        full = dict(batch, **extra)
        (loss, m), grads = jax.value_and_grad(
            lambda p: equiformer_loss(p, cfg, full, shard), has_aux=True
        )(params)
        params, opt = opt_update(grads, opt, params)
        return params, opt, {"loss": loss}

    return Cell(
        spec.arch_id, shape_name, "train", train_step,
        (param_shapes, opt_shapes, batch_shapes),
        (_named(mesh, pspecs), _named(mesh, ospecs), _named(mesh, bspecs)),
        (0, 1),
        {"tokens": n_tokens, "n_edges": e, "backward": True,
         "n_chunks": -(-e // cfg.edge_chunk)},
    )


# ------------------------------------------------------------- RecSys ----


def _build_rec(
    spec: ArchSpec, shape_name: str, mesh, cfg_override=None
) -> Cell:
    from repro.models.recsys.models import (
        apply_rec,
        init_rec,
        rec_loss,
        score_candidates,
    )

    cfg = cfg_override or spec.config
    if cfg.kind == "dien":
        # unroll the GRU so cost_analysis counts all seq_len steps
        cfg = dataclasses.replace(cfg, unroll=True)
    shape = spec.shapes[shape_name]
    bd = batch_axes(mesh)
    shard = sh.make_shard_fn(mesh)
    b = shape["batch"]
    with_hist = cfg.kind == "dien"

    param_shapes = jax.eval_shape(lambda k: init_rec(k, cfg), KEY)
    pspecs = sh.rec_param_specs(cfg, mesh)

    def batch_struct(bsz):
        out = {
            "dense": jax.ShapeDtypeStruct((bsz, max(cfg.n_dense, 1)), jnp.float32),
            "sparse": jax.ShapeDtypeStruct((bsz, cfg.n_sparse), jnp.int32),
            "label": jax.ShapeDtypeStruct((bsz,), jnp.float32),
        }
        if with_hist:
            out["history"] = jax.ShapeDtypeStruct((bsz, cfg.seq_len), jnp.int32)
        return out

    if shape["kind"] == "rec_train":
        opt_init, opt_update = make_optimizer(OptConfig(kind="adamw"))
        opt_shapes = jax.eval_shape(opt_init, param_shapes)
        ospecs = sh.opt_state_specs("adamw", pspecs, param_shapes)

        def train_step(params, opt, batch):
            (loss, m), grads = jax.value_and_grad(
                lambda p: rec_loss(p, cfg, batch, shard), has_aux=True
            )(params)
            params, opt = opt_update(grads, opt, params)
            return params, opt, {"loss": loss}

        return Cell(
            spec.arch_id, shape_name, "train", train_step,
            (param_shapes, opt_shapes, batch_struct(b)),
            (_named(mesh, pspecs), _named(mesh, ospecs),
             _named(mesh, sh.rec_batch_specs(cfg, mesh, with_hist))),
            (0, 1),
            {"tokens": b, "backward": True},
        )

    if shape["kind"] == "rec_serve":
        def serve_step(params, batch):
            return apply_rec(params, cfg, batch, shard)

        bs = batch_struct(b)
        bs.pop("label")
        specs = sh.rec_batch_specs(cfg, mesh, with_hist)
        specs.pop("label")
        return Cell(
            spec.arch_id, shape_name, "serve", serve_step,
            (param_shapes, bs),
            (_named(mesh, pspecs), _named(mesh, specs)),
            (),
            {"tokens": b, "backward": False},
        )

    if shape["kind"] == "rec_retrieval":
        # pad the candidate corpus to a 512 multiple (shardable over every
        # axis); the b=1 query is replicated (cannot shard batch=1).
        nc = -(-shape["n_candidates"] // 512) * 512
        every = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
        k_top = 100

        from repro.models.recsys.embedding import lookup as emb_lookup

        def retrieval_step(params, batch, cand):
            # §Perf iteration 3: per-shard top-k inside shard_map, then
            # all-gather only k hits per shard (devs*k*8B) instead of
            # letting GSPMD all-gather the full [1, 1M] score row.  This is
            # the ANNS global top-k pattern of DESIGN.md §7.
            emb = emb_lookup(params["embed"], cfg.spec, batch["sparse"], shard)
            query = emb.mean(axis=1)  # [1, D] replicated

            def scorer(q, c):
                s = (q @ c.T)[0]  # local scores [nc_local]
                d, i = jax.lax.top_k(s, k_top)  # local top-k
                # global candidate index = shard offset + local index
                offset = jnp.int32(0)
                mul = 1
                for ax in reversed(every):
                    offset = offset + jax.lax.axis_index(ax) * mul
                    mul *= mesh.shape[ax]
                i = i + offset * c.shape[0]
                d_all = jax.lax.all_gather(d, every, tiled=True)
                i_all = jax.lax.all_gather(i, every, tiled=True)
                dg, sel = jax.lax.top_k(d_all, k_top)
                return dg[None], jnp.take(i_all, sel)[None]

            return jax.shard_map(
                scorer,
                mesh=mesh,
                in_specs=(P(), P(every, None)),
                out_specs=(P(), P()),
                check_vma=False,  # replication via all_gather(tiled)+top_k
            )(query, cand)

        bs = batch_struct(b)
        bs.pop("label")
        repl_specs = {k: P() for k in bs}
        cand_struct = jax.ShapeDtypeStruct((nc, cfg.embed_dim), jnp.float32)
        return Cell(
            spec.arch_id, shape_name, "retrieval", retrieval_step,
            (param_shapes, bs, cand_struct),
            (_named(mesh, pspecs), _named(mesh, repl_specs),
             NamedSharding(mesh, P(every, None))),
            (),
            {"tokens": b, "candidates": nc, "backward": False},
        )
    raise ValueError(shape["kind"])


def build_cell(
    spec: ArchSpec, shape_name: str, mesh, cfg_override=None
) -> Cell:
    return {
        "lm": _build_lm,
        "gnn": _build_gnn,
        "recsys": _build_rec,
    }[spec.family](spec, shape_name, mesh, cfg_override)


def calibration_overrides(spec: ArchSpec, shape_name: str) -> list:
    """Cheap compile variants for exact FLOP accounting.

    XLA cost analysis counts while-loop bodies once, so the layer scan
    under-counts by ~n_layers.  Per family:
    * lm  — two *unrolled* variants with L=1 and L=2 layers: the delta is
            the exact per-layer cost; corrected = v1 + (v2-v1)*(L-1).
    * gnn — one variant with edge_chunk = n_edges (single chunk, exact).
            Only needed when the main cell has >1 chunk (ogb_products).
    * rec — none (dien GRU is unrolled in the main cell).
    Returns [(tag, cfg_override, combine_kind)].
    """
    if spec.family == "lm":
        c1 = dataclasses.replace(spec.config, n_layers=1, unroll=True, remat=False)
        c2 = dataclasses.replace(spec.config, n_layers=2, unroll=True, remat=False)
        return [("L1", c1, "lm_extrapolate"), ("L2", c2, "lm_extrapolate")]
    if spec.family == "gnn":
        shape = spec.shapes[shape_name]
        e = (
            shape["batch"] * shape["n_edges"]
            if shape["kind"] == "gnn_batched"
            else shape.get("max_edges", shape["n_edges"])
        )
        if e > spec.config.edge_chunk:
            c = dataclasses.replace(spec.config, edge_chunk=e)
            return [("onechunk", c, "gnn_exact")]
    return []
