"""Sharded checkpointing with manifest, async save, and elastic restore.

Fault-tolerance contract (DESIGN.md §7):

* ``save`` writes one ``.npz`` per host-shard plus a JSON manifest holding
  (step, mesh shape, RNG key, data cursor, tree structure).  Writes go to a
  temp dir and are atomically renamed — a crash mid-save never corrupts the
  latest checkpoint.  Re-saving an existing step renames the old dir aside,
  publishes, then deletes it: at no instant is the previous good checkpoint
  gone while the new one is unpublished (the earlier rmtree-then-rename had
  exactly that crash window).  ``async_save`` does the device->host transfer
  synchronously (cheap) and the file IO on a background thread, so training
  resumes while bytes hit disk.
* ``restore`` rebuilds the pytree and re-shards it onto the *current* mesh —
  elastic restart onto a different pod count re-shards on load (arrays are
  saved unsharded-logical, so any target mesh works).  Leaves are loaded by
  their explicit ``arr_<i>`` key (never ``data.files`` iteration order), and
  a leaf-count mismatch raises :class:`CheckpointCorruption`, not a bare
  assert.
* ``latest_step`` + retention give crash-loop safety; ``_gc`` also sweeps
  orphaned ``*.tmp`` / ``*.old`` dirs left behind by crashed saves (they
  used to leak forever).  The training loop installs a SIGTERM hook that
  forces a final synchronous save (preemption).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointCorruption(RuntimeError):
    """A checkpoint dir exists but cannot be trusted (missing leaves,
    leaf-count mismatch, unreadable manifest) — named so callers can refuse
    to serve instead of crashing on a bare assert."""


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self._sweep_orphans()

    # ------------------------------------------------------------ paths --
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    @staticmethod
    def _is_published(name: str) -> bool:
        return (
            name.startswith("step_")
            and not name.endswith(".tmp")
            and not name.endswith(".old")
        )

    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if self._is_published(d)
        ]
        return max(steps) if steps else None

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()  # one async save in flight at a time
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        self._write(step, host, str(treedef), extra or {})

    def async_save(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]  # sync D2H
        td = str(treedef)
        ex = dict(extra or {})
        self._pending = threading.Thread(
            target=self._write, args=(step, host, td, ex), daemon=True
        )
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host: list, treedef: str, extra: dict):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):  # leftover of a crashed save of this step
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "shard_0.npz"), *host)
        manifest = {
            "step": step,
            "treedef": treedef,
            "n_leaves": len(host),
            "time": time.time(),
            **extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # publish without a window where no good copy of this step exists:
        # the previous copy (if any) is renamed aside — still restorable up
        # to the instant the fresh one lands — and deleted only afterwards
        old = final + ".old"
        if os.path.exists(old):
            if os.path.exists(final):  # superseded leftover
                shutil.rmtree(old)
            else:  # a previous publish died between its two renames
                os.rename(old, final)
        if os.path.exists(final):
            os.rename(final, old)
        os.rename(tmp, final)  # atomic publish
        if os.path.exists(old):
            shutil.rmtree(old)
        self._gc()

    def _sweep_orphans(self):
        """Crash cleanup.  ``*.tmp`` dirs are unfinished writes — delete
        (there is no way to know the write completed).  A ``*.old`` whose
        base step is still published was superseded — delete; one whose
        base is *missing* is the previous good checkpoint caught between
        the two publish renames — restore it instead of leaking (or worse,
        deleting) it."""
        names = os.listdir(self.dir)
        published = {d for d in names if self._is_published(d)}
        for d in names:
            if not d.startswith("step_"):
                continue
            path = os.path.join(self.dir, d)
            if d.endswith(".tmp"):
                shutil.rmtree(path, ignore_errors=True)
            elif d.endswith(".old"):
                base = d.rsplit(".", 1)[0]
                if base in published:
                    shutil.rmtree(path, ignore_errors=True)
                else:
                    os.rename(path, os.path.join(self.dir, base))

    def _gc(self):
        self._sweep_orphans()
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if self._is_published(d)
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def restore(
        self,
        step: Optional[int] = None,
        like: Any = None,
        shardings: Any = None,
    ):
        """Load a checkpoint.  ``like`` provides the pytree structure;
        ``shardings`` (same structure, NamedSharding leaves) re-shards onto
        the current mesh — elastic restore."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        n = manifest.get("n_leaves")
        if n is None or n != len(data.files):
            raise CheckpointCorruption(
                f"{d}: manifest says {n} leaves, archive holds "
                f"{len(data.files)}"
            )
        # load by explicit index — ``data.files`` iteration order is a zip
        # implementation detail, and trusting it silently permutes leaves
        try:
            host = [data[f"arr_{i}"] for i in range(n)]
        except KeyError as e:
            raise CheckpointCorruption(
                f"{d}: missing leaf {e.args[0]!r} (expected arr_0..arr_{n - 1})"
            ) from e
        if like is None:
            raise ValueError("pass `like` (a pytree template)")
        leaves, treedef = jax.tree.flatten(like)
        if len(leaves) != len(host):
            raise CheckpointCorruption(
                f"{d}: checkpoint has {len(host)} leaves but the `like` "
                f"template has {len(leaves)} — schema mismatch"
            )
        if shardings is not None:
            sleaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec")
            )
            dev = [jax.device_put(h, s) for h, s in zip(host, sleaves)]
        else:
            dev = [jnp.asarray(h) for h in host]
        return jax.tree.unflatten(treedef, dev), manifest
