"""Sharded checkpointing with manifest, async save, and elastic restore.

Fault-tolerance contract (DESIGN.md §7):

* ``save`` writes one ``.npz`` per host-shard plus a JSON manifest holding
  (step, mesh shape, RNG key, data cursor, tree structure).  Writes go to a
  temp dir and are atomically renamed — a crash mid-save never corrupts the
  latest checkpoint.  ``async_save`` does the device->host transfer
  synchronously (cheap) and the file IO on a background thread, so training
  resumes while bytes hit disk.
* ``restore`` rebuilds the pytree and re-shards it onto the *current* mesh —
  elastic restart onto a different pod count re-shards on load (arrays are
  saved unsharded-logical, so any target mesh works).
* ``latest_step`` + retention give crash-loop safety; the training loop
  installs a SIGTERM hook that forces a final synchronous save (preemption).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------ paths --
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        ]
        return max(steps) if steps else None

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()  # one async save in flight at a time
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        self._write(step, host, str(treedef), extra or {})

    def async_save(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]  # sync D2H
        td = str(treedef)
        ex = dict(extra or {})
        self._pending = threading.Thread(
            target=self._write, args=(step, host, td, ex), daemon=True
        )
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host: list, treedef: str, extra: dict):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_0.npz"), *host)
        manifest = {
            "step": step,
            "treedef": treedef,
            "n_leaves": len(host),
            "time": time.time(),
            **extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def restore(
        self,
        step: Optional[int] = None,
        like: Any = None,
        shardings: Any = None,
    ):
        """Load a checkpoint.  ``like`` provides the pytree structure;
        ``shardings`` (same structure, NamedSharding leaves) re-shards onto
        the current mesh — elastic restore."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        host = [data[k] for k in data.files]
        assert like is not None, "pass `like` (a pytree template)"
        leaves, treedef = jax.tree.flatten(like)
        assert len(leaves) == len(host), (len(leaves), len(host))
        if shardings is not None:
            sleaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec")
            )
            dev = [jax.device_put(h, s) for h, s in zip(host, sleaves)]
        else:
            dev = [jnp.asarray(h) for h in host]
        return jax.tree.unflatten(treedef, dev), manifest
