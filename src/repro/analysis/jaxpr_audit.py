"""Layer 1: trace-time audit of every jitted program the system dispatches.

Enumerates every registered search path × payload dtype × rerank from
``resolve_search_impl``/``SEARCH_IMPLS`` plus the mutation and compaction
dispatches mirrored from ``ServingRuntime._build_steps``, traces each with
``jax.make_jaxpr`` on representative ``ShapeDtypeStruct`` state (nothing is
materialized or executed), and checks four properties per trace:

* **intermediate-bytes** — no equation output exceeds the per-path byte
  budget.  This is the ``[C, Q, T]``-class regression the fused kernels
  exist to prevent (pre-PR1 the union path materialized 268 MB to HBM).
* **int8-upcast** — int8/uint8 payloads are never dequantized wholesale
  before the contraction; int8 paths must keep an integer ``dot_general``
  (the MXU contraction PR 3 moved to int8 operands).
* **host-callback** — no ``pure_callback``/``io_callback``/``debug_callback``
  inside a traced program (a silent host sync on the serving hot path).
* **baked-const** — no concrete array above 4 KiB closed over as a jit
  constant (the PR 2 stale-centroids bug class: state must flow through
  the traced arguments, never the closure).

Everything here is geometry-parameterized so budgets are formulas, not
magic numbers; the audit geometry is small enough that the full 42-trace
sweep runs in a couple of seconds on CPU.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding

# ---------------------------------------------------------------------------
# audit geometry + enumeration bookkeeping
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AuditGeometry:
    """Representative shapes: small enough to trace fast, large enough that
    a rematerialized ``[C, Q, T]`` intermediate dwarfs every legitimate one."""

    q: int = 64  # query batch
    dim: int = 64  # D
    block_size: int = 128  # T
    n_blocks: int = 256  # P
    n_clusters: int = 64  # N
    max_chain: int = 8
    nprobe: int = 8
    k: int = 10
    batch: int = 128  # mutation batch rows
    pq_m: int = 8


GEOM = AuditGeometry()

PAYLOAD_CONFIGS = ("float32", "bfloat16", "int8", "pq")
MUTATION_KINDS = ("insert", "delete", "update")

# resolve_search_impl admits exactly these combos (asserted by the audit and
# by tests/test_analysis.py): 6 paths for f32/bf16 + fused rerank (8 each),
# 2 fused paths × rerank for int8 (4), 4 PQ paths + fused rerank (6).
EXPECTED_SEARCH_TRACES = 26
EXPECTED_INVALID_COMBOS = 22
EXPECTED_MUTATION_TRACES = len(MUTATION_KINDS) * len(PAYLOAD_CONFIGS)  # 12
EXPECTED_REARRANGE_TRACES = len(PAYLOAD_CONFIGS)  # 4
EXPECTED_TOTAL_TRACES = (
    EXPECTED_SEARCH_TRACES + EXPECTED_MUTATION_TRACES + EXPECTED_REARRANGE_TRACES
)

# jit constants larger than this are treated as baked-in state
CONST_BYTES_LIMIT = 4 * 2 ** 10

# size-preserving view primitives: XLA lowers these to bitcasts/layout
# changes, so counting their outputs would double-bill every pool-sized
# reshape as a materialization
_VIEW_PRIMS = frozenset({"reshape", "bitcast_convert_type"})

_CALLBACK_PRIMS = ("callback", "outside_call", "host")


def default_kprime(k: int) -> int:
    from repro.core.search import default_kprime as _dk

    return _dk(k)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct state builder (mirrors block_pool.init_state leaf shapes)
# ---------------------------------------------------------------------------


def spec_state(cfg):
    """An ``IVFState`` whose leaves are ``ShapeDtypeStruct``s — traceable by
    ``jax.make_jaxpr`` without allocating a byte of device memory."""
    from repro.core.block_pool import IVFState

    n, p, t, mc = cfg.n_clusters, cfg.n_blocks, cfg.block_size, cfg.max_chain
    S = jax.ShapeDtypeStruct
    f32, i32, u8 = jnp.float32, jnp.int32, jnp.uint8
    scalar = lambda: S((), i32)  # noqa: E731
    return IVFState(
        centroids=S((n, cfg.dim), f32),
        pool_payload=S(cfg.payload_shape(), cfg.payload_dtype()),
        pool_ids=S((p, t), i32),
        pool_scales=S(cfg.scales_shape(), f32),
        pool_live=S((p, t), u8),
        id_map=S((cfg.max_ids,), i32),
        block_owner=S((p,), i32),
        next_block=S((p,), i32),
        cluster_head=S((n,), i32),
        cluster_tail=S((n,), i32),
        cluster_blocks=S((n, mc), i32),
        cluster_nblocks=S((n,), i32),
        cluster_len=S((n,), i32),
        dead_count=S((n,), i32),
        new_since_rearrange=S((n,), i32),
        cur_p=scalar(),
        free_stack=S((p,), i32),
        free_top=scalar(),
        num_vectors=scalar(),
        num_dropped=scalar(),
        num_deleted=scalar(),
        num_missed=scalar(),
        num_unmapped=scalar(),
    )


def _pool_config(payload: str, geom: AuditGeometry):
    from repro.core.block_pool import PoolConfig

    kw = dict(
        n_clusters=geom.n_clusters,
        dim=geom.dim,
        block_size=geom.block_size,
        n_blocks=geom.n_blocks,
        max_chain=geom.max_chain,
    )
    if payload == "pq":
        return PoolConfig(payload="pq", pq_m=geom.pq_m, **kw)
    return PoolConfig(dtype=payload, **kw)


def _spec_pq(geom: AuditGeometry):
    from repro.core.pq import KSUB, PQParams

    return PQParams(
        codebooks=jax.ShapeDtypeStruct(
            (geom.pq_m, KSUB, geom.dim // geom.pq_m), jnp.float32
        )
    )


# ---------------------------------------------------------------------------
# per-path byte budgets
# ---------------------------------------------------------------------------


def search_budget_bytes(
    path: str, payload: str, rerank: bool, geom: AuditGeometry = GEOM
) -> int:
    """2x the documented dominant intermediate of each path (the cost model
    from docs/search_paths.md, evaluated at the audit geometry).

    The gather paths (block_table / chain_walk) and plain-union paths
    materialize large score/gather tensors *by design*; the fused paths'
    whole point is that they do not — their budgets are K'-row sized, so a
    reintroduced ``[C, Q, T]`` materialization fails the audit by an order
    of magnitude rather than a rounding error.
    """
    from repro.core.pq import KSUB

    g = geom
    q, t, d, m = g.q, g.block_size, g.dim, g.pq_m
    c = g.nprobe * g.max_chain  # gathered chain slots per query
    cb = min(g.q * g.nprobe * g.max_chain, g.n_blocks)  # union candidates
    kp = default_kprime(g.k)
    rerank_term = q * kp * d * 4 if rerank else 0
    if path == "block_table":
        # one-HLO gather of every probed chain, scored in f32
        peak = q * c * t * (2 * m * 4 if payload == "pq" else d * 4)
    elif path == "chain_walk":
        # per-hop gather under lax.scan: one chain slot per probe per hop
        peak = q * g.nprobe * t * (2 * m * 4 if payload == "pq" else d * 4)
    elif path in ("union", "union_pallas"):
        # the [CB, Q, T] score tensor is this path's documented cost
        peak = cb * q * t * 4
    elif path == "union_fused":
        # streaming kernel: [Q, K'] writeback + routing prologue; PQ builds
        # the [Q, NP, M, KSUB] LUT, int8 quantizes [Q, NP, D] residuals
        peak = max(
            q * kp * 8,
            q * g.nprobe * d * 4,
            q * g.nprobe * m * KSUB * 4 if payload == "pq" else 0,
            rerank_term,
        )
    elif path == "union_fused_scan":
        # pure-XLA fallback: adds a [Q, chunk * T] score tile per scan step
        chunk = 16 if payload == "pq" else 64
        peak = max(
            q * chunk * t * (4 * m * 4 if payload == "pq" else 4),
            q * g.nprobe * d * 4,
            rerank_term,
        )
    else:  # pragma: no cover - enumeration comes from SEARCH_IMPLS
        raise ValueError(f"no budget model for search path {path!r}")
    return 2 * max(peak, rerank_term)


def mutation_budget_bytes(
    kind: str, payload: str, geom: AuditGeometry = GEOM
) -> int:
    """Mutation steps are donated full-state updates: the budget is the
    largest state leaf (the payload scatter) plus encode/batch terms."""
    from repro.core.pq import KSUB

    g = geom
    esize = {"float32": 4, "bfloat16": 2, "int8": 1, "pq": 1}[payload]
    pool = g.n_blocks * g.block_size * (g.pq_m if payload == "pq" else g.dim)
    id_map = 2 * g.n_blocks * g.block_size * 4
    if kind == "delete":
        peak = max(id_map, g.n_blocks * g.block_size * 4)
    else:  # insert / update (+ PQ encode distance matrix)
        encode = g.batch * g.pq_m * KSUB * 4 if payload == "pq" else 0
        peak = max(pool * esize, id_map, encode)
    return 2 * peak


def rearrange_budget_bytes(payload: str, geom: AuditGeometry = GEOM) -> int:
    g = geom
    esize = {"float32": 4, "bfloat16": 2, "int8": 1, "pq": 1}[payload]
    pool = g.n_blocks * g.block_size * (g.pq_m if payload == "pq" else g.dim)
    return 2 * max(pool * esize, g.n_blocks * g.block_size * 4)


# ---------------------------------------------------------------------------
# jaxpr walkers
# ---------------------------------------------------------------------------


def _subjaxprs(eqn):
    for v in eqn.params.values():
        if hasattr(v, "jaxpr"):  # ClosedJaxpr
            yield v.jaxpr
        elif hasattr(v, "eqns"):  # raw Jaxpr
            yield v


def peak_intermediate_bytes(jaxpr) -> int:
    """Largest equation output in the trace, HBM view.

    Pallas inner jaxprs are skipped — their values are VMEM refs budgeted
    by ``repro.analysis.vmem`` — but a ``pallas_call``'s *outputs* count
    (an oversized kernel writeback is an HBM intermediate like any other).
    """
    peak = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            for v in eqn.outvars:
                peak = max(peak, v.aval.size * v.aval.dtype.itemsize)
            continue
        if eqn.primitive.name not in _VIEW_PRIMS:
            for v in eqn.outvars:
                aval = v.aval
                if hasattr(aval, "shape"):
                    peak = max(peak, aval.size * aval.dtype.itemsize)
        for sub in _subjaxprs(eqn):
            peak = max(peak, peak_intermediate_bytes(sub))
    return peak


def find_int8_upcasts(jaxpr, min_elements: int) -> list:
    """(shape, dtype, size) of every int8/uint8 -> float convert at or above
    ``min_elements`` — pool-scale dequantization before the contraction."""
    out = []
    small = (jnp.int8.dtype, jnp.uint8.dtype)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "convert_element_type":
            src = eqn.invars[0].aval
            dst = eqn.params["new_dtype"]
            if (
                getattr(src, "dtype", None) in small
                and jnp.issubdtype(dst, jnp.floating)
                and src.size >= min_elements
            ):
                out.append((tuple(src.shape), str(dst), int(src.size)))
        for sub in _subjaxprs(eqn):
            out.extend(find_int8_upcasts(sub, min_elements))
    return out


def has_integer_dot(jaxpr) -> bool:
    """Whether any dot_general contracts integer operands (the int8 MXU
    path; disappears if someone dequantizes before the dot)."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general":
            if all(
                jnp.issubdtype(v.aval.dtype, jnp.integer) for v in eqn.invars
            ):
                return True
        for sub in _subjaxprs(eqn):
            if has_integer_dot(sub):
                return True
    return False


def find_callbacks(jaxpr) -> list:
    out = []
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if any(tag in name for tag in _CALLBACK_PRIMS):
            out.append(name)
        for sub in _subjaxprs(eqn):
            out.extend(find_callbacks(sub))
    return out


def find_big_consts(closed_jaxpr, limit: int = CONST_BYTES_LIMIT) -> list:
    """Concrete arrays the traced fn closed over (stale-state bug class)."""
    out = []
    for const in closed_jaxpr.consts:
        arr = np.asarray(const)
        nbytes = arr.size * arr.dtype.itemsize
        if nbytes > limit:
            out.append((tuple(arr.shape), str(arr.dtype), nbytes))
    return out


# ---------------------------------------------------------------------------
# trace enumeration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceCase:
    name: str
    kind: str  # "search" | "mutation" | "rearrange"
    fn: Callable
    args: tuple
    budget_bytes: int
    int8_contract: bool = False  # enforce the integer-MXU rules


def enumerate_traces(geom: AuditGeometry = GEOM) -> tuple:
    """(cases, invalid_combos): every dispatchable program the runtime can
    build, plus the (path, payload, rerank) combos the registry must reject."""
    from repro.core import pq as pqmod
    from repro.core import rearrange
    from repro.core import search as searchmod
    from repro.core.insert import assign_clusters, insert_payload
    from repro.core.mutate import apply_delete, last_occurrence_mask

    S = jax.ShapeDtypeStruct
    queries = S((geom.q, geom.dim), jnp.float32)
    cases: List[TraceCase] = []
    invalid: List[tuple] = []

    for payload in PAYLOAD_CONFIGS:
        cfg = _pool_config(payload, geom)
        state = spec_state(cfg)
        pq = _spec_pq(geom) if payload == "pq" else None

        # ---- search: registry enumeration -----------------------------
        for path in searchmod.SEARCH_IMPLS:
            for rerank in (False, True):
                try:
                    impl = searchmod.resolve_search_impl(cfg, path, rerank)
                except (ValueError, NotImplementedError):
                    invalid.append((path, payload, rerank))
                    continue

                def _search_fn(
                    state, queries, pq=None,
                    _impl=impl, _cfg=cfg, _path=path, _rerank=rerank,
                ):
                    # PQ scoring hooks take pq from the *traced* arguments,
                    # mirroring ServingRuntime._build_steps / make_search_fn
                    # (a concrete closure would trip the baked-const rule,
                    # which is exactly the PR 2 bug it exists to catch)
                    score_fn = (
                        pqmod.pq_score_fn(pq)
                        if pq is not None and _path in ("block_table", "chain_walk")
                        else None
                    )
                    return _impl(
                        _cfg, state, queries,
                        nprobe=geom.nprobe, k=geom.k, score_fn=score_fn,
                        chain_budget=None, pq=pq, rerank=_rerank,
                    )

                args = (state, queries, pq) if payload == "pq" else (state, queries)
                cases.append(
                    TraceCase(
                        name=f"search/{path}/{payload}"
                        + ("/rerank" if rerank else ""),
                        kind="search",
                        fn=_search_fn,
                        args=args,
                        budget_bytes=search_budget_bytes(
                            path, payload, rerank, geom
                        ),
                        int8_contract=payload == "int8",
                    )
                )

        # ---- mutations: the runtime's _build_steps dispatches ----------
        vecs = S((geom.batch, geom.dim), jnp.float32)
        ids = S((geom.batch,), jnp.int32)
        valid = S((geom.batch,), jnp.bool_)

        def _insert(state, vectors, ids, valid, pq=None, _cfg=cfg):
            assign = assign_clusters(state.centroids, vectors)
            if pq is None:
                payload_rows = vectors
            else:
                payload_rows = pqmod.encode(
                    pq, vectors - state.centroids[assign]
                )
            return insert_payload(
                _cfg, state, assign, payload_rows, ids, valid
            )

        def _delete(state, ids, valid, pq=None, _cfg=cfg):
            return apply_delete(_cfg, state, ids, valid)

        def _update(state, vectors, ids, valid, pq=None, _cfg=cfg):
            state = apply_delete(_cfg, state, ids, valid)
            return _insert(
                state, vectors, ids, last_occurrence_mask(ids, valid),
                pq, _cfg=_cfg,
            )

        extra = (pq,) if payload == "pq" else ()
        for kind, fn, margs in (
            ("insert", _insert, (state, vecs, ids, valid) + extra),
            ("delete", _delete, (state, ids, valid) + extra),
            ("update", _update, (state, vecs, ids, valid) + extra),
        ):
            cases.append(
                TraceCase(
                    name=f"mutation/{kind}/{payload}",
                    kind="mutation",
                    fn=fn,
                    args=margs,
                    budget_bytes=mutation_budget_bytes(kind, payload, geom),
                )
            )

        # ---- compaction ------------------------------------------------
        cases.append(
            TraceCase(
                name=f"rearrange/{payload}",
                kind="rearrange",
                fn=rearrange.make_rearrange_fn(cfg, threshold=geom.max_chain // 2),
                args=(state,),
                budget_bytes=rearrange_budget_bytes(payload, geom),
            )
        )

    return cases, invalid


# ---------------------------------------------------------------------------
# the audit
# ---------------------------------------------------------------------------


def audit_trace(
    name: str,
    fn: Callable,
    args: tuple,
    budget_bytes: int,
    int8_contract: bool = False,
    geom: AuditGeometry = GEOM,
) -> List[Finding]:
    """Run the four jaxpr rules on one traced program."""
    findings: List[Finding] = []
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except Exception as e:  # a path that no longer traces is itself a finding
        return [
            Finding(
                rule="trace-error", path=name, line=0,
                message=f"{type(e).__name__}: {e}",
            )
        ]
    peak = peak_intermediate_bytes(closed.jaxpr)
    if peak > budget_bytes:
        findings.append(
            Finding(
                rule="intermediate-bytes", path=name, line=0,
                message=(
                    f"peak intermediate {peak:,} B exceeds the per-path "
                    f"budget {budget_bytes:,} B "
                    f"([C, Q, T]-class rematerialization?)"
                ),
            )
        )
    for prim in find_callbacks(closed.jaxpr):
        findings.append(
            Finding(
                rule="host-callback", path=name, line=0,
                message=f"host callback primitive {prim!r} in traced program",
            )
        )
    for shape, dtype, nbytes in find_big_consts(closed):
        findings.append(
            Finding(
                rule="baked-const", path=name, line=0,
                message=(
                    f"concrete {dtype}{list(shape)} ({nbytes:,} B) closed "
                    "over as a jit constant — pass it through the traced "
                    "arguments (stale-centroids bug class)"
                ),
            )
        )
    if int8_contract:
        # legitimate ceiling: the rerank epilogue dequantizes the gathered
        # [Q, K', D] survivor rows; anything bigger is a pool-scale upcast
        limit = geom.q * default_kprime(geom.k) * geom.dim + 1
        for shape, dtype, size in find_int8_upcasts(closed.jaxpr, limit):
            findings.append(
                Finding(
                    rule="int8-upcast", path=name, line=0,
                    message=(
                        f"int8/uint8 tensor {list(shape)} upcast to {dtype} "
                        f"({size:,} elements) before the contraction"
                    ),
                )
            )
        if not has_integer_dot(closed.jaxpr):
            findings.append(
                Finding(
                    rule="int8-upcast", path=name, line=0,
                    message=(
                        "no integer dot_general in an int8-payload trace — "
                        "the contraction left the integer MXU"
                    ),
                )
            )
    return findings


def run_trace_audit(geom: AuditGeometry = GEOM) -> tuple:
    """(findings, stats) over the full enumeration.

    stats carries the enumeration counts the acceptance tests assert, so a
    registry change that silently drops a path from the audit fails CI.
    """
    cases, invalid = enumerate_traces(geom)
    findings: List[Finding] = []
    stats = {
        "search": sum(1 for c in cases if c.kind == "search"),
        "mutation": sum(1 for c in cases if c.kind == "mutation"),
        "rearrange": sum(1 for c in cases if c.kind == "rearrange"),
        "invalid_combos": len(invalid),
        "total": len(cases),
    }
    if stats["search"] != EXPECTED_SEARCH_TRACES:
        findings.append(
            Finding(
                rule="enumeration", path="registry", line=0,
                message=(
                    f"expected {EXPECTED_SEARCH_TRACES} search combos from "
                    f"SEARCH_IMPLS, enumerated {stats['search']} — update "
                    "the expected counts alongside the registry"
                ),
            )
        )
    if stats["invalid_combos"] != EXPECTED_INVALID_COMBOS:
        findings.append(
            Finding(
                rule="enumeration", path="registry", line=0,
                message=(
                    f"expected {EXPECTED_INVALID_COMBOS} rejected combos, "
                    f"got {stats['invalid_combos']}"
                ),
            )
        )
    for case in cases:
        findings.extend(
            audit_trace(
                case.name, case.fn, case.args, case.budget_bytes,
                int8_contract=case.int8_contract, geom=geom,
            )
        )
    return findings, stats
