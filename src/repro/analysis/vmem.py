"""BlockSpec-derived VMEM budgets for the Pallas kernels.

The estimator does not re-model the kernels: it traces each public
``repro.kernels.ops`` wrapper with ``jax.make_jaxpr`` on the documented
geometry, finds the ``pallas_call`` equation, and reads the per-grid-step
resident set straight off the kernel jaxpr's ref avals (block operands,
outputs, and VMEM scratch — scalar-prefetch SMEM operands excluded).
Whatever BlockSpecs the kernels declare is therefore what gets budgeted;
if a kernel grows an operand, the labelled-operand count check below fails
loudly instead of silently under-reporting.

The same renderer produces the generated section of
``docs/search_paths.md`` (between the ``vmem-budgets`` markers), which
``python -m repro.analysis`` byte-compares against a fresh render — docs
and kernels cannot drift.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding

# Per-core VMEM on current TPU generations (the number the kernel tiling
# was sized against in docs/search_paths.md).
VMEM_LIMIT_BYTES = 16 * 2 ** 20

BEGIN_MARK = "<!-- BEGIN GENERATED: vmem-budgets " \
             "(python -m repro.analysis --write-docs) -->"
END_MARK = "<!-- END GENERATED: vmem-budgets -->"


@dataclasses.dataclass(frozen=True)
class DocGeometry:
    """The documented deployment geometry (paper §3.1: T_m = 1024)."""

    q: int = 128  # query batch
    dim: int = 128  # D
    block_size: int = 1024  # T_m
    n_blocks: int = 64  # P (irrelevant to per-step residents)
    n_clusters: int = 1024  # N (coarse kernel streams over this)
    n_candidates: int = 8  # C (grid size only)
    nprobe: int = 16
    kprime: int = 128
    pq_m: int = 16
    pq_ksub: int = 256


DOC_GEOM = DocGeometry()


@dataclasses.dataclass(frozen=True)
class Resident:
    label: str
    shape: tuple
    dtype: str
    space: str  # "block" (auto-pipelined operand/output) | "scratch"
    nbytes: int


@dataclasses.dataclass(frozen=True)
class KernelBudget:
    kernel: str
    grid: tuple
    residents: List[Resident]
    sort_transient: int  # analytic concat width of the in-kernel sort

    @property
    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self.residents)

    @property
    def peak_bytes(self) -> int:
        return self.total_bytes + self.sort_transient


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    name: str
    # operand labels in kernel-jaxpr order, scalar-prefetch refs excluded
    labels: Sequence[str]
    build: Callable  # geom -> (fn, args) to trace
    # analytic transient: bytes of the widest (dist, id) concat the
    # in-kernel bitonic sort materializes, from the discovered residents
    sort_rows: Callable  # (geom, residents) -> int


def _q_tile_default(kernel_name: str) -> int:
    from repro.kernels import ivf_scan

    fn = getattr(ivf_scan, kernel_name)
    return inspect.signature(fn).parameters["q_tile"].default


def _find_pallas_eqns(jaxpr, out):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None) if hasattr(v, "jaxpr") else (
                v if hasattr(v, "eqns") else None
            )
            if sub is not None:
                _find_pallas_eqns(sub, out)
    return out


def _build_coarse(g: DocGeometry):
    from repro.kernels import ops

    S = jax.ShapeDtypeStruct
    return (
        lambda q, c: ops.coarse_topk(q, c, nprobe=g.nprobe),
        (S((g.q, g.dim), jnp.float32), S((g.n_clusters, g.dim), jnp.float32)),
    )


def _build_block_topk(g: DocGeometry):
    from repro.kernels import ops

    S = jax.ShapeDtypeStruct
    f32, i32, u8 = jnp.float32, jnp.int32, jnp.uint8
    args = (
        S((g.q, g.dim), f32),
        S((g.n_blocks, g.block_size, g.dim), f32),
        S((g.n_candidates,), i32),
        S((g.n_candidates,), i32),
        S((g.n_blocks, g.block_size), i32),
        S((g.n_blocks, g.block_size), u8),
        S((g.q, g.nprobe), i32),
    )
    return lambda *a: ops.ivf_block_topk(*a, kprime=g.kprime), args


def _build_block_topk_int8(g: DocGeometry):
    from repro.kernels import ops

    S = jax.ShapeDtypeStruct
    f32, i32, i8, u8 = jnp.float32, jnp.int32, jnp.int8, jnp.uint8
    args = (
        S((g.q, g.nprobe, g.dim), i8),
        S((g.q, g.nprobe, 2), f32),
        S((g.n_blocks, g.block_size, g.dim), i8),
        S((g.n_blocks, g.block_size), f32),
        S((g.n_candidates,), i32),
        S((g.n_candidates,), i32),
        S((g.n_blocks, g.block_size), i32),
        S((g.n_blocks, g.block_size), u8),
        S((g.q, g.nprobe), i32),
    )
    return lambda *a: ops.ivf_block_topk_int8(*a, kprime=g.kprime), args


def _build_pq_topk(g: DocGeometry):
    from repro.kernels import ops

    S = jax.ShapeDtypeStruct
    f32, i32, u8 = jnp.float32, jnp.int32, jnp.uint8
    args = (
        S((g.q, g.nprobe, g.pq_m, g.pq_ksub), f32),
        S((g.n_blocks, g.block_size, g.pq_m), u8),
        S((g.n_candidates,), i32),
        S((g.n_candidates,), i32),
        S((g.n_blocks, g.block_size), i32),
        S((g.n_blocks, g.block_size), u8),
        S((g.q, g.nprobe), i32),
    )
    return lambda *a: ops.ivf_pq_block_topk(*a, kprime=g.kprime), args


def _build_rerank(g: DocGeometry):
    from repro.kernels import ops

    S = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    args = (
        S((g.q, g.dim), f32),
        S((g.q, g.kprime, g.dim), f32),
        S((g.q, g.kprime), f32),
        S((g.q, g.kprime), i32),
    )
    return lambda *a: ops.rerank_topk(*a), args


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _sort_coarse(g: DocGeometry, residents) -> int:
    # per sort step the kernel concatenates the [qt, NP'] accumulator with
    # the [qt, c_tile] fresh tile, dists + ids at 8 B per entry
    qt = _q_tile_default("coarse_topk")
    npp = _round_up(g.nprobe, 128)
    return qt * (npp + 128) * 8


def _sort_topk(kernel_name: str):
    def _sort(g: DocGeometry, residents) -> int:
        qt = _q_tile_default(kernel_name)
        return qt * (_round_up(g.kprime, 128) + g.block_size) * 8

    return _sort


def _sort_rerank(g: DocGeometry, residents) -> int:
    qt = _q_tile_default("rerank_topk")
    return qt * g.kprime * 8


KERNEL_SPECS: List[KernelSpec] = [
    KernelSpec(
        name="coarse_topk",
        labels=[
            "queries tile",
            "centroid tile",
            "out dists [qt, NP']",
            "out ids [qt, NP']",
            "acc dists (scratch)",
            "acc ids (scratch)",
        ],
        build=_build_coarse,
        sort_rows=_sort_coarse,
    ),
    KernelSpec(
        name="ivf_block_topk",
        labels=[
            "queries tile",
            "probe list [qt, NP]",
            "pool block [T, D]",
            "id row [1, T]",
            "live row [1, T]",
            "out dists [qt, K']",
            "out ids [qt, K']",
            "acc dists (scratch)",
            "acc ids (scratch)",
        ],
        build=_build_block_topk,
        sort_rows=_sort_topk("ivf_block_topk"),
    ),
    KernelSpec(
        name="ivf_block_topk_int8",
        labels=[
            "query residual codes [qt, NP, D]",
            "query meta [qt, NP, 2]",
            "probe list [qt, NP]",
            "code block [T, D]",
            "scale row [1, T]",
            "id row [1, T]",
            "live row [1, T]",
            "out dists [qt, K']",
            "out ids [qt, K']",
            "acc dists (scratch)",
            "acc ids (scratch)",
        ],
        build=_build_block_topk_int8,
        sort_rows=_sort_topk("ivf_block_topk_int8"),
    ),
    KernelSpec(
        name="ivf_pq_block_topk",
        labels=[
            "LUT tile [qt, NP, M, 256]",
            "probe list [qt, NP]",
            "code block [T, M]",
            "id row [1, T]",
            "live row [1, T]",
            "out dists [qt, K']",
            "out ids [qt, K']",
            "acc dists (scratch)",
            "acc ids (scratch)",
        ],
        build=_build_pq_topk,
        sort_rows=_sort_topk("ivf_pq_block_topk"),
    ),
    KernelSpec(
        name="rerank_topk",
        labels=[
            "queries tile",
            "survivor rows [qt, K', D]",
            "dequant scales [qt, K']",
            "locations [qt, K']",
            "out dists [qt, K']",
            "out ids [qt, K']",
        ],
        build=_build_rerank,
        sort_rows=_sort_rerank,
    ),
]


def kernel_budget(spec: KernelSpec, geom: DocGeometry = DOC_GEOM) -> KernelBudget:
    """Trace one kernel wrapper and read its resident set off the jaxpr."""
    fn, args = spec.build(geom)
    closed = jax.make_jaxpr(fn)(*args)
    eqns = _find_pallas_eqns(closed.jaxpr, [])
    if len(eqns) != 1:
        raise AssertionError(
            f"{spec.name}: expected exactly one pallas_call in the trace, "
            f"found {len(eqns)}"
        )
    eqn = eqns[0]
    grid = tuple(eqn.params["grid_mapping"].grid)
    residents = []
    for var in eqn.params["jaxpr"].invars:
        aval = var.aval
        space = str(getattr(aval, "memory_space", "")).lower()
        if "smem" in space:
            continue  # scalar prefetch (block ids / owners) lives in SMEM
        residents.append(
            Resident(
                label="",
                shape=tuple(aval.shape),
                dtype=str(aval.dtype),
                space="scratch" if "vmem" in space else "block",
                nbytes=int(aval.size) * aval.dtype.itemsize,
            )
        )
    if len(residents) != len(spec.labels):
        raise AssertionError(
            f"{spec.name}: kernel has {len(residents)} VMEM refs but "
            f"{len(spec.labels)} documented operands — a kernel operand was "
            f"added or removed; update KERNEL_SPECS and regenerate the docs"
        )
    residents = [
        dataclasses.replace(r, label=lb)
        for r, lb in zip(residents, spec.labels)
    ]
    return KernelBudget(
        kernel=spec.name,
        grid=grid,
        residents=residents,
        sort_transient=spec.sort_rows(geom, residents),
    )


def all_budgets(geom: DocGeometry = DOC_GEOM) -> List[KernelBudget]:
    return [kernel_budget(s, geom) for s in KERNEL_SPECS]


def _fmt_bytes(n: int) -> str:
    if n >= 2 ** 20:
        return f"{n / 2 ** 20:.2f} MiB"
    return f"{n / 2 ** 10:.1f} KiB"


def render_markdown(geom: DocGeometry = DOC_GEOM) -> str:
    """The generated docs section, exclusive of the BEGIN/END markers."""
    g = geom
    lines = [
        "Per-grid-step VMEM residents of every Pallas kernel, read off the",
        "kernel jaxprs' ref avals by `repro.analysis.vmem` (BlockSpec-derived,",
        "not hand-maintained) at the documented geometry: "
        f"Q = {g.q}, D = {g.dim}, T_m = {g.block_size}, "
        f"nprobe = {g.nprobe}, K' = {g.kprime}, "
        f"M = {g.pq_m}, N = {g.n_clusters} centroids.",
        "`sort concat` is the transient (dists, ids) concatenation the",
        "in-kernel bitonic selection materializes at 8 B per entry.",
        "",
    ]
    for b in all_budgets(geom):
        lines.append(f"### `{b.kernel}` — grid {b.grid}")
        lines.append("")
        lines.append("| operand | block shape | dtype | bytes |")
        lines.append("|---|---|---|---|")
        for r in b.residents:
            shape = " × ".join(str(d) for d in r.shape)
            lines.append(f"| {r.label} | {shape} | {r.dtype} | {r.nbytes:,} |")
        lines.append(
            f"| sort concat (transient) | | | {b.sort_transient:,} |"
        )
        lines.append(
            f"| **peak** | | | **{b.peak_bytes:,} "
            f"({_fmt_bytes(b.peak_bytes)})** |"
        )
        lines.append("")
    lines.append(
        f"Every kernel fits the {_fmt_bytes(VMEM_LIMIT_BYTES)}/core VMEM "
        "budget with headroom for double-buffered pipelining; "
        "`python -m repro.analysis` fails if a kernel change pushes a peak "
        "past the limit or makes this section stale."
    )
    return "\n".join(lines)


def _split_docs(text: str, path: str):
    try:
        head, rest = text.split(BEGIN_MARK, 1)
        body, tail = rest.split(END_MARK, 1)
    except ValueError:
        raise AssertionError(
            f"{path}: vmem-budgets markers not found (expected "
            f"{BEGIN_MARK!r} ... {END_MARK!r})"
        )
    return head, body, tail


def check_docs(doc_path: str, geom: DocGeometry = DOC_GEOM) -> List[Finding]:
    """Byte-compare the docs section against a fresh render + VMEM limits."""
    findings: List[Finding] = []
    for b in all_budgets(geom):
        if b.peak_bytes > VMEM_LIMIT_BYTES:
            findings.append(
                Finding(
                    rule="vmem-budget",
                    path=doc_path,
                    line=0,
                    message=(
                        f"kernel {b.kernel} peak VMEM "
                        f"{_fmt_bytes(b.peak_bytes)} exceeds the "
                        f"{_fmt_bytes(VMEM_LIMIT_BYTES)}/core budget"
                    ),
                )
            )
    try:
        with open(doc_path, encoding="utf-8") as f:
            text = f.read()
        _, body, _ = _split_docs(text, doc_path)
    except (OSError, AssertionError) as e:
        findings.append(
            Finding(rule="vmem-docs", path=doc_path, line=0, message=str(e))
        )
        return findings
    expected = "\n" + render_markdown(geom) + "\n"
    if body != expected:
        findings.append(
            Finding(
                rule="vmem-docs",
                path=doc_path,
                line=0,
                message=(
                    "generated VMEM section is stale — run "
                    "`python -m repro.analysis --write-docs`"
                ),
            )
        )
    return findings


def write_docs(doc_path: str, geom: DocGeometry = DOC_GEOM) -> None:
    with open(doc_path, encoding="utf-8") as f:
        text = f.read()
    head, _, tail = _split_docs(text, doc_path)
    new = head + BEGIN_MARK + "\n" + render_markdown(geom) + "\n" + END_MARK + tail
    with open(doc_path, "w", encoding="utf-8") as f:
        f.write(new)
