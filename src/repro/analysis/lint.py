"""Layer 2: repo-specific AST lint over the serving core.

Each rule is a function ``(module: LintModule) -> list[Finding]`` registered
in ``repro.analysis.rules``.  The driver owns the part every rule needs and
``ast`` alone cannot provide: the comment map (annotations like
``# guarded-by: _state_lock`` and suppressions like ``# unlocked-ok: ...``
live in comments, which the parser throws away).

Suppression comments must carry a justification after the colon; an empty
one is itself a finding (``invalid-suppression``) — a silenced check with
no recorded reason is how suppressions rot.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import os
import tokenize
from typing import Dict, List

from repro.analysis.findings import Finding

# lint scope for a full-repo run: the serving core and everything that
# drives it.  tests/ is excluded on purpose — the seeded-bad fixtures
# under tests/fixtures/analysis/ must flag when linted *directly*, not
# poison the clean-repo pass.
DEFAULT_ROOTS = ("src/repro", "examples", "benchmarks")


@dataclasses.dataclass
class LintModule:
    path: str  # repo-relative, for findings
    tree: ast.Module
    comments: Dict[int, str]  # line -> comment text (sans leading '#')
    source_lines: List[str]

    def comment(self, line: int) -> str:
        return self.comments.get(line, "")

    def tagged(self, line: int, tag: str):
        """Value of an ``# <tag>: <value>`` annotation on ``line``, or on a
        comment-only line directly above (for annotations that do not fit
        trailing).  A *trailing* comment annotates only its own line — a
        code line above must not leak its annotation downward."""
        candidates = [line]
        if 2 <= line <= len(self.source_lines) + 1:
            prev = self.source_lines[line - 2].lstrip()
            if prev.startswith("#"):
                candidates.append(line - 1)
        for ln in candidates:
            text = self.comment(ln)
            if text.startswith(tag + ":"):
                return text[len(tag) + 1:].strip()
            # same-line code comments may chain: "# guarded-by: x" only
            if tag + ":" in text:
                return text.split(tag + ":", 1)[1].strip()
        return None


def _comment_map(source: str) -> Dict[int, str]:
    out: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string.lstrip("#").strip()
    except tokenize.TokenError:  # pragma: no cover - half-written files
        pass
    return out


def load_module(path: str, repo_root: str = ".") -> LintModule:
    abspath = os.path.join(repo_root, path) if not os.path.isabs(path) else path
    with open(abspath, encoding="utf-8") as f:
        source = f.read()
    return LintModule(
        path=os.path.relpath(abspath, repo_root),
        tree=ast.parse(source, filename=path),
        comments=_comment_map(source),
        source_lines=source.splitlines(),
    )


def check_suppression(
    mod: LintModule, line: int, tag: str
) -> "tuple[bool, List[Finding]]":
    """(suppressed?, findings).  A ``# <tag>: <why>`` comment suppresses the
    rule at ``line`` iff the justification is non-empty."""
    reason = mod.tagged(line, tag)
    if reason is None:
        return False, []
    if not reason:
        return True, [
            Finding(
                rule="invalid-suppression",
                path=mod.path,
                line=line,
                message=(
                    f"'# {tag}:' suppression without a justification — "
                    "say why the unchecked access is safe"
                ),
            )
        ]
    return True, []


def iter_python_files(repo_root: str, roots=DEFAULT_ROOTS):
    for root in roots:
        base = os.path.join(repo_root, root)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.relpath(os.path.join(dirpath, fn), repo_root)


def lint_file(path: str, repo_root: str = ".") -> List[Finding]:
    from repro.analysis.rules import ALL_RULES

    mod = load_module(path, repo_root)
    findings: List[Finding] = []
    seen = set()
    for rule in ALL_RULES:
        for finding in rule(mod):
            if finding not in seen:  # rules may overlap on one access
                seen.add(finding)
                findings.append(finding)
    return findings


def lint_repo(repo_root: str = ".", roots=DEFAULT_ROOTS) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_python_files(repo_root, roots):
        findings.extend(lint_file(path, repo_root))
    return findings
