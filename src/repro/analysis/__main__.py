"""``python -m repro.analysis`` — run every static layer, exit nonzero on
findings.

    python -m repro.analysis                  # lint + trace audit + vmem docs
    python -m repro.analysis --fail-on-findings   # same (explicit, for CI)
    python -m repro.analysis --write-docs     # regenerate docs vmem section
    python -m repro.analysis --fixture tests/fixtures/analysis/int8_upcast.py
"""

from __future__ import annotations

import argparse
import importlib.util
import os
import sys
from typing import List

from repro.analysis.findings import Finding


def _run_fixture(path: str) -> List[Finding]:
    """Seeded-bad snippets declare FIXTURE_KIND = 'lint' | 'trace'."""
    spec = importlib.util.spec_from_file_location("_analysis_fixture", path)
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    kind = getattr(module, "FIXTURE_KIND", None)
    if kind == "lint":
        from repro.analysis.lint import lint_file

        return lint_file(
            os.path.basename(path), repo_root=os.path.dirname(path) or "."
        )
    if kind == "trace":
        from repro.analysis.jaxpr_audit import audit_trace

        case = module.build()
        return audit_trace(
            case.get("name", os.path.basename(path)),
            case["fn"],
            case["args"],
            case["budget_bytes"],
            int8_contract=case.get("int8_contract", False),
        )
    raise SystemExit(
        f"{path}: fixture must declare FIXTURE_KIND = 'lint' | 'trace'"
    )


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.analysis")
    parser.add_argument(
        "--fail-on-findings", action="store_true",
        help="exit nonzero when findings exist (the default; kept explicit "
             "so the CI invocation documents its contract)",
    )
    parser.add_argument(
        "--write-docs", action="store_true",
        help="regenerate the generated VMEM section of docs/search_paths.md",
    )
    parser.add_argument(
        "--fixture", metavar="PATH",
        help="run the analyzers on a single fixture file instead of the repo",
    )
    parser.add_argument(
        "--root", default=".", help="repo root (default: cwd)"
    )
    args = parser.parse_args(argv)

    if args.fixture:
        findings = _run_fixture(args.fixture)
        stats = None
    else:
        from repro.analysis import DOCS_SEARCH_PATHS, run_all
        from repro.analysis import vmem

        if args.write_docs:
            vmem.write_docs(os.path.join(args.root, DOCS_SEARCH_PATHS))
            print(f"regenerated vmem section of {DOCS_SEARCH_PATHS}")
        findings, stats = run_all(args.root)

    for finding in findings:
        print(finding)
    if stats is not None:
        print(
            f"audited {stats['total']} traces "
            f"({stats['search']} search, {stats['mutation']} mutation, "
            f"{stats['rearrange']} rearrange; "
            f"{stats['invalid_combos']} combos rejected by the registry), "
            f"{len(findings)} finding(s)"
        )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
