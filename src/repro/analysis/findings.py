"""The one currency every analysis layer trades in."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str  # stable rule id (docs/static_analysis.md catalogs them)
    path: str  # file (lint) or trace name (jaxpr audit)
    line: int  # 0 when the finding has no source line (trace audit)
    message: str

    def __str__(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"[{self.rule}] {loc}: {self.message}"
