"""Persist file-format strings must be named module-level constants.

The durability layer's on-disk formats (WAL record headers, snapshot
manifests) are cache-key-relevant config: two builds that disagree about
a ``struct`` layout corrupt each other's files exactly the way two jit
caches keyed on half the config serve each other's programs.  The repo's
convention (``repro.persist.wal``) is one named UPPER_CASE constant per
layout — ``REC_HEADER_FMT = "<IIQB3x"`` — referenced everywhere the
bytes are produced or parsed, next to the format-version constant that
must be bumped when it changes.

This rule flags any ``struct.pack/unpack/unpack_from/pack_into/calcsize``
call whose format argument is an *inline string literal*: an anonymous
layout that version-bump discipline cannot see.  Assigning the literal
to an UPPER_CASE module-level name is the fix; a deliberate throwaway
(e.g. a test forging a corrupt header) carries ``# format-ok: <why>``.
"""

from __future__ import annotations

import ast
from typing import List

from repro.analysis.findings import Finding
from repro.analysis.lint import LintModule, check_suppression

_STRUCT_FNS = {
    "struct.pack", "struct.unpack", "struct.unpack_from",
    "struct.pack_into", "struct.calcsize", "struct.iter_unpack",
    "struct.Struct",
}


def _dotted(node):
    parts = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def check(mod: LintModule) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        if _dotted(node.func) not in _STRUCT_FNS:
            continue
        fmt = node.args[0]
        if not (isinstance(fmt, ast.Constant) and isinstance(fmt.value, str)):
            continue  # a Name — the convention this rule wants
        suppressed, extra = check_suppression(mod, node.lineno, "format-ok")
        findings.extend(extra)
        if not suppressed:
            findings.append(
                Finding(
                    rule="persist-format",
                    path=mod.path,
                    line=node.lineno,
                    message=(
                        f"inline struct format {fmt.value!r}: on-disk "
                        "layouts are versioned config — assign it to an "
                        "UPPER_CASE module constant (see repro.persist.wal) "
                        "so format breaks are visible and greppable"
                    ),
                )
            )
    return findings
