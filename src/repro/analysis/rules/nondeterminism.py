"""No wall-clock or host RNG inside traced functions.

``time.time()`` inside a jitted function doesn't measure anything — it
runs once at trace time and bakes a constant timestamp into the program;
``np.random``/``random`` likewise freeze one sample forever.  The rule
flags those calls inside any function it can prove is traced:

* decorated with ``@jax.jit`` / ``@partial(jax.jit, ...)``;
* passed by name to ``jax.jit(fn, ...)`` or ``pl.pallas_call(kernel, ...)``
  anywhere in the same module;
* explicitly marked ``# traced-fn`` on its ``def`` line (search impls and
  kernel bodies that are only ever called from inside a trace).

``jax.random`` is fine (functional, keyed); a deliberate trace-time value
carries ``# nondet-ok: <why>``.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.findings import Finding
from repro.analysis.lint import LintModule, check_suppression

_BANNED_EXACT = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "datetime.now", "datetime.utcnow", "datetime.datetime.now",
    "datetime.datetime.utcnow",
}
_BANNED_PREFIX = ("random.", "np.random.", "numpy.random.")


def _dotted(node) -> Optional[str]:
    parts = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_expr(node) -> bool:
    """jax.jit / jit, possibly wrapped in (functools.)partial(jax.jit, ...)."""
    name = _dotted(node)
    if name in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call):
        fname = _dotted(node.func)
        if fname in ("partial", "functools.partial") and node.args:
            return _is_jit_expr(node.args[0])
        return _is_jit_expr(node.func)
    return False


def _traced_by_reference(tree) -> Set[str]:
    """Function names passed to jax.jit(...) / pl.pallas_call(...)."""
    traced: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        fname = _dotted(node.func) or ""
        if fname in ("jax.jit", "jit") or fname.endswith("pallas_call"):
            first = node.args[0]
            if isinstance(first, ast.Name):
                traced.add(first.id)
    return traced


def _is_traced(mod: LintModule, func, by_ref: Set[str]) -> bool:
    if func.name in by_ref:
        return True
    if mod.tagged(func.lineno, "traced-fn") is not None:
        return True
    return any(_is_jit_expr(d) for d in func.decorator_list)


def check(mod: LintModule) -> List[Finding]:
    findings: List[Finding] = []
    by_ref = _traced_by_reference(mod.tree)

    def scan(func):
        for node in ast.walk(func):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            if name is None:
                continue
            if name in _BANNED_EXACT or any(
                name.startswith(p) for p in _BANNED_PREFIX
            ):
                suppressed, extra = check_suppression(
                    mod, node.lineno, "nondet-ok"
                )
                findings.extend(extra)
                if not suppressed:
                    findings.append(
                        Finding(
                            rule="nondeterminism",
                            path=mod.path,
                            line=node.lineno,
                            message=(
                                f"{name}() inside traced function "
                                f"{func.name!r} runs once at trace time and "
                                "bakes in a constant"
                            ),
                        )
                    )

    for func in ast.walk(mod.tree):
        if isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _is_traced(mod, func, by_ref):
                scan(func)
    return findings
