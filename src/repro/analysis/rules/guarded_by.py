"""Lock-discipline checker for ``# guarded-by:`` annotated fields.

Annotation (on the field's assignment in ``__init__``):

    self._accepting = True            # guarded-by: _submit_lock
    self.index = index                # guarded-by: _state_lock [state, _next_id]

The bare form guards the attribute itself; the bracketed form guards the
named sub-attributes of a held object (``self.index.state`` must be read
under ``_state_lock``; ``self.index.pq`` is immutable and stays free).

Every access outside ``__init__`` must then be lexically inside
``with self.<lock>:``.  Helpers that are only ever called with the lock
held declare it on their ``def`` line:

    def _current_budget(self):  # holds: _state_lock

(call sites of a ``# holds:``-annotated helper are then checked for the
declared lock too), and individually-safe accesses carry a justified
suppression:

    self._check_accepting()  # unlocked-ok: racy fast-path, rechecked under lock

The check is lexical by design: a nested function's body runs later, so
entering one resets the held-lock set (a closure traced under the lock
does not execute under it).
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Set

from repro.analysis.findings import Finding
from repro.analysis.lint import LintModule, check_suppression

_ANNOT_RE = re.compile(r"^(\w+)(?:\s*\[([^\]]*)\])?$")


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    field: str
    lock: str
    attrs: Optional[frozenset]  # None = the field itself; else sub-attrs
    line: int


def _attr_path(node) -> Optional[tuple]:
    """('index', 'state') for ``self.index.state``; None if not self-rooted."""
    parts = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name) and cur.id == "self":
        return tuple(reversed(parts))
    return None


def _with_locks(node) -> Set[str]:
    locks: Set[str] = set()
    for item in node.items:
        path = _attr_path(item.context_expr)
        if path is not None and len(path) == 1:
            locks.add(path[0])
    return locks


def _holds(mod: LintModule, func) -> Set[str]:
    declared = mod.tagged(func.lineno, "holds")
    if not declared:
        return set()
    return {name.strip() for name in declared.split(",") if name.strip()}


def _collect_specs(mod: LintModule, cls) -> Dict[str, FieldSpec]:
    specs: Dict[str, FieldSpec] = {}
    for node in ast.walk(cls):
        if not isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            continue
        annot = mod.tagged(node.lineno, "guarded-by")
        if annot is None:
            continue
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        for target in targets:
            path = _attr_path(target)
            if path is None or len(path) != 1:
                continue
            m = _ANNOT_RE.match(annot)
            if m is None:
                continue
            lock, attrs = m.group(1), m.group(2)
            specs[path[0]] = FieldSpec(
                field=path[0],
                lock=lock,
                attrs=(
                    frozenset(a.strip() for a in attrs.split(",") if a.strip())
                    if attrs is not None
                    else None
                ),
                line=node.lineno,
            )
    return specs


def _match(specs: Dict[str, FieldSpec], path: tuple) -> Optional[FieldSpec]:
    if not path or path[0] not in specs:
        return None
    spec = specs[path[0]]
    if spec.attrs is None:
        return spec if len(path) == 1 else None
    return spec if len(path) == 2 and path[1] in spec.attrs else None


def check(mod: LintModule) -> List[Finding]:
    findings: List[Finding] = []

    def check_class(cls, specs: Dict[str, FieldSpec],
                    holds_map: Dict[str, Set[str]]):
        def walk(node, held: Set[str]):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    walk(item.context_expr, held)
                    if item.optional_vars is not None:
                        walk(item.optional_vars, held)
                inner = held | _with_locks(node)
                for stmt in node.body:
                    walk(stmt, inner)
                return
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # a nested def's body runs after the enclosing with released
                for child in ast.iter_child_nodes(node):
                    walk(child, _holds(mod, node))
                return
            if isinstance(node, ast.Lambda):
                walk(node.body, set())
                return
            if isinstance(node, ast.Call) and isinstance(
                node.func, ast.Attribute
            ):
                # calling a helper that declares "# holds: X" is itself an
                # access that needs X held at the call site
                fpath = _attr_path(node.func)
                if fpath is not None and len(fpath) == 1:
                    missing = holds_map.get(fpath[0], set()) - held
                    if missing:
                        suppressed, extra = check_suppression(
                            mod, node.lineno, "unlocked-ok"
                        )
                        findings.extend(extra)
                        if not suppressed:
                            findings.append(
                                Finding(
                                    rule="guarded-by",
                                    path=mod.path,
                                    line=node.lineno,
                                    message=(
                                        f"call to self.{fpath[0]}() outside "
                                        "'with self."
                                        f"{', '.join(sorted(missing))}:' "
                                        "(its def declares '# holds:')"
                                    ),
                                )
                            )
            if isinstance(node, ast.Attribute):
                path = _attr_path(node)
                spec = _match(specs, path) if path else None
                if spec is not None and spec.lock not in held:
                    if node.lineno != spec.line:  # annotation line registers
                        suppressed, extra = check_suppression(
                            mod, node.lineno, "unlocked-ok"
                        )
                        findings.extend(extra)
                        if not suppressed:
                            dotted = "self." + ".".join(path)
                            findings.append(
                                Finding(
                                    rule="guarded-by",
                                    path=mod.path,
                                    line=node.lineno,
                                    message=(
                                        f"{dotted} accessed outside "
                                        f"'with self.{spec.lock}:' (declared "
                                        f"guarded-by at line {spec.line})"
                                    ),
                                )
                            )
            for child in ast.iter_child_nodes(node):
                walk(child, held)

        for item in cls.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if item.name == "__init__":
                continue  # construction precedes every worker thread
            for child in ast.iter_child_nodes(item):
                walk(child, _holds(mod, item))

    for cls in ast.walk(mod.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        specs = _collect_specs(mod, cls)
        holds_map: Dict[str, Set[str]] = {}
        for item in cls.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                declared = _holds(mod, item)
                if declared:
                    holds_map[item.name] = declared
        if not specs and not holds_map:
            continue
        check_class(cls, specs, holds_map)
    return findings
