"""Counters mutate only through ``metrics.CounterSet`` (PR 6 bug class).

Two checks:

* **counter-race** — in the serving-concurrency modules (runtime,
  admission, metrics, faults, scheduler, ivf), a bare
  ``self.<attr> += n`` outside any ``with self.<lock>:`` block is a lost
  update waiting for two threads.  Locked increments (the admission gate's
  ``self._pending += rows`` under ``_cond``) are fine; genuinely
  single-writer fields carry ``# counter-ok: <why>``.
* **counter-poke** — nothing outside the owning object reaches into a
  private ``_counters`` CounterSet (``rt._counters._counts[...] += 1``
  bypasses its lock *and* its snapshot semantics).  Applies everywhere the
  linter looks, examples and benchmarks included.
"""

from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.findings import Finding
from repro.analysis.lint import LintModule, check_suppression

# the concurrency surface: modules whose objects are shared across the
# serving worker threads.  baselines.py (single-threaded host reference
# loops) is deliberately out of scope.
_SERVING_MODULES = (
    "src/repro/core/runtime.py",
    "src/repro/core/admission.py",
    "src/repro/core/metrics.py",
    "src/repro/core/faults.py",
    "src/repro/core/scheduler.py",
    "src/repro/core/ivf.py",
)


def _self_rooted(node) -> bool:
    cur = node
    while isinstance(cur, (ast.Attribute, ast.Subscript)):
        cur = cur.value
    return isinstance(cur, ast.Name) and cur.id == "self"


def _with_self_locks(node) -> Set[str]:
    locks: Set[str] = set()
    for item in node.items:
        expr = item.context_expr
        if (
            isinstance(expr, ast.Attribute)
            and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"
        ):
            locks.add(expr.attr)
    return locks


def _check_aug_assigns(mod: LintModule) -> List[Finding]:
    findings: List[Finding] = []

    def walk(node, locked: bool):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            inner = locked or bool(_with_self_locks(node))
            for item in node.items:
                walk(item.context_expr, locked)
            for stmt in node.body:
                walk(stmt, inner)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            held = bool(mod.tagged(node.lineno, "holds"))
            for child in ast.iter_child_nodes(node):
                walk(child, held)
            return
        if isinstance(node, ast.AugAssign) and _self_rooted(node.target):
            if not locked:
                suppressed, extra = check_suppression(
                    mod, node.lineno, "counter-ok"
                )
                findings.extend(extra)
                if not suppressed:
                    findings.append(
                        Finding(
                            rule="counter-race",
                            path=mod.path,
                            line=node.lineno,
                            message=(
                                "augmented assignment to shared state "
                                "outside any lock — route it through "
                                "metrics.CounterSet or hold the owning lock"
                            ),
                        )
                    )
        for child in ast.iter_child_nodes(node):
            walk(child, locked)

    for cls in ast.walk(mod.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        for item in cls.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if item.name == "__init__":
                    continue
                held = bool(mod.tagged(item.lineno, "holds"))
                for child in ast.iter_child_nodes(item):
                    walk(child, held)
    return findings


def _check_counter_pokes(mod: LintModule) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Attribute):
            continue
        # <expr>._counters.<private> where <expr> is not `self`
        value = node.value
        if not (
            isinstance(value, ast.Attribute)
            and value.attr == "_counters"
            and node.attr.startswith("_")
        ):
            continue
        root = value.value
        if isinstance(root, ast.Name) and root.id == "self":
            continue
        suppressed, extra = check_suppression(mod, node.lineno, "counter-ok")
        findings.extend(extra)
        if not suppressed:
            findings.append(
                Finding(
                    rule="counter-poke",
                    path=mod.path,
                    line=node.lineno,
                    message=(
                        f"private counter access '._counters.{node.attr}' "
                        "from outside the owning object — use the public "
                        "stats()/snapshot() API"
                    ),
                )
            )
    return findings


def check(mod: LintModule) -> List[Finding]:
    findings = _check_counter_pokes(mod)
    # bare-filename paths are fixtures linted directly by the tests/CLI
    if mod.path in _SERVING_MODULES or "/" not in mod.path:
        findings.extend(_check_aug_assigns(mod))
    return findings
