"""Flight-recorder event names and span stages must be named constants.

The observability layer (``repro.obs``) registers every flight-recorder
event name in ``EVENT_CATALOG`` and every span stage in ``SPAN_STAGES``:
``record_event`` raises on an unknown name precisely so a typo'd emission
site fails loudly instead of producing an event no dashboard query ever
matches.  That guarantee only holds if call sites reference the registered
``EV_*`` / ``STAGE_*`` constants — an inline string literal re-introduces
the typo class at every emission site and unmoors grep from the catalog.

This rule flags any ``*.record_event(...)`` or ``*.stamp(...)`` call whose
first argument is an inline string literal.  Passing the module constant
(``repro.obs.events`` / ``repro.obs.trace``) is the fix; a deliberate
literal (e.g. a test asserting the unknown-name ValueError) carries
``# event-ok: <why>``.
"""

from __future__ import annotations

import ast
from typing import List

from repro.analysis.findings import Finding
from repro.analysis.lint import LintModule, check_suppression

#: Attribute names whose first positional argument is a catalog name.
#: ``_stamp`` (the runtime's batch helper) is deliberately absent: its
#: own body forwards to ``stamp`` and its callers pass constants.
_EVENT_METHODS = {"record_event", "stamp"}


def check(mod: LintModule) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        fn = node.func
        if not isinstance(fn, ast.Attribute) or fn.attr not in _EVENT_METHODS:
            continue
        name = node.args[0]
        if not (isinstance(name, ast.Constant)
                and isinstance(name.value, str)):
            continue  # a Name — the EV_*/STAGE_* convention this rule wants
        suppressed, extra = check_suppression(mod, node.lineno, "event-ok")
        findings.extend(extra)
        if not suppressed:
            findings.append(
                Finding(
                    rule="event-name",
                    path=mod.path,
                    line=node.lineno,
                    message=(
                        f"inline event/stage name {name.value!r} passed to "
                        f".{fn.attr}(): emission sites must reference the "
                        "registered EV_*/STAGE_* constants "
                        "(repro.obs.events / repro.obs.trace) so typos fail "
                        "at import time and grep stays anchored to the "
                        "catalog"
                    ),
                )
            )
    return findings
