"""AST rule registry.  A rule is ``(LintModule) -> list[Finding]``; adding
one means writing the module and listing its ``check`` here (and in the
catalog in docs/static_analysis.md)."""

from repro.analysis.rules import (
    counters,
    event_names,
    guarded_by,
    jit_cache_keys,
    nondeterminism,
    persist_format,
)

ALL_RULES = (
    guarded_by.check,
    counters.check,
    jit_cache_keys.check,
    nondeterminism.check,
    persist_format.check,
    event_names.check,
)
