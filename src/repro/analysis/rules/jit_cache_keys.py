"""jit-cache-key completeness (the PR 2 frozen-chain-budget bug class).

The repo's jit caches all share one shape::

    def _step_for(self, base, budget=None, nprobe=None, rerank=None):
        ...
        key = (base, budget, nprobe, rerank)
        if key not in self._steps:
            self._steps[key] = jax.jit(self._make(...))
        return self._steps[key]

Every parameter that can vary the traced closure must appear in the key
tuple: a parameter missing from the key silently serves a step compiled
for some *other* value of it (PR 2's frozen budget truncated chains — and
recall — for every request after the first).  The rule finds
membership-guarded cache inserts (``if <key> not in <cache>:`` +
``<cache>[<key>] = ...``), resolves the key tuple's names, and requires
every function parameter to appear in it.  Parameters that deliberately
don't key the cache carry ``# cache-key-ok: <why>`` on the key assignment.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.findings import Finding
from repro.analysis.lint import LintModule, check_suppression


def _key_tuple_assign(func, key_name: str) -> Optional[ast.Assign]:
    found = None
    for node in ast.walk(func):
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == key_name
            and isinstance(node.value, ast.Tuple)
        ):
            found = node
    return found


def _is_cache_insert(if_node: ast.If, key_name: str) -> bool:
    for node in ast.walk(if_node):
        if (
            isinstance(node, ast.Assign)
            and isinstance(node.targets[0], ast.Subscript)
        ):
            sl = node.targets[0].slice
            if isinstance(sl, ast.Name) and sl.id == key_name:
                return True
    return False


def check(mod: LintModule) -> List[Finding]:
    findings: List[Finding] = []
    for func in ast.walk(mod.tree):
        if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        params = [
            a.arg
            for a in (
                func.args.posonlyargs + func.args.args + func.args.kwonlyargs
            )
            if a.arg not in ("self", "cls")
        ]
        if not params:
            continue
        for node in ast.walk(func):
            if not isinstance(node, ast.If):
                continue
            test = node.test
            if not (
                isinstance(test, ast.Compare)
                and len(test.ops) == 1
                and isinstance(test.ops[0], ast.NotIn)
                and isinstance(test.left, ast.Name)
            ):
                continue
            key_name = test.left.id
            if not _is_cache_insert(node, key_name):
                continue
            key_assign = _key_tuple_assign(func, key_name)
            if key_assign is None:
                continue
            key_names = {
                n.id
                for n in ast.walk(key_assign.value)
                if isinstance(n, ast.Name)
            }
            missing = [p for p in params if p not in key_names]
            if not missing:
                continue
            suppressed, extra = check_suppression(
                mod, key_assign.lineno, "cache-key-ok"
            )
            findings.extend(extra)
            if not suppressed:
                findings.append(
                    Finding(
                        rule="jit-cache-key",
                        path=mod.path,
                        line=key_assign.lineno,
                        message=(
                            f"{func.name}: parameter(s) {missing} vary the "
                            "cached closure but are missing from the cache "
                            "key tuple (frozen-budget bug class)"
                        ),
                    )
                )
    return findings
