"""Static analysis for the serving stack: a jaxpr trace auditor (layer 1)
and a repo-specific AST linter (layer 2).  ``python -m repro.analysis``
runs both plus the VMEM docs check and exits nonzero on findings; CI wires
it in as the ``analysis`` job.  Rule catalog: docs/static_analysis.md.
"""

from repro.analysis.findings import Finding  # noqa: F401

DOCS_SEARCH_PATHS = "docs/search_paths.md"


def run_all(repo_root: str = "."):
    """(findings, stats): full lint + trace audit + VMEM docs check."""
    import os

    from repro.analysis import jaxpr_audit, vmem
    from repro.analysis.lint import lint_repo

    findings = list(lint_repo(repo_root))
    trace_findings, stats = jaxpr_audit.run_trace_audit()
    findings.extend(trace_findings)
    findings.extend(
        vmem.check_docs(os.path.join(repo_root, DOCS_SEARCH_PATHS))
    )
    return findings, stats
