"""Fanout neighbour sampler for minibatch GNN training (GraphSAGE-style).

Host-side (numpy) CSR sampling — the device step consumes fixed-shape padded
subgraphs.  This is the real component the ``minibatch_lg`` shape requires:
232 965 nodes / 114 M edges cannot be full-batched, so training samples
``batch_nodes`` seeds with fanouts (15, 10) and runs the equiformer on the
induced block graph.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # [N+1]
    indices: np.ndarray  # [E]
    n_nodes: int

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray, n_nodes: int):
        order = np.argsort(dst, kind="stable")
        src, dst = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, dst + 1, 1)
        indptr = np.cumsum(indptr)
        return cls(indptr=indptr, indices=src, n_nodes=n_nodes)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int, rng):
        """Uniform sample up to ``fanout`` in-neighbours per node.

        Returns (src, dst) edge lists (padded stays absent — ragged here,
        fixed-shape padding happens in ``sample_block``)."""
        srcs, dsts = [], []
        for v in nodes:
            lo, hi = self.indptr[v], self.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            if deg <= fanout:
                nb = self.indices[lo:hi]
            else:
                nb = self.indices[lo + rng.integers(0, deg, fanout)]
            srcs.append(nb)
            dsts.append(np.full(len(nb), v, np.int64))
        if not srcs:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return np.concatenate(srcs), np.concatenate(dsts)


def sample_block(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanouts: tuple,
    rng: np.random.Generator,
    max_nodes: int,
    max_edges: int,
):
    """Multi-hop sampled subgraph, padded to (max_nodes, max_edges).

    Returns dict with local edge index, node id mapping, and masks — the
    fixed shapes keep one compiled executable across steps (jit friendly,
    and the production requirement for TPU).
    """
    nodes = list(seeds)
    node_set = {int(v): i for i, v in enumerate(seeds)}
    all_src, all_dst = [], []
    frontier = seeds
    for f in fanouts:
        src, dst = graph.sample_neighbors(frontier, f, rng)
        new = []
        for s in src:
            if int(s) not in node_set:
                node_set[int(s)] = len(nodes)
                nodes.append(int(s))
                new.append(int(s))
        all_src.append(src)
        all_dst.append(dst)
        frontier = np.asarray(new, np.int64)
        if len(frontier) == 0:
            break
    src = np.concatenate(all_src) if all_src else np.zeros(0, np.int64)
    dst = np.concatenate(all_dst) if all_dst else np.zeros(0, np.int64)
    # local ids
    lsrc = np.asarray([node_set[int(s)] for s in src], np.int64)
    ldst = np.asarray([node_set[int(d)] for d in dst], np.int64)
    nodes = np.asarray(nodes, np.int64)

    n, e = len(nodes), len(lsrc)
    n_keep = min(n, max_nodes)
    e_mask = (lsrc < n_keep) & (ldst < n_keep)
    lsrc, ldst = lsrc[e_mask][:max_edges], ldst[e_mask][:max_edges]
    e = len(lsrc)
    out_nodes = np.zeros(max_nodes, np.int64)
    out_nodes[:n_keep] = nodes[:n_keep]
    out_src = np.zeros(max_edges, np.int64)
    out_dst = np.full(max_edges, max_nodes, np.int64)  # pad -> dropped segment
    out_src[:e] = lsrc
    out_dst[:e] = ldst
    return {
        "node_ids": out_nodes,
        "n_nodes": n_keep,
        "edge_src": out_src.astype(np.int32),
        "edge_dst": out_dst.astype(np.int32),
        "n_edges": e,
        "seed_mask": np.arange(max_nodes) < len(seeds),
    }
