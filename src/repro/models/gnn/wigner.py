"""Wigner rotation matrices for real spherical harmonics, l <= L_MAX.

eSCN (EquiformerV2's convolution) rotates every edge's irrep features into a
frame where the edge direction is the z axis; there the SO(3) tensor product
collapses to independent SO(2) mixes per |m| — O(L^3) instead of O(L^6).

Construction (host precompute + vectorised device evaluation):
  complex-basis angular momentum operators Jz (diag) and Jy (from ladder
  operators); C_l = complex->real-SH change of basis; eigendecomposition
  Jy = V diag(m) V^H.  Then for Euler angles,
      D_real(Rz(g)) = Re( C diag(e^{-i m g}) C^H )
      D_real(Ry(b)) = Re( W diag(e^{-i m b}) W^H ),  W = C V
  and the edge-alignment rotation is D(Ry(-theta)) @ D(Rz(-phi)).
Correctness is property-tested against rotating the inputs of real spherical
harmonics directly (tests/test_gnn.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _complex_to_real_sh(l: int) -> np.ndarray:
    """Unitary C with Y_real = C @ Y_complex (Condon–Shortley phases)."""
    dim = 2 * l + 1
    c = np.zeros((dim, dim), np.complex128)
    isq2 = 1.0 / np.sqrt(2.0)
    for m in range(-l, l + 1):
        row = m + l
        if m < 0:
            c[row, l + m] = 1j * isq2
            c[row, l - m] = -1j * isq2 * (-1) ** m
        elif m == 0:
            c[row, l] = 1.0
        else:
            c[row, l - m] = isq2
            c[row, l + m] = isq2 * (-1) ** m
    return c


def _jy(l: int) -> np.ndarray:
    """Jy in the complex |l, m> basis (m = -l..l ordering)."""
    dim = 2 * l + 1
    jp = np.zeros((dim, dim), np.complex128)  # J+ |m> = c |m+1>
    for m in range(-l, l):
        jp[m + 1 + l, m + l] = np.sqrt(l * (l + 1) - m * (m + 1))
    jm = jp.conj().T
    return (jp - jm) / 2j


@functools.lru_cache(maxsize=None)
def wigner_tables(l_max: int):
    """Host precompute: per-l (W = C V, m eigenvalues, C) as numpy arrays."""
    ws, ms, cs = [], [], []
    for l in range(l_max + 1):
        c = _complex_to_real_sh(l)
        evals, v = np.linalg.eigh(_jy(l))
        # eigenvalues of Jy are exactly -l..l; snap to integers
        evals = np.round(evals).astype(np.float64)
        ws.append(c @ v)
        ms.append(evals)
        cs.append(c)
    return ws, ms, cs


def _rot_from_phase(
    w: jax.Array, m: jax.Array, angle: jax.Array, sign: float
) -> jax.Array:
    """Re( W diag(e^{sign * i m angle}) W^H ) for a batch of angles [...].

    Empirically validated conventions (tests/test_gnn.py): rotations about z
    use sign=+1 with W=C; rotations about y use sign=-1 with W=C V.
    """
    phase = jnp.exp(sign * 1j * m * angle[..., None])  # [..., dim]
    return jnp.real(jnp.einsum("ab,...b,cb->...ac", w, phase, w.conj()))


def edge_wigner(
    l_max: int, edge_vec: jax.Array
) -> list[jax.Array]:
    """Per-l rotation matrices aligning each edge vector to +z.

    edge_vec: [E, 3].  Returns list of [E, 2l+1, 2l+1] f32, l = 0..l_max.
    The inverse rotation is the transpose (orthogonal).
    """
    ws_np, ms_np, cs_np = wigner_tables(l_max)
    x, y, z = edge_vec[:, 0], edge_vec[:, 1], edge_vec[:, 2]
    r = jnp.sqrt(x * x + y * y + z * z) + 1e-12
    theta = jnp.arccos(jnp.clip(z / r, -1.0, 1.0))  # polar
    phi = jnp.arctan2(y, x)  # azimuth
    # R_align = Ry(-theta) @ Rz(-phi) maps the edge direction to +z
    out = []
    for l in range(l_max + 1):
        w = jnp.asarray(ws_np[l], jnp.complex64)
        cmat = jnp.asarray(cs_np[l], jnp.complex64)
        m = jnp.asarray(ms_np[l], jnp.float32)
        dz = _rot_from_phase(cmat, m, -phi, +1.0)  # [E, dim, dim]
        dy = _rot_from_phase(w, m, -theta, -1.0)
        out.append(jnp.einsum("eab,ebc->eac", dy, dz).astype(jnp.float32))
    return out


def real_sph_harm_l1(vec: jax.Array) -> jax.Array:
    """l=1 real SH (unnormalised, (y, z, x) ordering) — used by tests."""
    n = vec / (jnp.linalg.norm(vec, axis=-1, keepdims=True) + 1e-12)
    return jnp.stack([n[..., 1], n[..., 2], n[..., 0]], axis=-1)
