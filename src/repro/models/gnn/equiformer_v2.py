"""EquiformerV2-style equivariant graph attention with eSCN SO(2) convs.

[arXiv:2306.12059] structure, re-derived for JAX/TPU:

* Node features are real-SH irreps ``[N, S, C]`` with ``S = (l_max+1)^2``.
* Each edge rotates its endpoint features into the edge-aligned frame
  (Wigner blocks from ``wigner.py``), restricts to ``|m| <= m_max`` columns,
  applies per-m complex linear maps (the eSCN O(L^6)->O(L^3) reduction),
  modulates by a radial basis, and attends with scalar-derived logits.
* Message passing is ``jax.ops.segment_sum`` over an edge index — JAX has no
  sparse SpMM; the scatter IS the system (assignment note).  Edges are
  processed in fixed-size chunks under ``lax.scan`` so the 62M-edge
  ogb_products cell has bounded peak memory; attention normalisation
  accumulates (numerator, denominator) across chunks, giving exact softmax
  with bounded logits (5*tanh(z/5)) and no second pass.
* Equivariance is property-tested (tests/test_gnn.py): invariant outputs are
  rotation-stable and l=1 features co-rotate.

The paper's ANNS technique is inapplicable here (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.wigner import edge_wigner
from repro.models.layers import Shard, no_shard


@dataclasses.dataclass(frozen=True)
class EquiformerConfig:
    name: str
    n_layers: int = 12
    channels: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_feat_in: int = 16
    n_radial: int = 8
    edge_chunk: int = 4096
    readout: str = "node"  # node classification | "graph" energy
    n_out: int = 1
    dtype: Any = jnp.float32

    @property
    def s_full(self) -> int:
        return (self.l_max + 1) ** 2

    def m_indices(self) -> np.ndarray:
        """Flattened irrep indices with |m| <= m_max (edge-frame columns)."""
        idx = []
        for l in range(self.l_max + 1):
            for m in range(-min(l, self.m_max), min(l, self.m_max) + 1):
                idx.append(l * l + m + l)
        return np.asarray(idx, np.int32)

    def m_groups(self):
        """For each m: (rows_pos, rows_neg) flattened indices per l >= m."""
        groups = []
        for m in range(0, self.m_max + 1):
            pos = [l * l + m + l for l in range(max(m, 0), self.l_max + 1) if m <= l]
            neg = [l * l - m + l for l in range(max(m, 0), self.l_max + 1) if m <= l]
            groups.append((np.asarray(pos, np.int32), np.asarray(neg, np.int32)))
        return groups


# ------------------------------------------------------------------ init --


def _linear(key, din, dout, dtype):
    return (jax.random.normal(key, (din, dout)) * din**-0.5).astype(dtype)


def init_equiformer(key, cfg: EquiformerConfig) -> dict:
    c, dt = cfg.channels, cfg.dtype
    keys = jax.random.split(key, 8 + cfg.n_layers)
    groups = cfg.m_groups()

    def layer_init(k):
        ks = jax.random.split(k, 4 + 2 * len(groups))
        p = {
            "norm_scale": jnp.ones((cfg.l_max + 1, c), dt),
            "att_w1": _linear(ks[0], c, c, dt),
            "att_w2": _linear(ks[1], c, cfg.n_heads, dt),
            "radial_w": _linear(ks[2], cfg.n_radial, c, dt),
            "ffn_gate": _linear(ks[3], c, cfg.l_max * c, dt),
            "ffn_mix": jax.vmap(lambda kk: _linear(kk, c, c, dt))(
                jax.random.split(ks[4], cfg.l_max + 1)
            ),
        }
        for mi, (pos, neg) in enumerate(groups):
            n = len(pos)
            kr, ki = ks[5 + 2 * mi], ks[6 + 2 * mi]
            p[f"so2_{mi}_r"] = _linear(kr, 2 * n * c, n * c, dt)
            if mi > 0:
                p[f"so2_{mi}_i"] = _linear(ki, 2 * n * c, n * c, dt)
        return p

    layers = jax.vmap(layer_init)(jax.random.split(keys[0], cfg.n_layers))
    head_sizes = [c, c, cfg.n_out]
    kh = jax.random.split(keys[2], 2)
    return {
        "embed_w": _linear(keys[1], cfg.d_feat_in, c, dt),
        "layers": layers,
        "head_w1": _linear(kh[0], c, c, dt),
        "head_w2": _linear(kh[1], c, cfg.n_out, dt),
    }


# --------------------------------------------------------------- helpers --


def _irrep_norm(x: jax.Array, scale: jax.Array, l_max: int) -> jax.Array:
    """Separable norm: per-l RMS over (m, channel), learnable per-l scale."""
    outs = []
    for l in range(l_max + 1):
        blk = x[:, l * l : (l + 1) * (l + 1)]
        rms = jnp.sqrt(jnp.mean(blk.astype(jnp.float32) ** 2, axis=(1, 2), keepdims=True) + 1e-6)
        outs.append((blk / rms.astype(blk.dtype)) * scale[l])
    return jnp.concatenate(outs, axis=1)


def _apply_wigner(d_blocks, x, l_max: int, transpose=False):
    """Block-diagonal rotate: x [E, S, C] by per-l [E, dl, dl]."""
    outs = []
    for l in range(l_max + 1):
        blk = x[:, l * l : (l + 1) * (l + 1)]
        d = d_blocks[l]
        eq = "eba,ebc->eac" if transpose else "eab,ebc->eac"
        outs.append(jnp.einsum(eq, d, blk))
    return jnp.concatenate(outs, axis=1)


def _so2_conv(p, cfg: EquiformerConfig, h: jax.Array) -> jax.Array:
    """Per-m complex linear mixing in the edge frame.

    h: [E, S, 2C] (concat of rotated source/target features).
    Returns [E, S, C] with only |m| <= m_max rows populated.
    """
    e = h.shape[0]
    c = cfg.channels
    out = jnp.zeros((e, cfg.s_full, c), h.dtype)
    for mi, (pos, neg) in enumerate(cfg.m_groups()):
        n = len(pos)
        if mi == 0:
            f = h[:, pos].reshape(e, -1)  # [E, n*2C]
            y = f @ p["so2_0_r"]
            out = out.at[:, pos].set(y.reshape(e, n, c))
        else:
            fr = h[:, pos].reshape(e, -1)
            fi = h[:, neg].reshape(e, -1)
            wr, wi = p[f"so2_{mi}_r"], p[f"so2_{mi}_i"]
            yr = fr @ wr - fi @ wi
            yi = fr @ wi + fi @ wr
            out = out.at[:, pos].set(yr.reshape(e, n, c))
            out = out.at[:, neg].set(yi.reshape(e, n, c))
    return out


def _radial_basis(dist: jax.Array, n_radial: int, r_max: float = 6.0):
    mu = jnp.linspace(0.0, r_max, n_radial)
    gamma = n_radial / r_max
    return jnp.exp(-gamma * (dist[:, None] - mu[None, :]) ** 2)


# --------------------------------------------------------------- forward --


def _chunk_contribution(lp, cfg: EquiformerConfig, xn, pos, src, dst, n):
    """(num, den) contribution of one edge chunk (the paper-structure core)."""
    s, c = xn.shape[1:]
    heads = cfg.n_heads
    ch = c // heads
    vec = pos[jnp.minimum(dst, n - 1)] - pos[src]  # [e, 3]
    d_blocks = edge_wigner(cfg.l_max, vec)
    h_src = _apply_wigner(d_blocks, xn[src], cfg.l_max)
    h_dst = _apply_wigner(d_blocks, xn[jnp.minimum(dst, n - 1)], cfg.l_max)
    h = jnp.concatenate([h_src, h_dst], axis=-1)  # [e, S, 2C]
    msg = _so2_conv(lp, cfg, h)  # [e, S, C]
    dist = jnp.linalg.norm(vec, axis=-1)
    rbf = _radial_basis(dist, cfg.n_radial)
    msg = msg * (rbf @ lp["radial_w"])[:, None, :]
    # attention logits from the invariant (l=0) row
    inv = jax.nn.silu(msg[:, 0] @ lp["att_w1"]) @ lp["att_w2"]  # [e, H]
    logits = 5.0 * jnp.tanh(inv / 5.0)  # bounded: exact softmax w/o max pass
    alpha = jnp.exp(logits)  # [e, H]
    # zero-length edges (self-loops / padding) have no well-defined frame
    # — their messages are frame-dependent, so they get zero weight.
    alpha = alpha * (dist > 1e-8)[:, None]
    v_world = _apply_wigner(d_blocks, msg, cfg.l_max, transpose=True)
    v_heads = v_world.reshape(-1, s, heads, ch)
    weighted = v_heads * alpha[:, None, :, None]
    num = jax.ops.segment_sum(
        weighted.reshape(-1, s, c), dst, num_segments=n + 1
    )
    den = jax.ops.segment_sum(alpha, dst, num_segments=n + 1)
    return num, den


def _attention_layer(
    lp, cfg: EquiformerConfig, x, pos, edge_src, edge_dst, shard: Shard
):
    """One eSCN graph-attention block; edges processed in chunks.

    The chunk scan carries only the (num, den) accumulators, and a
    custom_vjp recomputes each chunk in the backward pass — without it,
    scan-AD would checkpoint the [N, S, C] accumulator *per chunk step*
    (236 copies x 61 GB for the ogb_products cell).  This is the memory
    trick that makes full-graph training of the 62M-edge cell feasible.
    """
    n, s, c = x.shape
    heads = cfg.n_heads
    xn = _irrep_norm(x, lp["norm_scale"], cfg.l_max)

    ne = edge_src.shape[0]
    chunk = min(cfg.edge_chunk, ne)
    n_chunks = -(-ne // chunk)
    pad = n_chunks * chunk - ne
    # pad edges: src 0 -> dst n (dropped segment), zero-length (zero weight)
    esrc = jnp.concatenate([edge_src, jnp.zeros((pad,), edge_src.dtype)])
    edst = jnp.concatenate([edge_dst, jnp.full((pad,), n, edge_dst.dtype)])
    esrc = esrc.reshape(n_chunks, chunk)
    edst = edst.reshape(n_chunks, chunk)

    def _impl(lp_, xn_, pos_):
        def chunk_fn(carry, inp):
            num, den = carry
            dn, dd = _chunk_contribution(lp_, cfg, xn_, pos_, *inp, n)
            return (num + dn, den + dd), None

        num0 = jnp.zeros((n + 1, s, c), x.dtype)
        den0 = jnp.zeros((n + 1, heads), x.dtype)
        (num, den), _ = jax.lax.scan(chunk_fn, (num0, den0), (esrc, edst))
        return num, den

    @jax.custom_vjp
    def aggregate(lp_, xn_, pos_):
        return _impl(lp_, xn_, pos_)

    def agg_fwd(lp_, xn_, pos_):
        return _impl(lp_, xn_, pos_), (lp_, xn_, pos_)

    def agg_bwd(res, ct):
        lp_, xn_, pos_ = res

        def chunk_bwd(carry, inp):
            d_lp, d_xn, d_pos = carry
            _, vjp = jax.vjp(
                lambda a, b, c_: _chunk_contribution(a, cfg, b, c_, *inp, n),
                lp_, xn_, pos_,
            )
            g_lp, g_xn, g_pos = vjp(ct)
            return (
                jax.tree.map(jnp.add, d_lp, g_lp),
                d_xn + g_xn,
                d_pos + g_pos,
            ), None

        zeros = (
            jax.tree.map(jnp.zeros_like, lp_),
            jnp.zeros_like(xn_),
            jnp.zeros_like(pos_),
        )
        (d_lp, d_xn, d_pos), _ = jax.lax.scan(chunk_bwd, zeros, (esrc, edst))
        return d_lp, d_xn, d_pos

    aggregate.defvjp(agg_fwd, agg_bwd)
    num, den = aggregate(lp, xn, pos)
    den = jnp.maximum(den, 1e-9)
    ch = c // heads
    agg = (
        num[:n].reshape(n, s, heads, ch) / den[:n, None, :, None]
    ).reshape(n, s, c)
    x = x + agg

    # ---- equivariant FFN: scalar-gated nonlinearity + per-l channel mix --
    xn2 = _irrep_norm(x, lp["norm_scale"], cfg.l_max)
    scalars = xn2[:, 0]  # [N, C]
    gates = jax.nn.sigmoid(scalars @ lp["ffn_gate"]).reshape(
        n, cfg.l_max, c
    )
    outs = [jax.nn.silu(scalars) @ lp["ffn_mix"][0]]
    for l in range(1, cfg.l_max + 1):
        blk = xn2[:, l * l : (l + 1) * (l + 1)]
        blk = blk * gates[:, l - 1][:, None, :]
        outs.append(jnp.einsum("nac,cd->nad", blk, lp["ffn_mix"][l]))
    y = jnp.concatenate(
        [outs[0][:, None]] + outs[1:], axis=1
    )
    return x + y


def equiformer_forward(
    params: dict,
    cfg: EquiformerConfig,
    node_feat: jax.Array,  # [N, d_feat_in]
    pos: jax.Array,  # [N, 3]
    edge_src: jax.Array,  # [E] i32
    edge_dst: jax.Array,  # [E] i32
    shard: Shard = no_shard,
    graph_ids: jax.Array | None = None,  # [N] for batched small graphs
    n_graphs: int = 1,
):
    """Returns [N, n_out] (node readout) or [n_graphs, n_out] (graph)."""
    n = node_feat.shape[0]
    x0 = node_feat.astype(cfg.dtype) @ params["embed_w"]  # [N, C]
    x = jnp.zeros((n, cfg.s_full, cfg.channels), cfg.dtype)
    x = x.at[:, 0].set(x0)
    x = shard(x, "act_nodes")

    for li in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[li], params["layers"])
        x = _attention_layer(lp, cfg, x, pos, edge_src, edge_dst, shard)
        x = shard(x, "act_nodes")

    inv = x[:, 0]  # invariant channels
    h = jax.nn.silu(inv @ params["head_w1"])
    out = h @ params["head_w2"]
    if cfg.readout == "graph":
        assert graph_ids is not None
        out = jax.ops.segment_sum(out, graph_ids, num_segments=n_graphs)
    return out


def equiformer_loss(params, cfg, batch, shard: Shard = no_shard):
    out = equiformer_forward(
        params, cfg, batch["node_feat"], batch["pos"], batch["edge_src"],
        batch["edge_dst"], shard,
        graph_ids=batch.get("graph_ids"),
        n_graphs=batch.get("n_graphs", 1),
    )
    if cfg.readout == "graph":
        err = out[:, 0] - batch["target"]
        loss = jnp.mean(err * err)
    else:
        labels = batch["label"]
        mask = (labels >= 0).astype(jnp.float32)
        lse = jax.nn.logsumexp(out.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(
            out.astype(jnp.float32), jnp.maximum(labels, 0)[:, None], axis=-1
        )[:, 0]
        loss = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss}
