"""Decoder-only LM (dense + MoE) with scan-over-layers and GQA attention.

Covers the five assigned LM architectures (llama3-8b, qwen3-1.7b,
qwen1.5-110b, kimi-k2-1t-a32b, llama4-maverick-400b-a17b) through one
parameterised definition.  Layers are stacked on a leading axis and executed
with ``lax.scan`` (+ optional remat) so giant configs compile quickly and
the HLO stays compact.

Serving: ``prefill`` builds the KV cache for a prompt; ``decode_step``
appends one token.  The block-pool paged-KV serving path (the paper's
technique applied to LM serving) lives in repro/serving/paged_lm.py and
reuses these parameters.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (
    AttnConfig,
    Shard,
    attention,
    attention_decode,
    init_attn,
    init_mlp,
    mlp_swiglu,
    no_shard,
    rmsnorm,
)
from repro.models.moe import MoEConfig, init_moe, moe_apply


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    attn_chunk: int = 512
    remat: bool = True
    unroll: bool = False  # python-loop layers/chunks: exact HLO accounting
    dtype: Any = jnp.bfloat16

    def attn_config(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.d_head,
            qk_norm=self.qk_norm,
            qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta,
            attn_chunk=self.attn_chunk,
            unroll=self.unroll,
        )

    def moe_config(self) -> MoEConfig:
        return MoEConfig(
            d_model=self.d_model,
            n_experts=self.n_experts,
            top_k=self.top_k,
            d_ff_expert=self.d_ff_expert,
            capacity_factor=self.capacity_factor,
        )

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding + layers + head)."""
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * d
        if self.moe:
            ff = 3 * d * self.d_ff_expert * self.n_experts + d * self.n_experts
        else:
            ff = 3 * d * self.d_ff
        per_layer = attn + ff + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d

    @property
    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.n_params
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * d
        ff = 3 * d * self.d_ff_expert * self.top_k + d * self.n_experts
        per_layer = attn + ff + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d


# ------------------------------------------------------------------ init --


def init_lm(key, cfg: LMConfig) -> dict:
    keys = jax.random.split(key, 6)
    acfg = cfg.attn_config()

    def layer_init(k):
        ka, km = jax.random.split(k)
        p = {"attn": init_attn(ka, acfg, cfg.dtype)}
        if cfg.moe:
            p["moe"] = init_moe(km, cfg.moe_config(), cfg.dtype)
        else:
            p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, cfg.dtype)
        p["attn_norm"] = jnp.ones((cfg.d_model,), cfg.dtype)
        p["mlp_norm"] = jnp.ones((cfg.d_model,), cfg.dtype)
        return p

    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    layers = jax.vmap(layer_init)(layer_keys)  # stacked on axis 0
    return {
        "embed": (
            jax.random.normal(keys[1], (cfg.vocab, cfg.d_model)) * 0.02
        ).astype(cfg.dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "lm_head": (
            jax.random.normal(keys[2], (cfg.d_model, cfg.vocab))
            * cfg.d_model**-0.5
        ).astype(cfg.dtype),
    }


# --------------------------------------------------------------- forward --


def _layer_fwd(lp, cfg: LMConfig, x, positions, shard: Shard):
    acfg = cfg.attn_config()
    h = x + attention(lp["attn"], acfg, rmsnorm(x, lp["attn_norm"]), positions, shard)
    hn = rmsnorm(h, lp["mlp_norm"])
    if cfg.moe:
        b, s, d = hn.shape
        y, aux = moe_apply(lp["moe"], cfg.moe_config(), hn.reshape(-1, d), shard)
        y = y.reshape(b, s, d)
        aux_loss = aux["aux_loss"]
    else:
        y = mlp_swiglu(lp["mlp"], hn, shard)
        aux_loss = jnp.zeros((), jnp.float32)
    return h + y, aux_loss


def forward(
    params: dict,
    cfg: LMConfig,
    tokens: jax.Array,  # [B, S] int32
    shard: Shard = no_shard,
):
    """Training / prefill forward. Returns (logits [B,S,V], aux_loss)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = shard(x, "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, lp):
        x, aux = carry
        x, al = _layer_fwd(lp, cfg, x, positions, shard)
        return (x, aux + al), None

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.unroll:
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            carry, _ = body_fn(carry, lp)
    else:
        carry, _ = jax.lax.scan(body_fn, carry, params["layers"])
    x, aux = carry
    x = rmsnorm(x, params["final_norm"])
    logits = shard(x @ params["lm_head"], "act_vocab")
    return logits, aux


def lm_loss(
    params: dict,
    cfg: LMConfig,
    tokens: jax.Array,  # [B, S]
    labels: jax.Array,  # [B, S] (-100 = ignore)
    shard: Shard = no_shard,
    aux_weight: float = 0.01,
):
    logits, aux = forward(params, cfg, tokens, shard)
    # NOTE: the label logit is extracted with a one-hot contraction, NOT
    # take_along_axis — a gather over the vocab-sharded axis makes GSPMD
    # all-gather the full [B, S, V] logits per device (measured: 43 GiB/dev
    # on qwen3 train_4k); the one-hot einsum contracts locally + all-reduce.
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(
        jnp.maximum(labels, 0), cfg.vocab, dtype=logits.dtype
    )
    ll = jnp.einsum(
        "bsv,bsv->bs", logits, onehot, preferred_element_type=jnp.float32
    )
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# --------------------------------------------------------------- serving --


def init_kv_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(
    params: dict,
    cfg: LMConfig,
    tokens: jax.Array,  # [B, S]
    cache: dict,
    shard: Shard = no_shard,
):
    """Run the prompt, fill the cache. Returns (logits_last [B,V], cache)."""
    b, s = tokens.shape
    acfg = cfg.attn_config()
    x = params["embed"][tokens].astype(cfg.dtype)
    x = shard(x, "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, lp):
        from repro.models.layers import _qkv  # reuse projection

        xn = rmsnorm(x, lp["attn_norm"])
        q, k, v = _qkv(lp["attn"], acfg, xn, positions, shard)
        from repro.models.layers import _sdpa_chunked

        o = _sdpa_chunked(q, k, v, acfg, shard, causal=True)
        o = o.reshape(b, s, cfg.n_heads * cfg.d_head) @ lp["attn"]["wo"]
        h = x + shard(o, "act_embed")
        hn = rmsnorm(h, lp["mlp_norm"])
        if cfg.moe:
            y, _ = moe_apply(
                lp["moe"], cfg.moe_config(), hn.reshape(-1, cfg.d_model), shard
            )
            y = y.reshape(b, s, cfg.d_model)
        else:
            y = mlp_swiglu(lp["mlp"], hn, shard)
        return h + y, (k.astype(cfg.dtype), v.astype(cfg.dtype))

    if cfg.unroll:
        kvs = []
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            x, kv = body(x, lp)
            kvs.append(kv)
        ks = jnp.stack([k for k, _ in kvs])
        vs = jnp.stack([v for _, v in kvs])
    else:
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(
            cache["k"], ks, 0, axis=2
        ),
        "v": jax.lax.dynamic_update_slice_in_dim(
            cache["v"], vs, 0, axis=2
        ),
    }
    x = rmsnorm(x[:, -1:], params["final_norm"])
    logits = shard(x @ params["lm_head"], "act_vocab")[:, 0]
    return logits, cache


def decode_step(
    params: dict,
    cfg: LMConfig,
    token: jax.Array,  # [B] int32 most recent token
    cache: dict,
    cache_len: jax.Array,  # [] tokens already in cache
    shard: Shard = no_shard,
):
    """One decode step. Returns (logits [B, V], cache')."""
    b = token.shape[0]
    acfg = cfg.attn_config()
    x = params["embed"][token][:, None].astype(cfg.dtype)  # [B, 1, D]
    x = shard(x, "act_embed")

    def body(carry, inp):
        x = carry
        lp, kc, vc = inp
        xn = rmsnorm(x, lp["attn_norm"])
        o, kc2, vc2 = attention_decode(
            lp["attn"], acfg, xn, kc, vc, cache_len, shard
        )
        h = x + o
        hn = rmsnorm(h, lp["mlp_norm"])
        if cfg.moe:
            y, _ = moe_apply(
                lp["moe"], cfg.moe_config(), hn.reshape(-1, cfg.d_model), shard
            )
            y = y.reshape(b, 1, cfg.d_model)
        else:
            y = mlp_swiglu(lp["mlp"], hn, shard)
        return h + y, (kc2, vc2)

    if cfg.unroll:
        kvs = []
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            x, kv = body(x, (lp, cache["k"][li], cache["v"][li]))
            kvs.append(kv)
        ks = jnp.stack([k for k, _ in kvs])
        vs = jnp.stack([v for _, v in kvs])
    else:
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"])
        )
    cache = {"k": ks, "v": vs}
    x = rmsnorm(x, params["final_norm"])
    logits = shard(x @ params["lm_head"], "act_vocab")[:, 0]
    return logits, cache
