"""Sharded embedding tables + EmbeddingBag built from JAX primitives.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — the bag op here IS
part of the system (assignment note): ``jnp.take`` over one concatenated
table + ``jax.ops.segment_sum`` for multi-hot reduction.

Layout: all fields live in ONE stacked table ``[total_rows, dim]`` with
per-field row offsets.  This is deliberate: the single table row-shards over
the "model" mesh axis (DLRM's 96 GB of tables cannot be replicated), and a
lookup becomes gather -> all-to-all under GSPMD, which mirrors production
DLRM hybrid parallelism.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Shard, no_shard


ROW_PAD = 512  # table rows padded to a multiple of the largest mesh size,
# so row-sharding the stacked table over every mesh axis is always legal.


@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    vocab_sizes: tuple  # rows per field
    dim: int

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_rows(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def padded_rows(self) -> int:
        return -(-self.total_rows // ROW_PAD) * ROW_PAD

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype(
            np.int32
        )


def init_embedding(key, spec: EmbeddingSpec, dtype=jnp.float32) -> dict:
    scale = spec.dim**-0.5
    return {
        "table": (
            jax.random.normal(key, (spec.padded_rows, spec.dim)) * scale
        ).astype(dtype)
    }


def lookup(
    params: dict,
    spec: EmbeddingSpec,
    ids: jax.Array,  # [B, F] one id per field (already in-field indices)
    shard: Shard = no_shard,
) -> jax.Array:  # [B, F, dim]
    offsets = jnp.asarray(spec.offsets)
    rows = ids + offsets[None, :]
    out = jnp.take(params["table"], rows.reshape(-1), axis=0)
    out = out.reshape(*ids.shape, spec.dim)
    return shard(out, "act_embed_bag")


def bag_lookup(
    params: dict,
    spec: EmbeddingSpec,
    ids: jax.Array,  # [B, F, L] multi-hot ids, -1 = padding
    weights: jax.Array | None = None,  # [B, F, L] per-sample weights
    combiner: str = "sum",
    shard: Shard = no_shard,
) -> jax.Array:  # [B, F, dim]
    """EmbeddingBag: ragged gather + segment reduction (sum/mean)."""
    b, f, l = ids.shape
    offsets = jnp.asarray(spec.offsets)
    valid = ids >= 0
    rows = jnp.where(valid, ids + offsets[None, :, None], 0)
    emb = jnp.take(params["table"], rows.reshape(-1), axis=0).reshape(
        b, f, l, spec.dim
    )
    w = valid.astype(emb.dtype)
    if weights is not None:
        w = w * weights.astype(emb.dtype)
    out = jnp.sum(emb * w[..., None], axis=2)
    if combiner == "mean":
        out = out / jnp.maximum(w.sum(axis=2), 1.0)[..., None]
    return shard(out, "act_embed_bag")


def hash_ids(raw: jax.Array, vocab: int, salt: int = 0) -> jax.Array:
    """Cheap multiplicative hash into [0, vocab) for synthetic/raw ids."""
    h = (raw.astype(jnp.uint32) + jnp.uint32(salt)) * jnp.uint32(2654435761)
    return (h % jnp.uint32(vocab)).astype(jnp.int32)
