"""Feature-interaction ops shared by the recsys architectures."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dot_interaction(feats: jax.Array, self_dots: bool = False) -> jax.Array:
    """DLRM dot interaction: pairwise dots of [B, F, D] -> [B, F*(F-1)/2]."""
    b, f, d = feats.shape
    dots = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(f, k=0 if self_dots else 1)
    return dots[:, iu, ju]


def cross_layer(x0: jax.Array, x: jax.Array, w: jax.Array, b: jax.Array):
    """DCN-v2 full-rank cross: x_{l+1} = x0 * (W x_l + b) + x_l."""
    return x0 * (x @ w + b) + x


def cross_layer_lowrank(x0, x, u, v, b):
    """DCN-v2 low-rank cross: x0 * (U(Vx) + b) + x."""
    return x0 * ((x @ v) @ u + b) + x


def mlp(params: list[dict], x: jax.Array, final_act: bool = False) -> jax.Array:
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init_mlp_params(key, sizes: list[int], dtype=jnp.float32) -> list[dict]:
    keys = jax.random.split(key, len(sizes) - 1)
    out = []
    for k, din, dout in zip(keys, sizes[:-1], sizes[1:]):
        out.append(
            {
                "w": (jax.random.normal(k, (din, dout)) * (2.0 / din) ** 0.5).astype(dtype),
                "b": jnp.zeros((dout,), dtype),
            }
        )
    return out
