"""The four assigned recsys architectures behind one RecModel interface.

* dlrm-mlperc  [arXiv:1906.00091]  — bottom MLP -> dot interaction -> top MLP
* dcn-v2      [arXiv:2008.13535]  — cross network ∥ deep MLP
* wide-deep   [arXiv:1606.07792]  — wide linear ∥ deep MLP
* dien        [arXiv:1809.03672]  — GRU over behaviour seq + AUGRU attention

Every model exposes ``init(key) -> params`` and
``apply(params, batch, shard) -> logits [B]``; training uses BCE loss.
Batches are dicts of dense features / sparse ids / (dien) behaviour
sequences.  The ``retrieval_cand`` shape (1 query vs 10^6 candidates) is
served by ``score_candidates`` — a batched dot against candidate item
embeddings — and, as the paper-technique integration, by the RTAMS IVF index
(examples/recsys_retrieval.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Shard, no_shard
from repro.models.recsys.embedding import (
    EmbeddingSpec,
    init_embedding,
    lookup,
)
from repro.models.recsys.interactions import (
    cross_layer,
    dot_interaction,
    init_mlp_params,
    mlp,
)


@dataclasses.dataclass(frozen=True)
class RecConfig:
    name: str
    kind: str  # dlrm | dcn_v2 | wide_deep | dien
    n_dense: int
    vocab_sizes: tuple
    embed_dim: int
    bot_mlp: tuple = ()
    top_mlp: tuple = ()
    mlp_sizes: tuple = ()
    n_cross_layers: int = 0
    # dien
    seq_len: int = 0
    gru_dim: int = 0
    unroll: bool = False  # python-loop the GRU (dry-run FLOP accounting)
    dtype: Any = jnp.float32

    @property
    def spec(self) -> EmbeddingSpec:
        return EmbeddingSpec(vocab_sizes=self.vocab_sizes, dim=self.embed_dim)

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)


# ------------------------------------------------------------------ DLRM --


def _init_dlrm(key, cfg: RecConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    f = cfg.n_sparse + 1  # +1: bottom-MLP output joins the interaction
    n_inter = f * (f - 1) // 2
    top_in = n_inter + cfg.bot_mlp[-1]
    return {
        "embed": init_embedding(k1, cfg.spec, cfg.dtype),
        "bot": init_mlp_params(k2, [cfg.n_dense, *cfg.bot_mlp], cfg.dtype),
        "top": init_mlp_params(k3, [top_in, *cfg.top_mlp], cfg.dtype),
    }


def _apply_dlrm(params, cfg: RecConfig, batch, shard: Shard):
    dense = mlp(params["bot"], batch["dense"].astype(cfg.dtype), final_act=True)
    emb = lookup(params["embed"], cfg.spec, batch["sparse"], shard)
    feats = jnp.concatenate([dense[:, None, :], emb], axis=1)
    inter = dot_interaction(feats)
    top_in = jnp.concatenate([inter, dense], axis=-1)
    return mlp(params["top"], top_in)[:, 0]


# ---------------------------------------------------------------- DCN-v2 --


def _init_dcn(key, cfg: RecConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    cross = []
    for i, kk in enumerate(jax.random.split(k2, cfg.n_cross_layers)):
        cross.append(
            {
                "w": (jax.random.normal(kk, (d_in, d_in)) * d_in**-0.5).astype(cfg.dtype),
                "b": jnp.zeros((d_in,), cfg.dtype),
            }
        )
    head_in = d_in + cfg.mlp_sizes[-1]
    return {
        "embed": init_embedding(k1, cfg.spec, cfg.dtype),
        "cross": cross,
        "deep": init_mlp_params(k3, [d_in, *cfg.mlp_sizes], cfg.dtype),
        "head": init_mlp_params(k4, [head_in, 1], cfg.dtype),
    }


def _apply_dcn(params, cfg: RecConfig, batch, shard: Shard):
    emb = lookup(params["embed"], cfg.spec, batch["sparse"], shard)
    x0 = jnp.concatenate(
        [batch["dense"].astype(cfg.dtype), emb.reshape(emb.shape[0], -1)], -1
    )
    x = x0
    for layer in params["cross"]:
        x = cross_layer(x0, x, layer["w"], layer["b"])
    deep = mlp(params["deep"], x0, final_act=True)
    return mlp(params["head"], jnp.concatenate([x, deep], -1))[:, 0]


# ------------------------------------------------------------- Wide&Deep --


def _init_wide_deep(key, cfg: RecConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d_in = cfg.n_sparse * cfg.embed_dim
    # wide part: a dim-1 embedding per field = linear over one-hots
    wide_spec = EmbeddingSpec(vocab_sizes=cfg.vocab_sizes, dim=1)
    return {
        "embed": init_embedding(k1, cfg.spec, cfg.dtype),
        "wide": init_embedding(k2, wide_spec, cfg.dtype),
        "deep": init_mlp_params(k3, [d_in, *cfg.mlp_sizes, 1], cfg.dtype),
    }


def _apply_wide_deep(params, cfg: RecConfig, batch, shard: Shard):
    emb = lookup(params["embed"], cfg.spec, batch["sparse"], shard)
    deep = mlp(params["deep"], emb.reshape(emb.shape[0], -1))[:, 0]
    wide_spec = EmbeddingSpec(vocab_sizes=cfg.vocab_sizes, dim=1)
    wide = lookup(params["wide"], wide_spec, batch["sparse"], shard)
    return deep + wide.sum(axis=(1, 2))


# ------------------------------------------------------------------ DIEN --


def _gru_cell(p, h, x):
    zr = jax.nn.sigmoid(x @ p["w_zr"] + h @ p["u_zr"] + p["b_zr"])
    z, r = jnp.split(zr, 2, axis=-1)
    hh = jnp.tanh(x @ p["w_h"] + (r * h) @ p["u_h"] + p["b_h"])
    return (1 - z) * h + z * hh


def _augru_cell(p, h, x, att):
    """AUGRU: attention scales the update gate (DIEN §4.3)."""
    zr = jax.nn.sigmoid(x @ p["w_zr"] + h @ p["u_zr"] + p["b_zr"])
    z, r = jnp.split(zr, 2, axis=-1)
    z = z * att[:, None]
    hh = jnp.tanh(x @ p["w_h"] + (r * h) @ p["u_h"] + p["b_h"])
    return (1 - z) * h + z * hh


def _init_gru(key, d_in, d_h, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_h = d_in**-0.5, d_h**-0.5
    return {
        "w_zr": (jax.random.normal(k1, (d_in, 2 * d_h)) * s_in).astype(dtype),
        "u_zr": (jax.random.normal(k2, (d_h, 2 * d_h)) * s_h).astype(dtype),
        "b_zr": jnp.zeros((2 * d_h,), dtype),
        "w_h": (jax.random.normal(k3, (d_in, d_h)) * s_in).astype(dtype),
        "u_h": (jax.random.normal(k4, (d_h, d_h)) * s_h).astype(dtype),
        "b_h": jnp.zeros((d_h,), dtype),
    }


def _init_dien(key, cfg: RecConfig):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d_e = cfg.embed_dim
    # profile fields = all but field 0 (item vocab used for history+target)
    d_profile = (cfg.n_sparse - 1) * d_e
    d_in = d_profile + cfg.gru_dim + d_e
    return {
        "embed": init_embedding(k1, cfg.spec, cfg.dtype),
        "gru1": _init_gru(k2, d_e, cfg.gru_dim, cfg.dtype),
        "augru": _init_gru(k3, cfg.gru_dim, cfg.gru_dim, cfg.dtype),
        "att": init_mlp_params(k4, [cfg.gru_dim + d_e, 64, 1], cfg.dtype),
        "mlp": init_mlp_params(k5, [d_in, *cfg.mlp_sizes, 1], cfg.dtype),
    }


def _apply_dien(params, cfg: RecConfig, batch, shard: Shard):
    spec = cfg.spec
    emb_all = lookup(params["embed"], spec, batch["sparse"], shard)  # [B,F,D]
    target = emb_all[:, 0]  # field 0 = target item
    profile = emb_all[:, 1:].reshape(emb_all.shape[0], -1)
    # history: [B, L] ids in item vocab (field 0)
    hist_ids = batch["history"]
    hist = jnp.take(params["embed"]["table"], hist_ids.reshape(-1), axis=0)
    hist = hist.reshape(*hist_ids.shape, cfg.embed_dim)  # [B, L, D]
    b, l, _ = hist.shape

    # interest extraction GRU over the sequence
    def step1(h, x_t):
        h = _gru_cell(params["gru1"], h, x_t)
        return h, h

    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)
    hist_t = jnp.swapaxes(hist, 0, 1)
    if cfg.unroll:
        hcur, ss = h0, []
        for t in range(l):
            hcur, _ = step1(hcur, hist_t[t])
            ss.append(hcur)
        states = jnp.stack(ss, axis=1)  # [B, L, gru]
    else:
        _, states = jax.lax.scan(step1, h0, hist_t)
        states = jnp.swapaxes(states, 0, 1)  # [B, L, gru]

    # attention vs target
    att_in = jnp.concatenate(
        [states, jnp.broadcast_to(target[:, None], (b, l, cfg.embed_dim))], -1
    )
    att = mlp(params["att"], att_in.reshape(b * l, -1)).reshape(b, l)
    att = jax.nn.softmax(att, axis=-1)

    # interest evolution AUGRU
    def step2(h, inp):
        x_t, a_t = inp
        h = _augru_cell(params["augru"], h, x_t, a_t)
        return h, None

    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)
    if cfg.unroll:
        hT = h0
        for t in range(l):
            hT, _ = step2(hT, (states[:, t], att[:, t]))
    else:
        hT, _ = jax.lax.scan(
            step2, h0, (jnp.swapaxes(states, 0, 1), jnp.swapaxes(att, 0, 1))
        )
    x = jnp.concatenate([profile, hT, target], -1)
    return mlp(params["mlp"], x)[:, 0]


# ------------------------------------------------------------- interface --

_INIT = {
    "dlrm": _init_dlrm,
    "dcn_v2": _init_dcn,
    "wide_deep": _init_wide_deep,
    "dien": _init_dien,
}
_APPLY = {
    "dlrm": _apply_dlrm,
    "dcn_v2": _apply_dcn,
    "wide_deep": _apply_wide_deep,
    "dien": _apply_dien,
}


def init_rec(key, cfg: RecConfig) -> dict:
    return _INIT[cfg.kind](key, cfg)


def apply_rec(params, cfg: RecConfig, batch: dict, shard: Shard = no_shard):
    return _APPLY[cfg.kind](params, cfg, batch, shard)


def rec_loss(params, cfg: RecConfig, batch: dict, shard: Shard = no_shard):
    logits = apply_rec(params, cfg, batch, shard)
    labels = batch["label"].astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"loss": loss}


def score_candidates(
    params, cfg: RecConfig, batch: dict, cand_emb: jax.Array,
    shard: Shard = no_shard, k: int = 100,
):
    """retrieval_cand shape: one user context vs [N, D] candidate items.

    Uses the deep tower's penultimate representation projected to embed_dim
    as the query; scoring is one [1, D] x [D, N] matmul + top-k (never a
    loop).  The RTAMS IVF path for the same task lives in examples/.
    """
    emb = lookup(params["embed"], cfg.spec, batch["sparse"], shard)
    query = emb.mean(axis=1)  # [B=1, D] pooled user context
    scores = query @ cand_emb.T  # [1, N]
    return jax.lax.top_k(scores, k)
