"""Transformer building blocks: RMSNorm, RoPE, GQA attention, SwiGLU.

Pure-JAX parameter dicts (no flax).  Every block takes an optional
``shard`` callback — ``shard(x, logical_name)`` applies a
``with_sharding_constraint`` when running under a mesh (see
repro/launch/shardings.py); the default is identity so the same code runs
unsharded in smoke tests.

Compute dtype is the params' dtype (bf16 in production configs); softmax
and norms accumulate in fp32.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Shard = Callable[[jax.Array, str], jax.Array]


def no_shard(x: jax.Array, name: str) -> jax.Array:
    return x


# ----------------------------------------------------------------- norms --


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ rope --


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, dh]; positions [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention --


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_chunk: int = 512  # query-chunked causal attention threshold/size
    unroll: bool = False  # python-loop chunks (dry-run: exact HLO flops)


def init_attn(key, cfg: AttnConfig, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s = d**-0.5
    p = {
        "wq": (jax.random.normal(k1, (d, h * dh)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, kv * dh)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, kv * dh)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (h * dh, d)) * (h * dh) ** -0.5).astype(
            dtype
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((dh,), dtype)
        p["k_scale"] = jnp.ones((dh,), dtype)
    return p


def _qkv(p, cfg: AttnConfig, x, positions, shard: Shard):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard(q.reshape(b, s, h, dh), "act_heads")
    k = shard(k.reshape(b, s, kv, dh), "act_kv_heads")
    v = shard(v.reshape(b, s, kv, dh), "act_kv_heads")
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_scale"])
        k = rmsnorm(k, p["k_scale"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_chunked(q, k, v, cfg: AttnConfig, shard: Shard, causal=True):
    """Query-chunked causal attention: live logits stay [B,H,Cq,S].

    The chunk scan is the pure-JAX flash analogue — O(S) memory in the
    query dimension; the KV tensor stays resident (sharded over heads).
    """
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = dh**-0.5
    cq = min(cfg.attn_chunk, s)
    s_pad = -(-s // cq) * cq  # pad queries up to a chunk multiple
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    n_chunks = s_pad // cq
    qg = q.reshape(b, s_pad, kvh, g, dh)
    kT = k  # [b, s, kvh, dh]

    def chunk_fn(_, idx):
        q_c = jax.lax.dynamic_slice_in_dim(qg, idx * cq, cq, axis=1)
        logits = jnp.einsum(
            "bqkgd,bskd->bkgqs", q_c, kT, preferred_element_type=jnp.float32
        ) * scale  # [b, kvh, g, cq, s]
        if causal:
            qpos = idx * cq + jnp.arange(cq)
            mask = qpos[:, None] >= jnp.arange(s)[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum(
            "bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        return None, o.astype(q.dtype)

    if cfg.unroll:
        # python loop: every chunk appears in the HLO, so cost_analysis
        # counts the true FLOPs (scan bodies are counted once by XLA)
        chunks = jnp.stack(
            [chunk_fn(None, jnp.int32(i))[1] for i in range(n_chunks)]
        )
    else:
        _, chunks = jax.lax.scan(chunk_fn, None, jnp.arange(n_chunks))
    # chunks [n_chunks, b, cq, kvh, g, dh] -> [b, s, h, dh]
    out = jnp.moveaxis(chunks, 0, 1).reshape(b, s_pad, kvh, g, dh)
    return out.reshape(b, s_pad, h, dh)[:, :s]


def attention(
    p: dict,
    cfg: AttnConfig,
    x: jax.Array,  # [B, S, D]
    positions: jax.Array,  # [B, S]
    shard: Shard = no_shard,
    causal: bool = True,
):
    """Full-sequence (training / prefill) attention.  Returns [B, S, D]."""
    q, k, v = _qkv(p, cfg, x, positions, shard)
    out = _sdpa_chunked(q, k, v, cfg, shard, causal=causal)
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head)
    return shard(out @ p["wo"], "act_embed")


def attention_decode(
    p: dict,
    cfg: AttnConfig,
    x: jax.Array,  # [B, 1, D] new token embeddings
    k_cache: jax.Array,  # [B, S, KV, dh] (running)
    v_cache: jax.Array,
    cache_len: jax.Array,  # [] or [B] current length (tokens already cached)
    shard: Shard = no_shard,
):
    """Single-token decode against a contiguous KV cache.

    Returns (out [B, 1, D], k_cache', v_cache').  The paged-KV variant
    (block-pool cache + Pallas kernel) lives in serve.py / kernels.
    """
    b = x.shape[0]
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pos = jnp.broadcast_to(
        jnp.asarray(cache_len).reshape(-1)[:, None], (b, 1)
    ).astype(jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, pos, shard)  # [B, 1, ...]
    idx = jnp.asarray(cache_len).reshape(())
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), idx, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), idx, axis=1
    )
    s = k_cache.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, dh)
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * (dh**-0.5)
    mask = jnp.arange(s)[None, None, None, :] <= idx
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum(
        "bkgs,bskd->bkgd", w.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out = o.reshape(b, 1, h * dh) @ p["wo"]
    return shard(out, "act_embed"), k_cache, v_cache


# ---------------------------------------------------------------- swiglu --


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * d_model**-0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * d_model**-0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * d_ff**-0.5).astype(dtype),
    }


def mlp_swiglu(p: dict, x: jax.Array, shard: Shard = no_shard) -> jax.Array:
    gate = shard(x @ p["w_gate"], "act_ff")
    up = shard(x @ p["w_up"], "act_ff")
    return shard((jax.nn.silu(gate) * up) @ p["w_down"], "act_embed")
