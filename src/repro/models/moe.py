"""Mixture-of-Experts layer: top-k routing + sort-based capacity dispatch.

Expert-parallel layout: expert weight tensors are sharded over the "model"
mesh axis; the dispatch gather/scatter becomes an all-to-all under GSPMD.
Dispatch is sort-based (MegaBlocks/MaxText style) rather than dense one-hot:
token->expert pairs are ranked per expert with the same cumulative trick the
IVF insert uses, truncated at a static capacity, then gathered into an
[E, C, D] tensor for a grouped einsum.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.layers import Shard, no_shard


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_dtype: object = jnp.float32


def init_moe(key, cfg: MoEConfig, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    return {
        "router": (jax.random.normal(k1, (d, e)) * d**-0.5).astype(
            jnp.float32
        ),
        "w_gate": (jax.random.normal(k2, (e, d, f)) * d**-0.5).astype(dtype),
        "w_up": (jax.random.normal(k3, (e, d, f)) * d**-0.5).astype(dtype),
        "w_down": (jax.random.normal(k4, (e, f, d)) * f**-0.5).astype(dtype),
    }


def _rank_within_expert(expert_ids: jax.Array, n_experts: int):
    """Position of each (token,k) pair within its expert's queue."""
    n = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    rank = jnp.zeros((n,), jnp.int32).at[order].set(idx - run_start)
    return rank


def moe_apply(
    p: dict,
    cfg: MoEConfig,
    x: jax.Array,  # [T, D] flattened tokens
    shard: Shard = no_shard,
):
    """Returns (out [T, D], aux) where aux has load-balance stats/loss."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, (t * k / e) * cfg.capacity_factor))

    logits = (x.astype(cfg.router_dtype)) @ p["router"]  # [T, E] fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)  # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- flatten (token, k) pairs and rank within expert ----------------
    flat_e = expert.reshape(-1).astype(jnp.int32)  # [T*K]
    flat_g = gate.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    pos = _rank_within_expert(flat_e, e)  # [T*K]
    keep = pos < cap  # capacity truncation (dropped pairs lose their gate)

    # scatter pair -> (expert, slot)
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)  # OOB = dropped
    tok_for_slot = jnp.full((e * cap,), t, jnp.int32)  # t = padding token row
    tok_for_slot = tok_for_slot.at[slot].set(flat_tok, mode="drop")
    gate_for_slot = jnp.zeros((e * cap,), flat_g.dtype).at[slot].set(
        flat_g, mode="drop"
    )

    # gather tokens into expert buffers (all-to-all under EP sharding)
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[tok_for_slot].reshape(e, cap, d)
    xe = shard(xe, "moe_experts")

    # ---- grouped expert FFN (einsum over the expert axis) ---------------
    h_gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    ye = shard(ye, "moe_experts")

    # ---- combine: weighted scatter-add back to tokens --------------------
    yflat = ye.reshape(e * cap, d) * gate_for_slot[:, None].astype(ye.dtype)
    out = jnp.zeros((t + 1, d), ye.dtype).at[tok_for_slot].add(yflat)[:t]

    # Switch-style load balance loss
    me = probs.mean(axis=0)  # [E] mean router prob
    ce = jax.ops.segment_sum(
        jnp.ones_like(flat_e, dtype=jnp.float32), flat_e, num_segments=e
    ) / (t * k)
    aux_loss = e * jnp.sum(me * ce)
    dropped = 1.0 - keep.mean()
    return out.astype(x.dtype), {"aux_loss": aux_loss, "drop_frac": dropped}
