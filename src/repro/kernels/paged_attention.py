"""Pallas TPU kernel: paged decode attention over a block-pool KV cache.

This is the paper's memory-block idea transplanted to LM serving (DESIGN.md
§6): the KV cache grows token-by-token exactly like an IVF list grows
vector-by-vector, so it lives in the same kind of central block pool with a
per-sequence block table — appends are O(1) and allocation-free, and no
cache copy ever happens on growth (vs. contiguous caches that must be
re-allocated or pre-sized per sequence).

Kernel shape: flash-decoding style streaming softmax over the sequence's
blocks.  Grid (batch, kv_head, block); the block table and lengths arrive
via scalar prefetch and drive the BlockSpec index maps (the same indirection
as ``ivf_scan``).  GQA groups (H // KVH query heads) are scored together so
the MXU contraction is [G, dh] x [dh, T].

VMEM scratch carries the running (max, sum, acc) across the block dimension;
the output is written on the last block step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(
    tables_ref,  # scalar prefetch [B, NB]
    lengths_ref,  # scalar prefetch [B]
    q_ref,  # [G, dh]
    k_ref,  # [T, dh]
    v_ref,  # [T, dh]
    o_ref,  # [G, dh]
    m_s,  # VMEM [G, 128] running max
    l_s,  # VMEM [G, 128] running sum
    acc_s,  # VMEM [G, dh] running numerator
    *,
    scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[:].astype(jnp.float32)  # [G, dh]
    k = k_ref[:].astype(jnp.float32)  # [T, dh]
    v = v_ref[:].astype(jnp.float32)
    t = k.shape[0]

    logits = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * scale
    )  # [G, T]
    length = lengths_ref[b]
    pos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) + j * t
    mask = pos < length
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_s[:, 0:1]  # [G, 1]
    m_cur = jnp.max(logits, axis=1, keepdims=True)  # [G, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)  # [G, 1]
    p = jnp.exp(logits - m_new) * mask.astype(jnp.float32)  # [G, T]
    l_new = l_s[:, 0:1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_s[...] = jnp.broadcast_to(m_new, m_s.shape)
    l_s[...] = jnp.broadcast_to(l_new, l_s.shape)

    @pl.when(j == nb - 1)
    def _finish():
        l = l_s[:, 0:1]
        o_ref[...] = (acc_s[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(
    q: jax.Array,  # [B, H, dh]
    k_pool: jax.Array,  # [P, T, KVH, dh]
    v_pool: jax.Array,  # [P, T, KVH, dh]
    block_tables: jax.Array,  # [B, NB] i32, -1 past end
    lengths: jax.Array,  # [B] i32
    *,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:  # [B, H, dh]
    b, h, dh = q.shape
    p, t, kvh, dh2 = k_pool.shape
    assert dh == dh2 and h % kvh == 0
    g = h // kvh
    nb = block_tables.shape[1]
    if scale is None:
        scale = float(dh) ** -0.5
    safe_tables = jnp.maximum(block_tables, 0).astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, nb),
        in_specs=[
            pl.BlockSpec((None, g, dh), lambda bi, hi, ji, tb, ln: (bi, hi, 0)),
            pl.BlockSpec(
                (None, t, None, dh),
                lambda bi, hi, ji, tb, ln: (tb[bi, ji], 0, hi, 0),
            ),
            pl.BlockSpec(
                (None, t, None, dh),
                lambda bi, hi, ji, tb, ln: (tb[bi, ji], 0, hi, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, g, dh), lambda bi, hi, ji, tb, ln: (bi, hi, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    qr = q.reshape(b, kvh * g, dh)  # heads grouped by kv head
    out = pl.pallas_call(
        functools.partial(_paged_attn_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh * g, dh), q.dtype),
        interpret=interpret,
    )(safe_tables, lengths, qr, k_pool, v_pool)
    return out.reshape(b, h, dh)
