"""Pallas TPU kernel: fused IVF block scan (the paper's search hot loop).

Design (TPU re-derivation of the paper's coalesced scan, DESIGN.md §8):

* The *union* of candidate blocks across the query batch is computed once;
  the kernel reads **each pool block exactly once from HBM** (the GPU version
  re-reads hot lists per query; on TPU we instead amortise a block over the
  whole batch — this is the beyond-paper optimisation measured in §Perf).
* Block ids arrive via **scalar prefetch** (`PrefetchScalarGridSpec`), so the
  BlockSpec index map performs the block-table indirection — identical
  machinery to paged-attention KV lookup: HBM -> VMEM DMA of one `[T, D]`
  block per grid step, overlapped with the previous step's MXU matmul by the
  Pallas pipeline.
* Per step the MXU computes `[Q, D] x [D, T]` and the VPU fuses the
  `||q||² + ||v||² - 2qv` epilogue.  Q is padded to a multiple of 8
  (sublanes) by the wrapper; D and T are lane/tile aligned by construction
  (configs use D ∈ {64, 128}, T_m multiples of 128 in production).

Hole blocks (id == -1) are clamped to block 0; callers mask their scores.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(ids_ref, q_ref, pool_ref, out_ref):
    """Grid step c: score all queries against pool block ids[c]."""
    q = q_ref[:]  # [Q, D]
    blk = pool_ref[:]  # [T, D]
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [Q, 1]
    vn = jnp.sum(blk * blk, axis=-1)[None, :]  # [1, T]
    dots = jax.lax.dot_general(
        q,
        blk,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, T] on the MXU
    out_ref[:] = qn + vn - 2.0 * dots


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_block_scan(
    queries: jax.Array,  # [Q, D] f32
    pool: jax.Array,  # [P, T, D] f32
    block_ids: jax.Array,  # [C] i32 (-1 holes clamped to 0)
    *,
    interpret: bool = False,
) -> jax.Array:  # [C, Q, T]
    q, d = queries.shape
    p, t, d2 = pool.shape
    assert d == d2, (d, d2)
    c = block_ids.shape[0]
    safe_ids = jnp.maximum(block_ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((q, d), lambda i, ids: (0, 0)),
            pl.BlockSpec((None, t, d), lambda i, ids: (ids[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q, t), lambda i, ids: (i, 0, 0)),
    )
    return pl.pallas_call(
        _scan_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, q, t), jnp.float32),
        interpret=interpret,
    )(safe_ids, queries, pool)
