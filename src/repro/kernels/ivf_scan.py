"""Pallas TPU kernel: fused IVF block scan (the paper's search hot loop).

Design (TPU re-derivation of the paper's coalesced scan, DESIGN.md §8):

* The *union* of candidate blocks across the query batch is computed once;
  the kernel reads **each pool block exactly once from HBM** (the GPU version
  re-reads hot lists per query; on TPU we instead amortise a block over the
  whole batch — this is the beyond-paper optimisation measured in §Perf).
* Block ids arrive via **scalar prefetch** (`PrefetchScalarGridSpec`), so the
  BlockSpec index map performs the block-table indirection — identical
  machinery to paged-attention KV lookup: HBM -> VMEM DMA of one `[T, D]`
  block per grid step, overlapped with the previous step's MXU matmul by the
  Pallas pipeline.
* Per step the MXU computes `[Q, D] x [D, T]` and the VPU fuses the
  `||q||² + ||v||² - 2qv` epilogue.  Q is padded to a multiple of 8
  (sublanes) by the wrapper; D and T are lane/tile aligned by construction
  (configs use D ∈ {64, 128}, T_m multiples of 128 in production).

Hole blocks (id == -1) are clamped to block 0; callers mask their scores.

Three kernels live here:

* ``ivf_block_scan``   — scores only: emits the full ``[C, Q, T]`` tensor to
  HBM; the caller masks and runs one monolithic ``top_k`` over ``C*T``.
* ``ivf_block_topk``   — **fused streaming selection**: a per-query running
  top-``K'`` accumulator lives in VMEM scratch across the candidate-block
  grid.  Each grid step scores one pool block, fuses hole/membership/empty
  masking into the epilogue, and merges the masked ``[Q_t, T]`` partials into
  the accumulator with a co-sorted concat (two-stage selection).  Only
  ``[Q, K']`` (score, vector-id) pairs ever leave the kernel — the ``C·Q·T``
  intermediate never touches HBM.  The grid is tiled over Q so large batches
  keep the accumulator + query tile inside the VMEM budget (see
  docs/search_paths.md for the budget math).
* ``ivf_pq_block_topk`` — the same streaming selection over a **PQ-coded**
  pool (IVFPQ, paper §3.3): per grid step one ``[T, M]`` uint8 code block is
  DMA'd and scored by asymmetric distance against VMEM-resident per-(query,
  probe) LUTs, using the one-hot MXU contraction from ``pq_adc.py`` instead
  of a per-lane byte gather.  Residuals are per-probe, so each query selects
  its LUT row through a ``[Q, C]`` probe-slot index built in the union
  prologue (``core/search.py``); slot -1 marks an invalid (non-member /
  hole) candidate and is fused into the epilogue mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(ids_ref, q_ref, pool_ref, out_ref):
    """Grid step c: score all queries against pool block ids[c]."""
    q = q_ref[:]  # [Q, D]
    blk = pool_ref[:]  # [T, D]
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [Q, 1]
    vn = jnp.sum(blk * blk, axis=-1)[None, :]  # [1, T]
    dots = jax.lax.dot_general(
        q,
        blk,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, T] on the MXU
    out_ref[:] = qn + vn - 2.0 * dots


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_block_scan(
    queries: jax.Array,  # [Q, D] f32
    pool: jax.Array,  # [P, T, D] f32
    block_ids: jax.Array,  # [C] i32 (-1 holes clamped to 0)
    *,
    interpret: bool = False,
) -> jax.Array:  # [C, Q, T]
    q, d = queries.shape
    p, t, d2 = pool.shape
    assert d == d2, (d, d2)
    c = block_ids.shape[0]
    safe_ids = jnp.maximum(block_ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((q, d), lambda i, ids: (0, 0)),
            pl.BlockSpec((None, t, d), lambda i, ids: (ids[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q, t), lambda i, ids: (i, 0, 0)),
    )
    return pl.pallas_call(
        _scan_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, q, t), jnp.float32),
        interpret=interpret,
    )(safe_ids, queries, pool)


# ---------------------------------------------------------------------------
# Fused streaming top-k selection (no [C, Q, T] writeback)
# ---------------------------------------------------------------------------


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _topk_kernel(
    ids_ref,  # [C] i32 scalar prefetch (clamped block ids)
    q_ref,  # [Q_t, D]
    ok_ref,  # [Q_t, 1] i32 candidate validity (membership & non-hole)
    pool_ref,  # [T, D] current candidate block
    pid_ref,  # [1, T] i32 vector ids of the block
    out_d_ref,  # [Q_t, K']
    out_i_ref,  # [Q_t, K'] i32
    acc_d_ref,  # VMEM scratch [Q_t, K'] running best distances
    acc_i_ref,  # VMEM scratch [Q_t, K'] i32 running best ids
):
    """Grid (qi, ci): score block ids[ci] and merge into the accumulator."""
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc_d_ref[:] = jnp.full(acc_d_ref.shape, jnp.inf, jnp.float32)
        acc_i_ref[:] = jnp.full(acc_i_ref.shape, -1, jnp.int32)

    q = q_ref[:]  # [Q_t, D]
    blk = pool_ref[:]  # [T, D]
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [Q_t, 1]
    vn = jnp.sum(blk * blk, axis=-1)[None, :]  # [1, T]
    dots = jax.lax.dot_general(
        q, blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q_t, T] on the MXU
    scores = qn + vn - 2.0 * dots
    # fused epilogue: invalid slots (hole block, non-member query, empty
    # NULL-id slot) never leave the kernel
    ok = (ok_ref[:] != 0) & (pid_ref[:] != -1)  # [Q_t,1] & [1,T] -> [Q_t,T]
    scores = jnp.where(ok, scores, jnp.inf)
    cand_i = jnp.where(ok, jnp.broadcast_to(pid_ref[:], scores.shape), -1)
    # two-stage selection: merge the masked partial into the running top-K'
    # via co-sorted concat (stable ascending sort keyed on distance)
    cat_d = jnp.concatenate([acc_d_ref[:], scores], axis=1)
    cat_i = jnp.concatenate([acc_i_ref[:], cand_i], axis=1)
    srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=1)
    kp = acc_d_ref.shape[1]
    acc_d_ref[:] = srt_d[:, :kp]
    acc_i_ref[:] = srt_i[:, :kp]

    @pl.when(ci == nc - 1)
    def _emit():
        out_d_ref[:] = acc_d_ref[:]
        out_i_ref[:] = acc_i_ref[:]


@functools.partial(
    jax.jit, static_argnames=("kprime", "q_tile", "interpret")
)
def ivf_block_topk(
    queries: jax.Array,  # [Q, D] f32
    pool: jax.Array,  # [P, T, D] f32
    block_ids: jax.Array,  # [C] i32 (-1 holes; masked via cand_ok)
    pool_ids: jax.Array,  # [P, T] i32 vector ids (-1 = empty slot)
    cand_ok: jax.Array,  # [Q, C] bool/i32 per-(query, candidate) validity
    *,
    kprime: int,
    q_tile: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] dist asc, [Q, K'] ids)
    """Streaming top-``kprime``: one HBM read per candidate block, ``[Q, K']``
    writeback.  Rows of the output are sorted ascending; masked-out slots
    carry ``inf`` / id ``-1``.

    The accumulator merge uses ``jax.lax.sort`` inside the kernel body; this
    is validated in interpret mode (CPU CI) but not yet compiled via Mosaic
    on real TPU hardware — if the sort lowering is unsupported there, swap
    the merge for a bitonic network or route through ``ivf_block_topk_scan``
    (same semantics, pure XLA) until it is."""
    q, d = queries.shape
    p, t, d2 = pool.shape
    assert d == d2, (d, d2)
    c = block_ids.shape[0]
    qt = min(q_tile, _round_up(q, 8))
    qp = _round_up(q, qt)
    queries = jnp.pad(queries, ((0, qp - q), (0, 0)))
    cand_ok = jnp.pad(cand_ok.astype(jnp.int32), ((0, qp - q), (0, 0)))
    safe_ids = jnp.maximum(block_ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qp // qt, c),
        in_specs=[
            pl.BlockSpec((qt, d), lambda qi, ci, ids: (qi, 0)),
            pl.BlockSpec((qt, 1), lambda qi, ci, ids: (qi, ci)),
            pl.BlockSpec((None, t, d), lambda qi, ci, ids: (ids[ci], 0, 0)),
            pl.BlockSpec((1, t), lambda qi, ci, ids: (ids[ci], 0)),
        ],
        out_specs=[
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids: (qi, 0)),
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids: (qi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((qt, kprime), jnp.float32),
            pltpu.VMEM((qt, kprime), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        _topk_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qp, kprime), jnp.float32),
            jax.ShapeDtypeStruct((qp, kprime), jnp.int32),
        ],
        interpret=interpret,
    )(safe_ids, queries, cand_ok, pool, pool_ids)
    return out_d[:q], out_i[:q]


@functools.partial(jax.jit, static_argnames=("kprime", "chunk"))
def ivf_block_topk_scan(
    queries: jax.Array,  # [Q, D] f32
    pool: jax.Array,  # [P, T, D] f32
    block_ids: jax.Array,  # [C] i32
    pool_ids: jax.Array,  # [P, T] i32
    cand_ok: jax.Array,  # [Q, C] bool/i32
    *,
    kprime: int,
    chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Chunked ``lax.scan`` fallback for the fused path (CPU / interpret
    mode): same streaming top-``kprime`` semantics, peak intermediate
    ``[Q, chunk*T]`` instead of ``[C, Q, T]``."""
    q, d = queries.shape
    p, t, _ = pool.shape
    c = block_ids.shape[0]
    cp = _round_up(c, chunk)
    nch = cp // chunk
    ids_p = jnp.pad(block_ids, (0, cp - c), constant_values=-1)
    ok_p = jnp.pad(cand_ok.astype(bool), ((0, 0), (0, cp - c)))
    safe = jnp.maximum(ids_p, 0).reshape(nch, chunk)
    ok_ch = ok_p.reshape(q, nch, chunk).transpose(1, 0, 2)  # [nch, Q, chunk]
    qn = jnp.sum(queries * queries, axis=-1)[:, None, None]  # [Q, 1, 1]

    def step(carry, xs):
        acc_d, acc_i = carry
        sc, ok = xs  # [chunk], [Q, chunk]
        blocks = pool[sc]  # [chunk, T, D]
        vids = pool_ids[sc]  # [chunk, T]
        vn = jnp.sum(blocks * blocks, axis=-1)  # [chunk, T]
        dots = jnp.einsum("qd,ctd->qct", queries, blocks)
        scores = qn + vn[None, :, :] - 2.0 * dots  # [Q, chunk, T]
        okf = ok[:, :, None] & (vids != -1)[None, :, :]
        scores = jnp.where(okf, scores, jnp.inf).reshape(q, -1)
        cids = jnp.where(okf, jnp.broadcast_to(vids, okf.shape), -1)
        cat_d = jnp.concatenate([acc_d, scores], axis=1)
        cat_i = jnp.concatenate([acc_i, cids.reshape(q, -1)], axis=1)
        srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=1)
        return (srt_d[:, :kprime], srt_i[:, :kprime]), None

    init = (
        jnp.full((q, kprime), jnp.inf, jnp.float32),
        jnp.full((q, kprime), -1, jnp.int32),
    )
    (acc_d, acc_i), _ = jax.lax.scan(step, init, (safe, ok_ch))
    return acc_d, acc_i


# ---------------------------------------------------------------------------
# PQ-ADC fused streaming top-k (IVFPQ payload): LUT resident in VMEM,
# one [T, M] uint8 code block DMA'd per grid step, [Q, K'] writeback.
#
# The PQ family sorts with num_keys=2 (distance, then vector id): quantized
# payloads produce exact distance ties whenever two vectors share a code, so
# a deterministic id tiebreak is required for the kernel / scan / oracle to
# stay bit-identical.
# ---------------------------------------------------------------------------


def _pq_topk_kernel(
    ids_ref,  # [C] i32 scalar prefetch (clamped block ids)
    lut_ref,  # [Q_t, NP, M, K] per-(query, probe) ADC tables
    pslot_ref,  # [Q_t, 1] i32 probe slot of this candidate (-1 = invalid)
    codes_ref,  # [T, M] uint8 current candidate code block
    pid_ref,  # [1, T] i32 vector ids of the block
    out_d_ref,  # [Q_t, K']
    out_i_ref,  # [Q_t, K'] i32
    acc_d_ref,  # VMEM scratch [Q_t, K'] running best distances
    acc_i_ref,  # VMEM scratch [Q_t, K'] i32 running best ids
):
    """Grid (qi, ci): ADC-score block ids[ci] and merge into the accumulator."""
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc_d_ref[:] = jnp.full(acc_d_ref.shape, jnp.inf, jnp.float32)
        acc_i_ref[:] = jnp.full(acc_i_ref.shape, -1, jnp.int32)

    lut = lut_ref[:]  # [Q_t, NP, M, K]
    pslot = pslot_ref[:]  # [Q_t, 1]
    codes = codes_ref[:].astype(jnp.int32)  # [T, M]
    qt, np_, m, ksub = lut.shape
    t = codes.shape[0]
    # Residuals are per-probe: select each query's LUT for this candidate's
    # probe slot via a one-hot contraction (slot -1 matches nothing; the
    # zeroed LUT row is masked out below anyway).
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (qt, np_), 1)
    sel = (pslot == slot_iota).astype(jnp.float32)  # [Q_t, NP]
    lut_q = jax.lax.dot_general(
        sel[:, None, :],  # [Q_t, 1, NP]
        lut.reshape(qt, np_, m * ksub),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).reshape(qt, m, ksub)
    # ADC accumulation as dense MXU work: one-hot-expand each code column and
    # contract with the selected LUT row (same trick as pq_adc._adc_kernel).
    kiota = jax.lax.broadcasted_iota(jnp.int32, (t, ksub), 1)
    scores = jnp.zeros((qt, t), jnp.float32)
    for j in range(m):  # static unroll over subquantizers
        onehot = (codes[:, j][:, None] == kiota).astype(jnp.float32)  # [T, K]
        scores = scores + jax.lax.dot_general(
            lut_q[:, j, :],
            onehot,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [Q_t, T]
    # fused epilogue: non-member queries, hole blocks, empty NULL-id slots
    ok = (pslot != -1) & (pid_ref[:] != -1)  # [Q_t,1] & [1,T] -> [Q_t,T]
    scores = jnp.where(ok, scores, jnp.inf)
    cand_i = jnp.where(ok, jnp.broadcast_to(pid_ref[:], scores.shape), -1)
    cat_d = jnp.concatenate([acc_d_ref[:], scores], axis=1)
    cat_i = jnp.concatenate([acc_i_ref[:], cand_i], axis=1)
    srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=2)
    kp = acc_d_ref.shape[1]
    acc_d_ref[:] = srt_d[:, :kp]
    acc_i_ref[:] = srt_i[:, :kp]

    @pl.when(ci == nc - 1)
    def _emit():
        out_d_ref[:] = acc_d_ref[:]
        out_i_ref[:] = acc_i_ref[:]


@functools.partial(
    jax.jit, static_argnames=("kprime", "q_tile", "interpret")
)
def ivf_pq_block_topk(
    lut: jax.Array,  # [Q, NP, M, K] f32 per-(query, probe) ADC tables
    pool_codes: jax.Array,  # [P, T, M] uint8 PQ codes
    block_ids: jax.Array,  # [C] i32 (-1 holes; masked via pslot)
    pool_ids: jax.Array,  # [P, T] i32 vector ids (-1 = empty slot)
    pslot: jax.Array,  # [Q, C] i32 probe slot per (query, candidate); -1 = invalid
    *,
    kprime: int,
    q_tile: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] dist asc, [Q, K'] ids)
    """Streaming top-``kprime`` over a PQ-coded pool: one HBM read of each
    ``[T, M]`` uint8 candidate block, ADC against the VMEM-resident LUT tile,
    ``[Q, K']`` writeback.  Rows come back sorted ascending by (distance,
    id); invalid slots carry ``inf`` / id ``-1``.

    The LUT tile is the dominant VMEM resident (``q_tile·nprobe·M·256·4B``,
    see docs/search_paths.md), hence the small default ``q_tile`` of 8."""
    q, np_, m, ksub = lut.shape
    p, t, m2 = pool_codes.shape
    assert m == m2, (lut.shape, pool_codes.shape)
    c = block_ids.shape[0]
    qt = min(q_tile, _round_up(q, 8))
    qp = _round_up(q, qt)
    lut = jnp.pad(lut, ((0, qp - q), (0, 0), (0, 0), (0, 0)))
    pslot = jnp.pad(
        pslot.astype(jnp.int32), ((0, qp - q), (0, 0)), constant_values=-1
    )
    safe_ids = jnp.maximum(block_ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qp // qt, c),
        in_specs=[
            pl.BlockSpec((qt, np_, m, ksub), lambda qi, ci, ids: (qi, 0, 0, 0)),
            pl.BlockSpec((qt, 1), lambda qi, ci, ids: (qi, ci)),
            pl.BlockSpec((None, t, m), lambda qi, ci, ids: (ids[ci], 0, 0)),
            pl.BlockSpec((1, t), lambda qi, ci, ids: (ids[ci], 0)),
        ],
        out_specs=[
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids: (qi, 0)),
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids: (qi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((qt, kprime), jnp.float32),
            pltpu.VMEM((qt, kprime), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        _pq_topk_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qp, kprime), jnp.float32),
            jax.ShapeDtypeStruct((qp, kprime), jnp.int32),
        ],
        interpret=interpret,
    )(safe_ids, lut, pslot, pool_codes, pool_ids)
    return out_d[:q], out_i[:q]


@functools.partial(jax.jit, static_argnames=("kprime", "chunk"))
def ivf_pq_block_topk_scan(
    lut: jax.Array,  # [Q, NP, M, K] f32
    pool_codes: jax.Array,  # [P, T, M] uint8
    block_ids: jax.Array,  # [C] i32
    pool_ids: jax.Array,  # [P, T] i32
    pslot: jax.Array,  # [Q, C] i32, -1 = invalid
    *,
    kprime: int,
    chunk: int = 16,
) -> tuple[jax.Array, jax.Array]:
    """Chunked ``lax.scan`` fallback for the PQ fused path (CPU / interpret
    mode): same streaming top-``kprime`` semantics, peak intermediate
    ``[Q, chunk, T, M]`` gathered LUT terms instead of ``[C, Q, T]``."""
    q = lut.shape[0]
    p, t, m = pool_codes.shape
    c = block_ids.shape[0]
    cp = _round_up(c, chunk)
    nch = cp // chunk
    ids_p = jnp.pad(block_ids, (0, cp - c), constant_values=-1)
    ps_p = jnp.pad(
        pslot.astype(jnp.int32), ((0, 0), (0, cp - c)), constant_values=-1
    )
    safe = jnp.maximum(ids_p, 0).reshape(nch, chunk)
    ps_ch = ps_p.reshape(q, nch, chunk).transpose(1, 0, 2)  # [nch, Q, chunk]

    def step(carry, xs):
        acc_d, acc_i = carry
        sc, ps = xs  # [chunk], [Q, chunk]
        codes = pool_codes[sc].astype(jnp.int32)  # [chunk, T, M]
        vids = pool_ids[sc]  # [chunk, T]
        lq = jnp.take_along_axis(
            lut, jnp.clip(ps, 0)[:, :, None, None], axis=1
        )  # [Q, chunk, M, K]
        gathered = jnp.take_along_axis(
            lq[:, :, None, :, :],  # [Q, chunk, 1, M, K]
            codes[None, :, :, :, None],  # [1, chunk, T, M, 1]
            axis=-1,
        )[..., 0]  # [Q, chunk, T, M]
        scores = jnp.sum(gathered, axis=-1)  # [Q, chunk, T]
        okf = (ps != -1)[:, :, None] & (vids != -1)[None, :, :]
        scores = jnp.where(okf, scores, jnp.inf).reshape(q, -1)
        cids = jnp.where(okf, jnp.broadcast_to(vids, okf.shape), -1)
        cat_d = jnp.concatenate([acc_d, scores], axis=1)
        cat_i = jnp.concatenate([acc_i, cids.reshape(q, -1)], axis=1)
        srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=2)
        return (srt_d[:, :kprime], srt_i[:, :kprime]), None

    init = (
        jnp.full((q, kprime), jnp.inf, jnp.float32),
        jnp.full((q, kprime), -1, jnp.int32),
    )
    (acc_d, acc_i), _ = jax.lax.scan(step, init, (safe, ps_ch))
    return acc_d, acc_i
