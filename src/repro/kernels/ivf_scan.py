"""Pallas TPU kernel: fused IVF block scan (the paper's search hot loop).

Design (TPU re-derivation of the paper's coalesced scan, DESIGN.md §8):

* The *union* of candidate blocks across the query batch is computed once;
  the kernel reads **each pool block exactly once from HBM** (the GPU version
  re-reads hot lists per query; on TPU we instead amortise a block over the
  whole batch — this is the beyond-paper optimisation measured in §Perf).
* Block ids arrive via **scalar prefetch** (`PrefetchScalarGridSpec`), so the
  BlockSpec index map performs the block-table indirection — identical
  machinery to paged-attention KV lookup: HBM -> VMEM DMA of one `[T, D]`
  block per grid step, overlapped with the previous step's MXU matmul by the
  Pallas pipeline.
* Per step the MXU computes `[Q, D] x [D, T]` and the VPU fuses the
  `||q||² + ||v||² - 2qv` epilogue.  Q is padded to a multiple of 8
  (sublanes) by the wrapper; D and T are lane/tile aligned by construction
  (configs use D ∈ {64, 128}, T_m multiples of 128 in production).

Hole blocks (id == -1) are clamped to block 0; callers mask their scores.

The payload dtype is a first-class axis: ``ivf_block_topk`` serves float32
*and* bfloat16 blocks (bf16 halves the HBM bytes of the dominant scan loop;
the MXU takes bf16 natively with f32 accumulation), ``ivf_block_topk_int8``
quarters them by contracting int8 query codes against int8 pool codes on
the integer MXU — blocks are never dequantized; only the per-step epilogue
tile and the ``[Q, K']`` accumulator are float32.  ``rerank_topk`` is the
exact re-rank epilogue over the K' fused survivors (gather + fused
dequant/distance/sort) that buys the recall back.

Kernels living here:

* ``coarse_topk``      — **streaming coarse probe** (the routing prologue):
  centroid tiles stream through VMEM while a per-query top-``nprobe``
  accumulator (same streaming-selection machinery as ``ivf_block_topk``)
  keeps the running nearest centroids on-chip, so the ``[Q, N_clusters]``
  coarse distance matrix never exists in HBM — only ``[Q, NP]`` probe
  ids/distances are written back.  Ties break by centroid id, making the
  result bit-exact with ``coarse_probe``'s ``top_k`` (which also prefers
  the lower index on ties).
* ``ivf_block_scan``   — scores only: emits the full ``[C, Q, T]`` tensor to
  HBM; the caller masks and runs one monolithic ``top_k`` over ``C*T``.
* ``ivf_block_topk``   — **fused streaming selection**: a per-query running
  top-``K'`` accumulator lives in VMEM scratch across the candidate-block
  grid.  Each grid step scores one pool block, fuses hole/membership/empty
  masking into the epilogue, and merges the masked ``[Q_t, T]`` partials into
  the accumulator with a co-sorted concat (two-stage selection).  Only
  ``[Q, K']`` (score, packed pool location) pairs ever leave the kernel —
  the ``C·Q·T`` intermediate never touches HBM; callers resolve locations
  (``block*T + offset``) to global ids with one gather, and the re-rank
  epilogue decodes them straight back to rows.  The grid is tiled over Q so large batches
  keep the accumulator + query tile inside the VMEM budget (see
  docs/search_paths.md for the budget math).
* ``ivf_pq_block_topk`` — the same streaming selection over a **PQ-coded**
  pool (IVFPQ, paper §3.3): per grid step one ``[T, M]`` uint8 code block is
  DMA'd and scored by asymmetric distance against VMEM-resident per-(query,
  probe) LUTs, using the one-hot MXU contraction from ``pq_adc.py`` instead
  of a per-lane byte gather.

Candidate routing is **derived on-chip** (this is what keeps the per-query
HBM routing traffic at O(NP) instead of O(CB)): alongside the candidate
block ids, the kernels scalar-prefetch each block's **owning cluster**
(``IVFState.block_owner``, maintained incrementally by insert/rearrange)
and compare it against the query tile's ``[Q_t, NP]`` probed-cluster list
resident in VMEM.  A query is a member of the candidate iff its probe list
contains the owner; for the residual families (int8, PQ) the position of
the match *is* the probe slot that selects the query's per-probe residual
codes / ADC LUT — the dense ``[Q, CB]`` ``cand_ok``/``pslot`` operands of
the older interface no longer exist.  Probe lists hold distinct cluster
ids (a top-``nprobe`` cannot repeat), so at most one slot matches.  Owner
-1 (NULL candidate padding, free blocks) matches no probe and is masked in
the fused epilogue together with empty (-1) id slots.

Tombstones (the online-mutation subsystem): every fused kernel streams the
block's ``[1, T]`` u8 **live-mask** tile alongside the payload
(``IVFState.pool_live``; a deleted row keeps its slot — and its stale id —
until compaction, so the id channel alone cannot distinguish dead from
live) and forces dead rows to ``inf`` before the top-K' merge.  O(T) extra
bytes per block, negligible next to the ``[T, D]`` payload.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(ids_ref, q_ref, pool_ref, out_ref):
    """Grid step c: score all queries against pool block ids[c]."""
    q = q_ref[:]  # [Q, D]
    blk = pool_ref[:]  # [T, D] payload dtype (f32 | bf16)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [Q, 1]
    blkf = blk.astype(jnp.float32)
    vn = jnp.sum(blkf * blkf, axis=-1)[None, :]  # [1, T]
    dots = jax.lax.dot_general(
        q.astype(blk.dtype),
        blk,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, T] on the MXU
    out_ref[:] = qn + vn - 2.0 * dots


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_block_scan(
    queries: jax.Array,  # [Q, D] f32
    pool: jax.Array,  # [P, T, D] f32
    block_ids: jax.Array,  # [C] i32 (-1 holes clamped to 0)
    *,
    interpret: bool = False,
) -> jax.Array:  # [C, Q, T]
    q, d = queries.shape
    p, t, d2 = pool.shape
    assert d == d2, (d, d2)
    c = block_ids.shape[0]
    safe_ids = jnp.maximum(block_ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((q, d), lambda i, ids: (0, 0)),
            pl.BlockSpec((None, t, d), lambda i, ids: (ids[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q, t), lambda i, ids: (i, 0, 0)),
    )
    return pl.pallas_call(
        _scan_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, q, t), jnp.float32),
        interpret=interpret,
    )(safe_ids, queries, pool)


# ---------------------------------------------------------------------------
# Streaming coarse probe (fused routing prologue): the [Q, N_clusters]
# distance matrix never exists in HBM — centroid tiles stream through VMEM
# and a per-query top-nprobe accumulator keeps the running nearest
# centroids on-chip, exactly like ivf_block_topk keeps its top-K'.  Ties
# break by centroid id (sort num_keys=2), which matches lax.top_k's
# prefer-the-lower-index contract, so results are bit-exact with
# coarse_probe.
# ---------------------------------------------------------------------------


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _coarse_kernel(
    n: int,  # static: real centroid count (tail of the last tile is padding)
    q_ref,  # [Q_t, D] f32 queries
    c_ref,  # [TC, D] f32 current centroid tile
    out_d_ref,  # [Q_t, NPP] distances asc
    out_i_ref,  # [Q_t, NPP] i32 centroid ids
    acc_d_ref,  # VMEM scratch [Q_t, NPP]
    acc_i_ref,  # VMEM scratch [Q_t, NPP] i32
):
    """Grid (qi, ci): score centroid tile ci, merge into the accumulator."""
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc_d_ref[:] = jnp.full(acc_d_ref.shape, jnp.inf, jnp.float32)
        acc_i_ref[:] = jnp.full(acc_i_ref.shape, jnp.int32(2**31 - 1),
                                jnp.int32)

    q = q_ref[:]  # [Q_t, D]
    cents = c_ref[:]  # [TC, D]
    tc = cents.shape[0]
    # same ||q||^2 + ||c||^2 - 2qc formulation as coarse_probe's l2_sq —
    # per-element the contraction over D is identical, so distances match
    # bit for bit
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [Q_t, 1]
    cn = jnp.sum(cents * cents, axis=-1)[None, :]  # [1, TC]
    dots = jax.lax.dot_general(
        q, cents, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q_t, TC]
    d = qn + cn - 2.0 * dots
    gid = ci * tc + jax.lax.broadcasted_iota(jnp.int32, (1, tc), 1)
    valid = gid < n  # [1, TC] padding centroids past N
    d = jnp.where(valid, d, jnp.inf)
    cid = jnp.where(
        valid, jnp.broadcast_to(gid, d.shape), jnp.int32(2**31 - 1)
    )
    # merge via co-sorted concat keyed on (distance, centroid id): the id
    # tiebreak reproduces top_k's lower-index-wins ordering exactly
    cat_d = jnp.concatenate([acc_d_ref[:], d], axis=1)
    cat_i = jnp.concatenate([acc_i_ref[:], cid], axis=1)
    srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=2)
    npp = acc_d_ref.shape[1]
    acc_d_ref[:] = srt_d[:, :npp]
    acc_i_ref[:] = srt_i[:, :npp]

    @pl.when(ci == nc - 1)
    def _emit():
        out_d_ref[:] = acc_d_ref[:]
        out_i_ref[:] = acc_i_ref[:]


@functools.partial(
    jax.jit, static_argnames=("nprobe", "q_tile", "c_tile", "interpret")
)
def coarse_topk(
    queries: jax.Array,  # [Q, D] f32
    centroids: jax.Array,  # [N, D] f32
    *,
    nprobe: int,
    q_tile: int = 128,
    c_tile: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:  # ([Q, NP] i32 ids, [Q, NP] dists asc)
    """Streaming top-``nprobe`` nearest centroids: one HBM read of each
    ``[TC, D]`` centroid tile, ``[Q, NP]`` writeback — the ``[Q, N]``
    coarse matrix never touches HBM.  Bit-exact with ``coarse_probe``
    (ties included: lower centroid id wins).  Returns ``(ids, dists)`` in
    ``coarse_probe``'s order."""
    q, d = queries.shape
    n, d2 = centroids.shape
    assert d == d2, (d, d2)
    assert 0 < nprobe <= n, (nprobe, n)
    qt = min(q_tile, _round_up(q, 8))
    qp = _round_up(q, qt)
    tc = min(c_tile, _round_up(n, 8))
    npad = _round_up(n, tc)
    npp = _round_up(nprobe, 128)  # lane-aligned accumulator width
    queries = jnp.pad(queries, ((0, qp - q), (0, 0)))
    centroids = jnp.pad(centroids, ((0, npad - n), (0, 0)))
    out_d, out_i = pl.pallas_call(
        functools.partial(_coarse_kernel, n),
        grid=(qp // qt, npad // tc),
        in_specs=[
            pl.BlockSpec((qt, d), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((tc, d), lambda qi, ci: (ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((qt, npp), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((qt, npp), lambda qi, ci: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, npp), jnp.float32),
            jax.ShapeDtypeStruct((qp, npp), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qt, npp), jnp.float32),
            pltpu.VMEM((qt, npp), jnp.int32),
        ],
        interpret=interpret,
    )(queries, centroids)
    return out_i[:q, :nprobe], out_d[:q, :nprobe]


@functools.partial(jax.jit, static_argnames=("nprobe", "chunk"))
def coarse_topk_scan(
    queries: jax.Array,  # [Q, D] f32
    centroids: jax.Array,  # [N, D] f32
    *,
    nprobe: int,
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:  # ([Q, NP] i32 ids, [Q, NP] dists asc)
    """Chunked ``lax.scan`` fallback for the streaming coarse probe: same
    top-``nprobe`` semantics and bit-exact results, peak intermediate
    ``[Q, chunk]`` instead of ``[Q, N]``."""
    q, d = queries.shape
    n = centroids.shape[0]
    assert 0 < nprobe <= n, (nprobe, n)
    npad = _round_up(n, chunk)
    nch = npad // chunk
    cents = jnp.pad(centroids, ((0, npad - n), (0, 0))).reshape(
        nch, chunk, d
    )
    gids = jnp.arange(npad, dtype=jnp.int32).reshape(nch, chunk)
    qn = jnp.sum(queries * queries, axis=-1, keepdims=True)  # [Q, 1]

    def step(carry, xs):
        acc_d, acc_i = carry
        ct, gid = xs  # [chunk, D], [chunk]
        cn = jnp.sum(ct * ct, axis=-1)[None, :]  # [1, chunk]
        dots = jax.lax.dot_general(
            queries, ct, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        dd = qn + cn - 2.0 * dots  # [Q, chunk]
        valid = (gid < n)[None, :]
        dd = jnp.where(valid, dd, jnp.inf)
        cid = jnp.where(
            valid, jnp.broadcast_to(gid[None, :], dd.shape),
            jnp.int32(2**31 - 1),
        )
        cat_d = jnp.concatenate([acc_d, dd], axis=1)
        cat_i = jnp.concatenate([acc_i, cid], axis=1)
        srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=2)
        return (srt_d[:, :nprobe], srt_i[:, :nprobe]), None

    init = (
        jnp.full((q, nprobe), jnp.inf, jnp.float32),
        jnp.full((q, nprobe), jnp.int32(2**31 - 1), jnp.int32),
    )
    (acc_d, acc_i), _ = jax.lax.scan(step, init, (cents, gids))
    return acc_i, acc_d


# ---------------------------------------------------------------------------
# Fused streaming top-k selection (no [C, Q, T] writeback)
# ---------------------------------------------------------------------------


def _topk_kernel(
    ids_ref,  # [C] i32 scalar prefetch (clamped block ids)
    own_ref,  # [C] i32 scalar prefetch (owning cluster, -1 = NULL slot)
    q_ref,  # [Q_t, D]
    probe_ref,  # [Q_t, NP] i32 probed cluster ids of the query tile
    pool_ref,  # [T, D] current candidate block
    pid_ref,  # [1, T] i32 vector ids of the block
    live_ref,  # [1, T] u8 live mask of the block (0 = empty or tombstoned)
    out_d_ref,  # [Q_t, K']
    out_i_ref,  # [Q_t, K'] i32
    acc_d_ref,  # VMEM scratch [Q_t, K'] running best distances
    acc_i_ref,  # VMEM scratch [Q_t, K'] i32 running best ids
):
    """Grid (qi, ci): score block ids[ci] and merge into the accumulator."""
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc_d_ref[:] = jnp.full(acc_d_ref.shape, jnp.inf, jnp.float32)
        acc_i_ref[:] = jnp.full(acc_i_ref.shape, -1, jnp.int32)

    q = q_ref[:]  # [Q_t, D] f32
    blk = pool_ref[:]  # [T, D] payload dtype (f32 | bf16)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [Q_t, 1]
    blkf = blk.astype(jnp.float32)  # VMEM-local; HBM moved `blk.dtype` bytes
    vn = jnp.sum(blkf * blkf, axis=-1)[None, :]  # [1, T]
    # bf16 payloads feed the MXU natively (bf16 x bf16 -> f32 accumulate);
    # the cast is a no-op for f32
    dots = jax.lax.dot_general(
        q.astype(blk.dtype), blk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q_t, T] on the MXU
    scores = qn + vn - 2.0 * dots
    # in-kernel membership: a query owns this candidate iff its VMEM probe
    # list contains the block's prefetched owner (owner -1 = NULL padding
    # matches nothing); the [Q, C] cand_ok operand no longer exists
    member = jnp.any(
        probe_ref[:] == own_ref[ci], axis=1, keepdims=True
    )  # [Q_t, 1]
    # fused epilogue: invalid slots (hole block, non-member query, empty
    # NULL-id slot, tombstoned row) never leave the kernel — the streamed
    # [1, T] live tile costs O(T) bytes next to the [T, D] payload
    ok = member & (pid_ref[:] != -1) & (live_ref[:] != 0)
    scores = jnp.where(ok, scores, jnp.inf)
    # candidates carry their packed pool location (block*T + offset),
    # derived from the prefetched block id at zero HBM cost — it decodes
    # back to the row for the re-rank gather, which a caller-assigned
    # global id cannot; callers resolve locations to ids with one gather
    t = scores.shape[1]
    loc_row = ids_ref[ci] * t + jax.lax.broadcasted_iota(
        jnp.int32, (1, t), 1
    )
    cand_i = jnp.where(ok, jnp.broadcast_to(loc_row, scores.shape), -1)
    # two-stage selection: merge the masked partial into the running top-K'
    # via co-sorted concat (stable ascending sort keyed on distance)
    cat_d = jnp.concatenate([acc_d_ref[:], scores], axis=1)
    cat_i = jnp.concatenate([acc_i_ref[:], cand_i], axis=1)
    srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=1)
    kp = acc_d_ref.shape[1]
    acc_d_ref[:] = srt_d[:, :kp]
    acc_i_ref[:] = srt_i[:, :kp]

    @pl.when(ci == nc - 1)
    def _emit():
        out_d_ref[:] = acc_d_ref[:]
        out_i_ref[:] = acc_i_ref[:]


@functools.partial(
    jax.jit, static_argnames=("kprime", "q_tile", "interpret")
)
def ivf_block_topk(
    queries: jax.Array,  # [Q, D] f32
    pool: jax.Array,  # [P, T, D] f32
    block_ids: jax.Array,  # [C] i32 (-1 holes; masked via block_owners)
    block_owners: jax.Array,  # [C] i32 owning cluster (-1 = NULL slot)
    pool_ids: jax.Array,  # [P, T] i32 vector ids (-1 = empty slot)
    pool_live: jax.Array,  # [P, T] u8 live mask (0 = empty/tombstoned)
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters per query
    *,
    kprime: int,
    q_tile: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] dist asc, [Q, K'] locations)
    """Streaming top-``kprime``: one HBM read per candidate block, ``[Q, K']``
    writeback.  Membership is derived on-chip — each candidate's prefetched
    owner is compared against the VMEM-resident probe list, so the only
    per-query routing operand is the ``[Q, NP]`` probe list.  Rows of the
    output are sorted ascending; the id channel
    carries packed pool locations (``block*T + offset``; resolve to global
    ids via ``pool_ids.reshape(-1)[loc]``); masked-out slots carry
    ``inf`` / ``-1``.

    The accumulator merge uses ``jax.lax.sort`` inside the kernel body; this
    is validated in interpret mode (CPU CI) but not yet compiled via Mosaic
    on real TPU hardware — if the sort lowering is unsupported there, swap
    the merge for a bitonic network or route through ``ivf_block_topk_scan``
    (same semantics, pure XLA) until it is."""
    q, d = queries.shape
    p, t, d2 = pool.shape
    assert d == d2, (d, d2)
    c = block_ids.shape[0]
    qt = min(q_tile, _round_up(q, 8))
    qp = _round_up(q, qt)
    queries = jnp.pad(queries, ((0, qp - q), (0, 0)))
    probe_idx = jnp.pad(
        probe_idx.astype(jnp.int32), ((0, qp - q), (0, 0)),
        constant_values=-2,  # padding rows match nothing (owners may be -1)
    )
    safe_ids = jnp.maximum(block_ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(qp // qt, c),
        in_specs=[
            pl.BlockSpec((qt, d), lambda qi, ci, ids, own: (qi, 0)),
            pl.BlockSpec(
                (qt, probe_idx.shape[1]), lambda qi, ci, ids, own: (qi, 0)
            ),
            pl.BlockSpec(
                (None, t, d), lambda qi, ci, ids, own: (ids[ci], 0, 0)
            ),
            pl.BlockSpec((1, t), lambda qi, ci, ids, own: (ids[ci], 0)),
            pl.BlockSpec((1, t), lambda qi, ci, ids, own: (ids[ci], 0)),
        ],
        out_specs=[
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids, own: (qi, 0)),
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids, own: (qi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((qt, kprime), jnp.float32),
            pltpu.VMEM((qt, kprime), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        _topk_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qp, kprime), jnp.float32),
            jax.ShapeDtypeStruct((qp, kprime), jnp.int32),
        ],
        interpret=interpret,
    )(safe_ids, block_owners.astype(jnp.int32), queries, probe_idx,
      pool, pool_ids, pool_live.astype(jnp.uint8))
    return out_d[:q], out_i[:q]


@functools.partial(jax.jit, static_argnames=("kprime", "chunk"))
def ivf_block_topk_scan(
    queries: jax.Array,  # [Q, D] f32
    pool: jax.Array,  # [P, T, D] f32
    block_ids: jax.Array,  # [C] i32
    block_owners: jax.Array,  # [C] i32 owning cluster (-1 = NULL slot)
    pool_ids: jax.Array,  # [P, T] i32
    pool_live: jax.Array,  # [P, T] u8 live mask (0 = empty/tombstoned)
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters per query
    *,
    kprime: int,
    chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Chunked ``lax.scan`` fallback for the fused path (CPU / interpret
    mode): same streaming top-``kprime`` semantics, peak intermediate
    ``[Q, chunk*T]`` instead of ``[C, Q, T]`` — membership is derived per
    chunk from the candidate owners ([Q, NP, chunk] compare), never as a
    dense [Q, C] operand."""
    q, d = queries.shape
    p, t, _ = pool.shape
    c = block_ids.shape[0]
    cp = _round_up(c, chunk)
    nch = cp // chunk
    ids_p = jnp.pad(block_ids, (0, cp - c), constant_values=-1)
    own_p = jnp.pad(
        block_owners.astype(jnp.int32), (0, cp - c), constant_values=-1
    )
    safe = jnp.maximum(ids_p, 0).reshape(nch, chunk)
    own_ch = own_p.reshape(nch, chunk)
    probe = probe_idx.astype(jnp.int32)
    qn = jnp.sum(queries * queries, axis=-1)[:, None, None]  # [Q, 1, 1]

    def step(carry, xs):
        acc_d, acc_i = carry
        sc, own = xs  # [chunk], [chunk]
        ok = jnp.any(
            probe[:, :, None] == own[None, None, :], axis=1
        )  # [Q, chunk]
        blocks = pool[sc]  # [chunk, T, D] payload dtype (f32 | bf16)
        vids = pool_ids[sc]  # [chunk, T]
        lives = pool_live[sc] != 0  # [chunk, T]
        bf = blocks.astype(jnp.float32)
        vn = jnp.sum(bf * bf, axis=-1)  # [chunk, T]
        dots = jnp.einsum(
            "qd,ctd->qct", queries.astype(pool.dtype), blocks,
            preferred_element_type=jnp.float32,
        )
        scores = qn + vn[None, :, :] - 2.0 * dots  # [Q, chunk, T]
        locs = sc[:, None] * t + jnp.arange(t, dtype=jnp.int32)[None, :]
        okf = ok[:, :, None] & ((vids != -1) & lives)[None, :, :]
        scores = jnp.where(okf, scores, jnp.inf).reshape(q, -1)
        cids = jnp.where(okf, jnp.broadcast_to(locs, okf.shape), -1)
        cat_d = jnp.concatenate([acc_d, scores], axis=1)
        cat_i = jnp.concatenate([acc_i, cids.reshape(q, -1)], axis=1)
        srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=1)
        return (srt_d[:, :kprime], srt_i[:, :kprime]), None

    init = (
        jnp.full((q, kprime), jnp.inf, jnp.float32),
        jnp.full((q, kprime), -1, jnp.int32),
    )
    (acc_d, acc_i), _ = jax.lax.scan(step, init, (safe, own_ch))
    return acc_d, acc_i


# ---------------------------------------------------------------------------
# int8 fused streaming top-k: the candidate blocks stay int8 end to end —
# the MXU contracts int8 query codes against int8 pool codes into an int32
# accumulator, and only the [Q_t, T] score tile of the epilogue (and the
# [Q, K'] accumulator) is ever in float32.  HBM payload traffic is 1 byte
# per dimension plus one f32 scale per vector.
#
# Pool rows are quantized as *residuals* against their coarse centroid
# (Faiss IVF-SQ ``by_residual`` semantics, same as the PQ payload): the
# residual dynamic range is a fraction of the raw vectors', so the 8-bit
# step — and the recall cost — shrinks with it.  Queries arrive as
# per-(query, probe) quantized residuals and each candidate block selects
# its probe slot through the same [Q, C] probe-slot index the PQ kernel
# uses (built in the union prologue).
#
# The int8 family sorts with num_keys=2 (distance, then location):
# quantization produces exact distance ties whenever two vectors share
# codes + scale, so
# a deterministic id tiebreak keeps the returned ids identical across
# kernel / scan / oracle (the integer dot is exact everywhere; the f32
# epilogue may differ by ulps from XLA fusion, hence ids — not raw float
# bits — are the cross-impl contract).
# ---------------------------------------------------------------------------


def quantize_queries(x: jax.Array):
    """Symmetric per-row int8 quantization for the int8 scan's query side.

    x [..., D] f32 -> (codes [..., D] i8, meta [..., 2] f32) where
    meta[..., 0] is the scale s and meta[..., 1] the reconstructed norm
    ``s^2 * sum(codes^2)`` — so the kernel's scores are exactly
    ``||s_q c_q - s_v c_v||^2`` between the two reconstructions.  For the
    residual scheme, x is the [Q, NP, D] batch of query residuals against
    every probed centroid."""
    from repro.core.block_pool import quantize_int8

    # same quantizer as the insert path — query codes and pool codes must
    # share range/rounding for the exact-reconstruction-distance contract
    codes, scale = quantize_int8(x)
    ci = codes.astype(jnp.int32)
    qn = (scale * scale) * jnp.sum(ci * ci, axis=-1).astype(jnp.float32)
    return codes, jnp.stack([scale, qn], axis=-1)


def _int8_scores(qn_b, vterm_b, coef_b, dotf):
    """Shared epilogue expression — identical op order across kernel /
    lax.scan fallback / oracle so int8 results stay bit-identical (the
    integer dot itself is exact in every impl)."""
    return qn_b + vterm_b - 2.0 * (coef_b * dotf)


def _topk_int8_kernel(
    ids_ref,  # [C] i32 scalar prefetch (clamped block ids)
    own_ref,  # [C] i32 scalar prefetch (owning cluster, -1 = NULL slot)
    qc_ref,  # [Q_t, NP, D] i8 per-probe quantized query residuals
    qmeta_ref,  # [Q_t, NP, 2] f32 (scale, reconstructed norm) per probe
    probe_ref,  # [Q_t, NP] i32 probed cluster ids of the query tile
    pool_ref,  # [T, D] i8 current candidate code block
    scale_ref,  # [1, T] f32 per-vector dequant scales of the block
    pid_ref,  # [1, T] i32 vector ids of the block
    live_ref,  # [1, T] u8 live mask of the block (0 = empty or tombstoned)
    out_d_ref,  # [Q_t, K']
    out_i_ref,  # [Q_t, K'] i32
    acc_d_ref,  # VMEM scratch [Q_t, K']
    acc_i_ref,  # VMEM scratch [Q_t, K'] i32
):
    """Grid (qi, ci): int8-score block ids[ci], merge into the accumulator."""
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc_d_ref[:] = jnp.full(acc_d_ref.shape, jnp.inf, jnp.float32)
        acc_i_ref[:] = jnp.full(acc_i_ref.shape, -1, jnp.int32)

    qc = qc_ref[:]  # [Q_t, NP, D] i8
    qmeta = qmeta_ref[:]  # [Q_t, NP, 2]
    qt, np_, _ = qc.shape
    # In-kernel membership + probe-slot derivation: residuals are
    # per-probe, and the probe slot of this candidate is the position of
    # its prefetched owner in the query's probe list (distinct ids — at
    # most one match).  The match one-hot selects the quantized residual
    # via an exact int32 reduction; no match (owner -1 / non-member) means
    # the row is masked below.  The [Q, C] pslot operand no longer exists.
    onehot = (probe_ref[:] == own_ref[ci]).astype(jnp.int32)  # [Q_t, NP]
    member = jnp.sum(onehot, axis=1, keepdims=True) > 0  # [Q_t, 1]
    qsel = jnp.sum(
        onehot[:, :, None] * qc.astype(jnp.int32), axis=1
    ).astype(jnp.int8)  # [Q_t, D]
    onef = onehot.astype(jnp.float32)
    sq = jnp.sum(onef * qmeta[:, :, 0], axis=1, keepdims=True)  # [Q_t, 1]
    qn = jnp.sum(onef * qmeta[:, :, 1], axis=1, keepdims=True)  # [Q_t, 1]
    codes = pool_ref[:]  # [T, D] i8 — never dequantized
    sv = scale_ref[:]  # [1, T] f32
    # integer MXU contraction: i8 x i8 -> i32, exact
    dots = jax.lax.dot_general(
        qsel, codes, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # [Q_t, T]
    ci32 = codes.astype(jnp.int32)
    cn = jnp.sum(ci32 * ci32, axis=-1)[None, :].astype(jnp.float32)  # [1, T]
    vterm = (sv * sv) * cn  # [1, T]
    coef = sq * sv  # [Q_t, T]
    scores = _int8_scores(qn, vterm, coef, dots.astype(jnp.float32))
    ok = member & (pid_ref[:] != -1) & (live_ref[:] != 0)
    scores = jnp.where(ok, scores, jnp.inf)
    t = scores.shape[1]
    loc_row = ids_ref[ci] * t + jax.lax.broadcasted_iota(
        jnp.int32, (1, t), 1
    )  # packed pool locations (see _topk_kernel)
    cand_i = jnp.where(ok, jnp.broadcast_to(loc_row, scores.shape), -1)
    cat_d = jnp.concatenate([acc_d_ref[:], scores], axis=1)
    cat_i = jnp.concatenate([acc_i_ref[:], cand_i], axis=1)
    srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=2)
    kp = acc_d_ref.shape[1]
    acc_d_ref[:] = srt_d[:, :kp]
    acc_i_ref[:] = srt_i[:, :kp]

    @pl.when(ci == nc - 1)
    def _emit():
        out_d_ref[:] = acc_d_ref[:]
        out_i_ref[:] = acc_i_ref[:]


@functools.partial(
    jax.jit, static_argnames=("kprime", "q_tile", "interpret")
)
def ivf_block_topk_int8(
    q_codes: jax.Array,  # [Q, NP, D] i8 per-probe quantized query residuals
    q_meta: jax.Array,  # [Q, NP, 2] f32 (scale, reconstructed norm)
    pool: jax.Array,  # [P, T, D] i8 residual codes
    pool_scales: jax.Array,  # [P, T] f32 per-vector dequant scales
    block_ids: jax.Array,  # [C] i32 (-1 holes; masked via block_owners)
    block_owners: jax.Array,  # [C] i32 owning cluster (-1 = NULL slot)
    pool_ids: jax.Array,  # [P, T] i32 vector ids (-1 = empty slot)
    pool_live: jax.Array,  # [P, T] u8 live mask (0 = empty/tombstoned)
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters per query
    *,
    kprime: int,
    q_tile: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] dist asc, [Q, K'] locations)
    """Streaming top-``kprime`` over an int8 residual-quantized pool: one
    HBM read of each ``[T, D]`` int8 block + its ``[T]`` scale row, integer
    MXU scoring against the per-probe query residual codes, ``[Q, K']``
    writeback.  Membership and the probe slot selecting each query's
    residual codes are derived on-chip from the prefetched block owner and
    the VMEM probe list.  Rows come back sorted ascending by (distance,
    location); invalid slots carry ``inf`` / id ``-1``."""
    q, np_, d = q_codes.shape
    p, t, d2 = pool.shape
    assert d == d2, (d, d2)
    assert pool.dtype == jnp.int8, pool.dtype
    assert probe_idx.shape == (q, np_), (probe_idx.shape, (q, np_))
    c = block_ids.shape[0]
    qt = min(q_tile, _round_up(q, 8))
    qp = _round_up(q, qt)
    q_codes = jnp.pad(q_codes, ((0, qp - q), (0, 0), (0, 0)))
    q_meta = jnp.pad(q_meta, ((0, qp - q), (0, 0), (0, 0)))
    probe_idx = jnp.pad(
        probe_idx.astype(jnp.int32), ((0, qp - q), (0, 0)),
        constant_values=-2,  # padding rows match nothing (owners may be -1)
    )
    safe_ids = jnp.maximum(block_ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(qp // qt, c),
        in_specs=[
            pl.BlockSpec((qt, np_, d), lambda qi, ci, ids, own: (qi, 0, 0)),
            pl.BlockSpec((qt, np_, 2), lambda qi, ci, ids, own: (qi, 0, 0)),
            pl.BlockSpec((qt, np_), lambda qi, ci, ids, own: (qi, 0)),
            pl.BlockSpec(
                (None, t, d), lambda qi, ci, ids, own: (ids[ci], 0, 0)
            ),
            pl.BlockSpec((1, t), lambda qi, ci, ids, own: (ids[ci], 0)),
            pl.BlockSpec((1, t), lambda qi, ci, ids, own: (ids[ci], 0)),
            pl.BlockSpec((1, t), lambda qi, ci, ids, own: (ids[ci], 0)),
        ],
        out_specs=[
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids, own: (qi, 0)),
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids, own: (qi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((qt, kprime), jnp.float32),
            pltpu.VMEM((qt, kprime), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        _topk_int8_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qp, kprime), jnp.float32),
            jax.ShapeDtypeStruct((qp, kprime), jnp.int32),
        ],
        interpret=interpret,
    )(safe_ids, block_owners.astype(jnp.int32), q_codes, q_meta, probe_idx,
      pool, pool_scales, pool_ids, pool_live.astype(jnp.uint8))
    return out_d[:q], out_i[:q]


@functools.partial(jax.jit, static_argnames=("kprime", "chunk"))
def ivf_block_topk_int8_scan(
    q_codes: jax.Array,  # [Q, NP, D] i8
    q_meta: jax.Array,  # [Q, NP, 2] f32
    pool: jax.Array,  # [P, T, D] i8
    pool_scales: jax.Array,  # [P, T] f32
    block_ids: jax.Array,  # [C] i32
    block_owners: jax.Array,  # [C] i32 owning cluster (-1 = NULL slot)
    pool_ids: jax.Array,  # [P, T] i32
    pool_live: jax.Array,  # [P, T] u8 live mask (0 = empty/tombstoned)
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters per query
    *,
    kprime: int,
    chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Chunked ``lax.scan`` fallback for the int8 fused path: same streaming
    top-``kprime`` semantics and identical returned ids, peak intermediate
    ``[Q, chunk*T]`` instead of ``[C, Q, T]`` — the probe slot of each
    candidate is derived per chunk from its owner, never materialized as a
    dense [Q, C] operand."""
    q = q_codes.shape[0]
    c = block_ids.shape[0]
    cp = _round_up(c, chunk)
    nch = cp // chunk
    ids_p = jnp.pad(block_ids, (0, cp - c), constant_values=-1)
    own_p = jnp.pad(
        block_owners.astype(jnp.int32), (0, cp - c), constant_values=-1
    )
    safe = jnp.maximum(ids_p, 0).reshape(nch, chunk)
    own_ch = own_p.reshape(nch, chunk)
    probe = probe_idx.astype(jnp.int32)
    qci = q_codes.astype(jnp.int32)

    def step(carry, xs):
        acc_d, acc_i = carry
        sc, own = xs  # [chunk], [chunk]
        match = probe[:, :, None] == own[None, None, :]  # [Q, NP, chunk]
        ps = jnp.where(
            match.any(axis=1), jnp.argmax(match, axis=1).astype(jnp.int32),
            -1,
        )  # [Q, chunk] probe slot, -1 = non-member / NULL slot
        codes = pool[sc]  # [chunk, T, D] i8
        svs = pool_scales[sc]  # [chunk, T]
        vids = pool_ids[sc]  # [chunk, T]
        lives = pool_live[sc] != 0  # [chunk, T]
        sel = jnp.clip(ps, 0)  # [Q, chunk]
        qsel = jnp.take_along_axis(
            qci, sel[:, :, None], axis=1
        )  # [Q, chunk, D] i32
        meta = jnp.take_along_axis(
            q_meta, sel[:, :, None], axis=1
        )  # [Q, chunk, 2]
        sq, qn = meta[..., 0], meta[..., 1]  # [Q, chunk]
        ci32 = codes.astype(jnp.int32)
        cn = jnp.sum(ci32 * ci32, axis=-1).astype(jnp.float32)  # [chunk, T]
        dots = jnp.einsum("qcd,ctd->qct", qsel, ci32)  # exact int32
        vterm = (svs * svs) * cn  # [chunk, T]
        coef = sq[:, :, None] * svs[None]  # [Q, chunk, T]
        scores = _int8_scores(
            qn[:, :, None], vterm[None], coef, dots.astype(jnp.float32)
        )
        t_ = vids.shape[1]
        locs = sc[:, None] * t_ + jnp.arange(t_, dtype=jnp.int32)[None, :]
        okf = (ps != -1)[:, :, None] & ((vids != -1) & lives)[None, :, :]
        scores = jnp.where(okf, scores, jnp.inf).reshape(q, -1)
        cids = jnp.where(okf, jnp.broadcast_to(locs, okf.shape), -1)
        cat_d = jnp.concatenate([acc_d, scores], axis=1)
        cat_i = jnp.concatenate([acc_i, cids.reshape(q, -1)], axis=1)
        srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=2)
        return (srt_d[:, :kprime], srt_i[:, :kprime]), None

    init = (
        jnp.full((q, kprime), jnp.inf, jnp.float32),
        jnp.full((q, kprime), -1, jnp.int32),
    )
    (acc_d, acc_i), _ = jax.lax.scan(step, init, (safe, own_ch))
    return acc_d, acc_i


# ---------------------------------------------------------------------------
# Exact re-rank epilogue: the K' fused survivors are gathered (one XLA gather
# — a data-dependent gather belongs in the gather HLO, not a grid of tiny
# DMAs), then one grid step per query tile fuses dequantization, exact fp32
# distance, and the final (distance, id) sort.  This is what lets the low-
# precision first pass run with aggressive K' without recall loss.
# ---------------------------------------------------------------------------


def _rerank_kernel(
    q_ref,  # [Q_t, D] f32 exact queries
    rows_ref,  # [Q_t, K', D] survivor rows (payload dtype)
    scale_ref,  # [Q_t, K'] f32 dequant scales (ones for f32/bf16)
    loc_ref,  # [Q_t, K'] i32 packed candidate ids (-1 = invalid)
    out_d_ref,  # [Q_t, K'] exact distances, ascending
    out_i_ref,  # [Q_t, K'] i32 co-sorted candidate ids
):
    """Grid (qi,): dequantize + exact fp32 distance + re-sort, fused."""
    q = q_ref[:]  # [Q_t, D]
    v = rows_ref[:].astype(jnp.float32) * scale_ref[:][..., None]
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [Q_t, 1]
    vn = jnp.sum(v * v, axis=-1)  # [Q_t, K']
    dots = jax.lax.dot_general(
        q[:, None, :], v, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[:, 0, :]  # [Q_t, K']
    d = qn + vn - 2.0 * dots
    ok = loc_ref[:] != -1
    d = jnp.where(ok, d, jnp.inf)
    loc = jnp.where(ok, loc_ref[:], -1)
    srt_d, srt_i = jax.lax.sort((d, loc), dimension=1, num_keys=2)
    out_d_ref[:] = srt_d
    out_i_ref[:] = srt_i


@functools.partial(jax.jit, static_argnames=("q_tile", "interpret"))
def rerank_topk(
    queries: jax.Array,  # [Q, D] f32
    rows: jax.Array,  # [Q, K', D] gathered survivor rows (f32|bf16|i8)
    scales: jax.Array,  # [Q, K'] f32 dequant scales (ones for f32/bf16)
    loc: jax.Array,  # [Q, K'] i32 packed candidate ids, -1 = invalid
    *,
    q_tile: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] exact dist asc, [Q, K'] locs)
    """Fused exact re-rank of the fused-scan survivors (see module notes)."""
    q, kp, d = rows.shape
    qt = min(q_tile, _round_up(q, 8))
    qp = _round_up(q, qt)
    queries = jnp.pad(queries, ((0, qp - q), (0, 0)))
    rows = jnp.pad(rows, ((0, qp - q), (0, 0), (0, 0)))
    scales = jnp.pad(scales, ((0, qp - q), (0, 0)))
    loc = jnp.pad(loc, ((0, qp - q), (0, 0)), constant_values=-1)
    out_d, out_i = pl.pallas_call(
        _rerank_kernel,
        grid=(qp // qt,),
        in_specs=[
            pl.BlockSpec((qt, d), lambda qi: (qi, 0)),
            pl.BlockSpec((qt, kp, d), lambda qi: (qi, 0, 0)),
            pl.BlockSpec((qt, kp), lambda qi: (qi, 0)),
            pl.BlockSpec((qt, kp), lambda qi: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((qt, kp), lambda qi: (qi, 0)),
            pl.BlockSpec((qt, kp), lambda qi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, kp), jnp.float32),
            jax.ShapeDtypeStruct((qp, kp), jnp.int32),
        ],
        interpret=interpret,
    )(queries, rows, scales, loc)
    return out_d[:q], out_i[:q]


# ---------------------------------------------------------------------------
# PQ-ADC fused streaming top-k (IVFPQ payload): LUT resident in VMEM,
# one [T, M] uint8 code block DMA'd per grid step, [Q, K'] writeback.
#
# The PQ family sorts with num_keys=2 (distance, then pool location): quantized
# payloads produce exact distance ties whenever two vectors share a code, so
# a deterministic id tiebreak is required for the kernel / scan / oracle to
# stay bit-identical.
# ---------------------------------------------------------------------------


def _pq_topk_kernel(
    ids_ref,  # [C] i32 scalar prefetch (clamped block ids)
    own_ref,  # [C] i32 scalar prefetch (owning cluster, -1 = NULL slot)
    lut_ref,  # [Q_t, NP, M, K] per-(query, probe) ADC tables
    probe_ref,  # [Q_t, NP] i32 probed cluster ids of the query tile
    codes_ref,  # [T, M] uint8 current candidate code block
    pid_ref,  # [1, T] i32 vector ids of the block
    live_ref,  # [1, T] u8 live mask of the block (0 = empty or tombstoned)
    out_d_ref,  # [Q_t, K']
    out_i_ref,  # [Q_t, K'] i32
    acc_d_ref,  # VMEM scratch [Q_t, K'] running best distances
    acc_i_ref,  # VMEM scratch [Q_t, K'] i32 running best ids
):
    """Grid (qi, ci): ADC-score block ids[ci] and merge into the accumulator."""
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc_d_ref[:] = jnp.full(acc_d_ref.shape, jnp.inf, jnp.float32)
        acc_i_ref[:] = jnp.full(acc_i_ref.shape, -1, jnp.int32)

    lut = lut_ref[:]  # [Q_t, NP, M, K]
    codes = codes_ref[:].astype(jnp.int32)  # [T, M]
    qt, np_, m, ksub = lut.shape
    t = codes.shape[0]
    # In-kernel membership + LUT selection: residuals are per-probe, and
    # the candidate's LUT row is the probe slot where its prefetched owner
    # sits in the query's probe list (distinct ids — at most one match; no
    # match selects a zeroed LUT and is masked below).  The [Q, C] pslot
    # operand no longer exists.
    sel = (probe_ref[:] == own_ref[ci]).astype(jnp.float32)  # [Q_t, NP]
    member = jnp.sum(sel, axis=1, keepdims=True) > 0.0  # [Q_t, 1]
    lut_q = jax.lax.dot_general(
        sel[:, None, :],  # [Q_t, 1, NP]
        lut.reshape(qt, np_, m * ksub),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).reshape(qt, m, ksub)
    # ADC accumulation as dense MXU work: one-hot-expand each code column and
    # contract with the selected LUT row (same trick as pq_adc._adc_kernel).
    kiota = jax.lax.broadcasted_iota(jnp.int32, (t, ksub), 1)
    scores = jnp.zeros((qt, t), jnp.float32)
    for j in range(m):  # static unroll over subquantizers
        onehot = (codes[:, j][:, None] == kiota).astype(jnp.float32)  # [T, K]
        scores = scores + jax.lax.dot_general(
            lut_q[:, j, :],
            onehot,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [Q_t, T]
    # fused epilogue: non-member queries, hole blocks, empty NULL-id slots,
    # tombstoned rows
    ok = member & (pid_ref[:] != -1) & (live_ref[:] != 0)
    scores = jnp.where(ok, scores, jnp.inf)
    loc_row = ids_ref[ci] * t + jax.lax.broadcasted_iota(
        jnp.int32, (1, t), 1
    )  # packed pool locations (see _topk_kernel)
    cand_i = jnp.where(ok, jnp.broadcast_to(loc_row, scores.shape), -1)
    cat_d = jnp.concatenate([acc_d_ref[:], scores], axis=1)
    cat_i = jnp.concatenate([acc_i_ref[:], cand_i], axis=1)
    srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=2)
    kp = acc_d_ref.shape[1]
    acc_d_ref[:] = srt_d[:, :kp]
    acc_i_ref[:] = srt_i[:, :kp]

    @pl.when(ci == nc - 1)
    def _emit():
        out_d_ref[:] = acc_d_ref[:]
        out_i_ref[:] = acc_i_ref[:]


@functools.partial(
    jax.jit, static_argnames=("kprime", "q_tile", "interpret")
)
def ivf_pq_block_topk(
    lut: jax.Array,  # [Q, NP, M, K] f32 per-(query, probe) ADC tables
    pool_codes: jax.Array,  # [P, T, M] uint8 PQ codes
    block_ids: jax.Array,  # [C] i32 (-1 holes; masked via block_owners)
    block_owners: jax.Array,  # [C] i32 owning cluster (-1 = NULL slot)
    pool_ids: jax.Array,  # [P, T] i32 vector ids (-1 = empty slot)
    pool_live: jax.Array,  # [P, T] u8 live mask (0 = empty/tombstoned)
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters per query
    *,
    kprime: int,
    q_tile: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] dist asc, [Q, K'] locations)
    """Streaming top-``kprime`` over a PQ-coded pool: one HBM read of each
    ``[T, M]`` uint8 candidate block, ADC against the VMEM-resident LUT
    tile selected on-chip by the candidate's prefetched owner, ``[Q, K']``
    writeback.  Rows come back sorted ascending by (distance,
    location); invalid slots carry ``inf`` / ``-1``.

    The LUT tile is the dominant VMEM resident (``q_tile·nprobe·M·256·4B``,
    see docs/search_paths.md), hence the small default ``q_tile`` of 8."""
    q, np_, m, ksub = lut.shape
    p, t, m2 = pool_codes.shape
    assert m == m2, (lut.shape, pool_codes.shape)
    assert probe_idx.shape == (q, np_), (probe_idx.shape, (q, np_))
    c = block_ids.shape[0]
    qt = min(q_tile, _round_up(q, 8))
    qp = _round_up(q, qt)
    lut = jnp.pad(lut, ((0, qp - q), (0, 0), (0, 0), (0, 0)))
    probe_idx = jnp.pad(
        probe_idx.astype(jnp.int32), ((0, qp - q), (0, 0)),
        constant_values=-2,  # padding rows match nothing (owners may be -1)
    )
    safe_ids = jnp.maximum(block_ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(qp // qt, c),
        in_specs=[
            pl.BlockSpec(
                (qt, np_, m, ksub), lambda qi, ci, ids, own: (qi, 0, 0, 0)
            ),
            pl.BlockSpec((qt, np_), lambda qi, ci, ids, own: (qi, 0)),
            pl.BlockSpec(
                (None, t, m), lambda qi, ci, ids, own: (ids[ci], 0, 0)
            ),
            pl.BlockSpec((1, t), lambda qi, ci, ids, own: (ids[ci], 0)),
            pl.BlockSpec((1, t), lambda qi, ci, ids, own: (ids[ci], 0)),
        ],
        out_specs=[
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids, own: (qi, 0)),
            pl.BlockSpec((qt, kprime), lambda qi, ci, ids, own: (qi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((qt, kprime), jnp.float32),
            pltpu.VMEM((qt, kprime), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        _pq_topk_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qp, kprime), jnp.float32),
            jax.ShapeDtypeStruct((qp, kprime), jnp.int32),
        ],
        interpret=interpret,
    )(safe_ids, block_owners.astype(jnp.int32), lut, probe_idx,
      pool_codes, pool_ids, pool_live.astype(jnp.uint8))
    return out_d[:q], out_i[:q]


@functools.partial(jax.jit, static_argnames=("kprime", "chunk"))
def ivf_pq_block_topk_scan(
    lut: jax.Array,  # [Q, NP, M, K] f32
    pool_codes: jax.Array,  # [P, T, M] uint8
    block_ids: jax.Array,  # [C] i32
    block_owners: jax.Array,  # [C] i32 owning cluster (-1 = NULL slot)
    pool_ids: jax.Array,  # [P, T] i32
    pool_live: jax.Array,  # [P, T] u8 live mask (0 = empty/tombstoned)
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters per query
    *,
    kprime: int,
    chunk: int = 16,
) -> tuple[jax.Array, jax.Array]:
    """Chunked ``lax.scan`` fallback for the PQ fused path (CPU / interpret
    mode): same streaming top-``kprime`` semantics, peak intermediate
    ``[Q, chunk, T, M]`` gathered LUT terms instead of ``[C, Q, T]`` — the
    probe slot of each candidate is derived per chunk from its owner."""
    q = lut.shape[0]
    p, t, m = pool_codes.shape
    c = block_ids.shape[0]
    cp = _round_up(c, chunk)
    nch = cp // chunk
    ids_p = jnp.pad(block_ids, (0, cp - c), constant_values=-1)
    own_p = jnp.pad(
        block_owners.astype(jnp.int32), (0, cp - c), constant_values=-1
    )
    safe = jnp.maximum(ids_p, 0).reshape(nch, chunk)
    own_ch = own_p.reshape(nch, chunk)
    probe = probe_idx.astype(jnp.int32)

    def step(carry, xs):
        acc_d, acc_i = carry
        sc, own = xs  # [chunk], [chunk]
        match = probe[:, :, None] == own[None, None, :]  # [Q, NP, chunk]
        ps = jnp.where(
            match.any(axis=1), jnp.argmax(match, axis=1).astype(jnp.int32),
            -1,
        )  # [Q, chunk] probe slot, -1 = non-member / NULL slot
        codes = pool_codes[sc].astype(jnp.int32)  # [chunk, T, M]
        vids = pool_ids[sc]  # [chunk, T]
        lives = pool_live[sc] != 0  # [chunk, T]
        lq = jnp.take_along_axis(
            lut, jnp.clip(ps, 0)[:, :, None, None], axis=1
        )  # [Q, chunk, M, K]
        gathered = jnp.take_along_axis(
            lq[:, :, None, :, :],  # [Q, chunk, 1, M, K]
            codes[None, :, :, :, None],  # [1, chunk, T, M, 1]
            axis=-1,
        )[..., 0]  # [Q, chunk, T, M]
        scores = jnp.sum(gathered, axis=-1)  # [Q, chunk, T]
        locs = sc[:, None] * t + jnp.arange(t, dtype=jnp.int32)[None, :]
        okf = (ps != -1)[:, :, None] & ((vids != -1) & lives)[None, :, :]
        scores = jnp.where(okf, scores, jnp.inf).reshape(q, -1)
        cids = jnp.where(okf, jnp.broadcast_to(locs, okf.shape), -1)
        cat_d = jnp.concatenate([acc_d, scores], axis=1)
        cat_i = jnp.concatenate([acc_i, cids.reshape(q, -1)], axis=1)
        srt_d, srt_i = jax.lax.sort((cat_d, cat_i), dimension=1, num_keys=2)
        return (srt_d[:, :kprime], srt_i[:, :kprime]), None

    init = (
        jnp.full((q, kprime), jnp.inf, jnp.float32),
        jnp.full((q, kprime), -1, jnp.int32),
    )
    (acc_d, acc_i), _ = jax.lax.scan(step, init, (safe, own_ch))
    return acc_d, acc_i
