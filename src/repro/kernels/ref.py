"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth; kernel tests sweep shapes and
dtypes and ``assert_allclose`` against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def coarse_topk_ref(
    queries: jax.Array,  # [Q, D] f32
    centroids: jax.Array,  # [N, D] f32
    *,
    nprobe: int,
) -> tuple[jax.Array, jax.Array]:  # ([Q, NP] i32 ids, [Q, NP] dists asc)
    """Oracle for the streaming coarse probe: materialize the full [Q, N]
    distance matrix and ``top_k`` it — literally ``coarse_probe``'s
    formulation (ties prefer the lower centroid id, which is the
    contract the streaming kernels' (distance, id) sort reproduces)."""
    qn = jnp.sum(queries * queries, axis=-1, keepdims=True)
    cn = jnp.sum(centroids * centroids, axis=-1)
    d = qn + cn[None, :] - 2.0 * (queries @ centroids.T)
    neg_d, idx = jax.lax.top_k(-d, nprobe)
    return idx.astype(jnp.int32), -neg_d


def _pslot_from_owners(
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters
    block_owners: jax.Array,  # [C] i32 owning cluster, -1 = NULL slot
) -> jax.Array:  # [Q, C] probe slot of each candidate, -1 = non-member
    """Reference expansion of the routing the kernels derive on-chip: the
    probe slot of a candidate is the position of its owner in the query's
    probe list (distinct ids — at most one match)."""
    match = (
        probe_idx.astype(jnp.int32)[:, :, None]
        == block_owners.astype(jnp.int32)[None, None, :]
    )  # [Q, NP, C]
    return jnp.where(
        match.any(axis=1), jnp.argmax(match, axis=1).astype(jnp.int32), -1
    )


def ivf_block_scan_ref(
    queries: jax.Array,  # [Q, D] f32
    pool: jax.Array,  # [P, T, D] f32 | bf16
    block_ids: jax.Array,  # [C] i32, -1 = hole (scores still computed vs block 0)
) -> jax.Array:  # [C, Q, T] squared L2
    safe = jnp.maximum(block_ids, 0)
    blocks = pool[safe]  # [C, T, D]
    qn = jnp.sum(queries * queries, axis=-1)  # [Q]
    bf = blocks.astype(jnp.float32)
    vn = jnp.sum(bf * bf, axis=-1)  # [C, T]
    # bf16 payloads: same formulation as the kernel (bf16 operands, f32
    # accumulation); a no-op for f32
    dots = jnp.einsum(
        "qd,ctd->cqt", queries.astype(pool.dtype), blocks,
        preferred_element_type=jnp.float32,
    )
    return qn[None, :, None] + vn[:, None, :] - 2.0 * dots


def ivf_block_topk_ref(
    queries: jax.Array,  # [Q, D]
    pool: jax.Array,  # [P, T, D]
    block_ids: jax.Array,  # [C] i32, -1 = hole
    block_owners: jax.Array,  # [C] i32 owning cluster, -1 = NULL slot
    pool_ids: jax.Array,  # [P, T] i32 vector ids, -1 = empty slot
    pool_live: jax.Array,  # [P, T] u8 live mask, 0 = empty/tombstoned
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters per query
    *,
    kprime: int,
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] dist asc, [Q, K'] locations)
    """Oracle for the fused streaming top-k scan: materialize everything,
    derive membership from the candidate owners, mask (holes, empty slots,
    tombstones), and sort — the id channel carries packed pool locations
    (``block*T + offset``); invalid slots come back as (inf, -1)."""
    scores = ivf_block_scan_ref(queries, pool, block_ids)  # [C, Q, T]
    safe = jnp.maximum(block_ids, 0)
    t = pool_ids.shape[1]
    vids = pool_ids[safe]  # [C, T]
    lives = pool_live[safe] != 0  # [C, T]
    locs = safe[:, None] * t + jnp.arange(t, dtype=jnp.int32)[None, :]
    cand_ok = _pslot_from_owners(probe_idx, block_owners) != -1  # [Q, C]
    ok = cand_ok[:, :, None] & ((vids != -1) & lives)[None, :, :]
    q = queries.shape[0]
    flat_d = jnp.where(ok, jnp.transpose(scores, (1, 0, 2)), jnp.inf)
    flat_d = flat_d.reshape(q, -1)
    flat_i = jnp.where(ok, jnp.broadcast_to(locs[None], ok.shape), -1)
    flat_i = flat_i.reshape(q, -1)
    n = flat_d.shape[1]
    if n < kprime:
        pad = kprime - n
        flat_d = jnp.pad(flat_d, ((0, 0), (0, pad)), constant_values=jnp.inf)
        flat_i = jnp.pad(flat_i, ((0, 0), (0, pad)), constant_values=-1)
    srt_d, srt_i = jax.lax.sort((flat_d, flat_i), dimension=1, num_keys=1)
    return srt_d[:, :kprime], srt_i[:, :kprime]


def ivf_block_topk_int8_ref(
    q_codes: jax.Array,  # [Q, NP, D] i8 per-probe quantized query residuals
    q_meta: jax.Array,  # [Q, NP, 2] f32 (scale, reconstructed norm)
    pool: jax.Array,  # [P, T, D] i8 residual codes
    pool_scales: jax.Array,  # [P, T] f32 per-vector dequant scales
    block_ids: jax.Array,  # [C] i32, -1 = hole
    block_owners: jax.Array,  # [C] i32 owning cluster, -1 = NULL slot
    pool_ids: jax.Array,  # [P, T] i32 vector ids, -1 = empty slot
    pool_live: jax.Array,  # [P, T] u8 live mask, 0 = empty/tombstoned
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters per query
    *,
    kprime: int,
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] dist asc, [Q, K'] locations)
    """Oracle for the int8 fused streaming top-k: derive each candidate's
    probe slot from its owner, materialize every score
    with the kernel's exact integer-dot formulation, mask, and sort by
    (distance, location) — the location tiebreak keeps quantization-induced
    exact ties deterministic across kernel / scan / oracle."""
    from repro.kernels.ivf_scan import _int8_scores

    q = q_codes.shape[0]
    pslot = _pslot_from_owners(probe_idx, block_owners)  # [Q, C]
    safe = jnp.maximum(block_ids, 0)
    codes = pool[safe].astype(jnp.int32)  # [C, T, D]
    svs = pool_scales[safe]  # [C, T]
    vids = pool_ids[safe]  # [C, T]
    lives = pool_live[safe] != 0  # [C, T]
    t = pool_ids.shape[1]
    locs = safe[:, None] * t + jnp.arange(t, dtype=jnp.int32)[None, :]
    sel = jnp.clip(pslot, 0)  # [Q, C]
    qsel = jnp.take_along_axis(
        q_codes.astype(jnp.int32), sel[:, :, None], axis=1
    )  # [Q, C, D]
    meta = jnp.take_along_axis(q_meta, sel[:, :, None], axis=1)  # [Q, C, 2]
    sq, qn = meta[..., 0], meta[..., 1]  # [Q, C]
    cn = jnp.sum(codes * codes, axis=-1).astype(jnp.float32)  # [C, T]
    dots = jnp.einsum("qcd,ctd->qct", qsel, codes)  # exact int32
    vterm = (svs * svs) * cn  # [C, T]
    coef = sq[:, :, None] * svs[None]  # [Q, C, T]
    scores = _int8_scores(
        qn[:, :, None], vterm[None], coef, dots.astype(jnp.float32)
    )
    ok = (pslot != -1)[:, :, None] & ((vids != -1) & lives)[None, :, :]
    flat_d = jnp.where(ok, scores, jnp.inf).reshape(q, -1)
    flat_i = jnp.where(ok, jnp.broadcast_to(locs[None], ok.shape), -1)
    flat_i = flat_i.reshape(q, -1)
    n = flat_d.shape[1]
    if n < kprime:
        pad = kprime - n
        flat_d = jnp.pad(flat_d, ((0, 0), (0, pad)), constant_values=jnp.inf)
        flat_i = jnp.pad(flat_i, ((0, 0), (0, pad)), constant_values=-1)
    srt_d, srt_i = jax.lax.sort((flat_d, flat_i), dimension=1, num_keys=2)
    return srt_d[:, :kprime], srt_i[:, :kprime]


def rerank_topk_ref(
    queries: jax.Array,  # [Q, D] f32
    rows: jax.Array,  # [Q, K', D] survivor rows (f32 | bf16 | i8)
    scales: jax.Array,  # [Q, K'] f32 dequant scales (ones for f32/bf16)
    loc: jax.Array,  # [Q, K'] i32 packed candidate ids, -1 = invalid
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] exact dist asc, [Q, K'] locs)
    """Oracle for the exact re-rank epilogue: dequantize, exact fp32
    distance, (distance, id) sort."""
    v = rows.astype(jnp.float32) * scales[..., None]
    qn = jnp.sum(queries * queries, axis=-1, keepdims=True)  # [Q, 1]
    vn = jnp.sum(v * v, axis=-1)  # [Q, K']
    dots = jnp.einsum(
        "qd,qkd->qk", queries, v, preferred_element_type=jnp.float32
    )
    d = qn + vn - 2.0 * dots
    ok = loc != -1
    d = jnp.where(ok, d, jnp.inf)
    li = jnp.where(ok, loc, -1)
    return jax.lax.sort((d, li), dimension=1, num_keys=2)


def ivf_pq_block_topk_ref(
    lut: jax.Array,  # [Q, NP, M, K] per-(query, probe) ADC tables
    pool_codes: jax.Array,  # [P, T, M] uint8/int PQ codes
    block_ids: jax.Array,  # [C] i32, -1 = hole
    block_owners: jax.Array,  # [C] i32 owning cluster, -1 = NULL slot
    pool_ids: jax.Array,  # [P, T] i32 vector ids, -1 = empty slot
    pool_live: jax.Array,  # [P, T] u8 live mask, 0 = empty/tombstoned
    probe_idx: jax.Array,  # [Q, NP] i32 distinct probed clusters per query
    *,
    kprime: int,
) -> tuple[jax.Array, jax.Array]:  # ([Q, K'] dist asc, [Q, K'] locations)
    """Oracle for the PQ fused streaming top-k: derive each candidate's
    LUT-selecting probe slot from its owner, materialize the full ADC
    score tensor, mask, and sort by (distance, location) — invalid slots
    come back as (inf, -1).  The double sort key makes ties (vectors
    sharing a code) deterministic across kernel / scan / oracle."""
    q = lut.shape[0]
    pslot = _pslot_from_owners(probe_idx, block_owners)  # [Q, C]
    safe = jnp.maximum(block_ids, 0)
    codes = pool_codes[safe].astype(jnp.int32)  # [C, T, M]
    vids = pool_ids[safe]  # [C, T]
    lives = pool_live[safe] != 0  # [C, T]
    t = pool_ids.shape[1]
    locs = safe[:, None] * t + jnp.arange(t, dtype=jnp.int32)[None, :]
    lq = jnp.take_along_axis(
        lut, jnp.clip(pslot, 0)[:, :, None, None], axis=1
    )  # [Q, C, M, K]
    gathered = jnp.take_along_axis(
        lq[:, :, None, :, :],  # [Q, C, 1, M, K]
        codes[None, :, :, :, None],  # [1, C, T, M, 1]
        axis=-1,
    )[..., 0]  # [Q, C, T, M]
    scores = jnp.sum(gathered, axis=-1)  # [Q, C, T]
    ok = (pslot != -1)[:, :, None] & ((vids != -1) & lives)[None, :, :]
    flat_d = jnp.where(ok, scores, jnp.inf).reshape(q, -1)
    flat_i = jnp.where(ok, jnp.broadcast_to(locs[None], ok.shape), -1)
    flat_i = flat_i.reshape(q, -1)
    n = flat_d.shape[1]
    if n < kprime:
        pad = kprime - n
        flat_d = jnp.pad(flat_d, ((0, 0), (0, pad)), constant_values=jnp.inf)
        flat_i = jnp.pad(flat_i, ((0, 0), (0, pad)), constant_values=-1)
    srt_d, srt_i = jax.lax.sort((flat_d, flat_i), dimension=1, num_keys=2)
    return srt_d[:, :kprime], srt_i[:, :kprime]


def pq_adc_ref(
    lut: jax.Array,  # [R, M, K] per-row ADC table
    codes: jax.Array,  # [R, N, M] integer codes in [0, K)
) -> jax.Array:  # [R, N] accumulated distances
    idx = codes.astype(jnp.int32)
    gathered = jnp.take_along_axis(
        lut[:, None, :, :],  # [R, 1, M, K]
        idx[:, :, :, None],  # [R, N, M, 1]
        axis=-1,
    )[..., 0]
    return jnp.sum(gathered, axis=-1)


def paged_decode_attention_ref(
    q: jax.Array,  # [B, H, dh]
    k_pool: jax.Array,  # [P, T, KVH, dh]
    v_pool: jax.Array,  # [P, T, KVH, dh]
    block_tables: jax.Array,  # [B, NB] i32, -1 past end
    lengths: jax.Array,  # [B] i32 tokens resident in cache
    scale: float | None = None,
) -> jax.Array:  # [B, H, dh]
    b, h, dh = q.shape
    p, t, kvh, _ = k_pool.shape
    nb = block_tables.shape[1]
    g = h // kvh  # query heads per kv head (GQA group)
    if scale is None:
        scale = dh**-0.5
    safe = jnp.maximum(block_tables, 0)
    k = k_pool[safe]  # [B, NB, T, KVH, dh]
    v = v_pool[safe]
    k = k.reshape(b, nb * t, kvh, dh)
    v = v.reshape(b, nb * t, kvh, dh)
    qg = q.reshape(b, kvh, g, dh)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32) * scale
    pos = jnp.arange(nb * t)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows (length 0)
    out = jnp.einsum("bkgs,bskd->bkgd", w.astype(v.dtype), v)
    return out.reshape(b, h, dh)
