"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode — the
kernel body runs as traced jnp on the host, which validates semantics
against ``ref.py``.  On TPU the same call sites compile to Mosaic.
"""

from __future__ import annotations

import jax

from repro.kernels.ivf_scan import coarse_topk as _coarse_topk
from repro.kernels.ivf_scan import ivf_block_scan as _ivf_block_scan
from repro.kernels.ivf_scan import ivf_block_topk as _ivf_block_topk
from repro.kernels.ivf_scan import (
    ivf_block_topk_int8 as _ivf_block_topk_int8,
)
from repro.kernels.ivf_scan import ivf_pq_block_topk as _ivf_pq_block_topk
from repro.kernels.ivf_scan import rerank_topk as _rerank_topk
from repro.kernels.paged_attention import (
    paged_decode_attention as _paged_decode_attention,
)
from repro.kernels.pq_adc import pq_adc as _pq_adc


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def coarse_topk(queries, centroids, *, nprobe, q_tile: int = 128,
                c_tile: int = 128):
    """Streaming coarse probe: [Q,D] x [N,D] -> ([Q,NP] ids, [Q,NP] dists)
    without materializing the [Q,N] distance matrix (bit-exact with
    ``coarse_probe``, ties included)."""
    return _coarse_topk(
        queries, centroids, nprobe=nprobe, q_tile=q_tile, c_tile=c_tile,
        interpret=_interpret(),
    )


def ivf_block_scan(queries, pool, block_ids):
    """[Q,D] x [P,T,D] x [C] -> [C,Q,T] squared-L2 scores."""
    return _ivf_block_scan(queries, pool, block_ids, interpret=_interpret())


def ivf_block_topk(queries, pool, block_ids, block_owners, pool_ids,
                   pool_live, probe_idx, *, kprime, q_tile: int = 128):
    """Fused streaming selection: [Q,D] x [P,T,D] x [C] -> ([Q,K'], [Q,K'])
    (ascending dists, vector ids) without materializing [C,Q,T];
    membership is derived in-kernel from each candidate's owner and the
    [Q,NP] probe list, and tombstoned rows are masked via the streamed
    [P,T] live mask."""
    return _ivf_block_topk(
        queries, pool, block_ids, block_owners, pool_ids, pool_live,
        probe_idx, kprime=kprime, q_tile=q_tile, interpret=_interpret(),
    )


def ivf_block_topk_int8(q_codes, q_meta, pool, pool_scales, block_ids,
                        block_owners, pool_ids, pool_live, probe_idx, *,
                        kprime, q_tile: int = 128):
    """int8 fused streaming selection: [Q,NP,D] i8 per-probe query residual
    codes contracted against [P,T,D] i8 residual codes on the integer MXU
    -> ([Q,K'], [Q,K']) without materializing [C,Q,T] or dequantizing any
    block; the probe slot is derived in-kernel from the candidate owner and
    tombstones are masked via the streamed live mask."""
    return _ivf_block_topk_int8(
        q_codes, q_meta, pool, pool_scales, block_ids, block_owners,
        pool_ids, pool_live, probe_idx,
        kprime=kprime, q_tile=q_tile, interpret=_interpret(),
    )


def rerank_topk(queries, rows, scales, loc, *, q_tile: int = 8):
    """Exact re-rank epilogue: [Q,K',D] gathered survivor rows (any flat
    dtype) -> fused dequant + exact fp32 distance + (dist, id) sort."""
    return _rerank_topk(
        queries, rows, scales, loc, q_tile=q_tile, interpret=_interpret(),
    )


def ivf_pq_block_topk(lut, pool_codes, block_ids, block_owners, pool_ids,
                      pool_live, probe_idx, *, kprime, q_tile: int = 8):
    """PQ-ADC fused streaming selection: [Q,NP,M,K] LUTs x [P,T,M] u8 codes
    -> ([Q,K'], [Q,K']) without materializing [C,Q,T]; the LUT-selecting
    probe slot is derived in-kernel from the candidate owner and tombstones
    are masked via the streamed live mask."""
    return _ivf_pq_block_topk(
        lut, pool_codes, block_ids, block_owners, pool_ids, pool_live,
        probe_idx, kprime=kprime, q_tile=q_tile, interpret=_interpret(),
    )


def pq_adc(lut, codes, tile_n: int = 1024):
    """[R,M,K] x [R,N,M] -> [R,N] ADC distances."""
    return _pq_adc(lut, codes, tile_n=tile_n, interpret=_interpret())


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, scale=None):
    """Flash-decoding over a block-pool KV cache (see paged_attention.py)."""
    return _paged_decode_attention(
        q, k_pool, v_pool, block_tables, lengths, scale=scale,
        interpret=_interpret(),
    )
