"""Pallas TPU kernel: PQ asymmetric-distance (ADC) accumulation.

GPU systems keep the per-query LUT in shared memory and gather per code
byte.  TPU has no per-lane gather from VMEM, so the TPU-native form is a
**one-hot MXU contraction**: for each subquantizer m, expand the code column
to a one-hot `[T, K]` tile and contract with the LUT row `[K]` on the MXU.
For K = 256 and M ≤ 64 this stays comfortably inside VMEM and turns a
byte-gather (bad on TPU) into dense matmul work (what the MXU is for).

Layout: one grid step handles one LUT row r (= one (query, probe) pair) and
one tile of N candidate codes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

KSUB = 256


def _adc_kernel(lut_ref, codes_ref, out_ref):
    """lut [M, K], codes [Tn, M] i32 -> out [Tn] f32 (one-hot MXU gather)."""
    codes = codes_ref[:]  # [Tn, M] int32
    tn, m = codes.shape
    ksub = lut_ref.shape[-1]
    lut = lut_ref[:]  # [M, K]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, ksub), 1)
    acc = jnp.zeros((tn,), jnp.float32)
    for j in range(m):  # static unroll over subquantizers
        onehot = (codes[:, j][:, None] == iota).astype(jnp.float32)  # [Tn, K]
        acc = acc + jax.lax.dot_general(
            onehot,
            lut[j][:, None],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[:, 0]
    out_ref[:] = acc


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def pq_adc(
    lut: jax.Array,  # [R, M, K] f32
    codes: jax.Array,  # [R, N, M] integer
    *,
    tile_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:  # [R, N]
    r, m, k = lut.shape
    r2, n, m2 = codes.shape
    assert (r, m) == (r2, m2), (lut.shape, codes.shape)
    codes = codes.astype(jnp.int32)
    tile_n = min(tile_n, n)
    if n % tile_n:
        pad = tile_n - n % tile_n
        codes = jnp.pad(codes, ((0, 0), (0, pad), (0, 0)))
    n_pad = codes.shape[1]

    out = pl.pallas_call(
        _adc_kernel,
        grid=(r, n_pad // tile_n),
        in_specs=[
            pl.BlockSpec((None, m, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, tile_n, m), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, n_pad), jnp.float32),
        interpret=interpret,
    )(lut, codes)
    return out[:, :n]
