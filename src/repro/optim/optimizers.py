"""Optimizers: AdamW (fp32 state), Adafactor (factored state, giant-MoE
default), and 8-bit-blockwise Adam state quantisation.

Pure-pytree implementations (init/update), no optax dependency.  Giant
models (kimi-k2 1T, llama4 400B) default to Adafactor so optimizer state
stays O(rows+cols) per matrix (PaLM/MaxText practice); 8-bit Adam is the
distributed-optimization alternative that keeps Adam semantics at 2 bytes
per parameter of state (block-wise absmax scaling, error kept by re-quant).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | adafactor | adam8bit
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # adafactor
    decay_rate: float = 0.8
    clip_threshold: float = 1.0
    # 8-bit
    block: int = 256


# ------------------------------------------------------------------ adam --


def adamw_init(params):
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": mu, "nu": nu, "step": step}


# ------------------------------------------------------------- adafactor --


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    # state leaves are dicts, so they are kept as a flat list aligned with
    # tree_flatten(params) order (tree.map cannot zip array-leaves with
    # dict-subtrees).
    return {
        "v": [init(p) for p in jax.tree.leaves(params)],
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)
    eps = 1e-30

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(g.shape):
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = (
                vr[..., None]
                * vc[..., None, :]
                / jnp.maximum(vr.mean(axis=-1)[..., None, None], eps)
            )
            u = g * jax.lax.rsqrt(denom + eps)
            nv = {"vr": vr, "vc": vc}
        else:
            nvv = beta2 * v["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(nvv + eps)
            nv = {"v": nvv}
        # update clipping (RMS(u) <= clip_threshold)
        rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        newp = (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype)
        return newp, nv

    pleaves, treedef = jax.tree.flatten(params)
    gleaves = jax.tree.leaves(grads)
    outs = [upd(g, v, p) for g, v, p in zip(gleaves, state["v"], pleaves)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    return new_params, {"v": [o[1] for o in outs], "step": step}


# -------------------------------------------------------------- 8-bit adam --


_NU_TINY = 1e-24  # log-domain floor for the second moment


def _quant_blockwise(x: jax.Array, block: int):
    """Signed linear absmax int8 per block (fine for mu: ~symmetric)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blk), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blk / jnp.maximum(scale, 1e-12)), -127, 127).astype(
        jnp.int8
    )
    return q, scale.astype(jnp.float32)


def _dequant_blockwise(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def _quant_log_blockwise(x: jax.Array, block: int):
    """Log-domain uint8 per block — for nu, whose values span many orders
    of magnitude: linear absmax rounds small nu to 0 and 1/sqrt(nu+eps)
    explodes (measured divergence); log-domain keeps relative error
    <= (hi-lo)/255/2 nats everywhere in the block."""
    flat = jnp.maximum(x.reshape(-1), 0.0)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blk = jnp.log(flat.reshape(-1, block) + _NU_TINY)
    lo = jnp.min(blk, axis=1, keepdims=True)
    hi = jnp.max(blk, axis=1, keepdims=True)
    span = jnp.maximum(hi - lo, 1e-12)
    q = jnp.clip(jnp.round(255.0 * (blk - lo) / span), 0, 255).astype(jnp.uint8)
    return q, lo.astype(jnp.float32), hi.astype(jnp.float32)


def _dequant_log_blockwise(q, lo, hi, shape):
    span = jnp.maximum(hi - lo, 1e-12)
    val = jnp.exp(lo + q.astype(jnp.float32) / 255.0 * span) - _NU_TINY
    flat = jnp.maximum(val, 0.0).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def adam8bit_init(params, block=256):
    def init(p):
        z = jnp.zeros_like(p, jnp.float32)
        mq, ms = _quant_blockwise(z, block)
        nq, lo, hi = _quant_log_blockwise(z, block)
        return {"mu_q": mq, "mu_s": ms, "nu_q": nq, "nu_lo": lo, "nu_hi": hi}

    return {
        "q": [init(p) for p in jax.tree.leaves(params)],
        "step": jnp.zeros((), jnp.int32),
    }


def adam8bit_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(g, q, p):
        g = g.astype(jnp.float32)
        mu = _dequant_blockwise(q["mu_q"], q["mu_s"], g.shape)
        nu = _dequant_log_blockwise(q["nu_q"], q["nu_lo"], q["nu_hi"], g.shape)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        u = (mu / bc1) / (jnp.sqrt(jnp.maximum(nu, 0.0) / bc2) + cfg.eps)
        newp = (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype)
        mq, ms = _quant_blockwise(mu, cfg.block)
        nq, lo, hi = _quant_log_blockwise(nu, cfg.block)
        return newp, {"mu_q": mq, "mu_s": ms, "nu_q": nq, "nu_lo": lo,
                      "nu_hi": hi}

    pleaves, treedef = jax.tree.flatten(params)
    gleaves = jax.tree.leaves(grads)
    outs = [upd(g, q, p) for g, q, p in zip(gleaves, state["q"], pleaves)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    return new_params, {"q": [o[1] for o in outs], "step": step}


# --------------------------------------------------------------- factory --


def make_optimizer(cfg: OptConfig):
    if cfg.kind == "adamw":
        return adamw_init, partial(adamw_update, cfg)
    if cfg.kind == "adafactor":
        return adafactor_init, partial(adafactor_update, cfg)
    if cfg.kind == "adam8bit":
        return partial(adam8bit_init, block=cfg.block), partial(
            adam8bit_update, cfg
        )
    raise ValueError(cfg.kind)


def compress_grads_bf16(grads):
    """Gradient compression for cross-pod all-reduce: bf16 on the wire.

    Halves DCI bytes; combined with fp32 accumulation inside the optimizer
    the loss of precision is one rounding per step (error feedback hooks in
    train.py when enabled).
    """
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
