"""User-facing IVFFlat / IVFPQ indexes over the block pool.

``IVFIndex`` owns the jitted step functions (insert / search / rearrange)
and the functional ``IVFState``.  The offline segment (paper §3.3) is built
by k-means + replaying batched inserts through the *same* insertion path the
online segment uses — there is deliberately no separate bulk loader, so the
offline/online split is purely operational, as deployed in the paper.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq as pqmod
from repro.core.block_pool import IVFState, PoolConfig, init_state, pool_stats
from repro.core.insert import make_insert_fn
from repro.core.kmeans import kmeans
from repro.core.mutate import make_delete_fn, make_update_fn
from repro.core.rearrange import make_rearrange_fn
from repro.core.search import make_search_fn

#: Version stamp of the (field set, field semantics) of :class:`IVFState`
#: as serialized by ``state_to_host``.  Bump it whenever a field is added,
#: removed, re-typed, or its meaning changes — recovery refuses to load a
#: snapshot written under a different schema rather than misinterpreting
#: leaves (see repro.persist.snapshot / recovery).
STATE_SCHEMA_VERSION = 1


class StateSchemaError(RuntimeError):
    """A serialized IVFState does not match this build's schema."""


class StateChecksumError(RuntimeError):
    """A serialized IVFState leaf failed its per-leaf CRC32."""


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def state_to_host(state) -> "tuple[dict[str, np.ndarray], dict]":
    """One D2H transfer of the whole pytree -> ``{field: np.ndarray}`` plus
    a schema + per-leaf-CRC32 meta dict (JSON-serializable).

    bfloat16 leaves are stored as their uint16 bit pattern (npz cannot hold
    ml_dtypes natively); the logical dtype is recorded in the meta and
    restored exactly by ``state_from_host``.
    """
    fields = [f.name for f in dataclasses.fields(type(state))]
    host = jax.device_get(state)
    arrays: dict[str, np.ndarray] = {}
    leaves: dict[str, dict] = {}
    for name in fields:
        arr = np.asarray(getattr(host, name))
        logical = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[name] = arr
        leaves[name] = {
            "crc32": _leaf_crc(arr),
            "dtype": logical,
            "shape": list(arr.shape),
        }
    meta = {
        "schema": STATE_SCHEMA_VERSION,
        "fields": fields,
        "leaves": leaves,
    }
    return arrays, meta


def state_from_host(
    arrays: "dict[str, np.ndarray]", meta: dict, *, verify: bool = True
) -> IVFState:
    """Inverse of ``state_to_host``: schema check, per-leaf CRC32 verify
    (``StateChecksumError`` names the bad leaf), then device upload."""
    if meta.get("schema") != STATE_SCHEMA_VERSION:
        raise StateSchemaError(
            f"snapshot schema {meta.get('schema')!r} != this build's "
            f"{STATE_SCHEMA_VERSION} — refusing to reinterpret leaves"
        )
    fields = [f.name for f in dataclasses.fields(IVFState)]
    if list(meta.get("fields", ())) != fields:
        raise StateSchemaError(
            f"snapshot fields {meta.get('fields')} != {fields}"
        )
    dev: dict[str, jax.Array] = {}
    for name in fields:
        if name not in arrays:
            raise StateSchemaError(f"snapshot is missing leaf {name!r}")
        arr = np.asarray(arrays[name])
        info = meta["leaves"][name]
        if verify and _leaf_crc(arr) != info["crc32"]:
            raise StateChecksumError(
                f"leaf {name!r} failed its CRC32 — snapshot bytes are "
                "corrupt, refusing to serve from it"
            )
        if info["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        dev[name] = jnp.asarray(arr)
    return IVFState(**dev)


@dataclasses.dataclass
class IVFIndexConfig:
    n_clusters: int
    dim: int
    block_size: int = 1024  # paper deployment value T_m
    max_chain: int = 64
    pool_blocks: Optional[int] = None  # default: sized for capacity_vectors
    capacity_vectors: Optional[int] = None
    payload: str = "flat"  # "flat" | "pq"
    pq_m: int = 0
    dtype: str = "float32"  # flat payload dtype: float32 | bfloat16 | int8
    rerank: bool = False  # exact-fp32 re-rank epilogue (fused paths only)
    nprobe: int = 16
    k: int = 10
    rearrange_threshold: int = 10_000  # T'_m (paper Table 1 sweeps this)
    # mutation subsystem: compaction triggers when a cluster's tombstoned
    # fraction reaches this (see core.rearrange); id_capacity sizes the
    # device id -> location map (None = 2x pool slot capacity)
    dead_frac_threshold: float = 0.3
    id_capacity: Optional[int] = None
    # "block_table" | "chain_walk" | "union" | "union_pallas" |
    # "union_fused" | "union_fused_scan" (see core.search / docs/search_paths.md)
    search_path: str = "block_table"
    use_kernel: bool = False  # route scan through Pallas ops
    kmeans_iters: int = 10
    seed: int = 0

    def pool_config(self) -> PoolConfig:
        if self.pool_blocks is not None:
            n_blocks = self.pool_blocks
        else:
            cap = self.capacity_vectors or (self.n_clusters * self.block_size)
            # slack: every cluster may hold a partial tail block, plus 25%
            n_blocks = int(cap // self.block_size + self.n_clusters * 0.5 + 16)
        return PoolConfig(
            n_clusters=self.n_clusters,
            dim=self.dim,
            block_size=self.block_size,
            n_blocks=n_blocks,
            max_chain=self.max_chain,
            payload=self.payload,
            pq_m=self.pq_m,
            dtype=self.dtype,
            max_ids=self.id_capacity or 0,
        )


class IVFIndex:
    """IVFFlat (payload='flat') or IVFPQ (payload='pq') with online insertion."""

    def __init__(self, cfg: IVFIndexConfig):
        self.cfg = cfg
        self.pool_cfg = cfg.pool_config()
        self.pq: Optional[pqmod.PQParams] = None
        self.state: Optional[IVFState] = None
        self._insert_fn = None
        self._search_fns: dict = {}
        self._rearrange_fn = None
        self._next_id = 0

    # ---------------------------------------------------------- build ----
    def train(self, x: np.ndarray) -> None:
        """Train the coarse quantizer (+ PQ codebooks) on offline vectors."""
        cents = kmeans(
            x, self.cfg.n_clusters, n_iter=self.cfg.kmeans_iters, seed=self.cfg.seed
        )
        self.state = init_state(self.pool_cfg, jnp.asarray(cents))
        if self.cfg.payload == "pq":
            # residuals of a sample against their centroid
            xs = np.asarray(x[: min(len(x), 65536)], np.float32)
            assign = np.asarray(
                _assign_blockwise(jnp.asarray(xs), jnp.asarray(cents))
            )
            res = xs - cents[assign]
            self.pq = pqmod.train_pq(res, self.cfg.pq_m, seed=self.cfg.seed)
        self._build_fns()

    def _build_fns(self) -> None:
        """Build the jitted mutation/maintenance steps for the current
        (pool_cfg, pq) pair.  Split out of ``train`` so recovery can adopt
        a restored state without re-running k-means (``install_state``)."""
        encode = pqmod.make_pq_encode_fn(self.pq) if self.pq else None
        self._insert_fn = make_insert_fn(self.pool_cfg, encode=encode)
        self._delete_fn = make_delete_fn(self.pool_cfg)
        self._update_fn = make_update_fn(self.pool_cfg, encode=encode)
        self._rearrange_fn = make_rearrange_fn(
            self.pool_cfg, self.cfg.rearrange_threshold,
            dead_frac=self.cfg.dead_frac_threshold,
        )

    def install_state(self, state: IVFState, *, pq=None,
                      next_id: int = 0) -> None:
        """Adopt a restored ``IVFState`` (recovery entry point): the
        centroids/codebooks travel inside the snapshot, so no training
        data is needed — only the config must match the snapshot schema."""
        expect = self.pool_cfg.payload_shape()
        if tuple(state.pool_payload.shape) != expect:
            raise StateSchemaError(
                f"restored pool payload {tuple(state.pool_payload.shape)} "
                f"!= {expect} from config — wrong IVFIndexConfig for this "
                "snapshot"
            )
        self.pq = pq
        self.state = state
        self._next_id = int(next_id)
        self._search_fns = {}
        self._build_fns()

    def add(self, x: np.ndarray | jax.Array, ids=None) -> np.ndarray:
        """Insert a batch (offline load and online insertion share this)."""
        assert self.state is not None, "train() first"
        x = jnp.asarray(x, jnp.float32)
        b = x.shape[0]
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + b, dtype=np.int32)
            # IVFIndex is a single-writer host object; concurrent submitters
            # allocate ids in ServingRuntime._mutation_args instead, so:
            # counter-ok: single-writer by contract (runtime path holds _state_lock)
            self._next_id += b
        self.state = self._insert_fn(self.state, x, jnp.asarray(ids, jnp.int32))
        return np.asarray(ids)

    # ------------------------------------------------------- mutations ----
    def delete(self, ids) -> int:
        """Tombstone a batch of ids; returns how many were actually found
        (misses — unknown / already-deleted / unmappable ids — accrue in
        ``state.num_missed``).  Dead space is reclaimed by the next
        compaction pass (``maybe_rearrange``)."""
        assert self.state is not None, "train() first"
        before = int(self.state.num_deleted)
        self.state = self._delete_fn(
            self.state, jnp.asarray(ids, jnp.int32)
        )
        return int(self.state.num_deleted) - before

    def update(self, x: np.ndarray | jax.Array, ids) -> np.ndarray:
        """Replace the vectors behind ``ids`` in one dispatch (tombstone +
        re-insert under the same id — no host round trip, no copy of any
        resident row).  Ids not currently resident degrade to plain inserts
        (upsert) and count toward ``num_missed``."""
        assert self.state is not None, "train() first"
        x = jnp.asarray(x, jnp.float32)
        ids = np.asarray(ids, np.int32)
        assert len(ids) == x.shape[0], (len(ids), x.shape)
        self.state = self._update_fn(self.state, x, jnp.asarray(ids))
        return ids

    def stats(self) -> dict:
        """Live-occupancy / reclamation gauges (see block_pool.pool_stats)."""
        return pool_stats(self.state, self.pool_cfg)

    # --------------------------------------------------------- search ----
    def _chain_budget(self) -> int:
        """Adaptive static scan bound (§Perf): the gather paths pay for the
        full ``max_chain`` table width even when live chains are short, so
        the budget tracks ``cluster_nblocks.max()`` bucketed to the next
        power of two — exact results, one recompile per bucket growth."""
        live = max(1, int(self.state.cluster_nblocks.max()))
        b = 1
        while b < live:
            b *= 2
        return min(b, self.cfg.max_chain)

    def _search_fn(self, nprobe: int, k: int, budget: int):
        key = (nprobe, k, self.cfg.search_path, self.cfg.use_kernel, budget,
               self.cfg.rerank)
        if key not in self._search_fns:
            score_fn = None
            if self.cfg.payload == "pq":
                # state-free: centroids come from the traced state argument,
                # so cached search fns never pin a stale pool copy
                score_fn = pqmod.pq_score_fn(
                    self.pq, use_kernel=self.cfg.use_kernel
                )
            self._search_fns[key] = make_search_fn(
                self.pool_cfg,
                nprobe=nprobe,
                k=k,
                path=self.cfg.search_path,
                score_fn=score_fn,
                chain_budget=budget,
                pq=self.pq,
                rerank=self.cfg.rerank,
            )
        return self._search_fns[key]

    def search(self, queries, nprobe=None, k=None):
        """Returns (dists [Q, k], ids [Q, k]); ids are -1 past corpus end."""
        assert self.state is not None
        nprobe = nprobe or self.cfg.nprobe
        k = k or self.cfg.k
        q = jnp.asarray(queries, jnp.float32)
        d, i = self._search_fn(nprobe, k, self._chain_budget())(self.state, q)
        return np.asarray(d), np.asarray(i)

    # ------------------------------------------------------ rearrange ----
    def maybe_rearrange(self, max_passes: int = 4) -> int:
        """Compact offender chains until quiescent; returns #passes run."""
        n = 0
        for _ in range(max_passes):
            self.state, triggered = self._rearrange_fn(self.state)
            if not bool(triggered):
                break
            n += 1
        return n

    @property
    def ntotal(self) -> int:
        return int(self.state.num_vectors)


def _assign_blockwise(x: jax.Array, cents: jax.Array, chunk: int = 8192):
    """Memory-bounded argmin assignment for large training sets."""
    outs = []
    cn = jnp.sum(cents * cents, axis=1)
    for i in range(0, x.shape[0], chunk):
        xc = x[i : i + chunk]
        d = cn[None] - 2.0 * xc @ cents.T
        outs.append(jnp.argmin(d, axis=1))
    return jnp.concatenate(outs)


def build_ivf(
    x: np.ndarray,
    *,
    n_clusters: int,
    payload: str = "flat",
    pq_m: int = 0,
    block_size: int = 1024,
    capacity_vectors: Optional[int] = None,
    add_batch: int = 65536,
    **kw,
) -> IVFIndex:
    """Offline build: train + replay the corpus through batched inserts."""
    cfg = IVFIndexConfig(
        n_clusters=n_clusters,
        dim=x.shape[1],
        payload=payload,
        pq_m=pq_m,
        block_size=block_size,
        capacity_vectors=capacity_vectors or 2 * len(x),
        **kw,
    )
    index = IVFIndex(cfg)
    index.train(x)
    for i in range(0, len(x), add_batch):
        index.add(x[i : i + add_batch])
    return index
