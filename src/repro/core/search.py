"""IVF search over the block pool: coarse probe -> block scan -> top-k.

Two scan paths are provided and benchmarked against each other in §Perf:

* ``chain_walk``  — paper-faithful: follow ``next_block`` header pointers one
  hop at a time (a ``lax.scan`` whose carry is the frontier block of every
  probed chain).  This is the direct port of the GPU linked-list traversal
  and is intentionally kept as the *baseline*: each hop is a dependent
  gather, so the TPU pays a serialised round trip per hop.
* ``block_table`` — TPU adaptation: gather the whole chain for every probed
  cluster in one vectorised HLO gather via ``cluster_blocks`` and scan all
  candidate blocks as one batched matmul (MXU-shaped).  Same results,
  no pointer chasing.

The distance scan itself can additionally be routed through the Pallas
kernel (``repro.kernels.ivf_scan``) via ``scan_impl="pallas"``.

* ``union_fused`` — streaming selection on top of the union scan: scoring
  and top-k are fused in one Pallas kernel keeping a per-query top-``K'``
  accumulator in VMEM, so the ``[C, Q, T]`` score tensor is never
  materialized to HBM (``union_fused_scan`` is the chunked ``lax.scan``
  fallback with the same semantics).  See ``docs/search_paths.md``.

The fused paths dispatch on the payload dtype (``PoolConfig.dtype``):
float32 and bfloat16 blocks route through ``ivf_block_topk``, int8
*residual* codes through the integer-MXU ``ivf_block_topk_int8``
(per-vector scales from ``IVFState.pool_scales``), PQ codes through
``ivf_pq_block_topk``.  The fused kernels identify candidates by *packed
pool location* (``block*T + offset``, derived in-kernel from the prefetched
block id at zero HBM cost); the final top-k resolves locations to global
ids with one ``[Q, k]`` gather.  With ``rerank=True`` the K' survivor rows
are gathered by location and an exact-fp32 re-rank epilogue
(``rerank_topk``; jnp fallback for the scan impl) re-sorts them before the
final top-k — recovering the recall a low-precision first pass gives up.

The *routing prologue* is fused too (§Perf): the coarse probe streams
through ``coarse_topk`` (per-query top-``nprobe`` accumulator on-chip —
the ``[Q, N_clusters]`` distance matrix never exists in HBM, bit-exact
with ``coarse_probe``), the union candidate list is deduped + compacted
by one sort/cumsum pass over the ``[CB]`` block list (no per-query work,
no ``[Q, NP, CU]`` match tensor), and per-(query, candidate) membership /
probe slots are derived *inside* the fused kernels by comparing each
candidate's prefetched owner (``IVFState.block_owner``, maintained
incrementally by insert/rearrange) against the VMEM-resident ``[Q, NP]``
probe list — per-query routing traffic is O(NP), not O(CB).

Every path accounts for tombstones (``core.mutate``): the fused kernels
stream ``IVFState.pool_live`` alongside the payload and force dead rows to
``inf`` before the top-K' accumulator; the gather paths fold the live mask
into their validity masks; the re-rank epilogue re-checks survivor
locations against the mask (defense in depth).  A deleted id can therefore
never surface from any impl, and k > live returns the usual (inf, -1) tail.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.block_pool import NULL, IVFState, PoolConfig
from repro.core.pq import PQParams

INF = jnp.float32(jnp.inf)

# score_fn hooks have signature (state, queries, payload, probe_idx) ->
# [Q, C, T] scores; centroids and any other index-dependent data must come
# from the traced ``state`` (see core.pq.pq_score_fn).


def l2_sq(queries: jax.Array, points: jax.Array) -> jax.Array:
    """[Q, D] x [N, D] -> [Q, N] squared L2 distances."""
    qn = jnp.sum(queries * queries, axis=-1, keepdims=True)
    pn = jnp.sum(points * points, axis=-1)
    return qn + pn[None, :] - 2.0 * (queries @ points.T)


def coarse_probe(state: IVFState, queries: jax.Array, nprobe: int):
    """Top-``nprobe`` nearest centroids per query (ivf coarse quantizer)."""
    d = l2_sq(queries, state.centroids)
    neg_d, idx = jax.lax.top_k(-d, nprobe)
    return idx.astype(jnp.int32), -neg_d


def exact_search(corpus: jax.Array, queries: jax.Array, k: int):
    """Brute-force oracle used for recall metrics."""
    d = l2_sq(queries, corpus)
    neg_d, idx = jax.lax.top_k(-d, k)
    return -neg_d, idx


# ---------------------------------------------------------------------------
# Block-table path (TPU-native)
# ---------------------------------------------------------------------------


def gather_candidate_blocks(
    state: IVFState, probe_idx: jax.Array, chain_budget: Optional[int] = None
):
    """probe_idx [Q, nprobe] -> (payload [Q, C, T, ...], ids [Q, C, T], valid).

    ``chain_budget`` statically bounds how many chain slots are gathered per
    cluster.  ``max_chain`` is a *capacity* knob (worst-case hot list); the
    live maximum chain length is usually far smaller, and gathering the full
    table pays for NULL padding.  The runtime picks the budget from
    ``cluster_nblocks.max()`` bucketed to a power of two (see IVFIndex),
    so results are exact and the jit cache stays tiny.
    """
    table = state.cluster_blocks
    if chain_budget is not None and chain_budget < table.shape[1]:
        table = table[:, :chain_budget]
    blocks = table[probe_idx]  # [Q, nprobe, budget]
    q = blocks.shape[0]
    flat = blocks.reshape(q, -1)  # [Q, C]
    safe = jnp.where(flat == NULL, 0, flat)
    payload = state.pool_payload[safe]
    ids = state.pool_ids[safe]
    # tombstoned rows keep a stale id until compaction — the live mask, not
    # id validity, decides whether a slot may score
    live = state.pool_live[safe] != 0
    valid = (flat != NULL)[..., None] & (ids != NULL) & live
    return payload, ids, valid


def flat_block_scores(queries: jax.Array, payload: jax.Array) -> jax.Array:
    """queries [Q, D], payload [Q, C, T, D] -> squared L2 [Q, C, T].

    bf16 payloads accumulate norms and dots in f32 (matching the fused
    kernels) — a bf16-accumulated norm would silently skew distances."""
    pf = payload.astype(jnp.float32)
    vn = jnp.sum(pf * pf, axis=-1)
    qn = jnp.sum(queries * queries, axis=-1)[:, None, None]
    dots = jnp.einsum(
        "qd,qctd->qct", queries.astype(payload.dtype), payload,
        preferred_element_type=jnp.float32,
    )
    return qn + vn - 2.0 * dots


def search_block_table(
    cfg: PoolConfig,
    state: IVFState,
    queries: jax.Array,
    *,
    nprobe: int,
    k: int,
    score_fn: Optional[Callable] = None,
    chain_budget: Optional[int] = None,
    pq: Optional[PQParams] = None,  # unused (PQ rides on score_fn here)
    rerank: bool = False,
):
    """Vectorised search. Returns (dists [Q, k], ids [Q, k])."""
    if rerank:
        raise NotImplementedError(
            "rerank is a fused-path epilogue; use union_fused[_scan]"
        )
    probe_idx, _ = coarse_probe(state, queries, nprobe)
    payload, ids, valid = gather_candidate_blocks(state, probe_idx, chain_budget)
    if score_fn is None:
        scores = flat_block_scores(queries, payload)
    else:
        scores = score_fn(state, queries, payload, probe_idx)
    scores = jnp.where(valid, scores, INF)
    q = queries.shape[0]
    flat_scores = scores.reshape(q, -1)
    flat_ids = ids.reshape(q, -1)
    neg_d, sel = jax.lax.top_k(-flat_scores, k)
    out_ids = jnp.take_along_axis(flat_ids, sel, axis=1)
    out_ids = jnp.where(jnp.isinf(-neg_d), NULL, out_ids)
    return -neg_d, out_ids


# ---------------------------------------------------------------------------
# Chain-walk path (paper-faithful linked list traversal)
# ---------------------------------------------------------------------------


def search_chain_walk(
    cfg: PoolConfig,
    state: IVFState,
    queries: jax.Array,
    *,
    nprobe: int,
    k: int,
    score_fn: Optional[Callable] = None,
    chain_budget: Optional[int] = None,
    pq: Optional[PQParams] = None,  # unused (PQ rides on score_fn here)
    rerank: bool = False,
):
    """Follow ``next_block`` headers hop by hop (GPU traversal port)."""
    if rerank:
        raise NotImplementedError(
            "rerank is a fused-path epilogue; use union_fused[_scan]"
        )
    q = queries.shape[0]
    probe_idx, _ = coarse_probe(state, queries, nprobe)
    cur0 = state.cluster_head[probe_idx]  # [Q, nprobe]
    best_d0 = jnp.full((q, k), INF)
    best_i0 = jnp.full((q, k), NULL, jnp.int32)

    def hop(carry, _):
        cur, best_d, best_i = carry
        safe = jnp.where(cur == NULL, 0, cur)
        payload = state.pool_payload[safe]  # [Q, nprobe, T, ...]
        ids = state.pool_ids[safe]  # [Q, nprobe, T]
        if score_fn is None:
            scores = flat_block_scores(
                queries, payload.reshape(q, -1, *payload.shape[2:])
            ).reshape(ids.shape)
        else:
            scores = score_fn(state, queries, payload, probe_idx)
        live = state.pool_live[safe] != 0
        alive = (cur != NULL)[..., None] & (ids != NULL) & live
        scores = jnp.where(alive, scores, INF)
        cat_d = jnp.concatenate([best_d, scores.reshape(q, -1)], axis=1)
        cat_i = jnp.concatenate([best_i, ids.reshape(q, -1)], axis=1)
        neg_d, sel = jax.lax.top_k(-cat_d, k)
        best_i = jnp.take_along_axis(cat_i, sel, axis=1)
        nxt = jnp.where(cur == NULL, NULL, state.next_block[safe])
        return (nxt, -neg_d, best_i), None

    (cur, best_d, best_i), _ = jax.lax.scan(
        hop, (cur0, best_d0, best_i0), None,
        length=chain_budget or cfg.max_chain,
    )
    best_i = jnp.where(jnp.isinf(best_d), NULL, best_i)
    return best_d, best_i


# ---------------------------------------------------------------------------
# Union-dedup scan (beyond-paper TPU optimisation, §Perf):
# the union of probed clusters across the query batch is scanned once, so
# every candidate block is read from HBM exactly once per *batch* instead of
# once per *query*.  ``scan_impl="pallas"`` routes the distance computation
# through the scalar-prefetch Pallas kernel (repro.kernels.ivf_scan).
# ---------------------------------------------------------------------------


class UnionCandidates(NamedTuple):
    flat_blocks: jax.Array  # [CB] deduped live block ids, NULL-padded tail
    owners: jax.Array  # [CB] owning cluster per candidate (NULL padding)
    probe_idx: jax.Array  # [Q, NP] probed cluster ids (distinct per row)


def _coarse_dispatch(
    state: IVFState, queries: jax.Array, nprobe: int, scan_impl: str
):
    """Coarse probe matching the path's execution style: the Pallas paths
    stream it through ``coarse_topk`` (no [Q, N] matrix in HBM), the scan
    fallback through its chunked ``lax.scan`` twin, and the jnp oracle
    through plain ``coarse_probe`` — all three are bit-exact, ties
    included, so the choice never changes results."""
    if scan_impl == "pallas":
        from repro.kernels.ops import coarse_topk

        return coarse_topk(queries, state.centroids, nprobe=nprobe)
    if scan_impl == "scan":
        from repro.kernels.ivf_scan import coarse_topk_scan

        return coarse_topk_scan(queries, state.centroids, nprobe=nprobe)
    return coarse_probe(state, queries, nprobe)


def _union_candidates(
    cfg: PoolConfig,
    state: IVFState,
    queries: jax.Array,
    nprobe: int,
    chain_budget: Optional[int],
    scan_impl: str = "jnp",
) -> UnionCandidates:
    """Fused routing prologue of the union paths: streaming coarse probe,
    then dedup + compaction of the candidate block list in a single
    sort/cumsum pass over the [CB] block ids — computed once per dispatch,
    not per query.  No ``jnp.unique``, no [Q, NP, CU] match tensor, no
    [Q, CB] membership operand: the per-(query, candidate) routing is
    derived in-kernel from ``owners`` and ``probe_idx``.

    The compacted list is statically capped at min(CB, P): every live
    block appears at most once (chains are disjoint), so dead slots (chain
    padding, cross-query duplicates) cost neither a grid step nor a DMA in
    the streaming kernels."""
    q = queries.shape[0]
    mc = min(chain_budget or cfg.max_chain, cfg.max_chain)
    probe_idx, _ = _coarse_dispatch(state, queries, nprobe, scan_impl)
    blocks = state.cluster_blocks[:, :mc][probe_idx].reshape(-1)  # [Q*NP*mc]
    # NULLs sort to the back via a +inf-like key; the first occurrence of
    # each block id is scattered to its rank among the uniques
    sentinel = jnp.int32(2**31 - 1)
    srt = jnp.sort(jnp.where(blocks == NULL, sentinel, blocks))
    keep = (srt != sentinel) & jnp.concatenate(
        [jnp.ones((1,), bool), srt[1:] != srt[:-1]]
    )
    cap = min(blocks.shape[0], cfg.n_blocks)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    flat = (
        jnp.full((cap,), NULL, jnp.int32)
        .at[jnp.where(keep, pos, cap)]
        .set(jnp.where(keep, srt, NULL), mode="drop")
    )
    owners = jnp.where(
        flat == NULL, NULL, state.block_owner[jnp.maximum(flat, 0)]
    )
    return UnionCandidates(flat, owners, probe_idx)


def search_union(
    cfg: PoolConfig,
    state: IVFState,
    queries: jax.Array,
    *,
    nprobe: int,
    k: int,
    score_fn: Optional[Callable] = None,  # unused (flat payload only)
    scan_impl: str = "jnp",
    chain_budget: Optional[int] = None,
    pq: Optional[PQParams] = None,
    rerank: bool = False,
):
    if cfg.payload != "flat" or cfg.has_scales:
        raise NotImplementedError(
            "union/union_pallas score raw f32/bf16 vectors; PQ and int8 "
            "payloads use the fused union paths (or block_table/chain_walk "
            "for PQ)"
        )
    if rerank:
        raise NotImplementedError(
            "rerank is a fused-path epilogue; use union_fused[_scan]"
        )
    q = queries.shape[0]
    # compacted prologue: dead (NULL / duplicate) slots are gone, so the
    # scan below only ever scores live blocks (they used to be scored
    # against clamped block 0 and masked)
    uc = _union_candidates(
        cfg, state, queries, nprobe, chain_budget,
        "pallas" if scan_impl == "pallas" else "jnp",
    )
    flat_blocks = uc.flat_blocks

    if scan_impl == "pallas":
        from repro.kernels.ops import ivf_block_scan

        scores = ivf_block_scan(queries, state.pool_payload, flat_blocks)
    else:
        from repro.kernels.ref import ivf_block_scan_ref

        scores = ivf_block_scan_ref(queries, state.pool_payload, flat_blocks)
    # scores [CB, Q, T] -> mask holes, non-membership, empty slots, and
    # tombstones (dead rows keep a stale id until compaction)
    ids = state.pool_ids[jnp.maximum(flat_blocks, 0)]  # [CB, T]
    live = state.pool_live[jnp.maximum(flat_blocks, 0)] != 0  # [CB, T]
    slot_ok = (flat_blocks != NULL)[:, None] & (ids != NULL) & live
    member_b = (
        uc.probe_idx[:, :, None] == uc.owners[None, None, :]
    ).any(axis=1)  # [Q, CB] (an XLA compare — fine outside the kernels)
    ok = slot_ok[None, :, :] & member_b[:, :, None]  # [Q, CB, T]
    sq = jnp.where(ok, jnp.transpose(scores, (1, 0, 2)), INF)
    flat_scores = sq.reshape(q, -1)
    flat_ids = jnp.broadcast_to(ids[None], (q, *ids.shape)).reshape(q, -1)
    neg_d, sel = jax.lax.top_k(-flat_scores, k)
    out_ids = jnp.take_along_axis(flat_ids, sel, axis=1)
    out_ids = jnp.where(jnp.isinf(-neg_d), NULL, out_ids)
    return -neg_d, out_ids


# ---------------------------------------------------------------------------
# Fused streaming-selection union scan (§Perf headline): identical candidate
# set to ``search_union``, but scoring and selection are fused — a running
# per-query top-K' accumulator is kept on-chip across the candidate-block
# scan, so only [Q, K'] (score, id) pairs are written back instead of the
# full [CB, Q, T] score tensor.  The final ``top_k(k)`` runs over K'
# candidates, not CB*T.  See docs/search_paths.md for when to pick it.
# ---------------------------------------------------------------------------


def default_kprime(k: int) -> int:
    """Accumulator width: smallest lane-aligned (128) multiple >= k."""
    return max(128, -(-k // 128) * 128)


def _rerank_dispatch(queries, rows, scales, loc, scan_impl):
    if scan_impl == "pallas":
        from repro.kernels.ops import rerank_topk

        return rerank_topk(queries, rows, scales, loc)
    from repro.kernels.ref import rerank_topk_ref

    return rerank_topk_ref(queries, rows, scales, loc)


def _live_locs(state, loc):
    """Invalidate survivor locations whose slot is no longer live.  The
    first pass already masks tombstones in-kernel, so this is pure defense
    in depth — it makes 'a deleted id can never leave the epilogue' a local
    property of the re-rank instead of a cross-kernel invariant."""
    live = state.pool_live.reshape(-1)[jnp.clip(loc, 0)] != 0
    return jnp.where((loc != NULL) & live, loc, NULL)


def _rerank_flat(cfg, state, queries, loc, scan_impl):
    """Exact-fp32 re-rank of flat-payload survivors: gather the K' rows by
    packed location (one XLA gather), then fused dequant + distance +
    (distance, location) sort.  int8 rows are residual codes, so the owning
    cluster's centroid is added back before scoring.  Returns
    ([Q, K'] dists asc, [Q, K'] locs)."""
    p, t = state.pool_ids.shape
    loc = _live_locs(state, loc)
    safe = jnp.clip(loc, 0)
    rows = state.pool_payload.reshape(p * t, -1)[safe]  # [Q, K', D]
    scales = jnp.ones(loc.shape, jnp.float32)
    if cfg.has_scales:
        svs = state.pool_scales.reshape(-1)[safe]
        # block_owner is maintained incrementally (free blocks own NULL —
        # clamp for the gather; invalid locations are masked by loc == -1)
        owner = jnp.maximum(state.block_owner[safe // t], 0)
        rows = state.centroids[owner] + rows.astype(jnp.float32) * svs[..., None]
    return _rerank_dispatch(queries, rows, scales, loc, scan_impl)


def _rerank_pq(cfg, state, pq, queries, loc, scan_impl):
    """Re-rank PQ survivors at full precision: decode codes, add the
    owning cluster's centroid back (residual semantics), exact fp32
    distance."""
    from repro.core import pq as pqmod

    p, t = state.pool_ids.shape
    loc = _live_locs(state, loc)
    safe = jnp.clip(loc, 0)
    codes = state.pool_payload.reshape(p * t, -1)[safe]  # [Q, K', M]
    cent = state.centroids[jnp.maximum(state.block_owner[safe // t], 0)]
    recon = cent + pqmod.decode(pq, codes)
    ones = jnp.ones(loc.shape, jnp.float32)
    return _rerank_dispatch(queries, recon, ones, loc, scan_impl)


def search_union_fused(
    cfg: PoolConfig,
    state: IVFState,
    queries: jax.Array,
    *,
    nprobe: int,
    k: int,
    score_fn: Optional[Callable] = None,  # unused (fused paths score inline)
    scan_impl: str = "pallas",
    chain_budget: Optional[int] = None,
    kprime: Optional[int] = None,
    pq: Optional[PQParams] = None,  # required for payload == "pq"
    rerank: bool = False,
):
    if cfg.payload == "pq" and pq is None:
        raise ValueError(
            "union_fused on a PQ payload needs the trained PQParams "
            "(pass pq=index.pq / via make_search_fn)"
        )
    # Fused routing prologue: the candidate list arrives deduped +
    # compacted (cap = min(Q*nprobe*budget, P) — every live block at most
    # once, dead slots truncated), and the only per-query routing operands
    # the kernels receive are the [Q, NP] probe list (VMEM-resident) and
    # the [CB] candidate owners (scalar-prefetched): membership and the
    # residual probe slot are derived on-chip.  No [Q, CB] cand_ok/pslot,
    # no [Q, N_clusters] coarse matrix.
    uc = _union_candidates(
        cfg, state, queries, nprobe, chain_budget, scan_impl
    )
    flat_blocks, owners, probe_idx = uc.flat_blocks, uc.owners, uc.probe_idx
    kp = kprime or default_kprime(k)
    assert kp >= k, (kp, k)
    if cfg.payload == "pq":
        from repro.core import pq as pqmod

        # per-(query, probe) residual ADC tables
        lut = pqmod.probe_residual_luts(
            pq, state.centroids, queries, probe_idx
        )  # [Q, NP, M, KSUB]
        if scan_impl == "pallas":
            from repro.kernels.ops import ivf_pq_block_topk

            d, i = ivf_pq_block_topk(
                lut, state.pool_payload, flat_blocks, owners,
                state.pool_ids, state.pool_live, probe_idx, kprime=kp,
            )
        elif scan_impl == "scan":
            from repro.kernels.ivf_scan import ivf_pq_block_topk_scan

            d, i = ivf_pq_block_topk_scan(
                lut, state.pool_payload, flat_blocks, owners,
                state.pool_ids, state.pool_live, probe_idx, kprime=kp,
            )
        else:
            from repro.kernels.ref import ivf_pq_block_topk_ref

            d, i = ivf_pq_block_topk_ref(
                lut, state.pool_payload, flat_blocks, owners,
                state.pool_ids, state.pool_live, probe_idx, kprime=kp,
            )
    elif cfg.has_scales:
        # int8 residual payload: quantize the per-probe query residuals
        # once, then the integer-MXU variant scores codes against codes
        from repro.kernels.ivf_scan import quantize_queries

        qres = queries[:, None, :] - state.centroids[probe_idx]
        q_codes, q_meta = quantize_queries(qres)  # [Q, NP, D], [Q, NP, 2]
        if scan_impl == "pallas":
            from repro.kernels.ops import ivf_block_topk_int8

            d, i = ivf_block_topk_int8(
                q_codes, q_meta, state.pool_payload, state.pool_scales,
                flat_blocks, owners, state.pool_ids, state.pool_live,
                probe_idx, kprime=kp,
            )
        elif scan_impl == "scan":
            from repro.kernels.ivf_scan import ivf_block_topk_int8_scan

            d, i = ivf_block_topk_int8_scan(
                q_codes, q_meta, state.pool_payload, state.pool_scales,
                flat_blocks, owners, state.pool_ids, state.pool_live,
                probe_idx, kprime=kp,
            )
        else:
            from repro.kernels.ref import ivf_block_topk_int8_ref

            d, i = ivf_block_topk_int8_ref(
                q_codes, q_meta, state.pool_payload, state.pool_scales,
                flat_blocks, owners, state.pool_ids, state.pool_live,
                probe_idx, kprime=kp,
            )
    elif scan_impl == "pallas":
        from repro.kernels.ops import ivf_block_topk

        d, i = ivf_block_topk(
            queries, state.pool_payload, flat_blocks, owners,
            state.pool_ids, state.pool_live, probe_idx, kprime=kp,
        )
    elif scan_impl == "scan":
        from repro.kernels.ivf_scan import ivf_block_topk_scan

        d, i = ivf_block_topk_scan(
            queries, state.pool_payload, flat_blocks, owners,
            state.pool_ids, state.pool_live, probe_idx, kprime=kp,
        )
    else:
        from repro.kernels.ref import ivf_block_topk_ref

        d, i = ivf_block_topk_ref(
            queries, state.pool_payload, flat_blocks, owners,
            state.pool_ids, state.pool_live, probe_idx, kprime=kp,
        )
    # the fused kernels emit packed pool locations (block*T + offset,
    # derived in-kernel from the prefetched block id at zero HBM cost)
    if rerank:
        # exact re-rank epilogue over the K' survivors; output rows come
        # back sorted ascending by (exact distance, location)
        if cfg.payload == "pq":
            d, loc = _rerank_pq(cfg, state, pq, queries, i, scan_impl)
        else:
            d, loc = _rerank_flat(cfg, state, queries, i, scan_impl)
        d, loc = d[:, :k], loc[:, :k]
        out_ids = state.pool_ids.reshape(-1)[jnp.clip(loc, 0)]
        out_ids = jnp.where((loc == NULL) | jnp.isinf(d), NULL, out_ids)
        return d, out_ids
    # second selection stage: k out of the K' streamed survivors, then one
    # [Q, k] gather resolves locations to caller-visible global ids
    neg_d, sel = jax.lax.top_k(-d, k)
    loc = jnp.take_along_axis(i, sel, axis=1)
    out_ids = state.pool_ids.reshape(-1)[jnp.clip(loc, 0)]
    out_ids = jnp.where((loc == NULL) | jnp.isinf(-neg_d), NULL, out_ids)
    return -neg_d, out_ids


# All selectable scan paths (docs/search_paths.md documents the ladder) and
# the subset that can serve a PQ payload: block_table / chain_walk score
# through the score_fn hook, the fused union paths route through the PQ-ADC
# streaming kernel; plain union / union_pallas score raw vectors only.
SEARCH_IMPLS = {
    "block_table": search_block_table,
    "chain_walk": search_chain_walk,
    "union": search_union,
    "union_pallas": partial(search_union, scan_impl="pallas"),
    "union_fused": search_union_fused,
    "union_fused_scan": partial(search_union_fused, scan_impl="scan"),
}
PQ_SEARCH_PATHS = frozenset(
    {"block_table", "chain_walk", "union_fused", "union_fused_scan"}
)
# the fused union paths are the only ones that understand int8 payloads
# (everything else would score the raw codes as numbers) and the only ones
# with the re-rank epilogue
FUSED_SEARCH_PATHS = frozenset({"union_fused", "union_fused_scan"})
INT8_SEARCH_PATHS = FUSED_SEARCH_PATHS


def resolve_search_impl(
    cfg: PoolConfig, path: str, rerank: bool = False
) -> Callable:
    """Look up a scan path, rejecting typos and payload mismatches loudly
    (a silent fallback would benchmark / serve the wrong path)."""
    if path not in SEARCH_IMPLS:
        raise ValueError(
            f"unknown search_path {path!r}; expected one of "
            f"{sorted(SEARCH_IMPLS)}"
        )
    if cfg.payload == "pq" and path not in PQ_SEARCH_PATHS:
        raise NotImplementedError(
            f"search_path {path!r} scores raw vectors; PQ payloads support "
            f"{sorted(PQ_SEARCH_PATHS)}"
        )
    if cfg.has_scales and path not in INT8_SEARCH_PATHS:
        raise NotImplementedError(
            f"search_path {path!r} scores raw vectors; int8 payloads "
            f"support {sorted(INT8_SEARCH_PATHS)}"
        )
    if rerank and path not in FUSED_SEARCH_PATHS:
        raise NotImplementedError(
            f"rerank is a fused-path epilogue; search_path {path!r} does "
            f"not support it (use one of {sorted(FUSED_SEARCH_PATHS)})"
        )
    return SEARCH_IMPLS[path]


def make_search_fn(
    cfg: PoolConfig,
    *,
    nprobe: int,
    k: int,
    path: str = "block_table",
    score_fn: Optional[Callable] = None,
    chain_budget: Optional[int] = None,
    pq: Optional[PQParams] = None,
    rerank: bool = False,
):
    """Jitted search step closed over static (nprobe, k, traversal path)."""
    impl = resolve_search_impl(cfg, path, rerank)

    @jax.jit
    def step(state: IVFState, queries: jax.Array):
        return impl(
            cfg, state, queries, nprobe=nprobe, k=k, score_fn=score_fn,
            chain_budget=chain_budget, pq=pq, rerank=rerank,
        )

    return step
