"""RTAMS-GANNS core: block-pool IVF with online insertion (paper §3)."""

from repro.core.block_pool import (  # noqa: F401
    IVFState,
    PoolConfig,
    check_invariants,
    dead_fraction,
    init_state,
    pool_stats,
    snapshot_ids,
    utilisation,
)
from repro.core.admission import (  # noqa: F401
    DeadlineExceeded,
    DegradationLadder,
    QueueFull,
    RequestRejected,
    RuntimeShutdown,
)
from repro.core.faults import FaultPlan  # noqa: F401
from repro.core.insert import assign_clusters, insert_payload, make_insert_fn  # noqa: F401
from repro.core.ivf import IVFIndex, IVFIndexConfig, build_ivf  # noqa: F401
from repro.core.kmeans import kmeans  # noqa: F401
from repro.core.mutate import apply_delete, make_delete_fn, make_update_fn  # noqa: F401
from repro.core.rearrange import make_rearrange_fn, rearrange_cluster  # noqa: F401
from repro.core.runtime import RuntimeConfig, ServingRuntime  # noqa: F401
from repro.core.search import (  # noqa: F401
    exact_search,
    make_search_fn,
    search_block_table,
    search_chain_walk,
)
