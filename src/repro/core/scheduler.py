"""Compatibility shim: the serving runtime moved to ``repro.core.runtime``
(with admission control / deadlines / degradation in
``repro.core.admission`` and deterministic fault injection in
``repro.core.faults``).  Import from those modules in new code; this one
keeps the historical ``repro.core.scheduler`` entry point working."""

from repro.core.admission import (  # noqa: F401
    DeadlineExceeded,
    QueueFull,
    RequestRejected,
    RuntimeShutdown,
)
from repro.core.faults import FaultPlan  # noqa: F401
from repro.core.runtime import RuntimeConfig, ServingRuntime  # noqa: F401
