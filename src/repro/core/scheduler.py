"""Multi-stream serving runtime (paper Alg. 4 + deployment §3.3).

Reproduces the paper's execution architecture with TPU-appropriate
mechanisms (DESIGN.md §2, §5):

* **Resource pool** — 32 slots, each a permit to dispatch a search; when all
  slots are busy the request is *rejected* (the paper's lock-free queue with
  rejection).  Slot scratch memory is implicit in JAX (each jitted search
  owns preallocated output buffers), the central-pool overflow grant is
  modelled by the shared device arena.
* **Dedicated mutation lane** — one thread owns the index state and applies
  donated insert/delete/update steps; the paper's single data stream, grown
  into a full mutation stream.  Deletes tombstone rows through the device
  id map, updates tombstone + re-insert under the same id in one dispatch
  (core.mutate), and arrival order is preserved: the lane batches
  *consecutive runs of the same kind*, so delete-then-insert of an id can
  never be reordered into insert-then-delete.
* **Dynamic batcher** — inserts aggregate until ``flush_min`` (128) pending
  or ``flush_interval`` (1 s) elapsed, capped at ``flush_max`` (1024);
  search batches are capped at ``max_search_batch`` (10).  All paper §3.3
  values are the defaults.
* **Execution modes** (benchmarked in Fig. 3 reproduction):
    - ``serial``   — Fig. 2a: one lane; an insert in flight blocks searches.
    - ``parallel`` — Fig. 2b: search slots dispatch concurrently with the
      insert lane.  Correctness under buffer donation: dispatch happens
      under the state lock (cheap — dispatch is async), execution overlaps.
    - ``fused``    — TPU-native multi-stream: a pending insert batch and a
      pending search batch are submitted as ONE jitted program whose two
      subgraphs share no data edge, so the XLA scheduler overlaps them
      (search reads the pre-insert state — the legal concurrent
      serialisation, same as the paper's streams).
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_pool import pool_stats
from repro.core.insert import assign_clusters, insert_payload
from repro.core.ivf import IVFIndex
from repro.core.metrics import LatencyStats
from repro.core.mutate import apply_delete, last_occurrence_mask
from repro.core import pq as pqmod
from repro.core.search import resolve_search_impl


class RequestRejected(RuntimeError):
    """All resource-pool slots busy (paper: reject at 32 exhausted)."""


@dataclasses.dataclass
class _Timed:
    future: Future
    t_arrival: float
    payload: object
    kind: str = "insert"  # insert | delete | update (mutation lane kinds)
    t_done: float = 0.0


@dataclasses.dataclass
class RuntimeConfig:
    n_slots: int = 32  # paper: 32 independent resources
    max_search_batch: int = 10  # paper: max search batch 10
    flush_min: int = 128  # paper: dispatch at 128 pending inserts
    flush_max: int = 1024  # paper: cap 1024
    flush_interval: float = 1.0  # paper: flush every second
    nprobe: int = 16
    k: int = 10
    mode: str = "parallel"  # serial | parallel | fused
    # any path make_search_fn supports: block_table | chain_walk | union |
    # union_pallas | union_fused | union_fused_scan (typos raise ValueError
    # at construction — a silent fallback would serve the wrong path)
    search_path: str = "block_table"
    # exact-fp32 re-rank epilogue over the fused survivors (fused paths
    # only; rejected at construction otherwise)
    rerank: bool = False
    # latency samples kept for stats(); unbounded lists grow forever under
    # sustained traffic
    latency_window: int = 10_000
    # run dead-space-reclaiming compaction passes on the mutation lane after
    # a delete/update batch whenever a cluster crosses the dead-fraction
    # trigger (see core.rearrange); off by default — maintenance cadence is
    # a deployment decision
    auto_compact: bool = False
    compact_passes: int = 4


class ServingRuntime:
    """Owns the IVF index state + jitted steps; serves search/insert."""

    def __init__(self, index: IVFIndex, cfg: RuntimeConfig = RuntimeConfig()):
        self.index = index
        self.cfg = cfg
        self.pool_cfg = index.pool_cfg
        self._state_lock = threading.Lock()
        self._slots = threading.Semaphore(cfg.n_slots)
        self._stop = threading.Event()
        self._search_q: queue.Queue = queue.Queue()
        self._insert_q: queue.Queue = queue.Queue()
        # bounded: stats() reports over a sliding window instead of every
        # sample since process start.  Appends and snapshots share a lock —
        # iterating a deque while a worker appends raises RuntimeError
        # (unlike the copy-a-list-under-GIL idiom it replaced).
        self._lat_lock = threading.Lock()
        self._search_lat: collections.deque = collections.deque(
            maxlen=cfg.latency_window
        )
        self._insert_lat: collections.deque = collections.deque(
            maxlen=cfg.latency_window
        )
        self._mutation_lat: collections.deque = collections.deque(
            maxlen=cfg.latency_window
        )
        self._rejects = 0
        # mutation-stream counters (rows applied, not batches)
        self._n_inserts = 0
        self._n_deletes = 0
        self._n_updates = 0
        self._n_compactions = 0
        self._fused_pending = queue.Queue()
        self._build_steps()
        self._threads = [
            threading.Thread(target=self._insert_loop, daemon=True),
            threading.Thread(target=self._search_loop, daemon=True),
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------ steps --
    def _build_steps(self):
        cfg, pc = self.cfg, self.pool_cfg
        pq = self.index.pq
        # fail at construction, not inside the worker thread's first jit
        # trace: raises ValueError on an unknown path (no silent fallback)
        # and NotImplementedError on a payload mismatch
        self._search_impl = resolve_search_impl(
            pc, cfg.search_path, cfg.rerank
        )
        # state-free: centroids come from the traced state argument, so the
        # cached steps never bake a stale pool copy in as jit constants
        self._score_fn = pqmod.pq_score_fn(pq) if pq is not None else None
        # jitted steps are cached per chain budget: the budget is recomputed
        # at dispatch time (see _current_budget), so online growth costs one
        # recompile per power-of-two bucket instead of silently truncating
        self._search_steps: dict[int, object] = {}
        self._fused_steps: dict[int, object] = {}
        # cached bucketed budget; None forces a recompute (a host readback
        # of the live chain depth) — invalidated only by the insert paths,
        # so pure-search traffic never pays the device sync
        self._budget: Optional[int] = None

        def _insert(state, vectors, ids, valid):
            assign = assign_clusters(state.centroids, vectors)
            if pq is None:
                payload = vectors
            else:
                payload = pqmod.encode(pq, vectors - state.centroids[assign])
            return insert_payload(pc, state, assign, payload, ids, valid)

        def _delete(state, ids, valid):
            return apply_delete(pc, state, ids, valid)

        def _update(state, vectors, ids, valid):
            # tombstone + re-insert under the same id, one dispatch: no
            # state where both (or neither) copy is visible can be observed;
            # duplicate targets merged into one run re-insert last-write-wins
            state = apply_delete(pc, state, ids, valid)
            return _insert(state, vectors, ids,
                           last_occurrence_mask(ids, valid))

        # raw fns feed the fused (search+mutation) programs; jitted steps
        # serve the standalone mutation lane
        self._mutation_fns = {
            "insert": _insert, "delete": _delete, "update": _update,
        }
        self._insert_fn = _insert
        self._insert_step = jax.jit(_insert, donate_argnums=(0,))
        self._delete_step = jax.jit(_delete, donate_argnums=(0,))
        self._update_step = jax.jit(_update, donate_argnums=(0,))

    def _current_budget(self) -> int:
        """Adaptive chain budget (§Perf), recomputed at *dispatch* time.

        The budget is the live chain depth bucketed to the next power of
        two with 2x headroom (capped at ``max_chain``) *before* it keys the
        ``_search_steps``/``_fused_steps`` jit caches, so steady chain
        growth costs O(log max_chain) recompiles instead of one per
        increment; computing it once at construction silently truncated
        chains — and dropped candidates — after online inserts grew them
        past 2x the initial depth.  The value is cached between inserts
        (callers hold ``_state_lock``).  Chains never shrink, so when the
        bucket advances the entries keyed by smaller budgets can never be
        dispatched again — they are evicted instead of pinning their
        compiled executables (and output buffers) forever.
        """
        if self._budget is None:
            # IVFIndex._chain_budget() happens to return pow2 buckets
            # already, making the _bucket pass idempotent today — it is
            # enforced *here* regardless, because the jit-cache keys below
            # are what actually bound the recompile count; a future budget
            # heuristic must not silently re-introduce
            # one-recompile-per-increment growth.
            budget = min(
                self._bucket(2 * self.index._chain_budget(), floor=1),
                self.pool_cfg.max_chain,
            )
            # _search_steps is keyed by budget, _fused_steps by
            # (budget, mutation kind)
            for cache in (self._search_steps, self._fused_steps):
                for stale in [
                    k for k in cache
                    if (k[0] if isinstance(k, tuple) else k) < budget
                ]:
                    del cache[stale]
            self._budget = budget
        return self._budget

    def _make_search(self, budget: int):
        cfg, pc = self.cfg, self.pool_cfg

        def _search(state, queries, valid):
            d, i = self._search_impl(
                pc, state, queries, nprobe=cfg.nprobe, k=cfg.k,
                score_fn=self._score_fn, chain_budget=budget,
                pq=self.index.pq, rerank=cfg.rerank,
            )
            return d, jnp.where(valid[:, None], i, -1)

        return _search

    def _search_step_for(self, budget: int):
        if budget not in self._search_steps:
            self._search_steps[budget] = jax.jit(self._make_search(budget))
        return self._search_steps[budget]

    def _fused_step_for(self, budget: int, kind: str = "insert"):
        key = (budget, kind)
        if key not in self._fused_steps:
            _search = self._make_search(budget)
            _mutate = self._mutation_fns[kind]

            def _fused(state, queries, qvalid, *m_args):
                # two independent subgraphs; XLA overlaps them (multi-stream)
                d, i = _search(state, queries, qvalid)
                new_state = _mutate(state, *m_args)
                return new_state, d, i

            self._fused_steps[key] = jax.jit(_fused, donate_argnums=(0,))
        return self._fused_steps[key]

    # ------------------------------------------------------------ API ----
    def submit_search(self, queries: np.ndarray) -> Future:
        if not self._slots.acquire(blocking=False):
            self._rejects += 1
            raise RequestRejected("resource pool exhausted")
        fut = Future()
        self._search_q.put(_Timed(fut, time.perf_counter(), queries))
        return fut

    def submit_insert(self, vectors: np.ndarray) -> Future:
        fut = Future()
        self._insert_q.put(_Timed(fut, time.perf_counter(), vectors))
        return fut

    def submit_delete(self, ids: np.ndarray) -> Future:
        """Tombstone ids through the mutation lane.  Resolves with the ids
        once the delete step has been applied (misses — unknown or already
        deleted ids — are counted in the index state, not surfaced per
        request: the batch is one fused dispatch)."""
        fut = Future()
        ids = np.atleast_1d(np.asarray(ids, np.int32))
        self._insert_q.put(
            _Timed(fut, time.perf_counter(), ids, kind="delete")
        )
        return fut

    def submit_update(self, vectors: np.ndarray, ids: np.ndarray) -> Future:
        """Replace the vectors behind ``ids`` (tombstone + re-insert under
        the same id, one dispatch).  Resolves with the ids once applied."""
        vectors = np.atleast_2d(vectors)
        ids = np.atleast_1d(np.asarray(ids, np.int32))
        if len(ids) != len(vectors):
            raise ValueError(f"{len(ids)} ids for {len(vectors)} vectors")
        fut = Future()
        self._insert_q.put(
            _Timed(fut, time.perf_counter(), (vectors, ids), kind="update")
        )
        return fut

    def stop(self):
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)

    def stats(self, timeout_ms: float = 20.0):
        with self._lat_lock:
            search = tuple(self._search_lat)
            insert = tuple(self._insert_lat)
            mutation = tuple(self._mutation_lat)
        out = {
            "search": LatencyStats.from_samples(search, timeout_ms),
            "insert": LatencyStats.from_samples(insert, timeout_ms),
            "mutation": LatencyStats.from_samples(mutation, timeout_ms),
            "rejected": self._rejects,
            "inserts": self._n_inserts,
            "deletes": self._n_deletes,
            "updates": self._n_updates,
            "compactions": self._n_compactions,
        }
        # live-occupancy gauges: allocated != occupied once tombstones exist
        with self._state_lock:
            out.update(pool_stats(self.index.state, self.pool_cfg))
        return out

    # --------------------------------------------------------- workers ---
    @staticmethod
    def _n_rows(it: _Timed) -> int:
        """Row count of a mutation item (vectors for insert, ids for
        delete, paired (vectors, ids) for update)."""
        if it.kind == "delete":
            return len(np.atleast_1d(it.payload))
        if it.kind == "update":
            return len(np.atleast_2d(it.payload[0]))
        return len(np.atleast_2d(it.payload))

    def _drain_inserts(self) -> list[_Timed]:
        """Dynamic batching policy from §3.3 over the mutation lane.

        A running row count is kept instead of re-concatenating every pending
        payload per queue pop (that was quadratic in batch size)."""
        items: list[_Timed] = []
        pending_rows = 0
        deadline = time.perf_counter() + self.cfg.flush_interval
        while not self._stop.is_set():
            timeout = deadline - time.perf_counter()
            if timeout <= 0:
                break
            try:
                item = self._insert_q.get(timeout=min(timeout, 0.01))
            except queue.Empty:
                continue
            items.append(item)
            pending_rows += self._n_rows(item)
            if pending_rows >= self.cfg.flush_min:
                break
        return items

    def _split_flush(self, items: list[_Timed]):
        """Longest whole-item same-kind prefix within ``flush_max`` rows +
        the remainder.

        Items are never split mid-payload (each future must resolve with its
        exact ids), so a single oversized item is dispatched alone and may
        exceed the cap.  A kind switch also ends the batch: runs of the same
        kind dispatch as one fused step, and arrival order across kinds is
        preserved (delete-then-insert of an id must never reorder).  The
        remainder is applied next, never dropped."""
        take: list[_Timed] = []
        rows = 0
        for pos, it in enumerate(items):
            n = self._n_rows(it)
            if take and (
                rows + n > self.cfg.flush_max or it.kind != take[0].kind
            ):
                return take, items[pos:]
            take.append(it)
            rows += n
        return take, []

    @staticmethod
    def _pending_vectors(items: list[_Timed]) -> np.ndarray:
        if not items:
            return np.zeros((0, 1), np.float32)
        return np.concatenate([np.atleast_2d(i.payload) for i in items], 0)

    @staticmethod
    def _bucket(n: int, floor: int = 8) -> int:
        """Next power-of-two bucket — keeps the jit cache tiny."""
        b = floor
        while b < n:
            b *= 2
        return b

    def _padded(self, rows: np.ndarray, bucket: int):
        n = len(rows)
        out = np.zeros((bucket, rows.shape[1]), np.float32)
        out[:n] = rows
        valid = np.zeros((bucket,), bool)
        valid[:n] = True
        return out, valid

    @staticmethod
    def _fail_futures(items: list[_Timed], exc: BaseException):
        """Propagate a mid-step failure: an unresolved future would hang its
        caller forever."""
        for it in items:
            if not it.future.done():
                it.future.set_exception(exc)

    def _mutation_args(self, kind: str, items: list[_Timed]):
        """Pack one same-kind run into the padded, fixed-shape device args
        of its jitted step.  Returns (step_args, ids) — ids are the
        per-row ids each future's slice resolves with (freshly assigned for
        inserts, caller-provided for delete/update)."""
        if kind == "insert":
            vecs = self._pending_vectors(items)
            b = len(vecs)
            ids = np.arange(
                self.index._next_id, self.index._next_id + b, dtype=np.int32
            )
            self.index._next_id += b
            pv, valid = self._padded(vecs, self._bucket(b))
        elif kind == "delete":
            ids = np.concatenate(
                [np.atleast_1d(i.payload) for i in items]
            ).astype(np.int32)
            b = len(ids)
            valid = np.zeros((self._bucket(b),), bool)
            valid[:b] = True
        else:  # update
            vecs = np.concatenate(
                [np.atleast_2d(i.payload[0]) for i in items], 0
            )
            ids = np.concatenate(
                [np.atleast_1d(i.payload[1]) for i in items]
            ).astype(np.int32)
            b = len(ids)
            pv, valid = self._padded(vecs, self._bucket(b))
        pids = np.full((len(valid),), -1, np.int32)
        pids[:b] = ids
        if kind == "delete":
            args = (jnp.asarray(pids), jnp.asarray(valid))
        else:
            args = (jnp.asarray(pv), jnp.asarray(pids), jnp.asarray(valid))
        return args, ids

    def _maybe_compact(self):
        """Opportunistic dead-space reclamation on the mutation lane (the
        caller holds no lock; passes run under it).  Uses the index's
        rearrange step, whose trigger covers both the paper's insert
        statistic and the mutation subsystem's dead-fraction threshold."""
        fn = self.index._rearrange_fn
        if fn is None:
            return
        for _ in range(max(self.cfg.compact_passes, 0)):
            with self._state_lock:
                self.index.state, triggered = fn(self.index.state)
                self._budget = None  # compaction may shrink chains
            if not bool(triggered):
                break
            self._n_compactions += 1

    def _apply_run(self, items: list[_Timed]):
        """Dispatch one same-kind run as one jitted step; same failure
        discipline as the search path (no future may hang)."""
        kind = items[0].kind
        step = {
            "insert": self._insert_step,
            "delete": self._delete_step,
            "update": self._update_step,
        }[kind]
        try:
            args, ids = self._mutation_args(kind, items)
            with self._state_lock:
                self.index.state = step(self.index.state, *args)
                st = self.index.state
                self._budget = None  # chains may have grown
            jax.block_until_ready(st.cluster_len)
            if kind == "insert":
                self._n_inserts += len(ids)
            elif kind == "delete":
                self._n_deletes += len(ids)
            else:
                self._n_updates += len(ids)
            self._resolve_mutations(items, ids)
            # after the futures resolve: a compaction failure must not fail
            # a mutation that already applied
            if kind != "insert" and self.cfg.auto_compact:
                self._maybe_compact()
        except Exception as e:
            self._fail_futures(items, e)

    def _apply_mutations(self, items: list[_Timed]):
        """Apply a drained (possibly mixed-kind) item list run by run, in
        arrival order."""
        while items:
            take, items = self._split_flush(items)
            self._apply_run(take)

    def _resolve_mutations(self, items: list[_Timed], ids: np.ndarray):
        """Each future gets exactly the ids of its own rows."""
        t = time.perf_counter()
        off = 0
        for it in items:
            n = self._n_rows(it)
            lat = self._insert_lat if it.kind == "insert" else \
                self._mutation_lat
            with self._lat_lock:
                lat.append(t - it.t_arrival)
            it.future.set_result(ids[off : off + n])
            off += n

    def _insert_loop(self):
        if self.cfg.mode == "serial":
            return  # serial mode: the search loop owns mutations too
        while not self._stop.is_set():
            items = self._drain_inserts()
            if not items:
                continue
            if self.cfg.mode == "fused":
                # hand the batch to the search loop for fused dispatch
                self._fused_pending.put(items)
            else:
                self._apply_mutations(items)

    def _collect_search_batch(self) -> list[_Timed]:
        items: list[_Timed] = []
        try:
            items.append(self._search_q.get(timeout=0.005))
        except queue.Empty:
            return items
        while len(items) < self.cfg.max_search_batch:
            try:
                items.append(self._search_q.get_nowait())
            except queue.Empty:
                break
        return items

    def _run_search(self, items: list[_Timed]):
        """Dispatch one search batch.  A mid-step exception (bad payload
        shape, jit failure, ...) must not leak: every batched future is
        resolved — result or exception — and every acquired slot is
        released in the ``finally`` (one slot per item, taken at submit)."""
        try:
            qs = [np.atleast_2d(i.payload) for i in items]
            counts = [len(q) for q in qs]
            batch = np.concatenate(qs, 0)
            pb, valid = self._padded(batch, self._bucket(len(batch)))
            with self._state_lock:
                st = self.index.state
                step = self._search_step_for(self._current_budget())
                d, i = step(st, jnp.asarray(pb), jnp.asarray(valid))
            d, i = np.asarray(d), np.asarray(i)
            t = time.perf_counter()
            off = 0
            for it, c in zip(items, counts):
                with self._lat_lock:
                    self._search_lat.append(t - it.t_arrival)
                it.future.set_result((d[off : off + c], i[off : off + c]))
                off += c
        except Exception as e:
            self._fail_futures(items, e)
        finally:
            for _ in items:
                self._slots.release()

    def _search_loop(self):
        serial_insert_items: list[_Timed] = []
        last_flush = time.perf_counter()
        while not self._stop.is_set():
            if self.cfg.mode == "serial":
                # Fig. 2a: one lane — inserts interleave with (and block)
                # searches on the same execution stream.
                try:
                    it = self._insert_q.get_nowait()
                    serial_insert_items.append(it)
                except queue.Empty:
                    pass
                n_pend = sum(self._n_rows(x) for x in serial_insert_items)
                if serial_insert_items and (
                    n_pend >= self.cfg.flush_min
                    or time.perf_counter() - last_flush > self.cfg.flush_interval
                ):
                    self._apply_mutations(serial_insert_items)
                    serial_insert_items = []
                    last_flush = time.perf_counter()
            items = self._collect_search_batch()
            if self.cfg.mode == "fused":
                try:
                    ins_items = self._fused_pending.get_nowait()
                except queue.Empty:
                    ins_items = None
                if ins_items and items:
                    self._run_fused(items, ins_items)
                    continue
                if ins_items:  # no search to pair with: standalone mutation
                    self._apply_mutations(ins_items)
            if items:
                self._run_search(items)

    def _run_fused(self, s_items: list[_Timed], i_items: list[_Timed]):
        """One fused search+mutation dispatch (the paper's multi-stream
        mode, now covering insert *and* delete/update batches).  The first
        same-kind run pairs with the search batch as ONE jitted program;
        any remaining runs of the drained batch are applied right after, in
        arrival order.  Same leak discipline as ``_run_search``: a mid-step
        exception resolves every search *and* mutation future, and the
        search slots are released in the ``finally``."""
        i_items, rest = self._split_flush(i_items)
        kind = i_items[0].kind
        try:
            qs = [np.atleast_2d(x.payload) for x in s_items]
            counts = [len(q) for q in qs]
            qbatch = np.concatenate(qs, 0)
            m_args, ids = self._mutation_args(kind, i_items)
            pq_, qvalid = self._padded(qbatch, self._bucket(len(qbatch)))
            with self._state_lock:
                fused_step = self._fused_step_for(
                    self._current_budget(), kind
                )
                self.index.state, d, i = fused_step(
                    self.index.state,
                    jnp.asarray(pq_),
                    jnp.asarray(qvalid),
                    *m_args,
                )
                st = self.index.state
                self._budget = None  # chains may have grown or shrunk
            d, i = np.asarray(d), np.asarray(i)
            jax.block_until_ready(st.cluster_len)
            if kind == "insert":
                self._n_inserts += len(ids)
            elif kind == "delete":
                self._n_deletes += len(ids)
            else:
                self._n_updates += len(ids)
            t = time.perf_counter()
            off = 0
            for it, c in zip(s_items, counts):
                with self._lat_lock:
                    self._search_lat.append(t - it.t_arrival)
                it.future.set_result((d[off : off + c], i[off : off + c]))
                off += c
            self._resolve_mutations(i_items, ids)
            if kind != "insert" and self.cfg.auto_compact:
                self._maybe_compact()
        except Exception as e:
            self._fail_futures(s_items, e)
            self._fail_futures(i_items, e)
        finally:
            for _ in s_items:
                self._slots.release()
        if rest:  # later runs / overflow of the drained batch, in order
            self._apply_mutations(rest)
