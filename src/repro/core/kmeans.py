"""K-means coarse quantizer training (``C = Kmeans(X, N)`` in Alg. 1/2).

Lloyd iterations are fully jitted; init is either random-subset or
k-means++ (host loop, used for small N).  Empty clusters are re-seeded from
the globally farthest points, matching Faiss behaviour closely enough for
recall parity experiments.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("n_clusters",))
def _assign(x: jax.Array, centroids: jax.Array, n_clusters: int):
    d = (
        jnp.sum(x * x, 1, keepdims=True)
        - 2.0 * x @ centroids.T
        + jnp.sum(centroids * centroids, 1)[None]
    )
    a = jnp.argmin(d, axis=1)
    return a, jnp.min(d, axis=1)


@partial(jax.jit, static_argnames=("n_clusters",))
def _update(x: jax.Array, assign: jax.Array, centroids: jax.Array, n_clusters: int):
    sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
    cnts = jax.ops.segment_sum(
        jnp.ones((x.shape[0],), x.dtype), assign, num_segments=n_clusters
    )
    new = sums / jnp.maximum(cnts, 1.0)[:, None]
    # keep old centroid where a cluster went empty (re-seeded by caller)
    return jnp.where(cnts[:, None] > 0, new, centroids), cnts


def kmeans(
    x: np.ndarray | jax.Array,
    n_clusters: int,
    *,
    n_iter: int = 20,
    seed: int = 0,
    reseed_empty: bool = True,
) -> np.ndarray:
    """Train centroids. Returns float32 [n_clusters, D] (host array)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    if n < n_clusters:
        raise ValueError(f"need >= {n_clusters} points, got {n}")
    rng = np.random.default_rng(seed)
    centroids = x[jnp.asarray(rng.choice(n, n_clusters, replace=False))]
    for _ in range(n_iter):
        assign, dist = _assign(x, centroids, n_clusters)
        centroids, cnts = _update(x, assign, centroids, n_clusters)
        if reseed_empty:
            empty = np.asarray(cnts == 0).nonzero()[0]
            if empty.size:
                far = np.asarray(jnp.argsort(-dist))[: empty.size]
                centroids = centroids.at[jnp.asarray(empty)].set(x[jnp.asarray(far)])
    return np.asarray(centroids)
