"""Recall and latency metrics used across benchmarks (paper §4)."""

from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Iterable, Optional

import numpy as np


class CounterSet:
    """Named monotonic counters shared across threads.

    The serving runtime's workers, submit paths, and the supervisor all
    bump counters concurrently; bare ``+=`` on instance ints loses
    increments under the GIL's byte-code interleaving (load/add/store is
    three ops).  Every mutation happens under one lock and ``snapshot()``
    returns a consistent point-in-time copy for ``stats()``.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counts: collections.defaultdict = collections.defaultdict(int)

    def inc(self, name: str, n: int = 1) -> int:
        with self._lock:
            self._counts[name] += n
            return self._counts[name]

    def __getitem__(self, name: str) -> int:
        with self._lock:
            return self._counts[name]

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._counts)

    def reset(self) -> None:
        with self._lock:
            self._counts.clear()


def percentile_summary(samples_s: Iterable[float]) -> dict:
    """p50/p95/p99/mean/max milliseconds over seconds-valued samples.

    The one shared percentile computation: ``LatencyStats``, the runtime's
    ``stats()`` output, and the open-loop load generator
    (``benchmarks/loadgen.py``) all report through it, so their numbers can
    never disagree on interpolation or unit conventions."""
    ms = np.asarray(list(samples_s), np.float64) * 1e3
    if ms.size == 0:
        return {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
                "mean_ms": 0.0, "max_ms": 0.0, "n": 0}
    return {
        "p50_ms": float(np.percentile(ms, 50)),
        "p95_ms": float(np.percentile(ms, 95)),
        "p99_ms": float(np.percentile(ms, 99)),
        "mean_ms": float(ms.mean()),
        "max_ms": float(ms.max()),
        "n": int(ms.size),
    }


class ArrivalEstimator:
    """Lock-disciplined EWMA tracker for one serving lane.

    Tracks three signals the adaptive controller (and the degradation
    ladder, which receives the very same queue-age observations — the
    estimator stores the signal, it does not duplicate the ladder's
    hysteresis) needs:

    * **arrival rate** — exponentially-weighted event counting: a weight
      ``W`` decays as ``exp(-dt / tau)`` and each arrival batch adds its
      event count, so ``rate = W / tau`` converges to the true arrival
      rate for steady traffic and decays toward zero in silence.  Reads
      apply the decay since the last arrival, so a stale estimate never
      reports a burst that ended seconds ago.
    * **queue-age watermark** — the age of the oldest item in each
      dispatched batch (how far behind the lane runs), EWMA-smoothed over
      dispatches with the same time constant.
    * **service time** — EWMA seconds per dispatch, the lane's measured
      cost, which turns the arrival rate into a load factor
      (``rho = rate * service / batch``).

    All fields move under one lock; ``observe_*`` accept an explicit
    ``now`` so unit tests are deterministic wall-clock-free.
    """

    def __init__(self, tau_s: float = 0.5):
        if tau_s <= 0:
            raise ValueError(f"tau_s must be positive, got {tau_s}")
        self.tau_s = tau_s
        self._lock = threading.Lock()
        self._weight = 0.0  # guarded-by: _lock (decayed event count)
        self._t_last: Optional[float] = None  # guarded-by: _lock
        self._age = 0.0  # guarded-by: _lock (queue-age watermark EWMA)
        self._service: Optional[float] = None  # guarded-by: _lock
        self._events = 0  # guarded-by: _lock (lifetime arrivals)

    def observe_arrival(self, n: int = 1,
                        now: Optional[float] = None) -> None:
        """Record ``n`` arrivals (rows for the mutation lane, requests for
        the search lane) at ``now``."""
        now = time.perf_counter() if now is None else now
        with self._lock:
            if self._t_last is not None:
                dt = max(0.0, now - self._t_last)
                self._weight *= math.exp(-dt / self.tau_s)
            self._weight += n
            self._t_last = now
            self._events += n

    def observe_queue_age(self, age_s: float,
                          now: Optional[float] = None) -> None:
        """Record one dispatch's queue-age watermark (seconds)."""
        with self._lock:
            # dispatches are already paced by the lane; a plain EWMA over
            # observations keeps the smoothing timing-independent
            self._age += 0.3 * (max(0.0, age_s) - self._age)

    def observe_service(self, service_s: float) -> None:
        """Record one dispatch's measured service seconds."""
        with self._lock:
            if self._service is None:
                self._service = service_s
            else:
                self._service += 0.3 * (service_s - self._service)

    def rate(self, now: Optional[float] = None) -> float:
        """Decayed arrivals/second estimate."""
        now = time.perf_counter() if now is None else now
        with self._lock:
            if self._t_last is None:
                return 0.0
            dt = max(0.0, now - self._t_last)
            return self._weight * math.exp(-dt / self.tau_s) / self.tau_s

    def queue_age(self) -> float:
        with self._lock:
            return self._age

    def service(self, default: float = 0.0) -> float:
        with self._lock:
            return self._service if self._service is not None else default

    def reset(self) -> None:
        """Forget every learned signal (rate, queue age, service EWMA,
        lifetime arrivals).  ``ServingRuntime.reset_stats()`` calls this
        between benchmark phases so one cell's learned load cannot bleed
        into the next cell's controller decisions; the first few
        post-reset dispatches re-learn service (EWMA seeds on the first
        sample)."""
        with self._lock:
            self._weight = 0.0
            self._t_last = None
            self._age = 0.0
            self._service = None
            self._events = 0

    def snapshot(self, now: Optional[float] = None) -> dict:
        """One consistent read of every signal (for ``stats()``)."""
        now = time.perf_counter() if now is None else now
        with self._lock:
            if self._t_last is None:
                rate = 0.0
            else:
                rate = self._weight * math.exp(
                    -max(0.0, now - self._t_last) / self.tau_s
                ) / self.tau_s
            return {
                "rate": rate,
                "queue_age_s": self._age,
                "service_s": self._service or 0.0,
                "events": self._events,
            }


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray, k: int) -> float:
    """Mean |found ∩ true| / k over queries (ids = -1 ignored)."""
    found = np.asarray(found_ids)[:, :k]
    true = np.asarray(true_ids)[:, :k]
    hits = 0
    for f, t in zip(found, true):
        hits += len(set(int(i) for i in f if i >= 0) & set(int(i) for i in t))
    return hits / (found.shape[0] * k)


@dataclasses.dataclass
class LatencyStats:
    mean_ms: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    max_ms: float
    n: int
    timeouts: int = 0

    @classmethod
    def from_samples(cls, samples_s: Iterable[float], timeout_ms: float = None):
        samples = list(samples_s)
        p = percentile_summary(samples)
        ms = np.asarray(samples, np.float64) * 1e3
        timeouts = int((ms > timeout_ms).sum()) if timeout_ms and ms.size \
            else 0
        return cls(
            mean_ms=p["mean_ms"], p50_ms=p["p50_ms"], p95_ms=p["p95_ms"],
            p99_ms=p["p99_ms"], max_ms=p["max_ms"], n=p["n"],
            timeouts=timeouts,
        )

    def as_dict(self) -> dict:
        """JSON-ready percentile summary (same keys as
        ``percentile_summary``) — benchmarks and the ops runbook consume
        this instead of post-processing raw latency windows by hand."""
        return {
            "p50_ms": self.p50_ms, "p95_ms": self.p95_ms,
            "p99_ms": self.p99_ms, "mean_ms": self.mean_ms,
            "max_ms": self.max_ms, "n": self.n, "timeouts": self.timeouts,
        }

    def row(self) -> str:
        return (
            f"mean={self.mean_ms:7.2f}ms p50={self.p50_ms:7.2f} "
            f"p95={self.p95_ms:7.2f} p99={self.p99_ms:7.2f} "
            f"max={self.max_ms:7.2f} n={self.n} timeouts={self.timeouts}"
        )


class Timer:
    """Context timer returning seconds."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
