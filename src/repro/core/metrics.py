"""Recall and latency metrics used across benchmarks (paper §4)."""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable

import numpy as np


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray, k: int) -> float:
    """Mean |found ∩ true| / k over queries (ids = -1 ignored)."""
    found = np.asarray(found_ids)[:, :k]
    true = np.asarray(true_ids)[:, :k]
    hits = 0
    for f, t in zip(found, true):
        hits += len(set(int(i) for i in f if i >= 0) & set(int(i) for i in t))
    return hits / (found.shape[0] * k)


@dataclasses.dataclass
class LatencyStats:
    mean_ms: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    max_ms: float
    n: int
    timeouts: int = 0

    @classmethod
    def from_samples(cls, samples_s: Iterable[float], timeout_ms: float = None):
        ms = np.asarray(list(samples_s), np.float64) * 1e3
        if ms.size == 0:
            return cls(0.0, 0.0, 0.0, 0.0, 0.0, 0)
        timeouts = int((ms > timeout_ms).sum()) if timeout_ms else 0
        return cls(
            mean_ms=float(ms.mean()),
            p50_ms=float(np.percentile(ms, 50)),
            p95_ms=float(np.percentile(ms, 95)),
            p99_ms=float(np.percentile(ms, 99)),
            max_ms=float(ms.max()),
            n=int(ms.size),
            timeouts=timeouts,
        )

    def row(self) -> str:
        return (
            f"mean={self.mean_ms:7.2f}ms p50={self.p50_ms:7.2f} "
            f"p95={self.p95_ms:7.2f} p99={self.p99_ms:7.2f} "
            f"max={self.max_ms:7.2f} n={self.n} timeouts={self.timeouts}"
        )


class Timer:
    """Context timer returning seconds."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
