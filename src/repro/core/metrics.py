"""Recall and latency metrics used across benchmarks (paper §4)."""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Iterable

import numpy as np


class CounterSet:
    """Named monotonic counters shared across threads.

    The serving runtime's workers, submit paths, and the supervisor all
    bump counters concurrently; bare ``+=`` on instance ints loses
    increments under the GIL's byte-code interleaving (load/add/store is
    three ops).  Every mutation happens under one lock and ``snapshot()``
    returns a consistent point-in-time copy for ``stats()``.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counts: collections.defaultdict = collections.defaultdict(int)

    def inc(self, name: str, n: int = 1) -> int:
        with self._lock:
            self._counts[name] += n
            return self._counts[name]

    def __getitem__(self, name: str) -> int:
        with self._lock:
            return self._counts[name]

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._counts)

    def reset(self) -> None:
        with self._lock:
            self._counts.clear()


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray, k: int) -> float:
    """Mean |found ∩ true| / k over queries (ids = -1 ignored)."""
    found = np.asarray(found_ids)[:, :k]
    true = np.asarray(true_ids)[:, :k]
    hits = 0
    for f, t in zip(found, true):
        hits += len(set(int(i) for i in f if i >= 0) & set(int(i) for i in t))
    return hits / (found.shape[0] * k)


@dataclasses.dataclass
class LatencyStats:
    mean_ms: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    max_ms: float
    n: int
    timeouts: int = 0

    @classmethod
    def from_samples(cls, samples_s: Iterable[float], timeout_ms: float = None):
        ms = np.asarray(list(samples_s), np.float64) * 1e3
        if ms.size == 0:
            return cls(0.0, 0.0, 0.0, 0.0, 0.0, 0)
        timeouts = int((ms > timeout_ms).sum()) if timeout_ms else 0
        return cls(
            mean_ms=float(ms.mean()),
            p50_ms=float(np.percentile(ms, 50)),
            p95_ms=float(np.percentile(ms, 95)),
            p99_ms=float(np.percentile(ms, 99)),
            max_ms=float(ms.max()),
            n=int(ms.size),
            timeouts=timeouts,
        )

    def row(self) -> str:
        return (
            f"mean={self.mean_ms:7.2f}ms p50={self.p50_ms:7.2f} "
            f"p95={self.p95_ms:7.2f} p99={self.p99_ms:7.2f} "
            f"max={self.max_ms:7.2f} n={self.n} timeouts={self.timeouts}"
        )


class Timer:
    """Context timer returning seconds."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
