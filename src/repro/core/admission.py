"""Admission control, deadlines, and graceful degradation for serving.

The paper's resource pool rejects searches when its 32 slots are busy;
this module makes the *mutation* lane symmetrical (a bounded pending-row
budget with reject / block-with-deadline overflow policies) and adds the
two mechanisms real-time systems use to survive sustained overload:

* **Load shedding** — each request may carry a deadline; the workers shed
  expired requests from the queue with :class:`DeadlineExceeded` instead of
  dispatching them late (serving a dead request steals capacity from live
  ones — the classic overload death spiral).
* **A degradation ladder** — under a sustained queue-age watermark the
  runtime steps down a configurable ladder of cheaper service levels
  (skip the exact re-rank → halve ``nprobe`` → halve the chain budget),
  and steps back up when pressure clears.  Rungs only vary per-call
  kwargs of the already-resolved search impl, so each (budget, rung)
  combination compiles at most once — degradation never recompiles per
  request (FusionANNS bounds worst-case work per request the same way;
  see PAPERS.md).
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np


class RequestRejected(RuntimeError):
    """All resource-pool slots busy (paper: reject at 32 exhausted)."""


class QueueFull(RequestRejected):
    """Mutation admission: pending-row budget exhausted (and, in ``block``
    mode, not freed within the admission timeout)."""


class DeadlineExceeded(TimeoutError):
    """The request expired in queue and was shed instead of dispatched."""


class RuntimeShutdown(RuntimeError):
    """The runtime stopped (or its worker lane died) before this request
    could be dispatched; submitted after ``stop()`` or failed during the
    shutdown drain."""


# --------------------------------------------------------------- gate ----
class AdmissionGate:
    """Bounded pending-row budget for the mutation lane.

    ``acquire(rows)`` runs in the *caller's* thread at submit time;
    ``release(rows)`` runs when the rows leave the system (applied, failed,
    shed, or drained at shutdown).  ``max_pending=None`` disables the bound
    (the seed behaviour).  Policies on overflow:

    * ``"reject"`` — raise :class:`QueueFull` immediately (mirror of the
      search lane's slot rejection);
    * ``"block"`` — wait up to ``timeout`` seconds for capacity, then
      raise :class:`QueueFull` (backpressure with a bounded stall, never
      an unbounded one).

    A single oversized request (``rows > max_pending``) is admitted alone
    when the gate is empty — the same never-split-an-item discipline the
    batcher uses — rather than deadlocking on a budget it can never fit.
    """

    def __init__(self, max_pending: Optional[int], policy: str = "reject",
                 timeout: float = 1.0):
        if policy not in ("reject", "block"):
            raise ValueError(f"admission policy {policy!r} not in "
                             "('reject', 'block')")
        self.max_pending = max_pending
        self.policy = policy
        self.timeout = timeout
        self._pending = 0  # guarded-by: _cond
        self._peak = 0  # guarded-by: _cond (high-watermark since last read)
        self._cond = threading.Condition()

    def _fits(self, rows: int) -> bool:  # holds: _cond
        if self.max_pending is None:
            return True
        if rows > self.max_pending:
            return self._pending == 0  # oversized: admit alone
        return self._pending + rows <= self.max_pending

    def acquire(self, rows: int) -> None:
        with self._cond:
            if self._fits(rows):
                self._pending += rows
                self._peak = max(self._peak, self._pending)
                return
            if self.policy == "reject":
                raise QueueFull(
                    f"mutation queue full: {self._pending} pending rows, "
                    f"{rows} requested, cap {self.max_pending}"
                )
            deadline = time.perf_counter() + self.timeout
            while not self._fits(rows):
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or not self._cond.wait(remaining):
                    if not self._fits(rows):
                        raise QueueFull(
                            f"mutation queue full after {self.timeout:.3f}s "
                            f"wait: {self._pending} pending rows, "
                            f"{rows} requested, cap {self.max_pending}"
                        )
                    break
            self._pending += rows
            self._peak = max(self._peak, self._pending)

    def release(self, rows: int) -> None:
        with self._cond:
            self._pending = max(0, self._pending - rows)
            self._cond.notify_all()

    def pending(self) -> int:
        with self._cond:
            return self._pending

    def set_max_pending(self, max_pending: Optional[int]) -> None:
        """Resize the budget online (the dynamic resource pool's lever on
        the mutation lane).  Growing wakes blocked acquirers; shrinking
        never revokes admitted rows — the bound tightens as they drain."""
        with self._cond:
            self.max_pending = max_pending
            self._cond.notify_all()

    def utilization(self) -> float:
        """Pending rows as a fraction of the budget (0 when unbounded)."""
        with self._cond:
            if not self.max_pending:
                return 0.0
            return min(1.0, self._pending / self.max_pending)

    def take_peak_utilization(self) -> float:
        """High-watermark utilization since the previous call, then re-arm
        to the current level.  An instantaneous read sampled between
        dispatches is biased toward empty (the sampler runs exactly when
        the lane just drained); the rebalancer needs "how full did this
        lane *get*" over its interval, not "is it full right now"."""
        with self._cond:
            peak, self._peak = self._peak, self._pending
            if not self.max_pending:
                return 0.0
            return min(1.0, peak / self.max_pending)

    def reset_peak(self) -> None:
        """Re-arm the high-watermark to the current pending level without
        consuming it (``reset_stats`` between benchmark phases — the next
        rebalance reads this phase's pressure, not a stale burst's)."""
        with self._cond:
            self._peak = self._pending


# --------------------------------------------------------------- pool ----
class DynamicResourcePool:
    """Apportions admission capacity between the search and mutation lanes
    from measured utilization, with hysteresis.

    The runtime's two admission bounds — ``n_slots`` search permits and
    the mutation gate's pending-row budget — are fixed at construction in
    the static runtime.  The pool treats them as shares of one capacity:
    ``total`` abstract slots, each worth one search permit on the search
    side and ``rows_per_slot`` pending rows on the mutation side.
    ``rebalance(util_search, util_mutation)`` moves **at most one slot per
    call**, and only after ``patience`` consecutive calls agreed that the
    utilization imbalance exceeds ``deadband`` — two mechanisms that
    together make oscillation impossible under a square-wave load whose
    half-period is shorter than ``patience`` rebalance intervals (the
    direction counter resets every time the sign flips).

    Floors (``min_search``, ``min_mutation``) guarantee neither lane is
    ever starved to zero regardless of how lopsided the load runs.
    """

    def __init__(self, total: int, min_search: int = 1,
                 min_mutation: int = 1, rows_per_slot: int = 32,
                 deadband: float = 0.2, patience: int = 3,
                 initial_search: Optional[int] = None):
        if total < min_search + min_mutation:
            raise ValueError(
                f"total {total} below min_search {min_search} + "
                f"min_mutation {min_mutation}"
            )
        if rows_per_slot < 1:
            raise ValueError(f"rows_per_slot must be >= 1, got {rows_per_slot}")
        self.total = total
        self.min_search = min_search
        self.min_mutation = min_mutation
        self.rows_per_slot = rows_per_slot
        self.deadband = deadband
        self.patience = max(1, patience)
        self._lock = threading.Lock()
        if initial_search is None:
            initial_search = total - min_mutation * 2
        # guarded-by: _lock
        self._search = min(
            max(min_search, initial_search), total - min_mutation
        )
        self._streak = 0  # guarded-by: _lock (+ toward search, - away)
        self._moves = 0  # guarded-by: _lock (slot reassignments, both ways)

    @property
    def search_slots(self) -> int:
        with self._lock:
            return self._search

    @property
    def mutation_rows(self) -> int:
        with self._lock:
            return (self.total - self._search) * self.rows_per_slot

    @property
    def moves(self) -> int:
        with self._lock:
            return self._moves

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "search_slots": self._search,
                "mutation_slots": self.total - self._search,
                "mutation_rows": (self.total - self._search)
                * self.rows_per_slot,
                "moves": self._moves,
            }

    def rebalance(self, util_search: float,
                  util_mutation: float) -> tuple[int, int]:
        """Feed one pair of lane utilizations (0..1); returns the current
        ``(search_slots, mutation_rows)`` apportionment after at most one
        hysteresis-gated slot move."""
        with self._lock:
            gap = util_search - util_mutation
            if gap > self.deadband:
                self._streak = self._streak + 1 if self._streak >= 0 else 1
            elif gap < -self.deadband:
                self._streak = self._streak - 1 if self._streak <= 0 else -1
            else:
                self._streak = 0
            if self._streak >= self.patience and \
                    self.total - self._search > self.min_mutation:
                self._search += 1
                self._moves += 1
                self._streak = 0
            elif self._streak <= -self.patience and \
                    self._search > self.min_search:
                self._search -= 1
                self._moves += 1
                self._streak = 0
            return (
                self._search,
                (self.total - self._search) * self.rows_per_slot,
            )


# ------------------------------------------------------------- ladder ----
#: Rung names -> what each takes away, applied *cumulatively* down the
#: ladder (level 2 of ("no_rerank", "half_nprobe") skips rerank AND halves
#: nprobe).  Halvings are per-level-occurrence: listing "half_nprobe"
#: twice quarters it at the bottom rung.
LADDER_RUNGS = ("no_rerank", "half_nprobe", "half_budget")


class DegradationLadder:
    """Hysteresis controller stepping service quality down under load.

    The pressure signal is the queue-age watermark: the age of the oldest
    request in the batch being dispatched (a direct read of how far behind
    the lane is running, unlike queue depth, which conflates batch sizing
    with overload).  ``observe(age)`` is called once per dispatch by the
    search worker; ``patience`` consecutive observations above ``high_s``
    step one rung down, ``patience`` below ``low_s`` step one rung up.
    ``apply(...)`` maps the current level onto effective per-call search
    parameters.  An empty ladder never leaves level 0 (full service).
    """

    def __init__(self, rungs: Sequence[str] = (), high_s: float = 0.05,
                 low_s: float = 0.01, patience: int = 3,
                 on_transition: Optional[Callable] = None):
        unknown = set(rungs) - set(LADDER_RUNGS)
        if unknown:
            raise ValueError(
                f"unknown degradation rungs {sorted(unknown)}; "
                f"known: {LADDER_RUNGS}"
            )
        if low_s > high_s:
            raise ValueError(f"low_s {low_s} > high_s {high_s}")
        self.rungs: tuple = ("full",) + tuple(rungs)
        self.high_s = high_s
        self.low_s = low_s
        self.patience = max(1, patience)
        self._lock = threading.Lock()
        self._level = 0  # guarded-by: _lock
        # _hot/_cool: consecutive observations above high_s / below low_s
        self._hot = 0  # guarded-by: _lock
        self._cool = 0  # guarded-by: _lock
        # rung changes (both directions)
        self.transitions = 0  # guarded-by: _lock
        # called AFTER the ladder lock drops on every rung change, with
        # (level, rung, direction) — the runtime points this at its
        # flight recorder (repro.obs.events); must not call back into
        # the ladder
        self._on_transition = on_transition

    @property
    def level(self) -> int:
        with self._lock:
            return self._level

    @property
    def rung(self) -> str:
        with self._lock:
            return self.rungs[self._level]

    def snapshot(self) -> dict:
        """One consistent ``{level, rung, transitions}`` read — three
        separate property reads can interleave with a transition and
        report a level that never co-existed with its rung."""
        with self._lock:
            return {
                "level": self._level,
                "rung": self.rungs[self._level],
                "transitions": self.transitions,
            }

    def observe(self, queue_age_s: float) -> int:
        """Feed one dispatch's queue-age watermark; returns the level to
        serve this dispatch at."""
        direction = None
        with self._lock:
            if len(self.rungs) == 1:
                return 0
            if queue_age_s > self.high_s:
                self._hot += 1
                self._cool = 0
                if self._hot >= self.patience and \
                        self._level < len(self.rungs) - 1:
                    self._level += 1
                    self._hot = 0
                    self.transitions += 1
                    direction = "down"
            elif queue_age_s < self.low_s:
                self._cool += 1
                self._hot = 0
                if self._cool >= self.patience and self._level > 0:
                    self._level -= 1
                    self._cool = 0
                    self.transitions += 1
                    direction = "up"
            else:
                self._hot = 0
                self._cool = 0
            level = self._level
            rung = self.rungs[level]
        if direction is not None and self._on_transition is not None:
            self._on_transition(level, rung, direction)
        return level

    def apply(self, nprobe: int, rerank: bool, budget: int,
              level: Optional[int] = None) -> tuple[int, bool, int]:
        """Effective ``(nprobe, rerank, budget)`` at ``level`` (default:
        the current level).  Halved values stay powers of two when their
        inputs are, so the jit caches stay pow2-bucketed under degradation."""
        if level is None:
            level = self.level
        for rung in self.rungs[1 : level + 1]:
            if rung == "no_rerank":
                rerank = False
            elif rung == "half_nprobe":
                nprobe = max(1, nprobe // 2)
            elif rung == "half_budget":
                budget = max(1, budget // 2)
        return nprobe, rerank, budget


# --------------------------------------------------------- validation ----
def validate_vectors(x, dim: int, name: str = "vectors") -> np.ndarray:
    """Fail-fast payload validation, run in the *caller's* thread at
    ``submit_*`` time: a malformed request must never reach a worker batch,
    where its exception would fail every co-batched future (or, pre-PR-3,
    hang them).  Returns the validated ``[N, dim]`` float32 array."""
    x = np.asarray(x)
    if x.dtype == object or x.dtype.kind not in "fiu":
        raise ValueError(
            f"{name}: dtype {x.dtype} is not numeric (want floating)"
        )
    x = np.atleast_2d(np.asarray(x, np.float32))
    if x.ndim != 2:
        raise ValueError(f"{name}: expected [N, {dim}], got shape {x.shape}")
    if x.shape[0] == 0:
        raise ValueError(f"{name}: empty batch")
    if x.shape[1] != dim:
        raise ValueError(
            f"{name}: dim {x.shape[1]} does not match index dim {dim}"
        )
    if not np.isfinite(x).all():
        bad = int((~np.isfinite(x)).sum())
        raise ValueError(f"{name}: {bad} non-finite value(s)")
    return x


def validate_ids(ids, name: str = "ids") -> np.ndarray:
    """Ids must be a non-empty integral batch (int32-exact)."""
    ids = np.atleast_1d(np.asarray(ids))
    if ids.dtype == object or ids.dtype.kind not in "iu":
        raise ValueError(f"{name}: dtype {ids.dtype} is not integral")
    if ids.ndim != 1:
        raise ValueError(f"{name}: expected [N], got shape {ids.shape}")
    if ids.shape[0] == 0:
        raise ValueError(f"{name}: empty batch")
    out = ids.astype(np.int32)
    if (out != ids).any():
        raise ValueError(f"{name}: values overflow int32")
    return out
