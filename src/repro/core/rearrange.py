"""In-place rearrangement of fragmented block chains (paper Alg. 3, Fig. 1c).

The paper merges split memory blocks through a temporary segment so a chain's
vectors become contiguous, eliminating header jumps.  Our functional
equivalent compacts one cluster's chain into a *physically contiguous* run of
freshly bump-allocated blocks (gather chain -> temp segment -> dense write),
then returns the old blocks to the free stack.  Semantics match the paper's
goal — after rearrangement a scan reads sequential memory instead of chasing
scattered blocks — and the cost/benefit is measured in
``benchmarks/table1_rearrangement.py`` (paper Table 1).

Notes vs the paper:
* Our insertion keeps every mid-chain block full (the per-cluster counter is
  global), so the "merge two half-filled blocks" case of Alg. 3 cannot arise;
  what remains — and what we compact — is physical scatter of the chain.
  The recursive lazy-merge branch (Alg. 3 lines 3-6, 13-15) therefore
  degenerates and is handled by the same dense rewrite.
* The temp segment is real: the gather materialises the chain before any
  write, so a preempted step never observes a half-moved chain (the donated
  state is replaced atomically at step boundaries).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.block_pool import NULL, IVFState, PoolConfig


def exceed(state: IVFState, threshold: int) -> jax.Array:
    """Eq. 3: clusters whose newly-inserted volume passed the threshold."""
    return state.new_since_rearrange > threshold


def rearrange_cluster(
    cfg: PoolConfig, state: IVFState, cluster: jax.Array
) -> IVFState:
    """Compact one cluster's chain into contiguous fresh blocks.

    ``cluster`` is a traced scalar; the op is a no-op (identity scatters) for
    empty chains, so callers may pass any cluster id unconditionally.
    """
    mc, tm = cfg.max_chain, cfg.block_size
    nblk = state.cluster_nblocks[cluster]  # scalar
    table = state.cluster_blocks[cluster]  # [max_chain]
    chain_valid = jnp.arange(mc) < nblk

    # ---- temp segment: gather the whole chain (paper line 7-9) ----------
    safe = jnp.where(chain_valid, table, 0)
    tmp_payload = state.pool_payload[safe]  # [mc, T, ...]
    tmp_ids = state.pool_ids[safe]  # [mc, T]
    if cfg.has_scales:  # int8 dequant scales travel with their rows
        tmp_scales = state.pool_scales[safe]  # [mc, T]

    # ---- allocate a contiguous run of nblk fresh blocks ------------------
    # Bump-only (NOT via the free stack): the whole point of rearrangement
    # is physical contiguity, so the run must be sequential block ids.
    # The old blocks are recycled onto the free stack for future *inserts*,
    # which don't care about contiguity.
    j = jnp.arange(mc, dtype=jnp.int32)
    new_blocks = jnp.where(chain_valid, state.cur_p + j, NULL)  # [mc]
    rows = jnp.where(chain_valid, new_blocks, cfg.n_blocks)

    # dense rewrite (the "merge" of Alg. 3 lines 9-11)
    pool_payload = state.pool_payload.at[rows].set(tmp_payload, mode="drop")
    pool_ids = state.pool_ids.at[rows].set(tmp_ids, mode="drop")
    pool_scales = state.pool_scales
    if cfg.has_scales:
        pool_scales = pool_scales.at[rows].set(tmp_scales, mode="drop")

    # ---- header/table updates (paper line 11) ----------------------------
    nxt = jnp.where(
        jnp.arange(mc) + 1 < nblk,
        jnp.roll(new_blocks, -1),
        NULL,
    )
    next_block = state.next_block.at[rows].set(nxt, mode="drop")
    cluster_blocks = state.cluster_blocks.at[cluster].set(
        jnp.where(chain_valid, new_blocks, NULL)
    )
    head = jnp.where(nblk > 0, new_blocks[0], NULL)
    last = jnp.where(nblk > 0, new_blocks[jnp.maximum(nblk - 1, 0)], NULL)
    cluster_head = state.cluster_head.at[cluster].set(head)
    cluster_tail = state.cluster_tail.at[cluster].set(last)

    # ---- free the old blocks (wait-for-spare analogue, line 12) ---------
    # Old chain blocks go to the free stack; their headers are cleared.
    n_alloc = nblk
    free_top = state.free_top
    free_pos = jnp.where(chain_valid, free_top + j, cfg.n_blocks)
    free_stack = state.free_stack.at[free_pos].set(
        jnp.where(chain_valid, table, NULL), mode="drop"
    )
    # clear freed block slots so stale ids never leak into future scans
    old_rows = jnp.where(chain_valid, table, cfg.n_blocks)
    pool_ids = pool_ids.at[old_rows].set(NULL, mode="drop")
    next_block = next_block.at[old_rows].set(NULL, mode="drop")
    # ownership moves with the chain: the fresh run belongs to this cluster,
    # the recycled blocks belong to nobody (a stale owner would let the
    # in-kernel membership test admit a freed block)
    block_owner = state.block_owner.at[rows].set(
        jnp.where(chain_valid, cluster, NULL), mode="drop"
    )
    block_owner = block_owner.at[old_rows].set(NULL, mode="drop")

    return dataclasses.replace(
        state,
        pool_payload=pool_payload,
        pool_ids=pool_ids,
        pool_scales=pool_scales,
        block_owner=block_owner,
        next_block=next_block,
        cluster_head=cluster_head,
        cluster_tail=cluster_tail,
        cluster_blocks=cluster_blocks,
        new_since_rearrange=state.new_since_rearrange.at[cluster].set(0),
        free_stack=free_stack,
        free_top=free_top + n_alloc,
        cur_p=state.cur_p + n_alloc,
    )


def make_rearrange_fn(cfg: PoolConfig, threshold: int):
    """Jitted maintenance step: compact the single worst offender (if any).

    The paper runs rearrangement as a single-thread GPU pass over chains
    (Alg. 2 lines 23-28); we compact the cluster with the largest
    ``new_since_rearrange`` exceeding the threshold — callers loop while
    ``triggered`` (mirrors the one-block-at-a-time deployment note in §3.3).
    """

    @jax.jit
    def step(state: IVFState):
        # compaction bump-allocates a contiguous run (it cannot use the free
        # stack); clusters whose run no longer fits the bump region are
        # masked out of the offender argmax — running off the pool would
        # record out-of-range block ids (the silent-recall failure mode of
        # an unchecked alloc_blocks), while gating the whole step on the
        # single worst offender would stall maintenance for every smaller
        # cluster that still fits
        fits = state.cur_p + state.cluster_nblocks <= cfg.n_blocks
        stat = jnp.where(fits, state.new_since_rearrange, -1)
        worst = jnp.argmax(stat).astype(jnp.int32)
        triggered = stat[worst] > threshold
        new_state = rearrange_cluster(cfg, state, worst)
        out = jax.tree.map(
            lambda a, b: jnp.where(triggered, a, b), new_state, state
        )
        return out, triggered

    return step
