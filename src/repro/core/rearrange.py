"""In-place rearrangement of fragmented block chains (paper Alg. 3, Fig. 1c)
— now also the *reclamation path* of the mutation subsystem.

The paper merges split memory blocks through a temporary segment so a chain's
vectors become contiguous, eliminating header jumps.  Our functional
equivalent compacts one cluster's chain into a *physically contiguous* run of
freshly bump-allocated blocks (gather chain -> temp segment -> dense write),
then returns the old blocks to the free stack.  Semantics match the paper's
goal — after rearrangement a scan reads sequential memory instead of chasing
scattered blocks — and the cost/benefit is measured in
``benchmarks/table1_rearrangement.py`` (paper Table 1).

With tombstone deletes (``core.mutate``) compaction does double duty: the
temp-segment gather *drops dead rows*, so the fresh run holds only the live
population — ``cluster_len`` shrinks back to the live count, the cluster's
``dead_count`` resets, surplus (including fully-dead) blocks go to the free
stack, and the ``id_map`` is re-pointed at every row's new location.  Two
triggers feed the maintenance loop: the paper's Exceed() insert statistic
(Eq. 3) and a per-cluster dead-fraction threshold (reclamation pressure).

Notes vs the paper:
* Our insertion keeps every mid-chain block full (the per-cluster counter is
  global), so the "merge two half-filled blocks" case of Alg. 3 cannot arise
  from inserts; deletions re-introduce exactly that fragmentation as
  tombstoned slots, and the same dense rewrite handles both.
* The temp segment is real: the gather materialises the chain before any
  write, so a preempted step never observes a half-moved chain (the donated
  state is replaced atomically at step boundaries).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.block_pool import NULL, IVFState, PoolConfig


def exceed(state: IVFState, threshold: int) -> jax.Array:
    """Eq. 3: clusters whose newly-inserted volume passed the threshold."""
    return state.new_since_rearrange > threshold


def rearrange_cluster(
    cfg: PoolConfig, state: IVFState, cluster: jax.Array
) -> IVFState:
    """Compact one cluster's chain into contiguous fresh blocks, dropping
    tombstoned rows.

    ``cluster`` is a traced scalar; the op is a no-op (identity scatters) for
    empty chains, so callers may pass any cluster id unconditionally.
    """
    mc, tm = cfg.max_chain, cfg.block_size
    nblk = state.cluster_nblocks[cluster]  # scalar
    table = state.cluster_blocks[cluster]  # [max_chain]
    chain_valid = jnp.arange(mc) < nblk

    # ---- temp segment: gather the whole chain (paper line 7-9) ----------
    safe = jnp.where(chain_valid, table, 0)
    tmp_payload = state.pool_payload[safe]  # [mc, T, ...]
    tmp_ids = state.pool_ids[safe]  # [mc, T]
    tmp_live = jnp.where(
        chain_valid[:, None], state.pool_live[safe] != 0, False
    )  # [mc, T] bool
    if cfg.has_scales:  # int8 dequant scales travel with their rows
        tmp_scales = state.pool_scales[safe]  # [mc, T]

    # ---- drop dead rows: stable partition, live rows first in chain order
    # (dids stay dense, so the slot arithmetic of future inserts holds)
    flat_live = tmp_live.reshape(-1)  # [mc*T]
    ordr = jnp.argsort(~flat_live, stable=True)
    n_live = flat_live.sum().astype(jnp.int32)
    comp_ids = tmp_ids.reshape(-1)[ordr]
    comp_payload = tmp_payload.reshape(mc * tm, -1)[ordr]
    if cfg.has_scales:
        comp_scales = tmp_scales.reshape(-1)[ordr]
    new_nblk = (n_live + tm - 1) // tm

    # ---- allocate a run of new_nblk fresh blocks ------------------------
    # Bump-allocated (contiguous — the whole point of rearrangement) while
    # the bump region fits the run; once ``cur_p`` approaches the pool end
    # the run comes off the free stack instead.  The bump pointer is
    # monotone, so without the fallback reclamation would shut off
    # permanently after a bounded number of lifetime compactions — dead
    # space matters more than contiguity at that point, and a free-stack
    # run is just the ordinary scattered-chain state every scan already
    # handles.  Precondition (enforced by make_rearrange_fn's fits mask):
    # bump fits nblk, or free_top >= nblk.  Old blocks are recycled onto
    # the free stack either way; dropping tombstones means the fresh run
    # can be shorter than the old chain — a fully-dead chain allocates
    # nothing and every old block is reclaimed.
    j = jnp.arange(mc, dtype=jnp.int32)
    blk_valid = j < new_nblk
    bump_ok = state.cur_p + nblk <= cfg.n_blocks
    free_idx = jnp.clip(state.free_top - 1 - j, 0, cfg.n_blocks - 1)
    alloc = jnp.where(
        bump_ok, state.cur_p + j, state.free_stack[free_idx]
    )  # [mc] block id of run slot j (garbage past new_nblk, masked below)
    new_blocks = jnp.where(blk_valid, alloc, NULL)  # [mc]
    rows = jnp.where(blk_valid, new_blocks, cfg.n_blocks)

    # dense rewrite (the "merge" of Alg. 3 lines 9-11): row r of the
    # compacted run lands in fresh block r // T at offset r % T; the tail
    # of the last block (r in [n_live, new_nblk*T)) is stamped empty
    r = jnp.arange(mc * tm, dtype=jnp.int32)
    in_run = r < n_live
    tgt_ok = r < new_nblk * tm
    row_r = jnp.where(tgt_ok, alloc[r // tm], cfg.n_blocks)
    off_r = r % tm
    pool_payload = state.pool_payload
    flat_shape = (mc * tm,) + state.pool_payload.shape[2:]
    pool_payload = pool_payload.at[row_r, off_r].set(
        comp_payload.reshape(flat_shape), mode="drop"
    )
    pool_ids = state.pool_ids.at[row_r, off_r].set(
        jnp.where(in_run, comp_ids, NULL), mode="drop"
    )
    pool_live = state.pool_live.at[row_r, off_r].set(
        jnp.where(in_run, 1, 0).astype(jnp.uint8), mode="drop"
    )
    pool_scales = state.pool_scales
    if cfg.has_scales:
        pool_scales = pool_scales.at[row_r, off_r].set(
            comp_scales, mode="drop"
        )
    # moved rows re-point their id-map entries at the fresh location
    # (tombstones were already unmapped at delete time, and the stable
    # partition keeps only live rows inside [0, n_live))
    max_ids = state.id_map.shape[0]
    new_loc = row_r * tm + off_r
    map_ok = in_run & (comp_ids >= 0) & (comp_ids < max_ids)
    id_map = state.id_map.at[jnp.where(map_ok, comp_ids, max_ids)].set(
        new_loc.astype(jnp.int32), mode="drop"
    )

    # ---- header/table updates (paper line 11) ----------------------------
    nxt = jnp.where(
        jnp.arange(mc) + 1 < new_nblk,
        jnp.roll(new_blocks, -1),
        NULL,
    )
    next_block = state.next_block.at[rows].set(nxt, mode="drop")
    cluster_blocks = state.cluster_blocks.at[cluster].set(
        jnp.where(blk_valid, new_blocks, NULL)
    )
    head = jnp.where(new_nblk > 0, new_blocks[0], NULL)
    last = jnp.where(
        new_nblk > 0, new_blocks[jnp.maximum(new_nblk - 1, 0)], NULL
    )
    cluster_head = state.cluster_head.at[cluster].set(head)
    cluster_tail = state.cluster_tail.at[cluster].set(last)

    # ---- free the old blocks (wait-for-spare analogue, line 12) ---------
    # Every old chain block goes to the free stack (the fresh run replaced
    # them all); their headers, owners, ids, and live bits are cleared so
    # stale state never leaks into future scans.  A free-stack-allocated
    # run first pops its new_nblk blocks off the top; the nblk pushes
    # (nblk >= new_nblk) overwrite every popped position, so no stale
    # entry survives inside the new [0, free_top) window.
    n_from_free = jnp.where(bump_ok, 0, new_nblk)
    free_top = state.free_top - n_from_free
    free_pos = jnp.where(chain_valid, free_top + j, cfg.n_blocks)
    free_stack = state.free_stack.at[free_pos].set(
        jnp.where(chain_valid, table, NULL), mode="drop"
    )
    old_rows = jnp.where(chain_valid, table, cfg.n_blocks)
    pool_ids = pool_ids.at[old_rows].set(NULL, mode="drop")
    pool_live = pool_live.at[old_rows].set(jnp.uint8(0), mode="drop")
    next_block = next_block.at[old_rows].set(NULL, mode="drop")
    # ownership moves with the chain: the fresh run belongs to this cluster,
    # the recycled blocks belong to nobody (a stale owner would let the
    # in-kernel membership test admit a freed block)
    block_owner = state.block_owner.at[rows].set(
        jnp.where(blk_valid, cluster, NULL), mode="drop"
    )
    block_owner = block_owner.at[old_rows].set(NULL, mode="drop")

    return dataclasses.replace(
        state,
        pool_payload=pool_payload,
        pool_ids=pool_ids,
        pool_scales=pool_scales,
        pool_live=pool_live,
        id_map=id_map,
        block_owner=block_owner,
        next_block=next_block,
        cluster_head=cluster_head,
        cluster_tail=cluster_tail,
        cluster_blocks=cluster_blocks,
        cluster_nblocks=state.cluster_nblocks.at[cluster].set(new_nblk),
        cluster_len=state.cluster_len.at[cluster].set(n_live),
        dead_count=state.dead_count.at[cluster].set(0),
        new_since_rearrange=state.new_since_rearrange.at[cluster].set(0),
        free_stack=free_stack,
        free_top=free_top + nblk,
        cur_p=state.cur_p + jnp.where(bump_ok, new_nblk, 0),
    )


def make_rearrange_fn(
    cfg: PoolConfig, threshold: int, dead_frac: float = 0.3
):
    """Jitted maintenance step: compact the single worst offender (if any).

    The paper runs rearrangement as a single-thread GPU pass over chains
    (Alg. 2 lines 23-28); we compact the cluster with the largest
    ``new_since_rearrange`` exceeding the threshold — callers loop while
    ``triggered`` (mirrors the one-block-at-a-time deployment note in §3.3).

    A second trigger serves the mutation subsystem: any cluster whose
    tombstoned fraction reaches ``dead_frac`` (and has at least one dead
    slot) is compacted to reclaim the dead space, worst absolute
    ``dead_count`` first — it takes priority over the insert statistic
    because dead slots cost scan work *and* capacity until reclaimed.
    """

    @jax.jit
    def step(state: IVFState):
        # A cluster is compactable when the fresh run fits the bump region
        # (contiguous, preferred) OR the free stack holds enough recycled
        # blocks (the reclamation fallback once the monotone bump pointer
        # nears the pool end).  Unfit clusters are masked out of the
        # offender argmax — running off the pool would record out-of-range
        # block ids (the silent-recall failure mode of an unchecked
        # alloc_blocks), while gating the whole step on the single worst
        # offender would stall maintenance for every smaller cluster that
        # still fits.  cluster_nblocks is an upper bound on the fresh run
        # (tombstone-dropping can only shrink it).
        fits = (
            state.cur_p + state.cluster_nblocks <= cfg.n_blocks
        ) | (state.free_top >= state.cluster_nblocks)
        frac = state.dead_count.astype(jnp.float32) / jnp.maximum(
            state.cluster_len, 1
        ).astype(jnp.float32)
        dstat = jnp.where(
            fits & (frac >= dead_frac), state.dead_count, -1
        )
        worst_dead = jnp.argmax(dstat).astype(jnp.int32)
        dead_trig = dstat[worst_dead] > 0
        stat = jnp.where(fits, state.new_since_rearrange, -1)
        worst_stat = jnp.argmax(stat).astype(jnp.int32)
        stat_trig = stat[worst_stat] > threshold
        worst = jnp.where(dead_trig, worst_dead, worst_stat)
        triggered = dead_trig | stat_trig
        new_state = rearrange_cluster(cfg, state, worst)
        out = jax.tree.map(
            lambda a, b: jnp.where(triggered, a, b), new_state, state
        )
        return out, triggered

    return step
