"""Multi-stream serving runtime (paper Alg. 4 + deployment §3.3).

Reproduces the paper's execution architecture with TPU-appropriate
mechanisms (DESIGN.md §2, §5):

* **Resource pool** — 32 slots, each a permit to dispatch a search; when all
  slots are busy the request is *rejected* (the paper's lock-free queue with
  rejection).  Slot scratch memory is implicit in JAX (each jitted search
  owns preallocated output buffers), the central-pool overflow grant is
  modelled by the shared device arena.
* **Dedicated mutation lane** — one thread owns the index state and applies
  donated insert/delete/update steps; the paper's single data stream, grown
  into a full mutation stream.  Deletes tombstone rows through the device
  id map, updates tombstone + re-insert under the same id in one dispatch
  (core.mutate), and arrival order is preserved: the lane batches
  *consecutive runs of the same kind*, so delete-then-insert of an id can
  never be reordered into insert-then-delete.
* **Dynamic batcher** — inserts aggregate until ``flush_min`` (128) pending
  or ``flush_interval`` (1 s) elapsed, capped at ``flush_max`` (1024);
  search batches are capped at ``max_search_batch`` (10).  All paper §3.3
  values are the defaults.
* **Execution modes** (benchmarked in Fig. 3 reproduction):
    - ``serial``   — Fig. 2a: one lane; an insert in flight blocks searches.
    - ``parallel`` — Fig. 2b: search slots dispatch concurrently with the
      insert lane.  Correctness under buffer donation: dispatch happens
      under the state lock (cheap — dispatch is async), execution overlaps.
    - ``fused``    — TPU-native multi-stream: a pending insert batch and a
      pending search batch are submitted as ONE jitted program whose two
      subgraphs share no data edge, so the XLA scheduler overlaps them
      (search reads the pre-insert state — the legal concurrent
      serialisation, same as the paper's streams).

Fault-tolerance layer (docs/serving_ops.md):

* **Admission control** — the mutation lane is bounded by
  ``max_pending_mutations`` rows (reject or block-with-deadline on
  overflow, symmetrical with the search lane's slot rejection).
* **Deadlines & shedding** — requests may carry a deadline; expired
  requests are shed from the queue with ``DeadlineExceeded`` instead of
  dispatched late.
* **Degradation ladder** — under a sustained queue-age watermark the
  runtime steps down ``degradation_ladder`` (skip rerank → halve nprobe →
  halve the chain budget) and back up when pressure clears; rungs key the
  same pow2-bucketed jit caches, so degrading never recompiles per request.
* **Crash-safe workers** — loop bodies run under a supervisor that logs,
  counts, restarts (bounded, with backoff); a lane that exhausts its
  restart budget fails its queue loudly and stops admission.
* **Graceful shutdown** — ``stop()`` drains: queued mutation batches are
  flushed (or failed with ``RuntimeShutdown`` when ``drain=False``),
  undispatchable search futures are failed, and ``submit_*`` afterwards
  raises instead of enqueueing into a dead runtime.
* **Poison isolation** — a failed batch retries once per item, so one bad
  payload fails only its own future (``poisoned`` counter).
* **Deterministic fault injection** — every path above is exercised through
  ``repro.core.faults.FaultPlan`` hooks (no-op by default).
"""

from __future__ import annotations

import collections
import dataclasses
import logging
import os
import queue
import threading
import time
from concurrent.futures import Future
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.admission import (
    AdmissionGate,
    DeadlineExceeded,
    DegradationLadder,
    DynamicResourcePool,
    QueueFull,
    RequestRejected,
    RuntimeShutdown,
    validate_ids,
    validate_vectors,
)
from repro.checkpoint.manager import CheckpointManager
from repro.core.block_pool import dead_fraction, pool_stats
from repro.core.faults import NO_FAULTS, FaultPlan
from repro.core.insert import assign_clusters, insert_payload
from repro.core.ivf import IVFIndex, IVFIndexConfig, state_to_host
from repro.core.metrics import (
    ArrivalEstimator,
    CounterSet,
    LatencyStats,
    percentile_summary,
)
from repro.core.mutate import apply_delete, last_occurrence_mask
from repro.core import pq as pqmod
from repro.core.search import resolve_search_impl
from repro.obs import bundle as obs_bundle
from repro.obs import export as obs_export
from repro.obs.events import (
    EV_COMPACTION,
    EV_COMPACTION_DEFERRED,
    EV_EFFORT,
    EV_FAULT_INJECTED,
    EV_LADDER_STEP,
    EV_LANE_DEAD,
    EV_POOL_REBALANCE,
    EV_SNAPSHOT_CUT,
    EV_SNAPSHOT_FAILED,
    EV_SNAPSHOT_PUBLISH,
    EV_WINDOW_RUNG,
    EV_WORKER_RESTART,
    FlightRecorder,
)
from repro.obs.trace import (
    OUTCOME_ERROR,
    OUTCOME_OK,
    OUTCOME_REJECTED,
    OUTCOME_SHED,
    STAGE_ACK,
    STAGE_ADMISSION,
    STAGE_BATCH,
    STAGE_COMPILE,
    STAGE_DEVICE,
    STAGE_EXECUTE,
    STAGE_QUEUE,
    RequestTracer,
)
from repro.persist import snapshot as snapmod
from repro.persist.snapshot import (
    SNAP_SUBDIR,
    WAL_SUBDIR,
    PersistDirConflict,
    persist_dir_in_use,
)
from repro.persist.wal import MutationWAL

log = logging.getLogger(__name__)


@dataclasses.dataclass
class _Timed:
    future: Future
    t_arrival: float
    payload: object
    kind: str = "insert"  # search | insert | delete | update
    deadline: Optional[float] = None  # absolute perf_counter time, or None
    rows: int = 0  # admission-gate rows held (mutation kinds only)
    released: bool = False  # gate budget already returned
    t_done: float = 0.0
    # sampled span-trace context (repro.obs.trace), or None on the
    # untraced fast path; owned by whichever thread holds the item
    trace: Optional[object] = None


@dataclasses.dataclass
class RuntimeConfig:
    n_slots: int = 32  # paper: 32 independent resources
    max_search_batch: int = 10  # paper: max search batch 10
    flush_min: int = 128  # paper: dispatch at 128 pending inserts
    flush_max: int = 1024  # paper: cap 1024
    flush_interval: float = 1.0  # paper: flush every second
    nprobe: int = 16
    k: int = 10
    mode: str = "parallel"  # serial | parallel | fused
    # any path make_search_fn supports: block_table | chain_walk | union |
    # union_pallas | union_fused | union_fused_scan (typos raise ValueError
    # at construction — a silent fallback would serve the wrong path)
    search_path: str = "block_table"
    # exact-fp32 re-rank epilogue over the fused survivors (fused paths
    # only; rejected at construction otherwise)
    rerank: bool = False
    # latency samples kept for stats(); unbounded lists grow forever under
    # sustained traffic
    latency_window: int = 10_000
    # run dead-space-reclaiming compaction passes on the mutation lane after
    # a delete/update batch whenever a cluster crosses the dead-fraction
    # trigger (see core.rearrange); off by default — maintenance cadence is
    # a deployment decision
    auto_compact: bool = False
    compact_passes: int = 4
    # ---- fault tolerance (docs/serving_ops.md) --------------------------
    # bound on mutation rows in the system (queued + in flight); None keeps
    # the seed's unbounded queue.  On overflow: "reject" raises QueueFull
    # in the caller's thread, "block" waits up to admission_timeout for
    # capacity first (backpressure with a bounded stall).
    max_pending_mutations: Optional[int] = None
    admission: str = "reject"  # reject | block
    admission_timeout: float = 1.0
    # deadline (seconds from submit) stamped on every request that does not
    # pass its own; None = requests never expire.  Expired requests are
    # shed from the queue with DeadlineExceeded, never dispatched late.
    default_deadline: Optional[float] = None
    # degradation ladder rungs, applied cumulatively under sustained
    # overload, e.g. ("no_rerank", "half_nprobe", "half_budget"); empty =
    # always full service.  Pressure signal: queue-age watermark of each
    # search dispatch vs the overload_high/low hysteresis band.
    degradation_ladder: tuple = ()
    overload_high: float = 0.05  # step down above this queue age (s)
    overload_low: float = 0.01  # step back up below this (s)
    overload_patience: int = 3  # consecutive observations per step
    # crash-safe workers: bounded restarts with exponential backoff; a lane
    # that exhausts the budget fails its queue and stops admission (loud)
    max_worker_restarts: int = 5
    restart_backoff: float = 0.05
    # fail malformed payloads (wrong dim / non-finite / empty / non-numeric)
    # in the caller's thread at submit time instead of deep in a worker batch
    validate: bool = True
    # stop() default: flush queued mutations (True) or fail everything
    # undispatched with RuntimeShutdown (False)
    drain_on_stop: bool = True
    # ---- durability (repro.persist; docs/serving_ops.md "Durability") ---
    # root directory for the mutation WAL + snapshots; None keeps the index
    # volatile (the seed behaviour).  Reopening a directory that already
    # holds data must go through ``ServingRuntime.recover`` — enforced:
    # the plain constructor raises PersistDirConflict over a used
    # directory, because a fresh runtime over it would fork the log from
    # the state.
    persist_dir: Optional[str] = None
    # mutation batches between WAL fsyncs.  1 (default) = fsync before
    # every ack: RPO = 0 acked rows.  N > 1 batches the fsync: up to N-1
    # most-recent acked batches ride in the page cache across a crash.
    wal_sync_interval: int = 1
    # ---- adaptive control (docs/serving_ops.md "Adaptive control") ------
    # master switch for the arrival-rate-driven control loop: batch window
    # and flush threshold from live QPS, effort inside the latency
    # envelope, load-paced compaction, and the dynamic resource pool.
    # Off (default) = the static §3.3 schedule above, bit-for-bit.
    adaptive: bool = False
    # batch-window bounds: the controller picks a pow2-rung window in
    # [window_min, window_max] from the load factor — small at low QPS
    # (a lone mutation dispatches almost immediately), wide near
    # saturation (dispatch cost amortizes over big batches).
    window_min: float = 0.005
    window_max: Optional[float] = None  # None -> flush_interval
    rate_tau: float = 0.5  # arrival-rate EWMA time constant (seconds)
    adaptive_interval: float = 0.05  # min seconds between controller steps
    adaptive_patience: int = 3  # consecutive agreeing steps per rung move
    # latency envelope for the effort knob (nprobe / chain budget);
    # None falls back to default_deadline; both None = never degrade
    latency_slo: Optional[float] = None
    max_effort: int = 2  # pow2 halving levels the controller may take
    # compaction pacing: defer auto-compact passes while the mutation
    # queue-age watermark sits above overload_high, catch up in lulls
    # (below overload_low) — but NEVER defer once the dead fraction
    # reaches this bound, so recall cannot silently decay under load
    compact_force_dead_frac: float = 0.45
    # dynamic resource pool: re-apportion search slots vs mutation
    # admission rows from measured lane utilization (requires
    # max_pending_mutations; hysteresis in admission.DynamicResourcePool)
    pool_rebalance: bool = True
    pool_rows_per_slot: int = 64
    pool_min_search: int = 2
    pool_min_mutation: int = 1
    pool_interval: float = 0.25
    # ---- observability (repro.obs; docs/observability.md) ---------------
    # fraction of submits that carry a span-trace context through the
    # serving path (deterministic stride sampling).  0 disables tracing
    # entirely (one None-check per submit); 1.0 traces every request.
    # Default 1% keeps steady-state overhead < 5% p50 (BENCH_obs.json).
    trace_sample_rate: float = 0.01
    trace_buffer: int = 2048  # finished traces kept (ring, oldest evicted)
    event_buffer: int = 2048  # flight-recorder events kept (ring)
    # where debug bundles land on lane death / shutdown / RecoveryError;
    # None falls back to persist_dir; both None = no bundles written
    debug_bundle_dir: Optional[str] = None


class AdaptiveSlots:
    """Resizable search-permit pool (the fixed ``Semaphore(n_slots)``
    grown a ``set_capacity`` lever for the dynamic resource pool).

    Shrinking below the in-flight count never revokes permits — new
    acquires are rejected until the lane drains under the new capacity,
    the same tighten-as-they-drain discipline as the admission gate.
    """

    def __init__(self, capacity: int):
        self._lock = threading.Lock()
        self._capacity = max(1, capacity)  # guarded-by: _lock
        self._busy = 0  # guarded-by: _lock (permits out)
        self._peak = 0  # guarded-by: _lock (high-watermark since read)

    def acquire(self, blocking: bool = False) -> bool:
        if blocking:
            raise ValueError("AdaptiveSlots is non-blocking by design")
        with self._lock:
            if self._busy < self._capacity:
                self._busy += 1
                self._peak = max(self._peak, self._busy)
                return True
            return False

    def release(self) -> None:
        with self._lock:
            self._busy = max(0, self._busy - 1)

    def set_capacity(self, capacity: int) -> None:
        with self._lock:
            self._capacity = max(1, capacity)

    @property
    def capacity(self) -> int:
        with self._lock:
            return self._capacity

    @property
    def in_flight(self) -> int:
        with self._lock:
            return self._busy

    def utilization(self) -> float:
        with self._lock:
            return min(1.0, self._busy / self._capacity)

    def take_peak_utilization(self) -> float:
        """High-watermark utilization since the previous call, then re-arm
        to the current level (mirror of the admission gate's method: the
        rebalancer samples between dispatches, exactly when an
        instantaneous read would always say "idle")."""
        with self._lock:
            peak, self._peak = self._peak, self._busy
            return min(1.0, peak / self._capacity)

    def reset_peak(self) -> None:
        """Re-arm the high-watermark to the current occupancy without
        consuming it (``reset_stats`` between benchmark phases: the next
        rebalance decision must see this phase's peak, not the last)."""
        with self._lock:
            self._peak = self._busy

    def snapshot(self) -> dict:
        """Capacity and occupancy as ONE consistent read.  ``stats()``
        used to read the two properties back-to-back — two separate lock
        acquisitions, between which a release could land and report
        ``in_flight > capacity`` mid-shrink."""
        with self._lock:
            return {"capacity": self._capacity, "in_flight": self._busy}


class AdaptiveController:
    """Arrival-rate-driven batch/budget control loop (the *Adaptive* in
    the paper's title; §3.3).  Steady-state tuning — the
    ``DegradationLadder`` stays on top of it as overload *protection*;
    see docs/serving_ops.md "Adaptive control" for the division of roles.

    Signals come from one :class:`ArrivalEstimator` per lane: EWMA
    arrival rate, queue-age watermark (the very observations the ladder
    receives), and measured service seconds per dispatch.  Laws:

    * **Batch window** — the load factor ``rho = rate * service /
      flush_max`` picks a pow2 rung in ``[window_min, window_max]``,
      with a *stability floor*: the window never drops below twice the
      measured per-dispatch service time.  Below that floor the flush
      threshold (``rate * window``) is smaller than what one dispatch
      interval admits, every batch pays the full fixed dispatch cost
      un-amortized, and the lane's dispatch utilization
      (``service / window``) exceeds 1 at *any* rate — a rate-blind
      death spiral ``rho`` alone cannot see.  Rung moves are
      hysteresis-gated: at most one rung per ``adaptive_interval``,
      only after ``adaptive_patience`` agreeing steps, so a square-wave
      load cannot oscillate the window.
    * **Flush threshold** — expected rows per window (``rate * window``)
      pow2-quantized into ``[1, flush_max]``: at low rate a lone
      mutation dispatches immediately; near saturation batches fill to
      the cap.
    * **Effort** — with a latency envelope configured (``latency_slo``,
      else ``default_deadline``), search service above half the envelope
      steps effort down (halve nprobe, then the chain budget too), and
      below a fifth steps back up.  Halvings are pow2, so effort levels
      key the same bounded jit caches as the ladder's rungs.
    * **Compaction pacing** — ``should_compact`` defers auto-compaction
      while the mutation queue-age watermark is above ``overload_high``
      (reclamation would steal the lane mid-burst), owes the pass, and
      releases it in the next lull — unless the dead fraction reached
      ``compact_force_dead_frac``, the max-deferral bound past which
      recall would silently decay.

    Disabled (``adaptive=False``) every method returns the static
    schedule: ``flush_interval`` window, ``flush_min`` threshold, full
    effort, compact-whenever-triggered.
    """

    def __init__(self, cfg: "RuntimeConfig",
                 recorder: Optional[FlightRecorder] = None):
        self.cfg = cfg
        self.enabled = cfg.adaptive
        # flight recorder for rung/effort transition events; emissions
        # happen after the controller lock drops (recorder lock is a leaf)
        self._recorder = recorder
        self.search = ArrivalEstimator(cfg.rate_tau)
        self.mutation = ArrivalEstimator(cfg.rate_tau)
        w_max = (cfg.window_max if cfg.window_max is not None
                 else cfg.flush_interval)
        w_min = min(cfg.window_min, w_max)
        rungs = [w_min]
        while rungs[-1] * 2 < w_max:
            rungs.append(rungs[-1] * 2)
        if w_max > rungs[-1]:
            rungs.append(w_max)
        #: pow2 window ladder, w_min doubling up to w_max
        self.window_rungs: tuple = tuple(rungs)
        self._slo = (cfg.latency_slo if cfg.latency_slo is not None
                     else cfg.default_deadline)
        self._lock = threading.Lock()
        self._level = 0  # guarded-by: _lock (window rung index)
        self._hot = 0  # guarded-by: _lock (steps wanting a wider window)
        self._cool = 0  # guarded-by: _lock (steps wanting a narrower one)
        self._effort = 0  # guarded-by: _lock (pow2 halvings in force)
        self._eff_hot = 0  # guarded-by: _lock
        self._eff_cool = 0  # guarded-by: _lock
        self._deferred = 0  # guarded-by: _lock (compaction passes owed)
        self._t_update = 0.0  # guarded-by: _lock (last controller step)
        self.window_changes = 0  # guarded-by: _lock
        self.effort_changes = 0  # guarded-by: _lock

    def load_factor(self, now: Optional[float] = None) -> float:
        """``rho`` = offered mutation rows/s over measured capacity
        (``flush_max`` rows per measured service interval)."""
        service = self.mutation.service(default=self.cfg.window_min)
        capacity = self.cfg.flush_max / max(service, 1e-6)
        return self.mutation.rate(now) / max(capacity, 1e-6)

    def _maybe_update(self, now: float) -> None:
        """One hysteresis-gated controller step (window rung + effort),
        rate-limited to ``adaptive_interval``.  Estimator reads happen
        before the controller lock — both are leaf locks, never nested."""
        rho = self.load_factor(now)
        svc = self.search.service(0.0)
        m_svc = self.mutation.service(0.0)
        q_age = self.mutation.queue_age()
        # transition events collected under the lock, emitted after it in
        # the finally (the early returns below must not swallow them)
        fired: list = []
        try:
            self._update_locked(now, rho, svc, m_svc, q_age, fired)
        finally:
            if self._recorder is not None:
                for name, fields in fired:
                    self._recorder.record_event(name, **fields)

    def _update_locked(self, now: float, rho: float, svc: float,
                       m_svc: float, q_age: float, fired: list) -> None:
        with self._lock:
            if now - self._t_update < self.cfg.adaptive_interval:
                return
            self._t_update = now
            n = len(self.window_rungs)
            target = min(n - 1, int(rho * n))
            # stability floor: a window under ~2x the per-dispatch
            # service time yields sub-service batches whose dispatch
            # rate alone exceeds lane capacity (util = service/window),
            # regardless of rho — clamp the target above it
            floor = 0
            while (floor < n - 1
                   and self.window_rungs[floor] < 2.0 * m_svc):
                floor += 1
            target = max(target, floor)
            # outcome feedback: rho and the floor are *models* of
            # capacity; the queue-age watermark is the ground truth.  A
            # lane measurably falling behind keeps escalating the window
            # one rung per patience period until amortization catches up
            # (or the top rung — max batching — is reached), even when
            # the model mis-prices a dispatch.  "Behind" is age in
            # EXCESS of the current window: under a wide window items
            # wait a window on purpose, and reading that intended wait
            # as overload would lock the window at the top rung
            if q_age > self.window_rungs[self._level] + \
                    self.cfg.overload_high:
                target = max(target, min(n - 1, self._level + 1))
            if target > self._level:
                self._hot += 1
                self._cool = 0
            elif target < self._level:
                self._cool += 1
                self._hot = 0
            else:
                self._hot = self._cool = 0
            if self._hot >= self.cfg.adaptive_patience:
                self._level += 1
                self._hot = 0
                self.window_changes += 1
                fired.append((EV_WINDOW_RUNG, {
                    "level": self._level, "direction": "up",
                    "window_s": self.window_rungs[self._level],
                    "load_factor": rho,
                }))
            elif self._cool >= self.cfg.adaptive_patience:
                self._level -= 1
                self._cool = 0
                self.window_changes += 1
                fired.append((EV_WINDOW_RUNG, {
                    "level": self._level, "direction": "down",
                    "window_s": self.window_rungs[self._level],
                    "load_factor": rho,
                }))
            if not self._slo:
                return
            if svc > 0.5 * self._slo and self._effort < self.cfg.max_effort:
                self._eff_hot += 1
                self._eff_cool = 0
            elif svc < 0.2 * self._slo and self._effort > 0:
                self._eff_cool += 1
                self._eff_hot = 0
            else:
                self._eff_hot = self._eff_cool = 0
            if self._eff_hot >= self.cfg.adaptive_patience:
                self._effort += 1
                self._eff_hot = 0
                self.effort_changes += 1
                fired.append((EV_EFFORT, {
                    "level": self._effort, "direction": "down",
                    "search_service_s": svc,
                }))
            elif self._eff_cool >= self.cfg.adaptive_patience:
                self._effort -= 1
                self._eff_cool = 0
                self.effort_changes += 1
                fired.append((EV_EFFORT, {
                    "level": self._effort, "direction": "up",
                    "search_service_s": svc,
                }))

    def window(self, now: Optional[float] = None) -> float:
        """Current batch window (seconds) for the mutation lane."""
        if not self.enabled:
            return self.cfg.flush_interval
        now = time.perf_counter() if now is None else now
        self._maybe_update(now)
        with self._lock:
            return self.window_rungs[self._level]

    def flush_rows(self, now: Optional[float] = None) -> int:
        """Current dispatch threshold (pending rows that end the wait)."""
        if not self.enabled:
            return self.cfg.flush_min
        now = time.perf_counter() if now is None else now
        self._maybe_update(now)
        with self._lock:
            w = self.window_rungs[self._level]
        target = self.mutation.rate(now) * w
        rows = 1
        while rows < target and rows < self.cfg.flush_max:
            rows *= 2
        return min(rows, self.cfg.flush_max)

    def search_effort(self, nprobe: int, rerank: bool,
                      budget: int) -> tuple:
        """Effective pow2 ``(nprobe, rerank, budget)`` at the current
        effort level — composed *before* the ladder's protective rungs,
        so both share the same bounded jit-cache key space."""
        if not self.enabled:
            return nprobe, rerank, budget
        with self._lock:
            effort = self._effort
        for lvl in range(effort):
            nprobe = max(1, nprobe // 2)
            if lvl >= 1:
                budget = max(1, budget // 2)
        return nprobe, rerank, budget

    def should_compact(self, dead_frac: float) -> bool:
        """Pacing gate for one auto-compact opportunity."""
        if not self.enabled:
            return True
        if dead_frac >= self.cfg.compact_force_dead_frac:
            return True  # max-deferral bound: recall never silently decays
        if self.mutation.queue_age() > self.cfg.overload_high:
            with self._lock:
                self._deferred += 1
            return False
        return True

    def compaction_owed(self) -> bool:
        """True in a lull with deferred passes outstanding (catch up)."""
        if not self.enabled:
            return False
        if self.mutation.queue_age() >= self.cfg.overload_low:
            return False
        with self._lock:
            return self._deferred > 0

    def compacted(self) -> None:
        with self._lock:
            self._deferred = 0

    def snapshot(self, now: Optional[float] = None) -> dict:
        now = time.perf_counter() if now is None else now
        rho = self.load_factor(now)
        s = self.search.snapshot(now)
        m = self.mutation.snapshot(now)
        with self._lock:
            return {
                "window_s": self.window_rungs[self._level],
                "window_level": self._level,
                "window_changes": self.window_changes,
                "effort_level": self._effort,
                "effort_changes": self.effort_changes,
                "compactions_owed": self._deferred,
                "load_factor": rho,
                "search_rate": s["rate"],
                "mutation_rate": m["rate"],
                "search_queue_age_s": s["queue_age_s"],
                "mutation_queue_age_s": m["queue_age_s"],
                "search_service_s": s["service_s"],
                "mutation_service_s": m["service_s"],
            }


class ServingRuntime:
    """Owns the IVF index state + jitted steps; serves search/insert."""

    def __init__(self, index: IVFIndex, cfg: RuntimeConfig = RuntimeConfig(),
                 faults: Optional[FaultPlan] = None, *,
                 _recovered: bool = False):
        """``_recovered`` is internal: only the ``recover`` classmethod may
        set it, after replaying the directory's history into ``index`` —
        it is what licenses opening a persist_dir that already holds data."""
        self.index = index  # guarded-by: _state_lock [state, _next_id]
        self.cfg = cfg
        self.pool_cfg = index.pool_cfg
        self._faults = faults if faults is not None else NO_FAULTS
        self._state_lock = threading.Lock()
        self._slots = AdaptiveSlots(cfg.n_slots)
        self._stop = threading.Event()
        self._search_q: queue.Queue = queue.Queue()
        self._insert_q: queue.Queue = queue.Queue()
        # submit/stop transition guard: stop() flips _accepting under this
        # lock, submits check-and-enqueue under it — nothing can slip into a
        # queue after the shutdown drain has swept it
        self._submit_lock = threading.Lock()
        self._accepting = True  # guarded-by: _submit_lock
        self._drained = False  # guarded-by: _submit_lock
        self._lane_dead: Optional[str] = None  # guarded-by: _submit_lock
        # ---- observability (repro.obs; docs/observability.md) -----------
        # flight recorder first: every control-plane subsystem below hooks
        # its transitions into it.  Its lock is a leaf — record_event is
        # safe to call from inside any other component's critical section.
        self._events = FlightRecorder(cfg.event_buffer)
        self._tracer = RequestTracer(cfg.trace_sample_rate, cfg.trace_buffer)
        if self._faults is not NO_FAULTS:
            # never mutate the shared no-op default: an observer on it
            # would leak one runtime's events into every other runtime
            self._faults.set_observer(
                lambda site, action, i: self._events.record_event(
                    EV_FAULT_INJECTED, site=site, action=action, call=i
                )
            )
        self._gate = AdmissionGate(
            cfg.max_pending_mutations, cfg.admission, cfg.admission_timeout
        )
        self._ladder = DegradationLadder(
            cfg.degradation_ladder, cfg.overload_high, cfg.overload_low,
            cfg.overload_patience,
            on_transition=lambda level, rung, direction:
                self._events.record_event(
                    EV_LADDER_STEP, level=level, rung=rung,
                    direction=direction,
                ),
        )
        # adaptive control loop: a no-op pass-through when cfg.adaptive is
        # off (window()/flush_rows() return the static schedule)
        self._controller = AdaptiveController(cfg, recorder=self._events)
        # dynamic resource pool: only meaningful with a bounded mutation
        # lane — without max_pending_mutations there is no mutation-side
        # budget for a slot to buy
        self._pool: Optional[DynamicResourcePool] = None
        self._pool_next = time.perf_counter() + cfg.pool_interval
        if cfg.adaptive and cfg.pool_rebalance and cfg.max_pending_mutations:
            m_slots = max(
                cfg.pool_min_mutation,
                -(-cfg.max_pending_mutations // cfg.pool_rows_per_slot),
            )
            self._pool = DynamicResourcePool(
                total=cfg.n_slots + m_slots,
                min_search=min(cfg.pool_min_search, cfg.n_slots),
                min_mutation=cfg.pool_min_mutation,
                rows_per_slot=cfg.pool_rows_per_slot,
                patience=cfg.adaptive_patience,
                initial_search=cfg.n_slots,
            )
        # bounded: stats() reports over a sliding window instead of every
        # sample since process start.  Appends and snapshots share a lock —
        # iterating a deque while a worker appends raises RuntimeError
        # (unlike the copy-a-list-under-GIL idiom it replaced).
        self._lat_lock = threading.Lock()
        # guarded-by: _lat_lock
        self._search_lat: collections.deque = collections.deque(
            maxlen=cfg.latency_window
        )
        # guarded-by: _lat_lock
        self._insert_lat: collections.deque = collections.deque(
            maxlen=cfg.latency_window
        )
        # guarded-by: _lat_lock
        self._mutation_lat: collections.deque = collections.deque(
            maxlen=cfg.latency_window
        )
        # every counter the runtime bumps lives here: workers, submit paths
        # and the supervisor all increment concurrently, and bare += on
        # instance ints drops increments (see metrics.CounterSet)
        self._counters = CounterSet()
        self._fused_pending = queue.Queue()
        # serial-mode pending mutations live on the instance (not a loop
        # local) so supervisor restarts and the shutdown drain see them
        self._serial_pending: list[_Timed] = []  # guarded-by: _submit_lock
        self._serial_last_flush = time.perf_counter()
        # jitted steps are cached per (chain-budget bucket, degradation
        # params): the budget is recomputed at dispatch time (see
        # _current_budget), so online growth costs one recompile per
        # power-of-two bucket, and each ladder rung adds at most one entry
        # per bucket — degradation never recompiles per request
        self._search_steps: dict[tuple, object] = {}  # guarded-by: _state_lock
        self._fused_steps: dict[tuple, object] = {}  # guarded-by: _state_lock
        # cached bucketed budget; None forces a recompute (a host readback
        # of the live chain depth) — invalidated only by the insert paths,
        # so pure-search traffic never pays the device sync
        self._budget: Optional[int] = None  # guarded-by: _state_lock
        # ---- durability (repro.persist) ---------------------------------
        # report attached by the `recover` classmethod; None on a cold start
        self.recovery_report = None
        self._wal: Optional[MutationWAL] = None
        self._snap_mgr: Optional[CheckpointManager] = None
        # LSN of the last mutation applied to device state.  Writes happen
        # under _state_lock, and only after block_until_ready confirmed
        # the apply — the fence never covers effects the device did not
        # acknowledge.  The snapshot barrier reads (state, lsn) as one cut
        # under _record_lock + _state_lock.
        self._applied_lsn = 0  # guarded-by: _state_lock
        # Serializes one WAL record's whole durable apply — append ->
        # device apply -> block_until_ready -> fence advance, *including*
        # the per-item isolation retries of an already-logged run —
        # against the snapshot cut.  Without it a cut could land between
        # a retried record's items (fence at L with only part of L
        # applied: rows acked after the cut are lost on replay) or
        # between an apply and its fence advance (replay would
        # double-apply the record).  Lock order: _record_lock before
        # _state_lock, never the other way.
        self._record_lock = threading.Lock()
        # one snapshot publisher at a time; the thread handle + last
        # published LSN move under this lock (never held across publish IO)
        self._snap_lock = threading.Lock()
        self._snap_thread: Optional[threading.Thread] = None  # guarded-by: _snap_lock
        self._snapshot_lsn = 0  # guarded-by: _snap_lock
        if cfg.persist_dir is not None:
            if not _recovered and persist_dir_in_use(cfg.persist_dir):
                raise PersistDirConflict(
                    f"{cfg.persist_dir} already holds snapshots/WAL from a "
                    "previous run; a fresh runtime over it would fork the "
                    "log from the in-memory index.  Reopen it through "
                    "ServingRuntime.recover(), or point persist_dir at an "
                    "empty directory."
                )
            self._snap_mgr = CheckpointManager(
                os.path.join(cfg.persist_dir, SNAP_SUBDIR)
            )
            # publishes never overlap: held for the whole checkpoint write
            self._publish_serial = threading.Lock()
            latest = self._snap_mgr.latest_step()
            self._wal = MutationWAL(
                os.path.join(cfg.persist_dir, WAL_SUBDIR),
                sync_interval=cfg.wal_sync_interval,
                faults=self._faults,
                # LSN floor = the snapshot fence: a log whose segments were
                # all pruned must not restart numbering under the fence
                start_lsn=latest or 0,
                recorder=self._events,
            )
            # cold start: 0.  After `recover`: the adopted log's last LSN —
            # the installed state already includes every replayed record.
            self._applied_lsn = self._wal.last_lsn
            if latest is None:
                # recovery requires a snapshot to anchor the LSN fence, so
                # publish the pre-traffic state now, synchronously — a crash
                # one batch in must already be recoverable
                self.snapshot(wait=True)
            else:
                self._snapshot_lsn = latest
        self._build_steps()
        self._threads = [
            threading.Thread(
                target=self._supervised,
                args=(self._insert_loop_body, "insert_loop"),
                daemon=True,
            ),
            threading.Thread(
                target=self._supervised,
                args=(self._search_loop_body, "search_loop"),
                daemon=True,
            ),
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------ steps --
    def _build_steps(self):
        cfg, pc = self.cfg, self.pool_cfg
        pq = self.index.pq
        # fail at construction, not inside the worker thread's first jit
        # trace: raises ValueError on an unknown path (no silent fallback)
        # and NotImplementedError on a payload mismatch
        self._search_impl = resolve_search_impl(
            pc, cfg.search_path, cfg.rerank
        )
        # state-free: centroids come from the traced state argument, so the
        # cached steps never bake a stale pool copy in as jit constants
        self._score_fn = pqmod.pq_score_fn(pq) if pq is not None else None

        def _insert(state, vectors, ids, valid):
            assign = assign_clusters(state.centroids, vectors)
            if pq is None:
                payload = vectors
            else:
                payload = pqmod.encode(pq, vectors - state.centroids[assign])
            return insert_payload(pc, state, assign, payload, ids, valid)

        def _delete(state, ids, valid):
            return apply_delete(pc, state, ids, valid)

        def _update(state, vectors, ids, valid):
            # tombstone + re-insert under the same id, one dispatch: no
            # state where both (or neither) copy is visible can be observed;
            # duplicate targets merged into one run re-insert last-write-wins
            state = apply_delete(pc, state, ids, valid)
            return _insert(state, vectors, ids,
                           last_occurrence_mask(ids, valid))

        # raw fns feed the fused (search+mutation) programs; jitted steps
        # serve the standalone mutation lane
        self._mutation_fns = {
            "insert": _insert, "delete": _delete, "update": _update,
        }
        self._insert_fn = _insert
        self._insert_step = jax.jit(_insert, donate_argnums=(0,))
        self._delete_step = jax.jit(_delete, donate_argnums=(0,))
        self._update_step = jax.jit(_update, donate_argnums=(0,))

    def _current_budget(self) -> int:  # holds: _state_lock
        """Adaptive chain budget (§Perf), recomputed at *dispatch* time.

        The budget is the live chain depth bucketed to the next power of
        two with 2x headroom (capped at ``max_chain``) *before* it keys the
        ``_search_steps``/``_fused_steps`` jit caches, so steady chain
        growth costs O(log max_chain) recompiles instead of one per
        increment; computing it once at construction silently truncated
        chains — and dropped candidates — after online inserts grew them
        past 2x the initial depth.  The value is cached between inserts
        (callers hold ``_state_lock``).  Chains never shrink, so when the
        bucket advances the entries keyed by smaller *base* budgets can
        never be dispatched again — they are evicted instead of pinning
        their compiled executables (and output buffers) forever.  Ladder
        rungs key smaller *effective* budgets under the current base
        (key[0]), so degraded entries survive until the base itself moves.
        """
        if self._budget is None:
            # IVFIndex._chain_budget() happens to return pow2 buckets
            # already, making the _bucket pass idempotent today — it is
            # enforced *here* regardless, because the jit-cache keys below
            # are what actually bound the recompile count; a future budget
            # heuristic must not silently re-introduce
            # one-recompile-per-increment growth.
            budget = min(
                self._bucket(2 * self.index._chain_budget(), floor=1),
                self.pool_cfg.max_chain,
            )
            # both caches key tuples whose first element is the base budget
            for cache in (self._search_steps, self._fused_steps):
                for stale in [k for k in cache if k[0] < budget]:
                    del cache[stale]
            self._budget = budget
        return self._budget

    def _make_search(self, budget: int, nprobe: int, rerank: bool):
        cfg, pc = self.cfg, self.pool_cfg

        def _search(state, queries, valid):
            d, i = self._search_impl(
                pc, state, queries, nprobe=nprobe, k=cfg.k,
                score_fn=self._score_fn, chain_budget=budget,
                pq=self.index.pq, rerank=rerank,
            )
            return d, jnp.where(valid[:, None], i, -1)

        return _search

    @staticmethod
    def _traced(step) -> int:
        """Entry count of a jitted step's shape-trace cache (``-1`` when
        the jit wrapper has no such counter).  Dispatch sites compare it
        before/after a call to tell a fresh compile from a steady-state
        hit: compile seconds must never poison the service EWMA the
        adaptive stability floor is built on — one poisoned observation
        can pin the batch window at the top rung for many dispatches."""
        try:
            return step._cache_size()
        except AttributeError:
            return -1

    # holds: _state_lock
    def _search_step_for(self, base: int, budget: Optional[int] = None,
                         nprobe: Optional[int] = None,
                         rerank: Optional[bool] = None):
        budget = base if budget is None else budget
        nprobe = self.cfg.nprobe if nprobe is None else nprobe
        rerank = self.cfg.rerank if rerank is None else rerank
        key = (base, budget, nprobe, rerank)
        if key not in self._search_steps:
            self._search_steps[key] = jax.jit(
                self._make_search(budget, nprobe, rerank)
            )
        return self._search_steps[key]

    # holds: _state_lock
    def _fused_step_for(self, base: int, kind: str = "insert",
                        budget: Optional[int] = None,
                        nprobe: Optional[int] = None,
                        rerank: Optional[bool] = None):
        budget = base if budget is None else budget
        nprobe = self.cfg.nprobe if nprobe is None else nprobe
        rerank = self.cfg.rerank if rerank is None else rerank
        key = (base, budget, nprobe, rerank, kind)
        if key not in self._fused_steps:
            _search = self._make_search(budget, nprobe, rerank)
            _mutate = self._mutation_fns[kind]

            def _fused(state, queries, qvalid, *m_args):
                # two independent subgraphs; XLA overlaps them (multi-stream)
                d, i = _search(state, queries, qvalid)
                new_state = _mutate(state, *m_args)
                return new_state, d, i

            self._fused_steps[key] = jax.jit(_fused, donate_argnums=(0,))
        return self._fused_steps[key]

    # ------------------------------------------------------------ API ----
    def _check_accepting(self):  # holds: _submit_lock
        if not self._accepting:
            if self._lane_dead is not None:
                raise RuntimeShutdown(
                    f"{self._lane_dead} died (restart budget exhausted); "
                    "runtime no longer accepts requests"
                )
            raise RuntimeShutdown("runtime stopped")

    def _abs_deadline(self, deadline: Optional[float]) -> Optional[float]:
        d = deadline if deadline is not None else self.cfg.default_deadline
        return None if d is None else time.perf_counter() + d

    def submit_search(self, queries: np.ndarray, *,
                      deadline: Optional[float] = None) -> Future:
        if self.cfg.validate:
            queries = validate_vectors(queries, self.pool_cfg.dim, "queries")
        # offered load is the control signal: count every arrival, rejected
        # or not, before the admission decision
        self._controller.search.observe_arrival(1)
        trace = self._tracer.start("search")
        with self._submit_lock:
            self._check_accepting()
            if not self._slots.acquire(blocking=False):
                self._counters.inc("rejected_search")
                if trace is not None:
                    trace.stamp(STAGE_ADMISSION)
                    self._tracer.finish(trace, OUTCOME_REJECTED)
                raise RequestRejected("resource pool exhausted")
            fut = Future()
            t_arr = time.perf_counter()
            if trace is not None:
                trace.stamp(STAGE_ADMISSION, t_arr)
            self._search_q.put(_Timed(
                fut, t_arr, queries, kind="search",
                deadline=self._abs_deadline(deadline), trace=trace,
            ))
        return fut

    def _submit_mutation(self, payload, kind: str, rows: int,
                         deadline: Optional[float]) -> Future:
        # cheap early out before blocking admission; the racy read is safe:
        # unlocked-ok: re-checked under _submit_lock before anything enqueues
        self._check_accepting()
        # offered rows/s, counted before admission (see submit_search)
        self._controller.mutation.observe_arrival(rows)
        trace = self._tracer.start(kind)
        try:
            self._faults.check("admission")
            self._gate.acquire(rows)
        except QueueFull:
            self._counters.inc("rejected_mutation")
            if trace is not None:
                trace.stamp(STAGE_ADMISSION)
                self._tracer.finish(trace, OUTCOME_REJECTED)
            raise
        try:
            with self._submit_lock:
                self._check_accepting()
                fut = Future()
                t_arr = time.perf_counter()
                if trace is not None:
                    trace.stamp(STAGE_ADMISSION, t_arr)
                self._insert_q.put(_Timed(
                    fut, t_arr, payload, kind=kind,
                    deadline=self._abs_deadline(deadline), rows=rows,
                    trace=trace,
                ))
            return fut
        except BaseException:
            self._gate.release(rows)
            raise

    def submit_insert(self, vectors: np.ndarray, *,
                      deadline: Optional[float] = None) -> Future:
        if self.cfg.validate:
            vectors = validate_vectors(vectors, self.pool_cfg.dim, "vectors")
        else:
            vectors = np.atleast_2d(np.asarray(vectors, np.float32))
        return self._submit_mutation(
            vectors, "insert", len(vectors), deadline
        )

    def submit_delete(self, ids: np.ndarray, *,
                      deadline: Optional[float] = None) -> Future:
        """Tombstone ids through the mutation lane.  Resolves with the ids
        once the delete step has been applied (misses — unknown or already
        deleted ids — are counted in the index state, not surfaced per
        request: the batch is one fused dispatch)."""
        if self.cfg.validate:
            ids = validate_ids(ids)
        else:
            ids = np.atleast_1d(np.asarray(ids, np.int32))
        return self._submit_mutation(ids, "delete", len(ids), deadline)

    def submit_update(self, vectors: np.ndarray, ids: np.ndarray, *,
                      deadline: Optional[float] = None) -> Future:
        """Replace the vectors behind ``ids`` (tombstone + re-insert under
        the same id, one dispatch).  Resolves with the ids once applied."""
        if self.cfg.validate:
            vectors = validate_vectors(vectors, self.pool_cfg.dim, "vectors")
            ids = validate_ids(ids)
        else:
            vectors = np.atleast_2d(np.asarray(vectors, np.float32))
            ids = np.atleast_1d(np.asarray(ids, np.int32))
        if len(ids) != len(vectors):
            raise ValueError(f"{len(ids)} ids for {len(vectors)} vectors")
        return self._submit_mutation(
            (vectors, ids), "update", len(ids), deadline
        )

    # --------------------------------------------------------- durability --
    def snapshot(self, wait: bool = True) -> int:
        """Crash-consistent online snapshot (the durability barrier).

        Under ``_record_lock`` + ``_state_lock`` — waiting out any
        in-flight WAL record, then quiescing the mutation lane for exactly
        one device_get — capture ``(state, applied LSN, id cursor)`` as a
        single cut, then seal the active WAL segment.  The expensive part
        (checkpoint write, then WAL prune) runs on a background thread
        while serving continues; the WAL is pruned only *after* the
        publish succeeded, so a crash at any instant leaves snapshot + WAL
        sufficient to rebuild the cut.  A publish failure (injectable at
        the ``snapshot_publish`` site) is counted, logged, and leaves the
        previous snapshot and the whole WAL intact — and is re-raised here
        when ``wait=True``.  Returns the cut's LSN fence.
        """
        if self._wal is None or self._snap_mgr is None:
            raise RuntimeError(
                "snapshot() needs cfg.persist_dir (durability is off)"
            )
        with self._snap_lock:
            prev = self._snap_thread
        if prev is not None and prev.is_alive():
            prev.join()  # barrier semantics: the previous cut lands first
        # _record_lock waits out any in-flight record — append -> apply ->
        # fence, including the per-item retry loop of a logged run — so
        # the cut can never pair a fence with a half-applied record
        with self._record_lock:
            with self._state_lock:
                arrays, meta = state_to_host(self.index.state)
                lsn = self._applied_lsn
                next_id = self.index._next_id
            # seal the segment: records after the cut land in a fresh
            # file, so prune can drop covered history at whole-segment
            # granularity (a post-cut record in the sealed segment just
            # keeps it alive)
            self._wal.rotate()
        self._events.record_event(EV_SNAPSHOT_CUT, lsn=lsn, next_id=next_id)
        books = (
            None if self.index.pq is None
            else np.asarray(self.index.pq.codebooks)
        )
        box: dict = {}

        def _publish():
            try:
                with self._publish_serial:
                    snapmod.publish(
                        self._snap_mgr, arrays, meta, lsn=lsn,
                        next_id=next_id, pq_books=books, faults=self._faults,
                    )
                    with self._snap_lock:
                        self._snapshot_lsn = max(self._snapshot_lsn, lsn)
                    self._wal.prune(lsn)
                self._counters.inc("snapshots")
                self._events.record_event(EV_SNAPSHOT_PUBLISH, lsn=lsn)
            except Exception as e:
                log.exception(
                    "snapshot publish @ lsn %d failed; WAL retained", lsn
                )
                self._counters.inc("snapshot_failures")
                self._events.record_event(
                    EV_SNAPSHOT_FAILED, lsn=lsn, error=repr(e)
                )
                box["exc"] = e

        t = threading.Thread(
            target=_publish, daemon=True, name="snapshot-publish"
        )
        with self._snap_lock:
            self._snap_thread = t
        t.start()
        if wait:
            t.join()
            if "exc" in box:
                raise box["exc"]
        return lsn

    @classmethod
    def recover(cls, index_cfg: IVFIndexConfig, persist_dir: str,
                cfg: Optional[RuntimeConfig] = None,
                faults: Optional[FaultPlan] = None,
                sample: int = 256) -> "ServingRuntime":
        """Verified crash recovery -> a serving runtime; the only way to
        reopen a persist directory that already holds data (the plain
        constructor refuses one with ``PersistDirConflict``, because a
        fresh index over an old log forks the log from the state).

        Loads the newest snapshot, replays the WAL tail through the same
        batch paths serving uses, verifies (``check_invariants`` + sampled
        id_map/pool_live cross-check), then opens for traffic with the log
        adopted at its last LSN.  Raises ``repro.persist.RecoveryError``
        instead of serving anything it cannot prove.  The recovery report
        is attached as ``runtime.recovery_report``."""
        # runtime<->recovery would be a module-level import cycle
        from repro.persist.recovery import RecoveryError, recover_index
        try:
            index, report = recover_index(
                index_cfg, persist_dir, faults=faults, sample=sample
            )
        except RecoveryError as e:
            # first responder's crash dump: what recovery had established
            # before it refused to serve (docs/observability.md)
            try:
                bundle_dir = (
                    cfg.debug_bundle_dir if cfg is not None else None
                ) or persist_dir
                partial = getattr(e, "report", None)
                obs_bundle.write_debug_bundle(
                    bundle_dir, reason="recovery-error",
                    extra={
                        "error": str(e),
                        "report": (
                            partial.as_dict() if partial is not None else None
                        ),
                        "persist_dir": persist_dir,
                    },
                )
            except Exception:
                log.exception("debug bundle for recovery failure not written")
            raise
        run_cfg = dataclasses.replace(
            cfg if cfg is not None else RuntimeConfig(),
            persist_dir=persist_dir,
        )
        rt = cls(index, run_cfg, faults=faults, _recovered=True)
        rt.recovery_report = report
        try:
            # collapse the replayed tail: the *next* crash replays only
            # what arrives after this point (RTO), and the WAL can prune
            rt.snapshot(wait=True)
        except Exception:
            log.exception("post-recovery snapshot failed; serving anyway")
        return rt

    def stop(self, drain: Optional[bool] = None, timeout: float = 10.0):
        """Graceful shutdown.  Stops admission (later ``submit_*`` raise
        ``RuntimeShutdown``), joins the workers, then drains: queued
        mutation batches are *flushed* (``drain=True``, the default from
        ``cfg.drain_on_stop`` — their futures resolve with ids) or failed
        with ``RuntimeShutdown``; queued searches are always failed (their
        results cannot be delivered to anyone meaningfully late) and their
        slots released.  No submitted future is ever left unresolved."""
        drain = self.cfg.drain_on_stop if drain is None else drain
        with self._submit_lock:
            self._accepting = False
        self._stop.set()
        for t in self._threads:
            t.join(timeout)
        with self._submit_lock:
            if self._drained:
                return
            self._drained = True
        self._drain_on_stop(drain)
        self._finish_persist(timeout)
        # final-state capture for post-mortems; a bundle failure must not
        # mask a clean shutdown (dump_debug_bundle swallows + logs)
        self.dump_debug_bundle("shutdown")

    def _finish_persist(self, timeout: float):
        """Shutdown tail of the durability layer: let an in-flight
        snapshot publish land, then close the WAL (final fsync) — the
        drain above already logged everything it flushed."""
        with self._snap_lock:
            t = self._snap_thread
        if t is not None and t.is_alive():
            t.join(timeout)
        if self._snap_mgr is not None:
            self._snap_mgr.wait()
        if self._wal is not None:
            self._wal.close()

    def _drain_on_stop(self, drain: bool):
        # mutation lane: everything not yet dispatched, in arrival order —
        # serial-mode pending first (oldest), then fused hand-offs, then
        # the queue itself
        items: list[_Timed] = []
        with self._submit_lock:
            items.extend(self._serial_pending)
            self._serial_pending = []
        while True:
            try:
                items.extend(self._fused_pending.get_nowait())
            except queue.Empty:
                break
        while True:
            try:
                items.append(self._insert_q.get_nowait())
            except queue.Empty:
                break
        # deadline semantics survive shutdown: an expired mutation is shed,
        # not flushed late under the cover of drain
        items = self._shed_expired(items, "mutation")
        if items:
            if drain:
                # flush: _apply_mutations resolves every future (result on
                # success, exception per failed run/item)
                self._apply_mutations(items)
            else:
                self._fail_futures(
                    items, RuntimeShutdown("runtime stopped before dispatch")
                )
        # search lane: undispatchable — fail + release the submit-time slot
        exc = RuntimeShutdown("runtime stopped before dispatch")
        while True:
            try:
                it = self._search_q.get_nowait()
            except queue.Empty:
                break
            if not it.future.done():
                it.future.set_exception(exc)
            if it.trace is not None:
                self._tracer.finish(it.trace, OUTCOME_ERROR)
            self._slots.release()

    def reset_stats(self):
        """Zero every *sampled* statistic: latency windows, counters, the
        adaptive controller's learned arrival/service estimators, the
        peak-utilization watermarks, and the trace ring (a sampling window
        over requests).  Live state — ladder level, pool slot assignment,
        controller rung — is left alone, as is the flight recorder: its
        history of transitions is the point, and post-reset readers still
        want to know what happened before the benchmark phase began."""
        with self._lat_lock:
            self._search_lat.clear()
            self._insert_lat.clear()
            self._mutation_lat.clear()
        self._counters.reset()
        # adaptive/pool sampled state (missed before the obs PR): learned
        # load from one benchmark cell must not steer the next cell
        self._controller.search.reset()
        self._controller.mutation.reset()
        self._slots.reset_peak()
        self._gate.reset_peak()
        self._tracer.ring.clear()

    def stats(self, timeout_ms: float = 20.0):
        with self._lat_lock:
            search = tuple(self._search_lat)
            insert = tuple(self._insert_lat)
            mutation = tuple(self._mutation_lat)
        c = self._counters.snapshot()
        ladder = self._ladder.snapshot()
        with self._submit_lock:
            accepting = self._accepting
        out = {
            "search": LatencyStats.from_samples(search, timeout_ms),
            "insert": LatencyStats.from_samples(insert, timeout_ms),
            "mutation": LatencyStats.from_samples(mutation, timeout_ms),
            # request outcome counters
            "rejected": c.get("rejected_search", 0),
            "rejected_search": c.get("rejected_search", 0),
            "rejected_mutation": c.get("rejected_mutation", 0),
            "shed_search": c.get("shed_search", 0),
            "shed_mutation": c.get("shed_mutation", 0),
            "poisoned": c.get("poisoned", 0),
            "isolations": c.get("isolations", 0),
            "fused_fallbacks": c.get("fused_fallbacks", 0),
            "worker_restarts": c.get("worker_restarts", 0),
            # mutation-stream counters (rows applied, not batches)
            "inserts": c.get("inserts", 0),
            "deletes": c.get("deletes", 0),
            "updates": c.get("updates", 0),
            "compactions": c.get("compactions", 0),
            # live gauges
            "pending_mutations": self._gate.pending(),
            "pending_searches": self._search_q.qsize(),
            "degradation_rung": ladder["rung"],
            "degradation_level": ladder["level"],
            "degradation_transitions": ladder["transitions"],
            "accepting": accepting,
            # JSON-ready p50/p95/p99 per lane via the one shared helper
            # (metrics.percentile_summary) — benchmarks and the runbook
            # consume these instead of post-processing raw windows
            "percentiles": {
                "search": percentile_summary(search),
                "insert": percentile_summary(insert),
                "mutation": percentile_summary(mutation),
            },
        }
        # one locked read: the separate capacity/in_flight property reads
        # could interleave with a rebalance and report in_flight > capacity
        slots = self._slots.snapshot()
        out["search_slots"] = slots["capacity"]
        out["search_in_flight"] = slots["in_flight"]
        if self.cfg.adaptive:
            out["adaptive"] = self._controller.snapshot()
            out["compactions_deferred"] = c.get("compactions_deferred", 0)
            if self._pool is not None:
                out["pool"] = self._pool.snapshot()
        # durability gauges: the LSN contract (docs/serving_ops.md) is
        # snapshot_lsn <= applied_lsn <= wal_lsn, durable_lsn <= wal_lsn
        if self._wal is not None:
            # lsns() is one locked read; two property reads can interleave
            # with an append+fsync and report durable_lsn > wal_lsn
            last, durable = self._wal.lsns()
            out["wal_lsn"] = last
            out["wal_durable_lsn"] = durable
            with self._snap_lock:
                out["snapshot_lsn"] = self._snapshot_lsn
            out["snapshots"] = c.get("snapshots", 0)
            out["snapshot_failures"] = c.get("snapshot_failures", 0)
        # live-occupancy gauges: allocated != occupied once tombstones exist
        with self._state_lock:
            if self._wal is not None:
                out["applied_lsn"] = self._applied_lsn
            out.update(pool_stats(self.index.state, self.pool_cfg))
        return out

    # ---------------------------------------------------- observability --
    def traces(self) -> list:
        """Sampled request traces, oldest first (``repro.obs.trace``)."""
        return self._tracer.ring.snapshot()

    def events(self) -> list:
        """Flight-recorder events, oldest first (``repro.obs.events``)."""
        return self._events.snapshot()

    def metrics(self) -> dict:
        """``stats()`` flattened to ``{dotted_name: float}`` — the unified
        registry behind both exporters."""
        return obs_export.flatten_metrics(self.stats())

    def prometheus_text(self) -> str:
        """Prometheus text exposition of :meth:`metrics`."""
        return obs_export.prometheus_text(self.metrics())

    def export_perfetto(self) -> dict:
        """Chrome/Perfetto ``trace_event`` envelope over the sampled
        traces plus flight-recorder instants (load into ui.perfetto.dev)."""
        return obs_export.perfetto_trace(self.traces(), self.events())

    def dump_debug_bundle(self, reason: str,
                          directory: Optional[str] = None) -> Optional[str]:
        """Write a post-mortem bundle (flight recorder + stats + config)
        to ``directory`` or ``cfg.debug_bundle_dir`` or
        ``cfg.persist_dir``; returns the path, or ``None`` when no
        destination is configured.  Never raises: called from shutdown and
        failure paths, where a bundle error must not mask the real one."""
        target = directory or self.cfg.debug_bundle_dir or \
            self.cfg.persist_dir
        if target is None:
            return None
        try:
            stats = {
                k: v.as_dict() if hasattr(v, "as_dict") else v
                for k, v in self.stats().items()
            }
        except Exception:  # a wedged runtime still deserves its bundle
            log.exception("stats() failed during debug bundle; omitting")
            stats = None
        try:
            return obs_bundle.write_debug_bundle(
                target, reason=reason, config=dataclasses.asdict(self.cfg),
                stats=stats, events=self.events(), traces=self.traces(),
            )
        except Exception:
            log.exception("debug bundle %r not written", reason)
            return None

    # --------------------------------------------------------- workers ---
    def _supervised(self, body, name: str):
        """Run a worker loop body under bounded-restart supervision: an
        uncaught exception used to kill the lane silently and forever.  A
        crash is logged, counted, and restarted with exponential backoff;
        when the restart budget is exhausted the lane fails its queue
        (futures resolve with ``RuntimeShutdown``) and stops admission —
        loud and bounded, never a silent wedge."""
        restarts = 0
        while not self._stop.is_set():
            try:
                body()
                return  # clean exit: stop was requested
            except Exception:
                log.exception("worker %s crashed", name)
                self._counters.inc("worker_restarts")
                self._counters.inc(f"restarts_{name}")
                restarts += 1
                if restarts > self.cfg.max_worker_restarts:
                    log.error(
                        "worker %s: restart budget (%d) exhausted; failing "
                        "its queue and stopping admission",
                        name, self.cfg.max_worker_restarts,
                    )
                    with self._submit_lock:
                        # set before _accepting flips so a rejected submit
                        # never reports a plain "stopped" for a dead lane
                        self._lane_dead = name
                        self._accepting = False
                    self._events.record_event(
                        EV_LANE_DEAD, lane=name, restarts=restarts - 1
                    )
                    self._fail_lane_queue(name)
                    self.dump_debug_bundle(f"lane-death-{name}")
                    return
                self._events.record_event(
                    EV_WORKER_RESTART, lane=name, restarts=restarts
                )
                time.sleep(min(
                    self.cfg.restart_backoff * (2 ** (restarts - 1)), 1.0
                ))

    def _fail_lane_queue(self, name: str):
        exc = RuntimeShutdown(f"{name} died (restart budget exhausted)")
        if name == "insert_loop":
            items = []
            while True:
                try:
                    items.append(self._insert_q.get_nowait())
                except queue.Empty:
                    break
            self._fail_futures(items, exc)
        else:
            # search lane owns serial-mode mutations and fused hand-offs too
            with self._submit_lock:
                items = list(self._serial_pending)
                self._serial_pending = []
            while True:
                try:
                    items.extend(self._fused_pending.get_nowait())
                except queue.Empty:
                    break
            self._fail_futures(items, exc)
            while True:
                try:
                    it = self._search_q.get_nowait()
                except queue.Empty:
                    break
                if not it.future.done():
                    it.future.set_exception(exc)
                if it.trace is not None:
                    self._tracer.finish(it.trace, OUTCOME_ERROR)
                self._slots.release()

    @staticmethod
    def _stamp(items: list[_Timed], stage: str,
               t: Optional[float] = None) -> None:
        """Stamp one span boundary on every sampled trace in a batch —
        unsampled items (``trace is None``, the overwhelming default) cost
        exactly this None check."""
        for it in items:
            if it.trace is not None:
                it.trace.stamp(stage, t)

    @staticmethod
    def _n_rows(it: _Timed) -> int:
        """Row count of a mutation item (vectors for insert, ids for
        delete, paired (vectors, ids) for update)."""
        if it.kind == "delete":
            return len(np.atleast_1d(it.payload))
        if it.kind == "update":
            return len(np.atleast_2d(it.payload[0]))
        return len(np.atleast_2d(it.payload))

    def _release_gate(self, it: _Timed):
        """Return an item's admission rows exactly once, when it leaves the
        system (applied / failed / shed / drained)."""
        if it.kind != "search" and it.rows and not it.released:
            it.released = True
            self._gate.release(it.rows)

    def _fail_futures(self, items: list[_Timed], exc: BaseException):
        """Propagate a mid-step failure: an unresolved future would hang its
        caller forever.  Mutation items also return their admission rows."""
        for it in items:
            if not it.future.done():
                it.future.set_exception(exc)
            if it.trace is not None:
                self._tracer.finish(it.trace, OUTCOME_ERROR)
            self._release_gate(it)

    def _shed_expired(self, items: list[_Timed], lane: str) -> list[_Timed]:
        """Load shedding: resolve expired requests with ``DeadlineExceeded``
        instead of dispatching them late — serving a dead request steals
        capacity from live ones.  Search sheds release the submit-time
        slot; mutation sheds return their admission rows."""
        now = time.perf_counter()
        live: list[_Timed] = []
        for it in items:
            if it.deadline is not None and now > it.deadline:
                if not it.future.done():
                    it.future.set_exception(DeadlineExceeded(
                        f"{it.kind} expired in queue "
                        f"({now - it.t_arrival:.3f}s old)"
                    ))
                self._counters.inc(f"shed_{lane}")
                if it.trace is not None:
                    self._tracer.finish(it.trace, OUTCOME_SHED)
                if lane == "search":
                    self._slots.release()
                else:
                    self._release_gate(it)
            else:
                live.append(it)
        return live

    def _drain_inserts(self) -> list[_Timed]:
        """Dynamic batching policy from §3.3 over the mutation lane.

        The flush deadline derives from the **oldest queued item's**
        arrival plus the *current* batch window, re-read on every wait
        iteration — never computed once per loop from a fixed
        ``flush_interval``.  With an adaptive window that distinction is
        the whole point: a window shrink under rising load takes effect
        on items already queued instead of one full old-window later
        (the stale-batch latency bug).  The flush threshold likewise
        comes from the controller (``flush_min`` when adaptive is off).

        A running row count is kept instead of re-concatenating every
        pending payload per queue pop (that was quadratic in batch size)."""
        items: list[_Timed] = []
        pending_rows = 0
        t_enter = time.perf_counter()
        while not self._stop.is_set():
            window = self._controller.window()
            anchor = items[0].t_arrival if items else t_enter
            timeout = anchor + window - time.perf_counter()
            if timeout <= 0:
                break
            try:
                item = self._insert_q.get(timeout=min(timeout, 0.01))
            except queue.Empty:
                continue
            if item.trace is not None:
                item.trace.stamp(STAGE_QUEUE)
            items.append(item)
            pending_rows += self._n_rows(item)
            if pending_rows >= self._controller.flush_rows():
                break
        return items

    def _split_flush(self, items: list[_Timed]):
        """Longest whole-item same-kind prefix within ``flush_max`` rows +
        the remainder.

        Items are never split mid-payload (each future must resolve with its
        exact ids), so a single oversized item is dispatched alone and may
        exceed the cap.  A kind switch also ends the batch: runs of the same
        kind dispatch as one fused step, and arrival order across kinds is
        preserved (delete-then-insert of an id must never reorder).  The
        remainder is applied next, never dropped."""
        take: list[_Timed] = []
        rows = 0
        for pos, it in enumerate(items):
            n = self._n_rows(it)
            if take and (
                rows + n > self.cfg.flush_max or it.kind != take[0].kind
            ):
                return take, items[pos:]
            take.append(it)
            rows += n
        return take, []

    @staticmethod
    def _pending_vectors(items: list[_Timed]) -> np.ndarray:
        if not items:
            return np.zeros((0, 1), np.float32)
        return np.concatenate([np.atleast_2d(i.payload) for i in items], 0)

    @staticmethod
    def _bucket(n: int, floor: int = 8) -> int:
        """Next power-of-two bucket — keeps the jit cache tiny."""
        b = floor
        while b < n:
            b *= 2
        return b

    def _padded(self, rows: np.ndarray, bucket: int):
        n = len(rows)
        out = np.zeros((bucket, rows.shape[1]), np.float32)
        out[:n] = rows
        valid = np.zeros((bucket,), bool)
        valid[:n] = True
        return out, valid

    def _mutation_args(self, kind: str, items: list[_Timed],
                       ids: Optional[np.ndarray] = None):
        """Pack one same-kind run into the padded, fixed-shape device args
        of its jitted step.  Returns (step_args, ids, raw_vectors) — ids
        are the per-row ids each future's slice resolves with (freshly
        assigned for inserts, caller-provided for delete/update);
        raw_vectors is the unpadded host batch (None for deletes), which is
        what the WAL logs.  ``ids`` may be passed in by a retry of a run
        whose ids were already assigned (and possibly already WAL-logged):
        re-allocating there would ack different ids than the log replays."""
        vecs = None
        if kind == "insert":
            vecs = self._pending_vectors(items)
            b = len(vecs)
            if ids is None:
                # id allocation shares _next_id with every other dispatch
                # path; an unlocked read-bump handed two concurrent runs
                # (fused lane + drain, or mutation lane + shutdown flush)
                # overlapping id ranges
                with self._state_lock:
                    ids = np.arange(
                        self.index._next_id, self.index._next_id + b,
                        dtype=np.int32,
                    )
                    self.index._next_id += b
            pv, valid = self._padded(vecs, self._bucket(b))
        elif kind == "delete":
            ids = np.concatenate(
                [np.atleast_1d(i.payload) for i in items]
            ).astype(np.int32)
            b = len(ids)
            valid = np.zeros((self._bucket(b),), bool)
            valid[:b] = True
        else:  # update
            vecs = np.concatenate(
                [np.atleast_2d(i.payload[0]) for i in items], 0
            )
            ids = np.concatenate(
                [np.atleast_1d(i.payload[1]) for i in items]
            ).astype(np.int32)
            b = len(ids)
            pv, valid = self._padded(vecs, self._bucket(b))
        pids = np.full((len(valid),), -1, np.int32)
        pids[:b] = ids
        if kind == "delete":
            args = (jnp.asarray(pids), jnp.asarray(valid))
        else:
            args = (jnp.asarray(pv), jnp.asarray(pids), jnp.asarray(valid))
        return args, ids, vecs

    def _maybe_compact(self):
        """Opportunistic dead-space reclamation on the mutation lane (the
        caller holds no lock; passes run under it).  Uses the index's
        rearrange step, whose trigger covers both the paper's insert
        statistic and the mutation subsystem's dead-fraction threshold.

        With the adaptive controller on, each opportunity first passes the
        pacing gate: under a load burst (mutation queue-age watermark
        above ``overload_high``) the pass is *deferred* — reclamation
        would steal the lane from live traffic — and caught up in the
        next lull via ``compaction_owed`` (see ``_insert_loop_body``).
        Deferral is bounded by the dead-fraction gauge
        (``compact_force_dead_frac``): past the bound the pass runs
        regardless of load, so recall never silently decays."""
        fn = self.index._rearrange_fn
        if fn is None:
            return
        if self.cfg.adaptive:
            with self._state_lock:
                st = self.index.state
            dead = float(dead_fraction(st))
            if not self._controller.should_compact(dead):
                self._counters.inc("compactions_deferred")
                self._events.record_event(
                    EV_COMPACTION_DEFERRED, dead_frac=dead
                )
                return
        passes = 0
        for _ in range(max(self.cfg.compact_passes, 0)):
            with self._state_lock:
                self.index.state, triggered = fn(self.index.state)
                self._budget = None  # compaction may shrink chains
            if not bool(triggered):
                break
            passes += 1
            self._counters.inc("compactions")
        if passes:
            self._events.record_event(EV_COMPACTION, passes=passes)
        self._controller.compacted()

    def _wal_append(self, kind: str, ids: np.ndarray,
                    vectors: Optional[np.ndarray]) -> Optional[int]:
        """Log one run before its device apply (no-op without a WAL).
        Called under ``_state_lock`` — append order *is* apply order, so
        the LSN sequence replays in exactly the order the device saw."""
        if self._wal is None:
            return None
        return self._wal.append(kind, ids, vectors)

    def _apply_run(self, items: list[_Timed], *, _isolate: bool = True,
                   _ids: Optional[np.ndarray] = None,
                   _logged_lsn: Optional[int] = None):
        """Dispatch one same-kind run as one jitted step; same failure
        discipline as the search path (no future may hang).  A failed
        multi-item run retries once per item so one poisoned payload fails
        only its own future.

        Durability ordering per run: WAL append (fsync per
        ``wal_sync_interval``) -> device apply -> fence advance -> ack,
        the whole sequence under ``_record_lock`` so the snapshot cut can
        never land inside it, and the fence (``_applied_lsn``) moving
        only after ``block_until_ready`` confirmed the apply — never over
        effects the device did not acknowledge.  Retries after a partial
        failure carry the original ids (``_ids``) and, when the run's
        record already hit the log, its LSN (``_logged_lsn``) — appending
        again would replay the rows twice.  The record lock spans the
        *entire* per-item retry loop of a logged run: each surviving item
        re-advances the fence to the record's LSN, and a cut between
        items would otherwise fence a half-applied record (rows acked
        after the cut silently lost on recovery).  An item that fails
        inside the loop is nacked, so a fence that ends at the record's
        LSN with those rows absent still honours RPO = 0 *acked* rows."""
        kind = items[0].kind
        step = {
            "insert": self._insert_step,
            "delete": self._delete_step,
            "update": self._update_step,
        }[kind]
        ids = _ids
        lsn = _logged_lsn
        if _isolate:  # retries run under the outer call's hold
            self._record_lock.acquire()
        try:
            try:
                # service is the WHOLE dispatch turnaround — fault site
                # (where benchmarks pin per-dispatch cost), marshalling,
                # device apply — not just the jit call: the controller's
                # capacity model (rho, stability floor) is only honest if
                # the measured seconds cover everything a dispatch costs
                n_traced = self._traced(step)
                t_svc = time.perf_counter()
                # batch_form span ends here, BEFORE the fault site: an
                # injected dispatch delay belongs to the dispatch stages
                self._stamp(items, STAGE_BATCH, t_svc)
                self._faults.check("mutation_step")
                if _isolate:  # top-level dispatch: feed the controller
                    self._controller.mutation.observe_queue_age(
                        time.perf_counter()
                        - min(it.t_arrival for it in items)
                    )
                args, ids, raw = self._mutation_args(kind, items, ids=ids)
                with self._state_lock:
                    if lsn is None:
                        lsn = self._wal_append(kind, ids, raw)
                    self.index.state = step(self.index.state, *args)
                    st = self.index.state
                    self._budget = None  # chains may have grown
                # trace-count delta = this dispatch compiled, not executed
                # from cache (PR 9's detection, reused for the span split)
                compiled = self._traced(step) != n_traced
                self._stamp(
                    items, STAGE_COMPILE if compiled else STAGE_EXECUTE
                )
                jax.block_until_ready(st.cluster_len)
                t_dev = time.perf_counter()
                self._stamp(items, STAGE_DEVICE, t_dev)
                if not compiled:  # compile != service
                    self._controller.mutation.observe_service(t_dev - t_svc)
                if lsn is not None:
                    with self._state_lock:
                        self._applied_lsn = lsn
            except Exception as e:
                if _isolate and len(items) > 1:
                    self._counters.inc("isolations")
                    off = 0
                    for it in items:
                        n = self._n_rows(it)
                        sl = None if ids is None else ids[off : off + n]
                        self._apply_run(
                            [it], _isolate=False, _ids=sl, _logged_lsn=lsn
                        )
                        off += n
                    return
                self._counters.inc("poisoned", len(items))
                self._fail_futures(items, e)
                return
        finally:
            if _isolate:
                self._record_lock.release()
        self._counters.inc(
            {"insert": "inserts", "delete": "deletes",
             "update": "updates"}[kind],
            len(ids),
        )
        self._resolve_mutations(items, ids)
        # after the futures resolve: a compaction failure must not fail
        # a mutation that already applied
        if kind != "insert" and self.cfg.auto_compact:
            try:
                self._maybe_compact()
            except Exception:
                log.exception("auto-compact pass failed")
                self._counters.inc("compact_errors")

    def _apply_mutations(self, items: list[_Timed]):
        """Apply a drained (possibly mixed-kind) item list run by run, in
        arrival order."""
        while items:
            take, items = self._split_flush(items)
            self._apply_run(take)

    def _resolve_mutations(self, items: list[_Timed], ids: np.ndarray):
        """Each future gets exactly the ids of its own rows."""
        t = time.perf_counter()
        off = 0
        for it in items:
            n = self._n_rows(it)
            with self._lat_lock:
                lat = self._insert_lat if it.kind == "insert" else \
                    self._mutation_lat
                lat.append(t - it.t_arrival)
            if not it.future.done():
                it.future.set_result(ids[off : off + n])
            if it.trace is not None:
                it.trace.stamp(STAGE_ACK)
                self._tracer.finish(it.trace, OUTCOME_OK)
            self._release_gate(it)
            off += n

    def _insert_loop_body(self):
        if self.cfg.mode == "serial":
            return  # serial mode: the search loop owns mutations too
        while not self._stop.is_set():
            items: list[_Timed] = []
            try:
                # fault site sits before any dequeue so an injected crash
                # never strands items in hand
                self._faults.check("insert_loop")
                items = self._drain_inserts()
                items = self._shed_expired(items, "mutation")
                if not items:
                    # an empty drain IS a queue-age observation: the lane
                    # is caught up.  Without it the watermark would stay
                    # frozen at its last loaded reading through a lull,
                    # pinning the window wide and compaction deferred
                    self._controller.mutation.observe_queue_age(0.0)
                    # lull: catch up on compaction passes deferred under a
                    # burst (pacing, bounded by the dead-fraction gauge)
                    if self.cfg.auto_compact and \
                            self._controller.compaction_owed():
                        try:
                            self._maybe_compact()
                        except Exception:
                            log.exception("catch-up compact pass failed")
                            self._counters.inc("compact_errors")
                    continue
                if self.cfg.mode == "fused":
                    # hand the batch to the search loop for fused dispatch
                    self._fused_pending.put(items)
                    items = []
                else:
                    self._apply_mutations(items)
                    items = []
            except Exception as e:
                # crash with a batch in hand: its futures must not outlive
                # the worker (the supervisor restarts the loop, not them)
                self._fail_futures(items, e)
                raise

    def _collect_search_batch(self) -> list[_Timed]:
        items: list[_Timed] = []
        try:
            it = self._search_q.get(timeout=0.005)
        except queue.Empty:
            return items
        if it.trace is not None:
            it.trace.stamp(STAGE_QUEUE)
        items.append(it)
        while len(items) < self.cfg.max_search_batch:
            try:
                it = self._search_q.get_nowait()
            except queue.Empty:
                break
            if it.trace is not None:
                it.trace.stamp(STAGE_QUEUE)
            items.append(it)
        return self._shed_expired(items, "search")

    def _run_search(self, items: list[_Timed], *, _isolate: bool = True,
                    _release: bool = True):
        """Dispatch one search batch.  A mid-step exception (jit failure,
        injected fault, ...) must not leak: every batched future is
        resolved — result or exception — and every acquired slot is
        released in the ``finally`` (one slot per item, taken at submit).
        A failed multi-item batch retries once per item (poison isolation)."""
        try:
            try:
                # full dispatch turnaround, as in _apply_run: the effort
                # law compares this against the latency envelope
                t_svc = time.perf_counter()
                # batch_form ends before the fault site (see _apply_run)
                self._stamp(items, STAGE_BATCH, t_svc)
                self._faults.check("search_step")
                qs = [np.atleast_2d(i.payload) for i in items]
                counts = [len(q) for q in qs]
                batch = np.concatenate(qs, 0)
                pb, valid = self._padded(batch, self._bucket(len(batch)))
                with self._state_lock:
                    st = self.index.state
                    base = self._current_budget()
                    if _isolate:  # top-level dispatch: feed the ladder
                        age = time.perf_counter() - min(
                            i.t_arrival for i in items
                        )
                        level = self._ladder.observe(age)
                        self._controller.search.observe_queue_age(age)
                    else:
                        level = self._ladder.level
                    # controller effort (steady-state tuning) first, ladder
                    # rungs (overload protection) on top: both halve pow2
                    # values, so the jit-cache key space stays bounded
                    c_nprobe, c_rerank, c_budget = \
                        self._controller.search_effort(
                            self.cfg.nprobe, self.cfg.rerank, base
                        )
                    nprobe, rerank, eff = self._ladder.apply(
                        c_nprobe, c_rerank, c_budget, level
                    )
                    step = self._search_step_for(base, eff, nprobe, rerank)
                    n_traced = self._traced(step)
                    d, i = step(st, jnp.asarray(pb), jnp.asarray(valid))
                # trace-count delta = compiled (see _apply_run)
                compiled = self._traced(step) != n_traced
                self._stamp(
                    items, STAGE_COMPILE if compiled else STAGE_EXECUTE
                )
                d, i = np.asarray(d), np.asarray(i)
                t_dev = time.perf_counter()
                self._stamp(items, STAGE_DEVICE, t_dev)
                if not compiled:  # compile != service
                    self._controller.search.observe_service(t_dev - t_svc)
            except Exception as e:
                if _isolate and len(items) > 1:
                    self._counters.inc("isolations")
                    for it in items:
                        self._run_search(
                            [it], _isolate=False, _release=False
                        )
                    return
                self._counters.inc("poisoned", len(items))
                self._fail_futures(items, e)
                return
            t = time.perf_counter()
            off = 0
            for it, c in zip(items, counts):
                with self._lat_lock:
                    self._search_lat.append(t - it.t_arrival)
                if not it.future.done():
                    it.future.set_result(
                        (d[off : off + c], i[off : off + c])
                    )
                if it.trace is not None:
                    it.trace.stamp(STAGE_ACK)
                    self._tracer.finish(it.trace, OUTCOME_OK)
                off += c
        finally:
            if _release:
                for _ in items:
                    self._slots.release()

    def _serial_mutations(self):
        """Fig. 2a single-lane mode: mutations interleave with (and block)
        searches on the same execution stream.  Pending items live on the
        instance so restarts and the shutdown drain never strand them; the
        list is shared with the drain paths, so it is only touched under
        ``_submit_lock`` — a due batch is swapped out whole and dispatched
        after the lock drops (jit dispatch must not block submitters)."""
        items: list[_Timed] = []
        with self._submit_lock:
            try:
                it = self._insert_q.get_nowait()
            except queue.Empty:
                pass
            else:
                if it.trace is not None:
                    it.trace.stamp(STAGE_QUEUE)
                self._serial_pending.append(it)
            self._serial_pending = self._shed_expired(
                self._serial_pending, "mutation"
            )
            n_pend = sum(self._n_rows(x) for x in self._serial_pending)
            # same oldest-item anchor as _drain_inserts: the wait a queued
            # mutation has already served counts against the current window
            if self._serial_pending and (
                n_pend >= self._controller.flush_rows()
                or time.perf_counter() - min(
                    self._serial_pending[0].t_arrival,
                    self._serial_last_flush,
                ) > self._controller.window()
            ):
                items, self._serial_pending = self._serial_pending, []
        if items:
            self._apply_mutations(items)
            self._serial_last_flush = time.perf_counter()

    def _maybe_rebalance(self):
        """Dynamic resource pool step, interval-gated.  Only the search
        loop calls this (single caller — ``_pool_next`` needs no lock);
        the pool itself applies deadband + patience hysteresis, so one
        slot at most moves per ``pool_interval``."""
        if self._pool is None:
            return
        now = time.perf_counter()
        if now < self._pool_next:
            return
        self._pool_next = now + self.cfg.pool_interval
        before = self._pool.moves
        slots, rows = self._pool.rebalance(
            self._slots.take_peak_utilization(),
            self._gate.take_peak_utilization(),
        )
        self._slots.set_capacity(slots)
        self._gate.set_max_pending(rows)
        moves = self._pool.moves
        if moves != before:
            self._events.record_event(
                EV_POOL_REBALANCE, search_slots=slots, mutation_rows=rows,
                moves=moves,
            )

    def _search_loop_body(self):
        while not self._stop.is_set():
            items: list[_Timed] = []
            ins: Optional[list[_Timed]] = None
            try:
                self._faults.check("search_loop")
                self._maybe_rebalance()
                if self.cfg.mode == "serial":
                    self._serial_mutations()
                items = self._collect_search_batch()
                if self.cfg.mode == "fused":
                    try:
                        ins = self._fused_pending.get_nowait()
                    except queue.Empty:
                        ins = None
                    if ins:
                        ins = self._shed_expired(ins, "mutation") or None
                    if ins and items:
                        s, m = items, ins
                        items, ins = [], None
                        self._run_fused(s, m)
                        continue
                    if ins:  # no search to pair with: standalone mutation
                        m, ins = ins, None
                        self._apply_mutations(m)
                if items:
                    s, items = items, []
                    self._run_search(s)
            except Exception as e:
                # crash with requests in hand: resolve them (and release
                # their slots) before the supervisor restarts the loop
                for it in items:
                    if not it.future.done():
                        it.future.set_exception(e)
                    self._slots.release()
                if ins:
                    self._fail_futures(ins, e)
                raise

    def _run_fused(self, s_items: list[_Timed], i_items: list[_Timed]):
        """One fused search+mutation dispatch (the paper's multi-stream
        mode, now covering insert *and* delete/update batches).  The first
        same-kind run pairs with the search batch as ONE jitted program;
        any remaining runs of the drained batch are applied right after, in
        arrival order.  Same leak discipline as ``_run_search``: a mid-step
        exception resolves every search *and* mutation future, and the
        search slots are released in the ``finally``.  A failed fused
        program decomposes into the two separate lanes so per-item poison
        isolation can find the bad payload."""
        i_run, rest = self._split_flush(i_items)
        kind = i_run[0].kind
        ids = None
        lsn = None
        try:
            try:
                # full dispatch turnaround (see _apply_run)
                t_svc = time.perf_counter()
                # batch_form ends before the fault site (see _apply_run)
                self._stamp(s_items, STAGE_BATCH, t_svc)
                self._stamp(i_run, STAGE_BATCH, t_svc)
                self._faults.check("fused_step")
                qs = [np.atleast_2d(x.payload) for x in s_items]
                counts = [len(q) for q in qs]
                qbatch = np.concatenate(qs, 0)
                m_args, ids, raw = self._mutation_args(kind, i_run)
                pq_, qvalid = self._padded(qbatch, self._bucket(len(qbatch)))
                # same per-record discipline as _apply_run: the snapshot
                # cut is held off from append to fence advance, and the
                # fence moves only once the device confirmed the apply
                with self._record_lock:
                    with self._state_lock:
                        base = self._current_budget()
                        now = time.perf_counter()
                        age = now - min(x.t_arrival for x in s_items)
                        m_age = now - min(x.t_arrival for x in i_run)
                        self._controller.search.observe_queue_age(age)
                        self._controller.mutation.observe_queue_age(m_age)
                        # controller effort first, ladder protection on top
                        # (same composition as _run_search)
                        c_nprobe, c_rerank, c_budget = \
                            self._controller.search_effort(
                                self.cfg.nprobe, self.cfg.rerank, base
                            )
                        nprobe, rerank, eff = self._ladder.apply(
                            c_nprobe, c_rerank, c_budget,
                            self._ladder.observe(age),
                        )
                        fused_step = self._fused_step_for(
                            base, kind, eff, nprobe, rerank
                        )
                        n_traced = self._traced(fused_step)
                        lsn = self._wal_append(kind, ids, raw)
                        self.index.state, d, i = fused_step(
                            self.index.state,
                            jnp.asarray(pq_),
                            jnp.asarray(qvalid),
                            *m_args,
                        )
                        st = self.index.state
                        self._budget = None  # chains may have grown/shrunk
                    # trace-count delta = compiled (see _apply_run)
                    compiled = self._traced(fused_step) != n_traced
                    stage = STAGE_COMPILE if compiled else STAGE_EXECUTE
                    self._stamp(s_items, stage)
                    self._stamp(i_run, stage)
                    d, i = np.asarray(d), np.asarray(i)
                    jax.block_until_ready(st.cluster_len)
                    t_dev = time.perf_counter()
                    self._stamp(s_items, STAGE_DEVICE, t_dev)
                    self._stamp(i_run, STAGE_DEVICE, t_dev)
                    if not compiled:
                        svc = t_dev - t_svc
                        self._controller.search.observe_service(svc)
                        self._controller.mutation.observe_service(svc)
                    if lsn is not None:
                        with self._state_lock:
                            self._applied_lsn = lsn
            except Exception:
                self._counters.inc("fused_fallbacks")
                self._run_search(s_items, _release=False)
                # the decomposed retry reuses the fused attempt's ids and —
                # when the append got through — its WAL record: logging the
                # run twice would replay it twice on recovery
                self._apply_run(i_run, _ids=ids, _logged_lsn=lsn)
                return
            self._counters.inc(
                {"insert": "inserts", "delete": "deletes",
                 "update": "updates"}[kind],
                len(ids),
            )
            t = time.perf_counter()
            off = 0
            for it, c in zip(s_items, counts):
                with self._lat_lock:
                    self._search_lat.append(t - it.t_arrival)
                if not it.future.done():
                    it.future.set_result(
                        (d[off : off + c], i[off : off + c])
                    )
                if it.trace is not None:
                    it.trace.stamp(STAGE_ACK)
                    self._tracer.finish(it.trace, OUTCOME_OK)
                off += c
            self._resolve_mutations(i_run, ids)
            if kind != "insert" and self.cfg.auto_compact:
                try:
                    self._maybe_compact()
                except Exception:
                    log.exception("auto-compact pass failed")
                    self._counters.inc("compact_errors")
        except Exception as e:
            self._fail_futures(s_items, e)
            self._fail_futures(i_run, e)
        finally:
            for _ in s_items:
                self._slots.release()
        if rest:  # later runs / overflow of the drained batch, in order
            self._apply_mutations(rest)
