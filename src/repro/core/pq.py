"""Product quantization (Jégou et al., TPAMI'11) for the IVFPQ payload.

Vectors are encoded as residuals against their coarse centroid (Faiss IVFPQ
semantics): ``code = PQ(y - c_k)``.  Search builds a per-(query, probe)
asymmetric-distance LUT and accumulates it over the candidate codes (ADC).

The jnp scorer here doubles as the oracle for the Pallas ADC kernel
(``repro.kernels.pq_adc``), which re-derives the GPU shared-memory LUT trick
as a VMEM-resident LUT + one-hot MXU accumulation.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_pool import IVFState
from repro.core.kmeans import kmeans

KSUB = 256  # codewords per subquantizer (uint8 codes)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQParams:
    codebooks: jax.Array  # [M, KSUB, dsub] f32

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]

    @property
    def dim(self) -> int:
        return self.m * self.dsub


def train_pq(
    residuals: np.ndarray, m: int, *, n_iter: int = 15, seed: int = 0
) -> PQParams:
    """Train per-subspace codebooks on (sampled) residual vectors."""
    n, d = residuals.shape
    if d % m:
        raise ValueError(f"dim {d} not divisible by M={m}")
    dsub = d // m
    books = np.zeros((m, KSUB, dsub), np.float32)
    for j in range(m):
        sub = residuals[:, j * dsub : (j + 1) * dsub]
        books[j] = kmeans(sub, KSUB, n_iter=n_iter, seed=seed + j)
    return PQParams(codebooks=jnp.asarray(books))


def encode(pq: PQParams, residuals: jax.Array) -> jax.Array:
    """residuals [B, D] -> codes [B, M] uint8 (argmin per subspace)."""
    b, d = residuals.shape
    sub = residuals.reshape(b, pq.m, pq.dsub)
    # [B, M, KSUB] distances per subspace
    dots = jnp.einsum("bmd,mkd->bmk", sub, pq.codebooks)
    cn = jnp.sum(pq.codebooks * pq.codebooks, axis=-1)  # [M, KSUB]
    d2 = cn[None] - 2.0 * dots
    return jnp.argmin(d2, axis=-1).astype(jnp.uint8)


def decode(pq: PQParams, codes: jax.Array) -> jax.Array:
    """codes [..., M] -> reconstructed residuals [..., D]."""
    recon = jax.vmap(lambda c: pq.codebooks[jnp.arange(pq.m), c.astype(jnp.int32)])(
        codes.reshape(-1, pq.m)
    )
    return recon.reshape(*codes.shape[:-1], pq.dim)


def adc_lut(pq: PQParams, query_residuals: jax.Array) -> jax.Array:
    """query residuals [..., D] -> LUT [..., M, KSUB] of squared L2 terms."""
    sub = query_residuals.reshape(*query_residuals.shape[:-1], pq.m, pq.dsub)
    dots = jnp.einsum("...md,mkd->...mk", sub, pq.codebooks)
    cn = jnp.sum(pq.codebooks * pq.codebooks, axis=-1)
    qn = jnp.sum(sub * sub, axis=-1)  # [..., M]
    return qn[..., None] + cn - 2.0 * dots


def adc_accumulate(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut [..., M, KSUB], codes [..., T, M] -> distances [..., T]."""
    idx = codes.astype(jnp.int32)  # [..., T, M]
    m = lut.shape[-2]
    gathered = jnp.take_along_axis(
        lut[..., None, :, :],  # [..., 1, M, KSUB]
        idx[..., :, :, None],  # [..., T, M, 1]
        axis=-1,
    )[..., 0]
    return jnp.sum(gathered, axis=-1)


def make_pq_encode_fn(pq: PQParams):
    """encode(state, assign, vectors) hook for ``make_insert_fn``."""

    def _encode(state: IVFState, assign: jax.Array, vectors: jax.Array):
        residuals = vectors - state.centroids[assign]
        return encode(pq, residuals)

    return _encode


def probe_residual_luts(
    pq: PQParams, centroids: jax.Array, queries: jax.Array, probe_idx: jax.Array
) -> jax.Array:
    """LUT-building prologue shared by every ADC scorer.

    queries [Q, D], probe_idx [Q, NP] -> [Q, NP, M, KSUB] ADC tables of the
    query residual against each probed centroid (Faiss IVFPQ semantics:
    distances are computed in residual space per probe).
    """
    qres = queries[:, None, :] - centroids[probe_idx]  # [Q, NP, D]
    return adc_lut(pq, qres)


def pq_score_fn(pq: PQParams, use_kernel: bool = False):
    """score_fn hook for ``search.py``: ADC over candidate block codes.

    payload: [Q, C, T, M] uint8 codes where C = nprobe * chain (block-table
    path) or C = nprobe (chain-walk path); probe_idx: [Q, nprobe].
    Centroids for the residual LUTs come from the *traced* state argument —
    closing over a concrete ``IVFState`` would bake them in as jit constants
    and pin a stale pool copy per cached search fn.
    """

    def _score(state: IVFState, queries, payload, probe_idx):
        q, c, t, m = payload.shape
        nprobe = probe_idx.shape[1]
        chain = c // nprobe
        lut = probe_residual_luts(
            pq, state.centroids, queries, probe_idx
        )  # [Q, P, M, KSUB]
        codes = payload.reshape(q, nprobe, chain * t, m)
        if use_kernel:
            from repro.kernels.ops import pq_adc

            d = pq_adc(lut.reshape(q * nprobe, pq.m, KSUB),
                       codes.reshape(q * nprobe, chain * t, m))
            d = d.reshape(q, nprobe, chain * t)
        else:
            d = adc_accumulate(lut, codes)  # [Q, P, chain*T]
        return d.reshape(q, c, t)

    return _score
