"""Comparison systems from the paper's experiment section (§4).

* ``FaissLikeIndex`` — Alg. 1 semantics with Faiss's ``add`` behaviour: the
  affected vector lists round-trip through the *host* (device->host copy,
  concatenate on host, host->device copy of the fully rebuilt storage).
* ``RaftLikeIndex``  — RAFT ``extend``: reallocation happens on-device — new
  arrays of size ``len+new`` are materialised and the old ones dropped
  (device-side copy-merge, no host round trip).
* ``RtCpuIndex``     — the paper's Rt-cpu ablation: our memory-block
  insertion algorithm implemented in pure numpy linked lists on the CPU.

All three expose the same (train / add / search) surface as ``IVFIndex`` so
the Fig. 3 benchmark drives them interchangeably.  The two realloc baselines
store each cluster as one contiguous array — exactly the layout whose growth
cost the paper attacks.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import kmeans
from repro.core.search import exact_search, l2_sq


@dataclasses.dataclass
class _List:
    vecs: object  # device or host array [n, D]
    ids: object  # [n]


class _ReallocIndexBase:
    """Contiguous per-cluster storage with realloc-on-insert (Alg. 1)."""

    host_roundtrip = False  # Faiss-style add copies via host

    def __init__(self, n_clusters: int, dim: int, *, nprobe=16, k=10, seed=0,
                 kmeans_iters=10):
        self.n_clusters, self.dim = n_clusters, dim
        self.nprobe, self.k = nprobe, k
        self.seed, self.kmeans_iters = seed, kmeans_iters
        self.centroids: Optional[jax.Array] = None
        self.lists: list[_List] = []
        self._next_id = 0

    def train(self, x: np.ndarray) -> None:
        cents = kmeans(x, self.n_clusters, n_iter=self.kmeans_iters, seed=self.seed)
        self.centroids = jnp.asarray(cents)
        self.lists = [
            _List(
                vecs=jnp.zeros((0, self.dim), jnp.float32),
                ids=jnp.zeros((0,), jnp.int32),
            )
            for _ in range(self.n_clusters)
        ]

    def _assign(self, x: jax.Array) -> np.ndarray:
        cn = jnp.sum(self.centroids * self.centroids, axis=1)
        return np.asarray(jnp.argmin(cn[None] - 2.0 * x @ self.centroids.T, axis=1))

    def add(self, x, ids=None) -> np.ndarray:
        x = jnp.asarray(x, jnp.float32)
        b = x.shape[0]
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + b, dtype=np.int32)
            self._next_id += b
        assign = self._assign(x)
        # Alg. 1 lines 8-14: for every touched list, allocate len+new and merge
        for kcl in np.unique(assign):
            sel = assign == kcl
            new_v, new_i = x[jnp.asarray(sel)], jnp.asarray(ids[sel], jnp.int32)
            lst = self.lists[int(kcl)]
            if self.host_roundtrip:
                # Faiss add: copy existing list to host, merge there, copy back
                hv = np.asarray(lst.vecs)
                hi = np.asarray(lst.ids)
                merged_v = np.concatenate([hv, np.asarray(new_v)], axis=0)
                merged_i = np.concatenate([hi, np.asarray(new_i)], axis=0)
                lst.vecs = jnp.asarray(merged_v)  # full re-upload
                lst.ids = jnp.asarray(merged_i)
            else:
                # RAFT extend: device-side realloc + merge copy
                lst.vecs = jnp.concatenate([lst.vecs, new_v], axis=0)
                lst.ids = jnp.concatenate([lst.ids, new_i], axis=0)
            lst.vecs.block_until_ready()
        return np.asarray(ids)

    def search(self, queries, nprobe=None, k=None):
        nprobe = nprobe or self.nprobe
        k = k or self.k
        q = jnp.asarray(queries, jnp.float32)
        cd = l2_sq(q, self.centroids)
        probe = np.asarray(jax.lax.top_k(-cd, nprobe)[1])
        out_d = np.full((q.shape[0], k), np.inf, np.float32)
        out_i = np.full((q.shape[0], k), -1, np.int32)
        for qi in range(q.shape[0]):
            vs, is_ = [], []
            for kcl in probe[qi]:
                lst = self.lists[int(kcl)]
                if lst.vecs.shape[0]:
                    vs.append(lst.vecs)
                    is_.append(lst.ids)
            if not vs:
                continue
            corpus = jnp.concatenate(vs, axis=0)
            cids = jnp.concatenate(is_, axis=0)
            kk = min(k, corpus.shape[0])
            d, sel = exact_search(corpus, q[qi : qi + 1], kk)
            out_d[qi, :kk] = np.asarray(d)[0]
            out_i[qi, :kk] = np.asarray(cids)[np.asarray(sel)[0]]
        return out_d, out_i

    @property
    def ntotal(self) -> int:
        return int(sum(l.vecs.shape[0] for l in self.lists))


class FaissLikeIndex(_ReallocIndexBase):
    host_roundtrip = True


class RaftLikeIndex(_ReallocIndexBase):
    host_roundtrip = False


class RtCpuIndex:
    """Paper's Rt-cpu: memory-block linked lists in numpy (CPU only)."""

    def __init__(self, n_clusters: int, dim: int, *, block_size=1024,
                 pool_blocks=None, nprobe=16, k=10, seed=0, kmeans_iters=10):
        self.n_clusters, self.dim, self.tm = n_clusters, dim, block_size
        self.nprobe, self.k = nprobe, k
        self.seed, self.kmeans_iters = seed, kmeans_iters
        self.pool_blocks = pool_blocks
        self._next_id = 0

    def train(self, x: np.ndarray) -> None:
        self.centroids = kmeans(
            x, self.n_clusters, n_iter=self.kmeans_iters, seed=self.seed
        )
        p = self.pool_blocks or (len(x) * 2 // self.tm + self.n_clusters + 16)
        self.pool_vecs = np.zeros((p, self.tm, self.dim), np.float32)
        self.pool_ids = np.full((p, self.tm), -1, np.int64)
        self.next_block = np.full((p,), -1, np.int64)
        self.head = np.full((self.n_clusters,), -1, np.int64)
        self.tail = np.full((self.n_clusters,), -1, np.int64)
        self.length = np.zeros((self.n_clusters,), np.int64)
        self.cur_p = 0

    def add(self, x, ids=None) -> np.ndarray:
        x = np.asarray(x, np.float32)
        b = len(x)
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + b, dtype=np.int64)
            self._next_id += b
        cn = (self.centroids**2).sum(1)
        assign = np.argmin(cn[None] - 2.0 * x @ self.centroids.T, axis=1)
        for i in range(b):  # thread-per-vector loop, CPU serialised
            kcl = int(assign[i])
            did = self.length[kcl]
            moff = did % self.tm
            if moff == 0:  # allocate a block (bump)
                blk = self.cur_p
                self.cur_p += 1
                if self.tail[kcl] >= 0:
                    self.next_block[self.tail[kcl]] = blk
                else:
                    self.head[kcl] = blk
                self.tail[kcl] = blk
            blk = self.tail[kcl]
            self.pool_vecs[blk, moff] = x[i]
            self.pool_ids[blk, moff] = ids[i]
            self.length[kcl] += 1
        return np.asarray(ids)

    def search(self, queries, nprobe=None, k=None):
        nprobe = nprobe or self.nprobe
        k = k or self.k
        q = np.asarray(queries, np.float32)
        cn = (self.centroids**2).sum(1)
        cd = cn[None] - 2.0 * q @ self.centroids.T
        probe = np.argsort(cd, axis=1)[:, :nprobe]
        out_d = np.full((len(q), k), np.inf, np.float32)
        out_i = np.full((len(q), k), -1, np.int64)
        for qi in range(len(q)):
            vs, is_ = [], []
            for kcl in probe[qi]:
                cur = self.head[kcl]
                while cur >= 0:
                    mask = self.pool_ids[cur] >= 0
                    vs.append(self.pool_vecs[cur][mask])
                    is_.append(self.pool_ids[cur][mask])
                    cur = self.next_block[cur]
            if not vs:
                continue
            corpus = np.concatenate(vs)
            cids = np.concatenate(is_)
            d = ((corpus - q[qi]) ** 2).sum(1)
            kk = min(k, len(d))
            sel = np.argpartition(d, kk - 1)[:kk]
            sel = sel[np.argsort(d[sel])]
            out_d[qi, :kk] = d[sel]
            out_i[qi, :kk] = cids[sel]
        return out_d, out_i

    @property
    def ntotal(self) -> int:
        return int(self.length.sum())
