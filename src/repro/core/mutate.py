"""Online mutations over the block pool: tombstone deletes and in-place
updates (beyond-paper subsystem; the paper's §3 index is insert-only).

The pool discipline stays exactly the paper's: no reallocation, no copies
of resident data, fixed shapes under ``jit``.  A delete therefore cannot
splice a row out of its chain — slot positions encode the did arithmetic
every insert relies on.  Instead:

* ``delete`` flips the slot's bit in ``IVFState.pool_live`` (the ``[P, T]``
  tombstone mask every scan streams alongside the payload) and clears the
  id's entry in the device-resident ``id_map`` — two scatters, O(batch)
  work, nothing else moves.  The slot is reclaimed later by compaction
  (``core.rearrange``), which drops dead rows and returns surplus blocks to
  the free stack.
* ``update`` = tombstone the old slot + insert the fresh row *under the
  same id* in one dispatch: the id map re-points at the new location, the
  stale copy dies, and no intermediate state where both (or neither) copy
  is visible can ever be observed — the whole step is one jitted program
  over donated state.  An update whose id is not resident degrades to a
  plain insert (upsert); the miss is counted in ``num_missed``.  If the
  re-insert is rejected at capacity (full chain / exhausted pool) the
  tombstone stands and the rejection surfaces in ``num_dropped`` — the
  same alert stat every insert rejection feeds.

Both steps take a fixed-size id batch with a validity mask (the serving
runtime pads to power-of-two buckets, same as insert), so online churn
costs O(log batch) recompiles total.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.block_pool import NULL, IVFState, PoolConfig
from repro.core.insert import assign_clusters, insert_payload, make_insert_fn


def last_occurrence_mask(ids: jax.Array, valid: jax.Array) -> jax.Array:
    """[B] bool mask keeping only the *last* valid occurrence of each id.

    An update batch may name the same id twice (two refreshes of one row
    racing into the same flush).  The tombstone pass is idempotent, but the
    re-insert is not: without dedup both rows would come back live under
    one id, the id map would keep an arbitrary winner, and the loser would
    be unreachable by any future mutation.  Last-write-wins matches the
    serialisation a caller would get submitting the updates one batch
    apart."""
    b = ids.shape[0]
    sid = jnp.where(valid, ids.astype(jnp.int32), NULL)
    order = jnp.argsort(sid, stable=True)
    srt = sid[order]
    is_last = jnp.concatenate(
        [srt[:-1] != srt[1:], jnp.ones((1,), bool)]
    )
    return valid & jnp.zeros((b,), bool).at[order].set(is_last)


def apply_delete(
    cfg: PoolConfig,
    state: IVFState,
    del_ids: jax.Array,  # [B] i32 ids to tombstone (NULL / negative = pad)
    valid: jax.Array | None = None,  # [B] bool — ragged batches (padding)
) -> IVFState:
    """Tombstone a batch of ids.  Pure function of (state, batch).

    Misses — ids never inserted, already deleted, out of ``max_ids`` map
    range, or repeated within the batch (first occurrence wins) — are
    counted in ``num_missed`` and change nothing else; a mutation stream
    that mostly misses is an upstream bug worth alerting on."""
    b = del_ids.shape[0]
    tm = cfg.block_size
    del_ids = del_ids.astype(jnp.int32)
    if valid is None:
        valid = jnp.ones((b,), bool)
    valid = valid & (del_ids >= 0)

    # first-occurrence-in-batch dedup: duplicates would double-flip nothing
    # (the scatter is idempotent) but would double-count dead_count.
    # Invalid rows are keyed to -1 first so a masked-out row can never
    # claim the first occurrence of a real id.
    sid = jnp.where(valid, del_ids, NULL)
    order = jnp.argsort(sid, stable=True)
    srt = sid[order]
    first = jnp.concatenate([jnp.ones((1,), bool), srt[1:] != srt[:-1]])
    uniq = jnp.zeros((b,), bool).at[order].set(first)

    max_ids = state.id_map.shape[0]
    in_map = valid & uniq & (del_ids < max_ids)
    loc = jnp.where(
        in_map, state.id_map[jnp.clip(del_ids, 0, max_ids - 1)], NULL
    )
    hit = in_map & (loc != NULL)
    sloc = jnp.where(hit, loc, 0)
    blk, off = sloc // tm, sloc % tm

    pool_live = state.pool_live.at[
        jnp.where(hit, blk, cfg.n_blocks), off
    ].set(jnp.uint8(0), mode="drop")
    id_map = state.id_map.at[jnp.where(hit, del_ids, max_ids)].set(
        NULL, mode="drop"
    )
    # the tombstoned slot's cluster accrues reclamation pressure (the
    # dead-fraction trigger in core.rearrange reads this)
    owner = state.block_owner[jnp.clip(blk, 0, cfg.n_blocks - 1)]
    dead_inc = jax.ops.segment_sum(
        hit.astype(jnp.int32),
        jnp.where(hit, owner, 0),
        num_segments=cfg.n_clusters,
    )
    n_hit = hit.sum().astype(jnp.int32)
    n_miss = (valid & ~hit).sum().astype(jnp.int32)
    return dataclasses.replace(
        state,
        pool_live=pool_live,
        id_map=id_map,
        dead_count=state.dead_count + dead_inc,
        num_vectors=state.num_vectors - n_hit,
        num_deleted=state.num_deleted + n_hit,
        num_missed=state.num_missed + n_miss,
    )


def make_delete_fn(cfg: PoolConfig):
    """Jitted delete step: (state, ids[, valid]) -> state, state donated."""

    def step(state: IVFState, del_ids, valid=None):
        return apply_delete(cfg, state, del_ids, valid)

    return jax.jit(step, donate_argnums=(0,))


#: Mutation kinds a WAL record may carry, in their wire-format order (the
#: durability layer maps these to/from the record header's kind byte).
REPLAY_KINDS = ("insert", "delete", "update")


def make_replay_fns(cfg: PoolConfig, encode=None) -> dict:
    """Durability replay entry points (``repro.persist.recovery``).

    One jitted batch step per mutation kind with a *uniform* signature
    ``(state, vectors, ids, valid) -> state`` (delete ignores ``vectors``),
    built from the exact same step constructors the online lane uses — a
    replayed WAL record goes through the same program as the original
    dispatch, so recovery can never diverge from what serving applied.
    """
    insert_step = make_insert_fn(cfg, encode=encode)
    delete_step = make_delete_fn(cfg)
    update_step = make_update_fn(cfg, encode=encode)

    def _insert(state, vectors, ids, valid=None):
        return insert_step(state, vectors, ids, valid)

    def _delete(state, vectors, ids, valid=None):
        del vectors  # a delete record carries only ids
        return delete_step(state, ids, valid)

    def _update(state, vectors, ids, valid=None):
        return update_step(state, vectors, ids, valid)

    return {"insert": _insert, "delete": _delete, "update": _update}


def make_update_fn(cfg: PoolConfig, encode=None):
    """Jitted update step: tombstone + re-insert under the same id, one
    dispatch.  ``encode`` matches ``make_insert_fn``'s hook (PQ / residual
    encoding of the raw rows)."""

    def step(state: IVFState, vectors, ids, valid=None):
        if valid is None:
            valid = jnp.ones((ids.shape[0],), bool)
        state = apply_delete(cfg, state, ids, valid)
        # duplicate targets within the batch: only the last write re-inserts
        keep = last_occurrence_mask(ids, valid)
        assign = assign_clusters(state.centroids, vectors)
        payload = vectors if encode is None else encode(state, assign, vectors)
        return insert_payload(cfg, state, assign, payload, ids, keep)

    return jax.jit(step, donate_argnums=(0,))
