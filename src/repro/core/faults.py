"""Deterministic fault injection for the serving runtime.

Every failure path the fault-tolerance layer claims to handle — a jitted
step raising mid-dispatch, a worker loop body crashing, dispatch delayed
past a request deadline, the resource pool pinned exhausted — is reachable
on purpose through a :class:`FaultPlan`, so the tier-1 suite exercises them
deterministically instead of by luck (racing malformed payloads against
batch boundaries was the previous state of the art).

The runtime calls ``plan.check(site)`` at a small set of named sites; a
plan with no rules is a per-site counter increment and nothing else, and
the default plan has no rules, so production dispatch pays one dict update
per batch.  Sites (see ``repro.core.runtime``):

``search_step``
    Immediately before a search-batch dispatch.  Call 0 is the first batch
    attempt; per-item isolation retries check the same site, so with a
    batch of B the retry of item *j* is call ``1 + j`` after a call-0
    failure — which is how a test poisons exactly one item of a batch.
``mutation_step``
    Same contract for the mutation lane (insert / delete / update runs).
``fused_step``
    Before a fused search+mutation dispatch; a failure here falls back to
    the two separate lanes (each with its own isolation).
``search_loop`` / ``insert_loop``
    Top of each worker loop iteration, *outside* the per-batch try blocks:
    a raise here kills the worker thread and must be survived by the
    supervisor (restart, counter, backoff).  A ``delay`` rule here ages
    queued requests past their deadlines without touching wall-clock
    tuning.

The durability layer (``repro.persist``) adds four more sites:

``wal_append``
    Immediately before a mutation batch's WAL record is written — a raise
    here models a crash before anything hit disk (the batch is neither
    durable nor applied, and its futures fail).
``wal_fsync``
    Immediately before the batched ``fsync`` — a raise models power loss
    with bytes in the page cache (tests pair it with byte-level truncation
    of the log tail).
``snapshot_publish``
    On the snapshot publisher thread, before the checkpoint write — a
    crash here must leave the previous snapshot *and* the whole WAL intact.
``recovery_replay``
    Before each replayed WAL batch during ``recover()`` — a crash
    mid-replay must be re-recoverable from the same directory.

Rules trigger on exact call indices (``nth``, 0-based, int or iterable)
or on every call (``nth=None``).  Call counting is per-site under a lock:
the trigger sequence depends only on dispatch order, never on timing.

Sites are **registered**: ``fail``/``delay`` raise ``ValueError`` at
rule-creation time on a site outside :data:`KNOWN_SITES` — a typo'd site
would otherwise silently never fire and the test would pass vacuously.
Test-private sites (exercising a harness, not the runtime) use the escape
hatch ``FaultPlan(extra_sites=("my_site",))``.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Iterable, Optional

#: Every site the runtime and durability layer actually check.  Adding a
#: ``plan.check("new_site")`` call site means adding it here (and to the
#: site catalog in docs/serving_ops.md).
KNOWN_SITES = frozenset({
    # serving runtime (repro.core.runtime)
    "search_step", "mutation_step", "fused_step",
    "search_loop", "insert_loop", "admission",
    # durability layer (repro.persist)
    "wal_append", "wal_fsync", "snapshot_publish", "recovery_replay",
})


class FaultError(RuntimeError):
    """Raised by an injected ``fail`` rule (default exception type)."""


@dataclasses.dataclass(frozen=True)
class _Rule:
    site: str
    action: str  # "fail" | "delay"
    nth: Optional[frozenset]  # call indices; None = every call
    exc: Optional[BaseException] = None
    delay_s: float = 0.0

    def matches(self, call_index: int) -> bool:
        return self.nth is None or call_index in self.nth


class FaultPlan:
    """An injectable schedule of failures, keyed by (site, call index)."""

    def __init__(self, extra_sites: Iterable[str] = ()):
        self._lock = threading.Lock()
        self._rules: list[_Rule] = []
        self._calls: collections.defaultdict = collections.defaultdict(int)
        # escape hatch for test-private sites (a harness checking its own
        # plan); immutable after construction so validation stays simple
        self._extra_sites = frozenset(extra_sites)
        # called once per *triggered* rule, outside the plan lock, with
        # (site, action, call_index) — the runtime points this at its
        # flight recorder so injected faults land next to the transitions
        # they caused.  Per-plan, never set on the shared NO_FAULTS.
        self._observer: Optional[Callable] = None

    # -------------------------------------------------------- authoring --
    @staticmethod
    def _nth_set(nth) -> Optional[frozenset]:
        if nth is None:
            return None
        if isinstance(nth, Iterable):
            return frozenset(int(i) for i in nth)
        return frozenset((int(nth),))

    def _validate_site(self, site: str) -> None:
        if site not in KNOWN_SITES and site not in self._extra_sites:
            raise ValueError(
                f"unknown fault site {site!r}: the runtime never checks it, "
                "so this rule would silently never fire.  Known sites: "
                f"{sorted(KNOWN_SITES)}; register test-private sites via "
                "FaultPlan(extra_sites=...)"
            )

    def fail(self, site: str, nth=0, *, exc: Optional[BaseException] = None,
             message: str = "") -> "FaultPlan":
        """Raise at ``site`` on call index(es) ``nth`` (0-based; iterable
        for several; ``None`` for every call).  ``exc`` overrides the
        raised exception instance."""
        self._validate_site(site)
        e = exc if exc is not None else FaultError(
            message or f"injected failure @ {site}"
        )
        with self._lock:
            self._rules.append(_Rule(site, "fail", self._nth_set(nth), exc=e))
        return self

    def delay(self, site: str, seconds: float, nth=None) -> "FaultPlan":
        """Sleep ``seconds`` at ``site`` on matching calls (default: every
        call) — ages queued requests / pins resource slots without raising."""
        self._validate_site(site)
        with self._lock:
            self._rules.append(
                _Rule(site, "delay", self._nth_set(nth), delay_s=seconds)
            )
        return self

    def set_observer(self, observer: Optional[Callable]) -> None:
        """Install the triggered-rule callback (see ``__init__``).  The
        runtime refuses to install one on the shared :data:`NO_FAULTS`
        instance — a global default must never carry per-runtime state."""
        with self._lock:
            self._observer = observer

    # --------------------------------------------------------- runtime ---
    def check(self, site: str) -> None:
        """Runtime hook: count the call, apply matching rules (delays
        first, then at most one raise — the earliest-authored match)."""
        with self._lock:
            i = self._calls[site]
            self._calls[site] += 1
            if not self._rules:
                return
            hits = [r for r in self._rules
                    if r.site == site and r.matches(i)]
            observer = self._observer
        if observer is not None:
            for r in hits:
                observer(site, r.action, i)
        for r in hits:
            if r.action == "delay":
                time.sleep(r.delay_s)
        for r in hits:
            if r.action == "fail":
                raise r.exc

    # ----------------------------------------------------- introspection --
    def calls(self, site: str) -> int:
        """How many times the runtime reached ``site`` so far."""
        with self._lock:
            return self._calls[site]

    def reset(self) -> None:
        with self._lock:
            self._rules.clear()
            self._calls.clear()


#: Shared no-op plan (no rules ever added): the runtime default.
NO_FAULTS = FaultPlan()
