"""Memory-block based dynamic vector insertion (paper Alg. 2).

The paper's GPU kernel is thread-per-vector with two atomics:

* ``did = atomicAdd(nl_k, 1)`` — slot assignment inside the cluster;
* ``P[atomicAdd(cur_P, 1)]`` — lock-free block allocation when a thread
  crosses a block boundary (``moff == 0``).

On TPU the SPMD analogue is a *deterministic* batch transform: a stable sort
by cluster gives every incoming vector its within-batch rank, so
``did = cluster_len[k] + rank`` reproduces the exact post-state of the atomic
protocol (the paper's insertion order inside one batch is arbitrary; ours is
batch order, which is one of the admissible serialisations).  Everything is
a handful of vectorised scatters — no data copies of resident vectors, no
reallocation, and the whole step runs under ``jit`` with the state donated.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.block_pool import (
    NULL,
    IVFState,
    PoolConfig,
    alloc_available,
    alloc_blocks,
    commit_alloc,
    quantize_int8,
)


def assign_clusters(centroids: jax.Array, vectors: jax.Array) -> jax.Array:
    """k <- argmin_c ||y - c||^2  (Alg. 2 line 5)."""
    # ||y-c||^2 = ||y||^2 - 2 y.c + ||c||^2 ; ||y||^2 constant per row.
    dots = vectors @ centroids.T
    cn = jnp.sum(centroids * centroids, axis=-1)
    return jnp.argmin(cn[None, :] - 2.0 * dots, axis=-1).astype(jnp.int32)


def insert_payload(
    cfg: PoolConfig,
    state: IVFState,
    assign: jax.Array,  # [B] i32 cluster of each new vector
    payload: jax.Array,  # [B, D] vectors | [B, M] u8 codes
    new_ids: jax.Array,  # [B] i32 global ids
    valid: jax.Array | None = None,  # [B] bool — ragged batches (padding)
) -> IVFState:
    """Insert a batch into the pool.  Pure function of (state, batch)."""
    b = assign.shape[0]
    tm = cfg.block_size
    if valid is None:
        valid = jnp.ones((b,), bool)
    # Padding rows are parked on cluster 0 but masked out of every scatter.
    assign = jnp.where(valid, assign, 0)

    # Within-batch rank of each valid row inside its cluster: stable sort by
    # (assign, ~valid) so valid rows of a cluster precede padding; padding
    # rows receive ranks past the valid run, which every scatter masks out.
    key = assign * 2 + (~valid).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    idx = jnp.arange(b, dtype=jnp.int32)
    sorted_key = key[order]
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_key[1:] != sorted_key[:-1]]
    )
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    rank = jnp.zeros((b,), jnp.int32).at[order].set(idx - run_start)

    # Hard per-cluster capacity: a chain can hold max_chain * T_m vectors.
    # Rows past capacity are *rejected* and counted (the paper's resource-
    # exhaustion rejection, §3.3); because the capacity filter removes the
    # highest ranks of a cluster, surviving dids stay contiguous.
    old_len = state.cluster_len
    cap_vecs = cfg.max_chain * tm
    pre_did = old_len[assign] + rank
    vec_ok = valid & (pre_did < cap_vecs)
    want = vec_ok  # chain-capacity survivors; pool capacity filters below
    counts_want = jax.ops.segment_sum(
        want.astype(jnp.int32), assign, num_segments=cfg.n_clusters
    )
    old_nblk = state.cluster_nblocks
    want_nblk = (old_len + counts_want + tm - 1) // tm
    nblk_needed = want_nblk - old_nblk  # [N] >= 0 demanded new blocks
    # exclusive cumsum -> allocation rank base per cluster
    cum = jnp.cumsum(nblk_needed)
    base = cum - nblk_needed
    total_new = cum[-1]

    # Pool exhaustion: allocation ranks are served free-stack-first then
    # bump, so failure is a *suffix* of [0, total_new).  Clip the demand to
    # what the allocator can actually hand out; rows that would land in a
    # failed block are rejected below (again a per-cluster rank suffix, so
    # surviving dids stay contiguous).
    succ_total = jnp.minimum(total_new, alloc_available(state))
    succ_nblk = jnp.clip(succ_total - base, 0, nblk_needed)  # [N] granted
    usable_cap = jnp.minimum((old_nblk + succ_nblk) * tm, cap_vecs)
    vec_ok = valid & vec_ok & (pre_did < usable_cap[assign])
    n_rejected = (valid & ~vec_ok).sum().astype(jnp.int32)
    valid = vec_ok
    counts = jax.ops.segment_sum(
        valid.astype(jnp.int32), assign, num_segments=cfg.n_clusters
    )
    new_len = old_len + counts
    new_nblk = (new_len + tm - 1) // tm

    # ---- allocate new physical blocks (Alg. 2 lines 10-15) --------------
    # at most B new blocks per batch; enumerate candidate slots j in [0, B)
    j = jnp.arange(b, dtype=jnp.int32)
    j_valid = j < total_new
    # cluster owning allocation rank j: searchsorted over inclusive cumsum
    owner = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
    owner = jnp.clip(owner, 0, cfg.n_clusters - 1)
    jj = j - base[owner]  # index of this new block within its cluster's run
    phys = alloc_blocks(state, j, j_valid)  # NULL past pool capacity

    # block-table scatter: cluster_blocks[owner, old_nblk[owner] + jj] = phys
    # (failed allocations write NULL into slots past new_nblk — a no-op)
    tbl_rows = jnp.where(j_valid, owner, cfg.n_clusters)
    tbl_cols = jnp.where(j_valid, old_nblk[owner] + jj, cfg.max_chain)
    cluster_blocks = state.cluster_blocks.at[tbl_rows, tbl_cols].set(
        phys, mode="drop"
    )

    # block->owner map, maintained incrementally (the fused search prologue
    # prefetches it per candidate instead of rebuilding a [P] scatter from
    # the block table on every dispatch)
    own_rows = jnp.where(j_valid & (phys != NULL), phys, cfg.n_blocks)
    block_owner = state.block_owner.at[own_rows].set(owner, mode="drop")

    # linked-list scatter (paper header relink, Alg. 2 line 14):
    # predecessor of run element jj>0 is phys of rank j-1 (same cluster by
    # construction of contiguous runs); predecessor of jj==0 is the old tail
    # (if the chain was non-empty).
    prev_same_run = alloc_blocks(state, j - 1, j_valid & (jj > 0))
    old_tail = state.cluster_tail[owner]
    prev_blk = jnp.where(jj > 0, prev_same_run, old_tail)
    link_valid = j_valid & (prev_blk != NULL) & (phys != NULL)
    next_block = state.next_block.at[
        jnp.where(link_valid, prev_blk, cfg.n_blocks)
    ].set(phys, mode="drop")

    # head/tail updates (only for blocks that were actually granted)
    first_valid = j_valid & (jj == 0) & (old_nblk[owner] == 0) & (phys != NULL)
    cluster_head = state.cluster_head.at[
        jnp.where(first_valid, owner, cfg.n_clusters)
    ].set(phys, mode="drop")
    last_valid = j_valid & (jj == succ_nblk[owner] - 1)
    cluster_tail = state.cluster_tail.at[
        jnp.where(last_valid, owner, cfg.n_clusters)
    ].set(phys, mode="drop")

    # ---- scatter the vectors themselves (Alg. 2 lines 6-8, 20) ----------
    did = old_len[assign] + rank
    mid = did // tm
    moff = did % tm
    vec_blk = cluster_blocks[assign, jnp.clip(mid, 0, cfg.max_chain - 1)]
    rows = jnp.where(valid, vec_blk, cfg.n_blocks)
    # quantize-on-insert (int8 flat payloads): the raw f32 rows are encoded
    # once here — as *residuals* against their coarse centroid (Faiss
    # IVF-SQ by_residual semantics: the residual dynamic range is a
    # fraction of the raw vectors', so the 8-bit step shrinks with it) —
    # and only the codes + per-vector scales become resident; resident data
    # is never re-encoded or copied (paper Alg. 2 invariant)
    pool_scales = state.pool_scales
    if cfg.has_scales:
        residuals = payload.astype(jnp.float32) - state.centroids[assign]
        payload, scales = quantize_int8(residuals)
        pool_scales = pool_scales.at[rows, moff].set(scales, mode="drop")
    pool_payload = state.pool_payload.at[rows, moff].set(
        payload.astype(state.pool_payload.dtype), mode="drop"
    )
    pool_ids = state.pool_ids.at[rows, moff].set(
        jnp.where(valid, new_ids, NULL), mode="drop"
    )
    # every accepted row is born live, and its id maps to its packed pool
    # location so delete/update can find it without a host round trip
    # (ids >= max_ids stay resident but unmappable — mutations miss them)
    pool_live = state.pool_live.at[rows, moff].set(
        jnp.uint8(1), mode="drop"
    )
    loc = rows * tm + moff
    max_ids = state.id_map.shape[0]
    map_ok = valid & (new_ids >= 0) & (new_ids < max_ids)
    id_map = state.id_map.at[jnp.where(map_ok, new_ids, max_ids)].set(
        loc.astype(jnp.int32), mode="drop"
    )
    # monotonically-assigned ids WILL outgrow the direct-address map under
    # sustained churn; the gauge makes that loud before deletes start
    # silently missing
    n_unmapped = (valid & ~map_ok).sum().astype(jnp.int32)

    n_inserted = valid.sum().astype(jnp.int32)
    return dataclasses.replace(
        state,
        pool_payload=pool_payload,
        pool_ids=pool_ids,
        pool_scales=pool_scales,
        pool_live=pool_live,
        id_map=id_map,
        block_owner=block_owner,
        next_block=next_block,
        cluster_head=cluster_head,
        cluster_tail=cluster_tail,
        cluster_blocks=cluster_blocks,
        cluster_nblocks=new_nblk,
        cluster_len=new_len,
        new_since_rearrange=state.new_since_rearrange + counts,
        num_vectors=state.num_vectors + n_inserted,
        num_dropped=state.num_dropped + n_rejected,
        num_unmapped=state.num_unmapped + n_unmapped,
        **commit_alloc(state, succ_total),
    )


def make_insert_fn(cfg: PoolConfig, encode=None):
    """Jitted end-to-end insert step: raw vectors -> updated state.

    ``encode(state, assign, vectors) -> payload`` converts raw vectors to the
    pool payload (identity for ivfflat; residual-PQ encode for ivfpq).  The
    state is donated so XLA writes the pool in place (paper property: no
    reallocation, no copying of resident data).
    """

    def step(state: IVFState, vectors, new_ids, valid=None):
        assign = assign_clusters(state.centroids, vectors)
        payload = vectors if encode is None else encode(state, assign, vectors)
        return insert_payload(cfg, state, assign, payload, new_ids, valid)

    return jax.jit(step, donate_argnums=(0,))
