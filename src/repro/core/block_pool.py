"""Central memory pool split into fixed-size blocks (paper §3.1, Fig. 1b).

The paper pre-splits almost the entire GPU memory into blocks of ``T_m``
vectors and allocates them with a lock-free ``atomicAdd(cur_P)`` bump
pointer.  On TPU/XLA there is *no* dynamic device allocation inside a
program, so the pool discipline is mandatory: every array below has a fixed
shape, and "allocation" is pure index arithmetic on ``cur_p`` (plus a free
stack fed by rearrangement).  The whole state is a pytree that flows through
jitted, buffer-donated update steps — XLA updates it in place, which is the
functional equivalent of the paper's "no realloc, no copy" property.

Two chain representations are kept simultaneously:

* ``next_block`` — the paper-faithful linked list of block headers
  (prev/next pointer jumps).  Used by the chain-walk search baseline and by
  rearrangement.
* ``cluster_blocks`` — a dense per-cluster *block table* (PagedAttention
  style).  This is the TPU adaptation: pointer chasing is hostile to a
  vector machine, while a block table lets search gather an entire chain in
  one HLO gather.  Both are maintained by every mutation and are checked
  against each other in tests.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

NULL = jnp.int32(-1)  # null block pointer / empty id slot

# admissible flat-payload dtypes (the dtype axis of the whole stack):
# float32 is exact, bfloat16 halves and int8 quarters the HBM bytes of the
# dominant scan loop.  int8 rows are symmetric per-vector quantized
# (code = round(v / s), s = max|v| / 127) with the scale stored in
# ``IVFState.pool_scales`` alongside ``pool_ids``.
FLAT_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "int8": jnp.int8,
}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static geometry of the central pool (hashable; static under jit)."""

    n_clusters: int  # N  — number of IVF lists
    dim: int  # D  — raw vector dimensionality
    block_size: int  # T_m — vectors per memory block (paper uses 1024)
    n_blocks: int  # P  — blocks in the central pool
    max_chain: int  # longest admissible block chain per cluster
    payload: str = "flat"  # "flat" (raw vectors) | "pq" (codes)
    pq_m: int = 0  # number of PQ subquantizers (payload == "pq")
    dtype: Any = jnp.float32  # flat payload dtype: float32 | bfloat16 | int8
    # capacity of the device-resident id -> pool-location map (delete/update
    # targets must have id < max_ids; 0 = auto-size to 2x the pool's slot
    # capacity, enough for one full generation of churn between id reuse)
    max_ids: int = 0

    def __post_init__(self):
        if self.max_ids <= 0:
            object.__setattr__(
                self, "max_ids", 2 * self.n_blocks * self.block_size
            )
        if self.payload not in ("flat", "pq"):
            raise ValueError(f"unknown payload {self.payload!r}")
        if self.payload == "pq" and self.pq_m <= 0:
            raise ValueError("pq payload requires pq_m > 0")
        if isinstance(self.dtype, str):
            if self.dtype not in FLAT_DTYPES:
                raise ValueError(
                    f"flat payload dtype must be one of "
                    f"{sorted(FLAT_DTYPES)}, got {self.dtype!r}"
                )
            object.__setattr__(self, "dtype", FLAT_DTYPES[self.dtype])
        if self.payload == "flat" and self.dtype not in FLAT_DTYPES.values():
            raise ValueError(
                f"flat payload dtype must be one of {sorted(FLAT_DTYPES)}, "
                f"got {self.dtype}"
            )

    # fields that define pytree-static identity
    def payload_shape(self) -> tuple:
        if self.payload == "flat":
            return (self.n_blocks, self.block_size, self.dim)
        return (self.n_blocks, self.block_size, self.pq_m)

    def payload_dtype(self):
        return self.dtype if self.payload == "flat" else jnp.uint8

    @property
    def has_scales(self) -> bool:
        """int8 flat payloads carry a per-vector dequantization scale."""
        return self.payload == "flat" and self.dtype == jnp.int8

    def scales_shape(self) -> tuple:
        # zero-size when unused so the state pytree stays lean; every
        # access is statically gated on ``has_scales``
        if self.has_scales:
            return (self.n_blocks, self.block_size)
        return (0, 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IVFState:
    """Dynamic pool + index state.  All leaves are fixed-shape jax arrays."""

    centroids: jax.Array  # [N, D] coarse quantizer
    pool_payload: jax.Array  # [P, T_m, D] vectors | [P, T_m, M] u8 codes
    pool_ids: jax.Array  # [P, T_m] i32 global ids, NULL = empty slot
    pool_scales: jax.Array  # [P, T_m] f32 int8 dequant scales ([0,0] unused)
    pool_live: jax.Array  # [P, T_m] u8 live mask: 1 = occupied & not deleted
    id_map: jax.Array  # [max_ids] i32 id -> packed location, NULL = absent
    block_owner: jax.Array  # [P] i32 owning cluster per block, NULL = free
    next_block: jax.Array  # [P] i32 linked-list next pointer (paper header)
    cluster_head: jax.Array  # [N] i32 first block of each chain
    cluster_tail: jax.Array  # [N] i32 last block of each chain
    cluster_blocks: jax.Array  # [N, max_chain] i32 block table (TPU path)
    cluster_nblocks: jax.Array  # [N] i32 chain length |m'_k|
    cluster_len: jax.Array  # [N] i32 slots used per cluster (incl. tombstones)
    dead_count: jax.Array  # [N] i32 tombstoned slots awaiting compaction
    new_since_rearrange: jax.Array  # [N] i32 Exceed() statistic (Eq. 3)
    cur_p: jax.Array  # []  i32 bump pointer cur_P
    free_stack: jax.Array  # [P] i32 recycled block ids (top at free_top-1)
    free_top: jax.Array  # []  i32
    num_vectors: jax.Array  # []  i32 *live* vectors resident (deletes decrement)
    num_dropped: jax.Array  # []  i32 inserts rejected at capacity (alert stat)
    num_deleted: jax.Array  # []  i32 cumulative successful deletes/tombstones
    num_missed: jax.Array  # []  i32 delete/update targets not found (alert)
    num_unmapped: jax.Array  # [] i32 rows inserted with id >= max_ids: they
    # serve fine but can never be deleted/updated (alert — size max_ids up)


def init_state(cfg: PoolConfig, centroids: jax.Array) -> IVFState:
    """Empty pool: nothing allocated, every chain empty."""
    n, p, mc = cfg.n_clusters, cfg.n_blocks, cfg.max_chain
    if centroids.shape != (n, cfg.dim):
        raise ValueError(
            f"centroids {centroids.shape} != {(n, cfg.dim)} from config"
        )
    return IVFState(
        # the coarse quantizer stays full precision regardless of the
        # payload dtype — quantization applies to pool rows, not centroids
        centroids=jnp.asarray(centroids, jnp.float32),
        pool_payload=jnp.zeros(cfg.payload_shape(), cfg.payload_dtype()),
        pool_ids=jnp.full((p, cfg.block_size), NULL, jnp.int32),
        pool_scales=jnp.zeros(cfg.scales_shape(), jnp.float32),
        pool_live=jnp.zeros((p, cfg.block_size), jnp.uint8),
        id_map=jnp.full((cfg.max_ids,), NULL, jnp.int32),
        block_owner=jnp.full((p,), NULL, jnp.int32),
        next_block=jnp.full((p,), NULL, jnp.int32),
        cluster_head=jnp.full((n,), NULL, jnp.int32),
        cluster_tail=jnp.full((n,), NULL, jnp.int32),
        cluster_blocks=jnp.full((n, mc), NULL, jnp.int32),
        cluster_nblocks=jnp.zeros((n,), jnp.int32),
        cluster_len=jnp.zeros((n,), jnp.int32),
        dead_count=jnp.zeros((n,), jnp.int32),
        new_since_rearrange=jnp.zeros((n,), jnp.int32),
        cur_p=jnp.zeros((), jnp.int32),
        free_stack=jnp.full((p,), NULL, jnp.int32),
        free_top=jnp.zeros((), jnp.int32),
        num_vectors=jnp.zeros((), jnp.int32),
        num_dropped=jnp.zeros((), jnp.int32),
        num_deleted=jnp.zeros((), jnp.int32),
        num_missed=jnp.zeros((), jnp.int32),
        num_unmapped=jnp.zeros((), jnp.int32),
    )


def quantize_int8(rows: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-vector int8 quantization: rows [..., D] f32 ->
    (codes [..., D] i8, scales [...] f32) with v ~= codes * scale.

    The scale floor keeps all-zero rows representable (codes 0, scale tiny)
    without a divide-by-zero."""
    scale = jnp.max(jnp.abs(rows), axis=-1) / 127.0
    scale = jnp.maximum(scale, jnp.float32(1e-30))
    codes = jnp.clip(jnp.round(rows / scale[..., None]), -127, 127)
    return codes.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_int8(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """codes [..., D] i8, scales [...] f32 -> reconstructed rows f32."""
    return codes.astype(jnp.float32) * scales[..., None]


def alloc_blocks(state: IVFState, j: jax.Array, valid: jax.Array) -> jax.Array:
    """Vectorised lock-free allocator (paper Alg. 2 line 13).

    ``j`` are *allocation ranks* 0..total_new-1 for this batch; rank j takes
    the j-th free-stack entry if available, else bump slot ``cur_p + spill``.
    Deterministic equivalent of ``atomicAdd(cur_P, 1)`` per thread.
    Returns physical block ids (NULL where ``valid`` is False *or* the bump
    pointer would run off the pool — an unchecked ``bump_idx >= n_blocks``
    would flow into ``cluster_blocks`` and make later clamped gathers return
    wrong vectors silently).
    """
    n_blocks = state.free_stack.shape[0]
    from_free = j < state.free_top
    free_idx = jnp.clip(state.free_top - 1 - j, 0, n_blocks - 1)
    bump_idx = state.cur_p + jnp.maximum(j - state.free_top, 0)
    phys = jnp.where(from_free, state.free_stack[free_idx], bump_idx)
    ok = valid & (from_free | (bump_idx < n_blocks))
    return jnp.where(ok, phys, NULL)


def alloc_available(state: IVFState) -> jax.Array:
    """How many blocks the allocator can still hand out (free + bump)."""
    n_blocks = state.free_stack.shape[0]
    return state.free_top + jnp.maximum(n_blocks - state.cur_p, 0)


def commit_alloc(state: IVFState, total_new: jax.Array) -> dict:
    """Post-allocation counter updates (to be merged with dataclasses.replace).

    ``total_new`` must be the count of *successful* allocations (callers clip
    demand against ``alloc_available``), so ``cur_p`` saturates at the pool
    size instead of running past it.
    """
    n_from_free = jnp.minimum(total_new, state.free_top)
    return dict(
        free_top=state.free_top - n_from_free,
        cur_p=state.cur_p + (total_new - n_from_free),
    )


def capacity_ok(state: IVFState, cfg: PoolConfig) -> jax.Array:
    """True while the allocator can still hand out at least one block (alert
    analogue: the paper fires an alarm at 90% utilisation).  ``cur_p`` never
    exceeds ``n_blocks`` (overflowed allocations are masked to NULL and the
    affected rows rejected), so exhaustion shows up as a full bump region
    with an empty free stack."""
    return (state.free_top > 0) | (state.cur_p < cfg.n_blocks)


def utilisation(state: IVFState, cfg: PoolConfig) -> jax.Array:
    """Fraction of pool *slot capacity* holding live vectors.

    Before tombstones existed this counted allocated blocks, which silently
    overstates occupancy the moment anything is deleted: a tombstoned slot
    still sits in its chain but holds nothing retrievable.  ``num_vectors``
    tracks exactly the live population (inserts increment, deletes
    decrement, compaction is neutral), so this gauge stays truthful under
    churn.  Allocator *pressure* (can a block still be handed out) is what
    ``capacity_ok`` answers; ``pool_stats`` reports both."""
    cap = float(cfg.n_blocks * cfg.block_size)
    return state.num_vectors.astype(jnp.float32) / cap


def dead_fraction(state: IVFState) -> jax.Array:
    """Tombstoned fraction of all chain-resident slots (the reclamation
    pressure gauge: compaction drives it back to zero)."""
    used = jnp.maximum(state.cluster_len.sum(), 1)
    return state.dead_count.sum().astype(jnp.float32) / used.astype(
        jnp.float32
    )


def pool_stats(state: IVFState, cfg: PoolConfig) -> dict:
    """Host-side gauge snapshot (one device sync for a handful of scalars)."""
    s = jax.device_get(
        (
            state.cur_p,
            state.free_top,
            state.num_vectors,
            state.num_dropped,
            state.num_deleted,
            state.num_missed,
            state.num_unmapped,
            state.dead_count.sum(),
            state.cluster_len.sum(),
        )
    )
    (cur_p, free_top, live, dropped, deleted, missed, unmapped, dead,
     used) = (int(v) for v in s)
    return {
        "blocks_in_use": cur_p - free_top,
        "blocks_free": free_top + max(cfg.n_blocks - cur_p, 0),
        "utilisation": live / float(cfg.n_blocks * cfg.block_size),
        "dead_fraction": dead / max(used, 1),
        "live_vectors": live,
        "dead_slots": dead,
        "num_dropped": dropped,
        "num_deleted": deleted,
        "num_missed": missed,
        "num_unmapped": unmapped,
    }


# ---------------------------------------------------------------------------
# Host-side invariant checker (used by tests and the serving runtime's
# debug mode) — walks the linked list with numpy and cross-checks the block
# table, chain lengths, and slot validity.
# ---------------------------------------------------------------------------


def check_invariants(state: IVFState, cfg: PoolConfig) -> None:
    s = jax.device_get(state)
    n = cfg.n_clusters
    seen_blocks: set[int] = set()
    for k in range(n):
        length = int(s.cluster_len[k])
        nblk = int(s.cluster_nblocks[k])
        expect_nblk = -(-length // cfg.block_size)  # ceil
        assert nblk == expect_nblk, (k, nblk, expect_nblk, length)
        # walk the faithful linked list
        chain = []
        cur = int(s.cluster_head[k])
        while cur != -1:
            assert cur not in seen_blocks, f"block {cur} in two chains"
            seen_blocks.add(cur)
            chain.append(cur)
            # every chained block knows its owner (the in-kernel membership
            # test of the fused prologue rides on this invariant)
            assert int(s.block_owner[cur]) == k, (
                k, cur, int(s.block_owner[cur])
            )
            cur = int(s.next_block[cur])
            assert len(chain) <= cfg.max_chain, f"cluster {k} chain overflow"
        assert len(chain) == nblk, (k, chain, nblk)
        if nblk:
            assert int(s.cluster_tail[k]) == chain[-1]
        else:
            assert int(s.cluster_tail[k]) == -1
        # block table mirrors the list
        table = [int(b) for b in s.cluster_blocks[k][:nblk]]
        assert table == chain, (k, table, chain)
        assert all(int(b) == -1 for b in s.cluster_blocks[k][nblk:])
        # slot occupancy: block j holds dids [j*T, min(len, (j+1)*T)).
        # Tombstoned slots keep their (stale) id but are dead in the live
        # mask; slots past the filled run are empty AND dead.
        dead_k = 0
        for j, b in enumerate(chain):
            filled = min(length - j * cfg.block_size, cfg.block_size)
            ids = s.pool_ids[b]
            live = s.pool_live[b]
            assert (ids[:filled] >= 0).all(), (k, j, b, ids)
            assert (ids[filled:] == -1).all(), (k, j, b, ids)
            assert (live[filled:] == 0).all(), (k, j, b, live)
            for t in range(filled):
                vid = int(ids[t])
                loc = b * cfg.block_size + t
                if live[t]:
                    # live slot <-> id map points exactly here (ids past
                    # max_ids are legal but unmappable, hence immutable)
                    if vid < cfg.max_ids:
                        assert int(s.id_map[vid]) == loc, (
                            k, b, t, vid, int(s.id_map[vid]), loc
                        )
                else:
                    dead_k += 1
                    # a tombstone's stale id must never map back to it
                    # (update re-points the id at its fresh copy; delete
                    # clears the entry)
                    if vid < cfg.max_ids:
                        assert int(s.id_map[vid]) != loc, (k, b, t, vid)
        assert dead_k == int(s.dead_count[k]), (k, dead_k, int(s.dead_count[k]))
    # num_vectors counts the *live* population only
    total = int(s.num_vectors)
    assert total == int(s.cluster_len.sum()) - int(s.dead_count.sum())
    # id map reverse direction: every mapped id resolves to a live slot of
    # a chained block holding exactly that id
    mapped = np.flatnonzero(np.asarray(s.id_map) != -1)
    for vid in mapped:
        loc = int(s.id_map[vid])
        b, t = loc // cfg.block_size, loc % cfg.block_size
        assert b in seen_blocks, (int(vid), loc)
        assert int(s.pool_ids[b, t]) == int(vid), (int(vid), loc)
        assert int(s.pool_live[b, t]) == 1, (int(vid), loc)
    # free stack entries are disjoint from live chains
    free = {int(b) for b in s.free_stack[: int(s.free_top)]}
    assert not (free & seen_blocks), "freed block still chained"
    # unchained blocks (never allocated, or freed) own nothing and hold no
    # live rows — a stale owner would make the in-kernel membership test
    # admit a dead block
    for b in range(s.block_owner.shape[0]):
        if b not in seen_blocks:
            assert int(s.block_owner[b]) == -1, (b, int(s.block_owner[b]))
            assert (s.pool_live[b] == 0).all(), b


def snapshot_ids(state: IVFState, cfg: PoolConfig) -> dict[int, list[int]]:
    """cluster -> ordered list of *live* vector ids (host-side test oracle).

    Tombstoned slots keep a stale id in ``pool_ids`` until compaction, so
    the live mask — not id validity — is what decides residency."""
    s = jax.device_get(state)
    out: dict[int, list[int]] = {}
    for k in range(cfg.n_clusters):
        ids: list[int] = []
        cur = int(s.cluster_head[k])
        while cur != -1:
            ids.extend(
                int(i)
                for i, lv in zip(s.pool_ids[cur], s.pool_live[cur])
                if int(i) != -1 and lv
            )
            cur = int(s.next_block[cur])
        out[k] = ids
    return out
