"""dien [arXiv:1809.03672]: GRU interest extraction + AUGRU evolution."""

import dataclasses

from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys.models import RecConfig

FULL = RecConfig(
    name="dien",
    kind="dien",
    n_dense=0,
    # field 0 = item vocab (shared by target + behaviour history)
    vocab_sizes=(1_000_000, 100_000, 10_000),
    embed_dim=18,
    mlp_sizes=(200, 80),
    seq_len=100,
    gru_dim=108,
)

SMOKE = dataclasses.replace(
    FULL, vocab_sizes=(128, 32, 16), embed_dim=8, mlp_sizes=(32, 16),
    seq_len=10, gru_dim=12,
)

register(
    ArchSpec(
        arch_id="dien",
        family="recsys",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(RECSYS_SHAPES),
        source="arXiv:1809.03672 (unverified tier)",
        notes="seq_len=100 behaviour history; AUGRU attention gate.",
    )
)
